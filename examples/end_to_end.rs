//! End-to-end validation (DESIGN.md §4): all three layers composing.
//!
//! 1. Pretrain the tiny LM **from Rust** by executing the AOT
//!    `train_step` artifact (L2 JAX graph, lowered once at build time).
//! 2. Prune with Wanda / Wanda+CP / PermLLM_Wanda (LCP via the Rust
//!    trainer with the Hungarian hardening + AdamW loop; gradient math
//!    identical to the `lcp_grad` artifact).
//! 3. Evaluate perplexity of every variant through BOTH the host forward
//!    and the `lm_forward` artifact, verifying they agree.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! Results recorded in EXPERIMENTS.md §End-to-end.

use std::path::Path;

use permllm::coordinator::{pretrain, prune_model, PipelineCfg, PruneMethod};
use permllm::data::{batch_to_i32, sample_batch, Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::model::ParamStore;
use permllm::pruning::Metric;
use permllm::runtime::{literal_to_vec, tokens_to_literal, vec_to_literal, Engine};
use permllm::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    permllm::util::logging::init();
    let artifacts = Path::new("artifacts/tiny-m");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(2);
    }
    let model_path = Path::new("models/tiny-m.bin");

    // ---- 1. pretrain via the train_step artifact --------------------------
    if !model_path.exists() {
        let steps = std::env::var("E2E_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
        println!("pretraining tiny-m for {steps} steps via the AOT train_step artifact...");
        let losses = pretrain(artifacts, CorpusKind::C4Like, steps, 25, model_path)?;
        println!("loss curve (every 25 steps):");
        for (i, l) in losses.iter().enumerate() {
            if i % 25 == 0 || i + 1 == losses.len() {
                println!("  step {i:>4}: {l:.4}");
            }
        }
    } else {
        println!("using cached pretrained model {}", model_path.display());
    }
    let ps = ParamStore::load(model_path)?;

    // ---- 2. prune ----------------------------------------------------------
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: 30, lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    let methods = [
        PruneMethod::Dense,
        PruneMethod::OneShot(Metric::Wanda),
        PruneMethod::OneShotCp(Metric::Wanda),
        PruneMethod::PermLlm(Metric::Wanda),
    ];

    // ---- 3. evaluate through host AND artifact forward --------------------
    let mut engine = Engine::load_lazy(artifacts)?;
    println!("\n{:<16} {:>14} {:>16} {:>10}", "method", "host ppl", "artifact ppl", "time(s)");
    for method in methods {
        let pruned = prune_model(&ps, &calib, method, &cfg);
        let host_ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        let art_ppl = artifact_perplexity(&mut engine, &pruned.params, &evalc)?;
        println!(
            "{:<16} {:>14.3} {:>16.3} {:>10.1}",
            method.name(),
            host_ppl,
            art_ppl,
            pruned.elapsed_s
        );
        anyhow::ensure!(
            (host_ppl - art_ppl).abs() / host_ppl < 0.02,
            "host and artifact forward disagree: {host_ppl} vs {art_ppl}"
        );
    }
    println!("\nhost forward == lm_forward artifact on every variant: OK");
    Ok(())
}

/// Perplexity via the `lm_forward` artifact (the no-Python request path).
fn artifact_perplexity(
    engine: &mut Engine,
    ps: &ParamStore,
    corpus: &Corpus,
) -> anyhow::Result<f64> {
    let (cfg, batch_size, param_order) =
        (engine.manifest().config.clone(), engine.manifest().batch, engine.manifest().param_order.clone());
    let mut inputs: Vec<xla::Literal> = Vec::with_capacity(param_order.len() + 1);
    for (name, shape) in &param_order {
        inputs.push(vec_to_literal(ps.get(name).data(), shape)?);
    }
    let mut rng = Pcg32::new(555, 999);
    let batch = sample_batch(corpus, &mut rng, batch_size, cfg.seq_len);
    inputs.push(tokens_to_literal(&batch_to_i32(&batch), batch_size, cfg.seq_len)?);
    let outs = engine.run("lm_forward", &inputs)?;
    let logits = literal_to_vec(&outs[0])?; // [B, T, V]
    let (t, v) = (cfg.seq_len, cfg.vocab);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (bi, seq) in batch.iter().enumerate() {
        for pos in 0..t - 1 {
            let row = &logits[bi * t * v + pos * v..bi * t * v + (pos + 1) * v];
            let target = seq[pos + 1] as usize;
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = row.iter().map(|x| (x - mx).exp()).sum();
            total += -((row[target] - mx) as f64 - (z as f64).ln());
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}
