//! Sparse inference hot path: the `sparse_fwd` artifact (Pallas permute +
//! compressed 2:4 SpMM kernels) serving batched layer requests from Rust.
//!
//! Prunes one layer with PermLLM, compresses it, then drives the AOT
//! sparse kernel with batches of activations — verifying numerics against
//! the host dense path and reporting latency/throughput, serving-paper
//! style.
//!
//! ```bash
//! make artifacts && cargo run --release --example sparse_inference
//! ```

use std::path::Path;

use permllm::bench::trained_or_synth;
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::lcp::LcpCfg;
use permllm::model::{LinearKind, LinearRef};
use permllm::pruning::Metric;
use permllm::runtime::{literal_to_vec, mat_to_literal, vec_to_literal, Engine};
use permllm::sparsity::Compressed;
use permllm::tensor::Mat;
use permllm::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    permllm::util::logging::init();
    let artifacts = Path::new("artifacts/tiny-m");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(2);
    }
    let mut engine = Engine::load_lazy(artifacts)?;

    // Prune one layer with PermLLM.
    let (ps, prov) = trained_or_synth("tiny-m");
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: 20, lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    let pruned = prune_model(&ps, &calib, PruneMethod::PermLlm(Metric::Wanda), &cfg);
    let lin = LinearRef { layer: 0, kind: LinearKind::WGate };
    let res = &pruned.layers[&lin];
    let (c_out, c_in) = res.weight.shape();
    println!("layer {} ({prov}): [{c_out} x {c_in}], 2:4-compressed", lin.param_name());

    // Compress to the Sparse-Tensor-Core layout.
    let comp = Compressed::compress(&res.weight, &res.mask);
    let name = format!("sparse_fwd_{c_out}x{c_in}");
    let spec = engine
        .manifest()
        .artifact(&name)
        .ok_or_else(|| anyhow::anyhow!("missing {name}"))?
        .clone();
    let rows = spec.inputs.iter().find(|i| i.name == "x").unwrap().shape[0];
    let k = comp.k();

    let vals_lit = vec_to_literal(comp.vals(), &[c_out, k])?;
    let idx: Vec<i32> = comp.idx().iter().map(|&v| v as i32).collect();
    let idx_lit = xla::Literal::vec1(&idx).reshape(&[c_out as i64, k as i64])?;
    let src: Vec<i32> = res.src_of.iter().map(|&v| v as i32).collect();
    let src_lit = xla::Literal::vec1(&src).reshape(&[c_in as i64])?;

    // Serve batches.
    let mut rng = Pcg32::seeded(5);
    let n_requests = 32;
    let mut total_s = 0.0f64;
    let mut max_err = 0.0f32;
    for _ in 0..n_requests {
        let x = Mat::randn(rows, c_in, 1.0, &mut rng);
        let x_lit = mat_to_literal(&x)?;
        let t0 = std::time::Instant::now();
        let outs = engine.run(&name, &[vals_lit.clone(), idx_lit.clone(), x_lit, src_lit.clone()])?;
        total_s += t0.elapsed().as_secs_f64();
        let y = literal_to_vec(&outs[0])?;
        // Host reference: permute activations, sparse matmul.
        let want = x.permute_cols(&res.src_of).matmul_bt(&res.weight);
        for (a, b) in y.iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
    }
    let per_req_ms = total_s / n_requests as f64 * 1e3;
    let tok_per_s = (rows * n_requests) as f64 / total_s;
    println!(
        "{n_requests} requests x {rows} tokens: {per_req_ms:.2} ms/request, {tok_per_s:.0} tokens/s (interpret-mode Pallas kernels)"
    );
    println!("max |artifact - host| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "numeric mismatch");
    println!("sparse_fwd artifact matches the host sparse path: OK");
    Ok(())
}
