"""AOT lowering: JAX graphs -> HLO text artifacts + manifest.json.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the rust `xla` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts emitted for a model config (default tiny-m):

  train_step.hlo.txt           flat params/m/v (x P) + step(1) + tokens[B,T]
                               -> params'/m'/v' + step' + loss(1)
  lm_forward.hlo.txt           flat params + tokens[B,T] -> logits[B,T,V]
  lcp_grad_{o}x{i}.hlo.txt     (W,S,X,Y,W_P,P_hard,tau) -> (loss, dW_P)
  sinkhorn_soft_{n}x{b}.hlo.txt (W_P, tau) -> P_soft
  sparse_fwd_{o}x{i}.hlo.txt   (vals, idx, x, src_of) -> y   [Pallas permute
                               + nm_spmm inference hot path]

manifest.json records the model/train configs, the canonical parameter
order, and per-artifact input/output specs so the Rust runtime is fully
generic over shapes.

Usage:  python -m compile.aot --outdir ../artifacts [--config tiny-m]
        [--block 64] [--calib-rows 128] [--batch 8]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import lcp as lcp_mod
from . import model as model_mod
from .kernels import nm_spmm_pallas, permute_pallas

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape: Sequence[int], dtype=F32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_entry(name: str, shape: Sequence[int], dtype: str = "f32") -> dict:
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _lower(fn: Callable, specs: List[jax.ShapeDtypeStruct], path: str) -> None:
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(path, "w") as f:
        f.write(text)


def linear_shapes(cfg: model_mod.ModelConfig) -> List[Tuple[int, int]]:
    """Distinct [C_out, C_in] shapes of the prunable linear layers."""
    d, f = cfg.dim, cfg.ffn
    shapes = {(d, d), (f, d), (d, f)}
    return sorted(shapes)


def build(outdir: str, cfg_name: str, block: int, calib_rows: int, batch: int,
          m: int, keep: int, sinkhorn_iters: int) -> dict:
    cfg = model_mod.CONFIGS[cfg_name]
    tc = model_mod.TrainConfig()
    os.makedirs(outdir, exist_ok=True)
    names = model_mod.param_names(cfg)
    shapes = model_mod.param_shapes(cfg)
    n_params = len(names)
    artifacts = []

    # ---- train_step -------------------------------------------------------
    def train_step_flat(*args):
        params = list(args[:n_params])
        m_state = list(args[n_params:2 * n_params])
        v_state = list(args[2 * n_params:3 * n_params])
        step = args[3 * n_params].reshape(())
        tokens = args[3 * n_params + 1]
        new_p, new_m, new_v, t, loss = model_mod.train_step(
            cfg, tc, params, m_state, v_state, step, tokens)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (t.reshape(1), loss.reshape(1))

    p_specs = [_spec(shapes[n]) for n in names]
    ts_specs = p_specs * 3 + [_spec((1,)), _spec((batch, cfg.seq_len), I32)]
    _lower(train_step_flat, ts_specs, os.path.join(outdir, "train_step.hlo.txt"))
    artifacts.append({
        "name": "train_step",
        "file": "train_step.hlo.txt",
        "kind": "train_step",
        "inputs": (
            [_io_entry(f"param.{n}", shapes[n]) for n in names]
            + [_io_entry(f"m.{n}", shapes[n]) for n in names]
            + [_io_entry(f"v.{n}", shapes[n]) for n in names]
            + [_io_entry("step", (1,)), _io_entry("tokens", (batch, cfg.seq_len), "i32")]
        ),
        "outputs": (
            [_io_entry(f"param.{n}", shapes[n]) for n in names]
            + [_io_entry(f"m.{n}", shapes[n]) for n in names]
            + [_io_entry(f"v.{n}", shapes[n]) for n in names]
            + [_io_entry("step", (1,)), _io_entry("loss", (1,))]
        ),
    })

    # ---- lm_forward -------------------------------------------------------
    def lm_forward_flat(*args):
        params = list(args[:n_params])
        tokens = args[n_params]
        return (model_mod.forward(cfg, params, tokens),)

    _lower(lm_forward_flat, p_specs + [_spec((batch, cfg.seq_len), I32)],
           os.path.join(outdir, "lm_forward.hlo.txt"))
    artifacts.append({
        "name": "lm_forward",
        "file": "lm_forward.hlo.txt",
        "kind": "lm_forward",
        "inputs": [_io_entry(f"param.{n}", shapes[n]) for n in names]
        + [_io_entry("tokens", (batch, cfg.seq_len), "i32")],
        "outputs": [_io_entry("logits", (batch, cfg.seq_len, cfg.vocab))],
    })

    # ---- per linear shape: lcp_grad / sinkhorn_soft / sparse_fwd ----------
    sinkhorn_done = set()
    for (c_out, c_in) in linear_shapes(cfg):
        n_b = c_in // block
        tag = f"{c_out}x{c_in}"

        def lcp_grad_fn(w, s, x, y, w_p, p_hard, tau, _m=m, _keep=keep):
            loss, grad = lcp_mod.lcp_grad(
                w, s, x, y, w_p, p_hard, tau.reshape(()),
                m=_m, keep=_keep, iters=sinkhorn_iters)
            return loss.reshape(1), grad

        specs = [
            _spec((c_out, c_in)), _spec((c_out, c_in)),
            _spec((calib_rows, c_in)), _spec((calib_rows, c_out)),
            _spec((n_b, block, block)), _spec((n_b, block, block)),
            _spec((1,)),
        ]
        fname = f"lcp_grad_{tag}.hlo.txt"
        _lower(lcp_grad_fn, specs, os.path.join(outdir, fname))
        artifacts.append({
            "name": f"lcp_grad_{tag}",
            "file": fname,
            "kind": "lcp_grad",
            "c_out": c_out, "c_in": c_in, "n_b": n_b, "block": block,
            "m": m, "keep": keep,
            "inputs": [
                _io_entry("w", (c_out, c_in)), _io_entry("s", (c_out, c_in)),
                _io_entry("x", (calib_rows, c_in)), _io_entry("y", (calib_rows, c_out)),
                _io_entry("w_p", (n_b, block, block)),
                _io_entry("p_hard", (n_b, block, block)),
                _io_entry("tau", (1,)),
            ],
            "outputs": [_io_entry("loss", (1,)), _io_entry("d_w_p", (n_b, block, block))],
        })

        if n_b not in sinkhorn_done:
            sinkhorn_done.add(n_b)

            def sink_fn(w_p, tau):
                return (lcp_mod.sinkhorn_soft(w_p, tau.reshape(()), iters=sinkhorn_iters),)

            sname = f"sinkhorn_soft_{n_b}x{block}.hlo.txt"
            _lower(sink_fn, [_spec((n_b, block, block)), _spec((1,))],
                   os.path.join(outdir, sname))
            artifacts.append({
                "name": f"sinkhorn_soft_{n_b}x{block}",
                "file": sname,
                "kind": "sinkhorn_soft",
                "n_b": n_b, "block": block, "iters": sinkhorn_iters,
                "inputs": [_io_entry("w_p", (n_b, block, block)), _io_entry("tau", (1,))],
                "outputs": [_io_entry("p_soft", (n_b, block, block))],
            })

        # Sparse inference hot path: permute activations then compressed spmm.
        k = c_in // m * keep

        def sparse_fwd_fn(vals, idx, x, src_of):
            xp = permute_pallas(x, src_of)
            return (nm_spmm_pallas(vals, idx, xp),)

        spname = f"sparse_fwd_{tag}.hlo.txt"
        _lower(sparse_fwd_fn,
               [_spec((c_out, k)), _spec((c_out, k), I32),
                _spec((calib_rows, c_in)), _spec((c_in,), I32)],
               os.path.join(outdir, spname))
        artifacts.append({
            "name": f"sparse_fwd_{tag}",
            "file": spname,
            "kind": "sparse_fwd",
            "c_out": c_out, "c_in": c_in, "k": k, "m": m, "keep": keep,
            "inputs": [
                _io_entry("vals", (c_out, k)), _io_entry("idx", (c_out, k), "i32"),
                _io_entry("x", (calib_rows, c_in)), _io_entry("src_of", (c_in,), "i32"),
            ],
            "outputs": [_io_entry("y", (calib_rows, c_out))],
        })

    manifest = {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "dim": cfg.dim,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads, "ffn": cfg.ffn,
            "seq_len": cfg.seq_len, "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps,
        },
        "train": {"lr": tc.lr, "beta1": tc.beta1, "beta2": tc.beta2,
                  "eps": tc.eps, "weight_decay": tc.weight_decay,
                  "batch": batch},
        "lcp": {"block": block, "calib_rows": calib_rows, "m": m,
                "keep": keep, "sinkhorn_iters": sinkhorn_iters},
        "param_order": [{"name": n, "shape": list(shapes[n])} for n in names],
        "artifacts": artifacts,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--config", default="tiny-m", choices=sorted(model_mod.CONFIGS))
    ap.add_argument("--block", type=int, default=64)
    ap.add_argument("--calib-rows", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--keep", type=int, default=2)
    ap.add_argument("--sinkhorn-iters", type=int, default=5)
    args = ap.parse_args()
    manifest = build(args.outdir, args.config, args.block, args.calib_rows,
                     args.batch, args.m, args.keep, args.sinkhorn_iters)
    total = sum(os.path.getsize(os.path.join(args.outdir, a["file"]))
                for a in manifest["artifacts"])
    print(f"wrote {len(manifest['artifacts'])} artifacts "
          f"({total / 1e6:.1f} MB) + manifest.json to {args.outdir}")


if __name__ == "__main__":
    main()
