"""Layer-1 Pallas kernels for PermLLM (interpret=True on CPU PJRT).

Every kernel has a pure-jnp oracle in :mod:`ref`; kernels that participate
in gradients (`sinkhorn`, `nm_mask_ste`) carry a custom_vjp whose backward
is the exact VJP of the oracle.
"""

from .ref import (  # noqa: F401
    nm_compress_ref,
    nm_mask_ref,
    nm_spmm_ref,
    permute_ref,
    sinkhorn_ref,
    soft_mask_ref,
)
from .sinkhorn import sinkhorn, sinkhorn_pallas  # noqa: F401
from .nm_mask import nm_mask_ste, nm_mask_pallas  # noqa: F401
from .permute import permute_pallas  # noqa: F401
from .nm_spmm import nm_spmm_pallas  # noqa: F401
