"""Pallas kernel: hard N:M mask selection (paper Eq. 7/8).

Given an importance matrix ``scores`` [C_out, C_in], emit the {0,1} mask
that keeps the ``keep = M - N`` largest entries in every group of ``m``
consecutive input channels.

TPU mapping (DESIGN.md §7): the grid tiles C_out; each kernel instance
ranks its [TILE, C_in] slab entirely in VMEM.  Ranking over a group of
m <= 8 lanes is a fixed sequence of VPU compares (we materialize it as a
rank-from-stable-argsort, which XLA lowers to a small sort network).

``nm_mask_ste`` wraps the kernel in the paper's Eq. 9 straight-through
estimator: forward = hard Pallas mask, backward = gradient of the
group-softmax soft mask — this is exactly how the mask enters the
``lcp_grad`` artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

_TILE = 8  # C_out rows per grid step


def _nm_mask_kernel(s_ref, out_ref, *, m: int, keep: int):
    s = s_ref[...]
    rows, c_in = s.shape
    g = s.reshape(rows, c_in // m, m)
    order = jnp.argsort(-g, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    out_ref[...] = (ranks < keep).astype(s.dtype).reshape(rows, c_in)


def nm_mask_pallas(scores: jnp.ndarray, m: int, keep: int) -> jnp.ndarray:
    """Raw Pallas call: scores [C_out, C_in] -> {0,1} mask [C_out, C_in]."""
    c_out, c_in = scores.shape
    tile = _TILE if c_out % _TILE == 0 else 1
    kernel = functools.partial(_nm_mask_kernel, m=m, keep=keep)
    return pl.pallas_call(
        kernel,
        grid=(c_out // tile,),
        in_specs=[pl.BlockSpec((tile, c_in), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, c_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_out, c_in), scores.dtype),
        interpret=True,
    )(scores)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def nm_mask_ste(scores: jnp.ndarray, m: int, keep: int) -> jnp.ndarray:
    """STE mask: hard N:M selection forward, soft-mask (Eq. 9) gradient."""
    return nm_mask_pallas(scores, m, keep)


def _ste_fwd(scores, m, keep):
    return nm_mask_pallas(scores, m, keep), scores


def _ste_bwd(m, keep, scores, g):
    # d(hard)/d(scores) ~= d(softmax over each group)/d(scores)   (Eq. 9)
    _, vjp = jax.vjp(lambda s: _ref.soft_mask_ref(s, m), scores)
    (ds,) = vjp(g)
    return (ds,)


nm_mask_ste.defvjp(_ste_fwd, _ste_bwd)
