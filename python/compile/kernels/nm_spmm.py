"""Pallas kernel: compressed N:M sparse x dense matmul.

The Sparse-Tensor-Core analogue for TPU (DESIGN.md §7).  NVIDIA's 2:4 GEMM
multiplies a compressed [C_out, C_in/2] value matrix against activations
selected by 2-bit metadata inside the tensor core.  The TPU has no sparse
MXU, so the equivalent win is *memory traffic*: stream the compressed
values + int32 indices HBM->VMEM (half the weight bytes for 2:4),
decompress to a dense tile **in VMEM** via a one-hot contraction, and feed
the MXU a standard dense tile.  Decompress-then-MXU beats per-element
gather on a systolic array.

Layout: ``vals``/``idx`` [C_out, K] with K = C_in/m*keep, produced by
``ref.nm_compress_ref`` (indices are absolute column ids, ascending within
each group).  y[t, o] = sum_k vals[o, k] * x[t, idx[o, k]].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_OUT_TILE = 8


def _nm_spmm_kernel(vals_ref, idx_ref, x_ref, out_ref):
    vals = vals_ref[...]           # [TILE, K]
    idx = idx_ref[...]             # [TILE, K]
    x = x_ref[...]                 # [T, C_in]
    c_in = x.shape[-1]
    # Decompress in VMEM: one-hot scatter of compressed values to a dense
    # [TILE, C_in] tile, then a standard dense contraction (MXU-shaped).
    onehot = (idx[..., None] == jnp.arange(c_in)[None, None, :]).astype(vals.dtype)
    w_dense = jnp.einsum("ok,okc->oc", vals, onehot)
    out_ref[...] = jnp.dot(x, w_dense.T)


def nm_spmm_pallas(vals: jnp.ndarray, idx: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Compressed sparse matmul: ([C_out,K], [C_out,K] i32, [T,C_in]) -> [T,C_out]."""
    c_out, _k = vals.shape
    t, c_in = x.shape
    tile = _OUT_TILE if c_out % _OUT_TILE == 0 else 1
    return pl.pallas_call(
        _nm_spmm_kernel,
        grid=(c_out // tile,),
        in_specs=[
            pl.BlockSpec((tile, _k), lambda i: (i, 0)),
            pl.BlockSpec((tile, _k), lambda i: (i, 0)),
            pl.BlockSpec((t, c_in), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, c_out), x.dtype),
        interpret=True,
    )(vals, idx.astype(jnp.int32), x)
