"""Pallas kernel: channel permutation (gather along the channel axis).

This is the Pallas analogue of the paper's custom CUDA permutation kernel
(§4, Table 3: 84x over the PyTorch index-select).  The CUDA kernel's win is
a coalesced gather; the TPU rethink (DESIGN.md §7) is a *lane permutation*:

  * the permutation index vector ``src_of`` is small (C_in int32) and rides
    in via a full-width block (SMEM-class operand on real TPU);
  * the activation matrix is tiled [ROW_TILE, C_in]; each VMEM tile is read
    once and written once — the gather happens entirely within registers/
    VMEM, so the kernel is purely bandwidth-bound with no HBM re-reads
    (the PyTorch baseline materializes an intermediate index tensor and
    re-reads the source per output element).

Used forward-only (inference path of the pruned model), so no custom_vjp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ROW_TILE = 8


def _permute_kernel(idx_ref, x_ref, out_ref):
    out_ref[...] = jnp.take(x_ref[...], idx_ref[...], axis=-1)


def permute_pallas(x: jnp.ndarray, src_of: jnp.ndarray) -> jnp.ndarray:
    """out[..., j] = x[..., src_of[j]] for x [T, C_in], src_of [C_in] int32."""
    t, c_in = x.shape
    tile = _ROW_TILE if t % _ROW_TILE == 0 else 1
    return pl.pallas_call(
        _permute_kernel,
        grid=(t // tile,),
        in_specs=[
            pl.BlockSpec((c_in,), lambda i: (0,)),      # index vector: broadcast
            pl.BlockSpec((tile, c_in), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, c_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, c_in), x.dtype),
        interpret=True,
    )(src_of.astype(jnp.int32), x)
