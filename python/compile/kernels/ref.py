"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: each Pallas kernel in this package
must match its oracle to float32 tolerance on every shape/dtype hypothesis
generates (python/tests/test_kernels.py), and the Rust host implementations
mirror the same math (rust/src/lcp/sinkhorn.rs etc.).

Conventions (match the paper and the Rust side):
  * weights are [C_out, C_in];
  * a permutation is stored as ``src_of`` with ``out[:, j] = in[:, src_of[j]]``
    (i.e. ``src_of[j] = i`` where the permutation matrix has P[i, j] = 1,
    so ``W @ P`` == ``permute_ref(W, src_of)`` and ``P.T @ x`` gathers
    activations with the same index vector);
  * N:M sparsity follows the paper's notation: N of every M consecutive
    input channels are ZEROED, ``keep = M - N`` survive per group.
"""

from __future__ import annotations

import jax.numpy as jnp


def sinkhorn_ref(w_p: jnp.ndarray, tau: float | jnp.ndarray, iters: int) -> jnp.ndarray:
    """Temperature-scaled Sinkhorn normalization (paper Eqs. 2-5).

    ``w_p``: [..., B, B] batched logits. Returns the soft permutation matrix
    S^L(w_p / tau): exp, then ``iters`` rounds of row- then column-
    normalization.  ``iters == 0`` returns plain ``exp(w_p / tau)`` (the
    paper's Table 4 ablation point).
    """
    s = jnp.exp(w_p / tau)
    for _ in range(iters):
        s = s / jnp.sum(s, axis=-1, keepdims=True)  # T_r: rows sum to 1
        s = s / jnp.sum(s, axis=-2, keepdims=True)  # T_c: cols sum to 1
    return s


def nm_mask_ref(scores: jnp.ndarray, m: int, keep: int) -> jnp.ndarray:
    """Hard N:M mask (paper Eq. 7): per group of ``m`` consecutive input
    channels, set the ``keep`` largest-score entries to 1.

    ``scores``: [C_out, C_in]; returns a {0,1} float mask of the same shape.
    Ties broken toward the lower index (stable, matches the Rust side).
    """
    c_out, c_in = scores.shape
    g = scores.reshape(c_out, c_in // m, m)
    # Stable argsort: equal scores keep ascending index order, so the lower
    # index wins a tie for a retained slot.
    order = jnp.argsort(-g, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = (ranks < keep).astype(scores.dtype)
    return mask.reshape(c_out, c_in)


def soft_mask_ref(scores: jnp.ndarray, m: int) -> jnp.ndarray:
    """Soft mask (paper Eq. 9): group-wise softmax over ``m`` channels."""
    c_out, c_in = scores.shape
    g = scores.reshape(c_out, c_in // m, m)
    g = g - jnp.max(g, axis=-1, keepdims=True)
    e = jnp.exp(g)
    sm = e / jnp.sum(e, axis=-1, keepdims=True)
    return sm.reshape(c_out, c_in)


def permute_ref(x: jnp.ndarray, src_of: jnp.ndarray) -> jnp.ndarray:
    """Channel permutation along the last axis: out[..., j] = x[..., src_of[j]]."""
    return jnp.take(x, src_of, axis=-1)


def nm_compress_ref(w: jnp.ndarray, mask: jnp.ndarray, m: int, keep: int):
    """Compress an N:M-masked weight into (values, indices).

    ``w``, ``mask``: [C_out, C_in]. Returns values [C_out, C_in//m*keep]
    and int32 indices (absolute column ids) of the retained entries, in
    ascending column order inside each group — the layout ``nm_spmm``
    consumes (the Sparse-Tensor-Core metadata analogue).
    """
    c_out, c_in = w.shape
    groups = c_in // m
    mg = mask.reshape(c_out, groups, m)
    # Retained positions, ascending: sort by (1 - mask, index).
    key = (1.0 - mg) * m + jnp.arange(m)[None, None, :]
    pos = jnp.argsort(key, axis=-1, stable=True)[..., :keep]  # [C_out, G, keep]
    col = pos + (jnp.arange(groups) * m)[None, :, None]
    vals = jnp.take_along_axis(w.reshape(c_out, groups, m), pos, axis=-1)
    return vals.reshape(c_out, groups * keep), col.reshape(c_out, groups * keep).astype(jnp.int32)


def nm_spmm_ref(vals: jnp.ndarray, idx: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Compressed N:M sparse matmul oracle.

    ``vals``/``idx``: [C_out, K] compressed weights (from nm_compress_ref),
    ``x``: [T, C_in] activations. Returns y [T, C_out] with
    y[t, o] = sum_k vals[o, k] * x[t, idx[o, k]].
    """
    gathered = x[:, idx]  # [T, C_out, K]
    return jnp.einsum("tok,ok->to", gathered, vals)
