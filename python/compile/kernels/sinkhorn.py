"""Pallas kernel: batched block Sinkhorn normalization (paper Eqs. 2-5).

This is the hot spot of learnable-channel-permutation training: every LCP
step normalizes ``N_B`` independent ``B x B`` logit blocks (B = 64 default)
into doubly-stochastic soft permutation matrices.

TPU mapping (DESIGN.md §7): one grid step per block; the whole ``B x B``
tile lives in VMEM across all ``iters`` row/column normalizations — zero
HBM round-trips between iterations.  Row and column sums are VPU
reductions; no MXU involvement.  ``tau`` rides in as a (1, 1) scalar.

The kernel is wrapped in a ``custom_vjp`` whose backward pass is the exact
VJP of the jnp reference (``ref.sinkhorn_ref``), so the kernel composes
with ``jax.grad`` inside the ``lcp_grad`` artifact while the forward value
comes from Pallas.  Equivalence kernel == ref is property-tested in
python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _sinkhorn_kernel(tau_ref, wp_ref, out_ref, *, iters: int):
    """One block: out = S^iters(exp(wp / tau)) with row-then-col normalization."""
    tau = tau_ref[0, 0]
    s = jnp.exp(wp_ref[...] / tau)
    for _ in range(iters):
        s = s / jnp.sum(s, axis=-1, keepdims=True)
        s = s / jnp.sum(s, axis=-2, keepdims=True)
    out_ref[...] = s


def sinkhorn_pallas(w_p: jnp.ndarray, tau: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Raw Pallas call: w_p [N_B, B, B], tau scalar array -> [N_B, B, B]."""
    n_b, b, _ = w_p.shape
    tau2 = jnp.asarray(tau, jnp.float32).reshape(1, 1)
    kernel = functools.partial(_sinkhorn_kernel, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(n_b,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),       # tau: broadcast scalar
            pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),  # one block per step
        ],
        out_specs=pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_b, b, b), jnp.float32),
        interpret=True,
    )(tau2, w_p)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def sinkhorn(w_p: jnp.ndarray, tau: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Differentiable Sinkhorn: Pallas forward, reference-VJP backward."""
    return sinkhorn_pallas(w_p, tau, iters)


def _sinkhorn_fwd(w_p, tau, iters):
    return sinkhorn_pallas(w_p, tau, iters), (w_p, tau)


def _sinkhorn_bwd(iters, res, g):
    w_p, tau = res
    _, vjp = jax.vjp(lambda wp, t: _ref.sinkhorn_ref(wp, t, iters), w_p, tau)
    dw_p, dtau = vjp(g)
    return dw_p, dtau


sinkhorn.defvjp(_sinkhorn_fwd, _sinkhorn_bwd)
