"""Layer-2 compute graphs for learnable channel permutation (paper §3-§4).

Two graphs get AOT-lowered per linear-layer shape:

``sinkhorn_soft``  (W_P [N_B,B,B], tau) -> P_soft
    Forward-only soft permutation, computed by the L1 Pallas kernel.  The
    Rust coordinator hardens P_soft into strict permutations with the
    Hungarian algorithm (Eq. 6) — discrete, sequential work that belongs on
    the host.

``lcp_grad``  (W, S, X, Y, W_P, P_hard, tau) -> (loss, dW_P)
    One LCP optimization step's loss and gradient.  The STE of §3.1 is
    factored across the language boundary: the graph receives the *hard*
    permutation as an input and forms

        P_ste = P_hard + P_soft - stop_gradient(P_soft)

    so the forward value uses the strict permutation while the backward
    pass flows through the Sinkhorn soft matrix.  The N:M mask is re-derived
    from the permuted importance S' = S . P each call (Eq. 8) through the
    Pallas ``nm_mask_ste`` kernel (hard forward, group-softmax backward,
    Eq. 9).  The loss is the paper's cosine discrepancy (Eq. 10) between
    the dense output Y and the sparse layer output, averaged over rows.

Shapes: W,S [C_out, C_in]; X [T, C_in]; Y [T, C_out]; W_P, P_hard
[N_B, B, B] with N_B*B == C_in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import nm_mask_ste, sinkhorn, sinkhorn_pallas

SINKHORN_ITERS = 5  # paper default (Table 4 ablates 0 vs 5)


def apply_block_perm(a: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Right-multiply by the block-diagonal permutation: A . diag(P_1..P_NB).

    ``a``: [R, C_in] (rows = C_out for weights/scores, rows = T for
    activations — note (P^T x)_j = sum_i P_ij x_i uses the same contraction),
    ``p``: [N_B, B, B].  Returns [R, C_in].
    """
    r, c_in = a.shape
    n_b, b, _ = p.shape
    blocks = a.reshape(r, n_b, b)
    out = jnp.einsum("rnb,nbc->rnc", blocks, p)
    return out.reshape(r, c_in)


def sinkhorn_soft(w_p: jnp.ndarray, tau: jnp.ndarray, iters: int = SINKHORN_ITERS) -> jnp.ndarray:
    """Forward-only soft permutation for the host-side Hungarian hardening."""
    return sinkhorn_pallas(w_p, tau, iters)


def lcp_loss(
    w: jnp.ndarray,
    s: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    w_p: jnp.ndarray,
    p_hard: jnp.ndarray,
    tau: jnp.ndarray,
    *,
    m: int = 4,
    keep: int = 2,
    iters: int = SINKHORN_ITERS,
) -> jnp.ndarray:
    """Cosine discrepancy of the permuted+pruned layer vs the dense output."""
    p_soft = sinkhorn(w_p, tau, iters)
    p_ste = p_hard + p_soft - jax.lax.stop_gradient(p_soft)

    w_perm = apply_block_perm(w, p_ste)   # W . P_B
    s_perm = apply_block_perm(s, p_ste)   # S . P_B   (Eq. 8 input)
    x_perm = apply_block_perm(x, p_ste)   # (P_B^T x)^T rows

    mask = nm_mask_ste(s_perm, m, keep)   # hard fwd / softmax-STE bwd (Eq. 9)
    y_sp = x_perm @ (mask * w_perm).T     # [T, C_out]

    # Eq. 10, averaged over calibration rows.
    dot = jnp.sum(y * y_sp, axis=-1)
    nrm = jnp.linalg.norm(y, axis=-1) * jnp.linalg.norm(y_sp, axis=-1) + 1e-8
    return jnp.mean(1.0 - dot / nrm)


def lcp_grad(w, s, x, y, w_p, p_hard, tau, *, m: int = 4, keep: int = 2,
             iters: int = SINKHORN_ITERS):
    """(loss, dL/dW_P) for one LCP step — the AOT artifact body."""
    loss, grad = jax.value_and_grad(
        lambda wp: lcp_loss(w, s, x, y, wp, p_hard, tau, m=m, keep=keep, iters=iters)
    )(w_p)
    return loss, grad
