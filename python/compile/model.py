"""Layer-2 JAX model: a tiny LLaMA-style causal LM.

This is the end-to-end validation model (DESIGN.md §4): pretrained *from
Rust* by repeatedly executing the AOT ``train_step`` artifact, evaluated
from Rust via the ``lm_forward`` artifact, and pruned by the PermLLM
pipeline.  The Rust host forward (rust/src/model/forward.rs) mirrors this
math exactly and is cross-checked against ``lm_forward`` in integration
tests, so every operation here is chosen to be reproducible in plain f32:

  * RMSNorm (eps 1e-5), split-half RoPE (theta 10000), causal softmax
    attention, SwiGLU MLP, untied LM head;
  * weights are stored [C_out, C_in] (paper convention) and applied as
    ``x @ W.T``;
  * parameters travel as a FLAT LIST in the order given by
    :func:`param_names` — the AOT manifest records this order and the Rust
    side follows it verbatim.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for the tiny LM."""

    name: str = "tiny-m"
    vocab: int = 256
    dim: int = 128
    n_layers: int = 4
    n_heads: int = 4
    ffn: int = 256
    seq_len: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads


#: Named model sizes used across the experiment harness (Table 1 "models").
CONFIGS: Dict[str, ModelConfig] = {
    "tiny-s": ModelConfig(name="tiny-s", vocab=256, dim=64, n_layers=2, n_heads=2, ffn=128, seq_len=128),
    "tiny-m": ModelConfig(name="tiny-m", vocab=256, dim=128, n_layers=4, n_heads=4, ffn=256, seq_len=128),
    "tiny-l": ModelConfig(name="tiny-l", vocab=256, dim=192, n_layers=6, n_heads=6, ffn=384, seq_len=128),
}


def param_names(cfg: ModelConfig) -> List[str]:
    """Canonical flat parameter order (the artifact I/O contract)."""
    names = ["tok_embed"]
    for l in range(cfg.n_layers):
        names += [
            f"layers.{l}.attn_norm",
            f"layers.{l}.wq",
            f"layers.{l}.wk",
            f"layers.{l}.wv",
            f"layers.{l}.wo",
            f"layers.{l}.mlp_norm",
            f"layers.{l}.w_gate",
            f"layers.{l}.w_up",
            f"layers.{l}.w_down",
        ]
    names += ["final_norm", "lm_head"]
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Shape of every parameter, keyed by canonical name ([C_out, C_in])."""
    d, f, v = cfg.dim, cfg.ffn, cfg.vocab
    shapes: Dict[str, Tuple[int, ...]] = {"tok_embed": (v, d)}
    for l in range(cfg.n_layers):
        shapes[f"layers.{l}.attn_norm"] = (d,)
        shapes[f"layers.{l}.wq"] = (d, d)
        shapes[f"layers.{l}.wk"] = (d, d)
        shapes[f"layers.{l}.wv"] = (d, d)
        shapes[f"layers.{l}.wo"] = (d, d)
        shapes[f"layers.{l}.mlp_norm"] = (d,)
        shapes[f"layers.{l}.w_gate"] = (f, d)
        shapes[f"layers.{l}.w_up"] = (f, d)
        shapes[f"layers.{l}.w_down"] = (d, f)
    shapes["final_norm"] = (d,)
    shapes["lm_head"] = (v, d)
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Deterministic init (numpy PCG64 so Rust never needs to replicate it)."""
    rng = np.random.default_rng(seed)
    shapes = param_shapes(cfg)
    out: List[jnp.ndarray] = []
    for name in param_names(cfg):
        shape = shapes[name]
        if name.endswith("norm"):
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[-1]
            arr = rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
        out.append(jnp.asarray(arr))
    return out


def _rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float) -> jnp.ndarray:
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x / rms * g


def _rope(x: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Split-half RoPE over [T, H, hd]."""
    t, _h, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) * 2.0 / hd)
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def forward(cfg: ModelConfig, params: List[jnp.ndarray], tokens: jnp.ndarray) -> jnp.ndarray:
    """Causal LM forward: tokens [B, T] int32 -> logits [B, T, V]."""
    p = dict(zip(param_names(cfg), params))
    d, h, hd = cfg.dim, cfg.n_heads, cfg.head_dim

    def one(seq: jnp.ndarray) -> jnp.ndarray:
        t = seq.shape[0]
        x = p["tok_embed"][seq]  # [T, d]
        causal = jnp.tril(jnp.ones((t, t), jnp.float32))
        neg = jnp.float32(-1e9)
        for l in range(cfg.n_layers):
            a = _rmsnorm(x, p[f"layers.{l}.attn_norm"], cfg.norm_eps)
            q = (a @ p[f"layers.{l}.wq"].T).reshape(t, h, hd)
            k = (a @ p[f"layers.{l}.wk"].T).reshape(t, h, hd)
            v = (a @ p[f"layers.{l}.wv"].T).reshape(t, h, hd)
            q = _rope(q, cfg.rope_theta)
            k = _rope(k, cfg.rope_theta)
            att = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(jnp.float32(hd))
            att = jnp.where(causal[None, :, :] > 0, att, neg)
            att = jax.nn.softmax(att, axis=-1)
            o = jnp.einsum("hqk,khd->qhd", att, v).reshape(t, d)
            x = x + o @ p[f"layers.{l}.wo"].T
            m = _rmsnorm(x, p[f"layers.{l}.mlp_norm"], cfg.norm_eps)
            gate = m @ p[f"layers.{l}.w_gate"].T
            up = m @ p[f"layers.{l}.w_up"].T
            x = x + (jax.nn.silu(gate) * up) @ p[f"layers.{l}.w_down"].T
        x = _rmsnorm(x, p["final_norm"], cfg.norm_eps)
        return x @ p["lm_head"].T

    return jax.vmap(one)(tokens)


def lm_loss(cfg: ModelConfig, params: List[jnp.ndarray], tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy over all positions (nats)."""
    logits = forward(cfg, params, tokens)  # [B, T, V]
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """AdamW hyperparameters baked into the train_step artifact."""

    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01


def train_step(
    cfg: ModelConfig,
    tc: TrainConfig,
    params: List[jnp.ndarray],
    m_state: List[jnp.ndarray],
    v_state: List[jnp.ndarray],
    step: jnp.ndarray,
    tokens: jnp.ndarray,
):
    """One AdamW step.  Returns (params', m', v', step', loss).

    Flat-list I/O keeps the artifact signature a plain tuple of arrays in
    ``param_names`` order (x3 for params/m/v), executable from Rust.
    """
    loss, grads = jax.value_and_grad(lambda ps: lm_loss(cfg, ps, tokens))(params)
    t = step + 1.0
    b1, b2 = jnp.float32(tc.beta1), jnp.float32(tc.beta2)
    new_p, new_m, new_v = [], [], []
    for pa, mo, vo, g in zip(params, m_state, v_state, grads):
        m_n = b1 * mo + (1.0 - b1) * g
        v_n = b2 * vo + (1.0 - b2) * g * g
        m_hat = m_n / (1.0 - b1 ** t)
        v_hat = v_n / (1.0 - b2 ** t)
        upd = m_hat / (jnp.sqrt(v_hat) + tc.eps) + tc.weight_decay * pa
        new_p.append(pa - tc.lr * upd)
        new_m.append(m_n)
        new_v.append(v_n)
    return new_p, new_m, new_v, t, loss
