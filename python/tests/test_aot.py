"""AOT pipeline: HLO text emission + manifest consistency.

Lowers a trivial config's graphs to a temp dir and checks the manifest
contract the Rust runtime depends on (shapes, artifact inventory, HLO text
parseability markers). The heavyweight end-to-end execution check lives on
the Rust side (tests/model_parity.rs, tests/lcp_cross_check.rs).
"""

import json
import os

import pytest

from compile import aot
from compile import model as model_mod


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, "tiny-s", block=32, calib_rows=16, batch=2,
                         m=4, keep=2, sinkhorn_iters=3)
    return out, manifest


def test_manifest_lists_all_artifacts(built):
    out, manifest = built
    kinds = [a["kind"] for a in manifest["artifacts"]]
    assert kinds.count("train_step") == 1
    assert kinds.count("lm_forward") == 1
    # tiny-s has 3 distinct linear shapes -> 3 lcp_grad + 3 sparse_fwd.
    assert kinds.count("lcp_grad") == 3
    assert kinds.count("sparse_fwd") == 3
    assert kinds.count("sinkhorn_soft") >= 1
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(out, a["file"])), a["name"]


def test_hlo_files_are_text_modules(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        head = open(os.path.join(out, a["file"])).read(200)
        assert "HloModule" in head, f"{a['name']} is not HLO text"


def test_param_order_matches_model(built):
    _, manifest = built
    cfg = model_mod.CONFIGS["tiny-s"]
    names = [p["name"] for p in manifest["param_order"]]
    assert names == model_mod.param_names(cfg)
    shapes = model_mod.param_shapes(cfg)
    for p in manifest["param_order"]:
        assert tuple(p["shape"]) == shapes[p["name"]]


def test_train_step_io_arity(built):
    _, manifest = built
    cfg = model_mod.CONFIGS["tiny-s"]
    n = len(model_mod.param_names(cfg))
    ts = next(a for a in manifest["artifacts"] if a["kind"] == "train_step")
    assert len(ts["inputs"]) == 3 * n + 2   # params, m, v, step, tokens
    assert len(ts["outputs"]) == 3 * n + 2  # params', m', v', step', loss


def test_lcp_grad_shapes_consistent(built):
    _, manifest = built
    for a in manifest["artifacts"]:
        if a["kind"] != "lcp_grad":
            continue
        assert a["n_b"] * a["block"] == a["c_in"]
        w_p = next(i for i in a["inputs"] if i["name"] == "w_p")
        assert w_p["shape"] == [a["n_b"], a["block"], a["block"]]
        out = next(o for o in a["outputs"] if o["name"] == "d_w_p")
        assert out["shape"] == w_p["shape"]


def test_manifest_is_valid_json_on_disk(built):
    out, _ = built
    with open(os.path.join(out, "manifest.json")) as f:
        j = json.load(f)
    assert j["config"]["name"] == "tiny-s"
    assert j["lcp"]["block"] == 32
