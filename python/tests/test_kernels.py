"""Pallas kernel vs pure-jnp oracle — the L1 correctness signal.

Hypothesis sweeps shapes/seeds; every kernel must match its ref oracle to
f32 tolerance, and the custom_vjp wrappers must differentiate like the
reference graph.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    nm_compress_ref,
    nm_mask_pallas,
    nm_mask_ref,
    nm_mask_ste,
    nm_spmm_pallas,
    nm_spmm_ref,
    permute_pallas,
    permute_ref,
    sinkhorn,
    sinkhorn_pallas,
    sinkhorn_ref,
    soft_mask_ref,
)

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=15, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------- sinkhorn
@settings(**SETTINGS)
@given(
    n_b=st.integers(1, 4),
    b=st.sampled_from([4, 8, 16, 64]),
    iters=st.integers(0, 7),
    tau=st.sampled_from([0.1, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sinkhorn_pallas_matches_ref(n_b, b, iters, tau, seed):
    rng = np.random.default_rng(seed)
    w_p = rand(rng, n_b, b, b)
    got = sinkhorn_pallas(w_p, jnp.float32(tau), iters)
    want = sinkhorn_ref(w_p, tau, iters)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_sinkhorn_is_doubly_stochastic():
    rng = np.random.default_rng(0)
    w_p = rand(rng, 3, 16, 16)
    p = np.asarray(sinkhorn_pallas(w_p, jnp.float32(0.5), 30))
    assert_allclose(p.sum(axis=-1), np.ones((3, 16)), rtol=1e-4)
    assert_allclose(p.sum(axis=-2), np.ones((3, 16)), rtol=1e-4)
    assert (p >= 0).all()


def test_sinkhorn_low_tau_approaches_hard():
    """As tau decreases entries polarize toward {0, 1} (paper §3.1)."""
    rng = np.random.default_rng(1)
    w_p = rand(rng, 1, 8, 8)
    hard = np.asarray(sinkhorn_pallas(w_p, jnp.float32(0.05), 50))[0]
    soft = np.asarray(sinkhorn_pallas(w_p, jnp.float32(1.0), 50))[0]
    # Lower temperature => rows closer to one-hot than at tau = 1.
    assert hard.max(axis=-1).mean() > soft.max(axis=-1).mean()
    assert hard.max(axis=-1).mean() > 0.8


def test_sinkhorn_custom_vjp_matches_ref_grad():
    rng = np.random.default_rng(2)
    w_p = rand(rng, 2, 8, 8)

    def via_kernel(wp):
        return jnp.sum(sinkhorn(wp, jnp.float32(0.7), 5) ** 2)

    def via_ref(wp):
        return jnp.sum(sinkhorn_ref(wp, 0.7, 5) ** 2)

    g1 = jax.grad(via_kernel)(w_p)
    g2 = jax.grad(via_ref)(w_p)
    assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------- nm_mask
@settings(**SETTINGS)
@given(
    c_out=st.sampled_from([1, 8, 32]),
    groups=st.integers(1, 16),
    m_keep=st.sampled_from([(4, 2), (8, 4), (4, 1), (4, 3)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nm_mask_pallas_matches_ref(c_out, groups, m_keep, seed):
    m, keep = m_keep
    rng = np.random.default_rng(seed)
    s = rand(rng, c_out, groups * m)
    got = nm_mask_pallas(s, m, keep)
    want = nm_mask_ref(s, m, keep)
    assert_allclose(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_nm_mask_keeps_exactly_keep_per_group(seed):
    rng = np.random.default_rng(seed)
    s = rand(rng, 16, 64)
    mask = np.asarray(nm_mask_pallas(s, 4, 2)).reshape(16, 16, 4)
    assert (mask.sum(axis=-1) == 2).all()


def test_nm_mask_keeps_largest():
    s = jnp.asarray([[0.1, 3.0, -2.0, 0.5]], jnp.float32)
    mask = np.asarray(nm_mask_pallas(s, 4, 2))
    assert mask.tolist() == [[0.0, 1.0, 0.0, 1.0]]


def test_nm_mask_ste_backward_is_softmax_grad():
    rng = np.random.default_rng(3)
    s = rand(rng, 4, 16)

    g1 = jax.grad(lambda a: jnp.sum(nm_mask_ste(a, 4, 2) * a))(s)
    # Manual: d/da [sum(hard(a) * a)] with hard treated as softmax via STE.
    # (hard mask precomputed outside the trace: this jaxlib cannot
    # JVP-trace through stable argsort's batched gather)
    hard = jnp.asarray(np.asarray(nm_mask_ref(s, 4, 2)))

    def manual(a):
        soft = soft_mask_ref(a, 4)
        ste = hard + soft - jax.lax.stop_gradient(soft)
        return jnp.sum(ste * a)

    g2 = jax.grad(manual)(s)
    assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------- permute
@settings(**SETTINGS)
@given(
    t=st.sampled_from([1, 8, 24]),
    c_in=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_permute_pallas_matches_ref(t, c_in, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, t, c_in)
    src = jnp.asarray(rng.permutation(c_in).astype(np.int32))
    got = permute_pallas(x, src)
    want = permute_ref(x, src)
    assert_allclose(np.asarray(got), np.asarray(want))


def test_permute_matches_matrix_multiply():
    """permute(x, src_of) == x @ P with P[src_of[j], j] = 1 (paper W.P)."""
    rng = np.random.default_rng(4)
    x = rand(rng, 5, 12)
    src = rng.permutation(12).astype(np.int32)
    p = np.zeros((12, 12), np.float32)
    p[src, np.arange(12)] = 1.0
    got = np.asarray(permute_pallas(x, jnp.asarray(src)))
    assert_allclose(got, np.asarray(x) @ p, rtol=1e-6)


# ---------------------------------------------------------------- nm_spmm
@settings(**SETTINGS)
@given(
    c_out=st.sampled_from([8, 16]),
    groups=st.integers(1, 8),
    t=st.sampled_from([1, 8]),
    m_keep=st.sampled_from([(4, 2), (8, 4)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nm_spmm_pallas_matches_ref(c_out, groups, t, m_keep, seed):
    m, keep = m_keep
    c_in = groups * m
    rng = np.random.default_rng(seed)
    w = rand(rng, c_out, c_in)
    mask = nm_mask_ref(jnp.abs(w), m, keep)
    vals, idx = nm_compress_ref(w, mask, m, keep)
    x = rand(rng, t, c_in)
    got = nm_spmm_pallas(vals, idx, x)
    want = nm_spmm_ref(vals, idx, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_nm_spmm_equals_masked_dense_matmul(seed):
    """Compressed spmm == x @ (mask * W).T — the end-to-end sparsity claim."""
    rng = np.random.default_rng(seed)
    w = rand(rng, 16, 32)
    mask = nm_mask_ref(jnp.abs(w), 4, 2)
    vals, idx = nm_compress_ref(w, mask, 4, 2)
    x = rand(rng, 8, 32)
    got = np.asarray(nm_spmm_pallas(vals, idx, x))
    want = np.asarray(x) @ (np.asarray(mask) * np.asarray(w)).T
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_nm_compress_halves_storage():
    rng = np.random.default_rng(5)
    w = rand(rng, 8, 64)
    mask = nm_mask_ref(jnp.abs(w), 4, 2)
    vals, idx = nm_compress_ref(w, mask, 4, 2)
    assert vals.shape == (8, 32) and idx.shape == (8, 32)
