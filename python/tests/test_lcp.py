"""L2 LCP graph: STE wiring, identity-permutation baseline, training signal.

The strongest check — lcp_grad numerics vs the pure-Rust LCP path — lives
on the Rust side (tests/lcp_cross_check.rs); here we verify the JAX graph
is internally consistent and actually reduces the pruning discrepancy.
"""

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import lcp
from compile.kernels import nm_mask_ref, sinkhorn_ref


def _layer(rng, c_out=16, c_in=32, t=24):
    w = jnp.asarray(rng.normal(size=(c_out, c_in)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(t, c_in)).astype(np.float32))
    y = x @ w.T
    s = jnp.abs(w)  # magnitude importance
    return w, s, x, y


def _identity_blocks(n_b, b):
    return jnp.tile(jnp.eye(b, dtype=jnp.float32)[None], (n_b, 1, 1))


def test_apply_block_perm_identity():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))
    p = _identity_blocks(4, 8)
    assert_allclose(np.asarray(lcp.apply_block_perm(a, p)), np.asarray(a))


def test_apply_block_perm_matches_full_blockdiag_matmul():
    rng = np.random.default_rng(1)
    a = np.asarray(rng.normal(size=(6, 16)).astype(np.float32))
    blocks = []
    full = np.zeros((16, 16), np.float32)
    for i in range(2):
        perm = rng.permutation(8)
        pm = np.zeros((8, 8), np.float32)
        pm[perm, np.arange(8)] = 1.0
        blocks.append(pm)
        full[i * 8:(i + 1) * 8, i * 8:(i + 1) * 8] = pm
    got = lcp.apply_block_perm(jnp.asarray(a), jnp.asarray(np.stack(blocks)))
    assert_allclose(np.asarray(got), a @ full, rtol=1e-6)


def test_lcp_loss_identity_perm_equals_plain_pruning_error():
    """With P = I (hard and soft pinned), the loss is the cosine error of
    direct N:M pruning — the paper's no-permutation baseline."""
    rng = np.random.default_rng(2)
    w, s, x, y = _layer(rng)
    n_b, b = 4, 8
    # Large positive diagonal logits => sinkhorn(WP) ~= I.
    w_p = jnp.asarray(np.tile((np.eye(b) * 40.0 - 20.0).astype(np.float32), (n_b, 1, 1)))
    p_hard = _identity_blocks(n_b, b)
    loss = lcp.lcp_loss(w, s, x, y, w_p, p_hard, jnp.float32(1.0))

    mask = np.asarray(nm_mask_ref(s, 4, 2))
    y_sp = np.asarray(x) @ (mask * np.asarray(w)).T
    yn = np.asarray(y)
    cos = 1.0 - (yn * y_sp).sum(-1) / (
        np.linalg.norm(yn, axis=-1) * np.linalg.norm(y_sp, axis=-1) + 1e-8)
    assert_allclose(float(loss), cos.mean(), rtol=1e-4, atol=1e-5)


def test_lcp_grad_nonzero_and_finite():
    rng = np.random.default_rng(3)
    w, s, x, y = _layer(rng)
    n_b, b = 4, 8
    w_p = jnp.asarray(rng.normal(size=(n_b, b, b)).astype(np.float32) * 0.1)
    p_soft = sinkhorn_ref(w_p, 1.0, 5)
    # Greedy row-wise hardening is fine for a smoke test.
    p_hard = np.zeros((n_b, b, b), np.float32)
    for n in range(n_b):
        cols = list(range(b))
        sp = np.asarray(p_soft[n])
        for i in np.argsort(-sp.max(axis=1)):
            j = max(cols, key=lambda c: sp[i, c])
            p_hard[n, i, j] = 1.0
            cols.remove(j)
    loss, grad = lcp.lcp_grad(w, s, x, y, w_p, jnp.asarray(p_hard), jnp.float32(1.0))
    g = np.asarray(grad)
    assert np.isfinite(float(loss)) and np.isfinite(g).all()
    assert np.abs(g).max() > 0.0


def test_lcp_adam_beats_identity_baseline():
    """Learned permutation must beat the no-permutation pruning error —
    the core claim of the paper in miniature.  Mirrors the Rust trainer:
    AdamW on W_P, linear tau decay 1.0 -> 0.1, keep the best-seen
    permutation (the loss oscillates once tau is small)."""
    rng = np.random.default_rng(4)
    w, s, x, y = _layer(rng, c_out=24, c_in=32, t=32)
    n_b, b = 4, 8
    # Identity-biased init: step 0 reproduces the no-permutation baseline.
    w_p = jnp.asarray(np.tile((np.eye(b) * 2.0).astype(np.float32), (n_b, 1, 1)))
    m_st = np.zeros((n_b, b, b), np.float32)
    v_st = np.zeros_like(m_st)

    def harden(p_soft):
        out = np.zeros_like(np.asarray(p_soft))
        for n in range(p_soft.shape[0]):
            sp = np.asarray(p_soft[n])
            cols = list(range(b))
            for i in np.argsort(-sp.max(axis=1)):
                j = max(cols, key=lambda c: sp[i, c])
                out[n, i, j] = 1.0
                cols.remove(j)
        return jnp.asarray(out)

    losses = []
    steps, lr = 50, 0.1
    for it in range(steps):
        tau = jnp.float32(1.0 + (0.1 - 1.0) * it / (steps - 1))
        p_hard = harden(lcp.sinkhorn_soft(w_p, tau))
        loss, grad = lcp.lcp_grad(w, s, x, y, w_p, p_hard, tau)
        losses.append(float(loss))
        g = np.asarray(grad)
        m_st = 0.9 * m_st + 0.1 * g
        v_st = 0.999 * v_st + 0.001 * g * g
        mh = m_st / (1 - 0.9 ** (it + 1))
        vh = v_st / (1 - 0.999 ** (it + 1))
        w_p = w_p - lr * jnp.asarray(mh / (np.sqrt(vh) + 1e-8))

    baseline = losses[0]  # identity permutation == plain N:M pruning
    assert min(losses) < baseline, losses
    assert np.isfinite(losses).all()
