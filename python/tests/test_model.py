"""L2 model sanity: shapes, determinism, loss decrease under train_step."""

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model as m


def test_param_order_matches_shapes():
    for cfg in m.CONFIGS.values():
        names = m.param_names(cfg)
        shapes = m.param_shapes(cfg)
        assert len(names) == len(set(names))
        assert set(names) == set(shapes)
        assert names[0] == "tok_embed" and names[-1] == "lm_head"
        # 9 tensors per layer + embed + final_norm + head
        assert len(names) == 3 + 9 * cfg.n_layers


def test_init_deterministic():
    cfg = m.CONFIGS["tiny-s"]
    a = m.init_params(cfg, seed=7)
    b = m.init_params(cfg, seed=7)
    for x, y in zip(a, b):
        assert_allclose(np.asarray(x), np.asarray(y))


def test_forward_shapes_and_finite():
    cfg = m.CONFIGS["tiny-s"]
    params = m.init_params(cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 16)).astype(np.int32))
    logits = m.forward(cfg, params, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality():
    """Changing a future token must not change past logits."""
    cfg = m.CONFIGS["tiny-s"]
    params = m.init_params(cfg)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, size=(1, 16)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % cfg.vocab
    l1 = np.asarray(m.forward(cfg, params, jnp.asarray(toks)))
    l2 = np.asarray(m.forward(cfg, params, jnp.asarray(toks2)))
    assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(l1[0, -1], l2[0, -1])


def test_train_step_decreases_loss():
    cfg = m.CONFIGS["tiny-s"]
    tc = m.TrainConfig(lr=3e-3)
    params = m.init_params(cfg)
    zeros = [jnp.zeros_like(p) for p in params]
    m_s, v_s = zeros, [jnp.zeros_like(p) for p in params]
    step = jnp.float32(0.0)
    rng = np.random.default_rng(2)
    # Single repeated batch: loss must drop when memorizing it.
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(4, 32)).astype(np.int32))
    losses = []
    for _ in range(8):
        params, m_s, v_s, step, loss = m.train_step(cfg, tc, params, m_s, v_s, step, toks)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()
