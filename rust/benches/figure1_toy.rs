//! Figure 1: the handcrafted quality metric can mislead.
//!
//! Exhaustively enumerate every channel-to-group partition of a small
//! layer (magnitude pruning, 2:4), score each by the retained-importance
//! metric S, and measure true output MSE.  The paper's point: the
//! score-maximizing permutation is often NOT the loss-minimizing one and
//! can even be worse than no permutation at all.  We report how often
//! that happens over random layers, plus one concrete example.

use permllm::cp::{exhaustive_partitions, permutation_score};

use permllm::sparsity::{NmConfig, NmMask};
use permllm::tensor::Mat;
use permllm::util::benchkit::{fmt, Table};
use permllm::util::rng::Pcg32;

fn output_mse(w: &Mat, x: &Mat, y: &Mat, perm: &[usize], cfg: NmConfig) -> f64 {
    let s = w.map(f32::abs); // magnitude pruning, as in Fig. 1
    let wp = w.permute_cols(perm);
    let sp = s.permute_cols(perm);
    let mask = NmMask::from_scores(&sp, cfg);
    let xp = x.permute_cols(perm);
    let y_sp = xp.matmul_bt(&mask.apply(&wp));
    y.mse(&y_sp) as f64
}

fn main() {
    permllm::util::logging::init();
    let cfg = NmConfig::PAT_2_4;
    let (c_out, c_in, t) = (4usize, 8usize, 16usize);
    let partitions = exhaustive_partitions(c_in, cfg.m);
    println!(
        "enumerating {} channel-to-group partitions of C_in={c_in}, M={}",
        partitions.len(),
        cfg.m
    );

    let trials = 200;
    let mut score_max_not_loss_min = 0;
    let mut score_max_worse_than_identity = 0;
    let mut example: Option<(f64, f64, f64, f64)> = None;

    for trial in 0..trials {
        let mut rng = Pcg32::seeded(3000 + trial);
        let w = Mat::randn(c_out, c_in, 1.0, &mut rng);
        let x = Mat::randn(t, c_in, 1.0, &mut rng);
        let y = x.matmul_bt(&w);
        let s = w.map(f32::abs);
        let id: Vec<usize> = (0..c_in).collect();

        let mut best_score = f64::NEG_INFINITY;
        let mut best_score_perm = id.clone();
        let mut best_loss = f64::INFINITY;
        for p in &partitions {
            let sc = permutation_score(&s, p, cfg);
            if sc > best_score {
                best_score = sc;
                best_score_perm = p.clone();
            }
            let l = output_mse(&w, &x, &y, p, cfg);
            if l < best_loss {
                best_loss = l;
            }
        }
        let loss_of_score_max = output_mse(&w, &x, &y, &best_score_perm, cfg);
        let loss_identity = output_mse(&w, &x, &y, &id, cfg);

        if loss_of_score_max > best_loss + 1e-9 {
            score_max_not_loss_min += 1;
        }
        if loss_of_score_max > loss_identity + 1e-9 {
            score_max_worse_than_identity += 1;
            if example.is_none() {
                example = Some((
                    loss_identity,
                    loss_of_score_max,
                    best_loss,
                    best_score - permutation_score(&s, &id, cfg),
                ));
            }
        }
    }

    let mut table = Table::new(
        "Figure 1: score-max CP vs true output loss (magnitude, 2:4, exhaustive)",
        &["Statistic", "Value"],
    );
    table.row(&[
        "trials".into(),
        trials.to_string(),
    ]);
    table.row(&[
        "score-max perm != loss-min perm".into(),
        format!("{score_max_not_loss_min} / {trials} ({:.0}%)", 100.0 * score_max_not_loss_min as f64 / trials as f64),
    ]);
    table.row(&[
        "score-max perm WORSE than identity".into(),
        format!("{score_max_worse_than_identity} / {trials} ({:.0}%)", 100.0 * score_max_worse_than_identity as f64 / trials as f64),
    ]);
    if let Some((l_id, l_smax, l_best, dscore)) = example {
        table.row(&["example: identity loss".into(), fmt(l_id, 4)]);
        table.row(&["example: score-max loss (higher!)".into(), fmt(l_smax, 4)]);
        table.row(&["example: true optimum loss".into(), fmt(l_best, 4)]);
        table.row(&["example: score gain of score-max".into(), fmt(dscore, 4)]);
    }
    table.finish("figure1_toy");
}
