//! Figure 3: mask visualization for layer `w_down` of the last decoder
//! layer under Wanda / RIA+CP / PermLLM_RIA (channels permuted back to
//! the original order, as in the paper).
//!
//! Emits an ASCII crop to stdout and PGM images to bench_results/, plus
//! retained-position overlap statistics between the methods.

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::lcp::LcpCfg;
use permllm::model::{LinearKind, LinearRef};
use permllm::pruning::Metric;
use permllm::recipe::{HeuristicCpPerm, LearnedPerm, PruneRecipe};
use permllm::sparsity::NmConfig;
use permllm::tensor::Mat;
use permllm::util::benchkit::Table;

fn mask_in_original_order(
    pruned: &permllm::coordinator::PrunedModel,
    lin: LinearRef,
) -> Mat {
    let res = &pruned.layers[&lin];
    let mut inv = vec![0usize; res.src_of.len()];
    for (j, &i) in res.src_of.iter().enumerate() {
        inv[i] = j;
    }
    res.mask.to_dense().permute_cols(&inv)
}

fn save_pgm(path: &str, m: &Mat, crop: usize) {
    let r = m.rows().min(crop);
    let c = m.cols().min(crop);
    let mut out = format!("P2\n{c} {r}\n255\n");
    for i in 0..r {
        for j in 0..c {
            // paper: blue = pruned, white = retained -> 0 = pruned here.
            out.push_str(if m[(i, j)] != 0.0 { "255 " } else { "40 " });
        }
        out.push('\n');
    }
    let _ = std::fs::create_dir_all("bench_results");
    let _ = std::fs::write(path, out);
}

fn ascii_crop(m: &Mat, rows: usize, cols: usize) -> String {
    let mut s = String::new();
    for i in 0..rows.min(m.rows()) {
        for j in 0..cols.min(m.cols()) {
            s.push(if m[(i, j)] != 0.0 { '#' } else { '.' });
        }
        s.push('\n');
    }
    s
}

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let lin = LinearRef { layer: ps.cfg().n_layers - 1, kind: LinearKind::WDown };
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
        ..Default::default()
    };

    let nm = NmConfig::PAT_2_4;
    let recipes = [
        PruneRecipe::oneshot(Metric::Wanda, nm),
        PruneRecipe::builder(nm).metric_kind(Metric::Ria).perm(HeuristicCpPerm).build(),
        PruneRecipe::builder(nm).metric_kind(Metric::Ria).perm(LearnedPerm::default()).build(),
    ];
    let mut masks = Vec::new();
    for recipe in recipes {
        let pruned = prune_with_recipe(&ps, &calib, &recipe, &cfg);
        let mask = mask_in_original_order(&pruned, lin);
        println!("\n--- {} mask ({}), {}:{} crop 24x48 ---", recipe.name(), prov,
                 lin.layer, "w_down");
        print!("{}", ascii_crop(&mask, 24, 48));
        save_pgm(
            &format!("bench_results/figure3_{}.pgm", recipe.name().replace('+', "_")),
            &mask,
            128,
        );
        masks.push((recipe.name(), mask));
    }

    // Overlap statistics (paper's point: retained sets genuinely differ).
    let mut table = Table::new(
        "Figure 3: retained-weight overlap between methods (w_down, original order)",
        &["Pair", "Overlap (%)"],
    );
    for i in 0..masks.len() {
        for j in i + 1..masks.len() {
            let (na, a) = &masks[i];
            let (nb, b) = &masks[j];
            let total: f32 = a.data().iter().sum();
            let inter: f32 = a
                .data()
                .iter()
                .zip(b.data())
                .map(|(x, y)| if *x != 0.0 && *y != 0.0 { 1.0 } else { 0.0 })
                .sum();
            table.row(&[format!("{na} vs {nb}"), format!("{:.1}", 100.0 * inter / total)]);
        }
    }
    table.finish("figure3_masks");
}
