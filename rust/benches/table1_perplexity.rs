//! Table 1: 2:4 semi-structured pruning, perplexity on the held-out
//! wikitext2-like corpus (calibration on c4-like, as in the paper).
//!
//! Paper shape to reproduce: PermLLM_X < X+CP < X for X in {Wanda, RIA};
//! SparseGPT competitive with one-shot metrics; Dense lowest.
//!
//! Rows are declared as [`PruneRecipe`]s (`recipe::rows::table1`) — the
//! labels are pinned by `table1_rows_are_recipes_with_pinned_labels` —
//! including the ROSE-style learned-permutation + SparseGPT-update row
//! the legacy method enum could not express.

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::recipe::rows;
use permllm::sparsity::NmConfig;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let models = ["tiny-s", "tiny-m", "tiny-l"];
    let recipes = rows::table1(NmConfig::PAT_2_4);
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);

    let mut header = vec!["Method".to_string()];
    let mut provs = Vec::new();
    for m in models {
        let (_, prov) = trained_or_synth(m);
        provs.push(prov);
        header.push(format!("{m} ({prov})"));
    }
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Table 1: Wikitext2-like perplexity, 2:4 sparsity", &hdr_refs);

    let mut rows_out: Vec<Vec<String>> = recipes.iter().map(|r| vec![r.name()]).collect();
    for model in models {
        let (ps, _) = trained_or_synth(model);
        let cfg = PipelineCfg {
            lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
            ..Default::default()
        };
        for (ri, recipe) in recipes.iter().enumerate() {
            let t0 = std::time::Instant::now();
            let pruned = prune_with_recipe(&ps, &calib, recipe, &cfg);
            let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
            log::info!("{model}/{}: ppl {ppl:.3} ({:.1}s)", recipe.name(), t0.elapsed().as_secs_f64());
            rows_out[ri].push(fmt(ppl, 3));
        }
    }
    for r in rows_out {
        table.row(&r);
    }
    table.finish("table1_perplexity");
}
