//! Table 2: zero-shot accuracy of 2:4 sparse models on the 5-task suite.
//!
//! Paper shape: PermLLM_Wanda achieves the highest sparse average,
//! Wanda+CP beats Wanda, SparseGPT in between; Dense on top.
//!
//! Rows are [`PruneRecipe`]s (`recipe::rows::headline`); the "WeightUpd"
//! column is derived from each recipe's update policy rather than
//! hard-coded per row.

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::{zeroshot_accuracy, zeroshot_suite};
use permllm::lcp::LcpCfg;
use permllm::recipe::rows;
use permllm::sparsity::NmConfig;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let model = "tiny-m";
    let (ps, prov) = trained_or_synth(model);
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let recipes = rows::headline(NmConfig::PAT_2_4);
    let n_items = scaled(60);

    let mut table = Table::new(
        &format!("Table 2: zero-shot accuracy (%), 2:4, {model} ({prov})"),
        &["Method", "WeightUpd", "HellaSwag", "ARC_E", "ARC_C", "OBQA", "RTE", "Average"],
    );
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    for recipe in &recipes {
        let pruned = prune_with_recipe(&ps, &calib, recipe, &cfg);
        let mut row = vec![recipe.name(), rows::weight_update_cell(recipe).to_string()];
        let mut mean = 0.0;
        for mut task in zeroshot_suite() {
            task.n_items = n_items;
            let acc = zeroshot_accuracy(&pruned.params, &task, 7) * 100.0;
            row.push(fmt(acc, 2));
            mean += acc;
        }
        row.push(fmt(mean / 5.0, 2));
        log::info!("{}: avg {:.2}", recipe.name(), mean / 5.0);
        table.row(&row);
    }
    table.finish("table2_zeroshot");
}
