//! Table 3: runtime of dense vs 2:4-sparse linear layers + the channel
//! permutation (CP) kernel, batch of 2048 tokens (paper's setup).
//!
//! Paper shape: ~1.6-1.7x speedup on every projection from 2:4 sparsity
//! (compressed inner products are half the length), and a CP cost that is
//! negligible relative to the matmuls once the permutation kernel is
//! index-precomputed (the paper's 84x-over-PyTorch custom CUDA kernel;
//! our analogue contrasts the fused gather with an explicit
//! permutation-matrix multiply).

use permllm::model::ModelConfig;
use permllm::sparsity::{Compressed, NmConfig, NmMask};
use permllm::tensor::Mat;
use permllm::util::benchkit::{fmt, Bench, Table};
use permllm::util::rng::Pcg32;

/// Dense matmul with no sparsity shortcut (framework-baseline analogue).
fn matmul_noskip(a: &Mat, b: &Mat) -> Mat {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut out = Mat::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        for (l, &av) in arow.iter().enumerate().take(k) {
            let brow = b.row(l);
            let orow = &mut out.data_mut()[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

fn main() {
    permllm::util::logging::init();
    let cfg = ModelConfig::by_name("tiny-m").unwrap();
    let t = 2048usize;
    let mut rng = Pcg32::seeded(11);
    let bench = Bench::default();

    let shapes: [(&str, usize, usize); 3] = [
        ("Q/K/V/O_proj", cfg.dim, cfg.dim),
        ("Up/Gate_proj", cfg.ffn, cfg.dim),
        ("Down_proj", cfg.dim, cfg.ffn),
    ];

    let mut table = Table::new(
        "Table 3: layer runtime, 2048 tokens (tiny-m shapes)",
        &["Layer", "Dense (ms)", "2:4 sparse (ms)", "Speedup", "CP (ms)"],
    );

    // CP kernel: fused gather (ours) vs explicit P-matmul ("PyTorch" analogue).
    let mut cp_fused_ms = 0.0;
    let mut cp_naive_ms = 0.0;

    for (name, c_out, c_in) in shapes {
        let w = Mat::randn(c_out, c_in, 1.0, &mut rng);
        let x = Mat::randn(t, c_in, 1.0, &mut rng);
        let mask = NmMask::from_scores(&w.map(f32::abs), NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &mask);
        let perm = rng.permutation(c_in);

        let dense = bench.run(&format!("{name}-dense"), || x.matmul_bt(&w));
        let sparse = bench.run(&format!("{name}-sparse"), || comp.matmul_xt(&x));
        let cp = bench.run(&format!("{name}-cp"), || x.permute_cols(&perm));

        // Naive CP baseline: materialize P and do a full *dense* matmul
        // without the library's zero-skip (models a framework that treats
        // the permutation as just another weight matrix, as the paper's
        // PyTorch baseline effectively does).
        let mut p = Mat::zeros(c_in, c_in);
        for (j, &i) in perm.iter().enumerate() {
            p[(i, j)] = 1.0;
        }
        let cp_naive = bench.run(&format!("{name}-cp-naive"), || matmul_noskip(&x, &p));
        cp_fused_ms += cp.mean_ms();
        cp_naive_ms += cp_naive.mean_ms();

        table.row(&[
            name.to_string(),
            fmt(dense.mean_ms(), 3),
            fmt(sparse.mean_ms(), 3),
            format!("{:.3}x", dense.mean_ns / sparse.mean_ns),
            fmt(cp.mean_ms(), 3),
        ]);
    }
    table.finish("table3_runtime");

    let mut cpt = Table::new(
        "Table 3b: CP kernel vs naive permutation-matmul (PyTorch analogue)",
        &["Impl", "Total (ms)", "Speedup"],
    );
    cpt.row(&["naive (x @ P)".into(), fmt(cp_naive_ms, 3), "1.0x".into()]);
    cpt.row(&[
        "fused gather".into(),
        fmt(cp_fused_ms, 3),
        format!("{:.0}x", cp_naive_ms / cp_fused_ms),
    ]);
    cpt.finish("table3b_cp_kernel");
}
