//! Table 4: Sinkhorn-iteration ablation (0 vs 5) for PermLLM_Wanda.
//!
//! Paper shape: 5 iterations (a near-doubly-stochastic soft matrix) beats
//! 0 iterations (plain exp) on the sparse model's quality.
//!
//! The ablation axis rides the recipe path: each row is a
//! [`PruneRecipe`] whose [`LearnedPerm`] overrides `sinkhorn_iters`
//! per strategy instead of mutating the pipeline config.

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::{eval_perplexity, zeroshot_accuracy, zeroshot_suite};
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::recipe::{LearnedPerm, PruneRecipe};
use permllm::sparsity::NmConfig;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);

    let mut table = Table::new(
        &format!("Table 4: Sinkhorn iteration ablation, PermLLM_Wanda, tiny-m ({prov})"),
        &["# Iter", "MeanLayerErr", "ZeroShotAvg", "Wikitext2 ppl"],
    );
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    for iters in [0usize, 5] {
        let recipe = PruneRecipe::builder(NmConfig::PAT_2_4)
            .metric_kind(Metric::Wanda)
            .perm(LearnedPerm { sinkhorn_iters: Some(iters), ..Default::default() })
            .build();
        let pruned = prune_with_recipe(&ps, &calib, &recipe, &cfg);
        let err = pruned.mean_layer_error();
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        let mut zs = 0.0;
        for mut task in zeroshot_suite() {
            task.n_items = scaled(40);
            zs += zeroshot_accuracy(&pruned.params, &task, 7) * 100.0;
        }
        table.row(&[iters.to_string(), fmt(err as f64, 5), fmt(zs / 5.0, 2), fmt(ppl, 3)]);
    }
    table.finish("table4_sinkhorn_ablation");
}
