//! Table 5: calibration-dataset robustness for PermLLM_Wanda.
//!
//! Paper shape: learned permutations perform consistently when calibrated
//! on Pile / Wikitext2 / C4 — the method is not calibration-fragile.
//! (Perplexity is lowest when calibration matches the eval corpus, as in
//! the paper's Wikitext2 row.)

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::{eval_perplexity, zeroshot_accuracy, zeroshot_suite};
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);

    let mut table = Table::new(
        &format!("Table 5: calibration dataset ablation, PermLLM_Wanda, tiny-m ({prov})"),
        &["Calib dataset", "MeanLayerErr", "ZeroShotAvg", "Wikitext2 ppl"],
    );
    for kind in [CorpusKind::PileLike, CorpusKind::WikitextLike, CorpusKind::C4Like] {
        let calib = Corpus::build(kind, 2024);
        let cfg = PipelineCfg {
            lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
            ..Default::default()
        };
        let pruned = prune_model(&ps, &calib, PruneMethod::PermLlm(Metric::Wanda), &cfg);
        let err: f32 =
            pruned.layer_errors.values().sum::<f32>() / pruned.layer_errors.len() as f32;
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        let mut zs = 0.0;
        for mut task in zeroshot_suite() {
            task.n_items = scaled(40);
            zs += zeroshot_accuracy(&pruned.params, &task, 7) * 100.0;
        }
        table.row(&[kind.name().to_string(), fmt(err as f64, 5), fmt(zs / 5.0, 2), fmt(ppl, 3)]);
    }
    table.finish("table5_calibration");
}
