//! Table 5: calibration-dataset robustness for PermLLM_Wanda.
//!
//! Paper shape: learned permutations perform consistently when calibrated
//! on Pile / Wikitext2 / C4 — the method is not calibration-fragile.
//! (Perplexity is lowest when calibration matches the eval corpus, as in
//! the paper's Wikitext2 row.)

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::{eval_perplexity, zeroshot_accuracy, zeroshot_suite};
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::recipe::{LearnedPerm, PruneRecipe};
use permllm::sparsity::NmConfig;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);
    let recipe = PruneRecipe::builder(NmConfig::PAT_2_4)
        .metric_kind(Metric::Wanda)
        .perm(LearnedPerm::default())
        .build();

    let mut table = Table::new(
        &format!("Table 5: calibration dataset ablation, PermLLM_Wanda, tiny-m ({prov})"),
        &["Calib dataset", "MeanLayerErr", "ZeroShotAvg", "Wikitext2 ppl"],
    );
    for kind in [CorpusKind::PileLike, CorpusKind::WikitextLike, CorpusKind::C4Like] {
        let calib = Corpus::build(kind, 2024);
        let cfg = PipelineCfg {
            lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
            ..Default::default()
        };
        let pruned = prune_with_recipe(&ps, &calib, &recipe, &cfg);
        let err = pruned.mean_layer_error();
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        let mut zs = 0.0;
        for mut task in zeroshot_suite() {
            task.n_items = scaled(40);
            zs += zeroshot_accuracy(&pruned.params, &task, 7) * 100.0;
        }
        table.row(&[kind.name().to_string(), fmt(err as f64, 5), fmt(zs / 5.0, 2), fmt(ppl, 3)]);
    }
    table.finish("table5_calibration");
}
