//! Table 6: block-size trade-off for block-wise LCP (32 / 64 / 128).
//!
//! Paper shape: larger blocks = larger optimization space = lower error,
//! at superlinear runtime cost (Hungarian is O(C_in * B^2); convergence
//! needs more iterations).
//!
//! The sweep runs through the trait-based recipe path (ROADMAP "block-
//! size sweeps" item): each row is a [`PruneRecipe`] whose
//! [`LearnedPerm`] carries the block size per strategy.

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::recipe::{LearnedPerm, PruneRecipe};
use permllm::sparsity::NmConfig;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);

    let mut table = Table::new(
        &format!("Table 6: LCP block size, PermLLM_Wanda, tiny-m ({prov})"),
        &["Block", "MeanLayerErr", "Wikitext2 ppl", "Prune time (s)"],
    );
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    for block in [32usize, 64, 128] {
        let recipe = PruneRecipe::builder(NmConfig::PAT_2_4)
            .metric_kind(Metric::Wanda)
            .perm(LearnedPerm { block: Some(block), ..Default::default() })
            .build();
        let pruned = prune_with_recipe(&ps, &calib, &recipe, &cfg);
        let err = pruned.mean_layer_error();
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        table.row(&[
            block.to_string(),
            fmt(err as f64, 5),
            fmt(ppl, 3),
            fmt(pruned.elapsed_s, 1),
        ]);
    }
    table.finish("table6_blocksize");
}
