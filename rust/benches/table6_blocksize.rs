//! Table 6: block-size trade-off for block-wise LCP (32 / 64 / 128).
//!
//! Paper shape: larger blocks = larger optimization space = lower error,
//! at superlinear runtime cost (Hungarian is O(C_in * B^2); convergence
//! needs more iterations).

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);

    let mut table = Table::new(
        &format!("Table 6: LCP block size, PermLLM_Wanda, tiny-m ({prov})"),
        &["Block", "MeanLayerErr", "Wikitext2 ppl", "Prune time (s)"],
    );
    for block in [32usize, 64, 128] {
        let cfg = PipelineCfg {
            lcp: LcpCfg { block, steps: scaled(50), lr: 0.05, ..Default::default() },
            ..Default::default()
        };
        let pruned = prune_model(&ps, &calib, PruneMethod::PermLlm(Metric::Wanda), &cfg);
        let err: f32 =
            pruned.layer_errors.values().sum::<f32>() / pruned.layer_errors.len() as f32;
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        table.row(&[
            block.to_string(),
            fmt(err as f64, 5),
            fmt(ppl, 3),
            fmt(pruned.elapsed_s, 1),
        ]);
    }
    table.finish("table6_blocksize");
}
