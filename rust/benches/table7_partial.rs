//! Table 7 (Appendix A): partial PermLLM — learnable permutation on the
//! last layers only, heuristic CP on the rest.
//!
//! Paper shape: RIA+CP < partial PermLLM < full PermLLM in quality, with
//! partial's prune time close to the heuristic's.
//!
//! Each row is a [`PruneRecipe`]; the partial run carries its layer
//! threshold in the [`LearnedPerm`] strategy itself (`from_layer`)
//! instead of the pipeline config.

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::recipe::{HeuristicCpPerm, LearnedPerm, PruneRecipe};
use permllm::sparsity::NmConfig;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let n_layers = ps.cfg().n_layers;
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);

    let nm = NmConfig::PAT_2_4;
    let ria = || PruneRecipe::builder(nm).metric_kind(Metric::Ria);
    let runs: [(&str, PruneRecipe); 3] = [
        ("RIA+CP", ria().perm(HeuristicCpPerm).build()),
        // last half of the decoder layers get LCP (paper: last 6 of 32)
        (
            "PermLLM_RIA (partial)",
            ria().perm(LearnedPerm { from_layer: Some(n_layers / 2), ..Default::default() }).build(),
        ),
        ("PermLLM_RIA (full)", ria().perm(LearnedPerm::default()).build()),
    ];

    let mut table = Table::new(
        &format!("Table 7: partial PermLLM, tiny-m ({prov})"),
        &["Method", "MeanLayerErr", "Wikitext2 ppl", "Prune time (s)"],
    );
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    for (name, recipe) in runs {
        let pruned = prune_with_recipe(&ps, &calib, &recipe, &cfg);
        let err = pruned.mean_layer_error();
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        table.row(&[name.to_string(), fmt(err as f64, 5), fmt(ppl, 3), fmt(pruned.elapsed_s, 1)]);
    }
    table.finish("table7_partial");
}
