//! Table 7 (Appendix A): partial PermLLM — learnable permutation on the
//! last layers only, heuristic CP on the rest.
//!
//! Paper shape: RIA+CP < partial PermLLM < full PermLLM in quality, with
//! partial's prune time close to the heuristic's.

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let n_layers = ps.cfg().n_layers;
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);

    let runs: [(&str, PruneMethod, usize); 3] = [
        ("RIA+CP", PruneMethod::OneShotCp(Metric::Ria), 0),
        // last half of the decoder layers get LCP (paper: last 6 of 32)
        ("PermLLM_RIA (partial)", PruneMethod::PermLlm(Metric::Ria), n_layers / 2),
        ("PermLLM_RIA (full)", PruneMethod::PermLlm(Metric::Ria), 0),
    ];

    let mut table = Table::new(
        &format!("Table 7: partial PermLLM, tiny-m ({prov})"),
        &["Method", "MeanLayerErr", "Wikitext2 ppl", "Prune time (s)"],
    );
    for (name, method, from_layer) in runs {
        let cfg = PipelineCfg {
            lcp: LcpCfg { steps: scaled(50), lr: 0.05, ..Default::default() },
            lcp_from_layer: from_layer,
            ..Default::default()
        };
        let pruned = prune_model(&ps, &calib, method, &cfg);
        let err: f32 =
            pruned.layer_errors.values().sum::<f32>() / pruned.layer_errors.len() as f32;
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        table.row(&[name.to_string(), fmt(err as f64, 5), fmt(ppl, 3), fmt(pruned.elapsed_s, 1)]);
    }
    table.finish("table7_partial");
}
