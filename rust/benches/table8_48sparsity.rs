//! Table 8 (Appendix B): 4:8 sparsity — PermLLM is not 2:4-specific.
//!
//! Paper shape: same ordering as Table 1/2 under the looser 4:8 pattern,
//! with smaller absolute degradation than 2:4 (more mask freedom).

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::sparsity::NmConfig;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);
    let methods = [
        (PruneMethod::Dense, "-"),
        (PruneMethod::SparseGpt, "yes"),
        (PruneMethod::OneShot(Metric::Wanda), "no"),
        (PruneMethod::OneShotCp(Metric::Wanda), "no"),
        (PruneMethod::PermLlm(Metric::Wanda), "no"),
    ];

    let mut table = Table::new(
        &format!("Table 8: 4:8 sparsity, tiny-m ({prov})"),
        &["Method", "WeightUpd", "MeanLayerErr", "Wikitext2 ppl"],
    );
    let nm = NmConfig::PAT_4_8;
    for (method, upd) in methods {
        let cfg = PipelineCfg {
            nm,
            lcp: LcpCfg { nm, steps: scaled(50), lr: 0.05, ..Default::default() },
            ..Default::default()
        };
        let pruned = prune_model(&ps, &calib, method, &cfg);
        let err: f32 = if pruned.layer_errors.is_empty() {
            0.0
        } else {
            pruned.layer_errors.values().sum::<f32>() / pruned.layer_errors.len() as f32
        };
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        table.row(&[method.name(), upd.to_string(), fmt(err as f64, 5), fmt(ppl, 3)]);
    }
    table.finish("table8_48sparsity");
}
