//! Table 8 (Appendix B): 4:8 sparsity — PermLLM is not 2:4-specific.
//!
//! Paper shape: same ordering as Table 1/2 under the looser 4:8 pattern,
//! with smaller absolute degradation than 2:4 (more mask freedom).
//!
//! Rows are the same [`PruneRecipe`] list as Table 2
//! (`recipe::rows::headline`), declared at 4:8 — each recipe carries its
//! own N:M pattern.

use permllm::bench::{scaled, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::recipe::rows;
use permllm::sparsity::NmConfig;
use permllm::util::benchkit::{fmt, Table};

fn main() {
    permllm::util::logging::init();
    let (ps, prov) = trained_or_synth("tiny-m");
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);
    let nm = NmConfig::PAT_4_8;
    let recipes = rows::headline(nm);

    let mut table = Table::new(
        &format!("Table 8: 4:8 sparsity, tiny-m ({prov})"),
        &["Method", "WeightUpd", "MeanLayerErr", "Wikitext2 ppl"],
    );
    for recipe in &recipes {
        let cfg = PipelineCfg {
            nm,
            lcp: LcpCfg { nm, steps: scaled(50), lr: 0.05, ..Default::default() },
            ..Default::default()
        };
        let pruned = prune_with_recipe(&ps, &calib, recipe, &cfg);
        let err = pruned.mean_layer_error();
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        table.row(&[
            recipe.name(),
            rows::weight_update_cell(recipe).to_string(),
            fmt(err as f64, 5),
            fmt(ppl, 3),
        ]);
    }
    table.finish("table8_48sparsity");
}
