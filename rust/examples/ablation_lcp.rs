//! Ablations of the LCP trainer's design choices (DESIGN.md §Perf /
//! EXPERIMENTS.md §Ablations):
//!
//! 1. keep-best-seen permutation vs take-final-step (the loss is noisy
//!    once tau is small — keep-best should win or tie always);
//! 2. CP-seeded refinement vs identity-init learning (the pipeline's
//!    composition choice);
//! 3. permutation-aware int4 quantization (paper §D future work) —
//!    range-sorted grouping vs natural order on outlier-channel weights.
//!
//! ```bash
//! cargo run --release --example ablation_lcp
//! ```

use permllm::cp::ria_cp;
use permllm::lcp::{harden, tau_schedule, AdamW, AdamWCfg, HostBackend, LayerData, LcpBackend, LcpCfg};
use permllm::pruning::{importance, Metric};
use permllm::quant::{range_sort_perm, QuantCfg, QuantWeight};
use permllm::sparsity::NmConfig;
use permllm::tensor::Mat;
use permllm::util::rng::Pcg32;

/// Run LCP and report (best_loss, final_loss).
fn run_lcp(data: &LayerData, cfg: LcpCfg, seed_perm: Option<&[usize]>) -> (f32, f32) {
    let (w, s, x) = (&data.w, &data.s, &data.x);
    // Optionally pre-permute the layer (CP seeding).
    let owned;
    let d = if let Some(p) = seed_perm {
        owned = LayerData::new(w.permute_cols(p), s.permute_cols(p), x.permute_cols(p));
        &owned
    } else {
        data
    };
    let mut backend = HostBackend::new(d, cfg.nm, cfg.sinkhorn_iters);
    let n_b = d.w.cols() / cfg.block;
    let b = cfg.block;
    let mut w_p: Vec<Mat> = (0..n_b).map(|_| Mat::eye(b).scale(2.0)).collect();
    let mut opts: Vec<AdamW> =
        (0..n_b).map(|_| AdamW::new(b * b, AdamWCfg { lr: cfg.lr, ..Default::default() })).collect();
    let mut best = f32::INFINITY;
    let mut last = f32::NAN;
    for step in 0..cfg.steps {
        let tau = tau_schedule(step, cfg.steps, cfg.tau0, cfg.tau1);
        let soft = backend.soft_perms(&w_p, tau);
        let hard: Vec<Vec<usize>> = soft.iter().map(|m| harden(m)).collect();
        let (loss, grads) = backend.loss_grad(&w_p, &hard, tau);
        best = best.min(loss);
        last = loss;
        for (n, opt) in opts.iter_mut().enumerate() {
            opt.step(w_p[n].data_mut(), grads[n].data());
            for v in w_p[n].data_mut() {
                *v = v.clamp(-8.0, 8.0);
            }
        }
    }
    (best, last)
}

fn main() {
    permllm::util::logging::init();
    let nm = NmConfig::PAT_2_4;
    let cfg = LcpCfg { block: 64, steps: 50, lr: 0.1, nm, ..Default::default() };

    println!("=== Ablation 1+2: keep-best vs final; identity-init vs CP-seeded ===");
    println!(
        "{:<6} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "seed", "cp-loss", "id-best", "id-final", "cp-best", "cp-final"
    );
    for seed in 0..4u64 {
        let mut rng = Pcg32::seeded(500 + seed);
        let w = Mat::randn(128, 128, 0.1, &mut rng);
        let x = Mat::randn(128, 128, 1.0, &mut rng);
        let s = importance(Metric::Wanda, &w, &x);
        let data = LayerData::new(w.clone(), s.clone(), x.clone());

        let perm_cp = ria_cp(&s, nm);
        // Loss of the heuristic permutation alone (step-0 of the seeded run).
        let (id_best, id_final) = run_lcp(&data, cfg, None);
        let (cp_best, cp_final) = run_lcp(&data, cfg, Some(&perm_cp));
        // cp-loss = loss at CP with no refinement = first-step loss of the
        // seeded run; approximate by re-running 1 step.
        let (cp_alone, _) = run_lcp(&data, LcpCfg { steps: 1, ..cfg }, Some(&perm_cp));
        println!(
            "{:<6} {:>12.5} {:>12.5} {:>12.5} {:>12.5} {:>12.5}",
            seed, cp_alone, id_best, id_final, cp_best, cp_final
        );
        assert!(cp_best <= cp_alone + 1e-6, "keep-best regressed below its seed");
        assert!(id_best <= id_final + 1e-6);
    }
    println!("keep-best never regresses; CP-seeded refinement ≤ CP alone. OK");

    println!("\n=== Ablation 3: permutation-aware int4 quantization (paper §D) ===");
    println!("{:<6} {:>14} {:>14} {:>10}", "seed", "natural mse", "range-sorted", "gain");
    for seed in 0..4u64 {
        let mut rng = Pcg32::seeded(900 + seed);
        // Outlier-channel weight (the LLM-like regime).
        let mut w = Mat::randn(64, 256, 0.05, &mut rng);
        for _ in 0..16 {
            let c = rng.below_usize(256);
            for r in 0..64 {
                w[(r, c)] *= 20.0;
            }
        }
        let base = QuantWeight::quantize(&w, QuantCfg::INT4_G64).mse(&w);
        let perm = range_sort_perm(&w);
        let sorted = QuantWeight::quantize_permuted(&w, &perm, QuantCfg::INT4_G64).mse(&w);
        println!(
            "{:<6} {:>14.6} {:>14.6} {:>9.2}x",
            seed,
            base,
            sorted,
            base / sorted
        );
    }
    println!("channel reordering reduces group-quantization error — the paper's §D direction holds on this substrate.");
}
