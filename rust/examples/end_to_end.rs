//! End-to-end validation (DESIGN.md §4): all three layers composing.
//!
//! 1. Obtain tiny-m weights — pretrained via the AOT `train_step`
//!    artifact when built with `--features pjrt` and `make artifacts` has
//!    run, else synthetic trained-statistics weights (offline default).
//! 2. Prune with Wanda / Wanda+CP / PermLLM_Wanda (LCP routed through the
//!    `ExecBackend` trait — the native engine serving `sinkhorn_soft_*`
//!    and `lcp_grad_*`).
//! 3. Evaluate perplexity of every variant through BOTH the host forward
//!    and the backend's `lm_forward` artifact, verifying they agree.
//!
//! ```bash
//! cargo run --release --example end_to_end            # offline, native
//! make artifacts && cargo run --release --features pjrt --example end_to_end
//! ```

use permllm::bench::trained_or_synth;
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::{eval_perplexity, eval_perplexity_exec};
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::recipe::{HeuristicCpPerm, LearnedPerm, PruneRecipe};
use permllm::runtime::NativeEngine;
use permllm::sparsity::NmConfig;

fn main() -> anyhow::Result<()> {
    permllm::util::logging::init();

    // ---- 1. weights --------------------------------------------------------
    #[cfg(feature = "pjrt")]
    maybe_pretrain();
    let (ps, prov) = trained_or_synth("tiny-m");
    println!("tiny-m weights: {prov}");

    // ---- 2. prune ----------------------------------------------------------
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: 30, lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    let nm = NmConfig::PAT_2_4;
    let recipes = [
        PruneRecipe::dense(nm),
        PruneRecipe::oneshot(Metric::Wanda, nm),
        PruneRecipe::builder(nm).metric_kind(Metric::Wanda).perm(HeuristicCpPerm).build(),
        PruneRecipe::builder(nm).metric_kind(Metric::Wanda).perm(LearnedPerm::default()).build(),
    ];

    // ---- 3. evaluate through host AND the exec backend ---------------------
    let mut engine = NativeEngine::with_model(ps.cfg().clone());
    println!("\n{:<16} {:>14} {:>16} {:>10}", "recipe", "host ppl", "backend ppl", "time(s)");
    for recipe in recipes {
        let pruned = prune_with_recipe(&ps, &calib, &recipe, &cfg);
        let host_ppl = eval_perplexity(&pruned.params, &evalc, 555, 8, 64);
        let exec_ppl = eval_perplexity_exec(&mut engine, &pruned.params, &evalc, 555, 8, 64)?;
        println!(
            "{:<16} {:>14.3} {:>16.3} {:>10.1}",
            recipe.name(),
            host_ppl,
            exec_ppl,
            pruned.elapsed_s
        );
        anyhow::ensure!(
            (host_ppl - exec_ppl).abs() / host_ppl < 1e-6,
            "host and backend forward disagree: {host_ppl} vs {exec_ppl}"
        );
    }
    println!("\nhost forward == ExecBackend lm_forward on every variant: OK");

    #[cfg(feature = "pjrt")]
    pjrt_cross_check(&ps, &evalc)?;
    Ok(())
}

/// Pretrain via the train_step artifact if artifacts exist and no cached
/// model does (pjrt builds only).
#[cfg(feature = "pjrt")]
fn maybe_pretrain() {
    use std::path::Path;
    let artifacts = Path::new("artifacts/tiny-m");
    let model_path = Path::new("models/tiny-m.bin");
    if !artifacts.join("manifest.json").exists() || model_path.exists() {
        return;
    }
    let steps = std::env::var("E2E_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    println!("pretraining tiny-m for {steps} steps via the AOT train_step artifact...");
    match permllm::coordinator::pretrain(artifacts, CorpusKind::C4Like, steps, 25, model_path) {
        Ok(losses) => println!(
            "loss {:.4} -> {:.4} over {} steps",
            losses.first().copied().unwrap_or(f32::NAN),
            losses.last().copied().unwrap_or(f32::NAN),
            losses.len()
        ),
        Err(e) => eprintln!("pretrain unavailable ({e:#}); falling back to synthetic weights"),
    }
}

/// With artifacts present, also pin the host forward to the PJRT engine's
/// `lm_forward` (the artifact consumes its baked batch/seq shape).
#[cfg(feature = "pjrt")]
fn pjrt_cross_check(ps: &permllm::model::ParamStore, evalc: &Corpus) -> anyhow::Result<()> {
    use std::path::Path;
    let dir = Path::new("artifacts/tiny-m");
    if !dir.join("manifest.json").exists() {
        println!("(pjrt cross-check skipped: artifacts not built)");
        return Ok(());
    }
    let mut engine = permllm::runtime::Engine::load_lazy(dir)?;
    let (batch, seq_len) = (engine.manifest().batch, engine.manifest().config.seq_len);
    let host_ppl = eval_perplexity(ps, evalc, 555, batch, seq_len);
    let art_ppl = eval_perplexity_exec(&mut engine, ps, evalc, 555, batch, seq_len)?;
    println!("pjrt lm_forward ppl {art_ppl:.3} vs host {host_ppl:.3}");
    anyhow::ensure!(
        (host_ppl - art_ppl).abs() / host_ppl < 0.02,
        "host and pjrt artifact forward disagree: {host_ppl} vs {art_ppl}"
    );
    Ok(())
}
