//! Prune a whole tiny LLM with every method and compare perplexity —
//! a miniature Table 1 run on one model.
//!
//! ```bash
//! cargo run --release --example prune_llm -- [model] [steps]
//! ```

use permllm::bench::trained_or_synth;
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;

fn main() {
    permllm::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("tiny-s");
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);

    let (ps, prov) = trained_or_synth(model);
    println!("model {model} ({prov}), {} params", ps.n_params());
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps, lr: 0.05, ..Default::default() },
        ..Default::default()
    };

    let methods = [
        PruneMethod::Dense,
        PruneMethod::SparseGpt,
        PruneMethod::OneShot(Metric::Wanda),
        PruneMethod::OneShotCp(Metric::Wanda),
        PruneMethod::PermLlm(Metric::Wanda),
        PruneMethod::OneShot(Metric::Ria),
        PruneMethod::OneShotCp(Metric::Ria),
        PruneMethod::PermLlm(Metric::Ria),
    ];
    println!("{:<16} {:>12} {:>14} {:>10}", "method", "ppl", "mean-layer-err", "time(s)");
    for method in methods {
        let pruned = prune_model(&ps, &calib, method, &cfg);
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 6, 64);
        let err: f32 = if pruned.layer_errors.is_empty() {
            0.0
        } else {
            pruned.layer_errors.values().sum::<f32>() / pruned.layer_errors.len() as f32
        };
        println!("{:<16} {:>12.3} {:>14.5} {:>10.1}", method.name(), ppl, err, pruned.elapsed_s);
    }
}
