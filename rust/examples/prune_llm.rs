//! Prune a whole tiny LLM with every method and compare perplexity —
//! a miniature Table 1 run on one model.
//!
//! ```bash
//! cargo run --release --example prune_llm -- [model] [steps]
//! ```

use permllm::bench::trained_or_synth;
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::eval_perplexity;
use permllm::lcp::LcpCfg;
use permllm::recipe::rows;
use permllm::sparsity::NmConfig;

fn main() {
    permllm::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("tiny-s");
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);

    let (ps, prov) = trained_or_synth(model);
    println!("model {model} ({prov}), {} params", ps.n_params());
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let evalc = Corpus::build(CorpusKind::WikitextLike, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps, lr: 0.05, ..Default::default() },
        ..Default::default()
    };

    // The Table-1 recipe rows, including the ROSE-style learned-perm +
    // SparseGPT-update combination the legacy enum could not express.
    let recipes = rows::table1(NmConfig::PAT_2_4);
    println!("{:<26} {:>12} {:>14} {:>10}", "recipe", "ppl", "mean-layer-err", "time(s)");
    for recipe in recipes {
        let pruned = prune_with_recipe(&ps, &calib, &recipe, &cfg);
        let ppl = eval_perplexity(&pruned.params, &evalc, 555, 6, 64);
        let err = pruned.mean_layer_error();
        println!("{:<26} {:>12.3} {:>14.5} {:>10.1}", recipe.name(), ppl, err, pruned.elapsed_s);
    }
}
