//! Quickstart: prune one linear layer three ways and compare output error.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core public API on a single `[C_out, C_in]` layer:
//! one-shot Wanda (Eq. 7), Wanda + RIA's heuristic channel permutation,
//! and PermLLM's learnable channel permutation (Sinkhorn + Hungarian +
//! AdamW with straight-through gradients).

use permllm::cp::ria_cp;
use permllm::lcp::{train_lcp, HostBackend, LayerData, LcpCfg};
use permllm::pruning::{importance, prune_oneshot, prune_permuted, Metric};
use permllm::runtime::{ExecLcpBackend, NativeCfg, NativeEngine};
use permllm::sparsity::NmConfig;
use permllm::tensor::Mat;
use permllm::util::rng::Pcg32;

fn main() {
    permllm::util::logging::init();
    let nm = NmConfig::PAT_2_4;
    let mut rng = Pcg32::seeded(7);

    // A synthetic layer: weight [64, 128], calibration activations [96, 128].
    let w = Mat::randn(64, 128, 0.1, &mut rng);
    let x = Mat::randn(96, 128, 1.0, &mut rng);
    let y_dense = x.matmul_bt(&w);

    // 1. One-shot Wanda pruning (no permutation).
    let plain = prune_oneshot(Metric::Wanda, &w, &x, nm);
    println!("wanda            cosine-err = {:.5}", plain.cosine_error(&x, &y_dense));

    // 2. Wanda + heuristic channel permutation (RIA's two-stage CP).
    let s = importance(Metric::Wanda, &w, &x);
    let perm = ria_cp(&s, nm);
    let cp = prune_permuted(Metric::Wanda, &w, &x, nm, &perm);
    println!("wanda+CP         cosine-err = {:.5}", cp.cosine_error(&x, &y_dense));

    // 3. PermLLM: learnable channel permutation.
    let data = LayerData::new(w.clone(), s, x.clone());
    let mut backend = HostBackend::new(&data, nm, 5);
    let cfg = LcpCfg { block: 64, steps: 50, lr: 0.05, nm, ..Default::default() };
    let res = train_lcp(&mut backend, w.cols(), cfg);
    let lcp = prune_permuted(Metric::Wanda, &w, &x, nm, &res.src_of);
    println!(
        "PermLLM(wanda)   cosine-err = {:.5}  (baseline {:.5}, {} LCP steps)",
        lcp.cosine_error(&x, &y_dense),
        res.baseline_loss,
        res.history.len()
    );
    println!("mask is valid 2:4: {}", lcp.mask.verify());

    // 4. The same training loop routed through the ExecBackend trait (the
    //    interface the PJRT artifact engine also serves): identical result.
    let mut engine = NativeEngine::new(NativeCfg { nm, ..NativeCfg::default() });
    let mut exec_backend =
        ExecLcpBackend::new(&mut engine, &data, cfg.block).expect("native backend");
    let res_exec = train_lcp(&mut exec_backend, w.cols(), cfg);
    assert_eq!(res.src_of, res_exec.src_of, "trait-routed LCP must match the direct path");
    println!("ExecBackend(native) reproduces the host trajectory: OK");
}
