//! Sparse serving hot path: prune a model, compress **every** linear to
//! the Sparse-Tensor-Core layout once, and serve batched requests through
//! the `serve` subsystem — micro-batched, routed through the
//! `ExecBackend` trait (weights bound backend-resident), and pipelined
//! across decoder layers.
//!
//! Benchmarks three serving configurations over the same workload and a
//! dense baseline:
//!
//! * **dense baseline** — the decompressed dense-masked model
//!   (`serve::DenseModel`), plain matmuls, single thread: what serving
//!   would cost without the compressed N:M path;
//! * **MLP-only sparse** — decoder MLP sublayers through `sparse_fwd`,
//!   pipelined (the original serving mode);
//! * **full-decoder sparse** — attention (q/k/v/o + RoPE/causal-softmax
//!   glue) *and* MLP through `sparse_fwd`, sequential (threads=1) and
//!   pipelined.
//!
//! Verifies full-decoder parity against the host dense-masked forward
//! (<1e-3), bit-determinism across thread counts, and **gates** on the
//! full-decoder sparse throughput staying above the dense baseline
//! (`PERMLLM_BENCH_GATE` overrides the required ratio, default 1.0) —
//! the CI `bench-smoke` job runs this in fast mode and uploads the
//! `--json` summary as the bench trajectory artifact.
//!
//! ```bash
//! cargo run --release --example sparse_inference
//! PERMLLM_BENCH_FAST=1 cargo run --release --example sparse_inference -- --json bench_out.json
//! ```

use std::time::Instant;

use permllm::bench::{fast_mode, trained_or_synth};
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::runtime::{ExecBackend, NativeCfg, NativeEngine};
use permllm::serve::{
    BatcherCfg, DenseModel, Request, ServeCfg, ServePath, ServeReport, Server, SparseModel,
};
use permllm::tensor::Mat;
use permllm::util::cli::Cli;
use permllm::util::json::{self, Json};
use permllm::util::pool::default_threads;
use permllm::util::rng::Pcg32;

fn print_report(label: &str, report: &ServeReport) {
    println!(
        "[{label}] {} micro-batches, {} tokens in {:.4}s -> {:.0} tokens/s",
        report.n_batches,
        report.total_tokens,
        report.total_seconds,
        report.tokens_per_s()
    );
    for s in &report.stage_stats {
        println!(
            "[{label}]   layer {:>2}: {:>10.0} tokens/s (busy {:.4}s)",
            s.layer,
            s.tokens_per_s(),
            s.seconds
        );
    }
}

fn engines(n: usize, threads: usize) -> Vec<Box<dyn ExecBackend + Send>> {
    (0..n)
        .map(|_| {
            Box::new(NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() }))
                as Box<dyn ExecBackend + Send>
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    permllm::util::logging::init();
    let p = Cli::new(
        "sparse_inference",
        "benchmark sparse full-decoder serving vs MLP-only and the dense baseline",
    )
    .opt("json", "", "write a machine-readable summary (the CI bench artifact) to this path")
    .parse()
    .map_err(anyhow::Error::msg)?;

    // Prune + compress once.  Fast mode (CI) uses the small model and a
    // lighter workload; the full run uses tiny-m.
    let (model_name, n_requests, rows) =
        if fast_mode() { ("tiny-s", 12usize, 32usize) } else { ("tiny-m", 32, 128) };
    let (ps, prov) = trained_or_synth(model_name);
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: if fast_mode() { 8 } else { 20 }, lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    let pruned = prune_model(&ps, &calib, PruneMethod::PermLlm(Metric::Wanda), &cfg);
    let sm = SparseModel::from_pruned(&pruned)?;
    println!(
        "{model_name} ({prov}): {} linears 2:4-compressed, {} decoder stages, storage {:.3}x dense",
        ps.cfg().prunable_linears().len(),
        sm.n_stages(),
        sm.storage_bytes() as f64 / sm.dense_bytes() as f64
    );

    // Decompress once for the dense baseline (never part of serving).
    let dense = DenseModel::from_sparse(&sm);

    // The request workload (identical for every configuration).
    let width = sm.width();
    let n_stages = sm.n_stages();
    let make_requests = || {
        let mut rng = Pcg32::seeded(5);
        (0..n_requests)
            .map(|id| Request { id: id as u64, x: Mat::randn(rows, width, 1.0, &mut rng) })
            .collect::<Vec<Request>>()
    };
    let requests = make_requests();
    let mut server = Server::new(
        sm,
        ServeCfg {
            batcher: BatcherCfg { max_tokens: rows * 4, max_requests: 8 },
            path: ServePath::FullDecoder,
            ..ServeCfg::default()
        },
    );
    println!(
        "workload: {n_requests} requests x {rows} tokens, micro-batch budget {} tokens",
        rows * 4
    );

    // Dense full-decoder baseline: plain matmuls, single thread — the
    // cost of serving without the compressed N:M path.
    let t0 = Instant::now();
    for req in &requests {
        std::hint::black_box(dense.forward(&req.x, &[(0, req.x.rows())], ServePath::FullDecoder));
    }
    let dense_s = t0.elapsed().as_secs_f64();
    let total_tokens = (n_requests * rows) as f64;
    let dense_tps = total_tokens / dense_s.max(1e-12);
    println!(
        "[dense full-decoder baseline] {total_tokens} tokens in {dense_s:.4}s \
         -> {dense_tps:.0} tokens/s"
    );

    let cores = default_threads();
    let threads = (cores / n_stages).max(1);

    // MLP-only sparse (the original serving mode), pipelined.
    server.cfg_mut().path = ServePath::MlpOnly;
    let mlp = server.run_pipelined(make_requests(), engines(n_stages, threads))?;
    print_report("mlp-only pipelined", &mlp);

    // Full decoder, sequential single-thread baseline.
    server.cfg_mut().path = ServePath::FullDecoder;
    let mut engine1 = NativeEngine::new(NativeCfg { threads: 1, ..NativeCfg::default() });
    let seq = server.run_sequential(make_requests(), &mut engine1)?;
    print_report("full-decoder threads=1 sequential", &seq);

    // Full decoder, parallel + pipelined: one backend per decoder layer.
    // Stages run concurrently, so the visible cores are divided across
    // them rather than oversubscribed with n_stages x cores workers.
    let par = server.run_pipelined(make_requests(), engines(n_stages, threads))?;
    print_report(&format!("full-decoder threads/stage={threads} pipelined"), &par);
    println!(
        "speedup: {:.2}x vs dense, {:.2}x vs threads=1 ({cores} core(s) across {n_stages} stages)",
        par.tokens_per_s() / dense_tps.max(1e-12),
        par.tokens_per_s() / seq.tokens_per_s().max(1e-12)
    );

    // Determinism: the output-row-tiled kernel is bit-exact at any thread
    // count, so both full-decoder configurations must agree exactly.
    for ((id_s, y_s), (_, y_p)) in seq.outputs.iter().zip(&par.outputs) {
        anyhow::ensure!(y_s.data() == y_p.data(), "request {id_s}: configurations diverged");
    }
    println!("threads=1 and threads={threads} outputs are bit-identical: OK");

    // Parity: full-decoder sparse serving (attention + MLP through
    // sparse_fwd) vs the host dense-masked forward.
    let mut max_err = 0.0f32;
    for ((_, got), req) in par.outputs.iter().zip(&requests) {
        let want = server.model().dense_forward(
            &req.x,
            &[(0, req.x.rows())],
            ServePath::FullDecoder,
        );
        for (a, b) in got.data().iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!("max |sparse full-decoder - dense-masked| = {max_err:.2e}");

    // The CI bench gate: full-decoder sparse serving must not regress
    // below the dense baseline.
    let gate: f64 = std::env::var("PERMLLM_BENCH_GATE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);

    let summary = json::obj(vec![
        ("model", json::s(model_name)),
        ("provenance", json::s(prov)),
        ("fast_mode", Json::Bool(fast_mode())),
        ("requests", json::num(n_requests as f64)),
        ("rows_per_request", json::num(rows as f64)),
        ("stages", json::num(n_stages as f64)),
        ("threads_per_stage", json::num(threads as f64)),
        ("dense_tokens_per_s", json::num(dense_tps)),
        ("sparse_mlp_only_tokens_per_s", json::num(mlp.tokens_per_s())),
        ("sparse_full_decoder_seq_tokens_per_s", json::num(seq.tokens_per_s())),
        ("sparse_full_decoder_tokens_per_s", json::num(par.tokens_per_s())),
        ("speedup_vs_dense", json::num(par.tokens_per_s() / dense_tps.max(1e-12))),
        ("max_abs_err", json::num(max_err as f64)),
        ("gate_ratio", json::num(gate)),
    ]);
    let json_path = p.get("json");
    if !json_path.is_empty() {
        // Written before the gate so CI uploads the numbers even when the
        // gate trips.
        std::fs::write(json_path, summary.to_string() + "\n")?;
        println!("wrote bench summary to {json_path}");
    }

    anyhow::ensure!(max_err < 1e-3, "numeric mismatch");
    println!("sparse full-decoder serving matches the dense-masked reference: OK");
    anyhow::ensure!(
        par.tokens_per_s() >= dense_tps * gate,
        "bench gate: sparse full-decoder throughput {:.0} tokens/s fell below {gate:.2}x the \
         dense baseline ({dense_tps:.0} tokens/s)",
        par.tokens_per_s()
    );
    println!(
        "bench gate: sparse full-decoder >= {gate:.2}x dense: OK ({:.0} vs {dense_tps:.0} tok/s)",
        par.tokens_per_s()
    );
    Ok(())
}
