//! Sparse serving hot path: prune a model, compress **every** linear to
//! the Sparse-Tensor-Core layout once, and serve batched requests through
//! the `serve` subsystem — micro-batched, routed through the
//! `ExecBackend` trait (weights bound backend-resident), and pipelined
//! across decoder layers.
//!
//! Benchmarks three serving configurations over the same workload and a
//! dense baseline:
//!
//! * **dense baseline** — the decompressed dense-masked model
//!   (`serve::DenseModel`), plain matmuls, single thread: what serving
//!   would cost without the compressed N:M path;
//! * **MLP-only sparse** — decoder MLP sublayers through `sparse_fwd`,
//!   pipelined (the original serving mode);
//! * **full-decoder sparse** — attention (q/k/v/o + RoPE/causal-softmax
//!   glue) *and* MLP through `sparse_fwd`, sequential (threads=1) and
//!   pipelined.
//!
//! A second section benchmarks the **KV-cached generation** path:
//! prefill vs decode tokens/s for the dense baseline, MLP-only sparse,
//! and full-decoder sparse (batched greedy decode through
//! `forward_cached`), and verifies the KV-cached token trajectory
//! against a full-sequence re-forward greedy loop.  A paged-KV workload
//! then serves two generations through a pressure-sized `KvPool`
//! (preemption + copy-on-write prefix sharing) and verifies they still
//! match the sequential contiguous reference.  Finally a seeded mixed
//! workload trace (`serve::trace`) is replayed through the decode loop
//! and its per-class SLO report lands in the JSON summary as
//! `trace_bench`.
//!
//! Two hot-path sections ride along (see docs/BENCH_SCHEMA.md):
//! a **kernel micro-bench** timing the vectorized gather-FMA N:M kernel
//! against the preserved scalar reference on decode-shaped activations
//! (`kernel_speedup_vs_scalar`, gated at `PERMLLM_KERNEL_GATE` x, default
//! 1.0), and a **zero-alloc decode** pass that repeats the generation
//! workload through the arena-backed `forward_cached_scratch` and counts
//! heap allocations around each steady-state forward via this binary's
//! counting global allocator (`decode_allocs_per_step`, gated at 0).
//!
//! Verifies full-decoder parity against the host dense-masked forward
//! (<1e-3), bit-determinism across thread counts, and **gates** on the
//! full-decoder sparse throughput staying above the dense baseline —
//! forward *and* decode, both at `PERMLLM_BENCH_GATE` x dense (default
//! 1.0) — the CI `bench-smoke` job runs this in fast mode and uploads
//! the `--json` summary as the bench trajectory artifact.
//!
//! ```bash
//! cargo run --release --example sparse_inference
//! PERMLLM_BENCH_FAST=1 cargo run --release --example sparse_inference -- --json bench_out.json
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use permllm::bench::{fast_mode, trained_or_synth};
use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::recipe::{LearnedPerm, PruneRecipe};
use permllm::runtime::{ExecBackend, NativeCfg, NativeEngine};
use permllm::serve::{
    greedy_token, trace, BatcherCfg, DenseModel, GenRequest, KvStore, Percentiles, Request,
    Sampler, ServeCfg, ServePath, ServeReport, Server, SparseModel,
};
use permllm::sparsity::{Compressed, NmConfig, NmMask};
use permllm::tensor::Mat;
use permllm::util::cli::Cli;
use permllm::util::json::{self, Json};
use permllm::util::pool::default_threads;
use permllm::util::rng::Pcg32;
use permllm::util::scratch::StepArena;

/// The system allocator wrapped with an allocation counter, so the
/// zero-alloc decode section can measure `decode_allocs_per_step`
/// directly instead of inferring it.  Counts allocations and
/// reallocations; frees are not interesting here.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn print_report(label: &str, report: &ServeReport) {
    println!(
        "[{label}] {} micro-batches, {} tokens in {:.4}s -> {:.0} tokens/s",
        report.n_batches,
        report.total_tokens,
        report.total_seconds,
        report.tokens_per_s()
    );
    for s in &report.stage_stats {
        println!(
            "[{label}]   layer {:>2}: {:>10.0} tokens/s (busy {:.4}s)",
            s.layer,
            s.tokens_per_s(),
            s.seconds
        );
    }
}

fn engines(n: usize, threads: usize) -> Vec<Box<dyn ExecBackend + Send>> {
    (0..n)
        .map(|_| {
            Box::new(NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() }))
                as Box<dyn ExecBackend + Send>
        })
        .collect()
}

/// One KV-cached generation bench over a batch of prompts: timed prefill
/// (all prompts as one span batch) and a timed greedy decode loop
/// (`gen_steps` one-token steps per prompt, batched across prompts).
/// Returns `(prefill_seconds, decode_seconds, per-step seconds,
/// per-prompt tokens)` — the per-step samples feed the decode
/// tail-latency percentiles in the bench artifact — generic over the
/// model via closures so the dense baseline and both sparse paths run
/// the identical loop.
fn decode_bench(
    width: usize,
    new_cache: &dyn Fn() -> KvStore,
    embed: &dyn Fn(&[u32]) -> anyhow::Result<Mat>,
    logits_of: &dyn Fn(&Mat) -> Mat,
    mut fwd: impl FnMut(&Mat, &[(usize, usize)], &mut [KvStore]) -> anyhow::Result<Mat>,
    prompts: &[Vec<u32>],
    gen_steps: usize,
) -> anyhow::Result<(f64, f64, Vec<f64>, Vec<Vec<u32>>)> {
    let r = prompts.len();
    let rows = prompts[0].len();
    let mut caches: Vec<KvStore> = (0..r).map(|_| new_cache()).collect();
    let mut x = Mat::zeros(r * rows, width);
    let mut spans = Vec::with_capacity(r);
    for (i, p) in prompts.iter().enumerate() {
        let e = embed(p)?;
        for rr in 0..rows {
            x.row_mut(i * rows + rr).copy_from_slice(e.row(rr));
        }
        spans.push((i * rows, (i + 1) * rows));
    }
    let t0 = Instant::now();
    let h = fwd(&x, &spans, &mut caches)?;
    let prefill_s = t0.elapsed().as_secs_f64();

    let step_spans: Vec<(usize, usize)> = (0..r).map(|i| (i, i + 1)).collect();
    let mut cur = Mat::zeros(r, width);
    for (i, &(_, hi)) in spans.iter().enumerate() {
        cur.row_mut(i).copy_from_slice(h.row(hi - 1));
    }
    let mut tokens: Vec<Vec<u32>> = vec![Vec::new(); r];
    let mut step_s = Vec::with_capacity(gen_steps);
    let t1 = Instant::now();
    for _ in 0..gen_steps {
        let s0 = Instant::now();
        let logits = logits_of(&cur);
        let mut xs = Mat::zeros(r, width);
        for i in 0..r {
            let tok = greedy_token(logits.row(i));
            tokens[i].push(tok);
            xs.row_mut(i).copy_from_slice(embed(&[tok])?.row(0));
        }
        cur = fwd(&xs, &step_spans, &mut caches)?;
        step_s.push(s0.elapsed().as_secs_f64());
    }
    let decode_s = t1.elapsed().as_secs_f64();
    Ok((prefill_s, decode_s, step_s, tokens))
}

/// Nearest-rank p50/p90/p99 over per-decode-step seconds, in ms — every
/// request advances one token per step, so a step's duration *is* the
/// per-token latency at this batch size.
fn step_percentiles_ms(step_s: &[f64]) -> Percentiles {
    let mut ms: Vec<f64> = step_s.iter().map(|s| s * 1e3).collect();
    Percentiles::of(&mut ms)
}

/// Best-of-`trials` wall time (seconds) for `reps` calls of `f` — the
/// minimum over trials de-noises a shared CI runner.
fn best_time(trials: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..trials {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// One full generation pass (prefill + `gen_steps` greedy decode steps)
/// through the arena-backed [`SparseModel::forward_cached_scratch`],
/// counting heap allocations around each decode-step forward.  Pass 1
/// with a fresh [`StepArena`] is the warmup that sizes the pools (the
/// attention score buffer needs `pos + rows` floats, which grows every
/// step, so only a full pass reaches the high-water mark); pass 2 over
/// the identical workload with the same arena must then run every
/// forward without touching the heap.  Returns
/// `(forward_allocations, decode_steps, per-prompt tokens)`.
fn decode_scratch_pass(
    sm: &SparseModel,
    engine: &mut dyn ExecBackend,
    prompts: &[Vec<u32>],
    gen_steps: usize,
    arena: &mut StepArena,
) -> anyhow::Result<(u64, u64, Vec<Vec<u32>>)> {
    let r = prompts.len();
    let rows = prompts[0].len();
    let width = sm.width();
    let path = ServePath::FullDecoder;
    let mut caches: Vec<KvStore> = (0..r).map(|_| sm.new_cache()).collect();
    for c in &mut caches {
        // Pre-size the KV buffers for the whole generation, so appends
        // inside the measured forwards cannot reallocate.
        c.reserve(rows + gen_steps);
    }
    let mut x = Mat::zeros(r * rows, width);
    let mut spans = Vec::with_capacity(r);
    for (i, p) in prompts.iter().enumerate() {
        let e = sm.embed(p)?;
        for rr in 0..rows {
            x.row_mut(i * rows + rr).copy_from_slice(e.row(rr));
        }
        spans.push((i * rows, (i + 1) * rows));
    }
    let h = sm.forward_cached_scratch(engine, &x, &spans, &mut caches, path, arena)?;
    let step_spans: Vec<(usize, usize)> = (0..r).map(|i| (i, i + 1)).collect();
    let mut cur = Mat::zeros(r, width);
    for (i, &(_, hi)) in spans.iter().enumerate() {
        cur.row_mut(i).copy_from_slice(h.row(hi - 1));
    }
    arena.give(h);
    arena.step();
    let mut tokens: Vec<Vec<u32>> = vec![Vec::new(); r];
    let mut xs = Mat::zeros(r, width);
    let mut fwd_allocs = 0u64;
    for _ in 0..gen_steps {
        // Sampling/embedding are the exits of the gated scope: the
        // counter brackets only the arena-backed forward.
        let logits = sm.logits(&cur);
        for i in 0..r {
            let tok = greedy_token(logits.row(i));
            tokens[i].push(tok);
            xs.row_mut(i).copy_from_slice(sm.embed(&[tok])?.row(0));
        }
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let h = sm.forward_cached_scratch(engine, &xs, &step_spans, &mut caches, path, arena)?;
        fwd_allocs += ALLOCS.load(Ordering::Relaxed) - a0;
        cur.data_mut().copy_from_slice(h.data());
        arena.give(h);
        arena.step();
    }
    Ok((fwd_allocs, gen_steps as u64, tokens))
}

fn main() -> anyhow::Result<()> {
    permllm::util::logging::init();
    let p = Cli::new(
        "sparse_inference",
        "benchmark sparse full-decoder serving vs MLP-only and the dense baseline",
    )
    .opt("json", "", "write a machine-readable summary (the CI bench artifact) to this path")
    .parse()
    .map_err(anyhow::Error::msg)?;

    // Prune + compress once.  Fast mode (CI) uses the small model and a
    // lighter workload; the full run uses tiny-m.
    let (model_name, n_requests, rows) =
        if fast_mode() { ("tiny-s", 12usize, 32usize) } else { ("tiny-m", 32, 128) };
    let (ps, prov) = trained_or_synth(model_name);
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: if fast_mode() { 8 } else { 20 }, lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    let recipe = PruneRecipe::builder(NmConfig::PAT_2_4)
        .metric_kind(Metric::Wanda)
        .perm(LearnedPerm::default())
        .build();
    let pruned = prune_with_recipe(&ps, &calib, &recipe, &cfg);
    let sm = SparseModel::from_pruned(&pruned)?;
    println!(
        "{model_name} ({prov}): {} linears 2:4-compressed by recipe {}, {} decoder stages, \
         storage {:.3}x dense",
        ps.cfg().prunable_linears().len(),
        sm.recipe_name(),
        sm.n_stages(),
        sm.storage_bytes() as f64 / sm.dense_bytes() as f64
    );

    // Decompress once for the dense baseline (never part of serving).
    let dense = DenseModel::from_sparse(&sm);

    // The request workload (identical for every configuration).
    let width = sm.width();
    let n_stages = sm.n_stages();
    let make_requests = || {
        let mut rng = Pcg32::seeded(5);
        (0..n_requests)
            .map(|id| Request { id: id as u64, x: Mat::randn(rows, width, 1.0, &mut rng) })
            .collect::<Vec<Request>>()
    };
    let requests = make_requests();
    let mut server = Server::new(
        sm,
        ServeCfg {
            batcher: BatcherCfg { max_tokens: rows * 4, max_requests: 8 },
            path: ServePath::FullDecoder,
            ..ServeCfg::default()
        },
    );
    println!(
        "workload: {n_requests} requests x {rows} tokens, micro-batch budget {} tokens",
        rows * 4
    );

    // Dense full-decoder baseline: plain matmuls, single thread — the
    // cost of serving without the compressed N:M path.
    let t0 = Instant::now();
    for req in &requests {
        std::hint::black_box(dense.forward(&req.x, &[(0, req.x.rows())], ServePath::FullDecoder));
    }
    let dense_s = t0.elapsed().as_secs_f64();
    let total_tokens = (n_requests * rows) as f64;
    let dense_tps = total_tokens / dense_s.max(1e-12);
    println!(
        "[dense full-decoder baseline] {total_tokens} tokens in {dense_s:.4}s \
         -> {dense_tps:.0} tokens/s"
    );

    let cores = default_threads();
    let threads = (cores / n_stages).max(1);

    // MLP-only sparse (the original serving mode), pipelined.
    server.cfg_mut().path = ServePath::MlpOnly;
    let mlp = server.run_pipelined(make_requests(), engines(n_stages, threads))?;
    print_report("mlp-only pipelined", &mlp);

    // Full decoder, sequential single-thread baseline.
    server.cfg_mut().path = ServePath::FullDecoder;
    let mut engine1 = NativeEngine::new(NativeCfg { threads: 1, ..NativeCfg::default() });
    let seq = server.run_sequential(make_requests(), &mut engine1)?;
    print_report("full-decoder threads=1 sequential", &seq);

    // Full decoder, parallel + pipelined: one backend per decoder layer.
    // Stages run concurrently, so the visible cores are divided across
    // them rather than oversubscribed with n_stages x cores workers.
    let par = server.run_pipelined(make_requests(), engines(n_stages, threads))?;
    print_report(&format!("full-decoder threads/stage={threads} pipelined"), &par);
    println!(
        "speedup: {:.2}x vs dense, {:.2}x vs threads=1 ({cores} core(s) across {n_stages} stages)",
        par.tokens_per_s() / dense_tps.max(1e-12),
        par.tokens_per_s() / seq.tokens_per_s().max(1e-12)
    );

    // Determinism: the output-row-tiled kernel is bit-exact at any thread
    // count, so both full-decoder configurations must agree exactly.
    for ((id_s, y_s), (_, y_p)) in seq.outputs.iter().zip(&par.outputs) {
        anyhow::ensure!(y_s.data() == y_p.data(), "request {id_s}: configurations diverged");
    }
    println!("threads=1 and threads={threads} outputs are bit-identical: OK");

    // Parity: full-decoder sparse serving (attention + MLP through
    // sparse_fwd) vs the host dense-masked forward.
    let mut max_err = 0.0f32;
    for ((_, got), req) in par.outputs.iter().zip(&requests) {
        let want = server.model().dense_forward(
            &req.x,
            &[(0, req.x.rows())],
            ServePath::FullDecoder,
        );
        for (a, b) in got.data().iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!("max |sparse full-decoder - dense-masked| = {max_err:.2e}");

    // ---- prefill vs decode: KV-cached generation throughput ----
    // The same greedy generation workload (prefill `rows`-token prompts,
    // then `gen_steps` batched one-token decode steps) on the dense
    // baseline, the MLP-only sparse path, and the full-decoder sparse
    // path.  Decode is where N:M sparsity pays at serving time: every
    // step is one row per request, so the matmuls are as memory-bound as
    // they get.
    let gen_steps = if fast_mode() { 8 } else { 32 };
    let mut rng = Pcg32::seeded(17);
    let prompts: Vec<Vec<u32>> =
        (0..n_requests).map(|_| (0..rows).map(|_| rng.below(256)).collect()).collect();
    let sm = server.model();
    let prefill_tokens = (n_requests * rows) as f64;
    let decode_rows = (n_requests * gen_steps) as f64;
    // threads=1: decode-step matmuls are tiny ([requests, d] activations)
    // and the row-tile fan-out spawns scoped threads per call, which
    // would cost more than it tiles — single-thread is the honest
    // apples-to-apples against the single-thread dense baseline.
    let mut decode_engine = NativeEngine::new(NativeCfg { threads: 1, ..NativeCfg::default() });
    let mut bench_path = |path: ServePath| {
        let engine = &mut decode_engine;
        decode_bench(
            sm.width(),
            &|| sm.new_cache(),
            &|t| sm.embed(t),
            &|h| sm.logits(h),
            |x, s, c| sm.forward_cached(engine, x, s, c, path),
            &prompts,
            gen_steps,
        )
    };
    let (mlp_pre_s, mlp_dec_s, mlp_step_s, _) = bench_path(ServePath::MlpOnly)?;
    let (fd_pre_s, fd_dec_s, fd_step_s, fd_tokens) = bench_path(ServePath::FullDecoder)?;
    let (dn_pre_s, dn_dec_s, dn_step_s, dn_tokens) = decode_bench(
        dense.width(),
        &|| dense.new_cache(),
        &|t| dense.embed(t),
        &|h| dense.logits(h),
        |x, s, c| Ok(dense.forward_cached(x, s, c, ServePath::FullDecoder)),
        &prompts,
        gen_steps,
    )?;
    let tps = |tokens: f64, s: f64| tokens / s.max(1e-12);
    let (dn_pre, dn_dec) = (tps(prefill_tokens, dn_pre_s), tps(decode_rows, dn_dec_s));
    let (mlp_pre, mlp_dec) = (tps(prefill_tokens, mlp_pre_s), tps(decode_rows, mlp_dec_s));
    let (fd_pre, fd_dec) = (tps(prefill_tokens, fd_pre_s), tps(decode_rows, fd_dec_s));
    println!("[decode bench] {n_requests} prompts x {rows} tokens, {gen_steps} greedy steps:");
    println!("[decode bench]   dense         prefill {dn_pre:>9.0} tok/s | decode {dn_dec:>9.0} tok/s");
    println!("[decode bench]   mlp-only      prefill {mlp_pre:>9.0} tok/s | decode {mlp_dec:>9.0} tok/s");
    println!("[decode bench]   full-decoder  prefill {fd_pre:>9.0} tok/s | decode {fd_dec:>9.0} tok/s");
    println!(
        "[decode bench]   full-decoder decode speedup vs dense: {:.2}x",
        fd_dec / dn_dec.max(1e-12)
    );
    let (dn_lat, mlp_lat, fd_lat) = (
        step_percentiles_ms(&dn_step_s),
        step_percentiles_ms(&mlp_step_s),
        step_percentiles_ms(&fd_step_s),
    );
    println!(
        "[decode bench]   per-token latency (ms): dense p50 {:.3} / p99 {:.3}, mlp-only p50 \
         {:.3} / p99 {:.3}, full-decoder p50 {:.3} / p99 {:.3}",
        dn_lat.p50, dn_lat.p99, mlp_lat.p50, mlp_lat.p99, fd_lat.p50, fd_lat.p99
    );

    // Decode parity: the KV-cached full-decoder generation of prompt 0
    // must match a greedy loop that re-forwards the whole sequence per
    // step (no cache) — same kernels, so the tokens must agree exactly.
    let mut all = prompts[0].clone();
    let mut want = Vec::with_capacity(gen_steps);
    for _ in 0..gen_steps {
        let x = sm.embed(&all)?;
        let h = sm.forward(&mut engine1, &x, &[(0, x.rows())], ServePath::FullDecoder)?;
        let tok = greedy_token(sm.logits(&h.row_block(h.rows() - 1, h.rows())).row(0));
        want.push(tok);
        all.push(tok);
    }
    anyhow::ensure!(
        fd_tokens[0] == want,
        "KV-cached decode diverged from full re-forward: {:?} vs {want:?}",
        fd_tokens[0]
    );
    println!("KV-cached decode matches full-sequence re-forward greedy tokens: OK");
    // The dense baseline decodes the same greedy trajectory (its logits
    // agree within the sparse-vs-dense tolerance; ties aside, tokens
    // should rarely differ — report, don't gate).
    let agree = fd_tokens.iter().zip(&dn_tokens).filter(|(a, b)| a == b).count();
    println!("dense and sparse decode agree on {agree}/{n_requests} token trajectories");

    // ---- kernel micro-bench: vectorized gather-FMA vs scalar reference ----
    // Decode-shaped activations (one row per in-flight request): the
    // vectorized kernel blocks LANES activation rows per compressed
    // entry and reads the precomputed gather indices; the scalar
    // reference is the pre-vectorization loop kept verbatim.  Both are
    // bit-identical by construction, so this is a pure speed comparison.
    let kw = Mat::randn(width, width, 1.0, &mut rng);
    let kmask = NmMask::from_scores(&kw.map(f32::abs), NmConfig::PAT_2_4);
    let kcomp = Compressed::compress(&kw, &kmask);
    let kx = Mat::randn(n_requests, width, 1.0, &mut rng);
    anyhow::ensure!(
        kcomp.matmul_xt(&kx).data() == kcomp.matmul_xt_scalar(&kx).data(),
        "vectorized kernel diverged from the scalar reference"
    );
    let (trials, reps) = if fast_mode() { (3, 50) } else { (5, 200) };
    let vec_s = best_time(trials, reps, || {
        std::hint::black_box(kcomp.matmul_xt(std::hint::black_box(&kx)));
    });
    let scalar_s = best_time(trials, reps, || {
        std::hint::black_box(kcomp.matmul_xt_scalar(std::hint::black_box(&kx)));
    });
    let kernel_speedup = scalar_s.max(1e-12) / vec_s.max(1e-12);
    println!(
        "[kernel bench] {width}x{width} 2:4, {n_requests}-row decode activations: vectorized \
         {:.4}ms vs scalar {:.4}ms per call -> {kernel_speedup:.2}x",
        vec_s * 1e3 / reps as f64,
        scalar_s * 1e3 / reps as f64
    );

    // ---- zero-alloc decode hot path: arena-backed forward ----
    // Repeat the generation workload through `forward_cached_scratch`:
    // pass 1 warms the arena to the workload's high-water mark, pass 2
    // must then run every steady-state forward without a single heap
    // allocation — and both must reproduce the `forward_cached` tokens
    // bit-for-bit.
    let mut arena = StepArena::new();
    let scratch_engine = &mut decode_engine;
    let (_, _, warm_tokens) =
        decode_scratch_pass(sm, scratch_engine, &prompts, gen_steps, &mut arena)?;
    let warm_grows = arena.grow_events();
    let (fwd_allocs, alloc_steps, scratch_tokens) =
        decode_scratch_pass(sm, scratch_engine, &prompts, gen_steps, &mut arena)?;
    anyhow::ensure!(
        warm_tokens == fd_tokens && scratch_tokens == fd_tokens,
        "scratch-arena decode diverged from forward_cached"
    );
    anyhow::ensure!(
        arena.grow_events() == warm_grows,
        "warmed-up arena grew during the measured pass ({} -> {} grow events)",
        warm_grows,
        arena.grow_events()
    );
    let decode_allocs_per_step = fwd_allocs as f64 / alloc_steps.max(1) as f64;
    println!(
        "[alloc bench] scratch decode: {fwd_allocs} heap allocations across {alloc_steps} \
         steady-state steps ({decode_allocs_per_step:.2}/step; arena holds {} pooled buffers \
         after {warm_grows} warmup grow events)",
        arena.pooled()
    );

    // ---- paged-KV pool: preemption + recompute under page pressure ----
    // Serve two full-decoder generations through the continuous-batching
    // decode loop with a pool sized for exactly one request's worst
    // case: their combined peak cannot fit, so the loop preempts and
    // recomputes, and must still produce exactly the sequential
    // reference tokens.  Prefix sharing is on — identical prompts, so
    // an admission after the first prefill adopts its pages
    // copy-on-write.
    let kv_new = gen_steps;
    let kv_pt = 16usize;
    let kv_layers = server.model().cfg().n_layers;
    let kv_pool_pages = kv_layers * (rows + kv_new - 1).div_ceil(kv_pt);
    server.cfg_mut().path = ServePath::FullDecoder;
    server.cfg_mut().kv_pages = kv_pool_pages;
    server.cfg_mut().kv_page_tokens = kv_pt;
    server.cfg_mut().kv_share_prefix = true;
    let kv_prompt = prompts[0].clone();
    let (kv_outs, kv_report) = server.run_decode_streaming(engines(1, 1), |client| {
        let tickets: Vec<_> = (0..2)
            .map(|_| client.submit(GenRequest::greedy(kv_prompt.clone(), kv_new)).unwrap())
            .collect();
        tickets.into_iter().map(|t| t.wait().unwrap()).collect::<Vec<_>>()
    })?;
    let kv_want = server.model().generate(
        &mut engine1,
        &kv_prompt,
        kv_new,
        None,
        ServePath::FullDecoder,
        Sampler::Greedy,
    )?;
    for toks in &kv_outs {
        anyhow::ensure!(
            toks == &kv_want,
            "paged decode diverged from the sequential contiguous reference"
        );
    }
    anyhow::ensure!(
        kv_report.stats.kv_free_pages == kv_pool_pages,
        "paged serving leaked pages: {} of {kv_pool_pages} free at drain",
        kv_report.stats.kv_free_pages
    );
    println!(
        "[paged kv] pool {kv_pool_pages} pages x {kv_pt} tokens: {} preemptions, shared peak \
         {} pages, {} CoW forks; both generations match the sequential reference: OK",
        kv_report.stats.kv_preemptions,
        kv_report.stats.kv_shared_pages_peak,
        kv_report.stats.kv_cow_forks
    );
    server.cfg_mut().kv_pages = 0;

    // ---- trace-driven workload replay: per-class SLOs ----
    // A small seeded mixed workload (chat / longdoc / burst /
    // prefix-fleet) replayed through the decode loop at its recorded
    // arrival times, paged pool + prefix sharing on so the fleet
    // prefixes exercise copy-on-write page adoption.  Lands in the JSON
    // artifact as `trace_bench` (field glossary: docs/BENCH_SCHEMA.md).
    let tb_cfg = trace::TraceCfg {
        vocab: server.model().cfg().vocab as u32,
        prefix_tokens: kv_pt,
        horizon_ms: 60,
        deadline_ms: 0,
        ..trace::TraceCfg::default()
    }
    .with_requests(if fast_mode() { 10 } else { 20 });
    let workload = trace::generate(&tb_cfg);
    server.cfg_mut().kv_pages = 256;
    server.cfg_mut().kv_page_tokens = kv_pt;
    server.cfg_mut().kv_share_prefix = true;
    let (slo, _) = trace::replay(&server, engines(1, 1), &workload)?;
    server.cfg_mut().kv_pages = 0;
    server.cfg_mut().kv_share_prefix = false;
    println!(
        "[trace bench] {} requests replayed in {:.2}s ({} classes, {} CoW forks, {} preemptions):",
        slo.n_requests,
        slo.replay_seconds,
        slo.classes.len(),
        slo.kv_cow_forks,
        slo.kv_preemptions
    );
    for c in &slo.classes {
        println!(
            "[trace bench]   {:<13} {:>3} reqs, first-token p50 {:>7.2}ms p99 {:>7.2}ms, \
             per-token p50 {:>6.3}ms p99 {:>6.3}ms",
            c.class,
            c.n_requests,
            c.first_token_ms.p50,
            c.first_token_ms.p99,
            c.token_latency_ms.p50,
            c.token_latency_ms.p99
        );
    }
    anyhow::ensure!(
        slo.n_completed == slo.n_requests,
        "trace replay dropped requests: {} of {} completed ({} rejected, {} failed)",
        slo.n_completed,
        slo.n_requests,
        slo.n_rejected,
        slo.n_failed
    );
    println!("[trace bench] every trace request completed: OK");

    // The CI bench gate: full-decoder sparse serving must not regress
    // below the dense baseline.
    let gate: f64 = std::env::var("PERMLLM_BENCH_GATE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    // The kernel-ratio gate: the vectorized kernel must stay at least
    // this much faster than the preserved scalar reference.
    let kernel_gate: f64 = std::env::var("PERMLLM_KERNEL_GATE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);

    let summary = json::obj(vec![
        ("model", json::s(model_name)),
        ("provenance", json::s(prov)),
        // Which metric × permutation × update produced the weights —
        // the bench artifact is self-describing about its recipe.
        ("method", json::s(server.model().recipe_name())),
        ("recipe", server.model().recipe_json().clone()),
        ("fast_mode", Json::Bool(fast_mode())),
        ("requests", json::num(n_requests as f64)),
        ("rows_per_request", json::num(rows as f64)),
        ("stages", json::num(n_stages as f64)),
        ("threads_per_stage", json::num(threads as f64)),
        ("dense_tokens_per_s", json::num(dense_tps)),
        ("sparse_mlp_only_tokens_per_s", json::num(mlp.tokens_per_s())),
        ("sparse_full_decoder_seq_tokens_per_s", json::num(seq.tokens_per_s())),
        ("sparse_full_decoder_tokens_per_s", json::num(par.tokens_per_s())),
        ("speedup_vs_dense", json::num(par.tokens_per_s() / dense_tps.max(1e-12))),
        ("max_abs_err", json::num(max_err as f64)),
        ("gate_ratio", json::num(gate)),
        ("decode_steps", json::num(gen_steps as f64)),
        ("dense_prefill_tokens_per_s", json::num(dn_pre)),
        ("dense_decode_tokens_per_s", json::num(dn_dec)),
        ("sparse_mlp_only_prefill_tokens_per_s", json::num(mlp_pre)),
        ("sparse_mlp_only_decode_tokens_per_s", json::num(mlp_dec)),
        ("sparse_full_decoder_prefill_tokens_per_s", json::num(fd_pre)),
        ("sparse_full_decoder_decode_tokens_per_s", json::num(fd_dec)),
        ("decode_speedup_vs_dense", json::num(fd_dec / dn_dec.max(1e-12))),
        // Decode tail latency (nearest-rank percentiles over per-step
        // wall clock, ms) — BENCH_serving.json's tail-latency columns.
        ("dense_decode_token_latency_p50_ms", json::num(dn_lat.p50)),
        ("dense_decode_token_latency_p90_ms", json::num(dn_lat.p90)),
        ("dense_decode_token_latency_p99_ms", json::num(dn_lat.p99)),
        ("sparse_mlp_only_decode_token_latency_p50_ms", json::num(mlp_lat.p50)),
        ("sparse_mlp_only_decode_token_latency_p90_ms", json::num(mlp_lat.p90)),
        ("sparse_mlp_only_decode_token_latency_p99_ms", json::num(mlp_lat.p99)),
        ("sparse_full_decoder_decode_token_latency_p50_ms", json::num(fd_lat.p50)),
        ("sparse_full_decoder_decode_token_latency_p90_ms", json::num(fd_lat.p90)),
        ("sparse_full_decoder_decode_token_latency_p99_ms", json::num(fd_lat.p99)),
        // Hot-path micro-metrics (docs/BENCH_SCHEMA.md): vectorized N:M
        // kernel vs the preserved scalar reference, and heap allocations
        // per steady-state arena-backed decode forward.
        ("kernel_speedup_vs_scalar", json::num(kernel_speedup)),
        ("kernel_gate_ratio", json::num(kernel_gate)),
        ("decode_allocs_per_step", json::num(decode_allocs_per_step)),
        // Paged-KV pool workload (pressure-sized: forces preemption and
        // exercises copy-on-write prefix sharing).
        ("kv_pool_pages", json::num(kv_pool_pages as f64)),
        ("kv_page_tokens", json::num(kv_pt as f64)),
        ("kv_preemptions", json::num(kv_report.stats.kv_preemptions as f64)),
        ("kv_shared_pages_peak", json::num(kv_report.stats.kv_shared_pages_peak as f64)),
        ("kv_cow_forks", json::num(kv_report.stats.kv_cow_forks as f64)),
        // Per-class SLO report from the trace-driven workload replay
        // (serve::trace) — docs/BENCH_SCHEMA.md documents the fields.
        ("trace_bench", slo.to_json()),
    ]);
    let json_path = p.get("json");
    if !json_path.is_empty() {
        // Written before the gate so CI uploads the numbers even when the
        // gate trips.
        std::fs::write(json_path, summary.to_string() + "\n")?;
        println!("wrote bench summary to {json_path}");
    }

    anyhow::ensure!(max_err < 1e-3, "numeric mismatch");
    println!("sparse full-decoder serving matches the dense-masked reference: OK");
    anyhow::ensure!(
        par.tokens_per_s() >= dense_tps * gate,
        "bench gate: sparse full-decoder throughput {:.0} tokens/s fell below {gate:.2}x the \
         dense baseline ({dense_tps:.0} tokens/s)",
        par.tokens_per_s()
    );
    println!(
        "bench gate: sparse full-decoder >= {gate:.2}x dense: OK ({:.0} vs {dense_tps:.0} tok/s)",
        par.tokens_per_s()
    );
    // The decode gate rides the same PERMLLM_BENCH_GATE ratio: KV-cached
    // full-decoder decode must not regress below dense decode.
    anyhow::ensure!(
        fd_dec >= dn_dec * gate,
        "bench gate: sparse full-decoder decode {fd_dec:.0} tokens/s fell below {gate:.2}x \
         the dense decode baseline ({dn_dec:.0} tokens/s)"
    );
    println!(
        "bench gate: sparse full-decoder decode >= {gate:.2}x dense decode: OK \
         ({fd_dec:.0} vs {dn_dec:.0} tok/s)"
    );
    anyhow::ensure!(
        kernel_speedup >= kernel_gate,
        "kernel gate: vectorized/scalar ratio {kernel_speedup:.2}x fell below \
         PERMLLM_KERNEL_GATE {kernel_gate:.2}x"
    );
    println!("kernel gate: vectorized >= {kernel_gate:.2}x scalar: OK ({kernel_speedup:.2}x)");
    anyhow::ensure!(
        fwd_allocs == 0,
        "alloc gate: {fwd_allocs} heap allocations across {alloc_steps} steady-state decode \
         steps (expected 0)"
    );
    println!("alloc gate: zero heap allocations across {alloc_steps} steady-state steps: OK");
    Ok(())
}
