//! Sparse serving hot path: prune a model, compress **every** linear to
//! the Sparse-Tensor-Core layout once, and serve batched requests through
//! the `serve` subsystem — micro-batched, routed through the
//! `ExecBackend` trait, and pipelined across decoder layers.
//!
//! Reports per-layer and end-to-end tokens/s for a single-threaded
//! baseline and for the parallel + pipelined configuration, then verifies
//! the sparse outputs against the host dense-masked forward (and the two
//! configurations against each other — the tiled kernel is bit-exact at
//! any thread count).
//!
//! ```bash
//! cargo run --release --example sparse_inference
//! PERMLLM_BENCH_FAST=1 cargo run --release --example sparse_inference  # CI-sized
//! ```

use permllm::bench::{fast_mode, trained_or_synth};
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::lcp::LcpCfg;
use permllm::pruning::Metric;
use permllm::runtime::{ExecBackend, NativeCfg, NativeEngine};
use permllm::serve::{BatcherCfg, Request, ServeCfg, ServeReport, Server, SparseModel};
use permllm::tensor::Mat;
use permllm::util::pool::default_threads;
use permllm::util::rng::Pcg32;

fn print_report(label: &str, report: &ServeReport) {
    println!(
        "[{label}] {} micro-batches, {} tokens in {:.4}s -> {:.0} tokens/s",
        report.n_batches,
        report.total_tokens,
        report.total_seconds,
        report.tokens_per_s()
    );
    for s in &report.stage_stats {
        println!(
            "[{label}]   layer {:>2}: {:>10.0} tokens/s (busy {:.4}s)",
            s.layer,
            s.tokens_per_s(),
            s.seconds
        );
    }
}

fn main() -> anyhow::Result<()> {
    permllm::util::logging::init();

    // Prune + compress once.  Fast mode (CI) uses the small model and a
    // lighter workload; the full run uses tiny-m.
    let (model_name, n_requests, rows) =
        if fast_mode() { ("tiny-s", 12usize, 32usize) } else { ("tiny-m", 32, 128) };
    let (ps, prov) = trained_or_synth(model_name);
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: if fast_mode() { 8 } else { 20 }, lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    let pruned = prune_model(&ps, &calib, PruneMethod::PermLlm(Metric::Wanda), &cfg);
    let sm = SparseModel::from_pruned(&pruned)?;
    println!(
        "{model_name} ({prov}): {} linears 2:4-compressed, {} MLP stages, storage {:.3}x dense",
        ps.cfg().prunable_linears().len(),
        sm.n_stages(),
        sm.storage_bytes() as f64 / sm.dense_bytes() as f64
    );

    // The request workload (identical for every configuration).
    let width = sm.width();
    let make_requests = || {
        let mut rng = Pcg32::seeded(5);
        (0..n_requests)
            .map(|id| Request { id: id as u64, x: Mat::randn(rows, width, 1.0, &mut rng) })
            .collect::<Vec<Request>>()
    };
    let requests = make_requests();
    let n_stages = sm.n_stages();
    let server = Server::new(
        sm,
        ServeCfg { batcher: BatcherCfg { max_tokens: rows * 4, max_requests: 8 } },
    );
    println!(
        "workload: {n_requests} requests x {rows} tokens, micro-batch budget {} tokens",
        rows * 4
    );

    // Baseline: one backend, one worker thread, no pipelining.
    let mut engine1 = NativeEngine::new(NativeCfg { threads: 1, ..NativeCfg::default() });
    let seq = server.run_sequential(make_requests(), &mut engine1)?;
    print_report("threads=1 sequential", &seq);

    // Parallel + pipelined: one backend per decoder layer.  Stages run
    // concurrently, so the visible cores are divided across them rather
    // than oversubscribed with n_stages x cores workers.
    let cores = default_threads();
    let threads = (cores / n_stages).max(1);
    let engines: Vec<Box<dyn ExecBackend + Send>> = (0..n_stages)
        .map(|_| {
            Box::new(NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() }))
                as Box<dyn ExecBackend + Send>
        })
        .collect();
    let par = server.run_pipelined(make_requests(), engines)?;
    print_report(&format!("threads/stage={threads} pipelined"), &par);
    println!(
        "speedup: {:.2}x end-to-end ({cores} core(s) across {n_stages} pipelined stages)",
        par.tokens_per_s() / seq.tokens_per_s().max(1e-12)
    );

    // Determinism: the output-row-tiled kernel is bit-exact at any thread
    // count, so both configurations must agree exactly.
    for ((id_s, y_s), (_, y_p)) in seq.outputs.iter().zip(&par.outputs) {
        anyhow::ensure!(y_s.data() == y_p.data(), "request {id_s}: configurations diverged");
    }
    println!("threads=1 and threads={threads} outputs are bit-identical: OK");

    // Parity: sparse serving vs the host dense-masked forward.
    let mut max_err = 0.0f32;
    for ((_, got), req) in par.outputs.iter().zip(&requests) {
        let want = server.model().dense_forward(&req.x);
        for (a, b) in got.data().iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!("max |sparse - dense-masked| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "numeric mismatch");
    println!("sparse serving matches the dense-masked reference: OK");
    Ok(())
}
