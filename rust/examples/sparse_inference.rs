//! Sparse inference hot path: the `sparse_fwd` artifact (channel permute
//! + compressed 2:4 SpMM) serving batched layer requests through the
//! `ExecBackend` trait.
//!
//! Prunes one layer with PermLLM, compresses it to the
//! Sparse-Tensor-Core layout, then serves batches of activations —
//! verifying numerics against the host dense path and reporting
//! latency/throughput, serving-paper style.  Uses the native engine by
//! default; with `--features pjrt` and built artifacts it serves the same
//! requests from the AOT Pallas kernels instead.
//!
//! ```bash
//! cargo run --release --example sparse_inference
//! ```

use permllm::bench::trained_or_synth;
use permllm::coordinator::{prune_model, PipelineCfg, PruneMethod};
use permllm::data::{Corpus, CorpusKind};
use permllm::lcp::LcpCfg;
use permllm::model::{LinearKind, LinearRef};
use permllm::pruning::Metric;
use permllm::runtime::{ExecBackend, NativeCfg, NativeEngine, TensorValue};
use permllm::sparsity::Compressed;
use permllm::tensor::Mat;
use permllm::util::pool::default_threads;
use permllm::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    permllm::util::logging::init();

    // Prune one layer with PermLLM.
    let (ps, prov) = trained_or_synth("tiny-m");
    let calib = Corpus::build(CorpusKind::C4Like, 2024);
    let cfg = PipelineCfg {
        lcp: LcpCfg { steps: 20, lr: 0.05, ..Default::default() },
        ..Default::default()
    };
    let pruned = prune_model(&ps, &calib, PruneMethod::PermLlm(Metric::Wanda), &cfg);
    let lin = LinearRef { layer: 0, kind: LinearKind::WGate };
    let res = &pruned.layers[&lin];
    let (c_out, c_in) = res.weight.shape();
    println!("layer {} ({prov}): [{c_out} x {c_in}], 2:4-compressed", lin.param_name());

    // Compress to the Sparse-Tensor-Core layout.
    let comp = Compressed::compress(&res.weight, &res.mask);
    let name = format!("sparse_fwd_{c_out}x{c_in}");
    #[cfg_attr(not(feature = "pjrt"), allow(unused_mut))]
    let mut rows = 128usize;

    // Backend selection: native always works; PJRT serves the same name
    // from the AOT Pallas kernels when artifacts are present.
    let mut engine: Box<dyn ExecBackend> =
        Box::new(NativeEngine::new(NativeCfg { threads: default_threads(), ..NativeCfg::default() }));
    #[cfg(feature = "pjrt")]
    {
        let dir = std::path::Path::new("artifacts/tiny-m");
        if dir.join("manifest.json").exists() {
            match permllm::runtime::Engine::load_lazy(dir) {
                Ok(e) => {
                    if let Some(spec) = e.manifest().artifact(&name) {
                        if let Some(x) = spec.inputs.iter().find(|i| i.name == "x") {
                            rows = x.shape[0];
                        }
                        engine = Box::new(e);
                    } else {
                        eprintln!("artifacts lack {name}; using the native backend");
                    }
                }
                Err(err) => eprintln!("pjrt engine unavailable ({err:#}); using native"),
            }
        }
    }
    println!("serving {name} via the '{}' backend, {rows} tokens/request", engine.backend_name());

    // Static layer tensors, converted once.
    let k = comp.k();
    let vals = TensorValue::f32(vec![c_out, k], comp.vals().to_vec())?;
    let idx = TensorValue::i32(vec![c_out, k], comp.idx().iter().map(|&v| v as i32).collect())?;
    let src = TensorValue::i32(vec![c_in], res.src_of.iter().map(|&v| v as i32).collect())?;

    // Serve batches.
    let mut rng = Pcg32::seeded(5);
    let n_requests = 32;
    let mut total_s = 0.0f64;
    let mut max_err = 0.0f32;
    for _ in 0..n_requests {
        let x = Mat::randn(rows, c_in, 1.0, &mut rng);
        let inputs = [vals.clone(), idx.clone(), TensorValue::from_mat(&x), src.clone()];
        let t0 = std::time::Instant::now();
        let outs = engine.run(&name, &inputs)?;
        total_s += t0.elapsed().as_secs_f64();
        // Host reference: permute activations, dense matmul on the masked weight.
        let want = x.permute_cols(&res.src_of).matmul_bt(&res.weight);
        for (a, b) in outs[0].as_f32()?.iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
    }
    let per_req_ms = total_s / n_requests as f64 * 1e3;
    let tok_per_s = (rows * n_requests) as f64 / total_s;
    println!(
        "{n_requests} requests x {rows} tokens: {per_req_ms:.2} ms/request, {tok_per_s:.0} tokens/s"
    );
    println!("max |backend - host| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "numeric mismatch");
    println!("sparse_fwd backend matches the host sparse path: OK");
    Ok(())
}
