//! Shared support for the paper-table bench binaries.

use std::path::Path;

use crate::model::{synth_trained_params, ModelConfig, ParamStore};

/// Get weights for a named model size, preferring (in order):
/// 1. `models/<name>.bin` — genuinely pretrained via the train_step
///    artifact (`make models`);
/// 2. synthetic trained-statistics weights (DESIGN.md §5 substitution).
///
/// Returns the store and a provenance tag printed in bench headers.
pub fn trained_or_synth(name: &str) -> (ParamStore, &'static str) {
    let path = format!("models/{name}.bin");
    if Path::new(&path).exists() {
        if let Ok(ps) = ParamStore::load(Path::new(&path)) {
            return (ps, "pretrained");
        }
    }
    let cfg = ModelConfig::by_name(name).unwrap_or_else(|| panic!("unknown model {name}"));
    (synth_trained_params(&cfg, 42), "synthetic")
}

/// Fast-mode scaling for bench workloads (`PERMLLM_BENCH_FAST=1`).
///
/// Off-values are honoured: `PERMLLM_BENCH_FAST=0` (or `false`/`off`/
/// `no`/empty) disables fast mode instead of silently enabling it the
/// way a bare `is_ok()` check used to.
pub fn fast_mode() -> bool {
    fast_mode_value(std::env::var("PERMLLM_BENCH_FAST").ok().as_deref())
}

/// Pure interpretation of the `PERMLLM_BENCH_FAST` value (testable
/// without mutating the process environment, which would race with
/// parallel tests).
fn fast_mode_value(v: Option<&str>) -> bool {
    match v {
        None => false,
        Some(raw) => {
            let t = raw.trim();
            !(t.is_empty()
                || t == "0"
                || t.eq_ignore_ascii_case("false")
                || t.eq_ignore_ascii_case("off")
                || t.eq_ignore_ascii_case("no"))
        }
    }
}

/// Scale an iteration/step count down in fast mode.
pub fn scaled(n: usize) -> usize {
    if fast_mode() {
        (n / 4).max(1)
    } else {
        n
    }
}

#[cfg(test)]
mod tests {
    use super::fast_mode_value;

    #[test]
    fn unset_and_off_values_disable_fast_mode() {
        let off = [None, Some(""), Some("0"), Some("false"), Some("FALSE"), Some("off"), Some("No")];
        for v in off.into_iter().chain([Some(" 0 ")]) {
            assert!(!fast_mode_value(v), "{v:?} should disable fast mode");
        }
    }

    #[test]
    fn on_values_enable_fast_mode() {
        for v in ["1", "true", "yes", "on", "anything-else"] {
            assert!(fast_mode_value(Some(v)), "{v:?} should enable fast mode");
        }
    }
}
