//! Shared support for the paper-table bench binaries.

use std::path::Path;

use crate::model::{synth_trained_params, ModelConfig, ParamStore};

/// Get weights for a named model size, preferring (in order):
/// 1. `models/<name>.bin` — genuinely pretrained via the train_step
///    artifact (`make models`);
/// 2. synthetic trained-statistics weights (DESIGN.md §5 substitution).
///
/// Returns the store and a provenance tag printed in bench headers.
pub fn trained_or_synth(name: &str) -> (ParamStore, &'static str) {
    let path = format!("models/{name}.bin");
    if Path::new(&path).exists() {
        if let Ok(ps) = ParamStore::load(Path::new(&path)) {
            return (ps, "pretrained");
        }
    }
    let cfg = ModelConfig::by_name(name).unwrap_or_else(|| panic!("unknown model {name}"));
    (synth_trained_params(&cfg, 42), "synthetic")
}

/// Fast-mode scaling for bench workloads (`PERMLLM_BENCH_FAST=1`).
pub fn fast_mode() -> bool {
    std::env::var("PERMLLM_BENCH_FAST").is_ok()
}

/// Scale an iteration/step count down in fast mode.
pub fn scaled(n: usize) -> usize {
    if fast_mode() {
        (n / 4).max(1)
    } else {
        n
    }
}
