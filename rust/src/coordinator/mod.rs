//! The pruning pipeline coordinator (the "PermLLM framework" of §4-§5).
//!
//! Orchestrates, for a model + calibration corpus + method:
//!
//! 1. capture per-linear calibration activations (host forward);
//! 2. prune every linear layer (fanned out over the worker pool) with the
//!    chosen method — one-shot metric, SparseGPT, heuristic CP, or
//!    learnable channel permutation;
//! 3. rebuild the model with pruned weights.
//!
//! On permutation handling: like the paper's runtime, each linear keeps
//! its own `src_of` and activations are permuted on the fly before the
//! sparse GEMM (the paper's custom CP kernel; Table 3 measures its cost —
//! see `benches/table3_runtime.rs`).  For *evaluation* we fold the
//! permutation back into the weight (`W' P^T`), which is numerically
//! identical and keeps the host forward untouched; Eq. 12's
//! fold-into-previous-layer optimization applies to `w_down` (whose input
//! producers `w_gate`/`w_up` can absorb the row permutation exactly) and
//! is exercised in `propagation::fold_down_proj`.

mod pipeline;
mod propagation;

// Pretraining executes the AOT `train_step` artifact, which only the PJRT
// engine can serve; the module is feature-gated with it.
#[cfg(feature = "pjrt")]
mod pretrain;

pub use pipeline::{prune_model, LcpExecutor, PipelineCfg, PruneMethod, PrunedModel};
pub use propagation::fold_down_proj;

#[cfg(feature = "pjrt")]
pub use pretrain::pretrain;
