//! The pruning pipeline coordinator (the "PermLLM framework" of §4-§5).
//!
//! Orchestrates, for a model + calibration corpus + recipe:
//!
//! 1. capture per-linear calibration activations (host forward);
//! 2. prune every linear layer (fanned out over the worker pool) with
//!    the composed [`crate::recipe::PruneRecipe`] — any score metric ×
//!    permutation strategy × weight-update policy, covering one-shot
//!    metrics, SparseGPT, heuristic CP, and the learnable channel
//!    permutation (plus combinations of them);
//! 3. rebuild the model with pruned weights.
//!
//! On permutation handling: like the paper's runtime, each linear keeps
//! its own `src_of` and activations are permuted on the fly before the
//! sparse GEMM (the paper's custom CP kernel; Table 3 measures its cost —
//! see `benches/table3_runtime.rs`).  For *evaluation* we fold the
//! permutation back into the weight (`W' P^T`), which is numerically
//! identical and keeps the host forward untouched; Eq. 12's
//! fold-into-previous-layer optimization applies to `w_down` (whose input
//! producers `w_gate`/`w_up` can absorb the row permutation exactly) and
//! is exercised in `propagation::fold_down_proj`.

mod pipeline;
mod propagation;

// Pretraining executes the AOT `train_step` artifact, which only the PJRT
// engine can serve; the module is feature-gated with it.
#[cfg(feature = "pjrt")]
mod pretrain;

#[allow(deprecated)]
pub use pipeline::{prune_model, PruneMethod};
pub use pipeline::{
    calibrate, prune_with_recipe, prune_with_recipe_calibrated, PipelineCfg, PrunedModel,
};
pub use propagation::fold_down_proj;

// The executor selector moved into the recipe layer with the rest of
// the composable-method machinery; re-exported here so `coordinator::
// LcpExecutor` keeps resolving for existing callers.
pub use crate::recipe::LcpExecutor;

#[cfg(feature = "pjrt")]
pub use pretrain::pretrain;
