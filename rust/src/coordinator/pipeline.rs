//! The end-to-end pruning pipeline.

use std::collections::HashMap;

use crate::cp::ria_cp;
use crate::data::{sample_batch, Corpus};
use crate::lcp::{train_lcp, HostBackend, LayerData, LcpCfg, LcpResult};
use crate::model::{forward_captured, LinearRef, ParamStore};
use crate::pruning::{importance, prune_oneshot, prune_permuted, sparsegpt, Metric, PruneResult, SparseGptCfg};
use crate::runtime::{ExecLcpBackend, NativeCfg, NativeEngine};
use crate::sparsity::NmConfig;
use crate::tensor::Mat;
use crate::util::pool::parallel_map;
use crate::util::rng::Pcg32;

/// Pruning method selector (one per row of Tables 1/2/8).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PruneMethod {
    /// No pruning (the "Dense" row).
    Dense,
    /// SparseGPT with OBS weight update.
    SparseGpt,
    /// One-shot metric, no permutation (Wanda / RIA rows).
    OneShot(Metric),
    /// One-shot metric + RIA's heuristic channel permutation (the "+CP" rows).
    OneShotCp(Metric),
    /// PermLLM: one-shot metric + learnable channel permutation.
    PermLlm(Metric),
}

impl PruneMethod {
    pub fn name(&self) -> String {
        match self {
            PruneMethod::Dense => "Dense".into(),
            PruneMethod::SparseGpt => "SparseGPT".into(),
            PruneMethod::OneShot(m) => cap(m.name()),
            PruneMethod::OneShotCp(m) => format!("{}+CP", cap(m.name())),
            PruneMethod::PermLlm(m) => format!("PermLLM_{}", cap(m.name())),
        }
    }
}

fn cap(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

/// How the PermLLM methods execute the LCP trainer's per-step kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LcpExecutor {
    /// Call [`HostBackend`] directly (no artifact indirection).
    Host,
    /// Route through the [`crate::runtime::ExecBackend`] trait served by
    /// [`NativeEngine`] — the same math behind the artifact interface the
    /// PJRT engine implements.  Numerically identical to `Host` (pinned
    /// by `host_and_native_executors_prune_identically`); pays a small
    /// per-step tensor copy at the trait boundary, an order below the
    /// matmul cost, in exchange for exercising the artifact plumbing on
    /// every default run.  Use `Host` (`--backend host`) to shave that
    /// off when benchmarking raw LCP throughput.
    Native,
}

impl LcpExecutor {
    /// Parse a `--backend` CLI value.
    pub fn parse(s: &str) -> Option<LcpExecutor> {
        match s {
            "host" => Some(LcpExecutor::Host),
            "native" => Some(LcpExecutor::Native),
            _ => None,
        }
    }
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineCfg {
    pub nm: NmConfig,
    /// Calibration: number of sequences and their length.
    pub calib_seqs: usize,
    pub calib_len: usize,
    pub calib_seed: u64,
    /// Max calibration rows fed to per-layer pruning (subsampled).
    pub calib_rows: usize,
    /// LCP hyperparameters (PermLLM methods only).
    pub lcp: LcpCfg,
    /// Apply LCP only to decoder layers >= this index (Table 7 "partial
    /// PermLLM"); earlier layers fall back to heuristic CP.
    pub lcp_from_layer: usize,
    /// Worker threads for the per-layer fan-out.
    pub threads: usize,
    /// LCP kernel executor (default: the trait-based native engine).
    pub executor: LcpExecutor,
}

impl Default for PipelineCfg {
    fn default() -> Self {
        PipelineCfg {
            nm: NmConfig::PAT_2_4,
            calib_seqs: 8,
            calib_len: 64,
            calib_seed: 1234,
            calib_rows: 128,
            lcp: LcpCfg::default(),
            lcp_from_layer: 0,
            threads: crate::util::pool::default_threads(),
            executor: LcpExecutor::Native,
        }
    }
}

/// A pruned model plus per-layer bookkeeping.
pub struct PrunedModel {
    /// Model with pruned (permutation-folded) weights — drop-in for eval.
    pub params: ParamStore,
    /// Per-linear prune results (permuted storage order + src_of).
    pub layers: HashMap<LinearRef, PruneResult>,
    /// Per-linear output cosine error on the calibration set.
    pub layer_errors: HashMap<LinearRef, f32>,
    /// Wall-clock of the pruning pass.
    pub elapsed_s: f64,
}

/// Run the pipeline: prune `ps` with `method` using calibration text from
/// `corpus`.
pub fn prune_model(
    ps: &ParamStore,
    corpus: &Corpus,
    method: PruneMethod,
    cfg: &PipelineCfg,
) -> PrunedModel {
    let t0 = std::time::Instant::now();
    if method == PruneMethod::Dense {
        return PrunedModel {
            params: ps.clone(),
            layers: HashMap::new(),
            layer_errors: HashMap::new(),
            elapsed_s: t0.elapsed().as_secs_f64(),
        };
    }

    // 1. Calibration capture.
    let mut rng = Pcg32::new(cfg.calib_seed, 7);
    let batch = sample_batch(corpus, &mut rng, cfg.calib_seqs, cfg.calib_len);
    let (_, cap) = forward_captured(ps, &batch);

    // 2. Per-layer pruning, fanned out over the pool.
    let linears = ps.cfg().prunable_linears();
    let results: Vec<(LinearRef, PruneResult, f32)> = parallel_map(linears.len(), cfg.threads, |i| {
        let lin = linears[i];
        let w = ps.get(&lin.param_name()).clone();
        let x_full = cap.stacked(lin).expect("calibration missing");
        let x = subsample_rows(&x_full, cfg.calib_rows, cfg.calib_seed ^ i as u64);
        let y = x.matmul_bt(&w);
        let res = prune_layer(&w, &x, lin, method, cfg);
        let err = res.cosine_error(&x, &y);
        (lin, res, err)
    });

    // 3. Rebuild the model with permutation-folded weights.
    let mut pruned = ps.clone();
    let mut layers = HashMap::new();
    let mut layer_errors = HashMap::new();
    for (lin, res, err) in results {
        pruned.set(&lin.param_name(), res.weight_original_order());
        layer_errors.insert(lin, err);
        layers.insert(lin, res);
    }
    PrunedModel { params: pruned, layers, layer_errors, elapsed_s: t0.elapsed().as_secs_f64() }
}

fn prune_layer(
    w: &Mat,
    x: &Mat,
    lin: LinearRef,
    method: PruneMethod,
    cfg: &PipelineCfg,
) -> PruneResult {
    match method {
        PruneMethod::Dense => unreachable!("handled above"),
        PruneMethod::SparseGpt => sparsegpt(w, x, cfg.nm, SparseGptCfg::default()),
        PruneMethod::OneShot(metric) => prune_oneshot(metric, w, x, cfg.nm),
        PruneMethod::OneShotCp(metric) => {
            let s = importance(metric, w, x);
            let perm = ria_cp(&s, cfg.nm);
            prune_permuted(metric, w, x, cfg.nm, &perm)
        }
        PruneMethod::PermLlm(metric) => {
            let s = importance(metric, w, x);
            if lin.layer < cfg.lcp_from_layer {
                // Partial PermLLM (Table 7): heuristic CP on early layers.
                let perm = ria_cp(&s, cfg.nm);
                return prune_permuted(metric, w, x, cfg.nm, &perm);
            }
            // Seed LCP from the heuristic CP solution: learn a block-wise
            // *refinement* of the globally-allocated permutation.  Blocks
            // can only express within-block reorderings, so composing with
            // the global heuristic gives LCP the cross-block moves for
            // free; keep-best over {identity, CP, CP∘refinement} on the
            // calibration cosine objective guarantees PermLLM never
            // regresses below either baseline (paper's Table 1 ordering).
            let perm_cp = ria_cp(&s, cfg.nm);
            let w_cp = w.permute_cols(&perm_cp);
            let s_cp = s.permute_cols(&perm_cp);
            let x_cp = x.permute_cols(&perm_cp);
            let data = LayerData::new(w_cp, s_cp, x_cp);

            let mut lcp_cfg = cfg.lcp;
            lcp_cfg.nm = cfg.nm;
            // Clamp block to the layer width (largest valid divisor).
            lcp_cfg.block = lcp_cfg.block.min(w.cols());
            if w.cols() % lcp_cfg.block != 0 {
                let mut b = lcp_cfg.block;
                while w.cols() % b != 0 || b % cfg.nm.m != 0 {
                    b -= cfg.nm.m;
                }
                lcp_cfg.block = b.max(cfg.nm.m);
            }
            let res = run_lcp(&data, w.cols(), lcp_cfg, cfg);
            // Compose: global heuristic then block refinement.
            let src_total: Vec<usize> = res.src_of.iter().map(|&j| perm_cp[j]).collect();
            let refined = prune_permuted(metric, w, x, cfg.nm, &src_total);
            // Guard against the Fig. 1 failure mode (CP worse than nothing):
            // fall back to plain one-shot if it has lower calibration error.
            let plain = prune_oneshot(metric, w, x, cfg.nm);
            let y = x.matmul_bt(w);
            if plain.cosine_error(x, &y) < refined.cosine_error(x, &y) {
                plain
            } else {
                refined
            }
        }
    }
}

/// Train LCP for one layer through the configured executor.
///
/// The `Native` path goes through the artifact-name interface
/// ([`ExecLcpBackend`] over [`NativeEngine`]) — the same plumbing the
/// PJRT engine serves — with internal fan-out disabled (`threads: 1`)
/// because this function already runs inside the per-layer worker pool.
fn run_lcp(data: &LayerData, c_in: usize, lcp_cfg: LcpCfg, cfg: &PipelineCfg) -> LcpResult {
    match cfg.executor {
        LcpExecutor::Host => {
            let mut backend = HostBackend::new(data, cfg.nm, lcp_cfg.sinkhorn_iters);
            train_lcp(&mut backend, c_in, lcp_cfg)
        }
        LcpExecutor::Native => {
            let mut engine = NativeEngine::new(NativeCfg {
                nm: cfg.nm,
                sinkhorn_iters: lcp_cfg.sinkhorn_iters,
                threads: 1,
                model: None,
            });
            let mut backend = ExecLcpBackend::new(&mut engine, data, lcp_cfg.block)
                .expect("native LCP backend");
            train_lcp(&mut backend, c_in, lcp_cfg)
        }
    }
}

/// Deterministically subsample `n` rows (all rows if fewer).
fn subsample_rows(x: &Mat, n: usize, seed: u64) -> Mat {
    if x.rows() <= n {
        return x.clone();
    }
    let mut rng = Pcg32::new(seed, 3);
    let mut idx: Vec<usize> = (0..x.rows()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(n);
    idx.sort_unstable();
    let mut out = Mat::zeros(n, x.cols());
    for (r, &i) in idx.iter().enumerate() {
        out.row_mut(r).copy_from_slice(x.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusKind;
    use crate::eval::eval_perplexity;
    use crate::model::{synth_trained_params, ModelConfig};

    fn setup() -> (ParamStore, Corpus, PipelineCfg) {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 3);
        let corpus = Corpus::build(CorpusKind::C4Like, 5);
        let pc = PipelineCfg {
            calib_seqs: 2,
            calib_len: 32,
            calib_rows: 48,
            lcp: LcpCfg { block: 16, steps: 12, lr: 0.1, ..Default::default() },
            ..Default::default()
        };
        (ps, corpus, pc)
    }

    #[test]
    fn dense_is_identity() {
        let (ps, corpus, pc) = setup();
        let pruned = prune_model(&ps, &corpus, PruneMethod::Dense, &pc);
        assert_eq!(pruned.params.get("layers.0.wq").data(), ps.get("layers.0.wq").data());
    }

    #[test]
    fn oneshot_prunes_every_linear() {
        let (ps, corpus, pc) = setup();
        let pruned = prune_model(&ps, &corpus, PruneMethod::OneShot(Metric::Wanda), &pc);
        for lin in ps.cfg().prunable_linears() {
            let res = &pruned.layers[&lin];
            assert!(res.mask.verify(), "{lin:?}");
            // folded weight differs from dense
            assert_ne!(pruned.params.get(&lin.param_name()).data(), ps.get(&lin.param_name()).data());
        }
        // embedding/head untouched (paper skips them)
        assert_eq!(pruned.params.get("tok_embed").data(), ps.get("tok_embed").data());
        assert_eq!(pruned.params.get("lm_head").data(), ps.get("lm_head").data());
    }

    #[test]
    fn folded_weight_is_numerically_equivalent_to_runtime_permute() {
        let (ps, corpus, pc) = setup();
        let pruned = prune_model(&ps, &corpus, PruneMethod::OneShotCp(Metric::Wanda), &pc);
        let lin = ps.cfg().prunable_linears()[0];
        let res = &pruned.layers[&lin];
        let mut rng = Pcg32::seeded(9);
        let x = Mat::randn(4, res.weight.cols(), 1.0, &mut rng);
        // Runtime path: permute activations then sparse weight.
        let y_runtime = x.permute_cols(&res.src_of).matmul_bt(&res.weight);
        // Eval path: folded weight in original order.
        let y_folded = x.matmul_bt(pruned.params.get(&lin.param_name()));
        crate::util::testkit::assert_close(y_runtime.data(), y_folded.data(), 1e-5).unwrap();
    }

    #[test]
    fn method_ordering_on_perplexity() {
        // The paper's headline ordering: dense < pruned, and CP should not
        // hurt vs plain one-shot on the calibration-matched corpus.
        let (ps, corpus, pc) = setup();
        let dense_ppl = eval_perplexity(&ps, &corpus, 77, 2, 32);
        let wanda = prune_model(&ps, &corpus, PruneMethod::OneShot(Metric::Wanda), &pc);
        let ppl_wanda = eval_perplexity(&wanda.params, &corpus, 77, 2, 32);
        assert!(ppl_wanda > dense_ppl * 0.99, "pruning should not beat dense: {ppl_wanda} vs {dense_ppl}");
    }

    #[test]
    fn permllm_layer_errors_not_worse_than_plain() {
        let (ps, corpus, pc) = setup();
        let plain = prune_model(&ps, &corpus, PruneMethod::OneShot(Metric::Wanda), &pc);
        let perm = prune_model(&ps, &corpus, PruneMethod::PermLlm(Metric::Wanda), &pc);
        let mut better = 0;
        let mut total = 0;
        for lin in ps.cfg().prunable_linears() {
            let e_plain = plain.layer_errors[&lin];
            let e_perm = perm.layer_errors[&lin];
            if e_perm <= e_plain + 1e-6 {
                better += 1;
            }
            total += 1;
        }
        // LCP keeps the best-seen permutation starting from identity, so it
        // can only tie or beat plain pruning on its own objective.
        assert!(better * 10 >= total * 9, "only {better}/{total} layers kept or improved");
    }

    #[test]
    fn host_and_native_executors_prune_identically() {
        // The native executor routes every LCP step through the
        // ExecBackend artifact interface; the math is the host's, so the
        // two trajectories (and the pruned weights) must match exactly.
        let (ps, corpus, mut pc) = setup();
        pc.executor = LcpExecutor::Host;
        let host = prune_model(&ps, &corpus, PruneMethod::PermLlm(Metric::Wanda), &pc);
        pc.executor = LcpExecutor::Native;
        let native = prune_model(&ps, &corpus, PruneMethod::PermLlm(Metric::Wanda), &pc);
        for lin in ps.cfg().prunable_linears() {
            assert_eq!(
                host.layers[&lin].src_of, native.layers[&lin].src_of,
                "{lin:?} diverged"
            );
            assert_eq!(
                host.params.get(&lin.param_name()).data(),
                native.params.get(&lin.param_name()).data(),
                "{lin:?} weights diverged"
            );
        }
    }

    #[test]
    fn partial_permllm_uses_cp_below_threshold() {
        let (ps, corpus, mut pc) = setup();
        pc.lcp_from_layer = 1;
        let pruned = prune_model(&ps, &corpus, PruneMethod::PermLlm(Metric::Wanda), &pc);
        // Still prunes everything.
        assert_eq!(pruned.layers.len(), ps.cfg().prunable_linears().len());
    }

    #[test]
    fn subsample_preserves_rows() {
        let mut rng = Pcg32::seeded(1);
        let x = Mat::randn(10, 4, 1.0, &mut rng);
        let s = subsample_rows(&x, 4, 7);
        assert_eq!(s.shape(), (4, 4));
        // Every sampled row exists in the original.
        for r in 0..4 {
            let found = (0..10).any(|orig| x.row(orig) == s.row(r));
            assert!(found);
        }
    }
}
