//! The end-to-end pruning pipeline, driven by [`PruneRecipe`]s.

use std::collections::HashMap;

use crate::data::{sample_batch, Corpus};
use crate::lcp::LcpCfg;
use crate::model::{forward_captured, Captured, LinearRef, ParamStore};
use crate::pruning::{Metric, PruneResult};
use crate::recipe::{LcpExecutor, PermContext, PruneRecipe};
use crate::sparsity::NmConfig;
use crate::tensor::Mat;
use crate::util::pool::parallel_map;
use crate::util::rng::Pcg32;

/// Legacy pruning-method selector (one per row of Tables 1/2/8).
///
/// The closed enum is superseded by the composable [`PruneRecipe`]
/// (metric × permutation × weight-update as open traits); it survives
/// one release as a constructor that lowers into recipes
/// ([`PruneMethod::to_recipe`]) with bit-identical results and labels,
/// so existing callers keep working while they migrate.
#[deprecated(
    since = "0.2.0",
    note = "compose a recipe::PruneRecipe instead (PruneMethod::to_recipe lowers this variant)"
)]
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PruneMethod {
    /// No pruning (the "Dense" row).
    Dense,
    /// SparseGPT with OBS weight update.
    SparseGpt,
    /// One-shot metric, no permutation (Wanda / RIA rows).
    OneShot(Metric),
    /// One-shot metric + RIA's heuristic channel permutation (the "+CP" rows).
    OneShotCp(Metric),
    /// PermLLM: one-shot metric + learnable channel permutation.
    PermLlm(Metric),
}

#[allow(deprecated)]
impl PruneMethod {
    /// Lower this legacy variant into the recipe that reproduces it bit
    /// for bit (`legacy_methods_lower_to_bit_identical_recipes` pins
    /// the equivalence).
    pub fn to_recipe(self, nm: NmConfig) -> PruneRecipe {
        use crate::recipe::{HeuristicCpPerm, LearnedPerm};
        match self {
            PruneMethod::Dense => PruneRecipe::dense(nm),
            PruneMethod::SparseGpt => PruneRecipe::sparsegpt(nm),
            PruneMethod::OneShot(m) => PruneRecipe::oneshot(m, nm),
            PruneMethod::OneShotCp(m) => {
                PruneRecipe::builder(nm).metric_kind(m).perm(HeuristicCpPerm).build()
            }
            PruneMethod::PermLlm(m) => {
                PruneRecipe::builder(nm).metric_kind(m).perm(LearnedPerm::default()).build()
            }
        }
    }

    /// The row label (identical to the lowered recipe's
    /// [`PruneRecipe::name`] by construction).
    pub fn name(&self) -> String {
        self.to_recipe(NmConfig::PAT_2_4).name()
    }
}

/// Pipeline configuration.
///
/// `lcp`, `lcp_from_layer`, and `executor` are the *defaults* a
/// [`crate::recipe::LearnedPerm`] strategy inherits when its own fields
/// are unset, so a sweep can vary them per recipe or per pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineCfg {
    /// Sparsity pattern used when lowering legacy methods; recipes
    /// carry their own `nm`, which takes precedence.
    pub nm: NmConfig,
    /// Calibration: number of sequences and their length.
    pub calib_seqs: usize,
    pub calib_len: usize,
    pub calib_seed: u64,
    /// Max calibration rows fed to per-layer pruning (subsampled).
    pub calib_rows: usize,
    /// Default LCP hyperparameters (learned-permutation strategies).
    pub lcp: LcpCfg,
    /// Default partial-PermLLM threshold: apply LCP only to decoder
    /// layers >= this index (Table 7); earlier layers fall back to
    /// heuristic CP.
    pub lcp_from_layer: usize,
    /// Worker threads for the per-layer fan-out.
    pub threads: usize,
    /// Default LCP kernel executor (the trait-based native engine).
    pub executor: LcpExecutor,
}

impl Default for PipelineCfg {
    fn default() -> Self {
        PipelineCfg {
            nm: NmConfig::PAT_2_4,
            calib_seqs: 8,
            calib_len: 64,
            calib_seed: 1234,
            calib_rows: 128,
            lcp: LcpCfg::default(),
            lcp_from_layer: 0,
            threads: crate::util::pool::default_threads(),
            executor: LcpExecutor::Native,
        }
    }
}

/// A pruned model plus per-layer bookkeeping.
pub struct PrunedModel {
    /// Model with pruned (permutation-folded) weights — drop-in for eval.
    pub params: ParamStore,
    /// Per-linear prune results (permuted storage order + src_of).
    pub layers: HashMap<LinearRef, PruneResult>,
    /// Per-linear output cosine error on the calibration set.
    pub layer_errors: HashMap<LinearRef, f32>,
    /// Wall-clock of the pruning pass.
    pub elapsed_s: f64,
    /// The recipe that produced these weights — carried through to
    /// serving so bench artifacts can record it.
    pub recipe: PruneRecipe,
}

impl PrunedModel {
    /// Mean per-linear output cosine error on the calibration set
    /// (0 for the unpruned Dense recipe) — the "MeanLayerErr" column
    /// every bench and the CLI report.
    pub fn mean_layer_error(&self) -> f32 {
        if self.layer_errors.is_empty() {
            0.0
        } else {
            self.layer_errors.values().sum::<f32>() / self.layer_errors.len() as f32
        }
    }
}

/// Capture the calibration activations once: sample `calib_seqs`
/// sequences from `corpus` and run the host forward with per-linear
/// input capture.  The capture depends only on the model and the
/// `calib_*` fields, so it can be shared across many recipe runs
/// ([`prune_with_recipe_calibrated`] — the `--sweep` path captures once
/// and fans the recipes out).
pub fn calibrate(ps: &ParamStore, corpus: &Corpus, cfg: &PipelineCfg) -> Captured {
    let mut rng = Pcg32::new(cfg.calib_seed, 7);
    let batch = sample_batch(corpus, &mut rng, cfg.calib_seqs, cfg.calib_len);
    forward_captured(ps, &batch).1
}

/// Run the pipeline: prune `ps` with `recipe` using calibration text
/// from `corpus`.  This is the one driver — the legacy [`prune_model`]
/// lowers its enum into a recipe and calls it.
pub fn prune_with_recipe(
    ps: &ParamStore,
    corpus: &Corpus,
    recipe: &PruneRecipe,
    cfg: &PipelineCfg,
) -> PrunedModel {
    let t0 = std::time::Instant::now();
    if recipe.is_dense() {
        return dense_result(ps, recipe, t0);
    }
    let cap = calibrate(ps, corpus, cfg);
    finish_prune(ps, &cap, recipe, cfg, t0)
}

/// [`prune_with_recipe`] with a pre-captured calibration set, so a
/// recipe sweep pays for [`calibrate`] once instead of once per recipe.
pub fn prune_with_recipe_calibrated(
    ps: &ParamStore,
    cap: &Captured,
    recipe: &PruneRecipe,
    cfg: &PipelineCfg,
) -> PrunedModel {
    let t0 = std::time::Instant::now();
    if recipe.is_dense() {
        return dense_result(ps, recipe, t0);
    }
    finish_prune(ps, cap, recipe, cfg, t0)
}

fn dense_result(ps: &ParamStore, recipe: &PruneRecipe, t0: std::time::Instant) -> PrunedModel {
    PrunedModel {
        params: ps.clone(),
        layers: HashMap::new(),
        layer_errors: HashMap::new(),
        elapsed_s: t0.elapsed().as_secs_f64(),
        recipe: recipe.clone(),
    }
}

fn finish_prune(
    ps: &ParamStore,
    cap: &Captured,
    recipe: &PruneRecipe,
    cfg: &PipelineCfg,
    t0: std::time::Instant,
) -> PrunedModel {
    // Per-layer pruning, fanned out over the pool.
    let linears = ps.cfg().prunable_linears();
    let results: Vec<(LinearRef, PruneResult, f32)> = parallel_map(linears.len(), cfg.threads, |i| {
        let lin = linears[i];
        let w = ps.get(&lin.param_name()).clone();
        let x_full = cap.stacked(lin).expect("calibration missing");
        let x = subsample_rows(&x_full, cfg.calib_rows, cfg.calib_seed ^ i as u64);
        let y = x.matmul_bt(&w);
        let res = prune_layer(recipe, &w, &x, lin, cfg);
        let err = res.cosine_error(&x, &y);
        (lin, res, err)
    });

    // Rebuild the model with permutation-folded weights.
    let mut pruned = ps.clone();
    let mut layers = HashMap::new();
    let mut layer_errors = HashMap::new();
    for (lin, res, err) in results {
        pruned.set(&lin.param_name(), res.weight_original_order());
        layer_errors.insert(lin, err);
        layers.insert(lin, res);
    }
    PrunedModel {
        params: pruned,
        layers,
        layer_errors,
        elapsed_s: t0.elapsed().as_secs_f64(),
        recipe: recipe.clone(),
    }
}

/// Legacy entry point: lower `method` into a recipe and run the driver.
#[deprecated(
    since = "0.2.0",
    note = "lower the method into a recipe::PruneRecipe and call prune_with_recipe"
)]
#[allow(deprecated)]
pub fn prune_model(
    ps: &ParamStore,
    corpus: &Corpus,
    method: PruneMethod,
    cfg: &PipelineCfg,
) -> PrunedModel {
    prune_with_recipe(ps, corpus, &method.to_recipe(cfg.nm), cfg)
}

/// One layer through the recipe: score, search the permutation, prune
/// under the update policy, and (for strategies that request it) keep
/// the identity-permutation result when it beats the searched one on
/// the calibration cosine objective — the guard against the Fig. 1
/// failure mode, where a permutation looks better on the handcrafted
/// score but is worse than no permutation at all.
fn prune_layer(
    recipe: &PruneRecipe,
    w: &Mat,
    x: &Mat,
    lin: LinearRef,
    cfg: &PipelineCfg,
) -> PruneResult {
    // Score only when a component reads it — the SparseGPT row
    // (identity perm + OBS update) never consumed importance in the
    // legacy pipeline either.
    let s = if recipe.perm.needs_scores() || recipe.update.needs_scores() {
        recipe.metric.score(w, x)
    } else {
        Mat::zeros(0, 0)
    };
    let ctx = PermContext {
        layer: lin.layer,
        nm: recipe.nm,
        lcp: cfg.lcp,
        lcp_from_layer: cfg.lcp_from_layer,
        executor: cfg.executor,
    };
    let src_of = recipe.perm.permutation(&s, w, x, &ctx);
    let res = recipe.update.prune(&s, w, x, recipe.nm, &src_of);
    if recipe.perm.guard_identity(&ctx) {
        let id: Vec<usize> = (0..w.cols()).collect();
        let plain = recipe.update.prune(&s, w, x, recipe.nm, &id);
        let y = x.matmul_bt(w);
        if plain.cosine_error(x, &y) < res.cosine_error(x, &y) {
            return plain;
        }
    }
    res
}

/// Deterministically subsample `n` rows (all rows if fewer).
fn subsample_rows(x: &Mat, n: usize, seed: u64) -> Mat {
    if x.rows() <= n {
        return x.clone();
    }
    let mut rng = Pcg32::new(seed, 3);
    let mut idx: Vec<usize> = (0..x.rows()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(n);
    idx.sort_unstable();
    let mut out = Mat::zeros(n, x.cols());
    for (r, &i) in idx.iter().enumerate() {
        out.row_mut(r).copy_from_slice(x.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusKind;
    use crate::eval::eval_perplexity;
    use crate::model::{synth_trained_params, ModelConfig};
    use crate::recipe::{rows, HeuristicCpPerm, LearnedPerm, ObsSparseGpt};

    fn setup() -> (ParamStore, Corpus, PipelineCfg) {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 3);
        let corpus = Corpus::build(CorpusKind::C4Like, 5);
        let pc = PipelineCfg {
            calib_seqs: 2,
            calib_len: 32,
            calib_rows: 48,
            lcp: LcpCfg { block: 16, steps: 12, lr: 0.1, ..Default::default() },
            ..Default::default()
        };
        (ps, corpus, pc)
    }

    fn wanda(nm: NmConfig) -> PruneRecipe {
        PruneRecipe::oneshot(Metric::Wanda, nm)
    }

    fn permllm_wanda(nm: NmConfig) -> PruneRecipe {
        PruneRecipe::builder(nm).metric_kind(Metric::Wanda).perm(LearnedPerm::default()).build()
    }

    #[test]
    fn dense_is_identity() {
        let (ps, corpus, pc) = setup();
        let pruned = prune_with_recipe(&ps, &corpus, &PruneRecipe::dense(pc.nm), &pc);
        assert_eq!(pruned.params.get("layers.0.wq").data(), ps.get("layers.0.wq").data());
        assert_eq!(pruned.recipe.name(), "Dense");
    }

    #[test]
    fn oneshot_prunes_every_linear() {
        let (ps, corpus, pc) = setup();
        let pruned = prune_with_recipe(&ps, &corpus, &wanda(pc.nm), &pc);
        for lin in ps.cfg().prunable_linears() {
            let res = &pruned.layers[&lin];
            assert!(res.mask.verify(), "{lin:?}");
            // folded weight differs from dense
            assert_ne!(pruned.params.get(&lin.param_name()).data(), ps.get(&lin.param_name()).data());
        }
        // embedding/head untouched (paper skips them)
        assert_eq!(pruned.params.get("tok_embed").data(), ps.get("tok_embed").data());
        assert_eq!(pruned.params.get("lm_head").data(), ps.get("lm_head").data());
    }

    #[test]
    fn folded_weight_is_numerically_equivalent_to_runtime_permute() {
        let (ps, corpus, pc) = setup();
        let recipe =
            PruneRecipe::builder(pc.nm).metric_kind(Metric::Wanda).perm(HeuristicCpPerm).build();
        let pruned = prune_with_recipe(&ps, &corpus, &recipe, &pc);
        let lin = ps.cfg().prunable_linears()[0];
        let res = &pruned.layers[&lin];
        let mut rng = Pcg32::seeded(9);
        let x = Mat::randn(4, res.weight.cols(), 1.0, &mut rng);
        // Runtime path: permute activations then sparse weight.
        let y_runtime = x.permute_cols(&res.src_of).matmul_bt(&res.weight);
        // Eval path: folded weight in original order.
        let y_folded = x.matmul_bt(pruned.params.get(&lin.param_name()));
        crate::util::testkit::assert_close(y_runtime.data(), y_folded.data(), 1e-5).unwrap();
    }

    #[test]
    fn method_ordering_on_perplexity() {
        // The paper's headline ordering: dense < pruned, and CP should not
        // hurt vs plain one-shot on the calibration-matched corpus.
        let (ps, corpus, pc) = setup();
        let dense_ppl = eval_perplexity(&ps, &corpus, 77, 2, 32);
        let pruned = prune_with_recipe(&ps, &corpus, &wanda(pc.nm), &pc);
        let ppl_wanda = eval_perplexity(&pruned.params, &corpus, 77, 2, 32);
        assert!(ppl_wanda > dense_ppl * 0.99, "pruning should not beat dense: {ppl_wanda} vs {dense_ppl}");
    }

    #[test]
    fn permllm_layer_errors_not_worse_than_plain() {
        let (ps, corpus, pc) = setup();
        let plain = prune_with_recipe(&ps, &corpus, &wanda(pc.nm), &pc);
        let perm = prune_with_recipe(&ps, &corpus, &permllm_wanda(pc.nm), &pc);
        let mut better = 0;
        let mut total = 0;
        for lin in ps.cfg().prunable_linears() {
            let e_plain = plain.layer_errors[&lin];
            let e_perm = perm.layer_errors[&lin];
            if e_perm <= e_plain + 1e-6 {
                better += 1;
            }
            total += 1;
        }
        // LCP keeps the best-seen permutation starting from identity, so it
        // can only tie or beat plain pruning on its own objective.
        assert!(better * 10 >= total * 9, "only {better}/{total} layers kept or improved");
    }

    #[test]
    fn host_and_native_executors_prune_identically() {
        // The native executor routes every LCP step through the
        // ExecBackend artifact interface; the math is the host's, so the
        // two trajectories (and the pruned weights) must match exactly.
        let (ps, corpus, mut pc) = setup();
        pc.executor = LcpExecutor::Host;
        let host = prune_with_recipe(&ps, &corpus, &permllm_wanda(pc.nm), &pc);
        pc.executor = LcpExecutor::Native;
        let native = prune_with_recipe(&ps, &corpus, &permllm_wanda(pc.nm), &pc);
        for lin in ps.cfg().prunable_linears() {
            assert_eq!(
                host.layers[&lin].src_of, native.layers[&lin].src_of,
                "{lin:?} diverged"
            );
            assert_eq!(
                host.params.get(&lin.param_name()).data(),
                native.params.get(&lin.param_name()).data(),
                "{lin:?} weights diverged"
            );
        }
    }

    #[test]
    fn partial_permllm_uses_cp_below_threshold() {
        let (ps, corpus, mut pc) = setup();
        pc.lcp_from_layer = 1;
        let via_cfg = prune_with_recipe(&ps, &corpus, &permllm_wanda(pc.nm), &pc);
        // Still prunes everything.
        assert_eq!(via_cfg.layers.len(), ps.cfg().prunable_linears().len());
        // The per-strategy override expresses the same run without
        // touching the pipeline config — Table 7 through the recipe path.
        pc.lcp_from_layer = 0;
        let recipe = PruneRecipe::builder(pc.nm)
            .metric_kind(Metric::Wanda)
            .perm(LearnedPerm { from_layer: Some(1), ..Default::default() })
            .build();
        let via_recipe = prune_with_recipe(&ps, &corpus, &recipe, &pc);
        for lin in ps.cfg().prunable_linears() {
            assert_eq!(
                via_cfg.layers[&lin].src_of, via_recipe.layers[&lin].src_of,
                "{lin:?}: per-strategy from_layer must match the pipeline default route"
            );
        }
    }

    #[test]
    fn learned_recipe_layer_matches_handwritten_legacy_permllm_path() {
        // The composite PermLLM path is pinned against a HAND-INLINED
        // copy of the deleted legacy `prune_layer` branch (CP warm
        // start -> LCP refinement -> compose -> keep-best guard vs
        // plain one-shot), so the recipe rewiring cannot silently
        // change its semantics.  The simpler variants are pinned at
        // the primitive level in recipe::tests.
        use crate::cp::ria_cp;
        use crate::lcp::{train_lcp, HostBackend, LayerData};
        use crate::model::LinearKind;
        use crate::pruning::{importance, prune_oneshot, prune_permuted};
        use crate::recipe::LearnedPerm;

        let mut rng = Pcg32::seeded(40);
        let w = Mat::randn(8, 16, 1.0, &mut rng);
        let x = Mat::randn(12, 16, 1.0, &mut rng);
        let nm = NmConfig::PAT_2_4;
        let lcp = LcpCfg { block: 8, steps: 10, lr: 0.1, nm, ..Default::default() };

        // --- the legacy branch, verbatim (Host executor) -------------
        let s = importance(Metric::Wanda, &w, &x);
        let perm_cp = ria_cp(&s, nm);
        let data = LayerData::new(
            w.permute_cols(&perm_cp),
            s.permute_cols(&perm_cp),
            x.permute_cols(&perm_cp),
        );
        let mut backend = HostBackend::new(&data, nm, lcp.sinkhorn_iters);
        let res = train_lcp(&mut backend, w.cols(), lcp);
        let src_total: Vec<usize> = res.src_of.iter().map(|&j| perm_cp[j]).collect();
        let refined = prune_permuted(Metric::Wanda, &w, &x, nm, &src_total);
        let plain = prune_oneshot(Metric::Wanda, &w, &x, nm);
        let y = x.matmul_bt(&w);
        let want = if plain.cosine_error(&x, &y) < refined.cosine_error(&x, &y) {
            plain
        } else {
            refined
        };

        // --- the recipe driver on the same layer ---------------------
        let recipe = PruneRecipe::builder(nm)
            .metric_kind(Metric::Wanda)
            .perm(LearnedPerm::default())
            .build();
        let cfg = PipelineCfg { nm, lcp, executor: LcpExecutor::Host, ..Default::default() };
        let lin = LinearRef { layer: 0, kind: LinearKind::Wq };
        let got = prune_layer(&recipe, &w, &x, lin, &cfg);
        assert_eq!(got.src_of, want.src_of);
        assert_eq!(got.weight.data(), want.weight.data());
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_methods_lower_to_bit_identical_recipes() {
        // Satellite acceptance: every legacy enum variant lowers into a
        // recipe with the historical Table-1 row label, and prune_model
        // (the deprecated shim) returns exactly what the recipe driver
        // returns.  The equivalence with the *pre-refactor* per-variant
        // branches is pinned separately: at the primitive level in
        // recipe::tests (oneshot / permuted / sparsegpt bit-parity) and
        // for the composite PermLLM path in
        // learned_recipe_layer_matches_handwritten_legacy_permllm_path.
        let (ps, corpus, pc) = setup();
        let variants: [(PruneMethod, &str); 9] = [
            (PruneMethod::Dense, "Dense"),
            (PruneMethod::SparseGpt, "SparseGPT"),
            (PruneMethod::OneShot(Metric::Magnitude), "Magnitude"),
            (PruneMethod::OneShot(Metric::Wanda), "Wanda"),
            (PruneMethod::OneShot(Metric::Ria), "Ria"),
            (PruneMethod::OneShotCp(Metric::Wanda), "Wanda+CP"),
            (PruneMethod::OneShotCp(Metric::Ria), "Ria+CP"),
            (PruneMethod::PermLlm(Metric::Wanda), "PermLLM_Wanda"),
            (PruneMethod::PermLlm(Metric::Ria), "PermLLM_Ria"),
        ];
        for (method, label) in variants {
            let recipe = method.to_recipe(pc.nm);
            assert_eq!(recipe.name(), label, "{method:?}");
            assert_eq!(method.name(), label, "{method:?}");
            let legacy = prune_model(&ps, &corpus, method, &pc);
            let lowered = prune_with_recipe(&ps, &corpus, &recipe, &pc);
            assert_eq!(legacy.layers.len(), lowered.layers.len(), "{label}");
            for (lin, res) in &legacy.layers {
                let low = &lowered.layers[lin];
                assert_eq!(res.src_of, low.src_of, "{label}/{lin:?} src_of");
                assert_eq!(res.weight.data(), low.weight.data(), "{label}/{lin:?} weight");
            }
            for lin in ps.cfg().prunable_linears() {
                let name = lin.param_name();
                assert_eq!(
                    legacy.params.get(&name).data(),
                    lowered.params.get(&name).data(),
                    "{label}/{name} folded params"
                );
            }
        }
    }

    #[test]
    fn table1_rows_are_recipes_with_pinned_labels() {
        let labels: Vec<String> = rows::table1(NmConfig::PAT_2_4).iter().map(|r| r.name()).collect();
        assert_eq!(
            labels,
            [
                "Dense",
                "SparseGPT",
                "Wanda",
                "Wanda+CP",
                "PermLLM_Wanda",
                "Ria",
                "Ria+CP",
                "PermLLM_Ria",
                "PermLLM_Wanda+SparseGPT",
            ]
        );
    }

    #[test]
    fn novel_learned_plus_obs_recipe_runs_end_to_end() {
        // Acceptance: the previously-inexpressible ROSE-style row —
        // learned permutation + SparseGPT OBS update — through the full
        // pipeline driver.
        let (ps, corpus, pc) = setup();
        let recipe = PruneRecipe::builder(pc.nm)
            .metric_kind(Metric::Wanda)
            .perm(LearnedPerm::default())
            .update(ObsSparseGpt::default())
            .build();
        assert_eq!(recipe.name(), "PermLLM_Wanda+SparseGPT");
        assert!(recipe.updates_weights());
        let pruned = prune_with_recipe(&ps, &corpus, &recipe, &pc);
        assert_eq!(pruned.layers.len(), ps.cfg().prunable_linears().len());
        for lin in ps.cfg().prunable_linears() {
            assert!(pruned.layers[&lin].mask.verify(), "{lin:?}");
        }
        assert_eq!(pruned.recipe.name(), "PermLLM_Wanda+SparseGPT");
    }

    #[test]
    fn subsample_preserves_rows() {
        let mut rng = Pcg32::seeded(1);
        let x = Mat::randn(10, 4, 1.0, &mut rng);
        let s = subsample_rows(&x, 4, 7);
        assert_eq!(s.shape(), (4, 4));
        // Every sampled row exists in the original.
        for r in 0..4 {
            let found = (0..10).any(|orig| x.row(orig) == s.row(r));
            assert!(found);
        }
    }
}
