//! Pretraining driver: run the AOT `train_step` artifact from Rust.
//!
//! The end-to-end validation path (DESIGN.md §4): Python lowered one AdamW
//! step of the tiny LM to HLO once; this loop feeds it token batches from
//! a synthetic corpus and threads the parameter/optimizer-state literals
//! from step to step — no Python anywhere at runtime.

use std::path::Path;

use anyhow::Result;

use crate::data::{batch_to_i32, sample_batch, Corpus, CorpusKind};
use crate::model::ParamStore;
use crate::runtime::{literal_to_vec, tokens_to_literal, vec_to_literal, Engine};
// NOTE: this whole module is `#[cfg(feature = "pjrt")]` (see coordinator/mod.rs).
use crate::tensor::Mat;
use crate::util::rng::Pcg32;

/// Pretrain for `steps` batches; logs loss every `log_every` steps,
/// saves the final parameters to `out` (PLLM binary), and returns the
/// loss curve.
pub fn pretrain(
    artifacts: &Path,
    corpus_kind: CorpusKind,
    steps: usize,
    log_every: usize,
    out: &Path,
) -> Result<Vec<f32>> {
    let mut engine = Engine::load_lazy(artifacts)?;
    engine.ensure_compiled("train_step")?;
    let manifest = engine.manifest().clone_config();
    let (cfg, batch_size, param_order) = manifest;

    // Initial parameter literals (deterministic Rust init; the artifact is
    // a pure function so init provenance does not matter).
    let mut rng = Pcg32::seeded(7);
    let init = ParamStore::init(&cfg, &mut rng);
    let mut params: Vec<xla::Literal> = Vec::with_capacity(param_order.len());
    let mut m_state: Vec<xla::Literal> = Vec::with_capacity(param_order.len());
    let mut v_state: Vec<xla::Literal> = Vec::with_capacity(param_order.len());
    for (name, shape) in &param_order {
        let mat = init.get(name);
        params.push(vec_to_literal(mat.data(), shape)?);
        let zeros = vec![0.0f32; mat.data().len()];
        m_state.push(vec_to_literal(&zeros, shape)?);
        v_state.push(vec_to_literal(&zeros, shape)?);
    }
    let mut step_lit = vec_to_literal(&[0.0], &[1])?;

    // Train on a mixture: the requested corpus plus the other two, so the
    // model has genuine signal on every eval corpus (the paper's LLMs are
    // general-purpose; a single-corpus tiny model is near-random off-domain
    // and pruning deltas would drown in eval noise).
    let corpora = [
        Corpus::build(corpus_kind, 2024),
        Corpus::build(CorpusKind::WikitextLike, 2024),
        Corpus::build(CorpusKind::PileLike, 2024),
        Corpus::build(CorpusKind::C4Like, 2024),
    ];
    let mut data_rng = Pcg32::seeded(99);
    let n = param_order.len();
    let mut losses = Vec::with_capacity(steps);

    for step in 0..steps {
        let corpus = &corpora[step % corpora.len()];
        let batch = sample_batch(corpus, &mut data_rng, batch_size, cfg.seq_len);
        let tokens = tokens_to_literal(&batch_to_i32(&batch), batch_size, cfg.seq_len)?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * n + 2);
        inputs.extend(params.drain(..));
        inputs.extend(m_state.drain(..));
        inputs.extend(v_state.drain(..));
        inputs.push(step_lit);
        inputs.push(tokens);

        let mut outs = engine.run_literals("train_step", &inputs)?;
        // Outputs: params' (n) + m' (n) + v' (n) + step' + loss.
        let loss = literal_to_vec(&outs[3 * n + 1])?[0];
        losses.push(loss);
        step_lit = outs.remove(3 * n);
        let mut it = outs.into_iter();
        params = it.by_ref().take(n).collect();
        m_state = it.by_ref().take(n).collect();
        v_state = it.by_ref().take(n).collect();

        if log_every > 0 && (step % log_every == 0 || step + 1 == steps) {
            log::info!("train step {step}: loss {loss:.4}");
        }
        anyhow::ensure!(loss.is_finite(), "training diverged at step {step}");
    }

    // Convert final params to a ParamStore and save.
    let mut store = init;
    for ((name, shape), lit) in param_order.iter().zip(&params) {
        let data = literal_to_vec(lit)?;
        let mat = if shape.len() == 1 {
            Mat::from_vec(1, shape[0], data)
        } else {
            Mat::from_vec(shape[0], shape[1], data)
        };
        store.set(name, mat);
    }
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    store.save(out)?;
    Ok(losses)
}

/// Small helper on Manifest to pull what pretraining needs without holding
/// a borrow across the training loop.
trait CloneConfig {
    fn clone_config(&self) -> (crate::model::ModelConfig, usize, Vec<(String, Vec<usize>)>);
}

impl CloneConfig for crate::runtime::Manifest {
    fn clone_config(&self) -> (crate::model::ModelConfig, usize, Vec<(String, Vec<usize>)>) {
        (self.config.clone(), self.batch, self.param_order.clone())
    }
}

#[allow(unused)]
fn _assert_send() {}

#[cfg(test)]
mod tests {
    // Exercised end-to-end (with real artifacts) by examples/end_to_end.rs
    // and tests/artifact_integration.rs; no artifact-free unit surface here.
}
