//! Eq. 12: fold a layer's input permutation into the preceding layer.
//!
//! For `w_down` (input = silu(w_gate x) * (w_up x), elementwise in the ffn
//! dimension) the permutation of `w_down`'s input channels is exactly a
//! row permutation of BOTH `w_gate` and `w_up`:
//!
//!   silu(g x) * (u x)  permuted by P  ==  silu((P^T g) x) * ((P^T u) x)
//!
//! Row permutations preserve the N:M pattern of an already-pruned weight
//! (the paper's point after Eq. 12), so this removes the runtime permute
//! for the down projection entirely.

use crate::tensor::Mat;

/// Apply Eq. 12: given `w_down`'s `src_of`, return the row-permuted
/// `(w_gate', w_up')` such that running the MLP *without* an activation
/// permute before `w_down_permuted` is numerically identical.
///
/// `src_of[j] = i` means `w_down`'s stored column `j` reads original ffn
/// channel `i`; so stored channel `j` must be produced by original row `i`
/// of gate/up: `w'_{j,:} = w_{src_of[j],:}` — a row gather.
pub fn fold_down_proj(w_gate: &Mat, w_up: &Mat, src_of: &[usize]) -> (Mat, Mat) {
    (w_gate.permute_rows(src_of), w_up.permute_rows(src_of))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::{NmConfig, NmMask};
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    fn silu(v: f32) -> f32 {
        v / (1.0 + (-v).exp())
    }

    fn mlp(w_gate: &Mat, w_up: &Mat, w_down: &Mat, x: &Mat) -> Mat {
        let g = x.matmul_bt(w_gate);
        let u = x.matmul_bt(w_up);
        let mut h = Mat::zeros(g.rows(), g.cols());
        for r in 0..g.rows() {
            for c in 0..g.cols() {
                h[(r, c)] = silu(g[(r, c)]) * u[(r, c)];
            }
        }
        h.matmul_bt(w_down)
    }

    #[test]
    fn prop_folding_is_numerically_exact() {
        testkit::check_n("eq12-exact", 16, |rng| {
            let (d, f, t) = (8, 16, 5);
            let w_gate = Mat::randn(f, d, 1.0, rng);
            let w_up = Mat::randn(f, d, 1.0, rng);
            let w_down = Mat::randn(d, f, 1.0, rng);
            let x = Mat::randn(t, d, 1.0, rng);
            let src_of = rng.permutation(f);

            // Runtime-permute path: w_down stored permuted, activations
            // permuted before the down matmul.
            let w_down_perm = w_down.permute_cols(&src_of);
            let g = x.matmul_bt(&w_gate);
            let u = x.matmul_bt(&w_up);
            let mut h = Mat::zeros(t, f);
            for r in 0..t {
                for c in 0..f {
                    h[(r, c)] = silu(g[(r, c)]) * u[(r, c)];
                }
            }
            let y_runtime = h.permute_cols(&src_of).matmul_bt(&w_down_perm);

            // Eq. 12 path: fold into gate/up rows, no activation permute.
            let (g2, u2) = fold_down_proj(&w_gate, &w_up, &src_of);
            let y_folded = mlp(&g2, &u2, &w_down_perm, &x);

            testkit::assert_close(y_runtime.data(), y_folded.data(), 1e-4)
        });
    }

    #[test]
    fn row_permutation_preserves_nm_sparsity() {
        let mut rng = Pcg32::seeded(2);
        let w = Mat::randn(16, 16, 1.0, &mut rng);
        let mask = NmMask::from_scores(&w.map(f32::abs), NmConfig::PAT_2_4);
        let sparse = mask.apply(&w);
        let perm = rng.permutation(16);
        let permuted = sparse.permute_rows(&perm);
        // Every row still satisfies 2:4 (row permutation does not touch
        // the grouping along C_in).
        let as_mask = permuted.map(|v| if v != 0.0 { 1.0 } else { 0.0 });
        // rows may have fewer nonzeros if original had zeros, so verify
        // group-wise <= keep.
        for r in 0..16 {
            for g in 0..4 {
                let ones: f32 = (0..4).map(|k| as_mask[(r, g * 4 + k)]).sum();
                assert!(ones <= 2.0);
            }
        }
    }
}
