//! Greedy and exhaustive channel permutation (Pool & Yu style).

use super::permutation_score;
use crate::sparsity::NmConfig;
use crate::tensor::Mat;

/// Greedy hill-climbing on the retained-importance score: repeatedly try
/// swapping channel pairs across groups, accept improving swaps, stop at a
/// local optimum or `max_sweeps`.  This is the "exhaustive search + greedy
/// incremental refinement" of Pool & Yu [46] scaled to small layers.
pub fn greedy_cp(s: &Mat, cfg: NmConfig, max_sweeps: usize) -> Vec<usize> {
    let c_in = s.cols();
    let mut perm: Vec<usize> = (0..c_in).collect();
    let mut best = permutation_score(s, &perm, cfg);
    for _ in 0..max_sweeps {
        let mut improved = false;
        for a in 0..c_in {
            for b in a + 1..c_in {
                // Swapping within a group never changes the mask's score.
                if a / cfg.m == b / cfg.m {
                    continue;
                }
                perm.swap(a, b);
                let sc = permutation_score(s, &perm, cfg);
                if sc > best + 1e-9 {
                    best = sc;
                    improved = true;
                } else {
                    perm.swap(a, b);
                }
            }
        }
        if !improved {
            break;
        }
    }
    perm
}

/// Enumerate every distinct channel-to-group partition for tiny `c_in`
/// (Fig. 1 ground truth).  Returns each partition as a `src_of` vector.
/// The count is `c_in! / ((m!)^g * g!)` — caller is responsible for
/// keeping `c_in` small (<= 12).
pub fn exhaustive_partitions(c_in: usize, m: usize) -> Vec<Vec<usize>> {
    assert_eq!(c_in % m, 0);
    assert!(c_in <= 12, "exhaustive enumeration is for toy sizes");
    let mut out = Vec::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut used = vec![false; c_in];
    fn rec(
        c_in: usize,
        m: usize,
        used: &mut Vec<bool>,
        groups: &mut Vec<Vec<usize>>,
        out: &mut Vec<Vec<usize>>,
    ) {
        if groups.len() == c_in / m && groups.iter().all(|g| g.len() == m) {
            out.push(groups.iter().flatten().copied().collect());
            return;
        }
        // Extend the last unfinished group, or open a new one anchored at
        // the smallest unused channel (canonical form kills group-order and
        // within-group-order duplicates).
        if let Some(last) = groups.last_mut() {
            if last.len() < m {
                let min_in_group = *last.last().unwrap();
                let candidates: Vec<usize> =
                    (min_in_group + 1..c_in).filter(|&c| !used[c]).collect();
                for c in candidates {
                    used[c] = true;
                    groups.last_mut().unwrap().push(c);
                    rec(c_in, m, used, groups, out);
                    groups.last_mut().unwrap().pop();
                    used[c] = false;
                }
                return;
            }
        }
        // Open a new group with the smallest unused channel.
        if let Some(anchor) = (0..c_in).find(|&c| !used[c]) {
            used[anchor] = true;
            groups.push(vec![anchor]);
            rec(c_in, m, used, groups, out);
            groups.pop();
            used[anchor] = false;
        }
    }
    rec(c_in, m, &mut used, &mut groups, &mut out);
    out
}

/// Exact best permutation (by retained-importance score) over all
/// partitions; Fig. 1's "max score S" solution.
pub fn exhaustive_best(s: &Mat, cfg: NmConfig) -> (Vec<usize>, f64) {
    let mut best_perm: Vec<usize> = (0..s.cols()).collect();
    let mut best_score = f64::NEG_INFINITY;
    for perm in exhaustive_partitions(s.cols(), cfg.m) {
        let sc = permutation_score(s, &perm, cfg);
        if sc > best_score {
            best_score = sc;
            best_perm = perm;
        }
    }
    (best_perm, best_score)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    #[test]
    fn partition_count_matches_formula() {
        // 8 channels, groups of 4: 8! / (4!^2 * 2!) = 35.
        assert_eq!(exhaustive_partitions(8, 4).len(), 35);
        // 8 channels, groups of 2: 8! / (2!^4 * 4!) = 105.
        assert_eq!(exhaustive_partitions(8, 2).len(), 105);
    }

    #[test]
    fn partitions_are_valid_permutations() {
        for p in exhaustive_partitions(8, 4) {
            let mut seen = vec![false; 8];
            for &c in &p {
                assert!(!seen[c]);
                seen[c] = true;
            }
        }
    }

    #[test]
    fn prop_greedy_never_below_identity() {
        testkit::check_n("greedy-monotone", 16, |rng| {
            let cfg = crate::sparsity::NmConfig::PAT_2_4;
            let s = Mat::randn(4, 8, 1.0, rng).map(f32::abs);
            let id: Vec<usize> = (0..8).collect();
            let sc_id = permutation_score(&s, &id, cfg);
            let p = greedy_cp(&s, cfg, 4);
            let sc_g = permutation_score(&s, &p, cfg);
            if sc_g + 1e-9 < sc_id {
                return Err(format!("greedy {sc_g} < identity {sc_id}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_exhaustive_at_least_greedy() {
        testkit::check_n("exhaustive-is-max", 8, |rng| {
            let cfg = crate::sparsity::NmConfig::PAT_2_4;
            let s = Mat::randn(3, 8, 1.0, rng).map(f32::abs);
            let (_, sc_ex) = exhaustive_best(&s, cfg);
            let sc_greedy = permutation_score(&s, &greedy_cp(&s, cfg, 4), cfg);
            if sc_ex + 1e-6 < sc_greedy {
                return Err(format!("exhaustive {sc_ex} < greedy {sc_greedy}"));
            }
            Ok(())
        });
    }
}
