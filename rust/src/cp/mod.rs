//! Heuristic channel-permutation baselines (the methods PermLLM improves).
//!
//! * [`ria_cp`] — RIA's two-stage CP (paper [62] §, used for the
//!   "Wanda+CP" / "RIA+CP" rows): heuristic channel allocation that
//!   spreads important channels across groups, then linear-sum-assignment
//!   refinement maximizing retained importance.
//! * [`greedy_cp`] — Pool & Yu-style greedy/exhaustive search for small
//!   layers (Figure 1's toy enumeration).
//! * [`exhaustive_best`] — exact enumeration of channel-to-group
//!   partitions for tiny C_in; ground truth for Fig. 1 and the property
//!   tests.

mod ria_cp;
mod greedy;

pub use greedy::{exhaustive_best, exhaustive_partitions, greedy_cp};
pub use ria_cp::ria_cp;

use crate::sparsity::{NmConfig, NmMask};
use crate::tensor::Mat;

/// Sum of retained importance after permuting `s` by `src_of` and applying
/// the Eq. 7 mask — the handcrafted quality metric "Score S" of Fig. 1.
pub fn permutation_score(s: &Mat, src_of: &[usize], cfg: NmConfig) -> f64 {
    let sp = s.permute_cols(src_of);
    let mask = NmMask::from_scores(&sp, cfg);
    mask.retained_score(&sp)
}

/// Compose group assignment (list of channel ids per group, in order) into
/// a `src_of` permutation vector.
pub fn groups_to_perm(groups: &[Vec<usize>]) -> Vec<usize> {
    groups.iter().flat_map(|g| g.iter().copied()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn identity_score_matches_mask_score() {
        let mut rng = Pcg32::seeded(1);
        let s = Mat::randn(4, 16, 1.0, &mut rng).map(f32::abs);
        let id: Vec<usize> = (0..16).collect();
        let score = permutation_score(&s, &id, NmConfig::PAT_2_4);
        let mask = NmMask::from_scores(&s, NmConfig::PAT_2_4);
        assert!((score - mask.retained_score(&s)).abs() < 1e-6);
    }

    #[test]
    fn groups_to_perm_flattens() {
        let groups = vec![vec![3, 1], vec![0, 2]];
        assert_eq!(groups_to_perm(&groups), vec![3, 1, 0, 2]);
    }
}
