//! RIA's two-stage channel permutation (heuristic allocation + LSA refine).

use super::groups_to_perm;
use crate::lcp::hungarian::assign_max;
use crate::sparsity::NmConfig;
use crate::tensor::Mat;

/// RIA channel permutation: returns the `src_of` permutation maximizing
/// the sum of retained importance (the paper's handcrafted quality metric).
///
/// Stage 1 — heuristic allocation: sort channels by total importance
/// (column sums of S) descending and deal them round-robin across the
/// `G = C_in / M` groups, so heavy channels land in different groups
/// instead of competing for the same `keep` slots.
///
/// Stage 2 — LSA refinement: repeatedly pick one member slot per group,
/// build the G x G gain matrix "retained score if channel c moved to
/// group g", and solve the assignment exactly with the Hungarian
/// algorithm.  Iterate over slots until a full sweep yields no gain.
pub fn ria_cp(s: &Mat, cfg: NmConfig) -> Vec<usize> {
    let c_in = s.cols();
    assert_eq!(c_in % cfg.m, 0);
    let g = c_in / cfg.m;

    // ---- Stage 1: round-robin allocation by column importance ----------
    let mut col_imp: Vec<(f64, usize)> = (0..c_in)
        .map(|c| (s.col(c).iter().map(|&v| v as f64).sum::<f64>(), c))
        .collect();
    col_imp.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut groups: Vec<Vec<usize>> = vec![Vec::with_capacity(cfg.m); g];
    for (rank, &(_, c)) in col_imp.iter().enumerate() {
        groups[rank % g].push(c);
    }

    // ---- Stage 2: per-slot LSA refinement -------------------------------
    let mut best_score = score_groups(s, &groups, cfg);
    loop {
        let mut improved = false;
        for slot in 0..cfg.m {
            // Candidate channel from each group (its `slot`-th member).
            let cands: Vec<usize> = groups.iter().map(|gr| gr[slot]).collect();
            // gain[g][c] = group score if groups[g] swaps its slot for cands[c].
            let mut gain = Mat::zeros(g, g);
            for (gi, gr) in groups.iter().enumerate() {
                for (ci, &cand) in cands.iter().enumerate() {
                    let mut members = gr.clone();
                    members[slot] = cand;
                    gain[(gi, ci)] = group_score(s, &members, cfg) as f32;
                }
            }
            let assign = assign_max(&gain); // assign[group] = candidate idx
            let mut new_groups = groups.clone();
            for (gi, &ci) in assign.iter().enumerate() {
                new_groups[gi][slot] = cands[ci];
            }
            let new_score = score_groups(s, &new_groups, cfg);
            if new_score > best_score + 1e-9 {
                groups = new_groups;
                best_score = new_score;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }

    groups_to_perm(&groups)
}

/// Retained importance of one group's member channels (Eq. 7 per group).
fn group_score(s: &Mat, members: &[usize], cfg: NmConfig) -> f64 {
    let mut total = 0.0f64;
    let mut vals: Vec<f32> = Vec::with_capacity(members.len());
    for r in 0..s.rows() {
        vals.clear();
        let row = s.row(r);
        vals.extend(members.iter().map(|&c| row[c]));
        vals.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
        total += vals.iter().take(cfg.keep).map(|&v| v as f64).sum::<f64>();
    }
    total
}

fn score_groups(s: &Mat, groups: &[Vec<usize>], cfg: NmConfig) -> f64 {
    groups.iter().map(|gr| group_score(s, gr, cfg)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::permutation_score;
    use crate::util::testkit;

    #[test]
    fn prop_output_is_valid_permutation() {
        testkit::check("ria-cp-valid-perm", |rng| {
            let c_in = 4 * (2 + rng.below_usize(6));
            let s = Mat::randn(6, c_in, 1.0, rng).map(f32::abs);
            let p = ria_cp(&s, crate::sparsity::NmConfig::PAT_2_4);
            let mut seen = vec![false; c_in];
            for &i in &p {
                if seen[i] {
                    return Err(format!("duplicate channel {i}"));
                }
                seen[i] = true;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_never_worse_than_identity_score() {
        // The retained-importance score (RIA's own objective) must not
        // decrease relative to no permutation.
        testkit::check("ria-cp-score-monotone", |rng| {
            let cfg = crate::sparsity::NmConfig::PAT_2_4;
            let c_in = 4 * (2 + rng.below_usize(6));
            let s = Mat::randn(4, c_in, 1.0, rng).map(f32::abs);
            let id: Vec<usize> = (0..c_in).collect();
            let p = ria_cp(&s, cfg);
            let sc_id = permutation_score(&s, &id, cfg);
            let sc_cp = permutation_score(&s, &p, cfg);
            if sc_cp + 1e-6 < sc_id {
                return Err(format!("cp score {sc_cp} < identity {sc_id}"));
            }
            Ok(())
        });
    }

    #[test]
    fn separates_two_dominant_channels() {
        // Two huge channels inside one group must end up in different
        // groups so both survive 2:4 pruning... with keep=2 both survive
        // anyway; use keep=1 to force the separation.
        let cfg = crate::sparsity::NmConfig { m: 4, keep: 1 };
        let mut s = Mat::full(2, 8, 0.01);
        s[(0, 0)] = 10.0;
        s[(1, 0)] = 10.0;
        s[(0, 1)] = 9.0;
        s[(1, 1)] = 9.0;
        let p = ria_cp(&s, cfg);
        let g_of = |c: usize| p.iter().position(|&x| x == c).unwrap() / 4;
        assert_ne!(g_of(0), g_of(1), "dominant channels share a group: {p:?}");
    }
}
