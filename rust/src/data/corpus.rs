//! Markov-chain corpus generators with dataset-specific statistics.

use crate::util::rng::{zipf_cdf, Pcg32};

/// Which synthetic dataset to emulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusKind {
    /// Web-crawl-like: large effective vocabulary, Zipf s=1.05, mixed
    /// document lengths (stands in for C4).
    C4Like,
    /// Encyclopedic: narrower vocabulary, s=1.25, longer-range bigram
    /// structure (stands in for Wikitext2).
    WikitextLike,
    /// Diverse mixture: two interleaved sub-distributions with different
    /// alphabets (stands in for The Pile).
    PileLike,
}

impl CorpusKind {
    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::C4Like => "c4",
            CorpusKind::WikitextLike => "wikitext2",
            CorpusKind::PileLike => "pile",
        }
    }

    pub fn parse(s: &str) -> Option<CorpusKind> {
        match s {
            "c4" => Some(CorpusKind::C4Like),
            "wikitext2" | "wikitext" => Some(CorpusKind::WikitextLike),
            "pile" => Some(CorpusKind::PileLike),
            _ => None,
        }
    }
}

/// A first-order Markov chain over byte tokens with Zipfian stationary
/// marginals — enough structure for a tiny LM to learn non-trivial
/// statistics (loss well below uniform) while staying cheap to sample.
pub struct Corpus {
    kind: CorpusKind,
    /// Per-state cumulative transition distributions `[vocab][vocab]`.
    trans_cdf: Vec<Vec<f32>>,
    /// Unigram CDF for (re)starts.
    start_cdf: Vec<f32>,
}

const VOCAB: usize = 256;

impl Corpus {
    /// Build a deterministic corpus model for `kind`.
    pub fn build(kind: CorpusKind, seed: u64) -> Corpus {
        let mut rng = Pcg32::new(seed, kind as u64 + 10);
        let (zipf_s, peak, alphabet) = match kind {
            CorpusKind::C4Like => (1.05f32, 6.0f32, VOCAB),
            CorpusKind::WikitextLike => (1.25, 10.0, 160),
            CorpusKind::PileLike => (1.1, 8.0, VOCAB),
        };
        // Random rank assignment of tokens (so "frequent" ids differ per corpus).
        let ranks = rng.permutation(VOCAB);
        let zc = zipf_cdf(alphabet, zipf_s);
        let unigram: Vec<f32> = {
            let mut w = vec![1e-6f32; VOCAB];
            for (tok, &rank) in ranks.iter().enumerate() {
                if rank < alphabet {
                    let p = if rank == 0 { zc[0] } else { zc[rank] - zc[rank - 1] };
                    w[tok] = p.max(1e-6);
                }
            }
            w
        };
        // Transition rows: unigram reweighted by a per-state preference
        // vector (sparse "peaked" bigram structure).
        let mut trans_cdf = Vec::with_capacity(VOCAB);
        for _state in 0..VOCAB {
            let mut row = unigram.clone();
            // Boost a handful of successor tokens strongly.
            let n_peaks = 3 + rng.below_usize(5);
            for _ in 0..n_peaks {
                let t = rng.below_usize(VOCAB);
                row[t] *= peak * (0.5 + rng.uniform());
            }
            // PileLike: mix in a second "mode" for half the states.
            if kind == CorpusKind::PileLike && rng.uniform() < 0.5 {
                for t in 0..VOCAB {
                    if t % 2 == 0 {
                        row[t] *= 2.5;
                    }
                }
            }
            let total: f32 = row.iter().sum();
            let mut acc = 0.0;
            for v in row.iter_mut() {
                acc += *v / total;
                *v = acc;
            }
            trans_cdf.push(row);
        }
        let start_cdf = {
            let total: f32 = unigram.iter().sum();
            let mut acc = 0.0;
            unigram
                .iter()
                .map(|&v| {
                    acc += v / total;
                    acc
                })
                .collect()
        };
        Corpus { kind, trans_cdf, start_cdf }
    }

    pub fn kind(&self) -> CorpusKind {
        self.kind
    }

    fn draw(cdf: &[f32], rng: &mut Pcg32) -> u8 {
        let u = rng.uniform();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i as u8,
            Err(i) => i.min(cdf.len() - 1) as u8,
        }
    }

    /// One Markov transition from `state`.
    pub fn step(&self, state: u8, rng: &mut Pcg32) -> u8 {
        Self::draw(&self.trans_cdf[state as usize], rng)
    }

    /// Sample one sequence of `len` tokens.
    pub fn sample_seq(&self, rng: &mut Pcg32, len: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(len);
        let mut state = Self::draw(&self.start_cdf, rng);
        out.push(state);
        while out.len() < len {
            state = Self::draw(&self.trans_cdf[state as usize], rng);
            out.push(state);
        }
        out
    }

    /// Empirical unigram entropy (nats) over `n` sampled tokens — used by
    /// tests to verify the three corpora really have distinct statistics.
    pub fn unigram_entropy(&self, rng: &mut Pcg32, n: usize) -> f64 {
        let mut counts = vec![0usize; VOCAB];
        let seq = self.sample_seq(rng, n);
        for &t in &seq {
            counts[t as usize] += 1;
        }
        let total = seq.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = Corpus::build(CorpusKind::C4Like, 3);
        let b = Corpus::build(CorpusKind::C4Like, 3);
        let mut r1 = Pcg32::seeded(5);
        let mut r2 = Pcg32::seeded(5);
        assert_eq!(a.sample_seq(&mut r1, 64), b.sample_seq(&mut r2, 64));
    }

    #[test]
    fn corpora_have_distinct_statistics() {
        let mut rng = Pcg32::seeded(1);
        let e_c4 = Corpus::build(CorpusKind::C4Like, 7).unigram_entropy(&mut rng, 20_000);
        let e_wik = Corpus::build(CorpusKind::WikitextLike, 7).unigram_entropy(&mut rng, 20_000);
        let e_pile = Corpus::build(CorpusKind::PileLike, 7).unigram_entropy(&mut rng, 20_000);
        // Wikitext-like is narrower than c4-like.
        assert!(e_wik < e_c4, "wik {e_wik} vs c4 {e_c4}");
        // All three pairwise distinct by a margin.
        assert!((e_c4 - e_pile).abs() > 0.05 || (e_wik - e_pile).abs() > 0.05);
    }

    #[test]
    fn sequences_not_uniform_random() {
        // Bigram structure: repeated sampling from the same state must hit
        // the boosted successors often.
        let c = Corpus::build(CorpusKind::WikitextLike, 2);
        let mut rng = Pcg32::seeded(3);
        let seq = c.sample_seq(&mut rng, 50_000);
        let mut counts = vec![0usize; 256];
        for &t in &seq {
            counts[t as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let mean = seq.len() as f64 / 256.0;
        assert!(max > mean * 4.0, "no head tokens: max {max}, mean {mean}");
    }

    #[test]
    fn parse_names() {
        assert_eq!(CorpusKind::parse("c4"), Some(CorpusKind::C4Like));
        assert_eq!(CorpusKind::parse("wikitext2"), Some(CorpusKind::WikitextLike));
        assert_eq!(CorpusKind::parse("pile"), Some(CorpusKind::PileLike));
        assert_eq!(CorpusKind::parse("imagenet"), None);
    }
}
