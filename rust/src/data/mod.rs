//! Synthetic corpora and calibration sampling (DESIGN.md §5).
//!
//! Stand-ins for C4 / Wikitext2 / Pile: three generators with *distinct*
//! token statistics (different Zipf exponents, Markov orders, and
//! document structure), enough for Table 5's calibration-robustness
//! ablation and for pretraining the tiny LM. Byte-level tokens (vocab
//! 256) so no tokenizer state needs to cross the language boundary.

mod corpus;

pub use corpus::{Corpus, CorpusKind};

use crate::util::rng::Pcg32;

/// Sample a batch of fixed-length sequences from a corpus stream.
pub fn sample_batch(corpus: &Corpus, rng: &mut Pcg32, batch: usize, seq_len: usize) -> Vec<Vec<u8>> {
    (0..batch).map(|_| corpus.sample_seq(rng, seq_len)).collect()
}

/// Flatten a batch into the i32 token buffer the artifacts consume.
pub fn batch_to_i32(batch: &[Vec<u8>]) -> Vec<i32> {
    batch.iter().flat_map(|s| s.iter().map(|&b| b as i32)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let c = Corpus::build(CorpusKind::C4Like, 7);
        let mut rng = Pcg32::seeded(1);
        let b = sample_batch(&c, &mut rng, 3, 32);
        assert_eq!(b.len(), 3);
        assert!(b.iter().all(|s| s.len() == 32));
        assert_eq!(batch_to_i32(&b).len(), 96);
    }
}
