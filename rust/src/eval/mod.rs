//! Evaluation harness: perplexity + zero-shot tasks.
//!
//! * Perplexity re-exports the host forward's [`crate::model::perplexity`] over a
//!   held-out sample of a corpus (Table 1 / Table 8 metric), or — via
//!   [`eval_perplexity_exec`] — runs the same metric through any
//!   [`ExecBackend`]'s `lm_forward` artifact (native by default, PJRT
//!   with `--features pjrt`), which is what makes pruned-model evaluation
//!   backend-agnostic.
//! * The zero-shot suite ([`zeroshot_suite`]) builds five synthetic
//!   classification tasks mirroring the
//!   paper's HellaSwag / ARC-E / ARC-C / OBQA / RTE suite (Table 2): each
//!   task asks the model to rank a true corpus continuation above
//!   distractors by total log-likelihood, with task-specific difficulty
//!   knobs (context length, number and closeness of distractors).

mod zeroshot;

pub use zeroshot::{zeroshot_accuracy, zeroshot_suite, ZeroshotTask};

use anyhow::Result;

use crate::data::{batch_to_i32, sample_batch, Corpus};
use crate::model::{perplexity, ParamStore};
use crate::runtime::{ExecBackend, TensorValue};
use crate::util::rng::Pcg32;

/// Held-out perplexity on `n_seqs` sequences from `corpus`.
pub fn eval_perplexity(ps: &ParamStore, corpus: &Corpus, seed: u64, n_seqs: usize, seq_len: usize) -> f64 {
    let mut rng = Pcg32::new(seed, 999);
    let batch = sample_batch(corpus, &mut rng, n_seqs, seq_len);
    perplexity(ps, &batch)
}

/// Held-out perplexity through an execution backend's `lm_forward`
/// artifact.  Samples the same batch as [`eval_perplexity`] for the same
/// seed, so host and backend paths are directly comparable.
pub fn eval_perplexity_exec(
    engine: &mut dyn ExecBackend,
    ps: &ParamStore,
    corpus: &Corpus,
    seed: u64,
    n_seqs: usize,
    seq_len: usize,
) -> Result<f64> {
    let cfg = ps.cfg().clone();
    let mut rng = Pcg32::new(seed, 999);
    let batch = sample_batch(corpus, &mut rng, n_seqs, seq_len);
    let mut inputs = Vec::new();
    for name in cfg.param_names() {
        inputs.push(TensorValue::f32(cfg.param_shape(&name), ps.get(&name).data().to_vec())?);
    }
    inputs.push(TensorValue::i32(vec![n_seqs, seq_len], batch_to_i32(&batch))?);
    let outs = engine.run("lm_forward", &inputs)?;
    ppl_from_flat_logits(&batch, outs[0].as_f32()?, cfg.vocab)
}

/// Perplexity from flat `[B, T, V]` logits — exp of the mean next-token
/// cross-entropy, identical math to [`crate::model::lm_loss`].
pub fn ppl_from_flat_logits(batch: &[Vec<u8>], logits: &[f32], vocab: usize) -> Result<f64> {
    anyhow::ensure!(!batch.is_empty(), "empty batch");
    let t = batch[0].len();
    anyhow::ensure!(t >= 2, "sequences must have >= 2 tokens for next-token loss, got {t}");
    anyhow::ensure!(
        logits.len() == batch.len() * t * vocab,
        "logits have {} elements, expected {}",
        logits.len(),
        batch.len() * t * vocab
    );
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (bi, seq) in batch.iter().enumerate() {
        for pos in 0..t - 1 {
            let row = &logits[bi * t * vocab + pos * vocab..bi * t * vocab + (pos + 1) * vocab];
            let target = seq[pos + 1] as usize;
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = row.iter().map(|x| (x - mx).exp()).sum();
            total += -((row[target] - mx) as f64 - (z as f64).ln());
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusKind;
    use crate::model::{synth_trained_params, ModelConfig};

    #[test]
    fn eval_ppl_runs() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 1);
        let corpus = Corpus::build(CorpusKind::C4Like, 2);
        let ppl = eval_perplexity(&ps, &corpus, 3, 2, 32);
        assert!(ppl.is_finite() && ppl > 1.0);
    }

    #[test]
    fn exec_ppl_matches_host_ppl() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 1);
        let corpus = Corpus::build(CorpusKind::C4Like, 2);
        let host = eval_perplexity(&ps, &corpus, 3, 2, 32);
        let mut engine = crate::runtime::NativeEngine::with_model(cfg);
        let exec = eval_perplexity_exec(&mut engine, &ps, &corpus, 3, 2, 32).unwrap();
        assert!(
            (host - exec).abs() < 1e-9 * host.abs().max(1.0),
            "host {host} vs exec {exec}"
        );
    }
}
