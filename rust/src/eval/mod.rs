//! Evaluation harness: perplexity + zero-shot tasks.
//!
//! * Perplexity re-exports the host forward's [`model::perplexity`] over a
//!   held-out sample of a corpus (Table 1 / Table 8 metric).
//! * [`zeroshot`] builds five synthetic classification tasks mirroring the
//!   paper's HellaSwag / ARC-E / ARC-C / OBQA / RTE suite (Table 2): each
//!   task asks the model to rank a true corpus continuation above
//!   distractors by total log-likelihood, with task-specific difficulty
//!   knobs (context length, number and closeness of distractors).

mod zeroshot;

pub use zeroshot::{zeroshot_accuracy, zeroshot_suite, ZeroshotTask};

use crate::data::{sample_batch, Corpus};
use crate::model::{perplexity, ParamStore};
use crate::util::rng::Pcg32;

/// Held-out perplexity on `n_seqs` sequences from `corpus`.
pub fn eval_perplexity(ps: &ParamStore, corpus: &Corpus, seed: u64, n_seqs: usize, seq_len: usize) -> f64 {
    let mut rng = Pcg32::new(seed, 999);
    let batch = sample_batch(corpus, &mut rng, n_seqs, seq_len);
    perplexity(ps, &batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusKind;
    use crate::model::{synth_trained_params, ModelConfig};

    #[test]
    fn eval_ppl_runs() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 1);
        let corpus = Corpus::build(CorpusKind::C4Like, 2);
        let ppl = eval_perplexity(&ps, &corpus, 3, 2, 32);
        assert!(ppl.is_finite() && ppl > 1.0);
    }
}
