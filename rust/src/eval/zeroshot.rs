//! Synthetic zero-shot tasks (Table 2 analogue).
//!
//! Construction: sample a context from the task's corpus, continue it with
//! the true Markov continuation, and generate distractors by sampling
//! continuations from a *different* state (hard distractors resample from
//! a nearby state).  The model scores each candidate by total conditional
//! log-likelihood; accuracy = fraction of items where the true
//! continuation wins.  A trained model beats chance; pruning degrades
//! accuracy — the same signal the paper reads off lm-evaluation-harness.

use crate::data::{Corpus, CorpusKind};
use crate::model::ParamStore;
use crate::tensor::Mat;
use crate::util::rng::Pcg32;

/// One synthetic zero-shot task definition.
#[derive(Debug, Clone)]
pub struct ZeroshotTask {
    /// Display name (mirrors the paper's column).
    pub name: &'static str,
    pub corpus: CorpusKind,
    pub context_len: usize,
    pub cont_len: usize,
    pub n_distractors: usize,
    /// Distractors drawn from a nearby state (harder) vs random state.
    pub hard: bool,
    pub n_items: usize,
}

/// The five-task suite mirroring HellaSwag/ARC-E/ARC-C/OBQA/RTE.
pub fn zeroshot_suite() -> Vec<ZeroshotTask> {
    vec![
        ZeroshotTask { name: "HellaSwag", corpus: CorpusKind::C4Like, context_len: 24, cont_len: 8, n_distractors: 3, hard: false, n_items: 80 },
        ZeroshotTask { name: "ARC_E", corpus: CorpusKind::WikitextLike, context_len: 16, cont_len: 6, n_distractors: 3, hard: false, n_items: 80 },
        ZeroshotTask { name: "ARC_C", corpus: CorpusKind::WikitextLike, context_len: 16, cont_len: 6, n_distractors: 3, hard: true, n_items: 80 },
        ZeroshotTask { name: "OBQA", corpus: CorpusKind::PileLike, context_len: 12, cont_len: 8, n_distractors: 3, hard: true, n_items: 80 },
        ZeroshotTask { name: "RTE", corpus: CorpusKind::C4Like, context_len: 20, cont_len: 6, n_distractors: 1, hard: false, n_items: 80 },
    ]
}

/// Log-likelihood of `cont` given `ctx` under the model.
fn cont_loglik(ps: &ParamStore, ctx: &[u8], cont: &[u8]) -> f64 {
    let mut seq = ctx.to_vec();
    seq.extend_from_slice(cont);
    let logits = crate::model::lm_forward(ps, &[seq.clone()]);
    let l: &Mat = &logits[0];
    let mut total = 0.0f64;
    for (k, &tok) in cont.iter().enumerate() {
        let pos = ctx.len() + k - 1; // logits at pos predict token pos+1
        let row = l.row(pos);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - mx).exp()).sum();
        total += (row[tok as usize] - mx) as f64 - (z as f64).ln();
    }
    total
}

/// Accuracy of `ps` on one task (deterministic per seed).
pub fn zeroshot_accuracy(ps: &ParamStore, task: &ZeroshotTask, seed: u64) -> f64 {
    let corpus = Corpus::build(task.corpus, 1000 + task.corpus as u64);
    let mut rng = Pcg32::new(seed ^ 0xBEEF, 17);
    let mut correct = 0usize;
    for _ in 0..task.n_items {
        let full = corpus.sample_seq(&mut rng, task.context_len + task.cont_len);
        let (ctx, truth) = full.split_at(task.context_len);
        // Distractors: continuations sampled from a different start state.
        let mut cands: Vec<Vec<u8>> = vec![truth.to_vec()];
        for _ in 0..task.n_distractors {
            let d = if task.hard {
                // Hard: a continuation of a slightly perturbed context —
                // statistically close to the truth.
                let mut pert = ctx.to_vec();
                let at = pert.len() - 1;
                pert[at] = pert[at].wrapping_add(1 + rng.below(4) as u8);
                let seq = continue_from(&corpus, &mut rng, *pert.last().unwrap(), task.cont_len);
                seq
            } else {
                let start = rng.below(256) as u8;
                continue_from(&corpus, &mut rng, start, task.cont_len)
            };
            cands.push(d);
        }
        let scores: Vec<f64> = cands.iter().map(|c| cont_loglik(ps, ctx, c)).collect();
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if best == 0 {
            correct += 1;
        }
    }
    correct as f64 / task.n_items as f64
}

/// Walk the chain `len` steps from `state` (the state itself is context,
/// not part of the continuation).
fn continue_from(corpus: &Corpus, rng: &mut Pcg32, mut state: u8, len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        state = corpus.step(state, rng);
        out.push(state);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synth_trained_params, ModelConfig};

    #[test]
    fn suite_has_five_named_tasks() {
        let suite = zeroshot_suite();
        assert_eq!(suite.len(), 5);
        let names: Vec<_> = suite.iter().map(|t| t.name).collect();
        assert_eq!(names, vec!["HellaSwag", "ARC_E", "ARC_C", "OBQA", "RTE"]);
    }

    #[test]
    fn accuracy_in_unit_interval_and_deterministic() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 1);
        let mut task = zeroshot_suite()[4].clone(); // RTE: cheapest (1 distractor)
        task.n_items = 10;
        let a = zeroshot_accuracy(&ps, &task, 42);
        let b = zeroshot_accuracy(&ps, &task, 42);
        assert_eq!(a, b);
        assert!((0.0..=1.0).contains(&a));
    }
}
