//! AdamW optimizer over flat f32 buffers (paper §5.1 uses AdamW [33]).

/// AdamW hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamWCfg {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamWCfg {
    fn default() -> Self {
        // Paper: lr in {1e-3, 5e-3}; our tiny layers tolerate larger steps,
        // callers override per experiment.
        AdamWCfg { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// AdamW state for one parameter buffer.
#[derive(Debug, Clone)]
pub struct AdamW {
    cfg: AdamWCfg,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl AdamW {
    pub fn new(n: usize, cfg: AdamWCfg) -> AdamW {
        AdamW { cfg, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// One update step: `param -= lr * (m̂ / (√v̂ + eps) + wd * param)`.
    pub fn step(&mut self, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let c = self.cfg;
        let bc1 = 1.0 - c.beta1.powi(self.t as i32);
        let bc2 = 1.0 - c.beta2.powi(self.t as i32);
        for i in 0..param.len() {
            self.m[i] = c.beta1 * self.m[i] + (1.0 - c.beta1) * grad[i];
            self.v[i] = c.beta2 * self.v[i] + (1.0 - c.beta2) * grad[i] * grad[i];
            let m_hat = self.m[i] / bc1;
            let v_hat = self.v[i] / bc2;
            param[i] -= c.lr * (m_hat / (v_hat.sqrt() + c.eps) + c.weight_decay * param[i]);
        }
    }
}

/// Linear temperature decay from `tau0` to `tau1` over `steps` (paper:
/// 1.0 -> 0.1 over the 50 LCP iterations).
pub fn tau_schedule(step: usize, steps: usize, tau0: f32, tau1: f32) -> f32 {
    if steps <= 1 {
        return tau1;
    }
    tau0 + (tau1 - tau0) * step as f32 / (steps - 1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_minimizes_quadratic() {
        let cfg = AdamWCfg { lr: 0.1, ..Default::default() };
        let mut opt = AdamW::new(3, cfg);
        let mut x = vec![3.0f32, -2.0, 1.0];
        for _ in 0..200 {
            let g: Vec<f32> = x.iter().map(|v| 2.0 * v).collect();
            opt.step(&mut x, &g);
        }
        for v in &x {
            assert!(v.abs() < 1e-2, "{x:?}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params_with_zero_grad() {
        let cfg = AdamWCfg { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        let mut opt = AdamW::new(1, cfg);
        let mut x = vec![1.0f32];
        for _ in 0..10 {
            opt.step(&mut x, &[0.0]);
        }
        assert!(x[0] < 1.0 && x[0] > 0.0);
    }

    #[test]
    fn tau_schedule_endpoints() {
        assert_eq!(tau_schedule(0, 50, 1.0, 0.1), 1.0);
        assert!((tau_schedule(49, 50, 1.0, 0.1) - 0.1).abs() < 1e-6);
        let mid = tau_schedule(25, 50, 1.0, 0.1);
        assert!(mid < 1.0 && mid > 0.1);
    }
}
