//! Linear sum assignment (Hungarian / Jonker-Volgenant).
//!
//! Hardens a soft permutation matrix into the closest strict permutation
//! (paper Eq. 6: argmax_P Tr(P^T P̂)) and powers the LSA refinement stage
//! of the RIA channel-permutation baseline.  O(n^3) shortest augmenting
//! path with potentials (JV); exact.

use crate::tensor::Mat;

/// Maximize `sum_i gain[i, assign(i)]` over permutations.
/// Returns `assign` with `assign[row] = col`.
pub fn assign_max(gain: &Mat) -> Vec<usize> {
    // JV minimizes cost; negate.
    let (n, m) = gain.shape();
    assert_eq!(n, m, "assignment needs a square matrix");
    // Non-finite gains (overflowed soft permutations) are treated as
    // strongly undesirable instead of poisoning the potentials, which
    // would otherwise make the augmenting-path search loop forever.
    let cost: Vec<f64> = gain
        .data()
        .iter()
        .map(|&v| if v.is_finite() { -(v as f64) } else { 1e30 })
        .collect();
    assign_min_cost(n, &cost)
}

/// Harden a soft permutation block `p_soft` `[B, B]` (Eq. 6):
/// returns `src_of` with `P[src_of[j], j] = 1`, i.e. output position `j`
/// takes input channel `src_of[j]`.
pub fn harden(p_soft: &Mat) -> Vec<usize> {
    let assign = assign_max(p_soft); // assign[row i] = col j maximizing sum P[i, j]
    let n = p_soft.rows();
    let mut src_of = vec![0usize; n];
    for (i, &j) in assign.iter().enumerate() {
        src_of[j] = i;
    }
    src_of
}

/// Jonker-Volgenant shortest-augmenting-path, minimizing total cost.
/// `cost` is row-major `n x n`.  Returns `assign[row] = col`.
fn assign_min_cost(n: usize, cost: &[f64]) -> Vec<usize> {
    const INF: f64 = f64::INFINITY;
    // Potentials and matching; 1-based sentinel column 0 per the classic
    // e-maxx formulation, mapped onto 0-based storage with +1 offsets.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[col] = row matched to col (1-based rows)
    let mut way = vec![0usize; n + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[(i0 - 1) * n + (j - 1)] - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assign = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            assign[p[j] - 1] = j - 1;
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    fn brute_force_max(gain: &Mat) -> f64 {
        let n = gain.rows();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut best = f64::NEG_INFINITY;
        // Heap's algorithm.
        fn rec(k: usize, perm: &mut Vec<usize>, gain: &Mat, best: &mut f64) {
            if k == 1 {
                let sc: f64 = perm.iter().enumerate().map(|(i, &j)| gain[(i, j)] as f64).sum();
                if sc > *best {
                    *best = sc;
                }
                return;
            }
            for i in 0..k {
                rec(k - 1, perm, gain, best);
                if k % 2 == 0 {
                    perm.swap(i, k - 1);
                } else {
                    perm.swap(0, k - 1);
                }
            }
        }
        rec(n, &mut perm, gain, &mut best);
        best
    }

    #[test]
    fn prop_matches_brute_force_up_to_7() {
        testkit::check_n("hungarian-exact", 24, |rng| {
            let n = 2 + rng.below_usize(6);
            let gain = Mat::randn(n, n, 1.0, rng);
            let assign = assign_max(&gain);
            // valid permutation
            let mut seen = vec![false; n];
            for &j in &assign {
                if seen[j] {
                    return Err("not a permutation".into());
                }
                seen[j] = true;
            }
            let got: f64 = assign.iter().enumerate().map(|(i, &j)| gain[(i, j)] as f64).sum();
            let want = brute_force_max(&gain);
            if (got - want).abs() > 1e-9 {
                return Err(format!("got {got}, optimum {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn harden_identity_on_near_identity() {
        let mut p = Mat::full(4, 4, 0.1);
        for i in 0..4 {
            p[(i, i)] = 0.7;
        }
        assert_eq!(harden(&p), vec![0, 1, 2, 3]);
    }

    #[test]
    fn harden_recovers_known_permutation() {
        let mut rng = Pcg32::seeded(3);
        // Build a noisy soft version of a random permutation.
        let n = 16;
        let src_of = rng.permutation(n);
        let mut p = Mat::zeros(n, n);
        for (j, &i) in src_of.iter().enumerate() {
            p[(i, j)] = 1.0;
        }
        for v in p.data_mut() {
            *v += rng.uniform() * 0.3;
        }
        assert_eq!(harden(&p), src_of);
    }

    #[test]
    fn large_block_runs_fast() {
        let mut rng = Pcg32::seeded(4);
        let p = Mat::randn(64, 64, 1.0, &mut rng);
        let a = assign_max(&p);
        let mut seen = vec![false; 64];
        for &j in &a {
            assert!(!seen[j]);
            seen[j] = true;
        }
    }
}
