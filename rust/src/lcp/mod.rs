//! Learnable channel permutation (the paper's core contribution).
//!
//! * [`sinkhorn`] — host Sinkhorn normalization with hand-derived VJP;
//! * [`hungarian`] — exact linear-sum-assignment hardening (Eq. 6);
//! * [`adamw`] — the optimizer + temperature schedule;
//! * [`trainer`] — the per-layer LCP loop with straight-through gradients,
//!   generic over a [`trainer::LcpBackend`] (pure-Rust or AOT artifact).

pub mod adamw;
pub mod hungarian;
pub mod sinkhorn;
pub mod trainer;

pub use adamw::{tau_schedule, AdamW, AdamWCfg};
pub use hungarian::{assign_max, harden};
pub use sinkhorn::SinkhornTape;
pub use trainer::{cosine_loss_grad, train_lcp, HostBackend, LayerData, LcpBackend, LcpCfg, LcpResult};
