//! Host Sinkhorn normalization: forward + hand-derived backward.
//!
//! Mirrors the L1 Pallas kernel / jnp reference exactly (same operation
//! order, same eps-free math) so the pure-Rust LCP path cross-checks
//! against the AOT `lcp_grad` artifact to float tolerance.

use crate::tensor::Mat;

/// Forward pass with saved intermediates for the backward pass.
///
/// `S0 = exp(W_P / tau)`, then `iters` rounds of row normalization
/// followed by column normalization (paper Eqs. 2-4).
pub struct SinkhornTape {
    tau: f32,
    /// exp(W_P / tau).
    a0: Mat,
    /// Input to each column-normalization (i.e. output of the row step).
    row_outs: Vec<Mat>,
    /// Output of each column-normalization.
    col_outs: Vec<Mat>,
}

impl SinkhornTape {
    /// Run the forward pass on one `B x B` block.
    pub fn forward(w_p: &Mat, tau: f32, iters: usize) -> SinkhornTape {
        let a0 = w_p.map(|v| (v / tau).exp());
        let mut cur = a0.clone();
        let mut row_outs = Vec::with_capacity(iters);
        let mut col_outs = Vec::with_capacity(iters);
        for _ in 0..iters {
            let r = row_normalize(&cur);
            let c = col_normalize(&r);
            row_outs.push(r);
            col_outs.push(c.clone());
            cur = c;
        }
        SinkhornTape { tau, a0, row_outs, col_outs }
    }

    /// The soft permutation matrix (output of the last iteration).
    pub fn output(&self) -> &Mat {
        self.col_outs.last().unwrap_or(&self.a0)
    }

    /// Backward: given dL/dP_soft, return dL/dW_P.
    pub fn backward(&self, d_out: &Mat) -> Mat {
        let mut g = d_out.clone();
        for l in (0..self.row_outs.len()).rev() {
            // col_norm consumed row_outs[l] and produced col_outs[l].
            g = col_normalize_bwd(&self.row_outs[l], &self.col_outs[l], &g);
            // row_norm consumed (a0 or col_outs[l-1]) and produced row_outs[l].
            let input = if l == 0 { &self.a0 } else { &self.col_outs[l - 1] };
            g = row_normalize_bwd(input, &self.row_outs[l], &g);
        }
        // dW_P = g * a0 / tau   (a0 = exp(W_P/tau)).
        let mut out = g;
        for (o, &a) in out.data_mut().iter_mut().zip(self.a0.data()) {
            *o *= a / self.tau;
        }
        out
    }
}

/// `Y = X / rowsum(X)`.
fn row_normalize(x: &Mat) -> Mat {
    let (n, m) = x.shape();
    let mut out = x.clone();
    for r in 0..n {
        let s: f32 = x.row(r).iter().sum();
        for v in out.row_mut(r) {
            *v /= s;
        }
        let _ = m;
    }
    out
}

/// `Y = X / colsum(X)`.
fn col_normalize(x: &Mat) -> Mat {
    let (n, m) = x.shape();
    let mut sums = vec![0.0f32; m];
    for r in 0..n {
        for (s, &v) in sums.iter_mut().zip(x.row(r)) {
            *s += v;
        }
    }
    let mut out = x.clone();
    for r in 0..n {
        for (v, &s) in out.row_mut(r).iter_mut().zip(&sums) {
            *v /= s;
        }
    }
    out
}

/// VJP of row normalization: `dX_ij = (dY_ij - Σ_k dY_ik Y_ik) / s_i`.
fn row_normalize_bwd(x: &Mat, y: &Mat, dy: &Mat) -> Mat {
    let (n, _m) = x.shape();
    let mut out = dy.clone();
    for r in 0..n {
        let s: f32 = x.row(r).iter().sum();
        let inner: f32 = dy.row(r).iter().zip(y.row(r)).map(|(d, v)| d * v).sum();
        for v in out.row_mut(r) {
            *v = (*v - inner) / s;
        }
    }
    out
}

/// VJP of column normalization: `dX_ij = (dY_ij - Σ_k dY_kj Y_kj) / s_j`.
fn col_normalize_bwd(x: &Mat, y: &Mat, dy: &Mat) -> Mat {
    let (n, m) = x.shape();
    let mut sums = vec![0.0f32; m];
    let mut inners = vec![0.0f32; m];
    for r in 0..n {
        for c in 0..m {
            sums[c] += x[(r, c)];
            inners[c] += dy[(r, c)] * y[(r, c)];
        }
    }
    let mut out = dy.clone();
    for r in 0..n {
        for c in 0..m {
            out[(r, c)] = (out[(r, c)] - inners[c]) / sums[c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    #[test]
    fn forward_is_doubly_stochastic_at_convergence() {
        let mut rng = Pcg32::seeded(1);
        let w_p = Mat::randn(16, 16, 1.0, &mut rng);
        let tape = SinkhornTape::forward(&w_p, 0.7, 40);
        let p = tape.output();
        for r in 0..16 {
            let rs: f32 = p.row(r).iter().sum();
            assert!((rs - 1.0).abs() < 1e-3, "row {r} sums to {rs}");
        }
        for c in 0..16 {
            let cs: f32 = p.col(c).iter().sum();
            assert!((cs - 1.0).abs() < 1e-3, "col {c} sums to {cs}");
        }
    }

    #[test]
    fn zero_iters_is_plain_exp() {
        let w_p = Mat::from_vec(2, 2, vec![0.0, 1.0, -1.0, 0.5]);
        let tape = SinkhornTape::forward(&w_p, 1.0, 0);
        let want: Vec<f32> = w_p.data().iter().map(|v| v.exp()).collect();
        testkit::assert_close(tape.output().data(), &want, 1e-6).unwrap();
    }

    #[test]
    fn prop_backward_matches_finite_differences() {
        testkit::check_n("sinkhorn-fd", 12, |rng| {
            let b = 4 + rng.below_usize(4);
            let iters = rng.below_usize(6);
            let tau = 0.5 + rng.uniform();
            let w_p = Mat::randn(b, b, 0.5, rng);
            // Random downstream cotangent.
            let dy = Mat::randn(b, b, 1.0, rng);

            let tape = SinkhornTape::forward(&w_p, tau, iters);
            let grad = tape.backward(&dy);

            // Directional finite difference along a random direction.
            let dir = Mat::randn(b, b, 1.0, rng);
            let eps = 1e-3f32;
            let wp_plus = w_p.add(&dir.scale(eps));
            let wp_minus = w_p.sub(&dir.scale(eps));
            let f = |m: &Mat| -> f64 {
                let t = SinkhornTape::forward(m, tau, iters);
                t.output()
                    .data()
                    .iter()
                    .zip(dy.data())
                    .map(|(&y, &g)| (y * g) as f64)
                    .sum()
            };
            let fd = (f(&wp_plus) - f(&wp_minus)) / (2.0 * eps as f64);
            let analytic: f64 = grad
                .data()
                .iter()
                .zip(dir.data())
                .map(|(&g, &d)| (g * d) as f64)
                .sum();
            let denom = fd.abs().max(analytic.abs()).max(1e-3);
            if (fd - analytic).abs() / denom > 0.02 {
                return Err(format!("fd {fd} vs analytic {analytic}"));
            }
            Ok(())
        });
    }

    #[test]
    fn backward_shape_matches() {
        let mut rng = Pcg32::seeded(2);
        let w_p = Mat::randn(8, 8, 1.0, &mut rng);
        let tape = SinkhornTape::forward(&w_p, 1.0, 5);
        let g = tape.backward(&Mat::full(8, 8, 1.0));
        assert_eq!(g.shape(), (8, 8));
        assert!(g.data().iter().all(|v| v.is_finite()));
    }
}
