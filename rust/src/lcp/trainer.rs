//! The learnable-channel-permutation trainer (paper §3-§4).
//!
//! Per linear layer: learn block-diagonal permutation logits `W_P`
//! `[N_B, B, B]` by AdamW so that the permuted-then-N:M-pruned layer's
//! output matches the dense output under the cosine loss (Eq. 10).
//! Each step:
//!
//! 1. `P_soft = Sinkhorn(W_P / tau)` (temperature linearly decayed);
//! 2. `P_hard = Hungarian(P_soft)` per block (Eq. 6);
//! 3. loss/grad with the straight-through estimator: forward uses
//!    `P_hard` and the hard Eq. 8 mask, backward flows through `P_soft`
//!    and the group-softmax soft mask (Eq. 9);
//! 4. AdamW update on `W_P`; keep the best-seen permutation (the loss is
//!    noisy once tau is small — the hardening flips between neighbours).
//!
//! Two interchangeable gradient backends ([`LcpBackend`]):
//! * [`HostBackend`] — the pure-Rust hand-derived backward in this file;
//! * `runtime::ExecLcpBackend` — the same steps served through any
//!   `runtime::ExecBackend` (native engine, or the AOT `lcp_grad` XLA
//!   artifact with `--features pjrt`).
//! `tests/lcp_cross_check.rs` pins them to each other.

use crate::sparsity::{NmConfig, NmMask};
use crate::tensor::Mat;

use super::adamw::{tau_schedule, AdamW, AdamWCfg};
use super::hungarian::harden;
use super::sinkhorn::SinkhornTape;

/// Calibration bundle for one linear layer (original channel order).
#[derive(Debug, Clone)]
pub struct LayerData {
    /// Weight `[C_out, C_in]`.
    pub w: Mat,
    /// Importance scores `[C_out, C_in]` (from `pruning::importance`).
    pub s: Mat,
    /// Calibration activations `[T, C_in]`.
    pub x: Mat,
    /// Dense outputs `[T, C_out]` (`x W^T`).
    pub y: Mat,
}

impl LayerData {
    pub fn new(w: Mat, s: Mat, x: Mat) -> LayerData {
        let y = x.matmul_bt(&w);
        LayerData { w, s, x, y }
    }
}

/// LCP training hyperparameters (paper §5.1 defaults).
#[derive(Debug, Clone, Copy)]
pub struct LcpCfg {
    /// Block size B (paper default 64; Table 6 ablates 32/64/128).
    pub block: usize,
    /// Sinkhorn iterations L (paper default 5; Table 4 ablates 0/5).
    pub sinkhorn_iters: usize,
    /// Optimization steps (paper: 50).
    pub steps: usize,
    /// AdamW learning rate (paper: 1e-3..5e-3 at LLM scale; tiny layers
    /// train best around 0.05-0.1).
    pub lr: f32,
    /// Temperature decay endpoints (paper: 1.0 -> 0.1).
    pub tau0: f32,
    pub tau1: f32,
    /// Sparsity pattern.
    pub nm: NmConfig,
}

impl Default for LcpCfg {
    fn default() -> Self {
        LcpCfg {
            block: 64,
            sinkhorn_iters: 5,
            steps: 50,
            lr: 0.05,
            tau0: 1.0,
            tau1: 0.1,
            nm: NmConfig::PAT_2_4,
        }
    }
}

/// Gradient backend: everything the trainer needs per step.
pub trait LcpBackend {
    /// Soft permutations for the current logits (one `B x B` Mat per block).
    fn soft_perms(&mut self, w_p: &[Mat], tau: f32) -> Vec<Mat>;

    /// Loss and `dL/dW_P` for the hard permutation `p_hard_src`
    /// (per-block `src_of` vectors).
    fn loss_grad(&mut self, w_p: &[Mat], p_hard_src: &[Vec<usize>], tau: f32) -> (f32, Vec<Mat>);
}

/// Result of LCP training on one layer.
#[derive(Debug, Clone)]
pub struct LcpResult {
    /// Best global permutation found (`src_of` over all C_in channels).
    pub src_of: Vec<usize>,
    /// Loss at the best permutation.
    pub best_loss: f32,
    /// Loss of the identity permutation (plain one-shot pruning).
    pub baseline_loss: f32,
    /// Per-step losses (for convergence plots).
    pub history: Vec<f32>,
}

/// Train LCP for a layer with `c_in` input channels using `backend`.
pub fn train_lcp<B: LcpBackend>(backend: &mut B, c_in: usize, cfg: LcpCfg) -> LcpResult {
    assert_eq!(c_in % cfg.block, 0, "C_in must be divisible by block size");
    let n_b = c_in / cfg.block;
    let b = cfg.block;

    // Identity-biased init: step 0 reproduces the no-permutation baseline,
    // so training can only improve on it (mirrors python/tests/test_lcp.py).
    let mut w_p: Vec<Mat> = (0..n_b)
        .map(|_| {
            let mut m = Mat::zeros(b, b);
            for i in 0..b {
                m[(i, i)] = 2.0;
            }
            m
        })
        .collect();

    let mut opts: Vec<AdamW> = (0..n_b)
        .map(|_| AdamW::new(b * b, AdamWCfg { lr: cfg.lr, ..Default::default() }))
        .collect();

    let mut best_loss = f32::INFINITY;
    let mut baseline_loss = f32::NAN;
    let mut best_src: Vec<Vec<usize>> = (0..n_b).map(|_| (0..b).collect()).collect();
    let mut history = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        let tau = tau_schedule(step, cfg.steps, cfg.tau0, cfg.tau1);
        let soft = backend.soft_perms(&w_p, tau);
        let hard: Vec<Vec<usize>> = soft.iter().map(harden).collect();
        let (loss, grads) = backend.loss_grad(&w_p, &hard, tau);
        if step == 0 {
            // Identity-biased init + hungarian(I-dominant soft) = identity.
            baseline_loss = loss;
        }
        history.push(loss);
        if loss < best_loss {
            best_loss = loss;
            best_src = hard.clone();
        }
        for (n, opt) in opts.iter_mut().enumerate() {
            opt.step(w_p[n].data_mut(), grads[n].data());
            // Bound the logits so exp(w_p / tau) stays finite in f32 even at
            // tau = 0.1 (|8|/0.1 = 80, e^80 ~ 5.5e34 < f32::MAX).  Applied
            // identically for every backend, so host/artifact parity holds.
            for v in w_p[n].data_mut() {
                *v = v.clamp(-8.0, 8.0);
            }
        }
    }

    // Compose per-block src_of into a global permutation.
    let mut src_of = Vec::with_capacity(c_in);
    for (n, blk) in best_src.iter().enumerate() {
        src_of.extend(blk.iter().map(|&i| n * b + i));
    }
    LcpResult { src_of, best_loss, baseline_loss, history }
}

// ---------------------------------------------------------------------------
// Host backend: hand-derived forward/backward.
// ---------------------------------------------------------------------------

/// Pure-Rust gradient backend (no artifacts required).
pub struct HostBackend<'a> {
    data: &'a LayerData,
    nm: NmConfig,
    sinkhorn_iters: usize,
}

impl<'a> HostBackend<'a> {
    pub fn new(data: &'a LayerData, nm: NmConfig, sinkhorn_iters: usize) -> Self {
        HostBackend { data, nm, sinkhorn_iters }
    }
}

impl LcpBackend for HostBackend<'_> {
    fn soft_perms(&mut self, w_p: &[Mat], tau: f32) -> Vec<Mat> {
        w_p.iter()
            .map(|blk| SinkhornTape::forward(blk, tau, self.sinkhorn_iters).output().clone())
            .collect()
    }

    fn loss_grad(&mut self, w_p: &[Mat], p_hard_src: &[Vec<usize>], tau: f32) -> (f32, Vec<Mat>) {
        let d = self.data;
        let (c_out, c_in) = d.w.shape();
        let t = d.x.rows();
        let b = p_hard_src[0].len();
        let n_b = p_hard_src.len();
        debug_assert_eq!(n_b * b, c_in);

        // ---- forward (value path uses the HARD permutation) -------------
        let mut src_global = Vec::with_capacity(c_in);
        for (n, blk) in p_hard_src.iter().enumerate() {
            src_global.extend(blk.iter().map(|&i| n * b + i));
        }
        let w_perm = d.w.permute_cols(&src_global);
        let s_perm = d.s.permute_cols(&src_global);
        let x_perm = d.x.permute_cols(&src_global);
        let mask = NmMask::from_scores(&s_perm, self.nm);
        let wm = mask.apply(&w_perm);
        let y_sp = x_perm.matmul_bt(&wm);

        let (loss, d_y_sp) = cosine_loss_grad(&d.y, &y_sp);

        // ---- backward ----------------------------------------------------
        // y_sp = x_perm wm^T :  dWm = dY^T X,  dX_perm = dY Wm.
        let d_wm = d_y_sp.matmul_at(&x_perm); // [C_out, C_in]
        let d_x_perm = d_y_sp.matmul(&wm); // [T, C_in]

        // wm = mask ⊙ w_perm (product rule, both STE-coupled to P):
        let d_w_perm = {
            let mut g = d_wm.clone();
            for r in 0..c_out {
                for c in 0..c_in {
                    if !mask.get(r, c) {
                        g[(r, c)] = 0.0;
                    }
                }
            }
            g
        };
        // dM = dWm ⊙ w_perm, then group-softmax STE (Eq. 9) -> dS_perm.
        let d_s_perm = {
            let d_m = d_wm.hadamard(&w_perm);
            let m = self.nm.m;
            let mut out = Mat::zeros(c_out, c_in);
            let mut p = vec![0.0f32; m];
            for r in 0..c_out {
                for g in 0..c_in / m {
                    let base = g * m;
                    // softmax over the group of s_perm.
                    let mut mx = f32::NEG_INFINITY;
                    for k in 0..m {
                        mx = mx.max(s_perm[(r, base + k)]);
                    }
                    let mut z = 0.0f32;
                    for k in 0..m {
                        p[k] = (s_perm[(r, base + k)] - mx).exp();
                        z += p[k];
                    }
                    let mut inner = 0.0f32;
                    for k in 0..m {
                        p[k] /= z;
                        inner += p[k] * d_m[(r, base + k)];
                    }
                    for k in 0..m {
                        out[(r, base + k)] = p[k] * (d_m[(r, base + k)] - inner);
                    }
                }
            }
            out
        };

        // Accumulate dP_soft per block:
        // dP[n](i, j) = Σ_o W[o, nB+i] dW_perm[o, nB+j]
        //             + Σ_o S[o, nB+i] dS_perm[o, nB+j]
        //             + Σ_t X[t, nB+i] dX_perm[t, nB+j].
        let mut d_p: Vec<Mat> = (0..n_b).map(|_| Mat::zeros(b, b)).collect();
        accumulate_block_grad(&d.w, &d_w_perm, b, &mut d_p);
        accumulate_block_grad(&d.s, &d_s_perm, b, &mut d_p);
        accumulate_block_grad(&d.x, &d_x_perm, b, &mut d_p);
        let _ = (t, c_out);

        // STE: dP_soft = dP; Sinkhorn backward to the logits.
        let grads: Vec<Mat> = w_p
            .iter()
            .zip(&d_p)
            .map(|(blk, g)| SinkhornTape::forward(blk, tau, self.sinkhorn_iters).backward(g))
            .collect();

        (loss, grads)
    }
}

/// `dP[n] += A[:, nB..nB+B]^T · dA_perm[:, nB..nB+B]` for every block.
fn accumulate_block_grad(a: &Mat, d_a_perm: &Mat, b: usize, d_p: &mut [Mat]) {
    let (rows, cols) = a.shape();
    debug_assert_eq!(d_a_perm.shape(), (rows, cols));
    for r in 0..rows {
        let arow = a.row(r);
        let drow = d_a_perm.row(r);
        for (n, dp) in d_p.iter_mut().enumerate() {
            let base = n * b;
            for i in 0..b {
                let av = arow[base + i];
                if av == 0.0 {
                    continue;
                }
                let out = dp.row_mut(i);
                for (o, &dv) in out.iter_mut().zip(&drow[base..base + b]) {
                    *o += av * dv;
                }
            }
        }
    }
}

/// Mean cosine distance (Eq. 10) and its gradient w.r.t. `y_sp`.
/// Matches the JAX graph exactly: `nrm = |y| |ŷ| + 1e-8`, mean over rows.
pub fn cosine_loss_grad(y: &Mat, y_sp: &Mat) -> (f32, Mat) {
    let (t, c) = y.shape();
    assert_eq!(y_sp.shape(), (t, c));
    let mut loss = 0.0f64;
    let mut grad = Mat::zeros(t, c);
    for r in 0..t {
        let a = y.row(r);
        let b = y_sp.row(r);
        let dot: f32 = a.iter().zip(b).map(|(x, z)| x * z).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nrm = na * nb + 1e-8;
        loss += (1.0 - dot / nrm) as f64;
        // d/db [1 - dot/nrm] = -a/nrm + dot * na * (b/nb) / nrm^2.
        let coef = dot * na / (nb.max(1e-12) * nrm * nrm);
        let grow = grad.row_mut(r);
        for i in 0..c {
            grow[i] = (-a[i] / nrm + coef * b[i]) / t as f32;
        }
    }
    ((loss / t as f64) as f32, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::{importance, Metric};
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    fn layer(rng: &mut Pcg32, c_out: usize, c_in: usize, t: usize) -> LayerData {
        let w = Mat::randn(c_out, c_in, 1.0, rng);
        let x = Mat::randn(t, c_in, 1.0, rng);
        let s = importance(Metric::Wanda, &w, &x);
        LayerData::new(w, s, x)
    }

    #[test]
    fn cosine_grad_matches_finite_difference() {
        testkit::check_n("cosine-fd", 10, |rng| {
            let y = Mat::randn(4, 8, 1.0, rng);
            let y_sp = Mat::randn(4, 8, 1.0, rng);
            let (_, g) = cosine_loss_grad(&y, &y_sp);
            let dir = Mat::randn(4, 8, 1.0, rng);
            let eps = 1e-3f32;
            let lp = cosine_loss_grad(&y, &y_sp.add(&dir.scale(eps))).0 as f64;
            let lm = cosine_loss_grad(&y, &y_sp.sub(&dir.scale(eps))).0 as f64;
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an: f64 = g.data().iter().zip(dir.data()).map(|(&a, &b)| (a * b) as f64).sum();
            let denom = fd.abs().max(an.abs()).max(1e-4);
            if (fd - an).abs() / denom > 0.02 {
                return Err(format!("fd {fd} vs analytic {an}"));
            }
            Ok(())
        });
    }

    #[test]
    fn identity_hard_perm_reproduces_baseline_loss() {
        let mut rng = Pcg32::seeded(1);
        let data = layer(&mut rng, 16, 32, 24);
        let mut backend = HostBackend::new(&data, NmConfig::PAT_2_4, 5);
        let b = 8;
        let w_p: Vec<Mat> = (0..4).map(|_| Mat::eye(b).scale(2.0)).collect();
        let id: Vec<Vec<usize>> = (0..4).map(|_| (0..b).collect()).collect();
        let (loss, _) = backend.loss_grad(&w_p, &id, 1.0);
        // Direct computation.
        let mask = NmMask::from_scores(&data.s, NmConfig::PAT_2_4);
        let y_sp = data.x.matmul_bt(&mask.apply(&data.w));
        let want = data.y.mean_cosine_distance(&y_sp);
        assert!((loss - want).abs() < 1e-5, "{loss} vs {want}");
    }

    #[test]
    fn host_backend_grad_matches_finite_difference() {
        // End-to-end FD check of the full hand-derived backward.  The STE
        // makes the true objective piecewise-constant in W_P through the
        // hard path, so we check the *soft* surrogate the backward actually
        // differentiates: perturb W_P, keep P_hard and the hard mask FIXED,
        // and compare against the directional derivative of the surrogate
        // loss  L(P_soft-dependent soft mask + fixed hard forward)…
        // Simplest faithful probe: the gradient of the surrogate loss where
        // forward = soft path (P_soft, soft mask).  We rebuild that soft
        // forward here and compare directions.
        let mut rng = Pcg32::seeded(2);
        let c_out = 8;
        let c_in = 16;
        let b = 8;
        let data = layer(&mut rng, c_out, c_in, 12);
        let nm = NmConfig::PAT_2_4;
        let iters = 3;
        let tau = 0.8;

        let w_p: Vec<Mat> = (0..2).map(|_| Mat::randn(b, b, 0.3, &mut rng)).collect();

        // Soft-path loss as a function of W_P (what the STE backward
        // approximates): P = sinkhorn(W_P), M = group-softmax(S·P),
        // y = (M ⊙ W·P) ... contract with X·P.
        let soft_loss = |w_p: &[Mat]| -> f64 {
            let p: Vec<Mat> = w_p
                .iter()
                .map(|blk| SinkhornTape::forward(blk, tau, iters).output().clone())
                .collect();
            let apply = |a: &Mat| -> Mat {
                let (rows, cols) = a.shape();
                let mut out = Mat::zeros(rows, cols);
                for r in 0..rows {
                    for (n, pb) in p.iter().enumerate() {
                        for j in 0..b {
                            let mut acc = 0.0f32;
                            for i in 0..b {
                                acc += a[(r, n * b + i)] * pb[(i, j)];
                            }
                            out[(r, n * b + j)] = acc;
                        }
                    }
                }
                out
            };
            let w_perm = apply(&data.w);
            let s_perm = apply(&data.s);
            let x_perm = apply(&data.x);
            // soft mask
            let m = nm.m;
            let mut wm = w_perm.clone();
            for r in 0..c_out {
                for g in 0..c_in / m {
                    let base = g * m;
                    let mut mx = f32::NEG_INFINITY;
                    for k in 0..m {
                        mx = mx.max(s_perm[(r, base + k)]);
                    }
                    let mut z = 0.0;
                    let mut pg = vec![0.0f32; m];
                    for k in 0..m {
                        pg[k] = (s_perm[(r, base + k)] - mx).exp();
                        z += pg[k];
                    }
                    for k in 0..m {
                        wm[(r, base + k)] *= pg[k] / z;
                    }
                }
            }
            let y_sp = x_perm.matmul_bt(&wm);
            cosine_loss_grad(&data.y, &y_sp).0 as f64
        };

        // The hand backward differentiates the *hard-forward* STE surrogate,
        // which is NOT the soft loss above — but the two gradients must be
        // strongly aligned when soft≈hard. Force agreement by making W_P
        // strongly permutation-like first.
        let mut w_p_sharp: Vec<Mat> = Vec::new();
        for blk in &w_p {
            let hard = harden(SinkhornTape::forward(blk, tau, iters).output());
            let mut sharp = Mat::full(b, b, -3.0);
            for (j, &i) in hard.iter().enumerate() {
                sharp[(i, j)] = 3.0;
            }
            w_p_sharp.push(sharp);
        }

        let mut backend = HostBackend::new(&data, nm, iters);
        let soft = backend.soft_perms(&w_p_sharp, tau);
        let hard: Vec<Vec<usize>> = soft.iter().map(harden).collect();
        let (_, grads) = backend.loss_grad(&w_p_sharp, &hard, tau);

        // Directional FD on the soft surrogate.
        let dirs: Vec<Mat> = (0..2).map(|_| Mat::randn(b, b, 1.0, &mut rng)).collect();
        let eps = 1e-2f32;
        let plus: Vec<Mat> = w_p_sharp.iter().zip(&dirs).map(|(w, d)| w.add(&d.scale(eps))).collect();
        let minus: Vec<Mat> = w_p_sharp.iter().zip(&dirs).map(|(w, d)| w.sub(&d.scale(eps))).collect();
        let fd = (soft_loss(&plus) - soft_loss(&minus)) / (2.0 * eps as f64);
        let an: f64 = grads
            .iter()
            .zip(&dirs)
            .flat_map(|(g, d)| g.data().iter().zip(d.data()))
            .map(|(&g, &d)| (g * d) as f64)
            .sum();
        // Direction (sign + rough magnitude) must agree.
        let denom = fd.abs().max(an.abs()).max(1e-6);
        assert!(
            (fd - an).abs() / denom < 0.5,
            "hand grad {an} vs soft-surrogate fd {fd}"
        );
    }

    #[test]
    fn train_lcp_beats_identity_baseline() {
        let mut rng = Pcg32::seeded(3);
        let data = layer(&mut rng, 24, 32, 32);
        let mut backend = HostBackend::new(&data, NmConfig::PAT_2_4, 5);
        let cfg = LcpCfg { block: 8, steps: 40, lr: 0.1, ..Default::default() };
        let res = train_lcp(&mut backend, 32, cfg);
        assert!(res.best_loss <= res.baseline_loss + 1e-6,
            "best {} vs baseline {}", res.best_loss, res.baseline_loss);
        // Permutation is valid and block-diagonal.
        let mut seen = vec![false; 32];
        for (j, &i) in res.src_of.iter().enumerate() {
            assert!(!seen[i]);
            seen[i] = true;
            assert_eq!(j / 8, i / 8, "crossed block boundary");
        }
    }

    #[test]
    fn train_lcp_usually_improves_strictly() {
        // Across seeds, LCP should strictly beat the baseline more often
        // than not (matches the paper's consistent gains).
        let mut wins = 0;
        for seed in 0..5 {
            let mut rng = Pcg32::seeded(100 + seed);
            let data = layer(&mut rng, 16, 32, 24);
            let mut backend = HostBackend::new(&data, NmConfig::PAT_2_4, 5);
            let cfg = LcpCfg { block: 8, steps: 40, lr: 0.1, ..Default::default() };
            let res = train_lcp(&mut backend, 32, cfg);
            if res.best_loss < res.baseline_loss - 1e-6 {
                wins += 1;
            }
        }
        assert!(wins >= 3, "only {wins}/5 seeds improved");
    }
}
