//! # PermLLM — Learnable Channel Permutation for N:M Sparse LLMs
//!
//! A Rust + JAX + Pallas reproduction of *PermLLM: Learnable Channel
//! Permutation for N:M Sparse Large Language Models* (2025).
//!
//! Three layers (DESIGN.md §2):
//! * **L1** Pallas kernels (`python/compile/kernels/`) — Sinkhorn, N:M mask
//!   selection, channel permutation, compressed 2:4 SpMM;
//! * **L2** JAX graphs (`python/compile/`) — tiny LLaMA-style LM
//!   (train/forward) and the LCP loss+grad graphs, AOT-lowered to HLO text;
//! * **L3** this crate — the pruning pipeline: calibration, importance
//!   metrics, one-shot pruning (magnitude/Wanda/RIA/SparseGPT), heuristic
//!   channel permutation baselines, the learnable-channel-permutation
//!   trainer (Sinkhorn + Hungarian + AdamW + STE), permutation propagation,
//!   evaluation, and the experiment harness for every paper table/figure.
//!
//! ## Execution backends
//!
//! Compute kernels are addressed as named artifacts behind the
//! [`runtime::ExecBackend`] trait:
//!
//! * **default (offline)** — [`runtime::NativeEngine`], pure Rust, no
//!   external dependencies or artifacts.  `cargo build && cargo test`
//!   work on a clean machine with no network.
//! * **`--features pjrt`** — `runtime::Engine` loads the AOT artifacts
//!   (`make artifacts`) and executes them once-compiled via PJRT.  The
//!   workspace ships a typed `xla` stub so this feature type-checks
//!   offline; executing real artifacts requires swapping in the genuine
//!   `xla` bindings.  Python never runs on the request path either way.
//!
//! ## Pruning recipes
//!
//! Pruning methods are composed, not enumerated: a
//! [`recipe::PruneRecipe`] pairs a [`recipe::ScoreMetric`]
//! (magnitude/Wanda/RIA) with a [`recipe::PermStrategy`] (identity,
//! heuristic CP, the learned Sinkhorn permutation, RPTQ-style range
//! sorting) and a [`recipe::WeightUpdate`] (mask-only, or SparseGPT's
//! OBS solver) at an N:M pattern.  Every paper-table row is a recipe
//! ([`recipe::rows`]), recipes serialize to JSON for bench artifacts
//! and `permllm prune --sweep`, and the three traits are open — new
//! combinations (learned permutation *with* the OBS update, say) are
//! one builder chain, not an enum surgery.  The legacy
//! `coordinator::PruneMethod` enum is deprecated and lowers into
//! recipes.
//!
//! ## Quickstart
//!
//! ```no_run
//! use permllm::lcp::{train_lcp, LayerData, LcpCfg};
//! use permllm::pruning::{importance, prune_permuted, Metric};
//! use permllm::runtime::{ExecLcpBackend, NativeEngine};
//! use permllm::sparsity::NmConfig;
//! use permllm::tensor::Mat;
//! use permllm::util::rng::Pcg32;
//!
//! let nm = NmConfig::PAT_2_4;
//! let mut rng = Pcg32::seeded(7);
//! let w = Mat::randn(64, 128, 0.1, &mut rng); // a [C_out, C_in] layer
//! let x = Mat::randn(96, 128, 1.0, &mut rng); // calibration activations
//! let s = importance(Metric::Wanda, &w, &x);
//! let data = LayerData::new(w.clone(), s, x.clone());
//!
//! // Learn a channel permutation through the execution-backend trait.
//! let mut engine = NativeEngine::default();
//! let cfg = LcpCfg { block: 64, steps: 50, nm, ..Default::default() };
//! let mut backend = ExecLcpBackend::new(&mut engine, &data, cfg.block).unwrap();
//! let res = train_lcp(&mut backend, w.cols(), cfg);
//! let pruned = prune_permuted(Metric::Wanda, &w, &x, nm, &res.src_of);
//! assert!(pruned.mask.verify());
//! ```
//!
//! ## Serving
//!
//! [`serve`] turns the sparse hot path into a subsystem: a
//! [`serve::SparseModel`] caches every pruned linear in compressed form,
//! a micro-batcher coalesces the request queue, and
//! [`serve::Server`] runs decoder-layer stages either sequentially or
//! pipelined across per-stage backends (`permllm serve`, or the
//! `sparse_inference` example for the benchmark loop).  Token
//! generation runs through the KV-cached decode loop
//! ([`serve::Server::run_decode_streaming`], `permllm serve --decode`):
//! per-request [`serve::KvStore`]s — contiguous buffers, or fixed-size
//! pages from a shared [`serve::KvPool`] with copy-on-write prefix
//! sharing and preemption-by-recompute (`--kv-pages`) — continuous
//! batching of mixed prefill + decode steps, and greedy / top-k / top-p
//! token streaming per ticket.
//!
//! A pruned model persists to a versioned binary [`snapshot`]
//! (`permllm prune --snapshot-out` / `permllm serve --snapshot`, format
//! spec in `docs/SNAPSHOT_FORMAT.md`), so serving boots without
//! re-pruning and sweeps reuse pruned artifacts; [`serve::trace`] is
//! the trace-driven workload harness (`permllm serve --trace-gen` /
//! `--trace`) replaying seeded mixed workloads against the decode loop
//! with per-class SLO reporting.
//!
//! See `examples/` (`quickstart`, `prune_llm`, `end_to_end`,
//! `sparse_inference`, `ablation_lcp`) and the README for the full tour.

pub mod bench;
pub mod coordinator;
pub mod cp;
pub mod data;
pub mod eval;
pub mod lcp;
pub mod model;
pub mod pruning;
pub mod quant;
pub mod recipe;
pub mod runtime;
pub mod serve;
pub mod snapshot;
pub mod sparsity;
pub mod tensor;
pub mod util;
