//! # PermLLM — Learnable Channel Permutation for N:M Sparse LLMs
//!
//! A Rust + JAX + Pallas reproduction of *PermLLM: Learnable Channel
//! Permutation for N:M Sparse Large Language Models* (2025).
//!
//! Three layers (DESIGN.md §2):
//! * **L1** Pallas kernels (`python/compile/kernels/`) — Sinkhorn, N:M mask
//!   selection, channel permutation, compressed 2:4 SpMM;
//! * **L2** JAX graphs (`python/compile/`) — tiny LLaMA-style LM
//!   (train/forward) and the LCP loss+grad graphs, AOT-lowered to HLO text;
//! * **L3** this crate — the pruning pipeline: calibration, importance
//!   metrics, one-shot pruning (magnitude/Wanda/RIA/SparseGPT), heuristic
//!   channel permutation baselines, the learnable-channel-permutation
//!   trainer (Sinkhorn + Hungarian + AdamW + STE), permutation propagation,
//!   evaluation, and the experiment harness for every paper table/figure.
//!
//! Python never runs on the request path: the `xla` crate loads the AOT
//! artifacts once and executes them via PJRT (see [`runtime`]).

pub mod bench;
pub mod coordinator;
pub mod cp;
pub mod data;
pub mod eval;
pub mod lcp;
pub mod model;
pub mod pruning;
pub mod quant;
pub mod runtime;
pub mod sparsity;
pub mod tensor;
pub mod util;
