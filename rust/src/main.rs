//! `permllm` — CLI for the PermLLM pruning framework.
//!
//! Subcommands:
//!   prune     prune a model with a composed recipe (--metric/--perm/
//!             --update, or the legacy --method shim; --sweep runs a
//!             JSON recipe list over the worker pool) and report
//!             perplexity
//!   serve     prune, compress, and serve the sparse path (batched or
//!             streaming, MLP-only or full decoder with --sparse-attn,
//!             KV-cached token generation with --decode and greedy or
//!             seeded top-k/top-p sampling via --sampler, a paged KV
//!             pool with prefix sharing and preemption via --kv-pages,
//!             optionally pipelined across decoder layers; --snapshot
//!             boots from a `prune --snapshot-out` file without
//!             re-pruning, and --trace-gen / --trace generate and
//!             replay mixed workload traces with per-class SLO reports)
//!   eval      evaluate a saved model (perplexity + zero-shot suite)
//!   train     pretrain the tiny LM via the AOT train_step artifact (pjrt)
//!   info      print artifact manifest / model summary
//!   backends  list the execution backends compiled into this binary

use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, Result};

use permllm::coordinator::{
    calibrate, prune_with_recipe, prune_with_recipe_calibrated, LcpExecutor, PipelineCfg,
};
use permllm::data::{Corpus, CorpusKind};
use permllm::eval::{eval_perplexity, eval_perplexity_exec, zeroshot_accuracy, zeroshot_suite};
use permllm::lcp::LcpCfg;
use permllm::model::{synth_trained_params, ModelConfig, ParamStore};
use permllm::recipe::{self, PruneRecipe};
use permllm::runtime::{ExecBackend, NativeCfg, NativeEngine};
use permllm::serve::{
    trace, BatcherCfg, GenRequest, Request, Sampler, ServeCfg, ServePath, Server, SparseModel,
};
use permllm::sparsity::NmConfig;
use permllm::tensor::Mat;
use permllm::util::cli::{Cli, Parsed};
use permllm::util::json::{self, Json};
use permllm::util::pool::parallel_map;
use permllm::util::rng::Pcg32;

fn main() {
    permllm::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { vec![] } else { args[1..].to_vec() };
    let code = match cmd {
        "prune" => run(cmd_prune(&rest)),
        "serve" => run(cmd_serve(&rest)),
        "eval" => run(cmd_eval(&rest)),
        "train" => run(cmd_train(&rest)),
        "info" => run(cmd_info(&rest)),
        "backends" => run(cmd_backends()),
        _ => {
            eprintln!(
                "usage: permllm <prune|serve|eval|train|info|backends> [options]\n\
                 \n  permllm prune --model tiny-s --metric ria --perm learned --update none\
                 \n  permllm prune --model tiny-s --method permllm-wanda --sparsity 2:4\
                 \n  permllm prune --model tiny-s --sweep recipes.json\
                 \n  permllm serve --model tiny-s --requests 32 --tokens 64\
                 \n  permllm serve --model tiny-s --sparse-attn --stream\
                 \n  permllm serve --model tiny-s --sparse-attn --decode --max-new 16\
                 \n  permllm prune --model tiny-s --metric wanda --perm identity --snapshot-out model.pmsn\
                 \n  permllm serve --model tiny-s --snapshot model.pmsn --sparse-attn --decode\
                 \n  permllm serve --model tiny-s --trace-gen trace.json --trace-requests 24\
                 \n  permllm serve --model tiny-s --sparse-attn --trace trace.json --kv-pages 128 --kv-share-prefix\
                 \n  permllm eval  --params models/tiny-m.bin --backend native\
                 \n  permllm train --artifacts artifacts --steps 300 --out models/tiny-m.bin\
                 \n  permllm info  --artifacts artifacts\n\
                 \n  permllm backends\n"
            );
            1
        }
    };
    std::process::exit(code);
}

fn run(r: Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Valid values for the legacy `--method` shim (error messages + help).
const METHOD_NAMES: &str =
    "dense, sparsegpt, magnitude, wanda, ria, wanda-cp, ria-cp, permllm-wanda, permllm-ria";

/// Legacy `--method` compatibility shim: lower the old closed-enum
/// method names straight into recipes.
#[allow(deprecated)]
fn parse_method(s: &str, nm: NmConfig) -> Result<PruneRecipe> {
    use permllm::coordinator::PruneMethod;
    use permllm::pruning::Metric;
    let method = match s {
        "dense" => PruneMethod::Dense,
        "sparsegpt" => PruneMethod::SparseGpt,
        "magnitude" => PruneMethod::OneShot(Metric::Magnitude),
        "wanda" => PruneMethod::OneShot(Metric::Wanda),
        "ria" => PruneMethod::OneShot(Metric::Ria),
        "wanda-cp" => PruneMethod::OneShotCp(Metric::Wanda),
        "ria-cp" => PruneMethod::OneShotCp(Metric::Ria),
        "permllm-wanda" => PruneMethod::PermLlm(Metric::Wanda),
        "permllm-ria" => PruneMethod::PermLlm(Metric::Ria),
        _ => {
            return Err(anyhow!(
                "unknown --method '{s}' (valid: {METHOD_NAMES}; or compose a recipe with \
                 --metric/--perm/--update — see --help)"
            ))
        }
    };
    Ok(method.to_recipe(nm))
}

/// Build the recipe from the CLI flags: the legacy `--method` shim when
/// set, otherwise the composable `--metric` / `--perm` / `--update`
/// axes.  Every parse failure names the valid values.
fn recipe_from_args(p: &Parsed, nm: NmConfig) -> Result<PruneRecipe> {
    let method = p.get("method");
    if !method.is_empty() {
        return parse_method(method, nm);
    }
    if p.get("metric") == "dense" {
        return Ok(PruneRecipe::dense(nm));
    }
    let metric = recipe::metric_from_kind(p.get("metric"))
        .map_err(|e| anyhow!("--metric: {e} (or 'dense' for the unpruned baseline)"))?;
    let perm = recipe::perm_from_kind(p.get("perm")).map_err(|e| anyhow!("--perm: {e}"))?;
    let update =
        recipe::update_from_kind(p.get("update")).map_err(|e| anyhow!("--update: {e}"))?;
    Ok(PruneRecipe::from_parts(metric, perm, update, nm))
}

fn parse_nm(p: &Parsed) -> Result<NmConfig> {
    let s = p.get("sparsity");
    NmConfig::parse(s).ok_or_else(|| {
        anyhow!("bad --sparsity '{s}' (expected zeros:group, e.g. 2:4 or 4:8)")
    })
}

fn parse_corpus(p: &Parsed) -> Result<Corpus> {
    let s = p.get("corpus");
    let kind = CorpusKind::parse(s)
        .ok_or_else(|| anyhow!("unknown --corpus '{s}' (valid: c4, wikitext2, pile)"))?;
    Ok(Corpus::build(kind, 2024))
}

fn load_or_synth(model: &str, params: &str) -> Result<ParamStore> {
    if !params.is_empty() && Path::new(params).exists() {
        log::info!("loading params from {params}");
        return ParamStore::load(Path::new(params));
    }
    let cfg = ModelConfig::by_name(model).ok_or_else(|| anyhow!("unknown model '{model}'"))?;
    log::info!("using synthetic trained-statistics weights for {model}");
    Ok(synth_trained_params(&cfg, 42))
}

fn cmd_prune(args: &[String]) -> Result<()> {
    let p = Cli::new("permllm prune", "prune a model with a composed recipe and report perplexity")
        .opt("model", "tiny-s", "model config (tiny-s|tiny-m|tiny-l)")
        .opt("params", "", "path to a trained .bin (default: synthetic weights)")
        .opt("metric", "wanda", "score metric: magnitude|wanda|ria (or 'dense' for no pruning)")
        .opt("perm", "learned", "permutation strategy: identity|cp|greedy-cp|learned|range-sort")
        .opt("update", "none", "weight update: none|sparsegpt")
        .opt("method", "", "legacy method shim (dense|sparsegpt|...|permllm-ria); overrides the recipe flags")
        .opt("sweep", "", "run every recipe in this JSON file (an array of recipe objects)")
        .opt("sweep-out", "", "write per-recipe sweep results (JSON) to this path")
        .opt("sparsity", "2:4", "N:M pattern (zeros:group)")
        .opt("corpus", "c4", "calibration corpus: c4|wikitext2|pile")
        .opt("block", "64", "LCP block size")
        .opt("steps", "50", "LCP optimization steps")
        .opt("lr", "0.05", "LCP learning rate")
        .opt("lcp-from-layer", "0", "apply LCP only to layers >= this (partial PermLLM)")
        .opt("backend", "native", "LCP kernel executor: native (ExecBackend trait) | host (direct)")
        .opt("out", "", "save pruned model to this path")
        .opt("snapshot-out", "", "dump the compressed sparse model to this versioned snapshot (serve it with `permllm serve --snapshot`; format: docs/SNAPSHOT_FORMAT.md)")
        .parse_from(args)
        .map_err(|e| anyhow!(e))?;

    let ps = load_or_synth(p.get("model"), p.get("params"))?;
    let nm = parse_nm(&p)?;
    let executor = LcpExecutor::parse(p.get("backend")).ok_or_else(|| {
        anyhow!("unknown --backend '{}' (valid: {})", p.get("backend"), LcpExecutor::VALID)
    })?;
    let corpus = parse_corpus(&p)?;
    let cfg = PipelineCfg {
        nm,
        lcp: LcpCfg {
            block: p.get_usize("block"),
            steps: p.get_usize("steps"),
            lr: p.get_f32("lr"),
            nm,
            ..Default::default()
        },
        lcp_from_layer: p.get_usize("lcp-from-layer"),
        executor,
        ..Default::default()
    };

    if !p.get("sweep").is_empty() {
        return run_recipe_sweep(&p, &ps, &corpus, &cfg);
    }

    let recipe = recipe_from_args(&p, nm)?;
    let dense_ppl = eval_perplexity(&ps, &corpus, 99, 8, 64);
    log::info!("dense perplexity: {dense_ppl:.3}");
    let pruned = prune_with_recipe(&ps, &corpus, &recipe, &cfg);
    let ppl = eval_perplexity(&pruned.params, &corpus, 99, 8, 64);
    let mean_err = pruned.mean_layer_error();
    println!(
        "recipe={} sparsity={} ppl={:.3} (dense {:.3}) mean-layer-cosine-err={:.5} prune-time={:.1}s",
        recipe.name(),
        nm.name(),
        ppl,
        dense_ppl,
        mean_err,
        pruned.elapsed_s
    );
    let recipe_json = recipe.to_json().to_string();
    println!("recipe-json: {recipe_json}");
    let out = p.get("out");
    if !out.is_empty() {
        pruned.params.save(Path::new(out))?;
        log::info!("saved pruned model to {out}");
    }
    let snap_out = p.get("snapshot-out");
    if !snap_out.is_empty() {
        anyhow::ensure!(
            !recipe.is_dense(),
            "--snapshot-out captures the compressed sparse model; the Dense recipe has nothing to compress"
        );
        let sm = SparseModel::from_pruned(&pruned)?;
        permllm::snapshot::dump(&sm, Path::new(snap_out))?;
        println!(
            "snapshot: wrote {snap_out} ({} bytes compressed, recipe {})",
            sm.storage_bytes(),
            sm.recipe_name()
        );
    }
    Ok(())
}

/// `permllm prune --sweep recipes.json`: run every recipe in the file
/// over the same model + calibration corpus, fanned out across the
/// worker pool (the per-layer fan-out inside each run shares the
/// remaining threads), and report one result line per recipe.
fn run_recipe_sweep(p: &Parsed, ps: &ParamStore, corpus: &Corpus, cfg: &PipelineCfg) -> Result<()> {
    let path = p.get("sweep");
    let txt = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read --sweep file '{path}': {e}"))?;
    let parsed = Json::parse(&txt).map_err(|e| anyhow!("--sweep file '{path}': {e}"))?;
    let items = parsed
        .as_arr()
        .ok_or_else(|| anyhow!("--sweep file '{path}' must be a JSON array of recipe objects"))?;
    anyhow::ensure!(!items.is_empty(), "--sweep file '{path}' lists no recipes");
    let recipes = items
        .iter()
        .enumerate()
        .map(|(i, v)| {
            PruneRecipe::from_json(v).map_err(|e| anyhow!("--sweep recipe #{i}: {e}"))
        })
        .collect::<Result<Vec<PruneRecipe>>>()?;

    let dense_ppl = eval_perplexity(ps, corpus, 99, 8, 64);
    // Capture the calibration activations once — they depend only on
    // the model + corpus + calib settings, not the recipe.
    let cap = calibrate(ps, corpus, cfg);
    // Fan recipes out over the pool; each run's per-layer fan-out gets
    // the leftover share so the sweep never oversubscribes the cores.
    let outer = cfg.threads.clamp(1, recipes.len());
    let inner = (cfg.threads / outer).max(1);
    let results = parallel_map(recipes.len(), outer, |i| {
        let mut run_cfg = cfg.clone();
        run_cfg.threads = inner;
        run_cfg.nm = recipes[i].nm;
        let pruned = prune_with_recipe_calibrated(ps, &cap, &recipes[i], &run_cfg);
        let ppl = eval_perplexity(&pruned.params, corpus, 99, 8, 64);
        (ppl, pruned.mean_layer_error(), pruned.elapsed_s)
    });

    println!("sweep: {} recipes (dense ppl {dense_ppl:.3})", recipes.len());
    let mut out_rows = Vec::new();
    for (recipe, (ppl, mean_err, secs)) in recipes.iter().zip(&results) {
        println!(
            "  {:<28} sparsity={} ppl={:.3} mean-layer-cosine-err={:.5} prune-time={:.1}s",
            recipe.name(),
            recipe.nm.name(),
            ppl,
            mean_err,
            secs
        );
        out_rows.push(json::obj(vec![
            ("recipe", recipe.to_json()),
            ("ppl", json::num(*ppl as f64)),
            ("dense_ppl", json::num(dense_ppl as f64)),
            ("mean_layer_cosine_err", json::num(*mean_err as f64)),
            ("prune_time_s", json::num(*secs)),
        ]));
    }
    let out = p.get("sweep-out");
    if !out.is_empty() {
        std::fs::write(out, json::arr(out_rows).to_string() + "\n")?;
        println!("wrote sweep results to {out}");
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let p = Cli::new(
        "permllm serve",
        "prune + compress a model, then serve batched requests on the sparse path",
    )
    .opt("model", "tiny-s", "model config (tiny-s|tiny-m|tiny-l)")
    .opt("params", "", "path to a trained .bin (default: synthetic weights)")
    .opt("metric", "wanda", "score metric: magnitude|wanda|ria")
    .opt("perm", "learned", "permutation strategy: identity|cp|greedy-cp|learned|range-sort")
    .opt("update", "none", "weight update: none|sparsegpt")
    .opt("method", "", "legacy method shim (see `permllm prune --help`); overrides the recipe flags")
    .opt("sparsity", "2:4", "N:M pattern (zeros:group)")
    .opt("corpus", "c4", "calibration corpus: c4|wikitext2|pile")
    .opt("steps", "20", "LCP optimization steps (learned-permutation recipes)")
    .opt("requests", "32", "number of requests to serve")
    .opt("tokens", "64", "tokens (activation rows) per request")
    .opt("batch-tokens", "256", "micro-batch token budget")
    .opt("batch-requests", "8", "micro-batch request cap")
    .opt("threads", "0", "matmul worker threads per backend (0 = all cores)")
    .opt("seed", "7", "request activation seed")
    .flag("sequential", "disable cross-layer pipelining (single backend)")
    .flag("sparse-attn", "full decoder: serve attention (q/k/v/o + RoPE/softmax glue) sparsely too")
    .flag("stream", "long-lived streaming loop: requests enqueue while batches are in flight")
    .flag("decode", "KV-cached token generation: prompts in, greedy tokens out (continuous batching)")
    .opt("max-new", "16", "decode: max tokens to generate per request (staggered across requests)")
    .opt("sampler", "greedy", "decode token selection: greedy|top-k|top-p")
    .opt("top-k", "8", "decode: top-k shortlist size (with --sampler top-k)")
    .opt("top-p", "0.9", "decode: nucleus mass in (0,1] (with --sampler top-p)")
    .opt("temperature", "0.8", "decode: top-k/top-p softmax temperature")
    .opt("sample-seed", "7", "decode: top-k/top-p sampling seed (deterministic per seed)")
    .opt("kv-pages", "0", "decode: paged KV pool size in pages (0 = contiguous per-request caches)")
    .opt("kv-page-tokens", "16", "decode: token rows per KV page, per layer (with --kv-pages)")
    .flag("kv-share-prefix", "decode: share prefill pages across requests with a common page-aligned prompt prefix (copy-on-write; needs --kv-pages and --sparse-attn)")
    .opt("stream-clients", "4", "streaming/decode: concurrent submitting threads")
    .opt("linger-ms", "2", "streaming: micro-batch linger (ms) before dispatching a partial batch")
    .opt("queue-depth", "0", "streaming/decode: max in-flight requests before submit fails fast (0 = unbounded)")
    .opt("timeout-ms", "0", "streaming/decode: per-request queue timeout in ms (0 = disabled)")
    .opt("stats-every", "0", "streaming/decode: emit a StatsReport JSON line to stderr every N ms (0 = off)")
    .opt("snapshot", "", "boot from a versioned model snapshot (permllm prune --snapshot-out) instead of re-pruning; the recipe/pattern flags are ignored")
    .opt("trace", "", "replay a workload trace JSON (a --trace-gen file) through the decode loop and report per-class SLOs")
    .opt("trace-gen", "", "generate a seeded workload trace JSON at this path and exit")
    .opt("trace-seed", "7", "trace generator seed (with --trace-gen)")
    .opt("trace-requests", "24", "approximate request count in the generated trace (with --trace-gen)")
    .opt("slo-out", "", "write the --trace SLO report JSON to this path")
    .parse_from(args)
    .map_err(|e| anyhow!(e))?;

    // --trace-gen only writes a workload file; no model is pruned or
    // loaded (the trace stores the vocab it drew tokens from, and
    // replay re-validates against the serving model's vocab).
    let trace_gen = p.get("trace-gen");
    if !trace_gen.is_empty() {
        let mcfg = ModelConfig::by_name(p.get("model"))
            .ok_or_else(|| anyhow!("unknown model '{}'", p.get("model")))?;
        let tc = trace::TraceCfg {
            seed: p.get_u64("trace-seed"),
            vocab: mcfg.vocab as u32,
            // Page-align the shared fleet prefixes so CoW adoption can
            // take whole pages under --kv-share-prefix.
            prefix_tokens: p.get_usize("kv-page-tokens").max(1),
            ..trace::TraceCfg::default()
        }
        .with_requests(p.get_usize("trace-requests"));
        let t = trace::generate(&tc);
        let classes: std::collections::BTreeSet<&str> =
            t.requests.iter().map(|r| r.class.as_str()).collect();
        t.save(Path::new(trace_gen))?;
        println!(
            "trace: wrote {} requests across {} classes to {trace_gen} (seed {})",
            t.requests.len(),
            classes.len(),
            t.seed
        );
        return Ok(());
    }

    let snapshot = p.get("snapshot");
    let sm = if !snapshot.is_empty() {
        let sm = permllm::snapshot::load(Path::new(snapshot))?;
        println!(
            "loaded snapshot {snapshot}: {} ({} stages, recipe {}, pattern {}, {} bytes compressed)",
            sm.cfg().name,
            sm.n_stages(),
            sm.recipe_name(),
            sm.nm().name(),
            sm.storage_bytes()
        );
        sm
    } else {
        let ps = load_or_synth(p.get("model"), p.get("params"))?;
        let nm = parse_nm(&p)?;
        let recipe = recipe_from_args(&p, nm)?;
        anyhow::ensure!(!recipe.is_dense(), "serve needs a pruned model, not the Dense recipe");
        let corpus = parse_corpus(&p)?;
        let cfg = PipelineCfg {
            nm,
            lcp: LcpCfg { steps: p.get_usize("steps"), nm, ..Default::default() },
            ..Default::default()
        };
        log::info!("pruning {} with recipe {} for serving", p.get("model"), recipe.name());
        let pruned = prune_with_recipe(&ps, &corpus, &recipe, &cfg);
        let sm = SparseModel::from_pruned(&pruned)?;
        println!(
            "compressed {} linears ({} stages) from recipe {}: {} -> {} bytes ({:.3}x dense)",
            sm.cfg().prunable_linears().len(),
            sm.n_stages(),
            sm.recipe_name(),
            sm.dense_bytes(),
            sm.storage_bytes(),
            sm.storage_bytes() as f64 / sm.dense_bytes() as f64
        );
        sm
    };
    let nm = sm.nm();

    let n_stages = sm.n_stages();
    let threads = match p.get_usize("threads") {
        // Pipelined stages run concurrently: divide the cores across them
        // instead of oversubscribing with n_stages x cores workers.
        0 if !p.get_bool("sequential") => {
            (permllm::util::pool::default_threads() / n_stages).max(1)
        }
        0 => permllm::util::pool::default_threads(),
        n => n,
    };
    let n_requests = p.get_usize("requests");
    let tokens = p.get_usize("tokens");
    let path =
        if p.get_bool("sparse-attn") { ServePath::FullDecoder } else { ServePath::MlpOnly };
    let server = Server::new(
        sm,
        ServeCfg {
            batcher: BatcherCfg {
                max_tokens: p.get_usize("batch-tokens"),
                max_requests: p.get_usize("batch-requests"),
            },
            path,
            linger: Duration::from_millis(p.get_u64("linger-ms")),
            queue_depth: p.get_usize("queue-depth"),
            request_timeout: Duration::from_millis(p.get_u64("timeout-ms")),
            stats_every: Duration::from_millis(p.get_u64("stats-every")),
            kv_pages: p.get_usize("kv-pages"),
            kv_page_tokens: p.get_usize("kv-page-tokens"),
            kv_share_prefix: p.get_bool("kv-share-prefix"),
            ..ServeCfg::default()
        },
    );
    println!("serving path: {}", path.name());
    let native = |threads: usize| {
        NativeEngine::new(NativeCfg { nm, threads, ..NativeCfg::default() })
    };

    if !p.get("trace").is_empty() {
        return run_serve_trace(&p, &server, threads, n_stages, &native);
    }
    if p.get_bool("decode") {
        return run_serve_decode(&p, &server, threads, n_stages, &native);
    }
    if p.get_bool("stream") {
        return run_serve_streaming(&p, &server, threads, n_stages, &native);
    }

    let mut rng = Pcg32::seeded(p.get_u64("seed"));
    let requests: Vec<Request> = (0..n_requests)
        .map(|id| Request {
            id: id as u64,
            x: Mat::randn(tokens, server.model().width(), 1.0, &mut rng),
        })
        .collect();
    let originals = requests.clone();

    let (mode, report) = if p.get_bool("sequential") {
        let mut engine = native(threads);
        ("sequential", server.run_sequential(requests, &mut engine)?)
    } else {
        let engines: Vec<Box<dyn ExecBackend + Send>> = (0..n_stages)
            .map(|_| Box::new(native(threads)) as Box<dyn ExecBackend + Send>)
            .collect();
        ("pipelined", server.run_pipelined(requests, engines)?)
    };

    println!(
        "served {n_requests} requests ({} tokens) as {} micro-batches, {mode}, {threads} thread(s)/backend",
        report.total_tokens, report.n_batches
    );
    for s in &report.stage_stats {
        println!(
            "  layer {:>2}: {:>10.0} tokens/s (busy {:.4}s)",
            s.layer,
            s.tokens_per_s(),
            s.seconds
        );
    }
    println!("end-to-end: {:.4}s -> {:.0} tokens/s", report.total_seconds, report.tokens_per_s());

    // Parity vs the host dense-masked forward.
    let mut max_err = 0.0f32;
    for ((id, got), req) in report.outputs.iter().zip(&originals) {
        anyhow::ensure!(*id == req.id, "output order mismatch: {id} vs {}", req.id);
        let want = server.model().dense_forward(&req.x, &[(0, req.x.rows())], path);
        for (a, b) in got.data().iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!("max |sparse - dense| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "serving output diverged from the dense reference");
    // Content digest over the served activations, in request order — a
    // fresh prune and a --snapshot boot of the same recipe must print
    // identical digests (the CI snapshot smoke diffs this line).
    let mut bytes = Vec::new();
    for (_, y) in &report.outputs {
        for v in y.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    println!("outputs-digest: {:016x}", permllm::snapshot::fnv1a64(&bytes));
    println!("sparse serving matches the dense-masked reference: OK");
    Ok(())
}

/// `permllm serve --trace`: replay a recorded workload trace through the
/// continuous-batching decode loop at its arrival times and print the
/// per-class SLO report ([`trace::replay`]).
fn run_serve_trace(
    p: &Parsed,
    server: &Server,
    threads: usize,
    n_stages: usize,
    native: &dyn Fn(usize) -> NativeEngine,
) -> Result<()> {
    let path = p.get("trace");
    let t = trace::Trace::load(Path::new(path))?;
    let engines: Vec<Box<dyn ExecBackend + Send>> = if p.get_bool("sequential") {
        vec![Box::new(native(threads)) as Box<dyn ExecBackend + Send>]
    } else {
        (0..n_stages).map(|_| Box::new(native(threads)) as Box<dyn ExecBackend + Send>).collect()
    };
    println!("replaying {} trace requests from {path} (seed {})", t.requests.len(), t.seed);
    let (slo, report) = trace::replay(server, engines, &t)?;
    for c in &slo.classes {
        println!(
            "  {:<13} {:>3} reqs: {} ok / {} rejected / {} timed out / {} failed / {} missed \
             deadline; first-token p50 {:.1}ms p99 {:.1}ms; per-token p50 {:.2}ms p99 {:.2}ms",
            c.class,
            c.n_requests,
            c.n_completed,
            c.n_rejected,
            c.n_timed_out,
            c.n_failed,
            c.n_deadline_missed,
            c.first_token_ms.p50,
            c.first_token_ms.p99,
            c.token_latency_ms.p50,
            c.token_latency_ms.p99
        );
    }
    println!(
        "replayed in {:.2}s: {} tokens generated, {} KV preemptions, {} CoW forks",
        slo.replay_seconds, slo.generated_tokens, slo.kv_preemptions, slo.kv_cow_forks
    );
    println!("slo-report: {}", slo.to_json().to_string());
    let out = p.get("slo-out");
    if !out.is_empty() {
        std::fs::write(out, slo.to_json().to_string() + "\n")
            .map_err(|e| anyhow!("writing --slo-out {out}: {e}"))?;
        println!("wrote SLO report to {out}");
    }
    anyhow::ensure!(slo.n_completed > 0, "trace replay completed no generations");
    anyhow::ensure!(
        report.n_failed == 0,
        "{} generations failed mid-pipeline (not a backpressure refusal)",
        report.n_failed
    );
    Ok(())
}

/// `permllm serve --stream`: drive the long-lived streaming loop with a
/// few concurrent client threads, verify per-request parity, and report
/// the loop's throughput.
fn run_serve_streaming(
    p: &Parsed,
    server: &Server,
    threads: usize,
    n_stages: usize,
    native: &dyn Fn(usize) -> NativeEngine,
) -> Result<()> {
    let n_clients = p.get_usize("stream-clients").max(1);
    let n_requests = p.get_usize("requests");
    let tokens = p.get_usize("tokens");
    let seed = p.get_u64("seed");
    let path = server.cfg().path;
    let width = server.model().width();
    let engines: Vec<Box<dyn ExecBackend + Send>> = if p.get_bool("sequential") {
        vec![Box::new(native(threads)) as Box<dyn ExecBackend + Send>]
    } else {
        (0..n_stages).map(|_| Box::new(native(threads)) as Box<dyn ExecBackend + Send>).collect()
    };
    // Client threads only submit and wait inside the timed loop; the
    // dense-reference verification (which re-materializes weights per
    // call) runs afterwards so it neither inflates the reported wall
    // clock nor steals CPU from the serving threads.
    let (outputs, report) = server.run_streaming(engines, |client| {
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for c in 0..n_clients {
                let count = n_requests / n_clients + usize::from(c < n_requests % n_clients);
                handles.push(s.spawn(move || {
                    let mut rng = Pcg32::seeded(seed ^ (c as u64).wrapping_mul(0x9e37_79b9));
                    let mut in_flight = Vec::with_capacity(count);
                    for _ in 0..count {
                        let x = Mat::randn(tokens, width, 1.0, &mut rng);
                        match client.submit(x.clone()) {
                            Ok(ticket) => in_flight.push((ticket, x)),
                            // Backpressure refusals (--queue-depth) show
                            // up in the report counters, not as a panic.
                            Err(e) => log::warn!("submit refused: {e}"),
                        }
                    }
                    in_flight
                        .into_iter()
                        .filter_map(|(ticket, x)| match ticket.wait() {
                            Ok(y) => Some((y, x)),
                            Err(e) => {
                                log::warn!("request not served: {e}");
                                None
                            }
                        })
                        .collect::<Vec<(Mat, Mat)>>()
                }));
            }
            let mut outputs = Vec::new();
            for h in handles {
                outputs.extend(h.join().expect("client thread"));
            }
            outputs
        })
    })?;
    println!(
        "streamed {} requests from {n_clients} client thread(s) as {} micro-batches \
         ({} failed, {} timed out, {} rejected)",
        outputs.len(),
        report.n_batches,
        report.n_failed,
        report.n_timed_out,
        report.n_rejected
    );
    for s in &report.stage_stats {
        println!(
            "  layer {:>2}: {:>10.0} tokens/s (busy {:.4}s)",
            s.layer,
            s.tokens_per_s(),
            s.seconds
        );
    }
    println!(
        "end-to-end: {:.4}s -> {:.0} tokens/s ({} tokens)",
        report.total_seconds,
        report.tokens_per_s(),
        report.total_tokens
    );
    let lat = &report.stats.request_latency_ms;
    println!(
        "request latency: p50 {:.2}ms / p90 {:.2}ms / p99 {:.2}ms over {} samples",
        lat.p50, lat.p90, lat.p99, lat.n
    );
    let mut max_err = 0.0f32;
    for (y, x) in &outputs {
        let want = server.model().dense_forward(x, &[(0, x.rows())], path);
        for (a, b) in y.data().iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!("max |sparse - dense| = {max_err:.2e}");
    anyhow::ensure!(report.n_failed == 0, "{} requests failed", report.n_failed);
    anyhow::ensure!(max_err < 1e-3, "streamed output diverged from the dense reference");
    println!("streamed sparse serving matches the dense-masked reference: OK");
    Ok(())
}

/// Decode token-selection policy from the `--sampler` flags.  Numeric
/// values are parsed with typed errors (not the panicking `Parsed`
/// getters) so a bad `--temperature` exits with usage, like every
/// other recipe flag.
fn sampler_from_args(p: &Parsed) -> Result<Sampler> {
    fn num<T: std::str::FromStr>(p: &Parsed, key: &str, what: &str) -> Result<T> {
        p.get(key)
            .parse()
            .map_err(|_| anyhow!("--{key} must be {what}, got '{}'", p.get(key)))
    }
    let sampler = match p.get("sampler") {
        "greedy" => Sampler::Greedy,
        "top-k" | "topk" => Sampler::TopK {
            k: num(p, "top-k", "an integer >= 1")?,
            temperature: num(p, "temperature", "a number > 0")?,
            seed: num(p, "sample-seed", "an integer")?,
        },
        "top-p" | "topp" => Sampler::TopP {
            p: num(p, "top-p", "a number in (0, 1]")?,
            temperature: num(p, "temperature", "a number > 0")?,
            seed: num(p, "sample-seed", "an integer")?,
        },
        other => {
            return Err(anyhow!("unknown --sampler '{other}' (valid: greedy, top-k, top-p)"))
        }
    };
    sampler.validate().map_err(|e| anyhow!("--sampler: {e}"))?;
    Ok(sampler)
}

/// `permllm serve --decode`: KV-cached token generation through the
/// continuous-batching decode loop — concurrent client threads submit
/// random prompts with staggered generation lengths, tokens stream back
/// through their tickets, and a sample is verified against the
/// sequential KV-cached reference generator (bit-identical kernels and
/// per-request sampling RNG, so batching must not change a single
/// token, greedy or sampled).
fn run_serve_decode(
    p: &Parsed,
    server: &Server,
    threads: usize,
    n_stages: usize,
    native: &dyn Fn(usize) -> NativeEngine,
) -> Result<()> {
    let n_clients = p.get_usize("stream-clients").max(1);
    let n_requests = p.get_usize("requests");
    let prompt_len = p.get_usize("tokens").max(1);
    let max_new = p.get_usize("max-new").max(1);
    let seed = p.get_u64("seed");
    let sampler = sampler_from_args(p)?;
    let path = server.cfg().path;
    let vocab = server.model().cfg().vocab as u32;
    let engines: Vec<Box<dyn ExecBackend + Send>> = if p.get_bool("sequential") {
        vec![Box::new(native(threads)) as Box<dyn ExecBackend + Send>]
    } else {
        (0..n_stages).map(|_| Box::new(native(threads)) as Box<dyn ExecBackend + Send>).collect()
    };
    let (outputs, report) = server.run_decode_streaming(engines, |client| {
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for c in 0..n_clients {
                let count = n_requests / n_clients + usize::from(c < n_requests % n_clients);
                handles.push(s.spawn(move || {
                    let mut rng = Pcg32::seeded(seed ^ (c as u64).wrapping_mul(0x9e37_79b9));
                    let mut in_flight = Vec::with_capacity(count);
                    for i in 0..count {
                        let prompt: Vec<u32> =
                            (0..prompt_len).map(|_| rng.below(vocab)).collect();
                        // Staggered lengths exercise the rejoin pool.
                        let req = GenRequest {
                            prompt: prompt.clone(),
                            max_new_tokens: 1 + i % max_new,
                            eos: None,
                            sampler,
                        };
                        let max_new_i = req.max_new_tokens;
                        match client.submit(req) {
                            Ok(ticket) => in_flight.push((ticket, prompt, max_new_i)),
                            // Backpressure refusals (--queue-depth) show
                            // up in the report counters, not as a panic.
                            Err(e) => log::warn!("submit refused: {e}"),
                        }
                    }
                    in_flight
                        .into_iter()
                        .filter_map(|(ticket, prompt, m)| match ticket.wait() {
                            Ok(toks) => Some((toks, prompt, m)),
                            Err(e) => {
                                log::warn!("generation not served: {e}");
                                None
                            }
                        })
                        .collect::<Vec<_>>()
                }));
            }
            let mut outputs = Vec::new();
            for h in handles {
                outputs.extend(h.join().expect("client thread"));
            }
            outputs
        })
    })?;
    println!(
        "decoded {} generations from {n_clients} client thread(s) in {} step batches \
         ({} failed, {} abandoned, {} timed out, {} rejected)",
        outputs.len(),
        report.n_steps,
        report.n_failed,
        report.n_abandoned,
        report.n_timed_out,
        report.n_rejected
    );
    println!(
        "prefill {} tokens + decode {} tokens -> {} generated tokens in {:.4}s \
         ({:.0} tokens/s end-to-end, {:.0} generated/s)",
        report.prefill_tokens,
        report.decode_tokens,
        report.generated_tokens,
        report.total_seconds,
        report.tokens_per_s(),
        report.generated_per_s()
    );
    let req = &report.stats.request_latency_ms;
    let tok = &report.stats.token_latency_ms;
    println!(
        "request latency: p50 {:.2}ms / p90 {:.2}ms / p99 {:.2}ms; per-token: p50 {:.2}ms / \
         p90 {:.2}ms / p99 {:.2}ms",
        req.p50, req.p90, req.p99, tok.p50, tok.p90, tok.p99
    );
    println!(
        "KV cache: {} bytes high water ({} resident at drain)",
        report.stats.kv_high_water_bytes, report.stats.kv_bytes
    );
    if report.stats.kv_pool_pages > 0 {
        println!(
            "KV pool: {} pages ({} free at drain), shared peak {} pages, {} preemptions, \
             {} CoW forks",
            report.stats.kv_pool_pages,
            report.stats.kv_free_pages,
            report.stats.kv_shared_pages_peak,
            report.stats.kv_preemptions,
            report.stats.kv_cow_forks
        );
    }
    // Content digest over every generated token stream, in completion
    // order — deterministic for a fixed seed/sampler, so a fresh prune
    // and a --snapshot boot must print identical digests (the CI
    // snapshot smoke diffs this line).
    let mut bytes = Vec::new();
    for (toks, _, _) in &outputs {
        for t in toks {
            bytes.extend_from_slice(&t.to_le_bytes());
        }
    }
    println!("tokens-digest: {:016x}", permllm::snapshot::fnv1a64(&bytes));
    // Verify a sample against the sequential KV-cached reference (same
    // sampler, so greedy and seeded top-k/top-p must all match exactly
    // — paged or contiguous).
    let mut engine = native(threads);
    for (toks, prompt, max_new_i) in outputs.iter().take(3) {
        let want =
            server.model().generate(&mut engine, prompt, *max_new_i, None, path, sampler)?;
        anyhow::ensure!(
            toks == &want,
            "batched decode diverged from the sequential reference for prompt {prompt:?}"
        );
    }
    anyhow::ensure!(report.n_failed == 0, "{} generations failed", report.n_failed);
    println!("continuous-batched decode matches the sequential KV-cached reference: OK");
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let p = Cli::new("permllm eval", "evaluate a model: perplexity + zero-shot")
        .opt("model", "tiny-s", "model config if no params file")
        .opt("params", "", "path to .bin params")
        .opt("corpus", "c4", "perplexity corpus")
        .opt("items", "40", "items per zero-shot task")
        .opt("backend", "host", "perplexity path: host (direct forward) | native (ExecBackend lm_forward)")
        .parse_from(args)
        .map_err(|e| anyhow!(e))?;
    let ps = load_or_synth(p.get("model"), p.get("params"))?;
    let corpus = parse_corpus(&p)?;
    let ppl = match p.get("backend") {
        "host" => eval_perplexity(&ps, &corpus, 99, 8, 64),
        "native" => {
            let mut engine = NativeEngine::with_model(ps.cfg().clone());
            eval_perplexity_exec(&mut engine, &ps, &corpus, 99, 8, 64)?
        }
        other => return Err(anyhow!("unknown --backend '{other}' (valid: host, native)")),
    };
    println!("perplexity({}): {ppl:.3}", p.get("corpus"));
    let mut mean = 0.0;
    for mut task in zeroshot_suite() {
        task.n_items = p.get_usize("items");
        let acc = zeroshot_accuracy(&ps, &task, 7);
        println!("{:<10} acc = {:.2}%", task.name, acc * 100.0);
        mean += acc;
    }
    println!("{:<10} acc = {:.2}%", "Average", mean / 5.0 * 100.0);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &[String]) -> Result<()> {
    let p = Cli::new("permllm train", "pretrain the tiny LM via the train_step artifact")
        .opt("artifacts", "artifacts/tiny-m", "artifact directory")
        .opt("steps", "200", "training steps")
        .opt("corpus", "c4", "training corpus")
        .opt("out", "models/tiny-m.bin", "output params path")
        .opt("log-every", "20", "loss log cadence")
        .parse_from(args)
        .map_err(|e| anyhow!(e))?;
    let losses = permllm::coordinator::pretrain(
        Path::new(p.get("artifacts")),
        CorpusKind::parse(p.get("corpus"))
            .ok_or_else(|| anyhow!("unknown --corpus '{}' (valid: c4, wikitext2, pile)", p.get("corpus")))?,
        p.get_usize("steps"),
        p.get_usize("log-every"),
        Path::new(p.get("out")),
    )?;
    println!(
        "trained {} steps: loss {:.4} -> {:.4}; saved {}",
        losses.len(),
        losses.first().copied().unwrap_or(f32::NAN),
        losses.last().copied().unwrap_or(f32::NAN),
        p.get("out")
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &[String]) -> Result<()> {
    Err(anyhow!(
        "the train subcommand executes the AOT train_step artifact, which needs the \
         PJRT engine; rebuild with `cargo build --features pjrt` (and a real xla crate)"
    ))
}

fn cmd_backends() -> Result<()> {
    println!("native  always available; serves sinkhorn_soft_*, lcp_grad_*, sparse_fwd_*, lm_forward");
    #[cfg(feature = "pjrt")]
    println!("pjrt    compiled in; serves whatever artifacts/<model>/manifest.json lists");
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt    not compiled (rebuild with --features pjrt)");
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let p = Cli::new("permllm info", "print artifact manifest summary")
        .opt("artifacts", "artifacts/tiny-m", "artifact directory")
        .parse_from(args)
        .map_err(|e| anyhow!(e))?;
    let m = permllm::runtime::Manifest::load(Path::new(p.get("artifacts")))?;
    println!(
        "model {}: d={} layers={} heads={} ffn={} vocab={} seq={}",
        m.config.name, m.config.dim, m.config.n_layers, m.config.n_heads, m.config.ffn,
        m.config.vocab, m.config.seq_len
    );
    println!("lcp: block={} calib_rows={} pattern keep {}/{} sinkhorn={}",
        m.lcp_block, m.lcp_calib_rows, m.lcp_keep, m.lcp_m, m.sinkhorn_iters);
    println!("{} artifacts:", m.artifacts.len());
    for a in &m.artifacts {
        println!("  {:<24} kind={:<14} inputs={} outputs={}", a.name, a.kind, a.inputs.len(), a.outputs.len());
    }
    Ok(())
}
