//! Model architecture configuration (mirror of python ModelConfig).

/// Architecture hyperparameters of the tiny causal LM.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub seq_len: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// Named sizes matching `python/compile/model.py::CONFIGS`.
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        let (vocab, dim, n_layers, n_heads, ffn) = match name {
            "tiny-s" => (256, 64, 2, 2, 128),
            "tiny-m" => (256, 128, 4, 4, 256),
            "tiny-l" => (256, 192, 6, 6, 384),
            _ => return None,
        };
        Some(ModelConfig {
            name: name.to_string(),
            vocab,
            dim,
            n_layers,
            n_heads,
            ffn,
            seq_len: 128,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        })
    }

    /// Canonical flat parameter order (the artifact I/O contract; must
    /// equal `python/compile/model.py::param_names`).
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["tok_embed".to_string()];
        for l in 0..self.n_layers {
            for t in ["attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"] {
                names.push(format!("layers.{l}.{t}"));
            }
        }
        names.push("final_norm".to_string());
        names.push("lm_head".to_string());
        names
    }

    /// Shape of a named parameter (`[C_out, C_in]` for linears).
    pub fn param_shape(&self, name: &str) -> Vec<usize> {
        let (d, f, v) = (self.dim, self.ffn, self.vocab);
        if name == "tok_embed" {
            return vec![v, d];
        }
        if name == "final_norm" {
            return vec![d];
        }
        if name == "lm_head" {
            return vec![v, d];
        }
        let kind = name.rsplit('.').next().unwrap();
        match kind {
            "attn_norm" | "mlp_norm" => vec![d],
            "wq" | "wk" | "wv" | "wo" => vec![d, d],
            "w_gate" | "w_up" => vec![f, d],
            "w_down" => vec![d, f],
            _ => panic!("unknown param {name}"),
        }
    }

    /// The prunable linear layers, in forward order (embedding and head
    /// are skipped, as in the paper §5.1).
    pub fn prunable_linears(&self) -> Vec<LinearRef> {
        let mut out = Vec::new();
        for l in 0..self.n_layers {
            for kind in [
                LinearKind::Wq,
                LinearKind::Wk,
                LinearKind::Wv,
                LinearKind::Wo,
                LinearKind::WGate,
                LinearKind::WUp,
                LinearKind::WDown,
            ] {
                out.push(LinearRef { layer: l, kind });
            }
        }
        out
    }
}

/// Which linear inside a decoder layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinearKind {
    Wq,
    Wk,
    Wv,
    Wo,
    WGate,
    WUp,
    WDown,
}

impl LinearKind {
    pub fn param_suffix(&self) -> &'static str {
        match self {
            LinearKind::Wq => "wq",
            LinearKind::Wk => "wk",
            LinearKind::Wv => "wv",
            LinearKind::Wo => "wo",
            LinearKind::WGate => "w_gate",
            LinearKind::WUp => "w_up",
            LinearKind::WDown => "w_down",
        }
    }
}

/// A specific prunable linear layer in the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinearRef {
    pub layer: usize,
    pub kind: LinearKind,
}

impl LinearRef {
    pub fn param_name(&self) -> String {
        format!("layers.{}.{}", self.layer, self.kind.param_suffix())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_formula() {
        for name in ["tiny-s", "tiny-m", "tiny-l"] {
            let cfg = ModelConfig::by_name(name).unwrap();
            assert_eq!(cfg.param_names().len(), 3 + 9 * cfg.n_layers);
        }
    }

    #[test]
    fn shapes_consistent() {
        let cfg = ModelConfig::by_name("tiny-m").unwrap();
        assert_eq!(cfg.param_shape("tok_embed"), vec![256, 128]);
        assert_eq!(cfg.param_shape("layers.2.w_gate"), vec![256, 128]);
        assert_eq!(cfg.param_shape("layers.0.w_down"), vec![128, 256]);
        assert_eq!(cfg.param_shape("lm_head"), vec![256, 128]);
    }

    #[test]
    fn prunable_linears_cover_all_layers() {
        let cfg = ModelConfig::by_name("tiny-m").unwrap();
        let lins = cfg.prunable_linears();
        assert_eq!(lins.len(), 7 * 4);
        assert_eq!(lins[0].param_name(), "layers.0.wq");
        assert_eq!(lins.last().unwrap().param_name(), "layers.3.w_down");
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(ModelConfig::by_name("gpt-5").is_none());
    }
}
