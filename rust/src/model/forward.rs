//! Host forward pass (mirror of python/compile/model.py, f32).
//!
//! Used for perplexity/zero-shot evaluation of pruned models and for
//! capturing per-linear calibration activations. Numerics are pinned to
//! the `lm_forward` artifact in `tests/model_parity.rs`.

use std::collections::HashMap;

use super::config::{LinearKind, LinearRef, ModelConfig};
use super::kv::{ContigRows, KvRows, KvStore};
use super::params::ParamStore;
use crate::tensor::Mat;
use crate::util::scratch::StepArena;

/// Per-linear calibration activations captured during a forward pass:
/// the input `X` (rows = tokens) of every prunable linear layer, in
/// original channel order.
#[derive(Debug, Default)]
pub struct Captured {
    pub inputs: HashMap<LinearRef, Vec<Mat>>,
}

impl Captured {
    fn push(&mut self, r: LinearRef, x: Mat) {
        self.inputs.entry(r).or_default().push(x);
    }

    /// Concatenate all captured rows for one linear into a single `[T, C_in]`.
    pub fn stacked(&self, r: LinearRef) -> Option<Mat> {
        let mats = self.inputs.get(&r)?;
        let cols = mats[0].cols();
        let rows: usize = mats.iter().map(|m| m.rows()).sum();
        let mut out = Mat::zeros(rows, cols);
        let mut at = 0;
        for m in mats {
            for r in 0..m.rows() {
                out.row_mut(at).copy_from_slice(m.row(r));
                at += 1;
            }
        }
        Some(out)
    }
}

/// RMSNorm with gain `g: [1, d]`.  Shared with the serving subsystem's
/// dense reference path (`crate::serve`) so the two cannot drift.
pub(crate) fn rmsnorm(x: &Mat, g: &Mat, eps: f32) -> Mat {
    let mut out = Mat::zeros(x.rows(), x.cols());
    rmsnorm_into(x, g, eps, &mut out);
    out
}

/// [`rmsnorm`] into arena-backed storage: same arithmetic, same element
/// order, storage drawn from (and eventually returned to) `arena`.
pub(crate) fn rmsnorm_scratch(x: &Mat, g: &Mat, eps: f32, arena: &mut StepArena) -> Mat {
    let mut out = arena.take(x.rows(), x.cols());
    rmsnorm_into(x, g, eps, &mut out);
    out
}

fn rmsnorm_into(x: &Mat, g: &Mat, eps: f32, out: &mut Mat) {
    let (t, d) = x.shape();
    debug_assert_eq!(out.shape(), (t, d));
    for r in 0..t {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(r);
        for c in 0..d {
            orow[c] = row[c] * inv * g[(0, c)];
        }
    }
}

/// SwiGLU gate: `silu(gate) ⊙ up`, elementwise.  Shared with the serving
/// subsystem's dense reference path so the two cannot drift.
pub(crate) fn swiglu(gate: &Mat, up: &Mat) -> Mat {
    let mut out = Mat::zeros(gate.rows(), gate.cols());
    swiglu_into(gate, up, &mut out);
    out
}

/// [`swiglu`] into arena-backed storage (same arithmetic, same order).
pub(crate) fn swiglu_scratch(gate: &Mat, up: &Mat, arena: &mut StepArena) -> Mat {
    let mut out = arena.take(gate.rows(), gate.cols());
    swiglu_into(gate, up, &mut out);
    out
}

fn swiglu_into(gate: &Mat, up: &Mat, out: &mut Mat) {
    assert_eq!(gate.shape(), up.shape());
    debug_assert_eq!(out.shape(), gate.shape());
    for (o, (&g, &u)) in out.data_mut().iter_mut().zip(gate.data().iter().zip(up.data())) {
        let silu = g / (1.0 + (-g).exp());
        *o = silu * u;
    }
}

/// Split-half RoPE applied in place to `[T, H*hd]` laid out head-major;
/// row `r` is sequence position `r`.  Shared with the serving subsystem's
/// attention path (`crate::serve`) so the reference forward and the
/// sparse serving path cannot drift.
pub(crate) fn rope(x: &mut Mat, n_heads: usize, theta: f32) {
    rope_at(x, n_heads, theta, 0);
}

/// [`rope`] with a position offset: row `r` is sequence position
/// `pos0 + r`.  The incremental decode path rotates the new rows of a
/// partially-cached sequence with exactly the angles the full-sequence
/// forward would use, so cached and re-computed keys are bit-identical.
pub(crate) fn rope_at(x: &mut Mat, n_heads: usize, theta: f32, pos0: usize) {
    let (t, d) = x.shape();
    let hd = d / n_heads;
    let half = hd / 2;
    for p in 0..t {
        let row = x.row_mut(p);
        for h in 0..n_heads {
            let base = h * hd;
            for i in 0..half {
                let freq = theta.powf(-(i as f32) * 2.0 / hd as f32);
                let ang = (pos0 + p) as f32 * freq;
                let (sin, cos) = ang.sin_cos();
                let a = row[base + i];
                let b = row[base + half + i];
                row[base + i] = a * cos - b * sin;
                row[base + half + i] = b * cos + a * sin;
            }
        }
    }
}

/// Per-head causal softmax attention over ONE sequence: `q`/`k`/`v` are
/// `[T, H*hd]` head-major with RoPE already applied to `q`/`k`; returns
/// the `[T, H*hd]` attention mix (the input of `W_o`).  Scale is
/// `1/sqrt(hd)`.  Shared with the serving subsystem's attention path
/// (`crate::serve`) so the reference forward and the sparse serving path
/// cannot drift.
pub(crate) fn causal_attention(q: &Mat, k: &Mat, v: &Mat, n_heads: usize) -> Mat {
    let (t, d) = q.shape();
    assert_eq!(k.shape(), (t, d), "q/k shape mismatch");
    assert_eq!(v.shape(), (t, d), "q/v shape mismatch");
    causal_attention_offset(q, k.data(), v.data(), n_heads, 0)
}

/// [`causal_attention`] generalized to a partially-cached sequence: `q`
/// holds only the `T_new` *new* rows (already rotated at their absolute
/// positions `offset..offset+T_new`), while `k`/`v` hold the full
/// `offset + T_new` rows (cache plus new) as flat row-major
/// `[(offset+T_new) * d]` slices — borrowed straight from the KV cache,
/// so the decode hot path copies nothing.  Query row `i` attends over
/// key rows `0..=offset+i` — with `offset == 0` this is exactly the
/// full-sequence loop, term order and all, so the two paths are
/// bit-identical where they overlap.
pub(crate) fn causal_attention_offset(
    q: &Mat,
    k: &[f32],
    v: &[f32],
    n_heads: usize,
    offset: usize,
) -> Mat {
    let (t_new, d) = q.shape();
    let t_all = offset + t_new;
    assert_eq!(k.len(), t_all * d, "q/k shape mismatch");
    assert_eq!(v.len(), t_all * d, "q/v shape mismatch");
    causal_attention_rows(q, &ContigRows { k, v, dim: d }, n_heads, offset)
}

/// The attention inner loop, generic over the cached K/V layout
/// ([`KvRows`]): each key/value row is a contiguous `dim`-wide slice
/// whatever the storage (flat buffer or paged block table), so the
/// per-`(head, query, key)` arithmetic — term order included — is
/// byte-for-byte the loop [`causal_attention_offset`] always ran.
/// Monomorphized per layout; the paged decode path pays one slice lookup
/// per key row and no branch inside the dot-product loops.
fn causal_attention_rows<R: KvRows>(q: &Mat, rows: &R, n_heads: usize, offset: usize) -> Mat {
    let (t_new, d) = q.shape();
    let t_all = offset + t_new;
    let mut o = Mat::zeros(t_new, d);
    let mut att = vec![0.0f32; t_all];
    causal_attention_rows_into(q, rows, n_heads, offset, &mut o, &mut att);
    o
}

/// The body of [`causal_attention_rows`], writing the attention mix into
/// `o` (which must be `[T_new, d]` and all-zero — the mix accumulates)
/// using `att` (`[offset + T_new]`, fully overwritten per query) as the
/// score row.  Split out so the arena-backed hot path can run the exact
/// same loop on recycled buffers.
fn causal_attention_rows_into<R: KvRows>(
    q: &Mat,
    rows: &R,
    n_heads: usize,
    offset: usize,
    o: &mut Mat,
    att: &mut [f32],
) {
    let (t_new, d) = q.shape();
    let t_all = offset + t_new;
    debug_assert_eq!(o.shape(), (t_new, d));
    debug_assert_eq!(att.len(), t_all);
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    for head in 0..n_heads {
        let base = head * hd;
        for qi in 0..t_new {
            let qabs = offset + qi;
            let qrow = &q.row(qi)[base..base + hd];
            let mut mx = f32::NEG_INFINITY;
            for ki in 0..=qabs {
                let krow = &rows.k_row(ki)[base..base + hd];
                let mut dot = 0.0f32;
                for e in 0..hd {
                    dot += qrow[e] * krow[e];
                }
                att[ki] = dot * scale;
                mx = mx.max(att[ki]);
            }
            let mut z = 0.0f32;
            for ki in 0..=qabs {
                att[ki] = (att[ki] - mx).exp();
                z += att[ki];
            }
            let orow = o.row_mut(qi);
            for ki in 0..=qabs {
                let w = att[ki] / z;
                let vrow = &rows.v_row(ki)[base..base + hd];
                for e in 0..hd {
                    orow[base + e] += w * vrow[e];
                }
            }
        }
    }
}

/// KV-cached attention for the new rows of one sequence at one layer:
/// rotate `q`/`k` at positions `cache.pos(layer)..`, append the rotated
/// keys and the values to the cache, and attend the new queries over the
/// whole cached sequence.  This is the single attention body shared by
/// the host incremental forward ([`lm_forward_step`]) and the serving
/// subsystem's prefill/decode paths (`crate::serve`), so the reference
/// and the sparse path cannot drift.
///
/// With an empty cache this computes exactly `causal_attention(rope(q),
/// rope(k), v)` — prefill is just the `offset == 0` case.
pub(crate) fn cached_attention(
    mut q: Mat,
    mut k: Mat,
    v: Mat,
    n_heads: usize,
    theta: f32,
    cache: &mut KvStore,
    layer: usize,
) -> Mat {
    let offset = cache.pos(layer);
    rope_at(&mut q, n_heads, theta, offset);
    rope_at(&mut k, n_heads, theta, offset);
    cache.append(layer, &k, &v);
    match cache {
        KvStore::Contiguous(c) => {
            let (k_all, v_all) = c.slices(layer);
            causal_attention_offset(&q, k_all, v_all, n_heads, offset)
        }
        KvStore::Paged(p) => causal_attention_rows(&q, &p.rows(layer), n_heads, offset),
    }
}

/// [`cached_attention`] on arena storage: the attention mix and the
/// per-query score row come from `arena`, and the consumed `q`/`k`/`v`
/// (whose rows now live in the cache) are given back to it, so a
/// steady-state decode step runs this without touching the allocator.
/// Arithmetic and element order are exactly [`cached_attention`]'s.
pub(crate) fn cached_attention_scratch(
    mut q: Mat,
    mut k: Mat,
    v: Mat,
    n_heads: usize,
    theta: f32,
    cache: &mut KvStore,
    layer: usize,
    arena: &mut StepArena,
) -> Mat {
    let offset = cache.pos(layer);
    rope_at(&mut q, n_heads, theta, offset);
    rope_at(&mut k, n_heads, theta, offset);
    cache.append(layer, &k, &v);
    let (t_new, d) = q.shape();
    // `take` zero-fills, which the accumulating mix loop requires.
    let mut o = arena.take(t_new, d);
    let mut att = arena.take_vec(offset + t_new);
    match cache {
        KvStore::Contiguous(c) => {
            let (k_all, v_all) = c.slices(layer);
            let rows = ContigRows { k: k_all, v: v_all, dim: d };
            causal_attention_rows_into(&q, &rows, n_heads, offset, &mut o, &mut att);
        }
        KvStore::Paged(p) => {
            causal_attention_rows_into(&q, &p.rows(layer), n_heads, offset, &mut o, &mut att);
        }
    }
    arena.give_vec(att);
    arena.give(q);
    arena.give(k);
    arena.give(v);
    o
}

/// Forward one sequence with optional activation capture.
/// `tokens`: token ids; returns logits `[T, vocab]`.
fn forward_seq(
    cfg: &ModelConfig,
    ps: &ParamStore,
    tokens: &[u8],
    capture: Option<&mut Captured>,
) -> Mat {
    let t = tokens.len();
    let (d, h) = (cfg.dim, cfg.n_heads);
    let mut cap = capture;

    // Embedding lookup.
    let embed = ps.get("tok_embed");
    let mut x = Mat::zeros(t, d);
    for (r, &tok) in tokens.iter().enumerate() {
        x.row_mut(r).copy_from_slice(embed.row(tok as usize));
    }

    for l in 0..cfg.n_layers {
        let name = |s: &str| format!("layers.{l}.{s}");
        // ---- attention ----
        let a = rmsnorm(&x, ps.get(&name("attn_norm")), cfg.norm_eps);
        if let Some(c) = cap.as_deref_mut() {
            for kind in [LinearKind::Wq, LinearKind::Wk, LinearKind::Wv] {
                c.push(LinearRef { layer: l, kind }, a.clone());
            }
        }
        let mut q = a.matmul_bt(ps.get(&name("wq")));
        let mut k = a.matmul_bt(ps.get(&name("wk")));
        let v = a.matmul_bt(ps.get(&name("wv")));
        rope(&mut q, h, cfg.rope_theta);
        rope(&mut k, h, cfg.rope_theta);

        let o = causal_attention(&q, &k, &v, h);
        if let Some(c) = cap.as_deref_mut() {
            c.push(LinearRef { layer: l, kind: LinearKind::Wo }, o.clone());
        }
        let att_out = o.matmul_bt(ps.get(&name("wo")));
        x = x.add(&att_out);

        // ---- MLP (SwiGLU) ----
        let m = rmsnorm(&x, ps.get(&name("mlp_norm")), cfg.norm_eps);
        if let Some(c) = cap.as_deref_mut() {
            for kind in [LinearKind::WGate, LinearKind::WUp] {
                c.push(LinearRef { layer: l, kind }, m.clone());
            }
        }
        let gate = m.matmul_bt(ps.get(&name("w_gate")));
        let up = m.matmul_bt(ps.get(&name("w_up")));
        let hmid = swiglu(&gate, &up);
        if let Some(c) = cap.as_deref_mut() {
            c.push(LinearRef { layer: l, kind: LinearKind::WDown }, hmid.clone());
        }
        let mlp_out = hmid.matmul_bt(ps.get(&name("w_down")));
        x = x.add(&mlp_out);
    }

    let xn = rmsnorm(&x, ps.get("final_norm"), cfg.norm_eps);
    xn.matmul_bt(ps.get("lm_head"))
}

/// Logits for a batch of sequences: returns one `[T, vocab]` per sequence.
pub fn lm_forward(ps: &ParamStore, batch: &[Vec<u8>]) -> Vec<Mat> {
    batch.iter().map(|seq| forward_seq(ps.cfg(), ps, seq, None)).collect()
}

/// Incremental (KV-cached) forward of one sequence: process only the
/// `tokens` appended since the last call, re-using `cache` for every
/// earlier position, and return the `[t_new, vocab]` logits of the new
/// rows.  The reference decode loop — feeding a sequence token by token
/// produces, row for row, the same logits as [`lm_forward`] on the full
/// sequence (`tests::incremental_forward_matches_full_recompute` pins
/// this), which is the parity bar the serving subsystem's KV-cached
/// decode path (`crate::serve`) is held to.
///
/// `cache` is a [`KvStore`] of either layout — the legacy contiguous
/// buffers ([`KvStore::contiguous`]) or a pool-backed paged store
/// ([`KvStore::paged`], funded by the caller before each step) — created
/// with this model's layer count and width and only ever fed by this
/// function for this sequence.  The two layouts are bit-identical
/// (`tests::paged_store_logits_match_contiguous_bit_for_bit`).
pub fn lm_forward_step(ps: &ParamStore, cache: &mut KvStore, tokens: &[u8]) -> Mat {
    let cfg = ps.cfg();
    assert_eq!(cache.n_layers(), cfg.n_layers, "cache layer count != model");
    assert_eq!(cache.dim(), cfg.dim, "cache width != model");
    let (t, d, h) = (tokens.len(), cfg.dim, cfg.n_heads);
    let embed = ps.get("tok_embed");
    let mut x = Mat::zeros(t, d);
    for (r, &tok) in tokens.iter().enumerate() {
        x.row_mut(r).copy_from_slice(embed.row(tok as usize));
    }
    for l in 0..cfg.n_layers {
        let name = |s: &str| format!("layers.{l}.{s}");
        let a = rmsnorm(&x, ps.get(&name("attn_norm")), cfg.norm_eps);
        let q = a.matmul_bt(ps.get(&name("wq")));
        let k = a.matmul_bt(ps.get(&name("wk")));
        let v = a.matmul_bt(ps.get(&name("wv")));
        let o = cached_attention(q, k, v, h, cfg.rope_theta, cache, l);
        x = x.add(&o.matmul_bt(ps.get(&name("wo"))));
        let m = rmsnorm(&x, ps.get(&name("mlp_norm")), cfg.norm_eps);
        let gate = m.matmul_bt(ps.get(&name("w_gate")));
        let up = m.matmul_bt(ps.get(&name("w_up")));
        let hmid = swiglu(&gate, &up);
        x = x.add(&hmid.matmul_bt(ps.get(&name("w_down"))));
    }
    let xn = rmsnorm(&x, ps.get("final_norm"), cfg.norm_eps);
    xn.matmul_bt(ps.get("lm_head"))
}

/// Forward with calibration capture over a batch.
pub fn forward_captured(ps: &ParamStore, batch: &[Vec<u8>]) -> (Vec<Mat>, Captured) {
    let mut cap = Captured::default();
    let logits = batch
        .iter()
        .map(|seq| forward_seq(ps.cfg(), ps, seq, Some(&mut cap)))
        .collect();
    (logits, cap)
}

/// Mean next-token cross-entropy (nats) over a batch.
pub fn lm_loss(ps: &ParamStore, batch: &[Vec<u8>]) -> f64 {
    let mut total = 0.0f64;
    let mut count = 0usize;
    for seq in batch {
        let logits = forward_seq(ps.cfg(), ps, seq, None);
        for pos in 0..seq.len() - 1 {
            let row = logits.row(pos);
            let target = seq[pos + 1] as usize;
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = row.iter().map(|v| (v - mx).exp()).sum();
            total += -((row[target] - mx) as f64 - (z as f64).ln());
            count += 1;
        }
    }
    total / count as f64
}

/// Perplexity = exp(mean NLL).
pub fn perplexity(ps: &ParamStore, batch: &[Vec<u8>]) -> f64 {
    lm_loss(ps, batch).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tiny() -> (ModelConfig, ParamStore) {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let mut rng = Pcg32::seeded(1);
        let ps = ParamStore::init(&cfg, &mut rng);
        (cfg, ps)
    }

    fn seq(rng: &mut Pcg32, n: usize) -> Vec<u8> {
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    #[test]
    fn logits_shape_and_finite() {
        let (cfg, ps) = tiny();
        let mut rng = Pcg32::seeded(2);
        let s = seq(&mut rng, 16);
        let logits = lm_forward(&ps, &[s]);
        assert_eq!(logits[0].shape(), (16, cfg.vocab));
        assert!(logits[0].data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_future_token_does_not_change_past() {
        let (_, ps) = tiny();
        let mut rng = Pcg32::seeded(3);
        let mut s1 = seq(&mut rng, 12);
        let mut s2 = s1.clone();
        s2[11] = s2[11].wrapping_add(1);
        let l1 = lm_forward(&ps, &[s1.clone()]);
        let l2 = lm_forward(&ps, &[s2.clone()]);
        for pos in 0..11 {
            crate::util::testkit::assert_close(l1[0].row(pos), l2[0].row(pos), 1e-5).unwrap();
        }
        // last position differs (different input token at 11)
        let diff: f32 = l1[0]
            .row(11)
            .iter()
            .zip(l2[0].row(11))
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-3);
        s1.clear();
        let _ = s1;
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        let (cfg, ps) = tiny();
        let mut rng = Pcg32::seeded(4);
        let batch: Vec<Vec<u8>> = (0..4).map(|_| seq(&mut rng, 32)).collect();
        let ppl = perplexity(&ps, &batch);
        // Random init => close to uniform over 256 tokens.
        assert!(ppl > cfg.vocab as f64 * 0.3 && ppl < cfg.vocab as f64 * 3.0, "ppl {ppl}");
    }

    #[test]
    fn causal_attention_first_row_is_its_own_value() {
        // Position 0 attends only to itself, so its output is exactly v[0]
        // for every head — a direct invariant of the shared attention glue.
        let mut rng = Pcg32::seeded(8);
        let (t, heads, d) = (5usize, 2usize, 8usize);
        let q = Mat::randn(t, d, 1.0, &mut rng);
        let k = Mat::randn(t, d, 1.0, &mut rng);
        let v = Mat::randn(t, d, 1.0, &mut rng);
        let o = causal_attention(&q, &k, &v, heads);
        crate::util::testkit::assert_close(o.row(0), v.row(0), 1e-6).unwrap();
        assert!(o.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn incremental_forward_matches_full_recompute() {
        // Prefill a prompt, then feed one token at a time: every new row's
        // logits must match the full-sequence forward bit-for-bit in spirit
        // (same ops in the same order; tolerance only guards libm).
        let (cfg, ps) = tiny();
        let mut rng = Pcg32::seeded(9);
        let s = seq(&mut rng, 12);
        let full = &lm_forward(&ps, &[s.clone()])[0];
        let mut cache = KvStore::contiguous(cfg.n_layers, cfg.dim);
        let prefill = lm_forward_step(&ps, &mut cache, &s[..5]);
        assert_eq!(prefill.shape(), (5, cfg.vocab));
        for pos in 0..5 {
            crate::util::testkit::assert_close(prefill.row(pos), full.row(pos), 1e-5)
                .unwrap_or_else(|e| panic!("prefill row {pos}: {e}"));
        }
        assert_eq!(cache.len(), 5);
        for pos in 5..12 {
            let step = lm_forward_step(&ps, &mut cache, &s[pos..pos + 1]);
            assert_eq!(step.shape(), (1, cfg.vocab));
            crate::util::testkit::assert_close(step.row(0), full.row(pos), 1e-5)
                .unwrap_or_else(|e| panic!("decode row {pos}: {e}"));
        }
        assert_eq!(cache.len(), 12);
        // Memory accounting: K + V, every layer, every position.
        assert_eq!(cache.bytes(), 2 * cfg.n_layers * 12 * cfg.dim * 4);
    }

    #[test]
    fn chunked_prefill_matches_whole_prefill() {
        // Prefilling in two chunks is the same computation as one chunk —
        // the cache offset carries the RoPE positions across the split.
        let (cfg, ps) = tiny();
        let mut rng = Pcg32::seeded(10);
        let s = seq(&mut rng, 9);
        let mut whole = KvStore::contiguous(cfg.n_layers, cfg.dim);
        let all = lm_forward_step(&ps, &mut whole, &s);
        let mut chunked = KvStore::contiguous(cfg.n_layers, cfg.dim);
        let head = lm_forward_step(&ps, &mut chunked, &s[..4]);
        let tail = lm_forward_step(&ps, &mut chunked, &s[4..]);
        for pos in 0..4 {
            assert_eq!(head.row(pos), all.row(pos), "chunk A row {pos}");
        }
        for pos in 4..9 {
            assert_eq!(tail.row(pos - 4), all.row(pos), "chunk B row {pos}");
        }
    }

    #[test]
    fn paged_store_logits_match_contiguous_bit_for_bit() {
        // Property test over random prompt/decode schedules and page
        // sizes: feeding the same chunks through a contiguous store and
        // a pool-backed paged store must produce byte-identical logits
        // at every step — the layout changes where K/V rows live, never
        // a single arithmetic term.
        use super::super::kv::KvPool;
        let (cfg, ps) = tiny();
        let mut rng = Pcg32::seeded(12);
        for round in 0..3 {
            let total = 8 + rng.below(8) as usize;
            let s = seq(&mut rng, total);
            let pt = 1 + rng.below(5) as usize;
            let pool = KvPool::new(128, pt, cfg.n_layers, cfg.dim);
            let mut contig = KvStore::contiguous(cfg.n_layers, cfg.dim);
            let mut paged = KvStore::paged(pool.new_cache());
            let mut at = 0usize;
            while at < total {
                let hi = (at + 1 + rng.below(4) as usize).min(total);
                let chunk = &s[at..hi];
                let p = paged.as_paged_mut().unwrap();
                let need = p.pages_for(chunk.len());
                p.fund(pool.reserve(need).expect("pool sized amply"));
                let a = lm_forward_step(&ps, &mut contig, chunk);
                let b = lm_forward_step(&ps, &mut paged, chunk);
                assert_eq!(a.data(), b.data(), "round {round} pt {pt} rows {at}..{hi}");
                at = hi;
            }
            assert_eq!(paged.len(), total);
            drop(paged);
            assert_eq!(pool.free_pages(), 128, "all pages recycled on drop");
        }
    }

    #[test]
    fn rope_at_offsets_match_full_rotation() {
        // Rotating rows [3..7) of a sequence at offset 3 equals rows
        // [3..7) of rotating the whole sequence.
        let mut rng = Pcg32::seeded(11);
        let (heads, d) = (2usize, 8usize);
        let full0 = Mat::randn(7, d, 1.0, &mut rng);
        let mut full = full0.clone();
        rope(&mut full, heads, 10000.0);
        let mut tail = full0.row_block(3, 7);
        rope_at(&mut tail, heads, 10000.0, 3);
        for r in 0..4 {
            assert_eq!(tail.row(r), full.row(3 + r), "row {r}");
        }
    }

    #[test]
    fn capture_collects_every_prunable_linear() {
        let (cfg, ps) = tiny();
        let mut rng = Pcg32::seeded(5);
        let batch: Vec<Vec<u8>> = (0..2).map(|_| seq(&mut rng, 8)).collect();
        let (_, cap) = forward_captured(&ps, &batch);
        for lin in cfg.prunable_linears() {
            let x = cap.stacked(lin).unwrap_or_else(|| panic!("missing {lin:?}"));
            assert_eq!(x.rows(), 16, "{lin:?}");
            let want_cols = cfg.param_shape(&lin.param_name())[1];
            assert_eq!(x.cols(), want_cols, "{lin:?}");
        }
    }

    #[test]
    fn capture_inputs_match_layer_weights() {
        // x @ W^T must be computable for every captured pair.
        let (cfg, ps) = tiny();
        let mut rng = Pcg32::seeded(6);
        let batch = vec![seq(&mut rng, 8)];
        let (_, cap) = forward_captured(&ps, &batch);
        for lin in cfg.prunable_linears() {
            let x = cap.stacked(lin).unwrap();
            let w = ps.get(&lin.param_name());
            let y = x.matmul_bt(w);
            assert_eq!(y.shape(), (8, w.rows()));
        }
    }
}
