//! Per-sequence key/value cache for incremental (KV-cached) decoding.
//!
//! Autoregressive decode re-uses the attention keys and values of every
//! already-processed position instead of re-running the full sequence:
//! each forward step appends the *rotated* keys (RoPE already applied at
//! the row's absolute position) and the values for the new rows, and the
//! next step's queries attend over the whole cache.  One [`KvCache`]
//! holds one sequence's K/V for **every** decoder layer, so a request
//! carries a single cache object through the serving pipeline
//! (`crate::serve`) or the host reference forward
//! ([`crate::model::lm_forward_step`]).

use crate::tensor::Mat;

/// Cached K/V rows for one sequence, all decoder layers.
///
/// Keys are stored **post-RoPE**: row `p` of layer `l`'s key buffer was
/// rotated at absolute position `p` when it was appended, so appending is
/// the only write the cache ever needs — no re-rotation on later steps.
/// Between forward passes every layer holds the same number of positions;
/// mid-pass (e.g. inside a pipelined stage chain) layers advance
/// independently, which is why the position offset is per layer
/// ([`KvCache::pos`]).
#[derive(Debug, Clone)]
pub struct KvCache {
    dim: usize,
    /// Per-layer rotated keys, `pos(layer) * dim` values each.
    k: Vec<Vec<f32>>,
    /// Per-layer values, `pos(layer) * dim` values each.
    v: Vec<Vec<f32>>,
}

impl KvCache {
    /// Empty cache for a model with `n_layers` decoder layers of
    /// activation width `dim`.
    pub fn new(n_layers: usize, dim: usize) -> KvCache {
        KvCache { dim, k: vec![Vec::new(); n_layers], v: vec![Vec::new(); n_layers] }
    }

    /// Decoder layers this cache covers.
    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Activation width (`n_heads * head_dim`).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Positions cached at `layer` — the RoPE offset of the next row
    /// appended to that layer.
    pub fn pos(&self, layer: usize) -> usize {
        self.k[layer].len() / self.dim
    }

    /// Sequence length cached so far (positions at layer 0; all layers
    /// agree between forward passes).
    pub fn len(&self) -> usize {
        if self.k.is_empty() {
            0
        } else {
            self.pos(0)
        }
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident cache footprint in bytes (f32 K + V across every layer)
    /// — the decode-time analogue of `SparseModel::storage_bytes` for
    /// memory accounting.
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|b| b.len() * 4).sum()
    }

    /// What [`KvCache::bytes`] returns once `positions` rows are cached
    /// at every layer — the closed form serving-memory accounting (and
    /// its tests) check observed residency against.
    pub fn bytes_for(n_layers: usize, dim: usize, positions: usize) -> usize {
        2 * n_layers * positions * dim * 4
    }

    /// Append `[t_new, dim]` rotated keys and values for `layer`.
    pub fn append(&mut self, layer: usize, k_rows: &Mat, v_rows: &Mat) {
        assert_eq!(k_rows.cols(), self.dim, "key width != cache dim");
        assert_eq!(v_rows.cols(), self.dim, "value width != cache dim");
        assert_eq!(k_rows.rows(), v_rows.rows(), "k/v row count mismatch");
        self.k[layer].extend_from_slice(k_rows.data());
        self.v[layer].extend_from_slice(v_rows.data());
    }

    /// Borrow the full cached K and V of `layer` as flat row-major
    /// `[pos * dim]` slices — the attention hot path reads these in
    /// place; nothing is copied per decode step.
    pub fn slices(&self, layer: usize) -> (&[f32], &[f32]) {
        (&self.k[layer], &self.v[layer])
    }

    /// The full cached `([pos, dim]` K, `[pos, dim]` V)` of `layer` as
    /// host matrices (copies — for inspection/tests; the serving path
    /// uses [`KvCache::slices`]).
    pub fn mats(&self, layer: usize) -> (Mat, Mat) {
        let rows = self.pos(layer);
        (
            Mat::from_vec(rows, self.dim, self.k[layer].clone()),
            Mat::from_vec(rows, self.dim, self.v[layer].clone()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn append_grows_positions_and_bytes() {
        let mut rng = Pcg32::seeded(3);
        let mut cache = KvCache::new(2, 4);
        assert!(cache.is_empty());
        assert_eq!(cache.bytes(), 0);
        let k = Mat::randn(3, 4, 1.0, &mut rng);
        let v = Mat::randn(3, 4, 1.0, &mut rng);
        cache.append(0, &k, &v);
        assert_eq!(cache.pos(0), 3);
        assert_eq!(cache.pos(1), 0, "layers advance independently");
        assert_eq!(cache.len(), 3);
        cache.append(1, &k, &v);
        // 2 layers x (K + V) x 3 rows x 4 cols x 4 bytes.
        assert_eq!(cache.bytes(), 2 * 2 * 3 * 4 * 4);
        assert_eq!(cache.bytes(), KvCache::bytes_for(2, 4, 3));
        let (km, vm) = cache.mats(0);
        assert_eq!(km.data(), k.data());
        assert_eq!(vm.data(), v.data());
        // A second append concatenates below the first.
        let k2 = Mat::randn(1, 4, 1.0, &mut rng);
        let v2 = Mat::randn(1, 4, 1.0, &mut rng);
        cache.append(0, &k2, &v2);
        let (km, _) = cache.mats(0);
        assert_eq!(km.rows(), 4);
        assert_eq!(&km.data()[3 * 4..], k2.data());
    }

    #[test]
    #[should_panic(expected = "key width != cache dim")]
    fn wrong_width_is_rejected() {
        let mut cache = KvCache::new(1, 4);
        cache.append(0, &Mat::zeros(1, 5), &Mat::zeros(1, 5));
    }
}
