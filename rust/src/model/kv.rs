//! Per-sequence key/value storage for incremental (KV-cached) decoding
//! — contiguous per-request buffers and the paged, pooled layout behind
//! one [`KvStore`] interface.
//!
//! Autoregressive decode re-uses the attention keys and values of every
//! already-processed position instead of re-running the full sequence:
//! each forward step appends the *rotated* keys (RoPE already applied at
//! the row's absolute position) and the values for the new rows, and the
//! next step's queries attend over the whole cache.  Two layouts provide
//! that contract:
//!
//! * [`KvCache`] — the legacy contiguous layout: one growable flat
//!   buffer per layer, owned by one request.  Simple, zero bookkeeping,
//!   unbounded growth.
//! * [`PagedKvCache`] over a shared [`KvPool`] — fixed-size pages
//!   (`page_tokens x dim` of K and of V per layer), a pool-wide free
//!   list, and a per-request per-layer block table.  Requests admit by
//!   *free pages*, pages return to the pool the moment the last holder
//!   drops them, and concurrent requests with a common prompt prefix can
//!   share refcounted prefill pages ([`KvPool::lookup_prefix`] /
//!   [`KvPool::publish_prefix`]) copy-on-write style: shared pages are
//!   always full, so a diverging request simply starts appending into
//!   its own pages — a metadata-only fork.
//!
//! [`KvStore`] wraps either layout behind the `KvCache`-shaped API so
//! the attention glue ([`crate::model::lm_forward_step`], the serving
//! subsystem's `cached_attention` path) is layout-agnostic, and the
//! paged read path hands out per-row slices (each K/V row lives entirely
//! inside one page) so the attention inner loop runs the *identical*
//! arithmetic in the identical order — paged and contiguous decode are
//! bit-identical, which the layout-equivalence tests pin.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::tensor::Mat;

/// Cached K/V rows for one sequence, all decoder layers.
///
/// Keys are stored **post-RoPE**: row `p` of layer `l`'s key buffer was
/// rotated at absolute position `p` when it was appended, so appending is
/// the only write the cache ever needs — no re-rotation on later steps.
/// Between forward passes every layer holds the same number of positions;
/// mid-pass (e.g. inside a pipelined stage chain) layers advance
/// independently, which is why the position offset is per layer
/// ([`KvCache::pos`]).
#[derive(Debug, Clone)]
pub struct KvCache {
    dim: usize,
    /// Per-layer rotated keys, `pos(layer) * dim` values each.
    k: Vec<Vec<f32>>,
    /// Per-layer values, `pos(layer) * dim` values each.
    v: Vec<Vec<f32>>,
}

impl KvCache {
    /// Empty cache for a model with `n_layers` decoder layers of
    /// activation width `dim`.
    pub fn new(n_layers: usize, dim: usize) -> KvCache {
        KvCache { dim, k: vec![Vec::new(); n_layers], v: vec![Vec::new(); n_layers] }
    }

    /// Decoder layers this cache covers.
    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Activation width (`n_heads * head_dim`).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Positions cached at `layer` — the RoPE offset of the next row
    /// appended to that layer.
    pub fn pos(&self, layer: usize) -> usize {
        self.k[layer].len() / self.dim
    }

    /// Sequence length cached so far (positions at layer 0; all layers
    /// agree between forward passes).
    pub fn len(&self) -> usize {
        if self.k.is_empty() {
            0
        } else {
            self.pos(0)
        }
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident cache footprint in bytes (f32 K + V across every layer)
    /// — the decode-time analogue of `SparseModel::storage_bytes` for
    /// memory accounting.
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|b| b.len() * 4).sum()
    }

    /// What [`KvCache::bytes`] returns once `positions` rows are cached
    /// at every layer — the closed form serving-memory accounting (and
    /// its tests) check observed residency against.
    pub fn bytes_for(n_layers: usize, dim: usize, positions: usize) -> usize {
        2 * n_layers * positions * dim * 4
    }

    /// Pre-reserve capacity for `extra` more positions at every layer, so
    /// the `append`s of the next `extra` decode steps cannot reallocate —
    /// the zero-alloc hot path calls this once before a measured run.
    pub fn reserve(&mut self, extra: usize) {
        let n = extra * self.dim;
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            buf.reserve(n);
        }
    }

    /// Append `[t_new, dim]` rotated keys and values for `layer`.
    pub fn append(&mut self, layer: usize, k_rows: &Mat, v_rows: &Mat) {
        assert_eq!(k_rows.cols(), self.dim, "key width != cache dim");
        assert_eq!(v_rows.cols(), self.dim, "value width != cache dim");
        assert_eq!(k_rows.rows(), v_rows.rows(), "k/v row count mismatch");
        self.k[layer].extend_from_slice(k_rows.data());
        self.v[layer].extend_from_slice(v_rows.data());
    }

    /// Borrow the full cached K and V of `layer` as flat row-major
    /// `[pos * dim]` slices — the attention hot path reads these in
    /// place; nothing is copied per decode step.
    pub fn slices(&self, layer: usize) -> (&[f32], &[f32]) {
        (&self.k[layer], &self.v[layer])
    }

    /// The full cached `([pos, dim]` K, `[pos, dim]` V)` of `layer` as
    /// host matrices (copies — for inspection/tests; the serving path
    /// uses [`KvCache::slices`]).
    pub fn mats(&self, layer: usize) -> (Mat, Mat) {
        let rows = self.pos(layer);
        (
            Mat::from_vec(rows, self.dim, self.k[layer].clone()),
            Mat::from_vec(rows, self.dim, self.v[layer].clone()),
        )
    }
}

/// Row access into one layer's cached K/V, whatever the layout — the
/// single read interface the attention inner loop is generic over.  Each
/// row is a contiguous `dim`-wide slice (pages never split a row), so
/// the per-`(head, query, key)` arithmetic is identical across layouts.
pub(crate) trait KvRows {
    /// Rotated key row `i` (`dim` floats).
    fn k_row(&self, i: usize) -> &[f32];
    /// Value row `i` (`dim` floats).
    fn v_row(&self, i: usize) -> &[f32];
}

/// [`KvRows`] over the contiguous flat slices of a [`KvCache`] layer.
pub(crate) struct ContigRows<'a> {
    pub(crate) k: &'a [f32],
    pub(crate) v: &'a [f32],
    pub(crate) dim: usize,
}

impl KvRows for ContigRows<'_> {
    #[inline]
    fn k_row(&self, i: usize) -> &[f32] {
        &self.k[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    fn v_row(&self, i: usize) -> &[f32] {
        &self.v[i * self.dim..(i + 1) * self.dim]
    }
}

/// One page worth of K and V for one layer: `page_tokens * dim` floats
/// each, preallocated once by the pool and recycled for the pool's
/// lifetime.
#[derive(Debug)]
pub(crate) struct KvBuf {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// A page checked out of a [`KvPool`].  Dropping the last handle returns
/// the underlying buffer to the pool's free list automatically, so
/// owned, shared, and registry-held pages all account themselves —
/// there is no explicit free call to forget.
#[derive(Debug)]
pub struct PooledPage {
    buf: Option<KvBuf>,
    pool: Weak<KvPool>,
}

impl PooledPage {
    fn k(&self) -> &[f32] {
        &self.buf.as_ref().expect("page buffer present until drop").k
    }

    fn v(&self) -> &[f32] {
        &self.buf.as_ref().expect("page buffer present until drop").v
    }
}

impl Drop for PooledPage {
    fn drop(&mut self) {
        if let (Some(buf), Some(pool)) = (self.buf.take(), self.pool.upgrade()) {
            pool.give_back(vec![buf]);
        }
    }
}

/// A published prompt prefix: per-layer chains of full, immutable pages
/// plus the exact tokens they cover (stored so a hash collision can
/// never alias two different prompts).
struct PrefixEntry {
    tokens: Vec<u32>,
    /// `pages[layer][i]` — refcounted, always-full pages.
    pages: Vec<Vec<Arc<PooledPage>>>,
}

struct PoolState {
    free: Vec<KvBuf>,
    registry: HashMap<u64, PrefixEntry>,
}

/// A shared-prefix match from [`KvPool::lookup_prefix`]: the adopter
/// clones these page handles into its own block table instead of
/// re-prefilling the covered tokens.
pub struct SharedPrefix {
    /// Prompt tokens the shared pages cover (a multiple of
    /// [`KvPool::page_tokens`]).
    pub tokens_covered: usize,
    pages: Vec<Vec<Arc<PooledPage>>>,
}

/// Fixed-capacity paged KV allocator shared by every in-flight request
/// of one decode loop: `n_pages` pages of `page_tokens x dim` K and V
/// (per layer a request touches), a free list, and a refcounted
/// prefix-sharing registry.
///
/// The serving scheduler admits work by free pages ([`KvPool::reserve`]
/// is all-or-nothing) and preempts the youngest generation when the pool
/// is exhausted mid-decode; pages return to the free list automatically
/// when their last holder drops ([`PooledPage`]).
///
/// ```
/// use permllm::model::{KvPool, KvStore};
/// use permllm::tensor::Mat;
///
/// // 8 pages of 4 tokens x 2 channels, for a 1-layer model.
/// let pool = KvPool::new(8, 4, 1, 2);
/// let mut store = KvStore::paged(pool.new_cache());
/// let paged = store.as_paged_mut().unwrap();
/// let need = paged.pages_for(6); // 6 rows cross 2 page boundaries
/// assert_eq!(need, 2);
/// paged.fund(pool.reserve(need).unwrap());
/// store.append(0, &Mat::zeros(6, 2), &Mat::zeros(6, 2));
/// assert_eq!((store.len(), pool.free_pages()), (6, 6));
/// drop(store); // pages return to the free list automatically
/// assert_eq!(pool.free_pages(), 8);
/// ```
pub struct KvPool {
    n_pages: usize,
    page_tokens: usize,
    n_layers: usize,
    dim: usize,
    state: Mutex<PoolState>,
    /// Gauges/counters, readable without the state lock.
    free_pages: AtomicUsize,
    shared_pages_peak: AtomicUsize,
    preemptions: AtomicUsize,
    cow_forks: AtomicUsize,
}

impl KvPool {
    /// Allocate a pool of `n_pages` pages up front (each holding
    /// `page_tokens * dim` K floats and as many V floats) for a model
    /// with `n_layers` cached decoder layers of width `dim`.
    pub fn new(n_pages: usize, page_tokens: usize, n_layers: usize, dim: usize) -> Arc<KvPool> {
        assert!(n_pages > 0, "KvPool needs at least one page");
        assert!(page_tokens > 0, "KvPool pages hold at least one token");
        assert!(dim > 0, "KvPool needs a nonzero width");
        let free = (0..n_pages)
            .map(|_| KvBuf {
                k: vec![0.0; page_tokens * dim],
                v: vec![0.0; page_tokens * dim],
            })
            .collect();
        Arc::new(KvPool {
            n_pages,
            page_tokens,
            n_layers,
            dim,
            state: Mutex::new(PoolState { free, registry: HashMap::new() }),
            free_pages: AtomicUsize::new(n_pages),
            shared_pages_peak: AtomicUsize::new(0),
            preemptions: AtomicUsize::new(0),
            cow_forks: AtomicUsize::new(0),
        })
    }

    /// Total pool capacity in pages.
    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    /// Tokens per page.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Cached decoder layers per request.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Activation width.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Bytes of one page (f32 K + V).
    pub fn page_bytes(&self) -> usize {
        2 * self.page_tokens * self.dim * 4
    }

    /// Pages currently on the free list.
    pub fn free_pages(&self) -> usize {
        self.free_pages.load(Ordering::Acquire)
    }

    /// Pages currently checked out (owned, shared, or reserved).
    pub fn used_pages(&self) -> usize {
        self.n_pages - self.free_pages()
    }

    /// Distinct pages currently held by the prefix-sharing registry.
    pub fn shared_pages(&self) -> usize {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        Self::distinct_registry_pages(&st)
    }

    /// High water of [`KvPool::shared_pages`] (monotone).
    pub fn shared_pages_peak(&self) -> usize {
        self.shared_pages_peak.load(Ordering::Relaxed)
    }

    /// Generations evicted for recompute because the pool ran dry.
    pub fn preemptions(&self) -> usize {
        self.preemptions.load(Ordering::Relaxed)
    }

    /// Requests that diverged from a shared prefix into pages of their
    /// own (the copy-on-write fork — metadata only, shared pages are
    /// never copied because they are always full).
    pub fn cow_forks(&self) -> usize {
        self.cow_forks.load(Ordering::Relaxed)
    }

    /// Count one preemption (called by the scheduler that evicted).
    pub fn note_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    fn note_cow_fork(&self) {
        self.cow_forks.fetch_add(1, Ordering::Relaxed);
    }

    fn distinct_registry_pages(st: &PoolState) -> usize {
        let mut seen = HashSet::new();
        for entry in st.registry.values() {
            for chain in &entry.pages {
                for page in chain {
                    seen.insert(Arc::as_ptr(page) as usize);
                }
            }
        }
        seen.len()
    }

    /// Pop `n` free pages, all or nothing.  When the free list is short,
    /// the prefix registry is evicted first (pages no request references
    /// return to the free list as their registry handles drop); `None`
    /// means the demand cannot be met even then — the caller defers or
    /// preempts.
    pub fn reserve(&self, n: usize) -> Option<Vec<KvBuf>> {
        loop {
            let evicted = {
                let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
                if st.free.len() >= n {
                    let at = st.free.len() - n;
                    let bufs = st.free.split_off(at);
                    self.free_pages.store(st.free.len(), Ordering::Release);
                    return Some(bufs);
                }
                if st.registry.is_empty() {
                    return None;
                }
                std::mem::take(&mut st.registry)
            };
            // Dropped outside the lock: each page's Drop re-enters
            // `give_back`, which takes the state mutex.
            drop(evicted);
        }
    }

    /// Return page buffers to the free list ([`PooledPage`] drops and
    /// released reservations land here).
    pub(crate) fn give_back(&self, bufs: Vec<KvBuf>) {
        if bufs.is_empty() {
            return;
        }
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.free.extend(bufs);
        debug_assert!(st.free.len() <= self.n_pages, "more pages returned than allocated");
        self.free_pages.store(st.free.len(), Ordering::Release);
    }

    /// Fresh empty paged cache drawing on this pool.  It holds no pages
    /// until [`PagedKvCache::fund`] hands it reserved ones.
    pub fn new_cache(self: &Arc<Self>) -> PagedKvCache {
        PagedKvCache {
            pool: Arc::clone(self),
            blocks: vec![Vec::new(); self.n_layers],
            len: vec![0; self.n_layers],
            reserve: Vec::new(),
            shared_prefix_pages: 0,
            forked: false,
        }
    }

    /// Longest published prefix of `tokens` (hash-matched at full-page
    /// granularity, token-verified), capped at `max_tokens` so the
    /// adopter can keep at least one uncovered suffix token to forward.
    pub fn lookup_prefix(&self, tokens: &[u32], max_tokens: usize) -> Option<SharedPrefix> {
        let cover = tokens.len().min(max_tokens) / self.page_tokens;
        if cover == 0 {
            return None;
        }
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        for pages in (1..=cover).rev() {
            let prefix = &tokens[..pages * self.page_tokens];
            if let Some(entry) = st.registry.get(&fnv1a_tokens(prefix)) {
                if entry.tokens == prefix {
                    return Some(SharedPrefix {
                        tokens_covered: prefix.len(),
                        pages: entry.pages.clone(),
                    });
                }
            }
        }
        None
    }

    /// Publish the full pages covering a prompt prefix so later requests
    /// with the same prompt can adopt them.  `pages[layer]` holds the
    /// frozen page chain ([`PagedKvCache::freeze_prefix`]); an entry is
    /// registered for every full-page sub-prefix so partial overlaps
    /// match too.  No-op for prefixes already published.
    pub fn publish_prefix(&self, tokens: &[u32], pages: &[Vec<Arc<PooledPage>>]) {
        let chain_len = pages.first().map_or(0, Vec::len);
        if chain_len == 0 {
            return;
        }
        assert!(
            tokens.len() >= chain_len * self.page_tokens,
            "prefix tokens shorter than the published pages"
        );
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        for pcount in 1..=chain_len {
            let prefix = &tokens[..pcount * self.page_tokens];
            st.registry.entry(fnv1a_tokens(prefix)).or_insert_with(|| PrefixEntry {
                tokens: prefix.to_vec(),
                pages: pages.iter().map(|chain| chain[..pcount].to_vec()).collect(),
            });
        }
        let shared = Self::distinct_registry_pages(&st);
        self.shared_pages_peak.fetch_max(shared, Ordering::Relaxed);
    }

    /// Drop every registry entry (drain/shutdown): pages no live request
    /// references return to the free list immediately.
    pub fn flush_shared(&self) {
        let evicted = {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            std::mem::take(&mut st.registry)
        };
        drop(evicted);
    }
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvPool")
            .field("n_pages", &self.n_pages)
            .field("page_tokens", &self.page_tokens)
            .field("n_layers", &self.n_layers)
            .field("dim", &self.dim)
            .field("free_pages", &self.free_pages())
            .finish()
    }
}

/// FNV-1a over the token ids' little-endian bytes — the prefix-registry
/// key (token equality is still checked on lookup, so collisions cost a
/// miss, never a wrong match).
fn fnv1a_tokens(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// One request's paged KV view: a per-layer block table of refcounted
/// pages from a shared [`KvPool`], plus a reservation stack of pages the
/// scheduler funded for the upcoming step, so [`PagedKvCache::append`]
/// never has to allocate (or fail) on the forward hot path.
///
/// Shared (prefix-adopted) pages are always full, so writes only ever
/// touch pages this request uniquely owns — a request diverging from a
/// shared prefix simply appends into a fresh page (the copy-on-write
/// fork, counted on the pool).
#[derive(Debug)]
pub struct PagedKvCache {
    pool: Arc<KvPool>,
    /// `blocks[layer][i]` covers positions `i*page_tokens..` of `layer`.
    blocks: Vec<Vec<Arc<PooledPage>>>,
    /// Positions cached per layer (layers advance independently
    /// mid-pass, like [`KvCache`]).
    len: Vec<usize>,
    /// Pages reserved for upcoming appends, not yet in any block table.
    reserve: Vec<KvBuf>,
    /// Leading pages per layer that are shared with the pool registry /
    /// other requests (never written, excluded from [`Self::bytes`]).
    shared_prefix_pages: usize,
    forked: bool,
}

/// [`KvRows`] over one layer of a [`PagedKvCache`]: row `i` lives at
/// offset `(i % page_tokens) * dim` of page `i / page_tokens`.
pub(crate) struct PagedRows<'a> {
    blocks: &'a [Arc<PooledPage>],
    page_tokens: usize,
    dim: usize,
}

impl KvRows for PagedRows<'_> {
    #[inline]
    fn k_row(&self, i: usize) -> &[f32] {
        let at = (i % self.page_tokens) * self.dim;
        &self.blocks[i / self.page_tokens].k()[at..at + self.dim]
    }

    #[inline]
    fn v_row(&self, i: usize) -> &[f32] {
        let at = (i % self.page_tokens) * self.dim;
        &self.blocks[i / self.page_tokens].v()[at..at + self.dim]
    }
}

impl PagedKvCache {
    /// The pool this cache draws on.
    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Decoder layers this cache covers.
    pub fn n_layers(&self) -> usize {
        self.blocks.len()
    }

    /// Activation width.
    pub fn dim(&self) -> usize {
        self.pool.dim
    }

    /// Positions cached at `layer`.
    pub fn pos(&self, layer: usize) -> usize {
        self.len[layer]
    }

    /// Sequence length cached so far (positions at layer 0).
    pub fn len(&self) -> usize {
        self.len.first().copied().unwrap_or(0)
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident footprint in bytes: pages this request uniquely owns
    /// plus its unspent reservation.  Shared prefix pages are excluded —
    /// they are accounted once, on the pool's shared-page gauge, not per
    /// adopter.
    pub fn bytes(&self) -> usize {
        let total: usize = self.blocks.iter().map(Vec::len).sum();
        let shared = self.shared_prefix_pages * self.blocks.len();
        (total - shared + self.reserve.len()) * self.pool.page_bytes()
    }

    /// Pages a step appending `rows` new tokens to **every** layer will
    /// need beyond what the current tables cover — what the scheduler
    /// must [`KvPool::reserve`] before dispatching the step.
    pub fn pages_for(&self, rows: usize) -> usize {
        let pt = self.pool.page_tokens;
        let before = (self.len() + pt - 1) / pt;
        let after = (self.len() + rows + pt - 1) / pt;
        (after - before) * self.blocks.len()
    }

    /// Hand this cache pages popped by [`KvPool::reserve`]; subsequent
    /// [`KvStore::append`]s consume them instead of touching the pool.
    pub fn fund(&mut self, bufs: Vec<KvBuf>) {
        self.reserve.extend(bufs);
    }

    /// Pages currently reserved but not yet appended into.
    pub fn reserve_len(&self) -> usize {
        self.reserve.len()
    }

    /// Return unspent reserved pages to the pool (end of a step that
    /// reserved more than it appended — e.g. the MLP-only path, which
    /// never caches attention).
    pub fn release_reserve(&mut self) {
        let bufs = std::mem::take(&mut self.reserve);
        self.pool.give_back(bufs);
    }

    /// Adopt a published prompt prefix: clone its page chains into this
    /// (empty) cache so prefill starts at `tokens_covered` instead of 0.
    pub fn adopt_prefix(&mut self, prefix: &SharedPrefix) {
        assert!(self.is_empty(), "prefix adoption only into an empty cache");
        assert_eq!(prefix.pages.len(), self.blocks.len(), "prefix layer count mismatch");
        for (layer, chain) in prefix.pages.iter().enumerate() {
            self.blocks[layer] = chain.clone();
            self.len[layer] = prefix.tokens_covered;
        }
        self.shared_prefix_pages = prefix.tokens_covered / self.pool.page_tokens;
    }

    /// Freeze the first `pages` full pages of every layer as shared
    /// (immutable) and return the chains for [`KvPool::publish_prefix`].
    /// The cache keeps reading them; it just may never write them again
    /// — which it would not anyway, full pages are append-complete.
    pub fn freeze_prefix(&mut self, pages: usize) -> Vec<Vec<Arc<PooledPage>>> {
        let pt = self.pool.page_tokens;
        let chains: Vec<Vec<Arc<PooledPage>>> = self
            .blocks
            .iter()
            .enumerate()
            .map(|(layer, blocks)| {
                assert!(
                    self.len[layer] >= pages * pt,
                    "cannot freeze pages that are not yet full"
                );
                blocks[..pages].to_vec()
            })
            .collect();
        self.shared_prefix_pages = self.shared_prefix_pages.max(pages);
        // The freezer is the prefix's author, not an adopter: its later
        // appends are ordinary growth, not a copy-on-write divergence.
        self.forked = true;
        chains
    }

    /// Append `[t_new, dim]` rotated keys and values for `layer`,
    /// drawing new pages from the reservation stack.  Panics if the
    /// scheduler did not [`PagedKvCache::fund`] enough pages — the
    /// admission contract, not a recoverable condition.
    pub fn append(&mut self, layer: usize, k_rows: &Mat, v_rows: &Mat) {
        let dim = self.pool.dim;
        assert_eq!(k_rows.cols(), dim, "key width != cache dim");
        assert_eq!(v_rows.cols(), dim, "value width != cache dim");
        assert_eq!(k_rows.rows(), v_rows.rows(), "k/v row count mismatch");
        let pt = self.pool.page_tokens;
        for r in 0..k_rows.rows() {
            let slot = self.len[layer] % pt;
            if slot == 0 {
                let buf = self
                    .reserve
                    .pop()
                    .expect("paged KV append without a page reservation");
                if self.shared_prefix_pages > 0 && !self.forked {
                    // First owned page after an adopted prefix: the
                    // copy-on-write divergence point.
                    self.forked = true;
                    self.pool.note_cow_fork();
                }
                self.blocks[layer].push(Arc::new(PooledPage {
                    buf: Some(buf),
                    pool: Arc::downgrade(&self.pool),
                }));
            }
            let page = self.blocks[layer]
                .last_mut()
                .expect("block table nonempty after page push");
            let page = Arc::get_mut(page)
                .expect("appended page is uniquely owned (shared pages are immutable)");
            let buf = page.buf.as_mut().expect("page buffer present until drop");
            buf.k[slot * dim..(slot + 1) * dim].copy_from_slice(k_rows.row(r));
            buf.v[slot * dim..(slot + 1) * dim].copy_from_slice(v_rows.row(r));
            self.len[layer] += 1;
        }
    }

    /// Row-access view of `layer` for the attention read path.
    pub(crate) fn rows(&self, layer: usize) -> PagedRows<'_> {
        PagedRows {
            blocks: &self.blocks[layer],
            page_tokens: self.pool.page_tokens,
            dim: self.pool.dim,
        }
    }
}

/// One request's KV storage, contiguous or paged, behind the
/// [`KvCache`]-shaped API — the type the serving pipeline and the host
/// incremental forward ([`crate::model::lm_forward_step`]) carry, so
/// every caller is layout-agnostic and the two layouts stay
/// bit-identical by construction.
///
/// ```
/// use permllm::model::KvStore;
/// use permllm::tensor::Mat;
///
/// let mut store = KvStore::contiguous(2, 4);
/// store.append(0, &Mat::zeros(3, 4), &Mat::zeros(3, 4));
/// assert_eq!((store.pos(0), store.pos(1)), (3, 0));
/// assert!(!store.is_paged());
/// ```
#[derive(Debug)]
pub enum KvStore {
    /// Legacy per-request contiguous buffers.
    Contiguous(KvCache),
    /// Pooled fixed-size pages with block tables.
    Paged(PagedKvCache),
}

impl KvStore {
    /// Fresh contiguous store ([`KvCache::new`]).
    pub fn contiguous(n_layers: usize, dim: usize) -> KvStore {
        KvStore::Contiguous(KvCache::new(n_layers, dim))
    }

    /// Wrap a pool-backed paged cache ([`KvPool::new_cache`]).
    pub fn paged(cache: PagedKvCache) -> KvStore {
        KvStore::Paged(cache)
    }

    /// True for the paged layout.
    pub fn is_paged(&self) -> bool {
        matches!(self, KvStore::Paged(_))
    }

    /// The paged cache, when this store is paged — the scheduler's
    /// funding/adoption hooks live on [`PagedKvCache`].
    pub fn as_paged_mut(&mut self) -> Option<&mut PagedKvCache> {
        match self {
            KvStore::Paged(p) => Some(p),
            KvStore::Contiguous(_) => None,
        }
    }

    /// Decoder layers this store covers.
    pub fn n_layers(&self) -> usize {
        match self {
            KvStore::Contiguous(c) => c.n_layers(),
            KvStore::Paged(p) => p.n_layers(),
        }
    }

    /// Activation width.
    pub fn dim(&self) -> usize {
        match self {
            KvStore::Contiguous(c) => c.dim(),
            KvStore::Paged(p) => p.dim(),
        }
    }

    /// Positions cached at `layer`.
    pub fn pos(&self, layer: usize) -> usize {
        match self {
            KvStore::Contiguous(c) => c.pos(layer),
            KvStore::Paged(p) => p.pos(layer),
        }
    }

    /// Sequence length cached so far (positions at layer 0).
    pub fn len(&self) -> usize {
        match self {
            KvStore::Contiguous(c) => c.len(),
            KvStore::Paged(p) => p.len(),
        }
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident footprint in bytes (for paged stores: uniquely-owned
    /// pages + unspent reservation; shared prefix pages are accounted on
    /// the pool, not per request).
    pub fn bytes(&self) -> usize {
        match self {
            KvStore::Contiguous(c) => c.bytes(),
            KvStore::Paged(p) => p.bytes(),
        }
    }

    /// Append `[t_new, dim]` rotated keys and values for `layer`.
    pub fn append(&mut self, layer: usize, k_rows: &Mat, v_rows: &Mat) {
        match self {
            KvStore::Contiguous(c) => c.append(layer, k_rows, v_rows),
            KvStore::Paged(p) => p.append(layer, k_rows, v_rows),
        }
    }

    /// Pre-reserve capacity for `extra` more positions at every layer.
    /// Contiguous stores grow their flat buffers up front so appends
    /// cannot reallocate ([`KvCache::reserve`]); paged stores are a no-op
    /// — their capacity is the pool's funded pages.
    pub fn reserve(&mut self, extra: usize) {
        match self {
            KvStore::Contiguous(c) => c.reserve(extra),
            KvStore::Paged(_) => {}
        }
    }
}

impl From<KvCache> for KvStore {
    fn from(cache: KvCache) -> KvStore {
        KvStore::Contiguous(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn append_grows_positions_and_bytes() {
        let mut rng = Pcg32::seeded(3);
        let mut cache = KvCache::new(2, 4);
        assert!(cache.is_empty());
        assert_eq!(cache.bytes(), 0);
        let k = Mat::randn(3, 4, 1.0, &mut rng);
        let v = Mat::randn(3, 4, 1.0, &mut rng);
        cache.append(0, &k, &v);
        assert_eq!(cache.pos(0), 3);
        assert_eq!(cache.pos(1), 0, "layers advance independently");
        assert_eq!(cache.len(), 3);
        cache.append(1, &k, &v);
        // 2 layers x (K + V) x 3 rows x 4 cols x 4 bytes.
        assert_eq!(cache.bytes(), 2 * 2 * 3 * 4 * 4);
        assert_eq!(cache.bytes(), KvCache::bytes_for(2, 4, 3));
        let (km, vm) = cache.mats(0);
        assert_eq!(km.data(), k.data());
        assert_eq!(vm.data(), v.data());
        // A second append concatenates below the first.
        let k2 = Mat::randn(1, 4, 1.0, &mut rng);
        let v2 = Mat::randn(1, 4, 1.0, &mut rng);
        cache.append(0, &k2, &v2);
        let (km, _) = cache.mats(0);
        assert_eq!(km.rows(), 4);
        assert_eq!(&km.data()[3 * 4..], k2.data());
    }

    #[test]
    #[should_panic(expected = "key width != cache dim")]
    fn wrong_width_is_rejected() {
        let mut cache = KvCache::new(1, 4);
        cache.append(0, &Mat::zeros(1, 5), &Mat::zeros(1, 5));
    }

    #[test]
    fn paged_rows_match_contiguous_bit_for_bit() {
        // Random append schedules at several page sizes: every cached row
        // read back through the paged block table must equal the
        // contiguous layout exactly.
        let (n_layers, dim) = (2usize, 4usize);
        let mut rng = Pcg32::seeded(41);
        for pt in [1usize, 2, 3, 5] {
            let pool = KvPool::new(64, pt, n_layers, dim);
            let mut contig = KvCache::new(n_layers, dim);
            let mut paged = pool.new_cache();
            for _ in 0..5 {
                let rows = 1 + rng.below(4) as usize;
                let k = Mat::randn(rows, dim, 1.0, &mut rng);
                let v = Mat::randn(rows, dim, 1.0, &mut rng);
                let need = paged.pages_for(rows);
                paged.fund(pool.reserve(need).expect("pool sized amply"));
                for layer in 0..n_layers {
                    contig.append(layer, &k, &v);
                    paged.append(layer, &k, &v);
                }
            }
            assert_eq!(contig.len(), paged.len());
            for layer in 0..n_layers {
                let (kc, vc) = contig.slices(layer);
                let view = paged.rows(layer);
                for i in 0..contig.pos(layer) {
                    assert_eq!(view.k_row(i), &kc[i * dim..(i + 1) * dim], "pt {pt} k row {i}");
                    assert_eq!(view.v_row(i), &vc[i * dim..(i + 1) * dim], "pt {pt} v row {i}");
                }
            }
            assert_eq!(paged.reserve_len(), 0, "reservation exactly consumed");
            let held = pool.used_pages();
            assert_eq!(paged.bytes(), held * pool.page_bytes());
            drop(paged);
            assert_eq!(pool.free_pages(), 64, "dropping the cache returns every page");
        }
    }

    #[test]
    fn reserve_is_all_or_nothing_and_pages_recycle() {
        let pool = KvPool::new(4, 2, 1, 4);
        let a = pool.reserve(3).expect("3 of 4");
        assert_eq!((a.len(), pool.free_pages(), pool.used_pages()), (3, 1, 3));
        assert!(pool.reserve(2).is_none(), "only 1 page left");
        assert_eq!(pool.free_pages(), 1, "failed reserve takes nothing");
        pool.give_back(a);
        assert_eq!(pool.free_pages(), 4);
        assert_eq!(pool.page_bytes(), 2 * 2 * 4 * 4);
    }

    #[test]
    #[should_panic(expected = "paged KV append without a page reservation")]
    fn unfunded_append_is_rejected() {
        let pool = KvPool::new(2, 2, 1, 4);
        let mut cache = pool.new_cache();
        cache.append(0, &Mat::zeros(1, 4), &Mat::zeros(1, 4));
    }

    #[test]
    fn prefix_publish_lookup_adopt_and_evict() {
        let (n_layers, dim, pt) = (2usize, 4usize, 2usize);
        let pool = KvPool::new(8, pt, n_layers, dim);
        let tokens: Vec<u32> = vec![10, 11, 12, 13, 14]; // 2 full pages + 1
        let mut rng = Pcg32::seeded(43);
        let k = Mat::randn(tokens.len(), dim, 1.0, &mut rng);
        let v = Mat::randn(tokens.len(), dim, 1.0, &mut rng);

        // Writer prefilled the whole prompt, then publishes the 2 full pages.
        let mut writer = pool.new_cache();
        writer.fund(pool.reserve(writer.pages_for(tokens.len())).unwrap());
        for layer in 0..n_layers {
            writer.append(layer, &k, &v);
        }
        let chains = writer.freeze_prefix(2);
        pool.publish_prefix(&tokens, &chains);
        assert_eq!(pool.shared_pages(), 2 * n_layers);
        assert_eq!(pool.shared_pages_peak(), 2 * n_layers);
        // Frozen pages no longer count against the writer's residency.
        assert_eq!(writer.bytes(), n_layers * pool.page_bytes());

        // A prompt sharing both pages adopts them; the cap keeps >=1
        // suffix token uncovered.
        let prompt: Vec<u32> = vec![10, 11, 12, 13, 99];
        let hit = pool.lookup_prefix(&prompt, prompt.len() - 1).expect("2-page hit");
        assert_eq!(hit.tokens_covered, 4);
        // A prompt sharing only the first page matches the sub-entry.
        let short: Vec<u32> = vec![10, 11, 77, 78];
        let hit1 = pool.lookup_prefix(&short, short.len() - 1).expect("1-page hit");
        assert_eq!(hit1.tokens_covered, 2);
        // No match below one full page, or for different tokens.
        assert!(pool.lookup_prefix(&prompt, 1).is_none());
        assert!(pool.lookup_prefix(&[1, 2, 3, 4], 3).is_none());

        let mut reader = pool.new_cache();
        reader.adopt_prefix(&hit);
        assert_eq!(reader.len(), 4);
        assert_eq!(reader.bytes(), 0, "adopted pages are accounted on the pool");
        // Divergence: the reader's first own append is the CoW fork.
        assert_eq!(pool.cow_forks(), 0);
        reader.fund(pool.reserve(reader.pages_for(1)).unwrap());
        let k1 = Mat::randn(1, dim, 1.0, &mut rng);
        let v1 = Mat::randn(1, dim, 1.0, &mut rng);
        for layer in 0..n_layers {
            reader.append(layer, &k1, &v1);
        }
        assert_eq!(pool.cow_forks(), 1);
        // The adopted rows read back the writer's data, the fork row its own.
        let view = reader.rows(0);
        assert_eq!(view.k_row(0), &k.data()[..dim]);
        assert_eq!(view.k_row(4), k1.row(0));

        // Pool exhausted: a big reserve evicts the registry; pages still
        // referenced by writer/reader survive until those drop.
        drop(writer);
        drop(reader);
        assert!(pool.shared_pages() > 0, "registry still holds the prefix");
        let bufs = pool.reserve(8).expect("eviction frees the registry pages");
        assert_eq!(bufs.len(), 8);
        assert_eq!(pool.shared_pages(), 0);
        pool.give_back(bufs);
        pool.flush_shared();
        assert_eq!(pool.free_pages(), 8);
    }

    #[test]
    fn kv_store_mirrors_both_layouts() {
        let mut rng = Pcg32::seeded(47);
        let k = Mat::randn(3, 4, 1.0, &mut rng);
        let v = Mat::randn(3, 4, 1.0, &mut rng);
        let mut contig = KvStore::contiguous(2, 4);
        let pool = KvPool::new(8, 2, 2, 4);
        let mut paged = KvStore::paged(pool.new_cache());
        paged
            .as_paged_mut()
            .unwrap()
            .fund(pool.reserve(paged.as_paged_mut().unwrap().pages_for(3)).unwrap());
        for store in [&mut contig, &mut paged] {
            assert!(store.is_empty());
            store.append(0, &k, &v);
            store.append(1, &k, &v);
            assert_eq!((store.n_layers(), store.dim(), store.len()), (2, 4, 3));
            assert_eq!(store.pos(1), 3);
        }
        assert!(!contig.is_paged());
        assert!(paged.is_paged());
        assert_eq!(contig.bytes(), KvCache::bytes_for(2, 4, 3));
        // Paged rounds up to whole pages: 2 pages x 2 layers.
        assert_eq!(paged.bytes(), 4 * pool.page_bytes());
    }
}
