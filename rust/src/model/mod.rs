//! The tiny LLaMA-style LM on the Rust side.
//!
//! Mirrors `python/compile/model.py` exactly (same parameter order, same
//! RMSNorm/RoPE/attention/SwiGLU math in f32) so that:
//! * weights trained via the `train_step` artifact evaluate identically
//!   through the host forward and the `lm_forward` artifact
//!   (`tests/model_parity.rs` pins this);
//! * the pruning pipeline can capture per-linear calibration activations
//!   with [`forward::forward_captured`].

mod config;
mod forward;
mod kv;
mod params;
mod synth;

pub use config::{LinearKind, LinearRef, ModelConfig};
pub use forward::{forward_captured, lm_forward, lm_forward_step, lm_loss, perplexity, Captured};
pub(crate) use forward::{
    cached_attention, cached_attention_scratch, causal_attention, rmsnorm, rmsnorm_scratch, rope,
    swiglu, swiglu_scratch,
};
pub use kv::{KvCache, KvPool, KvStore, PagedKvCache, PooledPage, SharedPrefix};
pub use params::ParamStore;
pub use synth::synth_trained_params;
