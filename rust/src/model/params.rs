//! Parameter store: named tensors + binary serialization.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::config::ModelConfig;
use crate::tensor::Mat;
use crate::util::rng::Pcg32;

const MAGIC: &[u8; 4] = b"PLLM";
const VERSION: u32 = 1;

/// All model parameters, keyed by canonical name. Rank-1 params (norms)
/// are stored as `[1, d]` matrices.
#[derive(Debug, Clone)]
pub struct ParamStore {
    cfg: ModelConfig,
    params: BTreeMap<String, Mat>,
}

impl ParamStore {
    /// Gaussian init: std = fan_in^-0.5, norms = 1 (matches python init in
    /// spirit; exact pretrain init comes from the train_step artifact path).
    pub fn init(cfg: &ModelConfig, rng: &mut Pcg32) -> ParamStore {
        let mut params = BTreeMap::new();
        for name in cfg.param_names() {
            let shape = cfg.param_shape(&name);
            let m = if shape.len() == 1 {
                Mat::full(1, shape[0], 1.0)
            } else {
                let std = (shape[1] as f32).powf(-0.5);
                Mat::randn(shape[0], shape[1], std, rng)
            };
            params.insert(name, m);
        }
        ParamStore { cfg: cfg.clone(), params }
    }

    /// Build from a flat list in canonical order (artifact output).
    pub fn from_flat(cfg: &ModelConfig, flat: Vec<Mat>) -> Result<ParamStore> {
        let names = cfg.param_names();
        anyhow::ensure!(flat.len() == names.len(), "expected {} params, got {}", names.len(), flat.len());
        let mut params = BTreeMap::new();
        for (name, m) in names.into_iter().zip(flat) {
            let shape = cfg.param_shape(&name);
            let want = if shape.len() == 1 { (1, shape[0]) } else { (shape[0], shape[1]) };
            anyhow::ensure!(m.shape() == want, "param {name}: shape {:?} != {:?}", m.shape(), want);
            params.insert(name, m);
        }
        Ok(ParamStore { cfg: cfg.clone(), params })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn get(&self, name: &str) -> &Mat {
        self.params.get(name).unwrap_or_else(|| panic!("missing param {name}"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Mat {
        self.params.get_mut(name).unwrap_or_else(|| panic!("missing param {name}"))
    }

    pub fn set(&mut self, name: &str, m: Mat) {
        assert!(self.params.contains_key(name), "unknown param {name}");
        self.params.insert(name.to_string(), m);
    }

    /// Flat list in canonical order (artifact input).
    pub fn to_flat(&self) -> Vec<&Mat> {
        self.cfg.param_names().iter().map(|n| self.get(n)).collect()
    }

    /// Total scalar count.
    pub fn n_params(&self) -> usize {
        self.params.values().map(|m| m.data().len()).sum()
    }

    /// Serialize to the `PLLM` binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        let cfg_line = format!(
            "{} {} {} {} {} {} {} {} {}",
            self.cfg.name,
            self.cfg.vocab,
            self.cfg.dim,
            self.cfg.n_layers,
            self.cfg.n_heads,
            self.cfg.ffn,
            self.cfg.seq_len,
            self.cfg.rope_theta,
            self.cfg.norm_eps
        );
        f.write_all(&(cfg_line.len() as u32).to_le_bytes())?;
        f.write_all(cfg_line.as_bytes())?;
        f.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for name in self.cfg.param_names() {
            let m = self.get(&name);
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(m.rows() as u32).to_le_bytes())?;
            f.write_all(&(m.cols() as u32).to_le_bytes())?;
            for v in m.data() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load from the `PLLM` binary format.
    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad magic");
        let version = read_u32(&mut f)?;
        anyhow::ensure!(version == VERSION, "unsupported version {version}");
        let cfg_len = read_u32(&mut f)? as usize;
        let mut cfg_buf = vec![0u8; cfg_len];
        f.read_exact(&mut cfg_buf)?;
        let cfg_line = String::from_utf8(cfg_buf)?;
        let parts: Vec<&str> = cfg_line.split_whitespace().collect();
        anyhow::ensure!(parts.len() == 9, "bad config line");
        let cfg = ModelConfig {
            name: parts[0].to_string(),
            vocab: parts[1].parse()?,
            dim: parts[2].parse()?,
            n_layers: parts[3].parse()?,
            n_heads: parts[4].parse()?,
            ffn: parts[5].parse()?,
            seq_len: parts[6].parse()?,
            rope_theta: parts[7].parse()?,
            norm_eps: parts[8].parse()?,
        };
        let n = read_u32(&mut f)? as usize;
        let mut params = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            let mut nb = vec![0u8; name_len];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let rows = read_u32(&mut f)? as usize;
            let cols = read_u32(&mut f)? as usize;
            let mut data = vec![0f32; rows * cols];
            let mut buf = [0u8; 4];
            for v in data.iter_mut() {
                f.read_exact(&mut buf)?;
                *v = f32::from_le_bytes(buf);
            }
            params.insert(name, Mat::from_vec(rows, cols, data));
        }
        for name in cfg.param_names() {
            if !params.contains_key(&name) {
                return Err(anyhow!("missing param {name} in file"));
            }
        }
        Ok(ParamStore { cfg, params })
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut buf = [0u8; 4];
    f.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes_match_config() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let mut rng = Pcg32::seeded(1);
        let ps = ParamStore::init(&cfg, &mut rng);
        assert_eq!(ps.get("tok_embed").shape(), (256, 64));
        assert_eq!(ps.get("layers.0.attn_norm").shape(), (1, 64));
        assert_eq!(ps.get("layers.1.w_down").shape(), (64, 128));
        assert!(ps.n_params() > 100_000);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let mut rng = Pcg32::seeded(2);
        let ps = ParamStore::init(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("permllm_test_params.bin");
        ps.save(&dir).unwrap();
        let back = ParamStore::load(&dir).unwrap();
        assert_eq!(back.cfg(), ps.cfg());
        for name in cfg.param_names() {
            assert_eq!(back.get(&name).data(), ps.get(&name).data(), "{name}");
        }
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn from_flat_validates_shapes() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let mut rng = Pcg32::seeded(3);
        let ps = ParamStore::init(&cfg, &mut rng);
        let flat: Vec<Mat> = ps.to_flat().into_iter().cloned().collect();
        let back = ParamStore::from_flat(&cfg, flat).unwrap();
        assert_eq!(back.n_params(), ps.n_params());
        // wrong count rejected
        assert!(ParamStore::from_flat(&cfg, vec![Mat::zeros(1, 1)]).is_err());
    }
}
