//! Synthetic "trained-like" weights (DESIGN.md §5 substitution).
//!
//! Benches must run without artifacts or a pretraining pass, but pruning
//! dynamics are only interesting on weights with realistic statistics.
//! Trained LLM weights are (a) heavy-tailed, (b) have a minority of
//! high-magnitude *outlier channels*, and (c) rows with very different
//! norms; activations correspondingly have outlier channels (the
//! motivation for Wanda/RIA).  [`synth_trained_params`] instills exactly
//! those properties deterministically.  When `examples/end_to_end.rs` has
//! produced genuinely trained weights (`models/<name>.bin`), the benches
//! prefer them.

use super::config::ModelConfig;
use super::params::ParamStore;
use crate::tensor::Mat;
use crate::util::rng::Pcg32;

/// Fraction of channels made outliers.
const OUTLIER_FRAC: f32 = 0.06;
/// Outlier magnitude multiplier range.
const OUTLIER_GAIN: (f32, f32) = (3.0, 8.0);

fn heavy_tailed(rows: usize, cols: usize, std: f32, rng: &mut Pcg32) -> Mat {
    // Student-t-ish: normal / sqrt(uniform) gives excess kurtosis.
    let mut m = Mat::zeros(rows, cols);
    for v in m.data_mut() {
        let g = rng.normal();
        let u = 0.3 + 0.7 * rng.uniform();
        *v = g * std / u.sqrt();
    }
    m
}

fn add_outlier_channels(m: &mut Mat, rng: &mut Pcg32) {
    let cols = m.cols();
    let n_out = ((cols as f32 * OUTLIER_FRAC).ceil() as usize).max(1);
    for _ in 0..n_out {
        let c = rng.below_usize(cols);
        let gain = rng.range_f32(OUTLIER_GAIN.0, OUTLIER_GAIN.1);
        for r in 0..m.rows() {
            m[(r, c)] *= gain;
        }
    }
}

/// Deterministic trained-statistics parameters for a config.
pub fn synth_trained_params(cfg: &ModelConfig, seed: u64) -> ParamStore {
    let mut rng = Pcg32::seeded(seed);
    let mut ps = ParamStore::init(cfg, &mut rng);
    for name in cfg.param_names() {
        let shape = cfg.param_shape(&name);
        if shape.len() == 1 {
            // Norm gains drift slightly away from 1 during training.
            let mut g = Mat::zeros(1, shape[0]);
            for v in g.data_mut() {
                *v = 1.0 + 0.15 * rng.normal();
            }
            ps.set(&name, g);
            continue;
        }
        let std = (shape[1] as f32).powf(-0.5);
        let mut m = heavy_tailed(shape[0], shape[1], std, &mut rng);
        add_outlier_channels(&mut m, &mut rng);
        // Row-norm diversity: scale rows by lognormal-ish factors.
        for r in 0..m.rows() {
            let f = (0.5 * rng.normal()).exp();
            for v in m.row_mut(r) {
                *v *= f;
            }
        }
        ps.set(&name, m);
    }
    ps
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kurtosis(xs: &[f32]) -> f64 {
        let n = xs.len() as f64;
        let mean: f64 = xs.iter().map(|&v| v as f64).sum::<f64>() / n;
        let m2: f64 = xs.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        let m4: f64 = xs.iter().map(|&v| (v as f64 - mean).powi(4)).sum::<f64>() / n;
        m4 / (m2 * m2)
    }

    #[test]
    fn deterministic() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let a = synth_trained_params(&cfg, 9);
        let b = synth_trained_params(&cfg, 9);
        assert_eq!(a.get("layers.0.wq").data(), b.get("layers.0.wq").data());
    }

    #[test]
    fn weights_are_heavy_tailed() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 1);
        let k = kurtosis(ps.get("layers.0.w_gate").data());
        assert!(k > 4.0, "kurtosis {k} not heavy-tailed (normal = 3)");
    }

    #[test]
    fn has_outlier_channels() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 2);
        let w = ps.get("layers.0.wq");
        let norms: Vec<f32> = (0..w.cols())
            .map(|c| w.col(c).iter().map(|v| v * v).sum::<f32>().sqrt())
            .collect();
        let mean: f32 = norms.iter().sum::<f32>() / norms.len() as f32;
        let max = norms.iter().cloned().fold(0.0f32, f32::max);
        assert!(max > 2.5 * mean, "max/mean = {}", max / mean);
    }

    #[test]
    fn different_seeds_differ() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let a = synth_trained_params(&cfg, 1);
        let b = synth_trained_params(&cfg, 2);
        assert_ne!(a.get("layers.0.wq").data(), b.get("layers.0.wq").data());
    }
}
