//! Weight importance metrics for one-shot pruning.

use crate::tensor::Mat;

/// Importance metric selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// `|W_ij|` — magnitude pruning (Han et al. [21]).
    Magnitude,
    /// `|W_ij| * ||X_j||_2` — Wanda (Sun et al. [50]).
    Wanda,
    /// RIA (Zhang et al. [62]): relative importance x activation:
    /// `(|W_ij| / sum_i' |W_i'j|... ` see [`importance`] for the exact form.
    Ria,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Magnitude => "magnitude",
            Metric::Wanda => "wanda",
            Metric::Ria => "ria",
        }
    }

    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "magnitude" | "mag" => Some(Metric::Magnitude),
            "wanda" => Some(Metric::Wanda),
            "ria" => Some(Metric::Ria),
            _ => None,
        }
    }
}

/// RIA's activation exponent `a` (paper uses 0.5).
const RIA_ALPHA: f32 = 0.5;

/// Compute the importance matrix `S` for weight `w` `[C_out, C_in]` given
/// calibration activations `x` `[T, C_in]`.
///
/// * Magnitude: `S_ij = |W_ij|` (x unused).
/// * Wanda:     `S_ij = |W_ij| * ||X_j||_2`.
/// * RIA:       `S_ij = (|W_ij| / Σ_j'|W_ij'| + |W_ij| / Σ_i'|W_i'j|) *
///               (||X_j||_2)^a` — the relative-importance form that avoids
///               channel corruption (both row- and column-relative terms).
pub fn importance(metric: Metric, w: &Mat, x: &Mat) -> Mat {
    let (c_out, c_in) = w.shape();
    match metric {
        Metric::Magnitude => w.map(f32::abs),
        Metric::Wanda => {
            assert_eq!(x.cols(), c_in, "activation/weight width mismatch");
            let norms = x.col_l2_norms();
            let mut s = Mat::zeros(c_out, c_in);
            for r in 0..c_out {
                let wrow = w.row(r);
                let srow = s.row_mut(r);
                for c in 0..c_in {
                    srow[c] = wrow[c].abs() * norms[c];
                }
            }
            s
        }
        Metric::Ria => {
            assert_eq!(x.cols(), c_in, "activation/weight width mismatch");
            let norms = x.col_l2_norms();
            let abs = w.map(f32::abs);
            // Row sums Σ_j' |W_ij'| and column sums Σ_i' |W_i'j|.
            let mut row_sum = vec![0.0f32; c_out];
            let mut col_sum = vec![0.0f32; c_in];
            for r in 0..c_out {
                for (c, &a) in abs.row(r).iter().enumerate() {
                    row_sum[r] += a;
                    col_sum[c] += a;
                }
            }
            let mut s = Mat::zeros(c_out, c_in);
            for r in 0..c_out {
                let arow = abs.row(r);
                let srow = s.row_mut(r);
                for c in 0..c_in {
                    let rel = arow[c] / (row_sum[r] + 1e-12) + arow[c] / (col_sum[c] + 1e-12);
                    srow[c] = rel * norms[c].powf(RIA_ALPHA);
                }
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    #[test]
    fn magnitude_is_abs() {
        let w = Mat::from_vec(1, 4, vec![-3.0, 1.0, 0.0, -0.5]);
        let x = Mat::zeros(2, 4);
        let s = importance(Metric::Magnitude, &w, &x);
        assert_eq!(s.data(), &[3.0, 1.0, 0.0, 0.5]);
    }

    #[test]
    fn wanda_scales_by_column_norm() {
        let w = Mat::from_vec(1, 2, vec![1.0, 1.0]);
        // col 0 has norm 2, col 1 has norm 0.
        let x = Mat::from_vec(2, 2, vec![2.0, 0.0, 0.0, 0.0]);
        let s = importance(Metric::Wanda, &w, &x);
        assert_eq!(s.data(), &[2.0, 0.0]);
    }

    #[test]
    fn wanda_dead_channel_gets_zero_importance() {
        // A channel whose activation is always zero contributes nothing
        // regardless of its weight — this is Wanda's core insight.
        let w = Mat::from_vec(2, 4, vec![9.0, 0.1, 0.1, 0.1, 9.0, 0.1, 0.1, 0.1]);
        let mut x = Mat::zeros(8, 4);
        for t in 0..8 {
            for c in 1..4 {
                x[(t, c)] = 1.0;
            }
        }
        let s = importance(Metric::Wanda, &w, &x);
        assert_eq!(s[(0, 0)], 0.0);
        assert!(s[(0, 1)] > 0.0);
    }

    #[test]
    fn ria_counteracts_channel_corruption() {
        // RIA's relative term boosts the only surviving weight in an
        // otherwise-small column so whole input channels aren't zeroed.
        let mut rng = Pcg32::seeded(3);
        let mut w = Mat::randn(8, 8, 1.0, &mut rng);
        // Column 0 tiny everywhere except row 0.
        for r in 1..8 {
            w[(r, 0)] = 1e-4;
        }
        w[(0, 0)] = 0.05; // small in absolute terms but dominates its column
        let x = Mat::full(4, 8, 1.0);
        let s = importance(Metric::Ria, &w, &x);
        // Relative importance of (0,0) within column 0 should rescue it
        // relative to plain magnitude ranking.
        let mag = importance(Metric::Magnitude, &w, &x);
        let rank_ria = s.row(0).iter().filter(|&&v| v > s[(0, 0)]).count();
        let rank_mag = mag.row(0).iter().filter(|&&v| v > mag[(0, 0)]).count();
        assert!(rank_ria < rank_mag, "ria rank {rank_ria} vs mag rank {rank_mag}");
    }

    #[test]
    fn prop_metrics_nonnegative_and_finite() {
        testkit::check("metric-sane", |rng| {
            let w = Mat::randn(6, 8, 1.0, rng);
            let x = Mat::randn(5, 8, 1.0, rng);
            for m in [Metric::Magnitude, Metric::Wanda, Metric::Ria] {
                let s = importance(m, &w, &x);
                if s.data().iter().any(|v| !v.is_finite() || *v < 0.0) {
                    return Err(format!("{} produced invalid score", m.name()));
                }
            }
            Ok(())
        });
    }
}
