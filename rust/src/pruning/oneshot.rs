//! One-shot N:M pruning (metric -> Eq. 7 mask -> masked weight).

use super::{importance, Metric};
use crate::sparsity::{NmConfig, NmMask};
use crate::tensor::Mat;

/// Output of a pruning run on one linear layer.
#[derive(Debug, Clone)]
pub struct PruneResult {
    /// The N:M mask in the (possibly permuted) storage order.
    pub mask: NmMask,
    /// Masked (and possibly weight-updated) weight, storage order.
    pub weight: Mat,
    /// Channel permutation applied before masking (`src_of`; identity when
    /// no permutation was used). `weight[:, j]` corresponds to original
    /// input channel `src_of[j]`.
    pub src_of: Vec<usize>,
}

impl PruneResult {
    /// Mean cosine distance of this layer's output vs the dense output
    /// (paper Eq. 10) for calibration input `x` `[T, C_in]` in ORIGINAL
    /// channel order.
    pub fn cosine_error(&self, x: &Mat, y_dense: &Mat) -> f32 {
        let xp = x.permute_cols(&self.src_of);
        let y = xp.matmul_bt(&self.weight);
        y_dense.mean_cosine_distance(&y)
    }

    /// Mean squared output error vs the dense output.
    pub fn mse_error(&self, x: &Mat, y_dense: &Mat) -> f32 {
        let xp = x.permute_cols(&self.src_of);
        let y = xp.matmul_bt(&self.weight);
        y_dense.mse(&y)
    }

    /// The pruned weight expressed in ORIGINAL channel order (mask loses
    /// its N:M structure in this view — used for Fig. 3 visualizations and
    /// for single-layer error evaluation without activation permutes).
    pub fn weight_original_order(&self) -> Mat {
        let mut inv = vec![0usize; self.src_of.len()];
        for (j, &i) in self.src_of.iter().enumerate() {
            inv[i] = j;
        }
        self.weight.permute_cols(&inv)
    }
}

/// Prune `w` to the N:M pattern with a one-shot metric (no permutation).
pub fn prune_oneshot(metric: Metric, w: &Mat, x: &Mat, cfg: NmConfig) -> PruneResult {
    let s = importance(metric, w, x);
    let mask = NmMask::from_scores(&s, cfg);
    let weight = mask.apply(w);
    PruneResult { mask, weight, src_of: (0..w.cols()).collect() }
}

/// Prune with an explicit pre-permutation (`src_of`): permute channels,
/// recompute the mask in permuted order (Eq. 8), mask.
pub fn prune_permuted(metric: Metric, w: &Mat, x: &Mat, cfg: NmConfig, src_of: &[usize]) -> PruneResult {
    prune_scored(&importance(metric, w, x), w, cfg, src_of)
}

/// The [`prune_permuted`] body with the importance matrix supplied by
/// the caller — bit-identical to [`prune_permuted`] when
/// `s == importance(metric, w, x)`, and the primitive the trait-based
/// recipe path ([`crate::recipe`]) builds on (the driver computes `s`
/// once and shares it between the permutation search and the masking).
pub fn prune_scored(s: &Mat, w: &Mat, cfg: NmConfig, src_of: &[usize]) -> PruneResult {
    if src_of.iter().enumerate().all(|(j, &i)| j == i) {
        // Identity: skip the two full-matrix permute copies (a gather
        // by the identity yields the same values bit for bit).
        let mask = NmMask::from_scores(s, cfg);
        let weight = mask.apply(w);
        return PruneResult { mask, weight, src_of: src_of.to_vec() };
    }
    let wp = w.permute_cols(src_of);
    let sp = s.permute_cols(src_of);
    let mask = NmMask::from_scores(&sp, cfg);
    let weight = mask.apply(&wp);
    PruneResult { mask, weight, src_of: src_of.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    #[test]
    fn oneshot_masks_half_for_2_4() {
        let mut rng = Pcg32::seeded(1);
        let w = Mat::randn(8, 16, 1.0, &mut rng);
        let x = Mat::randn(12, 16, 1.0, &mut rng);
        let r = prune_oneshot(Metric::Wanda, &w, &x, NmConfig::PAT_2_4);
        assert!(r.mask.verify());
        let zeros = r.weight.data().iter().filter(|&&v| v == 0.0).count();
        assert!(zeros >= 8 * 16 / 2);
    }

    #[test]
    fn identity_permutation_equals_plain_oneshot() {
        let mut rng = Pcg32::seeded(2);
        let w = Mat::randn(4, 16, 1.0, &mut rng);
        let x = Mat::randn(8, 16, 1.0, &mut rng);
        let id: Vec<usize> = (0..16).collect();
        let a = prune_oneshot(Metric::Ria, &w, &x, NmConfig::PAT_2_4);
        let b = prune_permuted(Metric::Ria, &w, &x, NmConfig::PAT_2_4, &id);
        assert_eq!(a.weight.data(), b.weight.data());
    }

    #[test]
    fn prop_permuted_prune_output_independent_of_order_for_dense_path() {
        // Sanity: permuting then un-permuting the *unmasked* weight is
        // lossless; error comes only from masking.
        testkit::check("perm-lossless", |rng| {
            let w = Mat::randn(4, 16, 1.0, rng);
            let x = Mat::randn(6, 16, 1.0, rng);
            let y = x.matmul_bt(&w);
            let perm = rng.permutation(16);
            let wp = w.permute_cols(&perm);
            let xp = x.permute_cols(&perm);
            let yp = xp.matmul_bt(&wp);
            testkit::assert_close(y.data(), yp.data(), 1e-4)
        });
    }

    #[test]
    fn prop_cosine_error_evaluated_in_consistent_order() {
        testkit::check("cosine-consistent", |rng| {
            let w = Mat::randn(6, 16, 1.0, rng);
            let x = Mat::randn(8, 16, 1.0, rng);
            let y = x.matmul_bt(&w);
            let perm = rng.permutation(16);
            let r = prune_permuted(Metric::Wanda, &w, &x, NmConfig::PAT_2_4, &perm);
            // Equivalent evaluation through the original-order weight view.
            let w_orig = r.weight_original_order();
            let y_sp = x.matmul_bt(&w_orig);
            let direct = y.mean_cosine_distance(&y_sp);
            let via = r.cosine_error(&x, &y);
            if (direct - via).abs() > 1e-5 {
                return Err(format!("{direct} vs {via}"));
            }
            Ok(())
        });
    }
}
