//! SparseGPT (Frantar & Alistarh, ICML'23) for N:M patterns.
//!
//! The OBS-based baseline: prune column-by-column, and after zeroing a
//! weight, redistribute its contribution onto the not-yet-processed
//! columns using the inverse Hessian of the calibration activations.
//! This is the only Table 1/2 baseline that updates weight values.
//!
//! Implementation follows the reference: H = X^T X + λI, take the upper
//! Cholesky factor U of H^{-1} (so `U[j, j:]` drives the update), walk
//! columns left to right, and at each group boundary pick the N:M mask by
//! the OBS saliency `w^2 / U_jj^2`.

use crate::sparsity::{NmConfig, NmMask};
use crate::tensor::{cholesky, cholesky_inverse, Mat};

use super::PruneResult;

/// SparseGPT hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct SparseGptCfg {
    /// Relative dampening added to the Hessian diagonal (ref: 0.01).
    pub damp: f32,
}

impl Default for SparseGptCfg {
    fn default() -> Self {
        SparseGptCfg { damp: 0.01 }
    }
}

/// Run SparseGPT on one linear layer: weight `w` `[C_out, C_in]`,
/// calibration activations `x` `[T, C_in]`.
pub fn sparsegpt(w: &Mat, x: &Mat, nm: NmConfig, cfg: SparseGptCfg) -> PruneResult {
    let (c_out, c_in) = w.shape();
    assert_eq!(x.cols(), c_in);

    // H = X^T X + λ mean(diag) I.
    let mut h = x.matmul_at(x);
    let mean_diag: f32 = (0..c_in).map(|i| h[(i, i)]).sum::<f32>() / c_in as f32;
    let lambda = cfg.damp * mean_diag.max(1e-8);
    // Dead channels (zero activation) get pruned outright; bump their
    // diagonal so the factorization stays PD (reference does the same).
    for i in 0..c_in {
        h[(i, i)] += lambda;
    }

    // U = upper Cholesky factor of H^{-1} (H^{-1} = U^T U).  This equals
    // L^T for the lower factor L with H^{-1} = L L^T — exactly what the
    // reference's `torch.linalg.cholesky(Hinv, upper=True)` returns.
    let hinv = cholesky_inverse(&h).expect("damped Hessian must be PD");
    let u = cholesky(&hinv).expect("H^{-1} must be PD").transpose();

    let mut wt = w.clone();
    let mut mask_bits = vec![true; c_out * c_in];

    for g in 0..c_in / nm.m {
        let base = g * nm.m;
        // Choose the group's mask per row by OBS saliency w^2 / U_jj^2.
        for r in 0..c_out {
            let mut sal: Vec<(f32, usize)> = (0..nm.m)
                .map(|k| {
                    let j = base + k;
                    let d = u[(j, j)];
                    (wt[(r, j)] * wt[(r, j)] / (d * d + 1e-12), k)
                })
                .collect();
            sal.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
            for &(_, k) in sal.iter().skip(nm.keep) {
                mask_bits[r * c_in + base + k] = false;
            }
        }
        // OBS update: zero pruned entries, push error onto later columns.
        for k in 0..nm.m {
            let j = base + k;
            let d = u[(j, j)];
            for r in 0..c_out {
                let q = if mask_bits[r * c_in + j] { wt[(r, j)] } else { 0.0 };
                let err = (wt[(r, j)] - q) / d;
                if err != 0.0 {
                    for j2 in j + 1..c_in {
                        wt[(r, j2)] -= err * u[(j, j2)];
                    }
                }
                wt[(r, j)] = q;
            }
        }
    }

    let mask_mat = Mat::from_vec(
        c_out,
        c_in,
        mask_bits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
    );
    let mask = NmMask::from_dense(&mask_mat, nm).expect("sparsegpt produced non-N:M mask");
    let weight = mask.apply(&wt);
    PruneResult { mask, weight, src_of: (0..c_in).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::{prune_oneshot, Metric};
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    #[test]
    fn upper_factor_reconstructs() {
        let mut rng = Pcg32::seeded(1);
        let x = Mat::randn(20, 8, 1.0, &mut rng);
        let mut h = x.matmul_at(&x);
        for i in 0..8 {
            h[(i, i)] += 0.1;
        }
        let u = cholesky(&h).unwrap().transpose();
        let recon = u.transpose().matmul(&u); // U^T U = L L^T = H
        assert!(recon.mse(&h) < 1e-4, "mse {}", recon.mse(&h));
    }

    #[test]
    fn mask_is_nm_and_weights_updated() {
        let mut rng = Pcg32::seeded(2);
        let w = Mat::randn(8, 32, 1.0, &mut rng);
        let x = Mat::randn(64, 32, 1.0, &mut rng);
        let r = sparsegpt(&w, &x, NmConfig::PAT_2_4, SparseGptCfg::default());
        assert!(r.mask.verify());
        // Retained weights must differ from the originals somewhere
        // (weight update happened).
        let mut updated = false;
        for rr in 0..8 {
            for c in 0..32 {
                if r.mask.get(rr, c) && (r.weight[(rr, c)] - w[(rr, c)]).abs() > 1e-6 {
                    updated = true;
                }
            }
        }
        assert!(updated, "no weight update applied");
    }

    #[test]
    fn prop_sparsegpt_beats_magnitude_on_reconstruction() {
        // The whole point of OBS: lower output MSE than naive magnitude
        // masking, on average. Allow occasional ties on tiny problems.
        testkit::check_n("sparsegpt-better-than-mag", 8, |rng| {
            let w = Mat::randn(12, 32, 1.0, rng);
            let x = Mat::randn(96, 32, 1.0, rng);
            let y = x.matmul_bt(&w);
            let sg = sparsegpt(&w, &x, NmConfig::PAT_2_4, SparseGptCfg::default());
            let mag = prune_oneshot(Metric::Magnitude, &w, &x, NmConfig::PAT_2_4);
            let e_sg = sg.mse_error(&x, &y);
            let e_mag = mag.mse_error(&x, &y);
            if e_sg > e_mag * 1.05 {
                return Err(format!("sparsegpt {e_sg} worse than magnitude {e_mag}"));
            }
            Ok(())
        });
    }

    #[test]
    fn handles_dead_channels() {
        let mut rng = Pcg32::seeded(3);
        let w = Mat::randn(4, 16, 1.0, &mut rng);
        let mut x = Mat::randn(32, 16, 1.0, &mut rng);
        for t in 0..32 {
            x[(t, 3)] = 0.0; // dead input channel
        }
        let r = sparsegpt(&w, &x, NmConfig::PAT_2_4, SparseGptCfg::default());
        assert!(r.mask.verify());
        assert!(r.weight.data().iter().all(|v| v.is_finite()));
    }
}
