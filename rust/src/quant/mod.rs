//! Channel-permutation-aware quantization (paper §D future work).
//!
//! The paper's Limitations section points out that channel reordering
//! also helps *quantization* (RPTQ [59], DuQuant [30]).  This module
//! implements that direction on the same permutation substrate: per-group
//! symmetric integer quantization of `[C_out, C_in]` weights along the
//! input-channel axis, where a channel permutation regroups channels of
//! similar dynamic range so outlier channels stop inflating their
//! group's scale.
//!
//! Two permutation strategies are provided:
//! * [`range_sort_perm`] — RPTQ-style: sort channels by dynamic range;
//! * reuse of the N:M machinery — any `src_of` from `cp::ria_cp` or the
//!   LCP trainer can be passed to [`quantize_permuted`].
//!
//! [`range_sort_perm`] also composes with any pruning metric and weight
//! update through the recipe API ([`crate::recipe::RangeSortPerm`]
//! implements `PermStrategy`), so quantization-aware reordering can
//! drive the N:M pipeline end-to-end.

use crate::tensor::Mat;

/// Quantization configuration: `bits` signed symmetric, channels grouped
/// along C_in in groups of `group` (one scale per row per group).
#[derive(Debug, Clone, Copy)]
pub struct QuantCfg {
    pub bits: u32,
    pub group: usize,
}

impl QuantCfg {
    pub const INT8_G64: QuantCfg = QuantCfg { bits: 8, group: 64 };
    pub const INT4_G64: QuantCfg = QuantCfg { bits: 4, group: 64 };

    fn qmax(&self) -> f32 {
        ((1i32 << (self.bits - 1)) - 1) as f32
    }
}

/// A quantized weight: int codes + per-(row, group) scales, plus the
/// channel permutation used for grouping (`src_of`; identity if none).
#[derive(Debug, Clone)]
pub struct QuantWeight {
    cfg: QuantCfg,
    c_out: usize,
    c_in: usize,
    codes: Vec<i8>,
    scales: Vec<f32>,
    src_of: Vec<usize>,
}

impl QuantWeight {
    /// Quantize `w` in its given channel order.
    pub fn quantize(w: &Mat, cfg: QuantCfg) -> QuantWeight {
        let id: Vec<usize> = (0..w.cols()).collect();
        Self::quantize_permuted(w, &id, cfg)
    }

    /// Quantize `w` after permuting input channels by `src_of`.
    pub fn quantize_permuted(w: &Mat, src_of: &[usize], cfg: QuantCfg) -> QuantWeight {
        let wp = w.permute_cols(src_of);
        let (c_out, c_in) = wp.shape();
        assert_eq!(c_in % cfg.group, 0, "C_in must be divisible by group");
        assert!(cfg.bits >= 2 && cfg.bits <= 8);
        let groups = c_in / cfg.group;
        let qmax = cfg.qmax();
        let mut codes = vec![0i8; c_out * c_in];
        let mut scales = vec![0.0f32; c_out * groups];
        for r in 0..c_out {
            let row = wp.row(r);
            for g in 0..groups {
                let seg = &row[g * cfg.group..(g + 1) * cfg.group];
                let absmax = seg.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let scale = if absmax > 0.0 { absmax / qmax } else { 1.0 };
                scales[r * groups + g] = scale;
                for (k, &v) in seg.iter().enumerate() {
                    let q = (v / scale).round().clamp(-qmax, qmax);
                    codes[r * c_in + g * cfg.group + k] = q as i8;
                }
            }
        }
        QuantWeight { cfg, c_out, c_in, codes, scales, src_of: src_of.to_vec() }
    }

    /// Dequantize back to the ORIGINAL channel order.
    pub fn dequantize(&self) -> Mat {
        let groups = self.c_in / self.cfg.group;
        let mut out = Mat::zeros(self.c_out, self.c_in);
        for r in 0..self.c_out {
            for c in 0..self.c_in {
                let s = self.scales[r * groups + c / self.cfg.group];
                out[(r, c)] = self.codes[r * self.c_in + c] as f32 * s;
            }
        }
        // Undo the permutation.
        let mut inv = vec![0usize; self.c_in];
        for (j, &i) in self.src_of.iter().enumerate() {
            inv[i] = j;
        }
        out.permute_cols(&inv)
    }

    /// Mean squared quantization error vs the original weight.
    pub fn mse(&self, w: &Mat) -> f32 {
        self.dequantize().mse(w)
    }

    /// Storage bytes: codes at `bits` + one f32 scale per row-group.
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() * self.cfg.bits as usize / 8
            + self.scales.len() * 4
            + self.src_of.len() * 2
    }
}

/// RPTQ-style permutation: sort channels by dynamic range (column absmax)
/// so similarly-ranged channels share quantization groups.
pub fn range_sort_perm(w: &Mat) -> Vec<usize> {
    let mut ranges: Vec<(f32, usize)> = (0..w.cols())
        .map(|c| (w.col(c).iter().fold(0.0f32, |m, v| m.max(v.abs())), c))
        .collect();
    ranges.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    ranges.into_iter().map(|(_, c)| c).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    /// Weight with a few high-magnitude outlier channels (the regime where
    /// reordering pays, per RPTQ/DuQuant and the paper's §D).
    fn outlier_weight(rng: &mut Pcg32, c_out: usize, c_in: usize) -> Mat {
        let mut w = Mat::randn(c_out, c_in, 0.05, rng);
        for _ in 0..c_in / 16 {
            let c = rng.below_usize(c_in);
            for r in 0..c_out {
                w[(r, c)] *= 20.0;
            }
        }
        w
    }

    #[test]
    fn roundtrip_identity_perm_small_error() {
        let mut rng = Pcg32::seeded(1);
        let w = Mat::randn(8, 64, 1.0, &mut rng);
        let q = QuantWeight::quantize(&w, QuantCfg::INT8_G64);
        assert!(q.mse(&w) < 1e-4, "int8 mse {}", q.mse(&w));
    }

    #[test]
    fn prop_dequant_in_original_order() {
        testkit::check_n("quant-order", 12, |rng| {
            let w = Mat::randn(4, 64, 1.0, rng);
            let perm = rng.permutation(64);
            let q = QuantWeight::quantize_permuted(&w, &perm, QuantCfg::INT8_G64);
            // Dequantized matrix approximates w element-wise in ORIGINAL order.
            let dq = q.dequantize();
            testkit::assert_close(dq.data(), w.data(), 0.02)
        });
    }

    #[test]
    fn range_sort_reduces_int4_error_with_outliers() {
        let mut rng = Pcg32::seeded(3);
        let mut wins = 0;
        for _ in 0..5 {
            let w = outlier_weight(&mut rng, 16, 128);
            let base = QuantWeight::quantize(&w, QuantCfg::INT4_G64).mse(&w);
            let perm = range_sort_perm(&w);
            let sorted = QuantWeight::quantize_permuted(&w, &perm, QuantCfg::INT4_G64).mse(&w);
            if sorted < base {
                wins += 1;
            }
        }
        assert!(wins >= 4, "range-sort won only {wins}/5");
    }

    #[test]
    fn int4_worse_than_int8() {
        let mut rng = Pcg32::seeded(4);
        let w = Mat::randn(8, 64, 1.0, &mut rng);
        let e8 = QuantWeight::quantize(&w, QuantCfg::INT8_G64).mse(&w);
        let e4 = QuantWeight::quantize(&w, QuantCfg::INT4_G64).mse(&w);
        assert!(e4 > e8 * 10.0, "int4 {e4} vs int8 {e8}");
    }

    #[test]
    fn storage_accounting() {
        let mut rng = Pcg32::seeded(5);
        let w = Mat::randn(8, 128, 1.0, &mut rng);
        let q8 = QuantWeight::quantize(&w, QuantCfg::INT8_G64);
        // codes: 1024 B; scales: 8 rows * 2 groups * 4 B; perm 256 B.
        assert_eq!(q8.storage_bytes(), 8 * 128 + 8 * 2 * 4 + 128 * 2);
    }

    #[test]
    fn zero_weight_handled() {
        let w = Mat::zeros(2, 64);
        let q = QuantWeight::quantize(&w, QuantCfg::INT8_G64);
        assert_eq!(q.mse(&w), 0.0);
    }
}
