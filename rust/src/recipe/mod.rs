//! Composable pruning recipes: metric × permutation × weight-update.
//!
//! The paper's headline claim is that learnable channel permutation
//! "seamlessly integrates with existing one-shot pruning methods" — the
//! three axes of a pruning method are orthogonal:
//!
//! * **what to keep** — an importance metric ([`ScoreMetric`]:
//!   magnitude / Wanda / RIA, wrapping [`crate::pruning::Metric`]);
//! * **how to regroup channels** — a permutation search
//!   ([`PermStrategy`]: identity, RIA's heuristic CP, Pool&Yu greedy
//!   CP, the Sinkhorn LCP trainer, or RPTQ-style range sorting from
//!   [`crate::quant`]);
//! * **what to do with the survivors** — a weight-update policy
//!   ([`WeightUpdate`]: plain masking, or SparseGPT's OBS update).
//!
//! A [`PruneRecipe`] composes one implementation of each with an N:M
//! pattern.  Every row of the paper's Tables 1/2/8 is a recipe (see
//! [`rows`]), the legacy `coordinator::PruneMethod` enum lowers into
//! recipes ([`crate::coordinator::PruneMethod::to_recipe`]), and
//! combinations the closed enum could not express — e.g. a learned
//! permutation *with* SparseGPT's weight update, the ROSE-style row —
//! are one builder chain away.  Recipes serialize to JSON
//! ([`PruneRecipe::to_json`] / [`PruneRecipe::from_json`]) so bench
//! artifacts record exactly which recipe produced a set of weights and
//! `permllm prune --sweep recipes.json` can fan a recipe list out over
//! the worker pool.
//!
//! ## Example: composing a recipe
//!
//! ```
//! use permllm::pruning::Metric;
//! use permllm::recipe::{HeuristicCpPerm, MetricScore, ObsSparseGpt, PruneRecipe};
//! use permllm::sparsity::NmConfig;
//!
//! // RIA scores + heuristic channel permutation + SparseGPT's OBS
//! // update — a combination the legacy PruneMethod enum had no variant
//! // for:
//! let recipe = PruneRecipe::builder(NmConfig::PAT_2_4)
//!     .metric(MetricScore(Metric::Ria))
//!     .perm(HeuristicCpPerm)
//!     .update(ObsSparseGpt::default())
//!     .build();
//! assert_eq!(recipe.name(), "Ria+CP+SparseGPT");
//!
//! // Recipes round-trip through JSON for bench artifacts and sweeps.
//! let back = PruneRecipe::from_json(&recipe.to_json()).unwrap();
//! assert_eq!(back.name(), recipe.name());
//! ```
//!
//! ## Example: the traits are open
//!
//! ```
//! use permllm::recipe::{PruneRecipe, ScoreMetric};
//! use permllm::sparsity::NmConfig;
//! use permllm::tensor::Mat;
//!
//! /// A metric the crate does not ship: activation-blind magnitude
//! /// normalized per row.
//! struct RowRelative;
//! impl ScoreMetric for RowRelative {
//!     fn name(&self) -> String {
//!         "rowrel".into()
//!     }
//!     fn score(&self, w: &Mat, _x: &Mat) -> Mat {
//!         let mut s = w.map(f32::abs);
//!         for r in 0..s.rows() {
//!             let sum: f32 = s.row(r).iter().sum::<f32>() + 1e-12;
//!             for v in s.row_mut(r) {
//!                 *v /= sum;
//!             }
//!         }
//!         s
//!     }
//! }
//!
//! let recipe = PruneRecipe::builder(NmConfig::PAT_2_4).metric(RowRelative).build();
//! assert_eq!(recipe.name(), "Rowrel");
//! ```

use std::fmt;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cp::{greedy_cp, ria_cp};
use crate::lcp::{train_lcp, HostBackend, LayerData, LcpCfg, LcpResult};
use crate::pruning::{prune_scored, sparsegpt, Metric, PruneResult, SparseGptCfg};
use crate::quant::range_sort_perm;
use crate::runtime::{ExecLcpBackend, NativeCfg, NativeEngine};
use crate::sparsity::NmConfig;
use crate::tensor::Mat;
use crate::util::json::{self, Json};

/// How learned-permutation strategies execute the LCP trainer's per-step
/// kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LcpExecutor {
    /// Call [`HostBackend`] directly (no artifact indirection).
    Host,
    /// Route through the [`crate::runtime::ExecBackend`] trait served by
    /// [`NativeEngine`] — the same math behind the artifact interface the
    /// PJRT engine implements.  Numerically identical to `Host` (pinned
    /// by `host_and_native_executors_prune_identically`); pays a small
    /// per-step tensor copy at the trait boundary, an order below the
    /// matmul cost, in exchange for exercising the artifact plumbing on
    /// every default run.  Use `Host` (`--backend host`) to shave that
    /// off when benchmarking raw LCP throughput.
    Native,
}

impl LcpExecutor {
    /// Valid `--backend` CLI values, for error messages.
    pub const VALID: &str = "host, native";

    /// Parse a `--backend` CLI value.
    pub fn parse(s: &str) -> Option<LcpExecutor> {
        match s {
            "host" => Some(LcpExecutor::Host),
            "native" => Some(LcpExecutor::Native),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LcpExecutor::Host => "host",
            LcpExecutor::Native => "native",
        }
    }
}

/// Per-layer context a [`PermStrategy`] runs under: the recipe's N:M
/// pattern plus the pipeline-level defaults a strategy inherits unless
/// its own configuration overrides them.
#[derive(Debug, Clone)]
pub struct PermContext {
    /// Decoder-layer index of the linear being pruned.
    pub layer: usize,
    /// The recipe's sparsity pattern.
    pub nm: NmConfig,
    /// Pipeline-default LCP hyperparameters ([`LearnedPerm`] fields
    /// override individual values).
    pub lcp: LcpCfg,
    /// Pipeline-default partial-PermLLM threshold: layers below it fall
    /// back to heuristic CP (Table 7).
    pub lcp_from_layer: usize,
    /// Pipeline-default LCP kernel executor.
    pub executor: LcpExecutor,
}

// ---------------------------------------------------------------------------
// The three open traits.
// ---------------------------------------------------------------------------

/// Importance scoring: which weights matter (the metric axis of Tables
/// 1/2/8).  Implementations must be deterministic — the same `(w, x)`
/// must give bit-identical scores, or recipe↔legacy parity breaks.
pub trait ScoreMetric: Send + Sync {
    /// Lowercase identifier ("wanda"); row labels capitalize the first
    /// letter, JSON stores it verbatim.
    fn name(&self) -> String;

    /// Importance matrix `S` `[C_out, C_in]` for weight `w` and
    /// calibration activations `x` `[T, C_in]`.
    fn score(&self, w: &Mat, x: &Mat) -> Mat;

    /// JSON descriptor (the built-in deserializer only knows the kinds
    /// in [`METRIC_KINDS`]; custom impls serialize their name and must
    /// be re-attached in code).
    fn to_json(&self) -> Json {
        Json::Str(self.name())
    }
}

/// Channel-permutation search: how input channels are regrouped before
/// the Eq. 7/8 mask (the permutation axis).
pub trait PermStrategy: Send + Sync {
    /// Stable kind identifier for JSON ("identity", "cp", "learned", ...).
    fn kind(&self) -> &'static str;

    /// Compose the metric's row label into the recipe label —
    /// `"Wanda"` -> `"Wanda+CP"` / `"PermLLM_Wanda"` / ...
    fn decorate(&self, base: &str) -> String;

    /// Whether this strategy is the identity (drives the legacy
    /// `"SparseGPT"` label, which drops the metric entirely).
    fn is_identity(&self) -> bool {
        false
    }

    /// Whether [`PermStrategy::permutation`] reads the score matrix.
    /// Strategies that ignore it (identity, range-sort) return `false`
    /// so the pipeline can skip scoring when the update policy ignores
    /// it too; the conservative default is `true`.
    fn needs_scores(&self) -> bool {
        true
    }

    /// Whether the pipeline should keep the identity-permutation result
    /// when it has lower calibration error (the legacy PermLLM guard
    /// against the Fig. 1 failure mode; heuristic CP historically ran
    /// unguarded, so the default is `false`).
    fn guard_identity(&self, _ctx: &PermContext) -> bool {
        false
    }

    /// The permutation (`src_of`: stored column `j` reads original
    /// channel `src_of[j]`) for scores `s`, weight `w`, activations `x`.
    fn permutation(&self, s: &Mat, w: &Mat, x: &Mat, ctx: &PermContext) -> Vec<usize>;

    /// JSON descriptor; strategies with configuration emit an object
    /// with a `kind` field.
    fn to_json(&self) -> Json {
        Json::Str(self.kind().to_string())
    }
}

/// Weight-update policy: what happens to the surviving weights (the
/// "Weight Update" column of Table 2).
pub trait WeightUpdate: Send + Sync {
    /// Stable kind identifier for JSON ("none", "sparsegpt").
    fn kind(&self) -> &'static str;

    /// Whether this policy modifies surviving weight values (Table 2's
    /// "Weight Update" column).  Mask-only policies keep the `false`
    /// default; updating policies must override it — the row label and
    /// the bench JSON report it.
    fn updates_weights(&self) -> bool {
        false
    }

    /// Label component appended to updating rows; `None` keeps the
    /// metric's label unchanged (when [`WeightUpdate::updates_weights`]
    /// is true but no label is given, the capitalized kind is used).
    fn label(&self) -> Option<&'static str> {
        None
    }

    /// Whether [`WeightUpdate::prune`] reads the score matrix.  The OBS
    /// solver picks its own mask, so it returns `false`; the
    /// conservative default is `true`.
    fn needs_scores(&self) -> bool {
        true
    }

    /// Prune `w` under permutation `src_of` with precomputed scores `s`
    /// (`s == metric.score(w, x)`, original channel order; an empty
    /// matrix when neither the strategy nor the update declares
    /// [`WeightUpdate::needs_scores`]).  The returned [`PruneResult`]
    /// is in *storage* (permuted) order with `src_of` recorded.
    fn prune(&self, s: &Mat, w: &Mat, x: &Mat, nm: NmConfig, src_of: &[usize]) -> PruneResult;

    /// JSON descriptor.
    fn to_json(&self) -> Json {
        Json::Str(self.kind().to_string())
    }
}

// ---------------------------------------------------------------------------
// Built-in metric.
// ---------------------------------------------------------------------------

/// The built-in metrics, wrapping [`crate::pruning::Metric`]
/// (magnitude / Wanda / RIA) behind [`ScoreMetric`].
#[derive(Debug, Clone, Copy)]
pub struct MetricScore(pub Metric);

impl ScoreMetric for MetricScore {
    fn name(&self) -> String {
        self.0.name().to_string()
    }

    fn score(&self, w: &Mat, x: &Mat) -> Mat {
        crate::pruning::importance(self.0, w, x)
    }
}

// ---------------------------------------------------------------------------
// Built-in permutation strategies.
// ---------------------------------------------------------------------------

/// No permutation: channels stay in their original order.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdentityPerm;

impl PermStrategy for IdentityPerm {
    fn kind(&self) -> &'static str {
        "identity"
    }

    fn decorate(&self, base: &str) -> String {
        base.to_string()
    }

    fn is_identity(&self) -> bool {
        true
    }

    fn needs_scores(&self) -> bool {
        false
    }

    fn permutation(&self, _s: &Mat, w: &Mat, _x: &Mat, _ctx: &PermContext) -> Vec<usize> {
        (0..w.cols()).collect()
    }
}

/// RIA's two-stage heuristic CP ([`crate::cp::ria_cp`]) — the paper's
/// "+CP" rows.
#[derive(Debug, Clone, Copy, Default)]
pub struct HeuristicCpPerm;

impl PermStrategy for HeuristicCpPerm {
    fn kind(&self) -> &'static str {
        "cp"
    }

    fn decorate(&self, base: &str) -> String {
        format!("{base}+CP")
    }

    fn permutation(&self, s: &Mat, _w: &Mat, _x: &Mat, ctx: &PermContext) -> Vec<usize> {
        ria_cp(s, ctx.nm)
    }
}

/// Pool & Yu-style greedy swap search ([`crate::cp::greedy_cp`]) —
/// exhaustive-ish, only sensible for small layers (Fig. 1's regime).
#[derive(Debug, Clone, Copy)]
pub struct GreedyCpPerm {
    /// Improvement sweeps over all channel pairs.
    pub max_sweeps: usize,
}

impl Default for GreedyCpPerm {
    fn default() -> Self {
        GreedyCpPerm { max_sweeps: 2 }
    }
}

impl PermStrategy for GreedyCpPerm {
    fn kind(&self) -> &'static str {
        "greedy-cp"
    }

    fn decorate(&self, base: &str) -> String {
        format!("{base}+GreedyCP")
    }

    fn permutation(&self, s: &Mat, _w: &Mat, _x: &Mat, ctx: &PermContext) -> Vec<usize> {
        greedy_cp(s, ctx.nm, self.max_sweeps)
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", json::s(self.kind())),
            ("max_sweeps", json::num(self.max_sweeps as f64)),
        ])
    }
}

/// The learnable channel permutation (the paper's core contribution):
/// heuristic-CP warm start, block-wise Sinkhorn/Hungarian refinement
/// through the LCP trainer, keep-best guard against the identity
/// baseline.  Every field is an *override* of the pipeline defaults in
/// [`PermContext`] — `LearnedPerm::default()` reproduces the legacy
/// `PruneMethod::PermLlm` behavior bit for bit.
#[derive(Debug, Clone, Copy, Default)]
pub struct LearnedPerm {
    /// LCP block size B (Table 6 sweeps this through the recipe path).
    pub block: Option<usize>,
    /// Optimization steps.
    pub steps: Option<usize>,
    /// AdamW learning rate.
    pub lr: Option<f32>,
    /// Sinkhorn iterations L (Table 4's ablation axis).
    pub sinkhorn_iters: Option<usize>,
    /// Partial PermLLM (Table 7): layers below this index fall back to
    /// heuristic CP.
    pub from_layer: Option<usize>,
    /// LCP kernel executor.
    pub executor: Option<LcpExecutor>,
}

impl LearnedPerm {
    fn resolved_from_layer(&self, ctx: &PermContext) -> usize {
        self.from_layer.unwrap_or(ctx.lcp_from_layer)
    }

    fn resolve_lcp(&self, ctx: &PermContext) -> LcpCfg {
        let mut cfg = ctx.lcp;
        cfg.nm = ctx.nm;
        if let Some(b) = self.block {
            cfg.block = b;
        }
        if let Some(s) = self.steps {
            cfg.steps = s;
        }
        if let Some(lr) = self.lr {
            cfg.lr = lr;
        }
        if let Some(it) = self.sinkhorn_iters {
            cfg.sinkhorn_iters = it;
        }
        cfg
    }
}

impl PermStrategy for LearnedPerm {
    fn kind(&self) -> &'static str {
        "learned"
    }

    fn decorate(&self, base: &str) -> String {
        format!("PermLLM_{base}")
    }

    fn guard_identity(&self, ctx: &PermContext) -> bool {
        // The keep-best guard only applies where LCP actually ran;
        // partial-PermLLM layers below the threshold use unguarded
        // heuristic CP, exactly like the legacy pipeline.
        ctx.layer >= self.resolved_from_layer(ctx)
    }

    fn permutation(&self, s: &Mat, w: &Mat, x: &Mat, ctx: &PermContext) -> Vec<usize> {
        if ctx.layer < self.resolved_from_layer(ctx) {
            // Partial PermLLM (Table 7): heuristic CP on early layers.
            return ria_cp(s, ctx.nm);
        }
        // Seed LCP from the heuristic CP solution: learn a block-wise
        // *refinement* of the globally-allocated permutation.  Blocks
        // can only express within-block reorderings, so composing with
        // the global heuristic gives LCP the cross-block moves for
        // free; the pipeline's keep-best guard (via `guard_identity`)
        // then guarantees the result never regresses below plain
        // one-shot pruning (paper's Table 1 ordering).
        let perm_cp = ria_cp(s, ctx.nm);
        let w_cp = w.permute_cols(&perm_cp);
        let s_cp = s.permute_cols(&perm_cp);
        let x_cp = x.permute_cols(&perm_cp);
        let data = LayerData::new(w_cp, s_cp, x_cp);

        let mut lcp_cfg = self.resolve_lcp(ctx);
        // Sanitize, then clamp block to the layer width (largest valid
        // divisor).  Arbitrary block values can now arrive via sweep
        // JSON and per-recipe overrides, so first round to a positive
        // multiple of the group size (0 would divide-by-zero below, a
        // non-multiple would underflow the clamp loop), and bound the
        // loop at one group so it always terminates.
        let m = ctx.nm.m;
        lcp_cfg.block = ((lcp_cfg.block / m).max(1) * m).min(w.cols());
        if w.cols() % lcp_cfg.block != 0 {
            let mut b = lcp_cfg.block;
            while b > m && (w.cols() % b != 0 || b % m != 0) {
                b -= m;
            }
            lcp_cfg.block = b.max(m);
        }
        let res = run_lcp(&data, w.cols(), lcp_cfg, ctx.nm, self.executor.unwrap_or(ctx.executor));
        // Compose: global heuristic then block refinement.
        res.src_of.iter().map(|&j| perm_cp[j]).collect()
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![("kind", json::s(self.kind()))];
        if let Some(b) = self.block {
            pairs.push(("block", json::num(b as f64)));
        }
        if let Some(s) = self.steps {
            pairs.push(("steps", json::num(s as f64)));
        }
        if let Some(lr) = self.lr {
            pairs.push(("lr", json::num(lr as f64)));
        }
        if let Some(it) = self.sinkhorn_iters {
            pairs.push(("sinkhorn_iters", json::num(it as f64)));
        }
        if let Some(fl) = self.from_layer {
            pairs.push(("from_layer", json::num(fl as f64)));
        }
        if let Some(e) = self.executor {
            pairs.push(("executor", json::s(e.name())));
        }
        json::obj(pairs)
    }
}

/// Train LCP for one layer through the chosen executor.
///
/// The `Native` path goes through the artifact-name interface
/// ([`ExecLcpBackend`] over [`NativeEngine`]) — the same plumbing the
/// PJRT engine serves — with internal fan-out disabled (`threads: 1`)
/// because this runs inside the pipeline's per-layer worker pool.
fn run_lcp(
    data: &LayerData,
    c_in: usize,
    lcp_cfg: LcpCfg,
    nm: NmConfig,
    executor: LcpExecutor,
) -> LcpResult {
    match executor {
        LcpExecutor::Host => {
            let mut backend = HostBackend::new(data, nm, lcp_cfg.sinkhorn_iters);
            train_lcp(&mut backend, c_in, lcp_cfg)
        }
        LcpExecutor::Native => {
            let mut engine = NativeEngine::new(NativeCfg {
                nm,
                sinkhorn_iters: lcp_cfg.sinkhorn_iters,
                threads: 1,
                model: None,
            });
            let mut backend = ExecLcpBackend::new(&mut engine, data, lcp_cfg.block)
                .expect("native LCP backend");
            train_lcp(&mut backend, c_in, lcp_cfg)
        }
    }
}

/// RPTQ-style range sorting ([`crate::quant::range_sort_perm`]):
/// regroup channels by dynamic range so outliers share groups — the
/// quantization-aware reordering of the paper's §D, composable with any
/// metric and update through the same trait.
#[derive(Debug, Clone, Copy, Default)]
pub struct RangeSortPerm;

impl PermStrategy for RangeSortPerm {
    fn kind(&self) -> &'static str {
        "range-sort"
    }

    fn decorate(&self, base: &str) -> String {
        format!("{base}+RangeSort")
    }

    fn needs_scores(&self) -> bool {
        false
    }

    fn permutation(&self, _s: &Mat, w: &Mat, _x: &Mat, _ctx: &PermContext) -> Vec<usize> {
        range_sort_perm(w)
    }
}

// ---------------------------------------------------------------------------
// Built-in weight updates.
// ---------------------------------------------------------------------------

/// Mask-only: keep surviving weights at their original values
/// (magnitude / Wanda / RIA rows).
#[derive(Debug, Clone, Copy, Default)]
pub struct NoUpdate;

impl WeightUpdate for NoUpdate {
    fn kind(&self) -> &'static str {
        "none"
    }

    fn prune(&self, s: &Mat, w: &Mat, _x: &Mat, nm: NmConfig, src_of: &[usize]) -> PruneResult {
        prune_scored(s, w, nm, src_of)
    }
}

/// SparseGPT's OBS update ([`crate::pruning::sparsegpt`]): mask chosen
/// by OBS saliency, survivors updated column-by-column from the damped
/// calibration Hessian.  Under a non-identity permutation the update
/// runs in permuted channel order — the ROSE-style composition of
/// channel reordering with the OBS solver.
#[derive(Debug, Clone, Copy)]
pub struct ObsSparseGpt {
    /// Relative Hessian dampening (reference: 0.01).
    pub damp: f32,
}

impl Default for ObsSparseGpt {
    fn default() -> Self {
        ObsSparseGpt { damp: SparseGptCfg::default().damp }
    }
}

impl WeightUpdate for ObsSparseGpt {
    fn kind(&self) -> &'static str {
        "sparsegpt"
    }

    fn updates_weights(&self) -> bool {
        true
    }

    fn label(&self) -> Option<&'static str> {
        Some("SparseGPT")
    }

    fn needs_scores(&self) -> bool {
        false
    }

    fn prune(&self, _s: &Mat, w: &Mat, x: &Mat, nm: NmConfig, src_of: &[usize]) -> PruneResult {
        let cfg = SparseGptCfg { damp: self.damp };
        if src_of.iter().enumerate().all(|(j, &i)| j == i) {
            // Identity: the legacy SparseGPT row, bit for bit.
            return sparsegpt(w, x, nm, cfg);
        }
        let wp = w.permute_cols(src_of);
        let xp = x.permute_cols(src_of);
        let mut res = sparsegpt(&wp, &xp, nm, cfg);
        res.src_of = src_of.to_vec();
        res
    }

    fn to_json(&self) -> Json {
        json::obj(vec![("kind", json::s(self.kind())), ("damp", json::num(self.damp as f64))])
    }
}

// ---------------------------------------------------------------------------
// The recipe.
// ---------------------------------------------------------------------------

/// Valid built-in metric kinds (for CLI / JSON error messages).
pub const METRIC_KINDS: &str = "magnitude, wanda, ria";
/// Valid built-in permutation-strategy kinds.
pub const PERM_KINDS: &str = "identity, cp, greedy-cp, learned, range-sort";
/// Valid built-in weight-update kinds.
pub const UPDATE_KINDS: &str = "none, sparsegpt";

/// One composed pruning method: metric × permutation × update × N:M.
///
/// Cloning is cheap (the components are shared behind [`Arc`]), so
/// benches declare row lists of recipes and the pipeline fans each
/// layer's pruning out over worker threads with a shared recipe.
#[derive(Clone)]
pub struct PruneRecipe {
    /// Importance scoring.
    pub metric: Arc<dyn ScoreMetric>,
    /// Channel-permutation search.
    pub perm: Arc<dyn PermStrategy>,
    /// Weight-update policy.
    pub update: Arc<dyn WeightUpdate>,
    /// Sparsity pattern.
    pub nm: NmConfig,
    /// The "Dense" row: skip pruning entirely (no metric/perm/update
    /// runs; they are kept only so the struct stays uniform).
    dense: bool,
}

impl fmt::Debug for PruneRecipe {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PruneRecipe({} @ {})", self.name(), self.nm.name())
    }
}

impl PruneRecipe {
    /// Start composing a recipe (defaults: Wanda metric, identity
    /// permutation, no weight update).
    pub fn builder(nm: NmConfig) -> RecipeBuilder {
        RecipeBuilder {
            metric: Arc::new(MetricScore(Metric::Wanda)),
            perm: Arc::new(IdentityPerm),
            update: Arc::new(NoUpdate),
            nm,
        }
    }

    /// Compose from already-shared components.
    pub fn from_parts(
        metric: Arc<dyn ScoreMetric>,
        perm: Arc<dyn PermStrategy>,
        update: Arc<dyn WeightUpdate>,
        nm: NmConfig,
    ) -> PruneRecipe {
        PruneRecipe { metric, perm, update, nm, dense: false }
    }

    /// The unpruned baseline row.
    pub fn dense(nm: NmConfig) -> PruneRecipe {
        PruneRecipe {
            metric: Arc::new(MetricScore(Metric::Magnitude)),
            perm: Arc::new(IdentityPerm),
            update: Arc::new(NoUpdate),
            nm,
            dense: true,
        }
    }

    /// One-shot metric, no permutation, no update (the Wanda/RIA rows).
    pub fn oneshot(metric: Metric, nm: NmConfig) -> PruneRecipe {
        Self::builder(nm).metric(MetricScore(metric)).build()
    }

    /// The legacy SparseGPT row: identity permutation + OBS update (the
    /// metric is unused — the OBS solver picks its own mask).
    pub fn sparsegpt(nm: NmConfig) -> PruneRecipe {
        Self::builder(nm)
            .metric(MetricScore(Metric::Magnitude))
            .update(ObsSparseGpt::default())
            .build()
    }

    /// Whether this is the unpruned "Dense" row.
    pub fn is_dense(&self) -> bool {
        self.dense
    }

    /// Whether the recipe updates surviving weight values (Table 2's
    /// "Weight Update" column).
    pub fn updates_weights(&self) -> bool {
        !self.dense && self.update.updates_weights()
    }

    /// Canonical row label.  Reproduces the legacy Table-1/2/8 labels
    /// exactly ("Dense", "SparseGPT", "Wanda", "Wanda+CP",
    /// "PermLLM_Wanda", ...) and extends them compositionally
    /// ("PermLLM_Wanda+SparseGPT", "Ria+RangeSort", ...).
    pub fn name(&self) -> String {
        if self.dense {
            return "Dense".into();
        }
        let base = cap(&self.metric.name());
        // Updating policies always surface in the label: their declared
        // label component, or the capitalized kind as a fallback so a
        // custom policy without one is never misreported as mask-only.
        let suffix = match self.update.label() {
            Some(u) => Some(u.to_string()),
            None if self.update.updates_weights() => Some(cap(self.update.kind())),
            None => None,
        };
        match suffix {
            None => self.perm.decorate(&base),
            // Identity + an updating policy is the legacy SparseGPT-row
            // shape, whose label never mentioned a metric (the OBS
            // solver ignores it).
            Some(u) if self.perm.is_identity() => u,
            Some(u) => format!("{}+{u}", self.perm.decorate(&base)),
        }
    }

    /// JSON descriptor — stamped into bench artifacts
    /// (`sparse_inference --json`, `BENCH_serving.json`) so every
    /// result records which recipe produced the weights.
    pub fn to_json(&self) -> Json {
        if self.dense {
            return json::obj(vec![
                ("name", json::s("Dense")),
                ("dense", Json::Bool(true)),
                ("nm", json::s(&self.nm.name())),
            ]);
        }
        json::obj(vec![
            ("name", json::s(&self.name())),
            ("nm", json::s(&self.nm.name())),
            ("metric", self.metric.to_json()),
            ("perm", self.perm.to_json()),
            ("update", self.update.to_json()),
        ])
    }

    /// Rebuild a recipe from its JSON descriptor (built-in kinds only;
    /// a custom trait impl deserializes to an error naming the valid
    /// values).  Missing fields default to Wanda / identity / none /
    /// 2:4.
    pub fn from_json(v: &Json) -> Result<PruneRecipe> {
        let _ = v
            .as_obj()
            .ok_or_else(|| anyhow!("recipe must be a JSON object, got {}", v.to_string()))?;
        let nm = match v.get("nm") {
            None => NmConfig::PAT_2_4,
            Some(j) => {
                let s = j
                    .as_str()
                    .ok_or_else(|| anyhow!("recipe 'nm' must be a string like \"2:4\""))?;
                NmConfig::parse(s).ok_or_else(|| {
                    anyhow!("bad recipe 'nm' value '{s}' (expected zeros:group, e.g. 2:4 or 4:8)")
                })?
            }
        };
        if matches!(v.get("dense"), Some(Json::Bool(true)))
            || v.get("name").and_then(Json::as_str) == Some("Dense")
        {
            return Ok(PruneRecipe::dense(nm));
        }
        let metric = match v.get("metric") {
            None => Arc::new(MetricScore(Metric::Wanda)) as Arc<dyn ScoreMetric>,
            Some(j) => {
                let s = j.as_str().ok_or_else(|| anyhow!("recipe 'metric' must be a string"))?;
                metric_from_kind(s)?
            }
        };
        let perm = match v.get("perm") {
            None => Arc::new(IdentityPerm) as Arc<dyn PermStrategy>,
            Some(j) => perm_from_json(j)?,
        };
        let update = match v.get("update") {
            None => Arc::new(NoUpdate) as Arc<dyn WeightUpdate>,
            Some(j) => update_from_json(j)?,
        };
        Ok(PruneRecipe::from_parts(metric, perm, update, nm))
    }
}

/// Builder for [`PruneRecipe`]; every axis has a default so rows read
/// as deltas from plain one-shot Wanda.
pub struct RecipeBuilder {
    metric: Arc<dyn ScoreMetric>,
    perm: Arc<dyn PermStrategy>,
    update: Arc<dyn WeightUpdate>,
    nm: NmConfig,
}

impl RecipeBuilder {
    pub fn metric(mut self, m: impl ScoreMetric + 'static) -> Self {
        self.metric = Arc::new(m);
        self
    }

    /// Convenience for the built-in metrics.
    pub fn metric_kind(self, m: Metric) -> Self {
        self.metric(MetricScore(m))
    }

    pub fn perm(mut self, p: impl PermStrategy + 'static) -> Self {
        self.perm = Arc::new(p);
        self
    }

    pub fn update(mut self, u: impl WeightUpdate + 'static) -> Self {
        self.update = Arc::new(u);
        self
    }

    pub fn build(self) -> PruneRecipe {
        PruneRecipe {
            metric: self.metric,
            perm: self.perm,
            update: self.update,
            nm: self.nm,
            dense: false,
        }
    }
}

fn cap(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

// ---------------------------------------------------------------------------
// Kind parsing (shared by JSON deserialization and the CLI flags, so
// both fail with the same valid-value lists).
// ---------------------------------------------------------------------------

/// Resolve a built-in metric kind string.
pub fn metric_from_kind(s: &str) -> Result<Arc<dyn ScoreMetric>> {
    Metric::parse(s)
        .map(|m| Arc::new(MetricScore(m)) as Arc<dyn ScoreMetric>)
        .ok_or_else(|| anyhow!("unknown metric '{s}' (valid: {METRIC_KINDS})"))
}

/// Resolve a built-in permutation-strategy kind string (no
/// configuration — use [`perm_from_json`] for configured strategies).
pub fn perm_from_kind(s: &str) -> Result<Arc<dyn PermStrategy>> {
    match s {
        "identity" | "none" => Ok(Arc::new(IdentityPerm)),
        "cp" | "heuristic-cp" => Ok(Arc::new(HeuristicCpPerm)),
        "greedy-cp" => Ok(Arc::new(GreedyCpPerm::default())),
        "learned" | "lcp" => Ok(Arc::new(LearnedPerm::default())),
        "range-sort" | "rangesort" => Ok(Arc::new(RangeSortPerm)),
        _ => Err(anyhow!("unknown permutation strategy '{s}' (valid: {PERM_KINDS})")),
    }
}

/// Resolve a built-in weight-update kind string.
pub fn update_from_kind(s: &str) -> Result<Arc<dyn WeightUpdate>> {
    match s {
        "none" => Ok(Arc::new(NoUpdate)),
        "sparsegpt" | "obs" => Ok(Arc::new(ObsSparseGpt::default())),
        _ => Err(anyhow!("unknown weight update '{s}' (valid: {UPDATE_KINDS})")),
    }
}

/// Parse a permutation descriptor: either a kind string or an object
/// `{"kind": ..., <overrides>}`.
pub fn perm_from_json(v: &Json) -> Result<Arc<dyn PermStrategy>> {
    match v {
        Json::Str(s) => perm_from_kind(s),
        Json::Obj(_) => {
            let kind = v.get("kind").and_then(Json::as_str).ok_or_else(|| {
                anyhow!("permutation object needs a string 'kind' (valid: {PERM_KINDS})")
            })?;
            match kind {
                "learned" | "lcp" => {
                    let get_usize = |k: &str| v.get(k).and_then(Json::as_usize);
                    Ok(Arc::new(LearnedPerm {
                        block: get_usize("block"),
                        steps: get_usize("steps"),
                        lr: v.get("lr").and_then(Json::as_f64).map(|x| x as f32),
                        sinkhorn_iters: get_usize("sinkhorn_iters"),
                        from_layer: get_usize("from_layer"),
                        executor: match v.get("executor").and_then(Json::as_str) {
                            None => None,
                            Some(e) => Some(LcpExecutor::parse(e).ok_or_else(|| {
                                anyhow!("unknown executor '{e}' (valid: {})", LcpExecutor::VALID)
                            })?),
                        },
                    }))
                }
                "greedy-cp" => Ok(Arc::new(GreedyCpPerm {
                    max_sweeps: v
                        .get("max_sweeps")
                        .and_then(Json::as_usize)
                        .unwrap_or_else(|| GreedyCpPerm::default().max_sweeps),
                })),
                other => perm_from_kind(other),
            }
        }
        _ => Err(anyhow!("permutation must be a kind string or object (valid kinds: {PERM_KINDS})")),
    }
}

/// Parse a weight-update descriptor: a kind string or
/// `{"kind": ..., <overrides>}`.
pub fn update_from_json(v: &Json) -> Result<Arc<dyn WeightUpdate>> {
    match v {
        Json::Str(s) => update_from_kind(s),
        Json::Obj(_) => {
            let kind = v.get("kind").and_then(Json::as_str).ok_or_else(|| {
                anyhow!("update object needs a string 'kind' (valid: {UPDATE_KINDS})")
            })?;
            match kind {
                "sparsegpt" | "obs" => Ok(Arc::new(ObsSparseGpt {
                    damp: v
                        .get("damp")
                        .and_then(Json::as_f64)
                        .map(|d| d as f32)
                        .unwrap_or_else(|| ObsSparseGpt::default().damp),
                })),
                other => update_from_kind(other),
            }
        }
        _ => Err(anyhow!("update must be a kind string or object (valid kinds: {UPDATE_KINDS})")),
    }
}

// ---------------------------------------------------------------------------
// Canonical row lists (the paper tables, shared by the bench binaries
// and the label-pinning tests).
// ---------------------------------------------------------------------------

/// The paper-table row declarations, shared by `benches/table*.rs` and
/// the label-pinning tests so a bench can never drift from the pinned
/// labels.
pub mod rows {
    use super::*;

    /// Table 1's method rows at `nm` (plus the ROSE-style learned-perm +
    /// OBS-update row the closed enum could not express, appended last).
    pub fn table1(nm: NmConfig) -> Vec<PruneRecipe> {
        vec![
            PruneRecipe::dense(nm),
            PruneRecipe::sparsegpt(nm),
            PruneRecipe::oneshot(Metric::Wanda, nm),
            PruneRecipe::builder(nm).metric_kind(Metric::Wanda).perm(HeuristicCpPerm).build(),
            PruneRecipe::builder(nm).metric_kind(Metric::Wanda).perm(LearnedPerm::default()).build(),
            PruneRecipe::oneshot(Metric::Ria, nm),
            PruneRecipe::builder(nm).metric_kind(Metric::Ria).perm(HeuristicCpPerm).build(),
            PruneRecipe::builder(nm).metric_kind(Metric::Ria).perm(LearnedPerm::default()).build(),
            PruneRecipe::builder(nm)
                .metric_kind(Metric::Wanda)
                .perm(LearnedPerm::default())
                .update(ObsSparseGpt::default())
                .build(),
        ]
    }

    /// The Table 2 / Table 8 headline rows at `nm`.
    pub fn headline(nm: NmConfig) -> Vec<PruneRecipe> {
        vec![
            PruneRecipe::dense(nm),
            PruneRecipe::sparsegpt(nm),
            PruneRecipe::oneshot(Metric::Wanda, nm),
            PruneRecipe::builder(nm).metric_kind(Metric::Wanda).perm(HeuristicCpPerm).build(),
            PruneRecipe::builder(nm).metric_kind(Metric::Wanda).perm(LearnedPerm::default()).build(),
        ]
    }

    /// Table 2's "Weight Update" column for a recipe row.
    pub fn weight_update_cell(r: &PruneRecipe) -> &'static str {
        if r.is_dense() {
            "-"
        } else if r.updates_weights() {
            "yes"
        } else {
            "no"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::{importance, prune_oneshot, prune_permuted};
    use crate::util::rng::Pcg32;

    fn ctx(nm: NmConfig) -> PermContext {
        PermContext {
            layer: 0,
            nm,
            lcp: LcpCfg { block: 8, steps: 6, lr: 0.1, nm, ..Default::default() },
            lcp_from_layer: 0,
            executor: LcpExecutor::Native,
        }
    }

    fn layer(rng: &mut Pcg32) -> (Mat, Mat) {
        (Mat::randn(8, 16, 1.0, rng), Mat::randn(12, 16, 1.0, rng))
    }

    #[test]
    fn legacy_labels_are_pinned() {
        let nm = NmConfig::PAT_2_4;
        // The exact Table-1 row labels the legacy enum produced.
        let want = [
            "Dense",
            "SparseGPT",
            "Wanda",
            "Wanda+CP",
            "PermLLM_Wanda",
            "Ria",
            "Ria+CP",
            "PermLLM_Ria",
            "PermLLM_Wanda+SparseGPT",
        ];
        let got: Vec<String> = rows::table1(nm).iter().map(PruneRecipe::name).collect();
        assert_eq!(got, want);
        assert_eq!(PruneRecipe::oneshot(Metric::Magnitude, nm).name(), "Magnitude");
        // Novel compositions get systematic labels.
        let rose = PruneRecipe::builder(nm)
            .metric_kind(Metric::Ria)
            .perm(HeuristicCpPerm)
            .update(ObsSparseGpt::default())
            .build();
        assert_eq!(rose.name(), "Ria+CP+SparseGPT");
        let rs = PruneRecipe::builder(nm).metric_kind(Metric::Wanda).perm(RangeSortPerm).build();
        assert_eq!(rs.name(), "Wanda+RangeSort");
        let greedy = PruneRecipe::builder(nm).perm(GreedyCpPerm::default()).build();
        assert_eq!(greedy.name(), "Wanda+GreedyCP");
    }

    #[test]
    fn weight_update_cells_match_table2() {
        let cells: Vec<&str> =
            rows::headline(NmConfig::PAT_2_4).iter().map(rows::weight_update_cell).collect();
        assert_eq!(cells, ["-", "yes", "no", "no", "no"]);
    }

    #[test]
    fn custom_updating_policy_without_label_is_still_reported() {
        // updates_weights is decoupled from label: a third-party policy
        // that modifies weights but declares no label component must
        // still show "yes" in the WeightUpd column and surface in the
        // row name (via its capitalized kind).
        struct DampAll;
        impl WeightUpdate for DampAll {
            fn kind(&self) -> &'static str {
                "damp-all"
            }
            fn updates_weights(&self) -> bool {
                true
            }
            fn prune(&self, s: &Mat, w: &Mat, _x: &Mat, nm: NmConfig, src: &[usize]) -> PruneResult {
                let mut res = prune_scored(s, w, nm, src);
                for v in res.weight.data_mut() {
                    *v *= 0.5;
                }
                res
            }
        }
        let recipe = PruneRecipe::builder(NmConfig::PAT_2_4).update(DampAll).build();
        assert!(recipe.updates_weights());
        assert_eq!(rows::weight_update_cell(&recipe), "yes");
        assert_eq!(recipe.name(), "Damp-all");
        let with_perm = PruneRecipe::builder(NmConfig::PAT_2_4)
            .perm(HeuristicCpPerm)
            .update(DampAll)
            .build();
        assert_eq!(with_perm.name(), "Wanda+CP+Damp-all");
    }

    #[test]
    fn json_roundtrip_preserves_every_row() {
        let mut all = rows::table1(NmConfig::PAT_2_4);
        all.extend(rows::headline(NmConfig::PAT_4_8));
        all.push(
            PruneRecipe::builder(NmConfig::PAT_2_4)
                .metric_kind(Metric::Ria)
                .perm(LearnedPerm {
                    block: Some(32),
                    steps: Some(12),
                    lr: Some(0.1),
                    sinkhorn_iters: Some(3),
                    from_layer: Some(2),
                    executor: Some(LcpExecutor::Host),
                })
                .update(ObsSparseGpt { damp: 0.02 })
                .build(),
        );
        all.push(PruneRecipe::builder(NmConfig::PAT_2_4).perm(RangeSortPerm).build());
        for recipe in all {
            let j = recipe.to_json();
            let back = PruneRecipe::from_json(&j).unwrap();
            assert_eq!(back.name(), recipe.name(), "{j:?}");
            assert_eq!(back.nm, recipe.nm);
            assert_eq!(back.to_json(), j, "roundtrip must be a fixpoint");
        }
    }

    #[test]
    fn from_json_errors_name_the_valid_values() {
        let bad_metric = Json::parse(r#"{"metric": "l0"}"#).unwrap();
        let e = PruneRecipe::from_json(&bad_metric).unwrap_err().to_string();
        assert!(e.contains(METRIC_KINDS), "{e}");
        let bad_perm = Json::parse(r#"{"perm": "hungarian"}"#).unwrap();
        let e = PruneRecipe::from_json(&bad_perm).unwrap_err().to_string();
        assert!(e.contains(PERM_KINDS), "{e}");
        let bad_update = Json::parse(r#"{"update": "adamw"}"#).unwrap();
        let e = PruneRecipe::from_json(&bad_update).unwrap_err().to_string();
        assert!(e.contains(UPDATE_KINDS), "{e}");
        let bad_nm = Json::parse(r#"{"nm": "4:2"}"#).unwrap();
        assert!(PruneRecipe::from_json(&bad_nm).is_err());
        assert!(PruneRecipe::from_json(&Json::parse("[1]").unwrap()).is_err());
    }

    #[test]
    fn no_update_matches_oneshot_and_permuted_bitwise() {
        let mut rng = Pcg32::seeded(1);
        let (w, x) = layer(&mut rng);
        let nm = NmConfig::PAT_2_4;
        for metric in [Metric::Magnitude, Metric::Wanda, Metric::Ria] {
            let s = importance(metric, &w, &x);
            let id: Vec<usize> = (0..w.cols()).collect();
            let a = NoUpdate.prune(&s, &w, &x, nm, &id);
            let b = prune_oneshot(metric, &w, &x, nm);
            assert_eq!(a.weight.data(), b.weight.data(), "{}", metric.name());
            assert_eq!(a.src_of, b.src_of);
            let perm = rng.permutation(w.cols());
            let a = NoUpdate.prune(&s, &w, &x, nm, &perm);
            let b = prune_permuted(metric, &w, &x, nm, &perm);
            assert_eq!(a.weight.data(), b.weight.data(), "{}", metric.name());
            assert_eq!(a.src_of, b.src_of);
        }
    }

    #[test]
    fn obs_update_matches_sparsegpt_bitwise_at_identity() {
        let mut rng = Pcg32::seeded(2);
        let (w, x) = layer(&mut rng);
        let nm = NmConfig::PAT_2_4;
        let s = importance(Metric::Wanda, &w, &x);
        let id: Vec<usize> = (0..w.cols()).collect();
        let a = ObsSparseGpt::default().prune(&s, &w, &x, nm, &id);
        let b = sparsegpt(&w, &x, nm, SparseGptCfg::default());
        assert_eq!(a.weight.data(), b.weight.data());
        assert_eq!(a.src_of, b.src_of);
    }

    #[test]
    fn obs_update_composes_with_a_permutation() {
        // ROSE-style: reorder channels, then run the OBS solver in the
        // permuted order.  The result must be a valid N:M prune whose
        // runtime path (permute activations, sparse matmul) is coherent.
        let mut rng = Pcg32::seeded(3);
        let (w, x) = layer(&mut rng);
        let nm = NmConfig::PAT_2_4;
        let s = importance(Metric::Ria, &w, &x);
        let perm = rng.permutation(w.cols());
        let res = ObsSparseGpt::default().prune(&s, &w, &x, nm, &perm);
        assert!(res.mask.verify());
        assert_eq!(res.src_of, perm);
        assert!(res.weight.data().iter().all(|v| v.is_finite()));
        // And matches running sparsegpt on explicitly permuted inputs.
        let direct =
            sparsegpt(&w.permute_cols(&perm), &x.permute_cols(&perm), nm, SparseGptCfg::default());
        assert_eq!(res.weight.data(), direct.weight.data());
    }

    #[test]
    fn range_sort_perm_strategy_matches_quant_helper() {
        // Satellite: quantization-aware reordering composes with any
        // metric through the open trait.
        let mut rng = Pcg32::seeded(4);
        let (w, x) = layer(&mut rng);
        let nm = NmConfig::PAT_2_4;
        let s = importance(Metric::Wanda, &w, &x);
        let got = RangeSortPerm.permutation(&s, &w, &x, &ctx(nm));
        assert_eq!(got, range_sort_perm(&w));
        // Full composition parity: recipe-layer prune == prune_permuted
        // with the quant helper's permutation.
        let res = NoUpdate.prune(&s, &w, &x, nm, &got);
        let want = prune_permuted(Metric::Wanda, &w, &x, nm, &range_sort_perm(&w));
        assert_eq!(res.weight.data(), want.weight.data());
        assert!(res.mask.verify());
    }

    #[test]
    fn identity_perm_is_identity() {
        let mut rng = Pcg32::seeded(5);
        let (w, x) = layer(&mut rng);
        let nm = NmConfig::PAT_2_4;
        let s = importance(Metric::Wanda, &w, &x);
        let c = ctx(nm);
        assert_eq!(IdentityPerm.permutation(&s, &w, &x, &c), (0..16).collect::<Vec<_>>());
        assert!(IdentityPerm.is_identity());
        assert!(!IdentityPerm.guard_identity(&c));
    }

    #[test]
    fn learned_perm_respects_from_layer_and_guard() {
        let mut rng = Pcg32::seeded(6);
        let (w, x) = layer(&mut rng);
        let nm = NmConfig::PAT_2_4;
        let s = importance(Metric::Wanda, &w, &x);
        let mut c = ctx(nm);
        let lp = LearnedPerm { from_layer: Some(2), ..Default::default() };
        // Below the threshold: heuristic CP, unguarded.
        c.layer = 1;
        assert_eq!(lp.permutation(&s, &w, &x, &c), ria_cp(&s, nm));
        assert!(!lp.guard_identity(&c));
        // At the threshold: LCP runs (valid block-respecting perm) and
        // the keep-best guard applies.
        c.layer = 2;
        assert!(lp.guard_identity(&c));
        let perm = lp.permutation(&s, &w, &x, &c);
        let mut seen = vec![false; 16];
        for &i in &perm {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn learned_perm_sanitizes_hostile_block_overrides() {
        // Arbitrary block values arrive via sweep JSON / CLI now:
        // 0 must not divide-by-zero and a non-multiple of M must not
        // underflow the clamp loop — both settle on a valid divisor
        // and produce a proper permutation.
        let mut rng = Pcg32::seeded(8);
        let (w, x) = layer(&mut rng);
        let nm = NmConfig::PAT_2_4;
        let s = importance(Metric::Wanda, &w, &x);
        let c = ctx(nm);
        for bad_block in [0usize, 5, 7, 1000] {
            let lp = LearnedPerm { block: Some(bad_block), ..Default::default() };
            let perm = lp.permutation(&s, &w, &x, &c);
            let mut seen = vec![false; w.cols()];
            for &i in &perm {
                assert!(!seen[i], "block={bad_block} produced a non-permutation");
                seen[i] = true;
            }
        }
    }

    #[test]
    fn learned_perm_overrides_resolve_over_context() {
        let nm = NmConfig::PAT_2_4;
        let c = ctx(nm);
        let lp = LearnedPerm { block: Some(4), lr: Some(0.5), ..Default::default() };
        let resolved = lp.resolve_lcp(&c);
        assert_eq!(resolved.block, 4);
        assert_eq!(resolved.lr, 0.5);
        // Unset fields inherit the pipeline defaults.
        assert_eq!(resolved.steps, c.lcp.steps);
        assert_eq!(resolved.sinkhorn_iters, c.lcp.sinkhorn_iters);
        assert_eq!(resolved.nm, nm);
    }

    #[test]
    fn novel_learned_plus_obs_runs_end_to_end_on_a_layer() {
        // The acceptance combination: learned permutation + SparseGPT
        // update, at the layer level.
        let mut rng = Pcg32::seeded(7);
        let (w, x) = layer(&mut rng);
        let nm = NmConfig::PAT_2_4;
        let s = importance(Metric::Wanda, &w, &x);
        let c = ctx(nm);
        let perm = LearnedPerm::default().permutation(&s, &w, &x, &c);
        let res = ObsSparseGpt::default().prune(&s, &w, &x, nm, &perm);
        assert!(res.mask.verify());
        assert_eq!(res.src_of, perm);
        // The OBS update must actually change surviving values somewhere.
        let masked_only = NoUpdate.prune(&s, &w, &x, nm, &perm);
        assert_ne!(res.weight.data(), masked_only.weight.data());
    }
}
