//! AOT-artifact gradient backend for the LCP trainer.
//!
//! Drop-in [`LcpBackend`] that routes `soft_perms` through the
//! `sinkhorn_soft_{n}x{b}` artifact and `loss_grad` through
//! `lcp_grad_{c_out}x{c_in}` — the L1 Pallas kernels and L2 STE graph run
//! inside XLA while Rust keeps the Hungarian hardening and AdamW loop.
//! Cross-checked against the pure-Rust [`HostBackend`] in
//! `tests/lcp_cross_check.rs`.

use anyhow::Result;

use super::convert::{literal_to_vec, mat_to_literal, scalar_literal, vec_to_literal};
use super::engine::Engine;
use crate::lcp::{LayerData, LcpBackend};
use crate::tensor::Mat;

/// Artifact-powered LCP gradient backend for one layer shape.
pub struct ArtifactBackend<'e> {
    engine: &'e mut Engine,
    grad_name: String,
    sink_name: String,
    n_b: usize,
    block: usize,
    /// Pre-converted layer literals (w, s, x, y) reused every step.
    w_lit: xla::Literal,
    s_lit: xla::Literal,
    x_lit: xla::Literal,
    y_lit: xla::Literal,
}

impl<'e> ArtifactBackend<'e> {
    /// Build for layer `data`; resolves the artifact names from the shape.
    pub fn new(engine: &'e mut Engine, data: &LayerData) -> Result<ArtifactBackend<'e>> {
        let (c_out, c_in) = data.w.shape();
        let grad_name = format!("lcp_grad_{c_out}x{c_in}");
        let spec = engine
            .manifest()
            .artifact(&grad_name)
            .ok_or_else(|| anyhow::anyhow!("no artifact {grad_name} (rebuild with this shape)"))?;
        let n_b = spec.attrs["n_b"];
        let block = spec.attrs["block"];
        let calib_rows = spec.inputs.iter().find(|i| i.name == "x").unwrap().shape[0];
        anyhow::ensure!(
            data.x.rows() == calib_rows,
            "calibration rows {} != artifact expectation {calib_rows}",
            data.x.rows()
        );
        let sink_name = format!("sinkhorn_soft_{n_b}x{block}");
        Ok(ArtifactBackend {
            grad_name,
            sink_name,
            n_b,
            block,
            w_lit: mat_to_literal(&data.w)?,
            s_lit: mat_to_literal(&data.s)?,
            x_lit: mat_to_literal(&data.x)?,
            y_lit: mat_to_literal(&data.y)?,
            engine,
        })
    }

    fn stack_blocks(&self, blocks: &[Mat]) -> Result<xla::Literal> {
        let b = self.block;
        let mut flat = Vec::with_capacity(self.n_b * b * b);
        for blk in blocks {
            flat.extend_from_slice(blk.data());
        }
        vec_to_literal(&flat, &[self.n_b, b, b])
    }

    fn unstack_blocks(&self, flat: &[f32]) -> Vec<Mat> {
        let b = self.block;
        (0..self.n_b)
            .map(|n| Mat::from_vec(b, b, flat[n * b * b..(n + 1) * b * b].to_vec()))
            .collect()
    }
}

impl LcpBackend for ArtifactBackend<'_> {
    fn soft_perms(&mut self, w_p: &[Mat], tau: f32) -> Vec<Mat> {
        let inputs = [self.stack_blocks(w_p).unwrap(), scalar_literal(tau).unwrap()];
        let outs = self.engine.run(&self.sink_name, &inputs).expect("sinkhorn artifact");
        self.unstack_blocks(&literal_to_vec(&outs[0]).unwrap())
    }

    fn loss_grad(&mut self, w_p: &[Mat], p_hard_src: &[Vec<usize>], tau: f32) -> (f32, Vec<Mat>) {
        // src_of -> dense permutation blocks (P[src_of[j], j] = 1).
        let b = self.block;
        let hard_blocks: Vec<Mat> = p_hard_src
            .iter()
            .map(|src| {
                let mut p = Mat::zeros(b, b);
                for (j, &i) in src.iter().enumerate() {
                    p[(i, j)] = 1.0;
                }
                p
            })
            .collect();
        let inputs = [
            self.w_lit.clone(),
            self.s_lit.clone(),
            self.x_lit.clone(),
            self.y_lit.clone(),
            self.stack_blocks(w_p).unwrap(),
            self.stack_blocks(&hard_blocks).unwrap(),
            scalar_literal(tau).unwrap(),
        ];
        let outs = self.engine.run(&self.grad_name, &inputs).expect("lcp_grad artifact");
        let loss = literal_to_vec(&outs[0]).unwrap()[0];
        let grads = self.unstack_blocks(&literal_to_vec(&outs[1]).unwrap());
        (loss, grads)
    }
}
