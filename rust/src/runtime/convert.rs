//! Host <-> XLA literal conversion helpers (`--features pjrt` only).

use anyhow::Result;

use super::exec::TensorValue;
use crate::tensor::Mat;

/// Literal from a backend-boundary tensor value (dtype-preserving).
pub fn value_to_literal(v: &TensorValue) -> Result<xla::Literal> {
    let dims: Vec<i64> = v.shape().iter().map(|&d| d as i64).collect();
    Ok(match v {
        TensorValue::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        TensorValue::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
    })
}

/// `[rows, cols]` f32 literal from a host matrix.
pub fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
    let (r, c) = m.shape();
    Ok(xla::Literal::vec1(m.data()).reshape(&[r as i64, c as i64])?)
}

/// f32 literal of arbitrary shape from a flat buffer.
pub fn vec_to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape/{n} vs data/{}", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// `[b, t]` i32 token literal.
pub fn tokens_to_literal(tokens: &[i32], b: usize, t: usize) -> Result<xla::Literal> {
    anyhow::ensure!(tokens.len() == b * t);
    Ok(xla::Literal::vec1(tokens).reshape(&[b as i64, t as i64])?)
}

/// `(1,)` f32 literal (the AOT graphs take scalars as rank-1 size-1).
pub fn scalar_literal(v: f32) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&[v]).reshape(&[1])?)
}

/// Flatten any f32 literal back to a host vector.
pub fn literal_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn mat_roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let m = Mat::randn(3, 5, 1.0, &mut rng);
        let l = mat_to_literal(&m).unwrap();
        assert_eq!(l.element_count(), 15);
        let back = literal_to_vec(&l).unwrap();
        assert_eq!(back, m.data());
    }

    #[test]
    fn scalar_shape() {
        let l = scalar_literal(2.5).unwrap();
        assert_eq!(l.element_count(), 1);
        assert_eq!(literal_to_vec(&l).unwrap(), vec![2.5]);
    }

    #[test]
    fn vec_shape_mismatch_rejected() {
        assert!(vec_to_literal(&[1.0, 2.0], &[3]).is_err());
    }
}
