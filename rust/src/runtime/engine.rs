//! Artifact engine: compile-once, execute-many over the PJRT CPU client.
//!
//! Compiled only with `--features pjrt`; implements [`ExecBackend`] so the
//! rest of the codebase is agnostic to which engine serves an artifact.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::convert::value_to_literal;
use super::exec::{ExecBackend, TensorValue};
use super::manifest::Manifest;

/// Owns the PJRT client and every compiled artifact executable.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Load `manifest.json` from `dir` and compile every artifact eagerly.
    pub fn load(dir: &Path) -> Result<Engine> {
        let mut e = Engine::load_lazy(dir)?;
        let names: Vec<String> = e.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            e.ensure_compiled(&n)?;
        }
        Ok(e)
    }

    /// Load the manifest but compile artifacts on first use.
    pub fn load_lazy(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        log::info!(
            "PJRT platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Engine { client, manifest, dir: dir.to_path_buf(), executables: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile an artifact if not already compiled.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact: literals in, tuple-decomposed literals out.
    ///
    /// Validates input arity against the manifest spec so shape bugs
    /// surface as errors, not crashes inside XLA.
    pub fn run_literals(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.artifact(name).unwrap();
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "artifact {name}: got {} inputs, manifest expects {}",
            inputs.len(),
            spec.inputs.len()
        );
        for (lit, io) in inputs.iter().zip(&spec.inputs) {
            anyhow::ensure!(
                lit.element_count() == io.elements(),
                "artifact {name}: input '{}' has {} elements, expected {:?}",
                io.name,
                lit.element_count(),
                io.shape
            );
        }
        let exe = self.executables.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} result"))?;
        // AOT graphs are lowered with return_tuple=True.
        let outs = lit.to_tuple()?;
        anyhow::ensure!(
            outs.len() == spec.outputs.len(),
            "artifact {name}: got {} outputs, manifest expects {}",
            outs.len(),
            spec.outputs.len()
        );
        Ok(outs)
    }
}

impl ExecBackend for Engine {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn supports(&self, artifact: &str) -> bool {
        self.manifest.artifact(artifact).is_some()
    }

    fn input_shape(&self, artifact: &str, input: &str) -> Option<Vec<usize>> {
        let spec = self.manifest.artifact(artifact)?;
        spec.inputs.iter().find(|io| io.name == input).map(|io| io.shape.clone())
    }

    fn run(&mut self, artifact: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(value_to_literal).collect::<Result<_>>()?;
        let outs = self.run_literals(artifact, &lits)?;
        // run_literals validated output arity against the spec.
        let spec = self.manifest.artifact(artifact).unwrap().clone();
        let mut values = Vec::with_capacity(outs.len());
        for (lit, io) in outs.iter().zip(&spec.outputs) {
            anyhow::ensure!(
                io.dtype == "f32",
                "artifact {artifact}: output '{}' has unsupported dtype {}",
                io.name,
                io.dtype
            );
            values.push(TensorValue::f32(io.shape.clone(), lit.to_vec::<f32>()?)?);
        }
        Ok(values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::convert::*;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg32;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m")
    }

    #[test]
    fn sinkhorn_soft_artifact_matches_host_sinkhorn() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut engine = Engine::load_lazy(&dir).unwrap();
        let spec = engine
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.kind == "sinkhorn_soft")
            .expect("no sinkhorn artifact")
            .clone();
        let n_b = spec.attrs["n_b"];
        let b = spec.attrs["block"];
        let iters = spec.attrs["iters"];
        let mut rng = Pcg32::seeded(7);
        let blocks: Vec<Mat> = (0..n_b).map(|_| Mat::randn(b, b, 0.5, &mut rng)).collect();
        let mut flat = Vec::with_capacity(n_b * b * b);
        for blk in &blocks {
            flat.extend_from_slice(blk.data());
        }
        let tau = 0.7f32;
        let outs = engine
            .run_literals(
                &spec.name,
                &[vec_to_literal(&flat, &[n_b, b, b]).unwrap(), scalar_literal(tau).unwrap()],
            )
            .unwrap();
        let got = literal_to_vec(&outs[0]).unwrap();

        // Host reference.
        let mut want = Vec::with_capacity(flat.len());
        for blk in &blocks {
            let tape = crate::lcp::SinkhornTape::forward(blk, tau, iters);
            want.extend_from_slice(tape.output().data());
        }
        crate::util::testkit::assert_close(&got, &want, 2e-4).unwrap();
    }
}
