//! Artifact engine: compile-once, execute-many over the PJRT CPU client.
//!
//! Compiled only with `--features pjrt`; implements [`ExecBackend`] so the
//! rest of the codebase is agnostic to which engine serves an artifact.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::convert::value_to_literal;
use super::exec::{ExecBackend, TensorValue};
use super::manifest::Manifest;

/// Statics of a bound artifact, converted to literals exactly once.
struct BoundStatics {
    artifact: String,
    /// Input-name -> pre-converted literal.
    literals: Vec<(String, xla::Literal)>,
}

/// Owns the PJRT client and every compiled artifact executable.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Resident artifact statics, keyed by the caller's bind key.
    bound: HashMap<String, BoundStatics>,
}

impl Engine {
    /// Load `manifest.json` from `dir` and compile every artifact eagerly.
    pub fn load(dir: &Path) -> Result<Engine> {
        let mut e = Engine::load_lazy(dir)?;
        let names: Vec<String> = e.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            e.ensure_compiled(&n)?;
        }
        Ok(e)
    }

    /// Load the manifest but compile artifacts on first use.
    pub fn load_lazy(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        log::info!(
            "PJRT platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Engine {
            client,
            manifest,
            dir: dir.to_path_buf(),
            executables: HashMap::new(),
            bound: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile an artifact if not already compiled.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact: literals in, tuple-decomposed literals out.
    ///
    /// Validates input arity against the manifest spec so shape bugs
    /// surface as errors, not crashes inside XLA.
    pub fn run_literals(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.exec_refs(name, &refs)
    }

    /// Execute an already-compiled artifact from *borrowed* literals —
    /// the zero-copy core under [`Engine::run_literals`] and
    /// [`ExecBackend::run_bound`]: resident statics are passed by
    /// reference, never cloned per call.
    fn exec_refs(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "artifact {name}: got {} inputs, manifest expects {}",
            inputs.len(),
            spec.inputs.len()
        );
        for (lit, io) in inputs.iter().zip(&spec.inputs) {
            anyhow::ensure!(
                lit.element_count() == io.elements(),
                "artifact {name}: input '{}' has {} elements, expected {:?}",
                io.name,
                lit.element_count(),
                io.shape
            );
        }
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not compiled"))?;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} result"))?;
        // AOT graphs are lowered with return_tuple=True.
        let outs = lit.to_tuple()?;
        anyhow::ensure!(
            outs.len() == spec.outputs.len(),
            "artifact {name}: got {} outputs, manifest expects {}",
            outs.len(),
            spec.outputs.len()
        );
        Ok(outs)
    }

    /// Convert artifact output literals back to host tensors per the
    /// manifest spec (shared by `run` and `run_bound`).
    fn literals_to_values(
        &self,
        artifact: &str,
        outs: &[xla::Literal],
    ) -> Result<Vec<TensorValue>> {
        // Callers run run_literals first, which validates output arity.
        let spec = self.manifest.artifact(artifact).unwrap();
        let mut values = Vec::with_capacity(outs.len());
        for (lit, io) in outs.iter().zip(&spec.outputs) {
            anyhow::ensure!(
                io.dtype == "f32",
                "artifact {artifact}: output '{}' has unsupported dtype {}",
                io.name,
                io.dtype
            );
            values.push(TensorValue::f32(io.shape.clone(), lit.to_vec::<f32>()?)?);
        }
        Ok(values)
    }
}

impl ExecBackend for Engine {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn supports(&self, artifact: &str) -> bool {
        self.manifest.artifact(artifact).is_some()
    }

    fn input_shape(&self, artifact: &str, input: &str) -> Option<Vec<usize>> {
        let spec = self.manifest.artifact(artifact)?;
        spec.inputs.iter().find(|io| io.name == input).map(|io| io.shape.clone())
    }

    fn run(&mut self, artifact: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(value_to_literal).collect::<Result<_>>()?;
        let outs = self.run_literals(artifact, &lits)?;
        self.literals_to_values(artifact, &outs)
    }

    fn bind(&mut self, key: &str, artifact: &str, statics: &[(&str, &TensorValue)]) -> Result<()> {
        let spec = self
            .manifest
            .artifact(artifact)
            .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?;
        // Every static must name a manifest input and match its declared
        // shape/dtype — a mismatch fails here, not mid-serving inside the
        // first execute.  The host->literal conversion (the per-call cost
        // this API removes) also happens here, exactly once; `run_bound`
        // then passes the resident literals by reference (`exec_refs`),
        // so bound statics are zero-copy per request.
        let mut literals = Vec::with_capacity(statics.len());
        for &(name, value) in statics {
            let io = spec
                .inputs
                .iter()
                .find(|io| io.name == name)
                .ok_or_else(|| anyhow!("artifact {artifact}: bind names unknown input '{name}'"))?;
            anyhow::ensure!(
                value.element_count() == io.elements(),
                "artifact {artifact}: static '{name}' has {} elements, expected {:?}",
                value.element_count(),
                io.shape
            );
            let dtype_ok = match value {
                TensorValue::F32 { .. } => io.dtype == "f32",
                TensorValue::I32 { .. } => io.dtype == "i32",
            };
            anyhow::ensure!(
                dtype_ok,
                "artifact {artifact}: static '{name}' dtype does not match manifest '{}'",
                io.dtype
            );
            literals.push((name.to_string(), value_to_literal(value)?));
        }
        let artifact = artifact.to_string();
        self.bound.insert(key.to_string(), BoundStatics { artifact, literals });
        Ok(())
    }

    fn run_bound(&mut self, key: &str, dynamics: &[TensorValue]) -> Result<Vec<TensorValue>> {
        let artifact = self
            .bound
            .get(key)
            .ok_or_else(|| anyhow!("pjrt backend: no bound artifact under key '{key}'"))?
            .artifact
            .clone();
        // Compile first (the only step needing `&mut self`), then borrow
        // the resident statics for the zero-copy call.
        self.ensure_compiled(&artifact)?;
        // Convert the dynamic inputs up front so the assembled list can
        // be all references.
        let dyn_lits: Vec<xla::Literal> =
            dynamics.iter().map(value_to_literal).collect::<Result<_>>()?;
        let bound = self.bound.get(key).expect("checked above");
        let spec = self.manifest.artifact(&artifact).unwrap();
        // Assemble the full input list in manifest order: statics from the
        // resident literals (by reference — never cloned per request),
        // dynamics consumed left to right.
        let mut lits: Vec<&xla::Literal> = Vec::with_capacity(spec.inputs.len());
        let mut dyn_iter = dyn_lits.iter();
        for io in &spec.inputs {
            match bound.literals.iter().find(|(name, _)| *name == io.name) {
                Some((_, lit)) => lits.push(lit),
                None => {
                    let lit = dyn_iter.next().ok_or_else(|| {
                        anyhow!(
                            "bound artifact '{key}' ({artifact}): missing dynamic input '{}'",
                            io.name
                        )
                    })?;
                    lits.push(lit);
                }
            }
        }
        anyhow::ensure!(
            dyn_iter.next().is_none(),
            "bound artifact '{key}' ({artifact}): too many dynamic inputs (got {})",
            dynamics.len()
        );
        let outs = self.exec_refs(&artifact, &lits)?;
        self.literals_to_values(&artifact, &outs)
    }

    fn supports_bind(&self) -> bool {
        true
    }

    fn is_bound(&self, key: &str) -> bool {
        self.bound.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::convert::*;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg32;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m")
    }

    #[test]
    fn sinkhorn_soft_artifact_matches_host_sinkhorn() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut engine = Engine::load_lazy(&dir).unwrap();
        let spec = engine
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.kind == "sinkhorn_soft")
            .expect("no sinkhorn artifact")
            .clone();
        let n_b = spec.attrs["n_b"];
        let b = spec.attrs["block"];
        let iters = spec.attrs["iters"];
        let mut rng = Pcg32::seeded(7);
        let blocks: Vec<Mat> = (0..n_b).map(|_| Mat::randn(b, b, 0.5, &mut rng)).collect();
        let mut flat = Vec::with_capacity(n_b * b * b);
        for blk in &blocks {
            flat.extend_from_slice(blk.data());
        }
        let tau = 0.7f32;
        let outs = engine
            .run_literals(
                &spec.name,
                &[vec_to_literal(&flat, &[n_b, b, b]).unwrap(), scalar_literal(tau).unwrap()],
            )
            .unwrap();
        let got = literal_to_vec(&outs[0]).unwrap();

        // Host reference.
        let mut want = Vec::with_capacity(flat.len());
        for blk in &blocks {
            let tape = crate::lcp::SinkhornTape::forward(blk, tau, iters);
            want.extend_from_slice(tape.output().data());
        }
        crate::util::testkit::assert_close(&got, &want, 2e-4).unwrap();
    }
}
