//! The execution-backend abstraction.
//!
//! Every compute kernel the pipeline consumes is addressed as a named
//! *artifact* (the AOT naming scheme from `python/compile/aot.py`):
//!
//! | name                        | inputs                                   | outputs            |
//! |-----------------------------|------------------------------------------|--------------------|
//! | `sinkhorn_soft_{n}x{b}`     | `w_p [n,b,b]`, `tau [1]`                 | `p_soft [n,b,b]`   |
//! | `lcp_grad_{c_out}x{c_in}`   | `w`, `s`, `x`, `y`, `w_p`, `p_hard`, `tau` | `loss [1]`, `grads` |
//! | `sparse_fwd_{c_out}x{c_in}` | `vals`, `idx`, `x`, `src_of`             | `y [t,c_out]`      |
//! | `lm_forward`                | params (canonical order), `tokens [b,t]` | `logits [b,t,v]`   |
//!
//! [`ExecBackend`] abstracts who serves them:
//! * [`super::NativeEngine`] — pure Rust, always available, dispatches to
//!   the host implementations (`lcp::SinkhornTape`, `lcp::HostBackend`,
//!   `sparsity::Compressed`, `model::lm_forward`);
//! * `super::Engine` (`--features pjrt`) — compiles and executes the AOT
//!   HLO artifacts on the PJRT CPU client.
//!
//! Backends may additionally hold *static* artifact inputs (weights and
//! their metadata) resident via [`ExecBackend::bind`], so the serving hot
//! path ([`crate::serve`]) only moves activations across the boundary —
//! see the `bind`/`run_bound` contract below.
//!
//! [`ExecLcpBackend`] adapts any `ExecBackend` to the LCP trainer's
//! [`LcpBackend`] interface, which is how the pipeline runs learnable
//! channel permutation through this layer.

use anyhow::{anyhow, Result};

use crate::lcp::{LayerData, LcpBackend};
use crate::tensor::Mat;
use crate::util::scratch::StepArena;

/// A host tensor crossing the backend boundary: shape + typed flat buffer.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl TensorValue {
    /// f32 tensor (shape must match the buffer length).
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<TensorValue> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == data.len(), "shape {shape:?} needs {n} elements, got {}", data.len());
        Ok(TensorValue::F32 { shape, data })
    }

    /// i32 tensor (shape must match the buffer length).
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<TensorValue> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == data.len(), "shape {shape:?} needs {n} elements, got {}", data.len());
        Ok(TensorValue::I32 { shape, data })
    }

    /// `[1]`-shaped f32 scalar (the artifact convention for scalars).
    pub fn scalar(v: f32) -> TensorValue {
        TensorValue::F32 { shape: vec![1], data: vec![v] }
    }

    /// `[rows, cols]` f32 tensor from a host matrix.
    pub fn from_mat(m: &Mat) -> TensorValue {
        let (r, c) = m.shape();
        TensorValue::F32 { shape: vec![r, c], data: m.data().to_vec() }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            TensorValue::F32 { shape, .. } | TensorValue::I32 { shape, .. } => shape,
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            TensorValue::F32 { data, .. } => data.len(),
            TensorValue::I32 { data, .. } => data.len(),
        }
    }

    /// Borrow the f32 buffer (errors on i32 tensors).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorValue::F32 { data, .. } => Ok(data),
            TensorValue::I32 { .. } => Err(anyhow!("expected f32 tensor, got i32")),
        }
    }

    /// Borrow the i32 buffer (errors on f32 tensors).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorValue::I32 { data, .. } => Ok(data),
            TensorValue::F32 { .. } => Err(anyhow!("expected i32 tensor, got f32")),
        }
    }

    /// View a rank-2 f32 tensor as a host matrix (copies).
    pub fn to_mat(&self) -> Result<Mat> {
        let shape = self.shape();
        anyhow::ensure!(shape.len() == 2, "expected rank-2 tensor, got shape {shape:?}");
        let (r, c) = (shape[0], shape[1]);
        Ok(Mat::from_vec(r, c, self.as_f32()?.to_vec()))
    }

    /// Consume a rank-2 f32 tensor into a host matrix without copying the
    /// buffer (the serving hot path turns every artifact output into a
    /// `Mat` — see [`crate::serve`]).
    pub fn into_mat(self) -> Result<Mat> {
        let shape = self.shape().to_vec();
        anyhow::ensure!(shape.len() == 2, "expected rank-2 tensor, got shape {shape:?}");
        match self {
            TensorValue::F32 { data, .. } => Ok(Mat::from_vec(shape[0], shape[1], data)),
            TensorValue::I32 { .. } => Err(anyhow!("expected f32 tensor, got i32")),
        }
    }
}

/// An executor of named artifacts (see the module docs for the contract).
pub trait ExecBackend {
    /// Short backend identifier ("native", "pjrt").
    fn backend_name(&self) -> &'static str;

    /// Whether this backend can serve `artifact`.
    fn supports(&self, artifact: &str) -> bool;

    /// Execute one artifact.  Implementations validate input arity and
    /// element counts so shape bugs surface as errors, not corruption.
    fn run(&mut self, artifact: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>>;

    /// Declared shape of one named input of `artifact`, if this backend
    /// fixes it ahead of time (the PJRT engine's manifest does; the
    /// native engine accepts any consistent shape and returns None).
    /// Lets adapters fail fast at construction instead of mid-run.
    fn input_shape(&self, _artifact: &str, _input: &str) -> Option<Vec<usize>> {
        None
    }

    /// Hold the *static* inputs of `artifact` (weights/metadata that do
    /// not change across requests) resident under a caller-chosen `key`,
    /// so subsequent [`ExecBackend::run_bound`] calls only pass the
    /// dynamic per-request inputs across the boundary.
    ///
    /// `statics` are named with the artifact's input names; the backend
    /// validates and converts them exactly once at bind time (the native
    /// engine builds the [`crate::sparsity::Compressed`] weight here and
    /// never re-runs `from_parts` validation on the hot path).  Keys are
    /// caller-scoped: distinct weights sharing one artifact shape (e.g.
    /// `wq`/`wk` of the same decoder layer) bind under distinct keys.
    /// Re-binding an existing key replaces it.
    ///
    /// Backends without resident-weight support keep the default, which
    /// errors; probe with [`ExecBackend::supports_bind`] and fall back to
    /// [`ExecBackend::run`] with the full input list.
    fn bind(&mut self, key: &str, artifact: &str, statics: &[(&str, &TensorValue)]) -> Result<()> {
        let _ = (key, statics);
        Err(anyhow!(
            "backend '{}' cannot hold artifact '{artifact}' resident (no bind support)",
            self.backend_name()
        ))
    }

    /// Execute a bound artifact: `dynamics` are the non-static inputs in
    /// artifact order (for `sparse_fwd_*`, just the activation `x`).
    fn run_bound(&mut self, key: &str, dynamics: &[TensorValue]) -> Result<Vec<TensorValue>> {
        let _ = dynamics;
        Err(anyhow!("backend '{}' has no bound artifact under key '{key}'", self.backend_name()))
    }

    /// Allocation-free fast path for a bound single-matrix artifact:
    /// compute `artifact(x)` into a matrix drawn from `arena`, returning
    /// `None` when this backend has no such shortcut (the caller then
    /// falls back to [`ExecBackend::run_bound`] with a `TensorValue`
    /// round-trip).
    ///
    /// The contract mirrors `run_bound` exactly — same key, same single
    /// dynamic input, bit-identical output — minus the boundary copies:
    /// implementations must take every temporary from `arena` and give
    /// intermediates back, so steady-state callers (the serving decode
    /// loop) see zero heap allocations.  The native engine overrides this
    /// for `sparse_fwd_*`.
    fn run_bound_mat(
        &mut self,
        key: &str,
        x: &Mat,
        arena: &mut StepArena,
    ) -> Option<Result<Mat>> {
        let _ = (key, x, arena);
        None
    }

    /// Whether this backend implements [`ExecBackend::bind`] /
    /// [`ExecBackend::run_bound`].
    fn supports_bind(&self) -> bool {
        false
    }

    /// Whether `key` currently holds a bound artifact.
    fn is_bound(&self, key: &str) -> bool {
        let _ = key;
        false
    }
}

/// [`LcpBackend`] adapter over any [`ExecBackend`]: routes the trainer's
/// `soft_perms` through `sinkhorn_soft_{n}x{b}` and `loss_grad` through
/// `lcp_grad_{c_out}x{c_in}`.  Replaces the old xla-only ArtifactBackend;
/// cross-checked against the pure-Rust [`crate::lcp::HostBackend`] in
/// `tests/lcp_cross_check.rs`.
pub struct ExecLcpBackend<'e, E: ?Sized> {
    engine: &'e mut E,
    sink_name: String,
    grad_name: String,
    n_b: usize,
    block: usize,
    /// Pre-converted layer tensors (w, s, x, y) reused every step.
    w: TensorValue,
    s: TensorValue,
    x: TensorValue,
    y: TensorValue,
}

impl<'e, E: ExecBackend + ?Sized> ExecLcpBackend<'e, E> {
    /// Build for layer `data` with LCP block size `block`.
    pub fn new(engine: &'e mut E, data: &LayerData, block: usize) -> Result<ExecLcpBackend<'e, E>> {
        let (c_out, c_in) = data.w.shape();
        anyhow::ensure!(block > 0 && c_in % block == 0, "C_in {c_in} not divisible by block {block}");
        let n_b = c_in / block;
        let sink_name = format!("sinkhorn_soft_{n_b}x{block}");
        let grad_name = format!("lcp_grad_{c_out}x{c_in}");
        for name in [&sink_name, &grad_name] {
            anyhow::ensure!(
                engine.supports(name),
                "backend '{}' does not serve artifact '{name}'",
                engine.backend_name()
            );
        }
        // Backends with baked input shapes (PJRT artifacts) must match the
        // calibration data now, not via a panic mid-training.
        if let Some(shape) = engine.input_shape(&grad_name, "x") {
            anyhow::ensure!(
                shape.first() == Some(&data.x.rows()),
                "calibration rows {} != artifact expectation {:?}",
                data.x.rows(),
                shape.first()
            );
        }
        Ok(ExecLcpBackend {
            sink_name,
            grad_name,
            n_b,
            block,
            w: TensorValue::from_mat(&data.w),
            s: TensorValue::from_mat(&data.s),
            x: TensorValue::from_mat(&data.x),
            y: TensorValue::from_mat(&data.y),
            engine,
        })
    }

    fn stack_blocks(&self, blocks: &[Mat]) -> TensorValue {
        let b = self.block;
        let mut flat = Vec::with_capacity(self.n_b * b * b);
        for blk in blocks {
            flat.extend_from_slice(blk.data());
        }
        TensorValue::F32 { shape: vec![self.n_b, b, b], data: flat }
    }
}

/// Split a stacked `[n_b, b, b]` buffer into per-block matrices (shared
/// with the native engine's artifact implementations).
pub(crate) fn unstack_blocks(flat: &[f32], n_b: usize, b: usize) -> Vec<Mat> {
    (0..n_b)
        .map(|n| Mat::from_vec(b, b, flat[n * b * b..(n + 1) * b * b].to_vec()))
        .collect()
}

impl<E: ExecBackend + ?Sized> LcpBackend for ExecLcpBackend<'_, E> {
    fn soft_perms(&mut self, w_p: &[Mat], tau: f32) -> Vec<Mat> {
        let inputs = [self.stack_blocks(w_p), TensorValue::scalar(tau)];
        let outs = self.engine.run(&self.sink_name, &inputs).expect("sinkhorn artifact");
        unstack_blocks(outs[0].as_f32().expect("sinkhorn output dtype"), self.n_b, self.block)
    }

    fn loss_grad(&mut self, w_p: &[Mat], p_hard_src: &[Vec<usize>], tau: f32) -> (f32, Vec<Mat>) {
        // src_of -> dense permutation blocks (P[src_of[j], j] = 1).
        let b = self.block;
        let hard_blocks: Vec<Mat> = p_hard_src
            .iter()
            .map(|src| {
                let mut p = Mat::zeros(b, b);
                for (j, &i) in src.iter().enumerate() {
                    p[(i, j)] = 1.0;
                }
                p
            })
            .collect();
        let inputs = [
            self.w.clone(),
            self.s.clone(),
            self.x.clone(),
            self.y.clone(),
            self.stack_blocks(w_p),
            self.stack_blocks(&hard_blocks),
            TensorValue::scalar(tau),
        ];
        let outs = self.engine.run(&self.grad_name, &inputs).expect("lcp_grad artifact");
        let loss = outs[0].as_f32().expect("loss dtype")[0];
        let grads = unstack_blocks(outs[1].as_f32().expect("grad dtype"), self.n_b, self.block);
        (loss, grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_constructors_validate_shape() {
        assert!(TensorValue::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorValue::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(TensorValue::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
        assert!(TensorValue::i32(vec![4], vec![1]).is_err());
    }

    #[test]
    fn scalar_is_rank_one() {
        let s = TensorValue::scalar(2.5);
        assert_eq!(s.shape(), &[1]);
        assert_eq!(s.as_f32().unwrap(), &[2.5]);
        assert!(s.as_i32().is_err());
    }

    #[test]
    fn mat_roundtrip() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = TensorValue::from_mat(&m);
        assert_eq!(v.element_count(), 6);
        assert_eq!(v.to_mat().unwrap(), m);
    }

    #[test]
    fn to_mat_rejects_wrong_rank() {
        let v = TensorValue::f32(vec![8], vec![0.0; 8]).unwrap();
        assert!(v.to_mat().is_err());
    }

    #[test]
    fn into_mat_moves_rank_two_f32() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(TensorValue::from_mat(&m).into_mat().unwrap(), m);
        assert!(TensorValue::f32(vec![4], vec![0.0; 4]).unwrap().into_mat().is_err());
        assert!(TensorValue::i32(vec![2, 2], vec![0; 4]).unwrap().into_mat().is_err());
    }
}
