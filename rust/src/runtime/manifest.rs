//! Parse `artifacts/manifest.json` (written by python/compile/aot.py).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::model::ModelConfig;
use crate::util::json::Json;

/// One input or output of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Extra integer attributes (c_out, c_in, n_b, block, m, keep, ...).
    pub attrs: std::collections::BTreeMap<String, usize>,
}

/// The full manifest: model/train/lcp configs + artifact specs.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfig,
    pub batch: usize,
    pub lcp_block: usize,
    pub lcp_calib_rows: usize,
    pub lcp_m: usize,
    pub lcp_keep: usize,
    pub sinkhorn_iters: usize,
    /// Canonical parameter order: (name, shape).
    pub param_order: Vec<(String, Vec<usize>)>,
    pub artifacts: Vec<ArtifactSpec>,
}

fn io_specs(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("io list not an array"))?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
                shape: e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing shape"))?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect(),
                dtype: e.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let cfgj = j.get("config").ok_or_else(|| anyhow!("missing config"))?;
        let us = |k: &str| -> Result<usize> {
            cfgj.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let config = ModelConfig {
            name: cfgj.get("name").and_then(Json::as_str).unwrap_or("tiny-m").to_string(),
            vocab: us("vocab")?,
            dim: us("dim")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            ffn: us("ffn")?,
            seq_len: us("seq_len")?,
            rope_theta: cfgj.get("rope_theta").and_then(Json::as_f64).unwrap_or(10000.0) as f32,
            norm_eps: cfgj.get("norm_eps").and_then(Json::as_f64).unwrap_or(1e-5) as f32,
        };
        let lcpj = j.get("lcp").ok_or_else(|| anyhow!("missing lcp section"))?;
        let lu = |k: &str| lcpj.get(k).and_then(Json::as_usize).unwrap_or(0);

        let param_order = j
            .get("param_order")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing param_order"))?
            .iter()
            .map(|e| {
                let name = e.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
                let shape = e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().map(|v| v.as_usize().unwrap_or(0)).collect())
                    .unwrap_or_default();
                (name, shape)
            })
            .collect();

        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing artifacts"))?
            .iter()
            .map(|a| {
                let mut attrs = std::collections::BTreeMap::new();
                if let Some(o) = a.as_obj() {
                    for (k, v) in o {
                        if let Some(n) = v.as_f64() {
                            attrs.insert(k.clone(), n as usize);
                        }
                    }
                }
                Ok(ArtifactSpec {
                    name: a.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
                    file: a.get("file").and_then(Json::as_str).unwrap_or("?").to_string(),
                    kind: a.get("kind").and_then(Json::as_str).unwrap_or("?").to_string(),
                    inputs: io_specs(a.get("inputs").ok_or_else(|| anyhow!("missing inputs"))?)?,
                    outputs: io_specs(a.get("outputs").ok_or_else(|| anyhow!("missing outputs"))?)?,
                    attrs,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            config,
            batch: j.path(&["train", "batch"]).and_then(Json::as_usize).unwrap_or(8),
            lcp_block: lu("block"),
            lcp_calib_rows: lu("calib_rows"),
            lcp_m: lu("m"),
            lcp_keep: lu("keep"),
            sinkhorn_iters: lu("sinkhorn_iters"),
            param_order,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.config.dim > 0);
        assert!(m.artifact("train_step").is_some());
        assert!(m.artifact("lm_forward").is_some());
        assert!(!m.param_order.is_empty());
        // param count: 3 + 9 per layer.
        assert_eq!(m.param_order.len(), 3 + 9 * m.config.n_layers);
        // every lcp_grad artifact is self-consistent.
        for a in m.artifacts.iter().filter(|a| a.kind == "lcp_grad") {
            assert_eq!(a.attrs["n_b"] * a.attrs["block"], a.attrs["c_in"]);
        }
    }
}
