//! Execution runtime: named compute artifacts behind [`ExecBackend`].
//!
//! Two interchangeable backends serve the artifact names (see `exec.rs`
//! for the name/shape contract):
//!
//! * [`NativeEngine`] — pure Rust, the default and the only backend
//!   compiled without extra features.  Always available (CI, offline),
//!   dispatches to the host implementations of the same math.
//! * `Engine` (`--features pjrt`) — loads AOT HLO-text artifacts
//!   (`make artifacts` emits `artifacts/*.hlo.txt` + `manifest.json`),
//!   compiles each once on the PJRT CPU client, and executes them with
//!   host tensors.  HLO *text* is the interchange format (xla_extension
//!   0.5.1 rejects jax>=0.5 64-bit-id protos; the text parser reassigns
//!   ids — see DESIGN.md §2).  All `xla::` usage lives behind the
//!   feature gate; the offline build ships a typed stub (`shims/xla`).
//!
//! [`Manifest`] parsing is feature-independent so tooling (`permllm
//! info`) can inspect artifact directories without the PJRT runtime.

mod exec;
mod manifest;
mod native;

#[cfg(feature = "pjrt")]
mod convert;
#[cfg(feature = "pjrt")]
mod engine;

pub use exec::{ExecBackend, ExecLcpBackend, TensorValue};
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use native::{NativeCfg, NativeEngine};

#[cfg(feature = "pjrt")]
pub use convert::{
    literal_to_vec, mat_to_literal, scalar_literal, tokens_to_literal, value_to_literal,
    vec_to_literal,
};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
