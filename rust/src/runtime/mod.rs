//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! The request path is Rust-only: `make artifacts` (python, build-time)
//! emits `artifacts/*.hlo.txt` + `manifest.json`; [`Engine::load`] compiles
//! every artifact on the PJRT CPU client at startup and [`Engine::run`]
//! executes them with host tensors. HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax>=0.5 64-bit-id protos; the text parser
//! reassigns ids — see DESIGN.md §2).

mod backend;
mod convert;
mod engine;
mod manifest;

pub use backend::ArtifactBackend;
pub use convert::{literal_to_vec, mat_to_literal, scalar_literal, tokens_to_literal, vec_to_literal};
pub use engine::Engine;
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
