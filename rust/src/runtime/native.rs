//! Pure-Rust execution backend: serves the AOT artifact names offline.
//!
//! [`NativeEngine`] implements [`ExecBackend`] by dispatching each
//! artifact family to the host implementation of the same math:
//!
//! * `sinkhorn_soft_{n}x{b}` -> [`crate::lcp::SinkhornTape`] per block,
//!   fanned out over [`parallel_map`];
//! * `lcp_grad_{c_out}x{c_in}` -> [`crate::lcp::HostBackend`]'s
//!   hand-derived STE backward;
//! * `sparse_fwd_{c_out}x{c_in}` -> channel permute + compressed N:M
//!   SpMM ([`Compressed`]), row-tiled over [`parallel_map`];
//! * `lm_forward` -> the host transformer ([`crate::model::lm_forward`];
//!   requires a [`ModelConfig`], see [`NativeEngine::with_model`]).
//!
//! This is the reference path every CI run and offline environment uses;
//! `--features pjrt` swaps in the XLA-compiled artifacts behind the same
//! [`ExecBackend`] trait, and `tests/lcp_cross_check.rs` pins the two
//! together when artifacts are present.
//!
//! `sparse_fwd_*` additionally supports the resident-weight
//! [`ExecBackend::bind`] path: the compressed weight and its permutation
//! are validated and built exactly once at bind time, so per-request
//! `run_bound` calls move only the activation across the boundary (the
//! serving subsystem's hot path — see [`crate::serve`]).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::exec::{unstack_blocks, ExecBackend, TensorValue};
use crate::lcp::{HostBackend, LayerData, LcpBackend, SinkhornTape};
use crate::model::ModelConfig;
use crate::sparsity::{Compressed, NmConfig};
use crate::tensor::Mat;
use crate::util::pool::parallel_map;
use crate::util::scratch::StepArena;

/// Configuration for the native backend.
#[derive(Debug, Clone)]
pub struct NativeCfg {
    /// N:M pattern used by `lcp_grad` and `sparse_fwd`.
    pub nm: NmConfig,
    /// Sinkhorn iterations for `sinkhorn_soft` and `lcp_grad`.
    pub sinkhorn_iters: usize,
    /// Worker threads for block/row fan-out (1 = sequential; the pruning
    /// pipeline parallelizes across layers instead and passes 1 here).
    pub threads: usize,
    /// Model served by `lm_forward` (None disables that artifact).
    pub model: Option<ModelConfig>,
}

impl Default for NativeCfg {
    fn default() -> Self {
        NativeCfg { nm: NmConfig::PAT_2_4, sinkhorn_iters: 5, threads: 1, model: None }
    }
}

/// A bound (backend-resident) artifact: statics validated and converted
/// exactly once at [`ExecBackend::bind`] time.
#[derive(Debug, Clone)]
enum Bound {
    /// `sparse_fwd_*`: the compressed N:M weight (`from_parts` validation
    /// already paid) plus its checked channel permutation.
    SparseFwd { comp: Compressed, src: Vec<usize> },
}

/// The pure-Rust [`ExecBackend`].
#[derive(Debug, Clone, Default)]
pub struct NativeEngine {
    cfg: NativeCfg,
    /// Resident artifacts, keyed by the caller's bind key.
    bound: HashMap<String, Bound>,
}

impl NativeEngine {
    pub fn new(cfg: NativeCfg) -> NativeEngine {
        NativeEngine { cfg, bound: HashMap::new() }
    }

    /// Default config plus a model for `lm_forward`.
    pub fn with_model(model: ModelConfig) -> NativeEngine {
        NativeEngine::new(NativeCfg { model: Some(model), ..NativeCfg::default() })
    }

    pub fn cfg(&self) -> &NativeCfg {
        &self.cfg
    }

    fn run_sinkhorn(
        &self,
        name: &str,
        dims: &str,
        inputs: &[TensorValue],
    ) -> Result<Vec<TensorValue>> {
        let (n_b, b) = parse_dims(dims)
            .ok_or_else(|| anyhow!("artifact '{name}': malformed shape suffix '{dims}'"))?;
        anyhow::ensure!(
            inputs.len() == 2,
            "artifact {name}: got {} inputs, expected 2 (w_p, tau)",
            inputs.len()
        );
        check_shape(name, "w_p", &inputs[0], &[n_b, b, b])?;
        check_shape(name, "tau", &inputs[1], &[1])?;
        let flat = inputs[0].as_f32()?;
        let tau = inputs[1].as_f32()?[0];
        let iters = self.cfg.sinkhorn_iters;
        let bb = b * b;
        let blocks = parallel_map(n_b, self.cfg.threads, |n| {
            let blk = Mat::from_vec(b, b, flat[n * bb..(n + 1) * bb].to_vec());
            SinkhornTape::forward(&blk, tau, iters).output().data().to_vec()
        });
        let mut out = Vec::with_capacity(n_b * bb);
        for blk in blocks {
            out.extend_from_slice(&blk);
        }
        Ok(vec![TensorValue::f32(vec![n_b, b, b], out)?])
    }

    fn run_lcp_grad(
        &self,
        name: &str,
        dims: &str,
        inputs: &[TensorValue],
    ) -> Result<Vec<TensorValue>> {
        let (c_out, c_in) = parse_dims(dims)
            .ok_or_else(|| anyhow!("artifact '{name}': malformed shape suffix '{dims}'"))?;
        anyhow::ensure!(
            inputs.len() == 7,
            "artifact {name}: got {} inputs, expected 7 (w, s, x, y, w_p, p_hard, tau)",
            inputs.len()
        );
        check_shape(name, "w", &inputs[0], &[c_out, c_in])?;
        check_shape(name, "s", &inputs[1], &[c_out, c_in])?;
        let xshape = inputs[2].shape().to_vec();
        anyhow::ensure!(
            xshape.len() == 2 && xshape[1] == c_in,
            "artifact {name}: input 'x' has shape {xshape:?}, expected [T, {c_in}]"
        );
        let t = xshape[0];
        check_shape(name, "y", &inputs[3], &[t, c_out])?;
        let wp_shape = inputs[4].shape().to_vec();
        anyhow::ensure!(
            wp_shape.len() == 3 && wp_shape[1] == wp_shape[2] && wp_shape[0] * wp_shape[1] == c_in,
            "artifact {name}: input 'w_p' has shape {wp_shape:?}, expected [N_B, B, B] with N_B*B = {c_in}"
        );
        let (n_b, b) = (wp_shape[0], wp_shape[1]);
        check_shape(name, "p_hard", &inputs[5], &[n_b, b, b])?;
        check_shape(name, "tau", &inputs[6], &[1])?;

        let data = LayerData {
            w: inputs[0].to_mat()?,
            s: inputs[1].to_mat()?,
            x: inputs[2].to_mat()?,
            y: inputs[3].to_mat()?,
        };
        let w_p = unstack_blocks(inputs[4].as_f32()?, n_b, b);
        let hard = unstack_blocks(inputs[5].as_f32()?, n_b, b);
        let tau = inputs[6].as_f32()?[0];
        // Dense one-hot permutation blocks back to per-block src_of.
        let hard_src: Vec<Vec<usize>> = hard.iter().map(argmax_cols).collect();

        let mut host = HostBackend::new(&data, self.cfg.nm, self.cfg.sinkhorn_iters);
        let (loss, grads) = host.loss_grad(&w_p, &hard_src, tau);
        let mut flat = Vec::with_capacity(n_b * b * b);
        for g in &grads {
            flat.extend_from_slice(g.data());
        }
        Ok(vec![
            TensorValue::f32(vec![1], vec![loss])?,
            TensorValue::f32(vec![n_b, b, b], flat)?,
        ])
    }

    fn run_sparse_fwd(
        &self,
        name: &str,
        dims: &str,
        inputs: &[TensorValue],
    ) -> Result<Vec<TensorValue>> {
        let (c_out, c_in) = parse_dims(dims)
            .ok_or_else(|| anyhow!("artifact '{name}': malformed shape suffix '{dims}'"))?;
        anyhow::ensure!(
            inputs.len() == 4,
            "artifact {name}: got {} inputs, expected 4 (vals, idx, x, src)",
            inputs.len()
        );
        let nm = self.cfg.nm;
        anyhow::ensure!(c_in % nm.m == 0, "artifact {name}: C_in {c_in} not divisible by M {}", nm.m);
        let k = c_in / nm.m * nm.keep;
        check_shape(name, "vals", &inputs[0], &[c_out, k])?;
        check_shape(name, "idx", &inputs[1], &[c_out, k])?;
        let xshape = inputs[2].shape().to_vec();
        anyhow::ensure!(
            xshape.len() == 2 && xshape[1] == c_in,
            "artifact {name}: input 'x' has shape {xshape:?}, expected [T, {c_in}]"
        );
        check_shape(name, "src_of", &inputs[3], &[c_in])?;

        let comp = build_compressed(name, nm, c_out, c_in, &inputs[0], &inputs[1])?;
        let src = check_permutation(name, &inputs[3], c_in)?;
        let x = inputs[2].to_mat()?;
        let xp = x.permute_cols(&src);

        // Output-row-tiled sparse matmul over the worker pool — the tiling
        // (and its bit-exactness vs sequential) lives in `Compressed`, so
        // the serve subsystem and this artifact share one kernel.
        let y = comp.matmul_xt_threads(&xp, self.cfg.threads);
        let (yr, yc) = y.shape();
        Ok(vec![TensorValue::f32(vec![yr, yc], y.into_vec())?])
    }

    fn run_lm_forward(&self, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        let cfg = self.cfg.model.as_ref().ok_or_else(|| {
            anyhow!(
                "artifact lm_forward: native backend built without a model \
                 (use NativeEngine::with_model)"
            )
        })?;
        let names = cfg.param_names();
        anyhow::ensure!(
            inputs.len() == names.len() + 1,
            "artifact lm_forward: got {} inputs, expected {} params + tokens",
            inputs.len(),
            names.len()
        );
        let mut flat = Vec::with_capacity(names.len());
        for (v, name) in inputs.iter().zip(&names) {
            let shape = cfg.param_shape(name);
            let want: usize = shape.iter().product();
            anyhow::ensure!(
                v.element_count() == want,
                "artifact lm_forward: input '{name}' has {} elements, expected {shape:?}",
                v.element_count()
            );
            let data = v.as_f32()?.to_vec();
            flat.push(if shape.len() == 1 {
                Mat::from_vec(1, shape[0], data)
            } else {
                Mat::from_vec(shape[0], shape[1], data)
            });
        }
        let ps = crate::model::ParamStore::from_flat(cfg, flat)?;

        let tok = &inputs[names.len()];
        let tshape = tok.shape().to_vec();
        anyhow::ensure!(
            tshape.len() == 2,
            "artifact lm_forward: tokens have shape {tshape:?}, expected [B, T]"
        );
        let (bsz, t) = (tshape[0], tshape[1]);
        let toks = tok.as_i32()?;
        let mut batch: Vec<Vec<u8>> = Vec::with_capacity(bsz);
        for bi in 0..bsz {
            let row = &toks[bi * t..(bi + 1) * t];
            let seq: Vec<u8> = row
                .iter()
                .map(|&v| {
                    if (0..cfg.vocab.min(256) as i32).contains(&v) {
                        Ok(v as u8)
                    } else {
                        Err(anyhow!("artifact lm_forward: token {v} outside vocab {}", cfg.vocab))
                    }
                })
                .collect::<Result<_>>()?;
            batch.push(seq);
        }
        let logits = crate::model::lm_forward(&ps, &batch);
        let v = cfg.vocab;
        let mut out = Vec::with_capacity(bsz * t * v);
        for l in &logits {
            out.extend_from_slice(l.data());
        }
        Ok(vec![TensorValue::f32(vec![bsz, t, v], out)?])
    }
}

impl ExecBackend for NativeEngine {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, artifact: &str) -> bool {
        if artifact == "lm_forward" {
            return self.cfg.model.is_some();
        }
        for prefix in ["sinkhorn_soft_", "lcp_grad_", "sparse_fwd_"] {
            if let Some(dims) = artifact.strip_prefix(prefix) {
                return parse_dims(dims).is_some();
            }
        }
        false
    }

    fn run(&mut self, artifact: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        if let Some(dims) = artifact.strip_prefix("sinkhorn_soft_") {
            self.run_sinkhorn(artifact, dims, inputs)
        } else if let Some(dims) = artifact.strip_prefix("lcp_grad_") {
            self.run_lcp_grad(artifact, dims, inputs)
        } else if let Some(dims) = artifact.strip_prefix("sparse_fwd_") {
            self.run_sparse_fwd(artifact, dims, inputs)
        } else if artifact == "lm_forward" {
            self.run_lm_forward(inputs)
        } else {
            Err(anyhow!("native backend: unknown artifact '{artifact}'"))
        }
    }

    fn bind(&mut self, key: &str, artifact: &str, statics: &[(&str, &TensorValue)]) -> Result<()> {
        let Some(dims) = artifact.strip_prefix("sparse_fwd_") else {
            return Err(anyhow!(
                "native backend: only sparse_fwd_* artifacts support binding, got '{artifact}'"
            ));
        };
        let (c_out, c_in) = parse_dims(dims)
            .ok_or_else(|| anyhow!("artifact '{artifact}': malformed shape suffix '{dims}'"))?;
        let nm = self.cfg.nm;
        anyhow::ensure!(
            c_in % nm.m == 0,
            "artifact {artifact}: C_in {c_in} not divisible by M {}",
            nm.m
        );
        let k = c_in / nm.m * nm.keep;
        anyhow::ensure!(
            statics.len() == 3,
            "artifact {artifact}: bind expects 3 statics (vals, idx, src_of), got {}",
            statics.len()
        );
        let find = |want: &str| {
            statics
                .iter()
                .find(|(name, _)| *name == want)
                .map(|&(_, v)| v)
                .ok_or_else(|| anyhow!("artifact {artifact}: bind missing static input '{want}'"))
        };
        let (vals, idx, src) = (find("vals")?, find("idx")?, find("src_of")?);
        check_shape(artifact, "vals", vals, &[c_out, k])?;
        check_shape(artifact, "idx", idx, &[c_out, k])?;
        check_shape(artifact, "src_of", src, &[c_in])?;
        let comp = build_compressed(artifact, nm, c_out, c_in, vals, idx)?;
        let src = check_permutation(artifact, src, c_in)?;
        self.bound.insert(key.to_string(), Bound::SparseFwd { comp, src });
        Ok(())
    }

    fn run_bound(&mut self, key: &str, dynamics: &[TensorValue]) -> Result<Vec<TensorValue>> {
        let Some(Bound::SparseFwd { comp, src }) = self.bound.get(key) else {
            return Err(anyhow!("native backend: no bound artifact under key '{key}'"));
        };
        anyhow::ensure!(
            dynamics.len() == 1,
            "bound sparse_fwd '{key}': got {} dynamic inputs, expected 1 (x)",
            dynamics.len()
        );
        let (_, c_in) = comp.shape();
        let xshape = dynamics[0].shape();
        anyhow::ensure!(
            xshape.len() == 2 && xshape[1] == c_in,
            "bound sparse_fwd '{key}': input 'x' has shape {xshape:?}, expected [T, {c_in}]"
        );
        let x = dynamics[0].to_mat()?;
        let y = comp.matmul_xt_threads(&x.permute_cols(src), self.cfg.threads);
        let (yr, yc) = y.shape();
        Ok(vec![TensorValue::f32(vec![yr, yc], y.into_vec())?])
    }

    /// The zero-copy, zero-alloc form of [`NativeEngine::run_bound`] for
    /// `sparse_fwd_*`: the permuted activation and the output both come
    /// from `arena`, no `TensorValue` crosses the boundary.  Bit-identical
    /// to `run_bound` — same `permute_cols` gather, same
    /// `matmul_xt_threads` kernel at the same thread count (pinned by
    /// `bound_sparse_fwd_scratch_matches_run_bound`).
    fn run_bound_mat(&mut self, key: &str, x: &Mat, arena: &mut StepArena) -> Option<Result<Mat>> {
        let Some(Bound::SparseFwd { comp, src }) = self.bound.get(key) else {
            return Some(Err(anyhow!("native backend: no bound artifact under key '{key}'")));
        };
        let (c_out, c_in) = comp.shape();
        if x.cols() != c_in {
            return Some(Err(anyhow!(
                "bound sparse_fwd '{key}': input 'x' has shape {:?}, expected [T, {c_in}]",
                x.shape()
            )));
        }
        let mut xp = arena.take(x.rows(), c_in);
        x.permute_cols_into(src, &mut xp);
        let mut y = arena.take(x.rows(), c_out);
        comp.matmul_xt_threads_into(&xp, self.cfg.threads, &mut y);
        arena.give(xp);
        Some(Ok(y))
    }

    fn supports_bind(&self) -> bool {
        true
    }

    fn is_bound(&self, key: &str) -> bool {
        self.bound.contains_key(key)
    }
}

/// Validate `vals`/`idx` against the N:M layout and build the compressed
/// weight (shared by the per-call `sparse_fwd` path and `bind`).
fn build_compressed(
    name: &str,
    nm: NmConfig,
    c_out: usize,
    c_in: usize,
    vals: &TensorValue,
    idx: &TensorValue,
) -> Result<Compressed> {
    let mut cols = Vec::with_capacity(idx.element_count());
    for &v in idx.as_i32()? {
        let c = u32::try_from(v)
            .map_err(|_| anyhow!("artifact {name}: negative column index {v}"))?;
        cols.push(c);
    }
    Compressed::from_parts(nm, c_out, c_in, vals.as_f32()?.to_vec(), cols)
}

/// Validate that `src` is a true permutation of `0..c_in`: in-range AND
/// no duplicates, else the gather silently duplicates/drops channels.
fn check_permutation(name: &str, src: &TensorValue, c_in: usize) -> Result<Vec<usize>> {
    let src: Vec<usize> = src.as_i32()?.iter().map(|&v| v as usize).collect();
    let mut seen = vec![false; c_in];
    for &i in &src {
        anyhow::ensure!(i < c_in, "artifact {name}: permutation index {i} out of range");
        anyhow::ensure!(!seen[i], "artifact {name}: duplicate permutation index {i}");
        seen[i] = true;
    }
    Ok(src)
}

/// Parse an `"{A}x{B}"` artifact-name suffix.
fn parse_dims(s: &str) -> Option<(usize, usize)> {
    let (a, b) = s.split_once('x')?;
    let a: usize = a.parse().ok()?;
    let b: usize = b.parse().ok()?;
    if a == 0 || b == 0 {
        return None;
    }
    Some((a, b))
}

fn check_shape(artifact: &str, input: &str, v: &TensorValue, want: &[usize]) -> Result<()> {
    let n: usize = want.iter().product();
    anyhow::ensure!(
        v.element_count() == n,
        "artifact {artifact}: input '{input}' has {} elements, expected {want:?}",
        v.element_count()
    );
    Ok(())
}

/// `src_of[j]` = row index of the maximum in column `j` (ties -> lowest).
fn argmax_cols(blk: &Mat) -> Vec<usize> {
    let (rows, cols) = blk.shape();
    (0..cols)
        .map(|j| {
            let mut best = 0;
            for i in 1..rows {
                if blk[(i, j)] > blk[(best, j)] {
                    best = i;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lcp::harden;
    use crate::pruning::{importance, Metric};
    use crate::sparsity::NmMask;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    #[test]
    fn sinkhorn_artifact_matches_host_tape() {
        let mut rng = Pcg32::seeded(7);
        let (n_b, b, tau, iters) = (3usize, 8usize, 0.7f32, 5usize);
        let blocks: Vec<Mat> = (0..n_b).map(|_| Mat::randn(b, b, 0.5, &mut rng)).collect();
        let mut flat = Vec::new();
        for blk in &blocks {
            flat.extend_from_slice(blk.data());
        }
        let mut engine = NativeEngine::new(NativeCfg { sinkhorn_iters: iters, ..NativeCfg::default() });
        let outs = engine
            .run(
                &format!("sinkhorn_soft_{n_b}x{b}"),
                &[
                    TensorValue::f32(vec![n_b, b, b], flat).unwrap(),
                    TensorValue::scalar(tau),
                ],
            )
            .unwrap();
        let got = outs[0].as_f32().unwrap();
        let mut want = Vec::new();
        for blk in &blocks {
            want.extend_from_slice(SinkhornTape::forward(blk, tau, iters).output().data());
        }
        assert_close(got, &want, 1e-6).unwrap();
    }

    #[test]
    fn sinkhorn_parallel_matches_sequential() {
        let mut rng = Pcg32::seeded(8);
        let (n_b, b) = (4usize, 6usize);
        let flat: Vec<f32> = (0..n_b * b * b).map(|_| rng.normal()).collect();
        let inputs = [
            TensorValue::f32(vec![n_b, b, b], flat).unwrap(),
            TensorValue::scalar(0.9),
        ];
        let name = format!("sinkhorn_soft_{n_b}x{b}");
        let seq = NativeEngine::default().run(&name, &inputs).unwrap();
        let mut par_engine =
            NativeEngine::new(NativeCfg { threads: 4, ..NativeCfg::default() });
        let par = par_engine.run(&name, &inputs).unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn lcp_grad_artifact_matches_host_backend() {
        let mut rng = Pcg32::seeded(21);
        let (c_out, c_in, t, b) = (8usize, 16usize, 12usize, 8usize);
        let n_b = c_in / b;
        let w = Mat::randn(c_out, c_in, 0.2, &mut rng);
        let x = Mat::randn(t, c_in, 1.0, &mut rng);
        let s = importance(Metric::Wanda, &w, &x);
        let data = LayerData::new(w, s, x);

        let w_p: Vec<Mat> = (0..n_b).map(|_| Mat::randn(b, b, 0.4, &mut rng)).collect();
        let tau = 0.6f32;
        let mut host = HostBackend::new(&data, NmConfig::PAT_2_4, 5);
        let soft = host.soft_perms(&w_p, tau);
        let hard: Vec<Vec<usize>> = soft.iter().map(harden).collect();
        let (loss_h, grads_h) = host.loss_grad(&w_p, &hard, tau);

        let stack = |blocks: &[Mat]| {
            let mut flat = Vec::new();
            for blk in blocks {
                flat.extend_from_slice(blk.data());
            }
            TensorValue::f32(vec![n_b, b, b], flat).unwrap()
        };
        let hard_dense: Vec<Mat> = hard
            .iter()
            .map(|src| {
                let mut p = Mat::zeros(b, b);
                for (j, &i) in src.iter().enumerate() {
                    p[(i, j)] = 1.0;
                }
                p
            })
            .collect();
        let inputs = [
            TensorValue::from_mat(&data.w),
            TensorValue::from_mat(&data.s),
            TensorValue::from_mat(&data.x),
            TensorValue::from_mat(&data.y),
            stack(&w_p),
            stack(&hard_dense),
            TensorValue::scalar(tau),
        ];
        let outs = NativeEngine::default()
            .run(&format!("lcp_grad_{c_out}x{c_in}"), &inputs)
            .unwrap();
        let loss_n = outs[0].as_f32().unwrap()[0];
        assert!((loss_h - loss_n).abs() < 1e-6, "{loss_h} vs {loss_n}");
        let grads_n = outs[1].as_f32().unwrap();
        let mut flat_h = Vec::new();
        for g in &grads_h {
            flat_h.extend_from_slice(g.data());
        }
        assert_close(grads_n, &flat_h, 1e-6).unwrap();
    }

    #[test]
    fn sparse_fwd_matches_dense_reference() {
        let mut rng = Pcg32::seeded(5);
        let (c_out, c_in, t) = (6usize, 16usize, 9usize);
        let w = Mat::randn(c_out, c_in, 1.0, &mut rng);
        let mask = NmMask::from_scores(&w.map(f32::abs), NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &mask);
        let x = Mat::randn(t, c_in, 1.0, &mut rng);
        let src = rng.permutation(c_in);

        let idx: Vec<i32> = comp.idx().iter().map(|&v| v as i32).collect();
        let src_i: Vec<i32> = src.iter().map(|&v| v as i32).collect();
        let inputs = [
            TensorValue::f32(vec![c_out, comp.k()], comp.vals().to_vec()).unwrap(),
            TensorValue::i32(vec![c_out, comp.k()], idx).unwrap(),
            TensorValue::from_mat(&x),
            TensorValue::i32(vec![c_in], src_i).unwrap(),
        ];
        let name = format!("sparse_fwd_{c_out}x{c_in}");
        for threads in [1usize, 3] {
            let mut engine = NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() });
            let outs = engine.run(&name, &inputs).unwrap();
            let want = x.permute_cols(&src).matmul_bt(&mask.apply(&w));
            assert_close(outs[0].as_f32().unwrap(), want.data(), 1e-5).unwrap();
        }
    }

    #[test]
    fn bound_sparse_fwd_matches_per_call_run() {
        let mut rng = Pcg32::seeded(17);
        let (c_out, c_in, t) = (6usize, 16usize, 7usize);
        let w = Mat::randn(c_out, c_in, 1.0, &mut rng);
        let mask = NmMask::from_scores(&w.map(f32::abs), NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &mask);
        let x = Mat::randn(t, c_in, 1.0, &mut rng);
        let src = rng.permutation(c_in);

        let idx: Vec<i32> = comp.idx().iter().map(|&v| v as i32).collect();
        let vals = TensorValue::f32(vec![c_out, comp.k()], comp.vals().to_vec()).unwrap();
        let idx = TensorValue::i32(vec![c_out, comp.k()], idx).unwrap();
        let src_v =
            TensorValue::i32(vec![c_in], src.iter().map(|&v| v as i32).collect()).unwrap();
        let x_v = TensorValue::from_mat(&x);
        let name = format!("sparse_fwd_{c_out}x{c_in}");

        let mut engine = NativeEngine::default();
        assert!(engine.supports_bind());
        assert!(!engine.is_bound("layers.0.wq"));
        engine
            .bind("layers.0.wq", &name, &[("vals", &vals), ("idx", &idx), ("src_of", &src_v)])
            .unwrap();
        assert!(engine.is_bound("layers.0.wq"));

        // Bound execution is bit-identical to the per-call path.
        let bound = engine.run_bound("layers.0.wq", std::slice::from_ref(&x_v)).unwrap();
        let full = engine
            .run(&name, &[vals.clone(), idx.clone(), x_v.clone(), src_v.clone()])
            .unwrap();
        assert_eq!(bound, full);

        // Unknown keys, non-sparse_fwd artifacts, and bad statics error.
        assert!(engine.run_bound("nope", std::slice::from_ref(&x_v)).is_err());
        assert!(engine.bind("k", "sinkhorn_soft_2x4", &[]).is_err());
        assert!(engine
            .bind("k", &name, &[("vals", &vals), ("idx", &idx), ("src_of", &vals)])
            .is_err());
        // Wrong dynamic arity.
        assert!(engine.run_bound("layers.0.wq", &[x_v.clone(), x_v]).is_err());
    }

    #[test]
    fn bound_sparse_fwd_scratch_matches_run_bound() {
        let mut rng = Pcg32::seeded(23);
        let (c_out, c_in, t) = (5usize, 24usize, 9usize);
        let w = Mat::randn(c_out, c_in, 1.0, &mut rng);
        let mask = NmMask::from_scores(&w.map(f32::abs), NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &mask);
        let x = Mat::randn(t, c_in, 1.0, &mut rng);
        let src = rng.permutation(c_in);

        let idx: Vec<i32> = comp.idx().iter().map(|&v| v as i32).collect();
        let vals = TensorValue::f32(vec![c_out, comp.k()], comp.vals().to_vec()).unwrap();
        let idx = TensorValue::i32(vec![c_out, comp.k()], idx).unwrap();
        let src_v =
            TensorValue::i32(vec![c_in], src.iter().map(|&v| v as i32).collect()).unwrap();
        let name = format!("sparse_fwd_{c_out}x{c_in}");

        let mut engine = NativeEngine::default();
        engine
            .bind("layers.0.wq", &name, &[("vals", &vals), ("idx", &idx), ("src_of", &src_v)])
            .unwrap();

        let x_v = TensorValue::from_mat(&x);
        let want =
            engine.run_bound("layers.0.wq", std::slice::from_ref(&x_v)).unwrap()[0].to_mat().unwrap();

        let mut arena = StepArena::new();
        // Warm up the arena, then assert the steady-state call is served
        // from the pools and stays bit-identical.
        let y = engine.run_bound_mat("layers.0.wq", &x, &mut arena).unwrap().unwrap();
        assert_eq!(y.data(), want.data());
        arena.give(y);
        arena.step();
        let grows = arena.grow_events();
        let y = engine.run_bound_mat("layers.0.wq", &x, &mut arena).unwrap().unwrap();
        assert_eq!(y.data(), want.data());
        assert_eq!(arena.grow_events(), grows, "steady-state scratch call must not allocate");

        // Unknown keys report the error through the Some(Err) channel.
        assert!(engine.run_bound_mat("nope", &x, &mut arena).unwrap().is_err());
    }

    #[test]
    fn lm_forward_matches_host_forward() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = crate::model::synth_trained_params(&cfg, 3);
        let mut rng = Pcg32::seeded(4);
        let (bsz, t) = (2usize, 16usize);
        let batch: Vec<Vec<u8>> =
            (0..bsz).map(|_| (0..t).map(|_| rng.below(256) as u8).collect()).collect();

        let mut inputs = Vec::new();
        for name in cfg.param_names() {
            let shape = cfg.param_shape(&name);
            inputs.push(TensorValue::f32(shape, ps.get(&name).data().to_vec()).unwrap());
        }
        let toks: Vec<i32> = batch.iter().flat_map(|s| s.iter().map(|&b| b as i32)).collect();
        inputs.push(TensorValue::i32(vec![bsz, t], toks).unwrap());

        let mut engine = NativeEngine::with_model(cfg.clone());
        let outs = engine.run("lm_forward", &inputs).unwrap();
        assert_eq!(outs[0].shape(), &[bsz, t, cfg.vocab]);
        let host = crate::model::lm_forward(&ps, &batch);
        let mut want = Vec::new();
        for l in &host {
            want.extend_from_slice(l.data());
        }
        assert_eq!(outs[0].as_f32().unwrap(), &want[..]);
    }

    #[test]
    fn unknown_and_malformed_artifacts_error() {
        let mut engine = NativeEngine::default();
        assert!(engine.run("nonexistent", &[]).is_err());
        assert!(engine.run("sinkhorn_soft_axb", &[]).is_err());
        assert!(!engine.supports("lm_forward")); // no model configured
        assert!(engine.supports("sinkhorn_soft_4x16"));
        assert!(engine.run("lm_forward", &[]).is_err());
    }

    #[test]
    fn arity_and_shape_are_validated() {
        let mut engine = NativeEngine::default();
        let err = engine.run("sinkhorn_soft_2x4", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("inputs"), "{err:#}");
        let bad = [
            TensorValue::f32(vec![3], vec![0.0; 3]).unwrap(),
            TensorValue::scalar(1.0),
        ];
        let err = engine.run("sinkhorn_soft_2x4", &bad).unwrap_err();
        assert!(format!("{err:#}").contains("elements"), "{err:#}");
    }
}
