//! Request queue + micro-batchers for the serving subsystem.
//!
//! Requests carry per-request activation rows; the [`MicroBatcher`]
//! coalesces them (FIFO) into token-budgeted micro-batches that amortize
//! the per-artifact dispatch cost, and the [`ReorderBuffer`] re-emits
//! completed batches in submission order even when the execution engine
//! finishes them out of order.
//!
//! The [`ContinuousBatcher`] is the decode-pool generalization: its pool
//! holds *steps* rather than whole requests — a new request's prefill
//! (all prompt rows at once) and an in-flight request's next decode
//! token (one row) are both [`StepItem`]s, coalesced FIFO into mixed
//! prefill + decode [`StepBatch`]es under the same [`BatcherCfg`]
//! budgets.  In-flight requests *rejoin* the pool after every generated
//! token, which is what makes the batching continuous: a long generation
//! never blocks the admission of new prompts, and new prompts never
//! stall token cadence for running requests beyond one step.

use std::collections::{BTreeMap, VecDeque};

use anyhow::Result;

use crate::tensor::Mat;

/// One inference request: `x` is `[tokens, width]` activations for the
/// serving pipeline's entry layer.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub x: Mat,
}

/// A coalesced micro-batch: member requests stacked row-wise, plus the
/// bookkeeping to split results back out per request.
#[derive(Debug, Clone)]
pub struct MicroBatch {
    /// Submission sequence number (0, 1, 2, ... in drain order).
    pub seq: u64,
    /// Member request ids, in stacking order.
    pub ids: Vec<u64>,
    /// Row span `[lo, hi)` of each member inside `x`.
    spans: Vec<(usize, usize)>,
    /// `[total_tokens, width]` stacked activations.
    pub x: Mat,
}

impl MicroBatch {
    /// Tokens (rows) in this batch.
    pub fn tokens(&self) -> usize {
        self.x.rows()
    }

    /// Number of coalesced requests.
    pub fn n_requests(&self) -> usize {
        self.ids.len()
    }

    /// Row span `[lo, hi)` of each member request inside `x`, in stacking
    /// order.  Spans tile `[0, tokens)` contiguously — the serving path's
    /// attention glue treats each span as an independent sequence (RoPE
    /// positions restart, causal softmax never crosses a span boundary).
    pub fn spans(&self) -> &[(usize, usize)] {
        &self.spans
    }

    /// Split a `[total_tokens, c_out]` batch output back into per-request
    /// outputs, in stacking order.
    pub fn split(&self, y: &Mat) -> Vec<(u64, Mat)> {
        assert_eq!(y.rows(), self.tokens(), "batch output row count mismatch");
        self.ids
            .iter()
            .zip(&self.spans)
            .map(|(&id, &(lo, hi))| {
                let mut part = Mat::zeros(hi - lo, y.cols());
                for (r, src) in (lo..hi).enumerate() {
                    part.row_mut(r).copy_from_slice(y.row(src));
                }
                (id, part)
            })
            .collect()
    }
}

/// Micro-batcher limits.
#[derive(Debug, Clone)]
pub struct BatcherCfg {
    /// Token budget per micro-batch (a single larger request still forms
    /// its own batch — big requests are admitted, not starved).
    pub max_tokens: usize,
    /// Cap on coalesced requests per micro-batch.
    pub max_requests: usize,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_tokens: 256, max_requests: 16 }
    }
}

/// FIFO request queue that drains into token-budgeted micro-batches.
#[derive(Debug)]
pub struct MicroBatcher {
    cfg: BatcherCfg,
    /// Activation width every request must match.
    width: usize,
    pending: VecDeque<Request>,
    next_seq: u64,
}

impl MicroBatcher {
    pub fn new(width: usize, cfg: BatcherCfg) -> MicroBatcher {
        MicroBatcher { cfg, width, pending: VecDeque::new(), next_seq: 0 }
    }

    /// Enqueue a request (validates the activation width).
    pub fn push(&mut self, req: Request) -> Result<()> {
        anyhow::ensure!(
            req.x.cols() == self.width,
            "request {}: width {} != serving width {}",
            req.id,
            req.x.cols(),
            self.width
        );
        anyhow::ensure!(req.x.rows() > 0, "request {}: empty activation batch", req.id);
        self.pending.push_back(req);
        Ok(())
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.len()
    }

    /// Coalesce the next micro-batch (FIFO, greedy up to the caps), or
    /// `None` when the queue is empty.
    pub fn next_batch(&mut self) -> Option<MicroBatch> {
        let first = self.pending.pop_front()?;
        let mut members = vec![first];
        let mut tokens = members[0].x.rows();
        while members.len() < self.cfg.max_requests {
            let Some(next) = self.pending.front() else { break };
            if tokens + next.x.rows() > self.cfg.max_tokens {
                break;
            }
            tokens += next.x.rows();
            members.push(self.pending.pop_front().expect("front() was Some"));
        }
        let mut x = Mat::zeros(tokens, self.width);
        let mut ids = Vec::with_capacity(members.len());
        let mut spans = Vec::with_capacity(members.len());
        let mut lo = 0;
        for req in &members {
            let hi = lo + req.x.rows();
            for r in 0..req.x.rows() {
                x.row_mut(lo + r).copy_from_slice(req.x.row(r));
            }
            ids.push(req.id);
            spans.push((lo, hi));
            lo = hi;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        Some(MicroBatch { seq, ids, spans, x })
    }

    /// Drain the whole queue into micro-batches.
    pub fn drain(&mut self) -> Vec<MicroBatch> {
        let mut out = Vec::new();
        while let Some(b) = self.next_batch() {
            out.push(b);
        }
        out
    }
}

/// Re-emits completed work in submission (`seq`) order: completions may
/// arrive out of order (e.g. from an engine that retires small batches
/// first), and consumers still see 0, 1, 2, ...
#[derive(Debug, Default)]
pub struct ReorderBuffer<T> {
    next: u64,
    held: BTreeMap<u64, T>,
}

impl<T> ReorderBuffer<T> {
    pub fn new() -> ReorderBuffer<T> {
        ReorderBuffer { next: 0, held: BTreeMap::new() }
    }

    /// Accept completion `seq`; returns every item now deliverable in
    /// order (empty if `seq` is still ahead of the emission frontier).
    pub fn push(&mut self, seq: u64, item: T) -> Vec<(u64, T)> {
        self.held.insert(seq, item);
        let mut out = Vec::new();
        while let Some(item) = self.held.remove(&self.next) {
            out.push((self.next, item));
            self.next += 1;
        }
        out
    }

    /// True when nothing is parked waiting for an earlier completion.
    pub fn is_empty(&self) -> bool {
        self.held.is_empty()
    }
}

/// One schedulable step of a generation request: the activation rows to
/// run next (the whole prompt for a prefill, one token row for a decode
/// step) plus an opaque payload the serving loop threads through the
/// stage chain (its generation state and KV cache).
#[derive(Debug)]
pub struct StepItem<T> {
    pub id: u64,
    /// `[rows, width]` activations for this step.
    pub x: Mat,
    /// True for a new request's prompt pass, false for a decode step.
    pub is_prefill: bool,
    pub payload: T,
}

/// A coalesced decode-pool batch: member steps stacked row-wise, mixed
/// prefill + decode, each span attending through its own member's cache.
#[derive(Debug)]
pub struct StepBatch<T> {
    /// Dispatch sequence number (0, 1, 2, ... in drain order).
    pub seq: u64,
    /// Member request ids, in stacking order.
    pub ids: Vec<u64>,
    /// Row span `[lo, hi)` of each member inside `x` (tile `[0, tokens)`
    /// contiguously; each span holds only that member's *new* rows).
    spans: Vec<(usize, usize)>,
    /// Per-member prefill flag, parallel to `ids`.
    pub prefill: Vec<bool>,
    /// `[total_tokens, width]` stacked activations.
    pub x: Mat,
    /// Per-member payloads, parallel to `ids`.
    pub payloads: Vec<T>,
}

impl<T> StepBatch<T> {
    /// Tokens (rows) in this step batch.
    pub fn tokens(&self) -> usize {
        self.x.rows()
    }

    /// Number of coalesced member steps.
    pub fn n_requests(&self) -> usize {
        self.ids.len()
    }

    /// Row span `[lo, hi)` of each member inside `x`, in stacking order.
    pub fn spans(&self) -> &[(usize, usize)] {
        &self.spans
    }

    /// Prompt rows in this batch (prefill spans).
    pub fn prefill_tokens(&self) -> usize {
        self.span_tokens(true)
    }

    /// Decode rows in this batch (one per decoding member).
    pub fn decode_tokens(&self) -> usize {
        self.span_tokens(false)
    }

    fn span_tokens(&self, prefill: bool) -> usize {
        self.spans
            .iter()
            .zip(&self.prefill)
            .filter(|&(_, &p)| p == prefill)
            .map(|(&(lo, hi), _)| hi - lo)
            .sum()
    }
}

/// FIFO decode pool that drains into token-budgeted [`StepBatch`]es —
/// the continuous-batching scheduler.  Prefill steps of newly admitted
/// requests and decode steps of rejoining in-flight requests share one
/// pool in arrival order, so a batch naturally mixes the two under the
/// existing [`BatcherCfg`] budgets (a prefill costs its prompt length
/// against `max_tokens`, a decode step costs 1).
#[derive(Debug)]
pub struct ContinuousBatcher<T> {
    cfg: BatcherCfg,
    width: usize,
    pool: VecDeque<StepItem<T>>,
    next_seq: u64,
    /// Retired activation storage, reused for step-batch assembly so the
    /// steady-state decode loop stops allocating per step: consumed
    /// member buffers land here after their rows are stacked, and
    /// [`ContinuousBatcher::recycle`] lets the serving loop return
    /// finished batch matrices.  Bounded ([`Self::MAX_FREE`]) and
    /// best-fit by capacity, mirroring `util::scratch::StepArena`.
    free: Vec<Vec<f32>>,
}

impl<T> ContinuousBatcher<T> {
    /// Cap on retired buffers kept for reuse; beyond this, returned
    /// storage is simply dropped (the pool is an optimization, not an
    /// obligation).
    const MAX_FREE: usize = 64;

    pub fn new(width: usize, cfg: BatcherCfg) -> ContinuousBatcher<T> {
        ContinuousBatcher { cfg, width, pool: VecDeque::new(), next_seq: 0, free: Vec::new() }
    }

    /// Return a finished matrix's storage to the assembly pool (e.g. a
    /// dispatched batch's `x` once the serving loop is done with it, or
    /// a preempted victim's step rows).  Purely an allocation-recycling
    /// hint — dropping the matrix instead is always correct.
    pub fn recycle(&mut self, m: Mat) {
        let v = m.into_vec();
        if v.capacity() > 0 && self.free.len() < Self::MAX_FREE {
            self.free.push(v);
        }
    }

    /// Retired buffers currently held for reuse (test/bench visibility).
    pub fn recycled(&self) -> usize {
        self.free.len()
    }

    /// Zeroed `n`-float storage, served from the smallest sufficient
    /// retired buffer when one exists (same best-fit rule as
    /// `util::scratch::StepArena::take_vec`).
    fn take_storage(&mut self, n: usize) -> Vec<f32> {
        let mut best: Option<(usize, usize)> = None;
        for (i, buf) in self.free.iter().enumerate() {
            let cap = buf.capacity();
            if cap >= n && best.is_none_or(|(_, c)| cap < c) {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                let mut buf = self.free.swap_remove(i);
                buf.clear();
                buf.resize(n, 0.0);
                buf
            }
            None => vec![0.0; n],
        }
    }

    /// Enqueue a step (validates the activation width; decode steps must
    /// be exactly one row).
    pub fn push(&mut self, item: StepItem<T>) -> Result<()> {
        anyhow::ensure!(
            item.x.cols() == self.width,
            "request {}: width {} != serving width {}",
            item.id,
            item.x.cols(),
            self.width
        );
        anyhow::ensure!(item.x.rows() > 0, "request {}: empty step", item.id);
        anyhow::ensure!(
            item.is_prefill || item.x.rows() == 1,
            "request {}: decode step has {} rows, expected 1",
            item.id,
            item.x.rows()
        );
        self.pool.push_back(item);
        Ok(())
    }

    /// Steps waiting in the pool.
    pub fn pending(&self) -> usize {
        self.pool.len()
    }

    /// Tokens (rows) waiting in the pool.
    pub fn pending_tokens(&self) -> usize {
        self.pool.iter().map(|i| i.x.rows()).sum()
    }

    /// Coalesce the next step batch (FIFO, greedy up to the caps), or
    /// `None` when the pool is empty.  A single over-budget prefill still
    /// forms its own batch — big prompts are admitted, not starved.
    pub fn next_batch(&mut self) -> Option<StepBatch<T>> {
        self.next_batch_gated(|_| true)
    }

    /// [`ContinuousBatcher::next_batch`] with an admission gate: the
    /// front step must pass `gate` or no batch forms at all — steps park
    /// in the pool, FIFO order intact, so later arrivals never overtake
    /// a starved front.  Follow-up steps join only while the budgets
    /// hold *and* the gate passes; the first gate miss ends the batch.
    ///
    /// `gate` may mutate the step (the paged decode loop funds the
    /// step's KV page reservation inside its gate, so the `true` verdict
    /// and the pages it claims are one atomic decision).  A gate that is
    /// always `true` makes this exactly [`ContinuousBatcher::next_batch`].
    pub fn next_batch_gated(
        &mut self,
        mut gate: impl FnMut(&mut StepItem<T>) -> bool,
    ) -> Option<StepBatch<T>> {
        if !gate(self.pool.front_mut()?) {
            return None;
        }
        let first = self.pool.pop_front().expect("front was gated");
        let mut members = vec![first];
        let mut tokens = members[0].x.rows();
        while members.len() < self.cfg.max_requests {
            let Some(next) = self.pool.front_mut() else { break };
            if tokens + next.x.rows() > self.cfg.max_tokens || !gate(next) {
                break;
            }
            tokens += next.x.rows();
            members.push(self.pool.pop_front().expect("front() was Some"));
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        // Single-member batch: its rows *are* the batch — move them
        // through untouched (no copy, no allocation, bit-identical by
        // construction).
        if members.len() == 1 {
            let item = members.pop().expect("one member");
            return Some(StepBatch {
                seq,
                ids: vec![item.id],
                spans: vec![(0, tokens)],
                prefill: vec![item.is_prefill],
                x: item.x,
                payloads: vec![item.payload],
            });
        }
        // Multi-member: stack rows into pooled storage.  The spans tile
        // `[0, tokens)` contiguously, so every row of `x` is overwritten
        // by exactly one member copy — a recycled (stale-valued) buffer
        // is as correct as a fresh zeroed one.
        let mut x = Mat::from_vec(tokens, self.width, self.take_storage(tokens * self.width));
        let mut ids = Vec::with_capacity(members.len());
        let mut spans = Vec::with_capacity(members.len());
        let mut prefill = Vec::with_capacity(members.len());
        let mut payloads = Vec::with_capacity(members.len());
        let mut lo = 0;
        for item in members {
            let hi = lo + item.x.rows();
            for r in 0..item.x.rows() {
                x.row_mut(lo + r).copy_from_slice(item.x.row(r));
            }
            ids.push(item.id);
            spans.push((lo, hi));
            prefill.push(item.is_prefill);
            payloads.push(item.payload);
            lo = hi;
            // The member's rows now live in the batch; its storage feeds
            // the next assembly.
            self.recycle(item.x);
        }
        Some(StepBatch { seq, ids, spans, prefill, x, payloads })
    }

    /// Remove and return the newest (highest request id) single-row
    /// decode step that is *not* at the front of the pool — the
    /// preemption victim when the shared KV pool runs dry.  Evicting the
    /// youngest generation frees the most future-facing pages for the
    /// starved older front, and the front itself is never stolen (it is
    /// the very step the scheduler is trying to admit).  Prefill steps
    /// hold no pages yet and are never victims.
    pub fn steal_newest_decode(&mut self) -> Option<StepItem<T>> {
        let at = self
            .pool
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, it)| !it.is_prefill)
            .max_by_key(|(_, it)| it.id)
            .map(|(i, _)| i)?;
        self.pool.remove(at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn req(id: u64, rows: usize, width: usize, rng: &mut Pcg32) -> Request {
        Request { id, x: Mat::randn(rows, width, 1.0, rng) }
    }

    #[test]
    fn coalesces_fifo_within_budgets() {
        let mut rng = Pcg32::seeded(1);
        let mut b = MicroBatcher::new(4, BatcherCfg { max_tokens: 10, max_requests: 3 });
        for (id, rows) in [(0u64, 4usize), (1, 4), (2, 4), (3, 2), (4, 9), (5, 1)] {
            b.push(req(id, rows, 4, &mut rng)).unwrap();
        }
        let batches = b.drain();
        // 0+1 fit (8 <= 10), 2 would overflow; 2+3 fit (6), 4 would
        // overflow; 4+5 exactly hit the budget (9+1 = 10).
        let ids: Vec<Vec<u64>> = batches.iter().map(|b| b.ids.clone()).collect();
        assert_eq!(ids, vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
        assert_eq!(batches.iter().map(|b| b.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(batches[0].tokens(), 8);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn oversized_request_forms_its_own_batch() {
        let mut rng = Pcg32::seeded(2);
        let mut b = MicroBatcher::new(2, BatcherCfg { max_tokens: 4, max_requests: 8 });
        b.push(req(7, 9, 2, &mut rng)).unwrap();
        let batch = b.next_batch().expect("oversized request must still be served");
        assert_eq!(batch.ids, vec![7]);
        assert_eq!(batch.tokens(), 9);
    }

    #[test]
    fn request_cap_limits_batch_size() {
        let mut rng = Pcg32::seeded(3);
        let mut b = MicroBatcher::new(2, BatcherCfg { max_tokens: 1000, max_requests: 2 });
        for id in 0..5u64 {
            b.push(req(id, 1, 2, &mut rng)).unwrap();
        }
        let sizes: Vec<usize> = b.drain().iter().map(|b| b.n_requests()).collect();
        assert_eq!(sizes, vec![2, 2, 1]);
    }

    #[test]
    fn rejects_wrong_width_and_empty() {
        let mut rng = Pcg32::seeded(4);
        let mut b = MicroBatcher::new(4, BatcherCfg::default());
        assert!(b.push(req(0, 2, 3, &mut rng)).is_err());
        assert!(b.push(Request { id: 1, x: Mat::zeros(0, 4) }).is_err());
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn split_recovers_request_rows_exactly() {
        let mut rng = Pcg32::seeded(5);
        let reqs: Vec<Request> = [(10u64, 3usize), (11, 2), (12, 4)]
            .iter()
            .map(|&(id, r)| req(id, r, 4, &mut rng))
            .collect();
        let mut b = MicroBatcher::new(4, BatcherCfg { max_tokens: 100, max_requests: 8 });
        for r in &reqs {
            b.push(r.clone()).unwrap();
        }
        let batch = b.next_batch().unwrap();
        // Identity "layer": output == stacked input; split must hand every
        // request exactly its own rows back.
        let parts = batch.split(&batch.x);
        assert_eq!(parts.len(), 3);
        for ((id, part), orig) in parts.iter().zip(&reqs) {
            assert_eq!(*id, orig.id);
            assert_eq!(part.data(), orig.x.data());
        }
    }

    fn step(id: u64, rows: usize, prefill: bool, rng: &mut Pcg32) -> StepItem<&'static str> {
        StepItem { id, x: Mat::randn(rows, 4, 1.0, rng), is_prefill: prefill, payload: "p" }
    }

    #[test]
    fn continuous_batcher_mixes_prefill_and_decode_under_budgets() {
        let mut rng = Pcg32::seeded(6);
        let mut cb = ContinuousBatcher::new(4, BatcherCfg { max_tokens: 6, max_requests: 4 });
        // Arrival order: decode(1), prefill(4), decode(1), decode(1),
        // prefill(5), decode(1).
        cb.push(step(0, 1, false, &mut rng)).unwrap();
        cb.push(step(1, 4, true, &mut rng)).unwrap();
        cb.push(step(2, 1, false, &mut rng)).unwrap();
        cb.push(step(3, 1, false, &mut rng)).unwrap();
        cb.push(step(4, 5, true, &mut rng)).unwrap();
        cb.push(step(5, 1, false, &mut rng)).unwrap();
        assert_eq!(cb.pending(), 6);
        assert_eq!(cb.pending_tokens(), 13);
        // Batch 0: 1+4+1 = 6 tokens (budget hit; next decode would be 7).
        let b0 = cb.next_batch().unwrap();
        assert_eq!(b0.ids, vec![0, 1, 2]);
        assert_eq!(b0.prefill, vec![false, true, false]);
        assert_eq!(b0.spans(), &[(0, 1), (1, 5), (5, 6)]);
        assert_eq!(b0.decode_tokens(), 2);
        assert_eq!(b0.prefill_tokens(), 4);
        // Batch 1: decode(1) + prefill(5) exactly hit the budget.
        let b1 = cb.next_batch().unwrap();
        assert_eq!(b1.ids, vec![3, 4]);
        assert_eq!(b1.tokens(), 6);
        // Batch 2: the trailing decode step alone.
        let b2 = cb.next_batch().unwrap();
        assert_eq!(b2.ids, vec![5]);
        assert_eq!((b0.seq, b1.seq, b2.seq), (0, 1, 2));
        assert!(cb.next_batch().is_none());
    }

    #[test]
    fn continuous_batcher_admits_oversized_prefill_alone() {
        let mut rng = Pcg32::seeded(7);
        let mut cb = ContinuousBatcher::new(4, BatcherCfg { max_tokens: 4, max_requests: 8 });
        cb.push(step(9, 11, true, &mut rng)).unwrap();
        cb.push(step(10, 1, false, &mut rng)).unwrap();
        let b = cb.next_batch().unwrap();
        assert_eq!(b.ids, vec![9]);
        assert_eq!(b.tokens(), 11);
        assert_eq!(cb.next_batch().unwrap().ids, vec![10]);
    }

    #[test]
    fn continuous_batcher_validates_steps() {
        let mut rng = Pcg32::seeded(8);
        let mut cb = ContinuousBatcher::new(4, BatcherCfg::default());
        // Wrong width.
        assert!(cb
            .push(StepItem { id: 0, x: Mat::zeros(1, 3), is_prefill: false, payload: "p" })
            .is_err());
        // Empty step.
        assert!(cb
            .push(StepItem { id: 1, x: Mat::zeros(0, 4), is_prefill: true, payload: "p" })
            .is_err());
        // Multi-row decode step.
        assert!(cb.push(step(2, 3, false, &mut rng)).is_err());
        assert_eq!(cb.pending(), 0);
    }

    #[test]
    fn step_batch_payloads_and_rows_stay_aligned() {
        let mut rng = Pcg32::seeded(9);
        let mut cb: ContinuousBatcher<u64> =
            ContinuousBatcher::new(4, BatcherCfg { max_tokens: 100, max_requests: 8 });
        let items: Vec<(u64, usize, bool)> = vec![(10, 3, true), (11, 1, false), (12, 2, true)];
        let mut rows = Vec::new();
        for &(id, r, pre) in &items {
            let x = Mat::randn(r, 4, 1.0, &mut rng);
            rows.push(x.clone());
            cb.push(StepItem { id, x, is_prefill: pre, payload: id * 100 }).unwrap();
        }
        let b = cb.next_batch().unwrap();
        assert_eq!(b.payloads, vec![1000, 1100, 1200]);
        for ((&(lo, hi), x), &(_, r, _)) in b.spans().iter().zip(&rows).zip(&items) {
            assert_eq!(hi - lo, r);
            assert_eq!(&b.x.data()[lo * 4..hi * 4], x.data());
        }
    }

    #[test]
    fn gated_batch_parks_on_front_failure_and_stops_at_first_miss() {
        let mut rng = Pcg32::seeded(10);
        let mut cb = ContinuousBatcher::new(4, BatcherCfg { max_tokens: 10, max_requests: 8 });
        for id in 0..4u64 {
            cb.push(step(id, 1, false, &mut rng)).unwrap();
        }
        // Front fails the gate: nothing forms, nothing is lost, and the
        // FIFO order is untouched — later steps never overtake it.
        assert!(cb.next_batch_gated(|it| it.id != 0).is_none());
        assert_eq!(cb.pending(), 4);
        // Gate admits 0 and 1, rejects 2: the batch ends there even
        // though the budgets had room, and 2, 3 stay queued in order.
        let b = cb.next_batch_gated(|it| it.id < 2).unwrap();
        assert_eq!(b.ids, vec![0, 1]);
        assert_eq!(cb.pending(), 2);
        // A trivially-true gate is exactly next_batch.
        let b = cb.next_batch_gated(|_| true).unwrap();
        assert_eq!(b.ids, vec![2, 3]);
        assert!(cb.next_batch_gated(|_| true).is_none());
    }

    #[test]
    fn steal_newest_decode_skips_front_and_prefills() {
        let mut rng = Pcg32::seeded(11);
        let mut cb = ContinuousBatcher::new(4, BatcherCfg::default());
        cb.push(step(5, 1, false, &mut rng)).unwrap(); // front: never stolen
        cb.push(step(9, 3, true, &mut rng)).unwrap(); // prefill: never stolen
        cb.push(step(7, 1, false, &mut rng)).unwrap();
        cb.push(step(8, 1, false, &mut rng)).unwrap();
        assert_eq!(cb.steal_newest_decode().expect("victim").id, 8);
        assert_eq!(cb.steal_newest_decode().expect("victim").id, 7);
        assert!(cb.steal_newest_decode().is_none(), "front and prefills are not victims");
        // The survivors still batch in FIFO order.
        let b = cb.next_batch().unwrap();
        assert_eq!(b.ids, vec![5, 9]);
    }

    #[test]
    fn assembly_reuses_recycled_storage_and_single_member_moves_through() {
        let mut rng = Pcg32::seeded(12);
        let mut cb = ContinuousBatcher::new(4, BatcherCfg { max_tokens: 100, max_requests: 8 });
        // Single-member batch: rows move through untouched, no copy.
        let x = Mat::randn(3, 4, 1.0, &mut rng);
        let expect = x.data().to_vec();
        cb.push(StepItem { id: 0, x, is_prefill: true, payload: "p" }).unwrap();
        let b = cb.next_batch().unwrap();
        assert_eq!(b.spans(), &[(0, 3)]);
        assert_eq!(b.x.data(), &expect[..]);
        assert_eq!(cb.recycled(), 0, "a moved-through batch consumes no pooled storage");
        // Return the batch storage, then stack two members: assembly must
        // be bit-identical to a fresh buffer while reusing the returned
        // one, and the consumed member buffers feed the pool in turn.
        cb.recycle(b.x);
        assert_eq!(cb.recycled(), 1);
        let m0 = Mat::randn(2, 4, 1.0, &mut rng);
        let m1 = Mat::randn(1, 4, 1.0, &mut rng);
        let mut expect = m0.data().to_vec();
        expect.extend_from_slice(m1.data());
        cb.push(StepItem { id: 1, x: m0, is_prefill: true, payload: "p" }).unwrap();
        cb.push(StepItem { id: 2, x: m1, is_prefill: false, payload: "p" }).unwrap();
        let b = cb.next_batch().unwrap();
        assert_eq!(b.spans(), &[(0, 2), (2, 3)]);
        assert_eq!(b.x.data(), &expect[..]);
        assert_eq!(cb.recycled(), 2, "both member buffers were retired into the pool");
    }

    #[test]
    fn reorder_buffer_emits_submission_order_under_out_of_order_completion() {
        let mut rb = ReorderBuffer::new();
        // Completions arrive 2, 0, 3, 1, 4 — emission must be 0, 1, 2, 3, 4.
        assert!(rb.push(2, "b2").is_empty());
        assert_eq!(rb.push(0, "b0"), vec![(0, "b0")]);
        assert!(rb.push(3, "b3").is_empty());
        assert_eq!(rb.push(1, "b1"), vec![(1, "b1"), (2, "b2"), (3, "b3")]);
        assert_eq!(rb.push(4, "b4"), vec![(4, "b4")]);
        assert!(rb.is_empty());
    }
}
