//! Request queue + micro-batcher for the serving subsystem.
//!
//! Requests carry per-request activation rows; the [`MicroBatcher`]
//! coalesces them (FIFO) into token-budgeted micro-batches that amortize
//! the per-artifact dispatch cost, and the [`ReorderBuffer`] re-emits
//! completed batches in submission order even when the execution engine
//! finishes them out of order.

use std::collections::{BTreeMap, VecDeque};

use anyhow::Result;

use crate::tensor::Mat;

/// One inference request: `x` is `[tokens, width]` activations for the
/// serving pipeline's entry layer.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub x: Mat,
}

/// A coalesced micro-batch: member requests stacked row-wise, plus the
/// bookkeeping to split results back out per request.
#[derive(Debug, Clone)]
pub struct MicroBatch {
    /// Submission sequence number (0, 1, 2, ... in drain order).
    pub seq: u64,
    /// Member request ids, in stacking order.
    pub ids: Vec<u64>,
    /// Row span `[lo, hi)` of each member inside `x`.
    spans: Vec<(usize, usize)>,
    /// `[total_tokens, width]` stacked activations.
    pub x: Mat,
}

impl MicroBatch {
    /// Tokens (rows) in this batch.
    pub fn tokens(&self) -> usize {
        self.x.rows()
    }

    /// Number of coalesced requests.
    pub fn n_requests(&self) -> usize {
        self.ids.len()
    }

    /// Row span `[lo, hi)` of each member request inside `x`, in stacking
    /// order.  Spans tile `[0, tokens)` contiguously — the serving path's
    /// attention glue treats each span as an independent sequence (RoPE
    /// positions restart, causal softmax never crosses a span boundary).
    pub fn spans(&self) -> &[(usize, usize)] {
        &self.spans
    }

    /// Split a `[total_tokens, c_out]` batch output back into per-request
    /// outputs, in stacking order.
    pub fn split(&self, y: &Mat) -> Vec<(u64, Mat)> {
        assert_eq!(y.rows(), self.tokens(), "batch output row count mismatch");
        self.ids
            .iter()
            .zip(&self.spans)
            .map(|(&id, &(lo, hi))| {
                let mut part = Mat::zeros(hi - lo, y.cols());
                for (r, src) in (lo..hi).enumerate() {
                    part.row_mut(r).copy_from_slice(y.row(src));
                }
                (id, part)
            })
            .collect()
    }
}

/// Micro-batcher limits.
#[derive(Debug, Clone)]
pub struct BatcherCfg {
    /// Token budget per micro-batch (a single larger request still forms
    /// its own batch — big requests are admitted, not starved).
    pub max_tokens: usize,
    /// Cap on coalesced requests per micro-batch.
    pub max_requests: usize,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_tokens: 256, max_requests: 16 }
    }
}

/// FIFO request queue that drains into token-budgeted micro-batches.
#[derive(Debug)]
pub struct MicroBatcher {
    cfg: BatcherCfg,
    /// Activation width every request must match.
    width: usize,
    pending: VecDeque<Request>,
    next_seq: u64,
}

impl MicroBatcher {
    pub fn new(width: usize, cfg: BatcherCfg) -> MicroBatcher {
        MicroBatcher { cfg, width, pending: VecDeque::new(), next_seq: 0 }
    }

    /// Enqueue a request (validates the activation width).
    pub fn push(&mut self, req: Request) -> Result<()> {
        anyhow::ensure!(
            req.x.cols() == self.width,
            "request {}: width {} != serving width {}",
            req.id,
            req.x.cols(),
            self.width
        );
        anyhow::ensure!(req.x.rows() > 0, "request {}: empty activation batch", req.id);
        self.pending.push_back(req);
        Ok(())
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.len()
    }

    /// Coalesce the next micro-batch (FIFO, greedy up to the caps), or
    /// `None` when the queue is empty.
    pub fn next_batch(&mut self) -> Option<MicroBatch> {
        let first = self.pending.pop_front()?;
        let mut members = vec![first];
        let mut tokens = members[0].x.rows();
        while members.len() < self.cfg.max_requests {
            let Some(next) = self.pending.front() else { break };
            if tokens + next.x.rows() > self.cfg.max_tokens {
                break;
            }
            tokens += next.x.rows();
            members.push(self.pending.pop_front().expect("front() was Some"));
        }
        let mut x = Mat::zeros(tokens, self.width);
        let mut ids = Vec::with_capacity(members.len());
        let mut spans = Vec::with_capacity(members.len());
        let mut lo = 0;
        for req in &members {
            let hi = lo + req.x.rows();
            for r in 0..req.x.rows() {
                x.row_mut(lo + r).copy_from_slice(req.x.row(r));
            }
            ids.push(req.id);
            spans.push((lo, hi));
            lo = hi;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        Some(MicroBatch { seq, ids, spans, x })
    }

    /// Drain the whole queue into micro-batches.
    pub fn drain(&mut self) -> Vec<MicroBatch> {
        let mut out = Vec::new();
        while let Some(b) = self.next_batch() {
            out.push(b);
        }
        out
    }
}

/// Re-emits completed work in submission (`seq`) order: completions may
/// arrive out of order (e.g. from an engine that retires small batches
/// first), and consumers still see 0, 1, 2, ...
#[derive(Debug, Default)]
pub struct ReorderBuffer<T> {
    next: u64,
    held: BTreeMap<u64, T>,
}

impl<T> ReorderBuffer<T> {
    pub fn new() -> ReorderBuffer<T> {
        ReorderBuffer { next: 0, held: BTreeMap::new() }
    }

    /// Accept completion `seq`; returns every item now deliverable in
    /// order (empty if `seq` is still ahead of the emission frontier).
    pub fn push(&mut self, seq: u64, item: T) -> Vec<(u64, T)> {
        self.held.insert(seq, item);
        let mut out = Vec::new();
        while let Some(item) = self.held.remove(&self.next) {
            out.push((self.next, item));
            self.next += 1;
        }
        out
    }

    /// True when nothing is parked waiting for an earlier completion.
    pub fn is_empty(&self) -> bool {
        self.held.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn req(id: u64, rows: usize, width: usize, rng: &mut Pcg32) -> Request {
        Request { id, x: Mat::randn(rows, width, 1.0, rng) }
    }

    #[test]
    fn coalesces_fifo_within_budgets() {
        let mut rng = Pcg32::seeded(1);
        let mut b = MicroBatcher::new(4, BatcherCfg { max_tokens: 10, max_requests: 3 });
        for (id, rows) in [(0u64, 4usize), (1, 4), (2, 4), (3, 2), (4, 9), (5, 1)] {
            b.push(req(id, rows, 4, &mut rng)).unwrap();
        }
        let batches = b.drain();
        // 0+1 fit (8 <= 10), 2 would overflow; 2+3 fit (6), 4 would
        // overflow; 4+5 exactly hit the budget (9+1 = 10).
        let ids: Vec<Vec<u64>> = batches.iter().map(|b| b.ids.clone()).collect();
        assert_eq!(ids, vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
        assert_eq!(batches.iter().map(|b| b.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(batches[0].tokens(), 8);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn oversized_request_forms_its_own_batch() {
        let mut rng = Pcg32::seeded(2);
        let mut b = MicroBatcher::new(2, BatcherCfg { max_tokens: 4, max_requests: 8 });
        b.push(req(7, 9, 2, &mut rng)).unwrap();
        let batch = b.next_batch().expect("oversized request must still be served");
        assert_eq!(batch.ids, vec![7]);
        assert_eq!(batch.tokens(), 9);
    }

    #[test]
    fn request_cap_limits_batch_size() {
        let mut rng = Pcg32::seeded(3);
        let mut b = MicroBatcher::new(2, BatcherCfg { max_tokens: 1000, max_requests: 2 });
        for id in 0..5u64 {
            b.push(req(id, 1, 2, &mut rng)).unwrap();
        }
        let sizes: Vec<usize> = b.drain().iter().map(|b| b.n_requests()).collect();
        assert_eq!(sizes, vec![2, 2, 1]);
    }

    #[test]
    fn rejects_wrong_width_and_empty() {
        let mut rng = Pcg32::seeded(4);
        let mut b = MicroBatcher::new(4, BatcherCfg::default());
        assert!(b.push(req(0, 2, 3, &mut rng)).is_err());
        assert!(b.push(Request { id: 1, x: Mat::zeros(0, 4) }).is_err());
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn split_recovers_request_rows_exactly() {
        let mut rng = Pcg32::seeded(5);
        let reqs: Vec<Request> = [(10u64, 3usize), (11, 2), (12, 4)]
            .iter()
            .map(|&(id, r)| req(id, r, 4, &mut rng))
            .collect();
        let mut b = MicroBatcher::new(4, BatcherCfg { max_tokens: 100, max_requests: 8 });
        for r in &reqs {
            b.push(r.clone()).unwrap();
        }
        let batch = b.next_batch().unwrap();
        // Identity "layer": output == stacked input; split must hand every
        // request exactly its own rows back.
        let parts = batch.split(&batch.x);
        assert_eq!(parts.len(), 3);
        for ((id, part), orig) in parts.iter().zip(&reqs) {
            assert_eq!(*id, orig.id);
            assert_eq!(part.data(), orig.x.data());
        }
    }

    #[test]
    fn reorder_buffer_emits_submission_order_under_out_of_order_completion() {
        let mut rb = ReorderBuffer::new();
        // Completions arrive 2, 0, 3, 1, 4 — emission must be 0, 1, 2, 3, 4.
        assert!(rb.push(2, "b2").is_empty());
        assert_eq!(rb.push(0, "b0"), vec![(0, "b0")]);
        assert!(rb.push(3, "b3").is_empty());
        assert_eq!(rb.push(1, "b1"), vec![(1, "b1"), (2, "b2"), (3, "b3")]);
        assert_eq!(rb.push(4, "b4"), vec![(4, "b4")]);
        assert!(rb.is_empty());
    }
}
