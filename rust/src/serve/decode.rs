//! KV-cached autoregressive decode with continuous batching — token
//! generation as a long-lived serving loop.
//!
//! [`Server::run_streaming`] serves one forward pass per request; this
//! module serves *generations*: a client submits a prompt
//! ([`DecodeClient::submit`] with a [`GenRequest`]) and its
//! [`GenTicket`] yields tokens as they are produced (greedy argmax or
//! seeded top-k over the LM head, per the request's [`Sampler`]),
//! ending after `max_new_tokens` or at the request's EOS token.
//!
//! The loop is a continuous batcher over *steps*, not requests:
//!
//! 1. the **scheduler** thread drains newly admitted prompts (prefill
//!    steps, all prompt rows at once, fresh [`KvStore`]) and rejoining
//!    in-flight requests (decode steps, one token row, warm cache) from
//!    one FIFO pool into mixed [`super::StepBatch`]es under the
//!    [`super::BatcherCfg`] budgets;
//! 2. the **stage chain** (one backend for all layers, or one per
//!    decoder layer, exactly like the forward streaming loop) runs each
//!    step batch through [`super::SparseModel::stage_cached`] — every
//!    span attends through its own request's cache at its own positions,
//!    so batching never changes a request's numbers;
//! 3. the **collector** computes each member's next token from the LM
//!    head with the request's own [`Sampler`] (and per-request RNG, so
//!    stochastic decoding is batching-independent), streams it to the
//!    ticket, and either completes the request or pushes it back into
//!    the pool for its next decode step — the rejoin that makes the
//!    batching continuous.
//!
//! Backpressure ([`super::ServeCfg::queue_depth`] /
//! [`super::ServeCfg::request_timeout`]) and shutdown semantics match
//! the forward loop: closing admissions drains every in-flight
//! generation to its stop condition before the loop returns.  The
//! timeout is a deadline on the *whole generation*: a request can
//! expire before prefill or mid-generation, every time it rejoins the
//! step pool — the ticket observes [`ServeError::TimedOut`], the
//! in-flight slot frees, and the request's [`KvStore`] drops.
//!
//! With [`super::ServeCfg::kv_pages`] nonzero, every generation's KV
//! lives in fixed-size pages of one shared [`super::KvPool`] instead of
//! a private contiguous buffer: the scheduler funds each step's page
//! demand before dispatch (admission by free pages, all-or-nothing — an
//! unfundable step parks and FIFO order is preserved), **preempts** the
//! youngest in-flight decode behind a starved front when the pool runs
//! dry (its pages return and it re-enters as a recompute prefill of its
//! prompt plus every token sampled so far — bit-identical, because
//! chunked and whole prefill agree and the request's RNG is untouched),
//! and with [`super::ServeCfg::kv_share_prefix`] publishes each
//! prompt's full prefill pages so later requests with the same prompt
//! prefix adopt them copy-on-write.  Paged and contiguous serving
//! produce identical tokens — including across a forced
//! preemption/recompute cycle — which the tests here pin.
//!
//! The loop is instrumented through the [`super::stats`] plane: submit,
//! scheduler, and collector record typed [`super::StatsEvent`]s, and
//! [`DecodeReport::stats`] carries the final [`super::StatsReport`]
//! (periodic reports stream through [`super::ServeCfg::stats_every`] /
//! [`super::ServeCfg::stats_sink`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{ContinuousBatcher, StepItem};
use super::model::{Sampler, ServePath};
use super::server::{Server, StageStats};
use super::stats::{
    ReqOutcome, SamplerStop, StatsEvent, StatsHub, StatsRecorder, StatsReport, StatsSink,
    DEFAULT_WINDOW,
};
use super::stream::{CloseGuard, HasClosed, ServeError, SharedQueue};
use crate::model::KvStore;
use crate::runtime::ExecBackend;
use crate::tensor::Mat;
use crate::util::rng::Pcg32;
use crate::util::scratch::StepArena;

/// One generation request: prompt token ids plus stop conditions and
/// the token-selection policy.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    /// Stop after this many generated tokens (>= 1).
    pub max_new_tokens: usize,
    /// Optional end-of-sequence token: generation stops when it is
    /// produced (the EOS token itself is still streamed).
    pub eos: Option<u32>,
    /// Token selection per decode step ([`Sampler::Greedy`], seeded
    /// [`Sampler::TopK`], or seeded [`Sampler::TopP`]; deterministic
    /// either way).
    pub sampler: Sampler,
}

impl GenRequest {
    /// Greedy generation with no EOS — the common case.
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest { prompt, max_new_tokens, eos: None, sampler: Sampler::Greedy }
    }
}

/// What the loop streams to a ticket.
#[derive(Debug)]
enum GenEvent {
    Token(u32),
    Done,
}

type GenReply = std::result::Result<GenEvent, ServeError>;

/// A claim on one in-flight generation's token stream.
pub struct GenTicket {
    id: u64,
    rx: mpsc::Receiver<GenReply>,
    finished: bool,
}

impl GenTicket {
    /// Block for the next generated token; `None` once the generation
    /// has ended (max-new-tokens, EOS, or a prior error).  Errors are
    /// terminal — after `Some(Err(_))` the stream is over.
    pub fn next_token(&mut self) -> Option<std::result::Result<u32, ServeError>> {
        if self.finished {
            return None;
        }
        match self.rx.recv() {
            Ok(Ok(GenEvent::Token(t))) => Some(Ok(t)),
            Ok(Ok(GenEvent::Done)) => {
                self.finished = true;
                None
            }
            Ok(Err(e)) => {
                self.finished = true;
                Some(Err(e))
            }
            Err(_) => {
                self.finished = true;
                Some(Err(ServeError::Dropped))
            }
        }
    }

    /// Block until the generation ends and return every generated token.
    /// On an error mid-generation the error is returned and any tokens
    /// already streamed are discarded — iterate [`GenTicket::next_token`]
    /// instead to keep confirmed partial output across a failure.
    pub fn wait(mut self) -> std::result::Result<Vec<u32>, ServeError> {
        let mut out = Vec::new();
        while let Some(tok) = self.next_token() {
            out.push(tok?);
        }
        Ok(out)
    }

    pub fn id(&self) -> u64 {
        self.id
    }
}

/// A generation admitted but not yet prefilled.
struct PendingGen {
    id: u64,
    prompt: Vec<u32>,
    max_new_tokens: usize,
    eos: Option<u32>,
    sampler: Sampler,
    reply: mpsc::Sender<GenReply>,
    enqueued: Instant,
}

/// The per-request generation state machine, moved through the stage
/// chain with its batch and back into the pool on rejoin.
struct GenState {
    id: u64,
    reply: mpsc::Sender<GenReply>,
    max_new_tokens: usize,
    eos: Option<u32>,
    /// Token-selection policy plus its private RNG: one draw per step,
    /// owned by the request, so trajectories are independent of how
    /// steps are batched.
    sampler: Sampler,
    rng: Pcg32,
    n_generated: usize,
    /// When the request was submitted — the generation-wide
    /// `request_timeout` deadline is measured from here, and so is the
    /// request's end-to-end latency sample.
    enqueued: Instant,
    /// When the previous token was streamed (the enqueue time until the
    /// first token) — per-token latency samples are the gaps.
    last_token_at: Instant,
    /// Last observed [`KvStore::bytes`] for this request, so the
    /// collector can record residency deltas and free the exact resident
    /// amount when the generation ends.
    kv_bytes: usize,
    /// Prompt plus every token sampled so far.  A preempted generation
    /// re-prefills exactly this sequence to rebuild its KV bit-for-bit,
    /// and its full-page prompt prefix is what gets published for
    /// sharing.
    tokens: Vec<u32>,
}

/// An in-flight request re-entering the pool for its next decode step.
struct Rejoin {
    state: GenState,
    cache: KvStore,
    /// The token just generated — the next step's input row.
    token: u32,
}

#[derive(Default)]
struct GenQueueState {
    pending: Vec<PendingGen>,
    rejoin: Vec<Rejoin>,
    closed: bool,
}

impl HasClosed for GenQueueState {
    fn set_closed(&mut self) {
        self.closed = true;
    }
}

/// Handle clients use to submit generations while the decode loop is
/// live (`Copy` — share it across submitting threads).
#[derive(Clone, Copy)]
pub struct DecodeClient<'q> {
    queue: &'q SharedQueue<GenQueueState>,
    next_id: &'q AtomicU64,
    vocab: usize,
    queue_depth: usize,
    max_new_cap: usize,
    /// `(pool pages, page tokens, layers)` when serving from a paged
    /// [`super::KvPool`] on the full-decoder path — lets `submit` reject
    /// a generation whose worst-case page demand could never fit, which
    /// would otherwise park forever.
    kv_check: Option<(usize, usize, usize)>,
    stats: &'q StatsRecorder,
}

impl DecodeClient<'_> {
    /// Submit a generation; returns a [`GenTicket`] streaming its
    /// tokens.  Fails fast with the typed reason:
    /// [`ServeError::Invalid`] for a malformed request,
    /// [`ServeError::QueueFull`] when `queue_depth` generations are
    /// already in flight, [`ServeError::ShuttingDown`] after the loop
    /// closed.
    pub fn submit(&self, req: GenRequest) -> std::result::Result<GenTicket, ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if req.prompt.is_empty() {
            return Err(ServeError::Invalid(format!("request {id}: empty prompt")));
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| t as usize >= self.vocab) {
            return Err(ServeError::Invalid(format!(
                "request {id}: prompt token {bad} outside vocab {}",
                self.vocab
            )));
        }
        if req.max_new_tokens == 0 {
            return Err(ServeError::Invalid(format!("request {id}: max_new_tokens must be >= 1")));
        }
        if self.max_new_cap > 0 && req.max_new_tokens > self.max_new_cap {
            return Err(ServeError::Invalid(format!(
                "request {id}: max_new_tokens {} exceeds the serving cap {}",
                req.max_new_tokens, self.max_new_cap
            )));
        }
        if let Err(e) = req.sampler.validate() {
            return Err(ServeError::Invalid(format!("request {id}: {e}")));
        }
        if let Some((n_pages, page_tokens, n_layers)) = self.kv_check {
            // At its last step the store holds prompt + max_new - 1 rows
            // per layer (the final sampled token is never appended); a
            // request whose worst case exceeds the whole pool could
            // never be scheduled and would park the queue forever.
            let rows = req.prompt.len() + req.max_new_tokens - 1;
            let worst = n_layers * rows.div_ceil(page_tokens);
            if worst > n_pages {
                return Err(ServeError::Invalid(format!(
                    "request {id}: worst-case KV demand of {worst} pages ({} prompt + {} new \
                     tokens, {page_tokens} tokens/page x {n_layers} layers) exceeds the \
                     {n_pages}-page pool",
                    req.prompt.len(),
                    req.max_new_tokens,
                )));
            }
        }
        self.stats.record(StatsEvent::Submitted);
        if let Err(e) = self.queue.admit(self.queue_depth) {
            self.stats.record(StatsEvent::Rejected);
            return Err(e);
        }
        self.stats.record(StatsEvent::Admitted);
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.queue.state.lock().unwrap_or_else(|e| e.into_inner());
            if st.closed {
                // Drop the state lock first: `unadmit` -> `release`
                // re-takes it to publish the wakeup.
                drop(st);
                self.queue.unadmit();
                self.stats.record(StatsEvent::Retracted);
                return Err(ServeError::ShuttingDown);
            }
            st.pending.push(PendingGen {
                id,
                prompt: req.prompt,
                max_new_tokens: req.max_new_tokens,
                eos: req.eos,
                sampler: req.sampler,
                reply: tx,
                enqueued: Instant::now(),
            });
        }
        self.queue.arrived.notify_one();
        Ok(GenTicket { id, rx, finished: false })
    }
}

/// A step batch mid-flight through the decode stage chain.
struct DecodeWork {
    x: Mat,
    spans: Vec<(usize, usize)>,
    prefill: Vec<bool>,
    states: Vec<GenState>,
    caches: Vec<KvStore>,
    stage_s: Vec<f64>,
    /// When the scheduler dispatched this step — step latency is the
    /// gap to the collector picking it up.
    dispatched: Instant,
    err: Option<String>,
}

/// What the collector thread tallies while the loop runs.
struct Tally {
    stage_stats: Vec<StageStats>,
    prefill_tokens: usize,
    decode_tokens: usize,
    generated_tokens: usize,
    n_steps: usize,
    n_completed: usize,
    n_abandoned: usize,
    n_failed: usize,
}

/// Wall-clock + token accounting for one decode-streaming run.
#[derive(Debug)]
pub struct DecodeReport {
    /// Per-decoder-layer busy time (prefill + decode rows combined).
    pub stage_stats: Vec<StageStats>,
    /// From loop start to full drain.
    pub total_seconds: f64,
    /// Prompt rows processed through the stages (prefill spans).
    pub prefill_tokens: usize,
    /// Decode-step rows processed (one per generated token after the
    /// first; the first comes out of the prefill pass).
    pub decode_tokens: usize,
    /// Tokens streamed to tickets.
    pub generated_tokens: usize,
    /// Step batches dispatched.
    pub n_steps: usize,
    /// Generations served to a terminal state other than expiry —
    /// admissions net of `n_timed_out`, so
    /// `n_requests == n_completed + n_abandoned + n_failed` and
    /// `n_requests + n_timed_out` equals successful submissions.
    pub n_requests: usize,
    /// Generations that ran to their stop condition (max-new-tokens or
    /// EOS).
    pub n_completed: usize,
    /// Generations cut short because their ticket was dropped (nobody
    /// left to stream to) — not completions, not failures.
    pub n_abandoned: usize,
    /// Generations whose batch failed mid-pipeline.
    pub n_failed: usize,
    /// Generations expired by `request_timeout`
    /// ([`ServeError::TimedOut`]) — before prefill or mid-generation,
    /// checked every time the request rejoins the step pool.
    pub n_timed_out: usize,
    /// Submissions refused at admission ([`ServeError::QueueFull`]).
    pub n_rejected: usize,
    /// Final aggregate from the serve-loop metrics plane: latency
    /// percentiles, KV high-water bytes, occupancy histogram.
    pub stats: StatsReport,
}

impl DecodeReport {
    /// End-to-end throughput over every processed row (prefill +
    /// decode).
    pub fn tokens_per_s(&self) -> f64 {
        let tokens = (self.prefill_tokens + self.decode_tokens) as f64;
        if self.total_seconds > 0.0 {
            tokens / self.total_seconds
        } else {
            0.0
        }
    }

    /// Generated-token throughput (the decode-side number users feel).
    pub fn generated_per_s(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.generated_tokens as f64 / self.total_seconds
        } else {
            0.0
        }
    }
}

impl Server {
    /// Run the KV-cached decode loop for the duration of `client_fn`.
    ///
    /// `engines` picks the execution mode exactly like
    /// [`Server::run_streaming`]: one backend runs every decoder layer
    /// on one execution thread, `>= n_stages` backends build the
    /// channel-connected per-layer chain.  `client_fn` receives a
    /// [`DecodeClient`] and may submit generations at any point; when it
    /// returns, admissions close and every in-flight generation drains
    /// to its stop condition before the loop returns its
    /// [`DecodeReport`].
    pub fn run_decode_streaming<R>(
        &self,
        engines: Vec<Box<dyn ExecBackend + Send>>,
        client_fn: impl FnOnce(DecodeClient<'_>) -> R,
    ) -> Result<(R, DecodeReport)> {
        let n_stages = self.model().n_stages();
        anyhow::ensure!(!engines.is_empty(), "decode streaming needs at least one backend");
        anyhow::ensure!(
            engines.len() == 1 || engines.len() >= n_stages,
            "decode streaming runs with 1 backend (all stages on one thread) or one per \
             stage: got {}, need 1 or >= {n_stages}",
            engines.len()
        );
        for engine in &engines {
            self.check_backend(engine.as_ref())?;
        }
        let model = self.model();
        let path = self.cfg().path;
        let linger = self.cfg().linger;
        let timeout = self.cfg().request_timeout;
        let queue_depth = self.cfg().queue_depth;
        let max_new_cap = self.cfg().max_new_tokens_cap;
        let batcher_cfg = self.cfg().batcher.clone();
        anyhow::ensure!(
            self.cfg().kv_pages == 0 || self.cfg().kv_page_tokens > 0,
            "kv_page_tokens must be >= 1 when kv_pages is set"
        );
        // Paged KV: one shared pool; stores grow only on the
        // full-decoder path (MLP-only has no attention state), so page
        // funding and prefix sharing apply there alone.
        let pool = (self.cfg().kv_pages > 0)
            .then(|| model.new_kv_pool(self.cfg().kv_pages, self.cfg().kv_page_tokens));
        let kv_funding = pool.is_some() && path == ServePath::FullDecoder;
        let kv_share_prefix = kv_funding && self.cfg().kv_share_prefix;
        let kv_check = if kv_funding {
            let p = pool.as_ref().expect("funding implies a pool");
            Some((p.n_pages(), p.page_tokens(), p.n_layers()))
        } else {
            None
        };
        let queue: SharedQueue<GenQueueState> = SharedQueue::new();
        let next_id = AtomicU64::new(0);
        // Metrics plane: recorders used by non-`move` closures must
        // outlive the scope, so they are declared here; stage threads
        // create their own and move them in.
        let stats_every = self.cfg().stats_every;
        let sink = self.cfg().stats_sink.clone().unwrap_or_default();
        let hub = StatsHub::new(DEFAULT_WINDOW);
        let submit_stats = hub.recorder();
        let sched_stats = hub.recorder();
        let coll_stats = hub.recorder();
        let sampler_stop = SamplerStop::new();
        let t0 = Instant::now();

        let (result, tally) = std::thread::scope(|scope| {
            // ---- stage chain: scheduler -> [stage threads] -> collector ----
            let (step_tx, mut prev_rx) = mpsc::channel::<DecodeWork>();
            if engines.len() == 1 {
                let mut engine = engines.into_iter().next().expect("len checked");
                let (tx, rx) = mpsc::channel::<DecodeWork>();
                let rx_in = std::mem::replace(&mut prev_rx, rx);
                let stage_rec = hub.recorder();
                scope.spawn(move || {
                    // One persistent arena for the whole loop: after the
                    // first few steps size the pools, steady-state stage
                    // work runs without touching the heap (the incoming
                    // `work.x` retires into the arena as each stage's
                    // output leaves it, so the pool stays balanced).
                    let mut arena = StepArena::new();
                    for mut work in rx_in {
                        for layer in 0..n_stages {
                            if work.err.is_some() {
                                break;
                            }
                            let s0 = Instant::now();
                            match model.stage_cached_scratch(
                                engine.as_mut(),
                                layer,
                                &work.x,
                                &work.spans,
                                &mut work.caches,
                                path,
                                &mut arena,
                            ) {
                                Ok(y) => {
                                    let s = s0.elapsed().as_secs_f64();
                                    arena.give(std::mem::replace(&mut work.x, y));
                                    work.stage_s.push(s);
                                    stage_rec.record(StatsEvent::StageBusy { seconds: s });
                                }
                                Err(e) => work.err = Some(format!("{e:#}")),
                            }
                        }
                        arena.step();
                        if tx.send(work).is_err() {
                            break;
                        }
                    }
                });
            } else {
                for (layer, mut engine) in engines.into_iter().take(n_stages).enumerate() {
                    let (tx, rx) = mpsc::channel::<DecodeWork>();
                    let rx_in = std::mem::replace(&mut prev_rx, rx);
                    let stage_rec = hub.recorder();
                    scope.spawn(move || {
                        // Per-stage-thread arena, same balance as the
                        // single-engine loop: incoming `work.x` retires
                        // in, the stage output leaves.
                        let mut arena = StepArena::new();
                        for mut work in rx_in {
                            if work.err.is_none() {
                                let s0 = Instant::now();
                                match model.stage_cached_scratch(
                                    engine.as_mut(),
                                    layer,
                                    &work.x,
                                    &work.spans,
                                    &mut work.caches,
                                    path,
                                    &mut arena,
                                ) {
                                    Ok(y) => {
                                        let s = s0.elapsed().as_secs_f64();
                                        arena.give(std::mem::replace(&mut work.x, y));
                                        work.stage_s.push(s);
                                        stage_rec.record(StatsEvent::StageBusy { seconds: s });
                                    }
                                    Err(e) => work.err = Some(format!("{e:#}")),
                                }
                            }
                            arena.step();
                            if tx.send(work).is_err() {
                                break;
                            }
                        }
                    });
                }
            }

            // ---- collector: next token per member, complete or rejoin ----
            let queue_ref = &queue;
            let coll_pool = pool.clone();
            let coll_share = kv_share_prefix;
            let collector = scope.spawn(move || {
                let done_rx = prev_rx;
                // Mirror the pool's counters into the stats plane after
                // every processed step, so periodic reports see live
                // free/shared-page gauges.
                let sync_pool_gauges = || {
                    if let Some(p) = &coll_pool {
                        coll_stats.set_kv_pool(
                            p.n_pages(),
                            p.free_pages(),
                            p.shared_pages(),
                            p.preemptions(),
                            p.cow_forks(),
                        );
                    }
                };
                let stage_stats: Vec<StageStats> = (0..n_stages)
                    .map(|layer| StageStats { layer, seconds: 0.0, tokens: 0 })
                    .collect();
                let mut tally = Tally {
                    stage_stats,
                    prefill_tokens: 0,
                    decode_tokens: 0,
                    generated_tokens: 0,
                    n_steps: 0,
                    n_completed: 0,
                    n_abandoned: 0,
                    n_failed: 0,
                };
                for work in done_rx {
                    let DecodeWork { x, spans, prefill, states, caches, stage_s, dispatched, err } =
                        work;
                    let done_at = Instant::now();
                    tally.n_steps += 1;
                    coll_stats.record(StatsEvent::StepDone {
                        seconds: done_at.duration_since(dispatched).as_secs_f64(),
                    });
                    let tokens = x.rows();
                    for (layer, s) in stage_s.iter().enumerate() {
                        tally.stage_stats[layer].seconds += s;
                        tally.stage_stats[layer].tokens += tokens;
                    }
                    if let Some(e) = err {
                        // Drop the stores first so any pooled pages are
                        // back on the free list before slots release.
                        drop(caches);
                        for state in states {
                            let _ = state.reply.send(Err(ServeError::Stage(e.clone())));
                            tally.n_failed += 1;
                            coll_stats.record(StatsEvent::RequestDone {
                                latency_s: done_at.duration_since(state.enqueued).as_secs_f64(),
                                outcome: ReqOutcome::Failed,
                            });
                            coll_stats.kv_free(state.kv_bytes);
                            queue_ref.release();
                        }
                        sync_pool_gauges();
                        continue;
                    }
                    let span_iter = spans.iter().zip(&prefill);
                    for ((&(lo, hi), &is_prefill), (mut state, mut cache)) in
                        span_iter.zip(states.into_iter().zip(caches))
                    {
                        if is_prefill {
                            tally.prefill_tokens += hi - lo;
                        } else {
                            tally.decode_tokens += hi - lo;
                        }
                        if let Some(paged) = cache.as_paged_mut() {
                            // Funding is sized exactly per step, so this
                            // is normally empty — defensive return of any
                            // unspent pages.
                            paged.release_reserve();
                            // First prefill done: publish the prompt's
                            // full pages so same-prefix requests admitted
                            // later share them copy-on-write.
                            if coll_share && is_prefill && state.n_generated == 0 {
                                let pt = paged.pool().page_tokens();
                                let pages = state.tokens.len() / pt;
                                if pages > 0 {
                                    let chains = paged.freeze_prefix(pages);
                                    paged
                                        .pool()
                                        .publish_prefix(&state.tokens[..pages * pt], &chains);
                                }
                            }
                        }
                        // Residency is a signed delta: paged stores can
                        // shrink when a frozen prefix moves into the
                        // pool's shared-page accounting.  The high-water
                        // mark stays monotone either way.
                        let cache_bytes = cache.bytes();
                        if cache_bytes >= state.kv_bytes {
                            coll_stats.kv_alloc(cache_bytes - state.kv_bytes);
                        } else {
                            coll_stats.kv_free(state.kv_bytes - cache_bytes);
                        }
                        state.kv_bytes = cache_bytes;
                        // The span's next token: the request's sampler
                        // over the LM head of its last hidden row.
                        let last = x.row_block(hi - 1, hi);
                        let tok =
                            state.sampler.sample(model.logits(&last).row(0), &mut state.rng);
                        state.n_generated += 1;
                        state.tokens.push(tok);
                        let ended = state.n_generated >= state.max_new_tokens
                            || state.eos == Some(tok);
                        // A dropped ticket ends its generation early —
                        // no point decoding for nobody.
                        let delivered = state.reply.send(Ok(GenEvent::Token(tok))).is_ok();
                        if delivered {
                            tally.generated_tokens += 1;
                            coll_stats.record(StatsEvent::TokenStreamed {
                                latency_s: done_at
                                    .duration_since(state.last_token_at)
                                    .as_secs_f64(),
                            });
                            state.last_token_at = done_at;
                        }
                        if ended || !delivered {
                            let _ = state.reply.send(Ok(GenEvent::Done));
                            if ended {
                                tally.n_completed += 1;
                            } else {
                                tally.n_abandoned += 1;
                            }
                            coll_stats.record(StatsEvent::RequestDone {
                                latency_s: done_at.duration_since(state.enqueued).as_secs_f64(),
                                outcome: if ended {
                                    ReqOutcome::Completed
                                } else {
                                    ReqOutcome::Abandoned
                                },
                            });
                            coll_stats.kv_free(state.kv_bytes);
                            // Return this generation's pages before the
                            // release wakeup, so a scheduler parked on
                            // page funding sees them free.
                            drop(cache);
                            queue_ref.release();
                        } else {
                            let mut st =
                                queue_ref.state.lock().unwrap_or_else(|e| e.into_inner());
                            st.rejoin.push(Rejoin { state, cache, token: tok });
                            drop(st);
                            queue_ref.arrived.notify_all();
                        }
                    }
                    sync_pool_gauges();
                }
                tally
            });

            // ---- scheduler: the continuous batcher over the step pool ----
            scope.spawn(|| {
                let tx = step_tx;
                let mut cb: ContinuousBatcher<(GenState, KvStore)> =
                    ContinuousBatcher::new(model.width(), batcher_cfg.clone());
                'outer: loop {
                    let parked = cb.pending() > 0;
                    let (news, rejoins): (Vec<PendingGen>, Vec<Rejoin>) = {
                        let mut st = queue.state.lock().unwrap_or_else(|e| e.into_inner());
                        loop {
                            if !st.pending.is_empty() || !st.rejoin.is_empty() {
                                break;
                            }
                            if parked {
                                // Steps parked on page funding are woken
                                // by *completions* freeing pages (release
                                // notifies `arrived`), but poll on the
                                // linger cadence too so a missed wakeup
                                // can't strand them.
                                let tick = if linger.is_zero() {
                                    Duration::from_millis(1)
                                } else {
                                    linger
                                };
                                let woken = queue.arrived.wait_timeout(st, tick);
                                let (guard, _) = woken.unwrap_or_else(|e| e.into_inner());
                                st = guard;
                                break;
                            }
                            // Exit only when nothing is pending, nothing
                            // can rejoin (no generation in flight), and
                            // admissions are closed.
                            if st.closed && queue.in_flight.load(Ordering::Acquire) == 0 {
                                break 'outer;
                            }
                            st = queue.arrived.wait(st).unwrap_or_else(|e| e.into_inner());
                        }
                        // Linger: let the step batch fill — cut short by
                        // the budgets or shutdown.
                        let deadline = Instant::now() + linger;
                        loop {
                            let tokens: usize = st.rejoin.len()
                                + st.pending.iter().map(|p| p.prompt.len()).sum::<usize>();
                            let members = st.pending.len() + st.rejoin.len();
                            if st.closed
                                || tokens >= batcher_cfg.max_tokens
                                || members >= batcher_cfg.max_requests
                            {
                                break;
                            }
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            let woken = queue.arrived.wait_timeout(st, deadline - now);
                            let (guard, _) = woken.unwrap_or_else(|e| e.into_inner());
                            st = guard;
                        }
                        sched_stats.set_queue_depth(st.pending.len() + st.rejoin.len());
                        (st.pending.drain(..).collect(), st.rejoin.drain(..).collect())
                    };
                    for p in news {
                        if let Some(e) = queue.stale(p.enqueued, timeout) {
                            sched_stats.record(StatsEvent::Expired);
                            let _ = p.reply.send(Err(e));
                            continue;
                        }
                        let mut store = match &pool {
                            Some(pool) => KvStore::paged(pool.new_cache()),
                            None => model.new_cache(),
                        };
                        // Prefix sharing: adopt the longest published
                        // full-page prefix of this prompt (capped one
                        // short of the whole prompt, so at least one
                        // suffix row still runs as prefill) and forward
                        // only the uncovered suffix.  Chunked and whole
                        // prefill agree bit-for-bit, so adoption cannot
                        // change the trajectory.
                        let mut covered = 0usize;
                        if kv_share_prefix {
                            if let Some(hit) = pool
                                .as_ref()
                                .expect("sharing implies a pool")
                                .lookup_prefix(&p.prompt, p.prompt.len() - 1)
                            {
                                covered = hit.tokens_covered;
                                store
                                    .as_paged_mut()
                                    .expect("pool stores are paged")
                                    .adopt_prefix(&hit);
                            }
                        }
                        let x = model
                            .embed(&p.prompt[covered..])
                            .expect("prompt validated at submit");
                        let state = GenState {
                            id: p.id,
                            reply: p.reply,
                            max_new_tokens: p.max_new_tokens,
                            eos: p.eos,
                            sampler: p.sampler,
                            rng: p.sampler.rng(),
                            n_generated: 0,
                            enqueued: p.enqueued,
                            last_token_at: p.enqueued,
                            kv_bytes: 0,
                            tokens: p.prompt,
                        };
                        cb.push(StepItem {
                            id: p.id,
                            x,
                            is_prefill: true,
                            payload: (state, store),
                        })
                        .expect("prefill step validated at submit");
                    }
                    for r in rejoins {
                        // `request_timeout` is a deadline on the whole
                        // generation, so it is re-checked at every
                        // rejoin, not just before prefill: the ticket
                        // observes the typed error, the in-flight slot
                        // frees, and dropping the rejoin drops its
                        // KvStore (returning any pooled pages).
                        if let Some(e) = queue.stale(r.state.enqueued, timeout) {
                            sched_stats.record(StatsEvent::Expired);
                            sched_stats.kv_free(r.state.kv_bytes);
                            let _ = r.state.reply.send(Err(e));
                            continue;
                        }
                        let x = model.embed(&[r.token]).expect("generated token is in-vocab");
                        cb.push(StepItem {
                            id: r.state.id,
                            x,
                            is_prefill: false,
                            payload: (r.state, r.cache),
                        })
                        .expect("decode step is one row");
                    }
                    // Dispatch: paged serving gates every batch member on
                    // page funding (all-or-nothing per step); an
                    // unfundable front parks the queue in FIFO order, and
                    // if a younger in-flight decode sits behind it, that
                    // generation is preempted — its pages return to the
                    // pool and it re-enters as a recompute prefill.
                    loop {
                        let mut gate = |item: &mut StepItem<(GenState, KvStore)>| {
                            if !kv_funding {
                                return true;
                            }
                            let pool = pool.as_ref().expect("funding implies a pool");
                            let rows = item.x.rows();
                            let paged = item
                                .payload
                                .1
                                .as_paged_mut()
                                .expect("pool stores are paged");
                            let need = paged.pages_for(rows);
                            if need == 0 {
                                return true;
                            }
                            match pool.reserve(need) {
                                Some(bufs) => {
                                    paged.fund(bufs);
                                    true
                                }
                                None => false,
                            }
                        };
                        while let Some(batch) = cb.next_batch_gated(&mut gate) {
                            sched_stats.record(StatsEvent::BatchDispatched {
                                requests: batch.n_requests(),
                                prefill_tokens: batch.prefill_tokens(),
                                decode_tokens: batch.decode_tokens(),
                            });
                            let spans = batch.spans().to_vec();
                            let (states, caches): (Vec<GenState>, Vec<KvStore>) =
                                batch.payloads.into_iter().unzip();
                            let work = DecodeWork {
                                x: batch.x,
                                spans,
                                prefill: batch.prefill,
                                states,
                                caches,
                                stage_s: Vec::with_capacity(n_stages),
                                dispatched: Instant::now(),
                                err: None,
                            };
                            if tx.send(work).is_err() {
                                return; // stage chain died; nothing to do
                            }
                        }
                        if cb.pending() == 0 || !kv_funding {
                            break;
                        }
                        // The front could not fund its step.  Preempt the
                        // youngest in-flight decode behind it (never the
                        // front itself: FIFO keeps the oldest request
                        // making progress); with no victim, the parked
                        // steps wait for completions to free pages.
                        let Some(victim) = cb.steal_newest_decode() else { break };
                        let StepItem { x: vx, payload: (mut vstate, vstore), .. } = victim;
                        // The victim's step rows are dead weight now —
                        // retire the storage into the batcher's assembly
                        // pool instead of freeing it.
                        cb.recycle(vx);
                        // Dropping the store returns every page it holds
                        // (block tables and any unspent reserve).
                        drop(vstore);
                        let p = pool.as_ref().expect("funding implies a pool");
                        p.note_preemption();
                        sched_stats.kv_free(vstate.kv_bytes);
                        vstate.kv_bytes = 0;
                        // Recompute: re-prefill the prompt plus every
                        // token sampled so far (its pending next-step
                        // input was never appended), which rebuilds the
                        // KV bit-for-bit — chunked and whole prefill
                        // agree and the request's RNG is untouched — then
                        // retry dispatch with the freed pages.
                        let x = model
                            .embed(&vstate.tokens)
                            .expect("tokens were validated at submit or sampled in-vocab");
                        let store = KvStore::paged(p.new_cache());
                        cb.push(StepItem {
                            id: vstate.id,
                            x,
                            is_prefill: true,
                            payload: (vstate, store),
                        })
                        .expect("recompute prefill has model width");
                    }
                    if let Some(p) = &pool {
                        sched_stats.set_kv_pool(
                            p.n_pages(),
                            p.free_pages(),
                            p.shared_pages(),
                            p.preemptions(),
                            p.cow_forks(),
                        );
                    }
                }
                // Dropping `tx` lets the stage chain and collector drain.
            });

            // ---- periodic stats sampler (only when enabled) ----
            if !stats_every.is_zero() {
                let scope_queue = &queue;
                let scope_hub = &hub;
                let scope_sink = &sink;
                let scope_stop = &sampler_stop;
                scope.spawn(move || {
                    while !scope_stop.wait_for(stats_every) {
                        let in_flight = scope_queue.in_flight.load(Ordering::Acquire);
                        scope_sink.emit(&scope_hub.sample(in_flight, false));
                    }
                });
            }

            // ---- client closure on the caller's thread ----
            let close = CloseGuard(&queue);
            let result = client_fn(DecodeClient {
                queue: &queue,
                next_id: &next_id,
                vocab: model.cfg().vocab,
                queue_depth,
                max_new_cap,
                kv_check,
                stats: &submit_stats,
            });
            drop(close);
            let tally = collector.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
            sampler_stop.stop();
            (result, tally)
        });

        if let Some(p) = &pool {
            // Drained: release the prefix registry so every page is back
            // on the free list, then publish the terminal pool gauges
            // (the shared-pages peak survives in the report).
            p.flush_shared();
            submit_stats.set_kv_pool(
                p.n_pages(),
                p.free_pages(),
                p.shared_pages(),
                p.preemptions(),
                p.cow_forks(),
            );
        }
        let stats = hub.sample(queue.in_flight.load(Ordering::Acquire), true);
        if !stats_every.is_zero() {
            sink.emit(&stats);
        }
        let admitted = queue.admitted.load(Ordering::Relaxed);
        let timed_out = queue.timed_out.load(Ordering::Relaxed);
        Ok((
            result,
            DecodeReport {
                stage_stats: tally.stage_stats,
                total_seconds: t0.elapsed().as_secs_f64(),
                prefill_tokens: tally.prefill_tokens,
                decode_tokens: tally.decode_tokens,
                generated_tokens: tally.generated_tokens,
                n_steps: tally.n_steps,
                n_requests: admitted.saturating_sub(timed_out),
                n_completed: tally.n_completed,
                n_abandoned: tally.n_abandoned,
                n_failed: tally.n_failed,
                n_timed_out: timed_out,
                n_rejected: queue.rejected.load(Ordering::Relaxed),
                stats,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::model::KvCache;
    use crate::runtime::{NativeCfg, NativeEngine};
    use crate::serve::batcher::BatcherCfg;
    use crate::serve::model::tests::tiny_sparse_model;
    use crate::serve::{ServeCfg, ServePath};

    fn engines(n: usize, threads: usize) -> Vec<Box<dyn ExecBackend + Send>> {
        (0..n)
            .map(|_| {
                Box::new(NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() }))
                    as Box<dyn ExecBackend + Send>
            })
            .collect()
    }

    fn decode_server(path: ServePath) -> Server {
        Server::new(
            tiny_sparse_model(),
            ServeCfg {
                batcher: BatcherCfg { max_tokens: 12, max_requests: 4 },
                path,
                linger: Duration::from_millis(1),
                ..ServeCfg::default()
            },
        )
    }

    fn gen_req(prompt: Vec<u32>, max_new: usize) -> GenRequest {
        GenRequest::greedy(prompt, max_new)
    }

    #[test]
    fn concurrent_clients_with_staggered_max_new_tokens_match_reference() {
        // Satellite acceptance: several client threads stream generations
        // with different lengths concurrently; every ticket's tokens must
        // equal the single-request KV-cached reference (`SparseModel::
        // generate` — same kernels, so batching and interleaving must not
        // change a single token).
        let server = decode_server(ServePath::FullDecoder);
        let n_stages = server.model().n_stages();
        let (outputs, report) = server
            .run_decode_streaming(engines(n_stages, 1), |client| {
                std::thread::scope(|s| {
                    let mut handles = Vec::new();
                    for t in 0..3u64 {
                        handles.push(s.spawn(move || {
                            let mut done = Vec::new();
                            for i in 0..3usize {
                                let prompt: Vec<u32> =
                                    (0..2 + (t as usize + i) % 3)
                                        .map(|j| ((t as usize * 41 + i * 17 + j * 7) % 256) as u32)
                                        .collect();
                                let max_new = 1 + (t as usize + i) % 4; // staggered
                                let ticket =
                                    client.submit(gen_req(prompt.clone(), max_new)).unwrap();
                                let toks = ticket.wait().unwrap();
                                assert_eq!(toks.len(), max_new, "no EOS set => full length");
                                done.push((prompt, max_new, toks));
                            }
                            done
                        }));
                    }
                    handles.into_iter().flat_map(|h| h.join().unwrap()).collect::<Vec<_>>()
                })
            })
            .unwrap();
        assert_eq!(outputs.len(), 9);
        assert_eq!(report.n_requests, 9);
        assert_eq!(report.n_completed, 9);
        assert_eq!(report.n_failed, 0);
        let total_prompt: usize = outputs.iter().map(|(p, _, _)| p.len()).sum();
        let total_new: usize = outputs.iter().map(|(_, _, t)| t.len()).sum();
        assert_eq!(report.prefill_tokens, total_prompt);
        assert_eq!(report.generated_tokens, total_new);
        // Each generated token after a request's first came from one
        // 1-row decode step.
        assert_eq!(report.decode_tokens, total_new - outputs.len());
        // Reference: the sequential KV-cached generator on a fresh
        // backend — bit-identical kernels => identical tokens.
        let mut engine = NativeEngine::default();
        for (prompt, max_new, toks) in &outputs {
            let want = server
                .model()
                .generate(
                    &mut engine,
                    prompt,
                    *max_new,
                    None,
                    ServePath::FullDecoder,
                    Sampler::Greedy,
                )
                .unwrap();
            assert_eq!(toks, &want, "prompt {prompt:?} diverged from the reference");
        }
    }

    #[test]
    fn tokens_stream_incrementally_and_eos_stops() {
        let server = decode_server(ServePath::FullDecoder);
        // Find the reference continuation first, then use its second
        // token as EOS: the stream must end right after producing it.
        let prompt: Vec<u32> = vec![9, 81, 3];
        let mut engine = NativeEngine::default();
        let want = server
            .model()
            .generate(&mut engine, &prompt, 5, None, ServePath::FullDecoder, Sampler::Greedy)
            .unwrap();
        let eos = want[1];
        let cut = want.iter().position(|&t| t == eos).unwrap();
        let ((), report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                let mut ticket = client
                    .submit(GenRequest {
                        prompt: prompt.clone(),
                        max_new_tokens: 5,
                        eos: Some(eos),
                        sampler: Sampler::Greedy,
                    })
                    .unwrap();
                let mut got = Vec::new();
                while let Some(tok) = ticket.next_token() {
                    got.push(tok.unwrap());
                }
                assert_eq!(got, want[..=cut].to_vec());
                // The stream stays ended.
                assert!(ticket.next_token().is_none());
            })
            .unwrap();
        assert_eq!(report.n_completed, 1);
        assert_eq!(report.generated_tokens, cut + 1);
    }

    #[test]
    fn decode_works_on_the_mlp_only_path_too() {
        let server = decode_server(ServePath::MlpOnly);
        let (toks, report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                client.submit(gen_req(vec![1, 2, 3, 4], 3)).unwrap().wait().unwrap()
            })
            .unwrap();
        assert_eq!(toks.len(), 3);
        assert_eq!(report.n_completed, 1);
        let mut engine = NativeEngine::default();
        let want = server
            .model()
            .generate(&mut engine, &[1, 2, 3, 4], 3, None, ServePath::MlpOnly, Sampler::Greedy)
            .unwrap();
        assert_eq!(toks, want);
    }

    #[test]
    fn topk_decode_matches_the_sequential_sampled_reference() {
        // Satellite acceptance: the sampler rides through the
        // continuous-batching loop — each request owns its seeded RNG,
        // so batched stochastic decoding is bit-identical to the
        // sequential `SparseModel::generate` with the same sampler.
        let server = decode_server(ServePath::FullDecoder);
        let sampler = Sampler::TopK { k: 3, temperature: 0.7, seed: 2024 };
        let (outputs, report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                std::thread::scope(|s| {
                    let mut handles = Vec::new();
                    for t in 0..2u64 {
                        handles.push(s.spawn(move || {
                            let prompt: Vec<u32> =
                                (0..3).map(|j| ((t * 31 + j * 7) % 256) as u32).collect();
                            let req = GenRequest {
                                prompt: prompt.clone(),
                                max_new_tokens: 4,
                                eos: None,
                                sampler,
                            };
                            let toks = client.submit(req).unwrap().wait().unwrap();
                            (prompt, toks)
                        }));
                    }
                    handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
                })
            })
            .unwrap();
        assert_eq!(report.n_completed, 2);
        let mut engine = NativeEngine::default();
        for (prompt, toks) in &outputs {
            let want = server
                .model()
                .generate(&mut engine, prompt, 4, None, ServePath::FullDecoder, sampler)
                .unwrap();
            assert_eq!(toks, &want, "prompt {prompt:?} diverged under top-k sampling");
        }
    }

    #[test]
    fn shutdown_drains_in_flight_generations() {
        // The client closure returns immediately after submitting; every
        // generation still runs to its stop condition.
        let server = decode_server(ServePath::FullDecoder);
        let n_stages = server.model().n_stages();
        let (tickets, report) = server
            .run_decode_streaming(engines(n_stages, 1), |client| {
                (0..5u32)
                    .map(|i| client.submit(gen_req(vec![i, i + 40, i + 90], 4)).unwrap())
                    .collect::<Vec<_>>()
            })
            .unwrap();
        assert_eq!(report.n_completed, 5);
        for ticket in tickets {
            assert_eq!(ticket.wait().unwrap().len(), 4);
        }
    }

    #[test]
    fn invalid_generations_are_rejected_typed() {
        let mut server = decode_server(ServePath::MlpOnly);
        server.cfg_mut().max_new_tokens_cap = 8;
        let ((), report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                assert!(matches!(
                    client.submit(gen_req(vec![], 3)),
                    Err(ServeError::Invalid(_))
                ));
                assert!(matches!(
                    client.submit(gen_req(vec![1, 999], 3)),
                    Err(ServeError::Invalid(_))
                ));
                assert!(matches!(
                    client.submit(gen_req(vec![1], 0)),
                    Err(ServeError::Invalid(_))
                ));
                assert!(matches!(
                    client.submit(gen_req(vec![1], 9)),
                    Err(ServeError::Invalid(_))
                ));
                // Malformed samplers are rejected with the typed reason.
                assert!(matches!(
                    client.submit(GenRequest {
                        prompt: vec![1],
                        max_new_tokens: 2,
                        eos: None,
                        sampler: Sampler::TopK { k: 0, temperature: 1.0, seed: 0 },
                    }),
                    Err(ServeError::Invalid(_))
                ));
                assert!(matches!(
                    client.submit(GenRequest {
                        prompt: vec![1],
                        max_new_tokens: 2,
                        eos: None,
                        sampler: Sampler::TopK { k: 2, temperature: 0.0, seed: 0 },
                    }),
                    Err(ServeError::Invalid(_))
                ));
                // A valid one still flows.
                assert_eq!(client.submit(gen_req(vec![1], 2)).unwrap().wait().unwrap().len(), 2);
            })
            .unwrap();
        assert_eq!(report.n_completed, 1);
        assert_eq!(report.n_failed, 0);
    }

    #[test]
    fn mid_generation_timeout_expires_slot_and_kv() {
        // `request_timeout` is a whole-generation deadline: a generation
        // that keeps rejoining past it must expire through its ticket
        // with the typed error, free its in-flight slot (the follow-up
        // submit succeeds), and release its KV cache (final resident
        // bytes are zero).  Pre-fix, the deadline was only checked
        // before prefill and this request ran all the way to
        // `max_new_tokens`.
        let mut server = decode_server(ServePath::FullDecoder);
        server.cfg_mut().queue_depth = 1;
        server.cfg_mut().request_timeout = Duration::from_millis(40);
        let ((n_tokens, timed_out), report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                let mut ticket = client.submit(gen_req(vec![1, 2, 3], 5000)).unwrap();
                let mut n_tokens = 0usize;
                let timed_out = loop {
                    match ticket.next_token() {
                        Some(Ok(_)) => n_tokens += 1,
                        Some(Err(ServeError::TimedOut { .. })) => break true,
                        Some(Err(e)) => panic!("unexpected stream error: {e:?}"),
                        None => break false,
                    }
                };
                // The slot freed: a fresh generation is admitted and
                // completes.  The expiry is published to the ticket just
                // before the slot releases, so retry the race away.
                let follow = loop {
                    match client.submit(gen_req(vec![4, 5], 2)) {
                        Ok(t) => break t,
                        Err(ServeError::QueueFull { .. }) => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => panic!("unexpected submit error: {e:?}"),
                    }
                };
                assert_eq!(follow.wait().unwrap().len(), 2);
                (n_tokens, timed_out)
            })
            .unwrap();
        assert!(timed_out, "generation must expire mid-flight, not run to max_new_tokens");
        assert!(n_tokens >= 1, "prefill beat the deadline, some tokens streamed");
        assert!(n_tokens < 5000, "expired long before the cap");
        assert_eq!(report.n_timed_out, 1);
        assert_eq!(report.n_requests, 1, "only the follow-up reached a served terminal state");
        assert_eq!(report.n_completed, 1);
        assert_eq!(report.stats.n_expired, 1);
        assert_eq!(report.stats.kv_bytes, 0, "the expired generation's cache was released");
        assert!(report.stats.kv_high_water_bytes > 0);
    }

    #[test]
    fn decode_counters_add_up_under_concurrent_stress() {
        // Accounting invariant: every submission lands in exactly one
        // bucket, so `n_requests + n_timed_out + n_rejected` equals
        // submissions — including generations expired *after* admission
        // — under concurrent clients racing a tight deadline and a
        // shallow queue.
        let mut server = decode_server(ServePath::MlpOnly);
        server.cfg_mut().queue_depth = 2;
        server.cfg_mut().request_timeout = Duration::from_millis(25);
        let ((ok, rejected, timed_out, completed), report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                std::thread::scope(|s| {
                    let mut handles = Vec::new();
                    for t in 0..4u32 {
                        handles.push(s.spawn(move || {
                            let (mut ok, mut rejected, mut timed_out, mut completed) =
                                (0usize, 0usize, 0usize, 0usize);
                            for i in 0..6u32 {
                                let prompt: Vec<u32> = (0..1 + (t + i) % 3)
                                    .map(|j| (t * 37 + i * 11 + j) % 256)
                                    .collect();
                                let max_new = 1 + ((t + i) % 4) as usize * 40;
                                match client.submit(gen_req(prompt, max_new)) {
                                    Ok(ticket) => {
                                        ok += 1;
                                        match ticket.wait() {
                                            Ok(_) => completed += 1,
                                            Err(ServeError::TimedOut { .. }) => timed_out += 1,
                                            Err(e) => panic!("unexpected outcome: {e:?}"),
                                        }
                                    }
                                    Err(ServeError::QueueFull { .. }) => rejected += 1,
                                    Err(e) => panic!("unexpected submit error: {e:?}"),
                                }
                            }
                            (ok, rejected, timed_out, completed)
                        }));
                    }
                    handles.into_iter().map(|h| h.join().unwrap()).fold(
                        (0, 0, 0, 0),
                        |acc, c| (acc.0 + c.0, acc.1 + c.1, acc.2 + c.2, acc.3 + c.3),
                    )
                })
            })
            .unwrap();
        assert_eq!(ok + rejected, 24, "every submission got a typed outcome");
        assert_eq!(ok, completed + timed_out);
        assert_eq!(report.n_rejected, rejected);
        assert_eq!(report.n_timed_out, timed_out);
        assert_eq!(report.n_requests + report.n_timed_out, ok, "admissions all accounted for");
        assert_eq!(report.n_requests, report.n_completed + report.n_abandoned + report.n_failed);
        assert_eq!(report.n_completed, completed);
        assert_eq!(report.n_abandoned, 0, "every ticket was awaited");
        assert_eq!(report.n_failed, 0);
        assert_eq!(report.stats.n_admitted, ok);
        assert_eq!(report.stats.n_rejected, rejected);
        assert_eq!(report.stats.n_expired, timed_out);
        assert_eq!(report.stats.n_completed, completed);
        assert_eq!(report.stats.in_flight, 0);
        assert_eq!(report.stats.kv_bytes, 0, "every terminal path released its cache");
    }

    #[test]
    fn kv_lifecycle_releases_caches_and_tracks_high_water() {
        // Completed, EOS-stopped, and shutdown-drained generations all
        // release their caches: final resident KV is zero and the
        // high-water mark equals the closed-form hand computation over a
        // staggered sequential scenario.  A generation with `pl` prompt
        // tokens that streams `g` tokens peaks at `pl + g - 1` cached
        // positions (the step producing token `k` runs with
        // `pl + k - 1` positions resident).
        let mut server = decode_server(ServePath::FullDecoder);
        server.cfg_mut().queue_depth = 1;
        let n_layers = server.model().cfg().n_layers;
        let dim = server.model().width();
        let cases: [(usize, usize); 3] = [(3, 4), (5, 1), (2, 6)];
        // EOS reference: generation stops right after producing
        // `want[1]` the first time it appears.
        let eos_prompt: Vec<u32> = vec![7, 3, 11];
        let mut engine = NativeEngine::default();
        let want = server
            .model()
            .generate(&mut engine, &eos_prompt, 5, None, ServePath::FullDecoder, Sampler::Greedy)
            .unwrap();
        let eos = want[1];
        let eos_len = want.iter().position(|&t| t == eos).unwrap() + 1;
        let (drain_ticket, report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                // queue_depth = 1 serializes the cases; the previous
                // slot releases just after its Done arrives, so retry
                // the submit race away.
                let submit_retry = |req: GenRequest| loop {
                    match client.submit(req.clone()) {
                        Ok(t) => break t,
                        Err(ServeError::QueueFull { .. }) => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(e) => panic!("unexpected submit error: {e:?}"),
                    }
                };
                for &(pl, g) in &cases {
                    let prompt: Vec<u32> =
                        (0..pl as u32).map(|j| (j * 13 + 5) % 256).collect();
                    assert_eq!(submit_retry(gen_req(prompt, g)).wait().unwrap().len(), g);
                }
                let toks = submit_retry(GenRequest {
                    prompt: eos_prompt.clone(),
                    max_new_tokens: 5,
                    eos: Some(eos),
                    sampler: Sampler::Greedy,
                })
                .wait()
                .unwrap();
                assert_eq!(toks.len(), eos_len);
                // Shutdown-drained: return the ticket (keep it alive) so
                // the drain completes the generation instead of
                // abandoning it at the first undeliverable token.
                submit_retry(gen_req(vec![9, 10], 3))
            })
            .unwrap();
        assert_eq!(drain_ticket.wait().unwrap().len(), 3);
        assert_eq!(report.n_completed, 5);
        assert_eq!(report.n_abandoned, 0);
        let peak_positions = cases
            .iter()
            .map(|&(pl, g)| pl + g - 1)
            .chain([eos_prompt.len() + eos_len - 1, 2 + 3 - 1])
            .max()
            .unwrap();
        assert_eq!(
            report.stats.kv_high_water_bytes,
            KvCache::bytes_for(n_layers, dim, peak_positions),
            "high-water KV must match the closed form"
        );
        assert_eq!(report.stats.kv_bytes, 0, "every generation released its cache");
    }

    #[test]
    fn stats_sampler_emits_periodic_monotone_reports() {
        use std::sync::{Arc, Mutex};
        let mut server = decode_server(ServePath::FullDecoder);
        server.cfg_mut().stats_every = Duration::from_millis(10);
        let collected: Arc<Mutex<Vec<StatsReport>>> = Arc::new(Mutex::new(Vec::new()));
        let sink_reports = Arc::clone(&collected);
        server.cfg_mut().stats_sink = Some(StatsSink::new(move |r: &StatsReport| {
            sink_reports.lock().unwrap().push(r.clone());
        }));
        let (tickets, report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                let tickets = (0..2u32)
                    .map(|i| client.submit(gen_req(vec![i + 1, i + 5, i + 9], 60)).unwrap())
                    .collect::<Vec<_>>();
                // Keep the loop alive across a few sampling periods.
                std::thread::sleep(Duration::from_millis(35));
                tickets
            })
            .unwrap();
        for t in tickets {
            assert_eq!(t.wait().unwrap().len(), 60);
        }
        let reports = collected.lock().unwrap();
        assert!(!reports.is_empty(), "at least the final report reaches the sink");
        for r in reports.iter() {
            for p in [&r.request_latency_ms, &r.token_latency_ms, &r.step_latency_ms] {
                assert!(
                    p.p50 <= p.p90 && p.p90 <= p.p99,
                    "percentiles must be monotone: {p:?}"
                );
            }
        }
        let last = reports.last().unwrap();
        assert!(last.is_final, "the final aggregate is emitted last");
        assert_eq!(last.generated_tokens, report.generated_tokens);
        assert_eq!(last.n_completed, 2);
        assert_eq!(last.kv_bytes, 0);
        assert!(last.kv_high_water_bytes > 0);
        assert!(report.stats.is_final);
        assert_eq!(report.stats.generated_tokens, 120);
    }

    #[test]
    fn paged_decode_is_bit_identical_including_forced_preemption() {
        // Tentpole acceptance: a pool that cannot hold two full
        // generations at their peak (each needs 10 of 12 pages) forces
        // at least one preemption/recompute cycle, yet every streamed
        // token must equal the sequential contiguous-cache reference,
        // and every page must be back on the free list at drain.
        let mut server = decode_server(ServePath::FullDecoder);
        server.cfg_mut().kv_pages = 12;
        server.cfg_mut().kv_page_tokens = 2;
        let prompts: [Vec<u32>; 2] = [vec![5, 9, 13, 17], vec![21, 25, 29, 33]];
        let (outputs, report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                let tickets: Vec<GenTicket> = prompts
                    .iter()
                    .map(|p| client.submit(gen_req(p.clone(), 6)).unwrap())
                    .collect();
                tickets.into_iter().map(|t| t.wait().unwrap()).collect::<Vec<_>>()
            })
            .unwrap();
        assert_eq!(report.n_completed, 2);
        let mut engine = NativeEngine::default();
        for (prompt, toks) in prompts.iter().zip(&outputs) {
            let want = server
                .model()
                .generate(&mut engine, prompt, 6, None, ServePath::FullDecoder, Sampler::Greedy)
                .unwrap();
            assert_eq!(toks, &want, "prompt {prompt:?} diverged under paged serving");
        }
        assert!(
            report.stats.kv_preemptions >= 1,
            "the 12-page pool cannot hold both peaks; a preemption must fire"
        );
        assert_eq!(report.stats.kv_pool_pages, 12);
        assert_eq!(report.stats.kv_free_pages, 12, "every page returned at drain");
        assert_eq!(report.stats.kv_used_pages(), 0);
        assert_eq!(report.stats.kv_bytes, 0, "preempted + completed KV fully released");
    }

    #[test]
    fn shared_prompt_prefixes_are_adopted_copy_on_write() {
        // Two requests with the same prompt: the first prefills and
        // publishes its full prompt pages; waiting for its first token
        // guarantees the publish happened before the second submit, so
        // the second adopts the shared pages and must still stream the
        // exact reference tokens (diverging copy-on-write afterwards).
        let mut server = decode_server(ServePath::FullDecoder);
        server.cfg_mut().kv_pages = 64;
        server.cfg_mut().kv_page_tokens = 2;
        server.cfg_mut().kv_share_prefix = true;
        let prompt: Vec<u32> = vec![3, 14, 15, 92, 65];
        let ((first_toks, second_toks), report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                let mut first = client.submit(gen_req(prompt.clone(), 4)).unwrap();
                let t0 = first.next_token().unwrap().unwrap();
                let second = client.submit(gen_req(prompt.clone(), 4)).unwrap();
                let mut a = vec![t0];
                while let Some(t) = first.next_token() {
                    a.push(t.unwrap());
                }
                (a, second.wait().unwrap())
            })
            .unwrap();
        let mut engine = NativeEngine::default();
        let want = server
            .model()
            .generate(&mut engine, &prompt, 4, None, ServePath::FullDecoder, Sampler::Greedy)
            .unwrap();
        assert_eq!(first_toks, want, "publisher diverged from the reference");
        assert_eq!(second_toks, want, "adopter must read shared pages bit-identically");
        assert!(report.stats.kv_shared_pages_peak > 0, "prefix pages were shared");
        assert!(report.stats.kv_cow_forks >= 1, "the adopter diverged into its own pages");
        assert_eq!(report.stats.kv_shared_pages, 0, "registry flushed at drain");
        assert_eq!(report.stats.kv_free_pages, 64);
        assert_eq!(report.stats.kv_bytes, 0);
    }

    #[test]
    fn topp_decode_matches_the_sequential_sampled_reference() {
        let server = decode_server(ServePath::FullDecoder);
        let sampler = Sampler::TopP { p: 0.85, temperature: 0.9, seed: 4242 };
        let prompt: Vec<u32> = vec![8, 21, 34];
        let (toks, report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                client
                    .submit(GenRequest {
                        prompt: prompt.clone(),
                        max_new_tokens: 5,
                        eos: None,
                        sampler,
                    })
                    .unwrap()
                    .wait()
                    .unwrap()
            })
            .unwrap();
        assert_eq!(report.n_completed, 1);
        let mut engine = NativeEngine::default();
        let want = server
            .model()
            .generate(&mut engine, &prompt, 5, None, ServePath::FullDecoder, sampler)
            .unwrap();
        assert_eq!(toks, want, "batched top-p must match the sequential draw-for-draw");
        // Malformed nucleus mass is rejected at submit with the typed
        // reason, before admission.
        let ((), _report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                assert!(matches!(
                    client.submit(GenRequest {
                        prompt: vec![1],
                        max_new_tokens: 2,
                        eos: None,
                        sampler: Sampler::TopP { p: 1.5, temperature: 1.0, seed: 0 },
                    }),
                    Err(ServeError::Invalid(_))
                ));
            })
            .unwrap();
    }

    #[test]
    fn oversized_generations_are_rejected_before_admission_when_paged() {
        let mut server = decode_server(ServePath::FullDecoder);
        server.cfg_mut().kv_pages = 4;
        server.cfg_mut().kv_page_tokens = 2;
        let ((), report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                // Worst case: 2 layers x ceil((3 + 8 - 1) / 2) = 10 pages
                // against a 4-page pool — could never be scheduled, so it
                // must fail fast and typed instead of parking forever.
                assert!(matches!(
                    client.submit(gen_req(vec![1, 2, 3], 8)),
                    Err(ServeError::Invalid(_))
                ));
                // A generation that fits (2 x ceil(3/2) = 4 pages) flows.
                let toks = client.submit(gen_req(vec![1, 2], 2)).unwrap().wait().unwrap();
                assert_eq!(toks.len(), 2);
            })
            .unwrap();
        assert_eq!(report.n_completed, 1);
        assert_eq!(report.stats.kv_free_pages, 4);
    }

    #[test]
    fn mlp_only_decode_ignores_the_pool_but_still_matches() {
        // The MLP-only path has no attention state: paged serving must
        // neither fund pages for it nor cap its admissions, and tokens
        // still match the sequential reference.
        let mut server = decode_server(ServePath::MlpOnly);
        server.cfg_mut().kv_pages = 2;
        server.cfg_mut().kv_page_tokens = 2;
        let (toks, report) = server
            .run_decode_streaming(engines(1, 1), |client| {
                client.submit(gen_req(vec![1, 2, 3, 4], 3)).unwrap().wait().unwrap()
            })
            .unwrap();
        let mut engine = NativeEngine::default();
        let want = server
            .model()
            .generate(&mut engine, &[1, 2, 3, 4], 3, None, ServePath::MlpOnly, Sampler::Greedy)
            .unwrap();
        assert_eq!(toks, want);
        assert_eq!(report.stats.kv_pool_pages, 2);
        assert_eq!(report.stats.kv_free_pages, 2, "no page was ever taken");
    }
}
