//! Batched multi-layer sparse serving — the paper's Table 3 hot path
//! turned into a subsystem.
//!
//! The pruning pipeline produces per-linear N:M weights; this module
//! serves them:
//!
//! * [`SparseModel`] compresses **every** prunable linear of a
//!   [`crate::coordinator::PrunedModel`] to the Sparse-Tensor-Core layout
//!   exactly once (values + u8 group metadata + permutation, converted to
//!   artifact tensors at build time) and runs decoder-layer stages
//!   end-to-end on the sparse path.  The [`ServePath`] picks the stage
//!   shape: the full decoder layer (attention q/k/v/o through
//!   `sparse_fwd_{c_out}x{c_in}` with RoPE + causal-softmax host glue
//!   shared with the reference forward, then the SwiGLU MLP) or the MLP
//!   sublayer alone (the original mode, kept as the comparison point).
//!   Every `sparse_fwd` execution routes through the
//!   [`crate::runtime::ExecBackend`] trait; on backends with
//!   resident-weight support ([`crate::runtime::ExecBackend::bind`]) the
//!   static weight tensors are bound once per backend and only
//!   activations cross the per-request call boundary.
//! * [`MicroBatcher`] coalesces the FIFO request queue into
//!   token-budgeted micro-batches; [`ReorderBuffer`] keeps completions in
//!   submission order.  Attention is *span-local*: each coalesced
//!   request keeps its own RoPE positions and causal mask, so outputs
//!   are identical whether a request is served alone or batched.
//! * [`Server`] drives batch runs either sequentially
//!   ([`Server::run_sequential`], any backend) or with **cross-layer
//!   pipelining** ([`Server::run_pipelined`]): one backend per decoder
//!   layer connected by channels ([`crate::util::pool::pipeline_map`]),
//!   so layer `L` of batch `i` overlaps layer `L+1` of batch `i-1` while
//!   `Compressed::matmul_xt_threads` tiles each individual matmul across
//!   worker threads.
//! * [`Server::run_streaming`] keeps the loop *alive*: clients enqueue
//!   requests ([`StreamClient::submit`] -> [`Ticket`]) while batches are
//!   in flight, the micro-batcher thread wakes on arrival or after a
//!   linger timeout, and shutdown drains every enqueued request through
//!   the pipeline stages before returning a [`StreamReport`].
//! * [`DenseModel`] materializes the dense-masked weights once — the
//!   benchmark baseline the CI bench gate compares sparse serving
//!   against, never part of the serving path itself.
//!
//! Numerics: the sparse path matches the host dense-masked reference
//! ([`SparseModel::dense_forward`]) within 1e-3 at 2:4 and 4:8, and the
//! pipelined, sequential, and streaming modes are bit-identical (same
//! kernels, same tiling).
//!
//! Entry points: the `permllm serve` CLI subcommand (`--sparse-attn`,
//! `--stream`) and the `sparse_inference` example (per-layer + end-to-end
//! tokens/s, `--json` for the machine-readable bench summary).

mod batcher;
mod model;
mod server;
mod stream;

pub use batcher::{BatcherCfg, MicroBatch, MicroBatcher, ReorderBuffer, Request};
pub use model::{DenseModel, ServePath, SparseLayer, SparseModel};
pub use server::{ServeCfg, ServeReport, Server, StageStats};
pub use stream::{StreamClient, StreamReport, Ticket};
