//! Batched multi-layer sparse serving — the paper's Table 3 hot path
//! turned into a subsystem.
//!
//! The pruning pipeline produces per-linear N:M weights; this module
//! serves them:
//!
//! * [`SparseModel`] compresses **every** prunable linear of a
//!   [`crate::coordinator::PrunedModel`] to the Sparse-Tensor-Core layout
//!   exactly once (values + u8 group metadata + permutation, converted to
//!   artifact tensors at build time) and runs decoder-layer stages
//!   end-to-end on the sparse path.  The [`ServePath`] picks the stage
//!   shape: the full decoder layer (attention q/k/v/o through
//!   `sparse_fwd_{c_out}x{c_in}` with RoPE + causal-softmax host glue
//!   shared with the reference forward, then the SwiGLU MLP) or the MLP
//!   sublayer alone (the original mode, kept as the comparison point).
//!   Every `sparse_fwd` execution routes through the
//!   [`crate::runtime::ExecBackend`] trait; on backends with
//!   resident-weight support ([`crate::runtime::ExecBackend::bind`]) the
//!   static weight tensors are bound once per backend and only
//!   activations cross the per-request call boundary.
//! * [`MicroBatcher`] coalesces the FIFO request queue into
//!   token-budgeted micro-batches; [`ReorderBuffer`] keeps completions in
//!   submission order.  Attention is *span-local*: each coalesced
//!   request keeps its own RoPE positions and causal mask, so outputs
//!   are identical whether a request is served alone or batched.
//! * [`Server`] drives batch runs either sequentially
//!   ([`Server::run_sequential`], any backend) or with **cross-layer
//!   pipelining** ([`Server::run_pipelined`]): one backend per decoder
//!   layer connected by channels ([`crate::util::pool::pipeline_map`]),
//!   so layer `L` of batch `i` overlaps layer `L+1` of batch `i-1` while
//!   `Compressed::matmul_xt_threads` tiles each individual matmul across
//!   worker threads.
//! * [`Server::run_streaming`] keeps the loop *alive*: clients enqueue
//!   requests ([`StreamClient::submit`] -> [`Ticket`]) while batches are
//!   in flight, the micro-batcher thread wakes on arrival or after a
//!   linger timeout, and shutdown drains every enqueued request through
//!   the pipeline stages before returning a [`StreamReport`].
//!   Backpressure is built in: [`ServeCfg::queue_depth`] caps the
//!   in-flight count (submit fails fast with [`ServeError::QueueFull`])
//!   and [`ServeCfg::request_timeout`] expires stale queue entries with
//!   [`ServeError::TimedOut`] through the ticket.
//! * [`Server::run_decode_streaming`] is the *generation* loop: clients
//!   submit prompts ([`DecodeClient::submit`] with a [`GenRequest`]) and
//!   their [`GenTicket`]s stream tokens as they are produced, selected
//!   per request by a [`Sampler`] (greedy argmax, or seeded top-k /
//!   top-p with a per-request RNG so sampling is batching-independent).
//!   Each request carries a [`KvStore`]; prefill writes K/V into it and
//!   every subsequent step runs one token of incremental attention at
//!   the right RoPE offsets ([`SparseModel::stage_cached`]).  The
//!   [`ContinuousBatcher`] coalesces mixed prefill + decode steps under
//!   the same token/request budgets, and in-flight requests rejoin the
//!   decode pool after every token — continuous batching, not
//!   drain-and-refill.  With [`ServeCfg::kv_pages`] the stores are
//!   [`PagedKvCache`]s over one shared [`KvPool`] — fixed-size pages,
//!   per-request block tables, admission gated on free pages with
//!   preemption-by-recompute when the pool runs dry, and (with
//!   [`ServeCfg::kv_share_prefix`]) refcounted copy-on-write sharing of
//!   common prompt-prefix pages — bit-identical to the contiguous
//!   layout, including across a forced preemption.
//! * The [`stats`] module is the loops' metrics plane: serve-loop
//!   threads record typed [`StatsEvent`]s into per-thread ring buffers,
//!   and a sampler thread ([`ServeCfg::stats_every`]) aggregates them
//!   into periodic [`StatsReport`]s — interval tokens/s for prefill vs
//!   decode, queue depth, batch-occupancy histogram, resident and
//!   high-water KV-cache bytes, paged-pool gauges (free/shared pages,
//!   preemptions, CoW forks), and p50/p90/p99 request / per-token /
//!   step latency — emitted as JSON lines through a [`StatsSink`]
//!   (stderr by default) and returned as the final aggregate on
//!   [`StreamReport::stats`] / [`DecodeReport::stats`].
//! * The [`trace`] module is the workload harness: a seeded generator
//!   for mixed request classes (short chat turns, long-document
//!   prefill, bursty arrivals, shared-prefix fleets that exercise
//!   copy-on-write page adoption) emitting a replayable JSON [`Trace`],
//!   and a replayer ([`trace::replay`]) that drives
//!   [`Server::run_decode_streaming`] at the trace's arrival times with
//!   per-request deadlines and distills a per-class [`SloReport`]
//!   (p50/p90/p99 first-token / per-token / request latency, timeout and
//!   reject counts, KV preemptions) beside the [`StatsReport`].
//! * A pruned model round-trips through [`crate::snapshot`]
//!   ([`SparseModel::to_snapshot`] / [`SparseModel::from_snapshot`]), so
//!   `permllm serve --snapshot model.bin` boots without re-pruning and
//!   serves bit-identical tokens.
//! * [`DenseModel`] materializes the dense-masked weights once — the
//!   benchmark baseline the CI bench gate compares sparse serving
//!   against, never part of the serving path itself.  It shares the
//!   KV-cached glue, so the bench compares prefill and decode throughput
//!   like for like.
//!
//! Numerics: the sparse path matches the host dense-masked reference
//! ([`SparseModel::dense_forward`]) within 1e-3 at 2:4 and 4:8, the
//! pipelined, sequential, and streaming modes are bit-identical (same
//! kernels, same tiling), and incremental decode matches full-sequence
//! re-forward (the decode-parity tests pin this on both serve paths at
//! both patterns).
//!
//! Entry points: the `permllm serve` CLI subcommand (`--sparse-attn`,
//! `--stream`, `--decode`) and the `sparse_inference` example (per-layer
//! + end-to-end tokens/s, prefill vs decode tokens/s, `--json` for the
//! machine-readable bench summary).

mod batcher;
mod decode;
mod model;
mod server;
pub mod stats;
mod stream;
pub mod trace;

#[cfg(test)]
pub(crate) use model::tests as model_tests;

pub use batcher::{
    BatcherCfg, ContinuousBatcher, MicroBatch, MicroBatcher, ReorderBuffer, Request, StepBatch,
    StepItem,
};
pub use decode::{DecodeClient, DecodeReport, GenRequest, GenTicket};
pub use model::{greedy_token, DenseModel, Sampler, ServePath, SparseLayer, SparseModel};
pub use server::{ServeCfg, ServeReport, Server, StageStats};
pub use stats::{
    Percentiles, ReqOutcome, StatsEvent, StatsHub, StatsRecorder, StatsReport, StatsSink,
};
pub use stream::{ServeError, StreamClient, StreamReport, Ticket};
pub use trace::{ClassSlo, SloReport, Trace, TraceCfg, TraceRequest};

pub use crate::model::{KvCache, KvPool, KvStore, PagedKvCache, SharedPrefix};
