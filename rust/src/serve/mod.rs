//! Batched multi-layer sparse serving — the paper's Table 3 hot path
//! turned into a subsystem.
//!
//! The pruning pipeline produces per-linear N:M weights; this module
//! serves them:
//!
//! * [`SparseModel`] compresses **every** prunable linear of a
//!   [`crate::coordinator::PrunedModel`] to the Sparse-Tensor-Core layout
//!   exactly once (values + u8 group metadata + permutation, converted to
//!   artifact tensors at build time) and runs the decoder layers' SwiGLU
//!   MLP sublayers end-to-end on the sparse path, each
//!   `sparse_fwd_{c_out}x{c_in}` execution routed through the
//!   [`crate::runtime::ExecBackend`] trait — the same serving loop works
//!   on the pure-Rust [`crate::runtime::NativeEngine`] and any
//!   shape-polymorphic PJRT backend (fixed-shape AOT artifacts are
//!   rejected up front; see [`Server`]).
//! * [`MicroBatcher`] coalesces the FIFO request queue into
//!   token-budgeted micro-batches; [`ReorderBuffer`] keeps completions in
//!   submission order.
//! * [`Server`] drives the whole thing, either sequentially
//!   ([`Server::run_sequential`], any backend) or with **cross-layer
//!   pipelining** ([`Server::run_pipelined`]): one backend per decoder
//!   layer connected by channels ([`crate::util::pool::pipeline_map`]),
//!   so layer `L` of batch `i` overlaps layer `L+1` of batch `i-1` while
//!   `Compressed::matmul_xt_threads` tiles each individual matmul across
//!   worker threads.
//!
//! Numerics: the sparse path matches the host dense-masked reference
//! ([`SparseModel::dense_forward`]) within 1e-3, and the pipelined and
//! sequential modes are bit-identical (same kernels, same tiling).
//!
//! Entry points: the `permllm serve` CLI subcommand and the
//! `sparse_inference` example (per-layer + end-to-end tokens/s).

mod batcher;
mod model;
mod server;

pub use batcher::{BatcherCfg, MicroBatch, MicroBatcher, ReorderBuffer, Request};
pub use model::{SparseLayer, SparseModel};
pub use server::{ServeCfg, ServeReport, Server, StageStats};
