//! Multi-layer sparse model: every prunable linear of a pruned model
//! compressed to the N:M serving layout once, cached, and served through
//! the [`ExecBackend`] artifact interface — attention and MLP sublayers
//! both.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Result};

use crate::coordinator::PrunedModel;
use std::sync::Arc;

use crate::model::{
    cached_attention, cached_attention_scratch, causal_attention, rmsnorm, rmsnorm_scratch, rope,
    swiglu, swiglu_scratch, KvPool, KvStore, LinearKind, LinearRef, ModelConfig,
};
use crate::runtime::{ExecBackend, TensorValue};
use crate::sparsity::{Compressed, NmConfig};
use crate::tensor::Mat;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::scratch::StepArena;

/// Which sublayers of each decoder layer run on the sparse path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServePath {
    /// SwiGLU MLP sublayers only: attention is skipped entirely and each
    /// stage is `x + W_down(silu(W_gate(xn)) ⊙ W_up(xn))` — the original
    /// serving mode, kept as the comparison point.
    #[default]
    MlpOnly,
    /// The full decoder layer: the attention sublayer (q/k/v/o
    /// projections via `sparse_fwd`, RoPE + causal-softmax host glue,
    /// per request span) followed by the MLP sublayer.
    FullDecoder,
}

impl ServePath {
    pub fn name(&self) -> &'static str {
        match self {
            ServePath::MlpOnly => "mlp-only",
            ServePath::FullDecoder => "full-decoder",
        }
    }

    /// Whether `kind` is served on this path.
    fn uses(&self, kind: LinearKind) -> bool {
        match self {
            ServePath::MlpOnly => {
                matches!(kind, LinearKind::WGate | LinearKind::WUp | LinearKind::WDown)
            }
            ServePath::FullDecoder => true,
        }
    }
}

/// One compressed linear, ready to serve: the `sparse_fwd` artifact name
/// plus its static inputs (vals / idx / src) converted exactly once at
/// build time, so per-request work is only the activation conversion.
///
/// On backends with resident-weight support ([`ExecBackend::bind`]) the
/// statics are bound once per backend under [`SparseLayer::bind_key`] and
/// never cross the call boundary again; other backends fall back to the
/// full per-call input list.
#[derive(Debug, Clone)]
pub struct SparseLayer {
    pub lin: LinearRef,
    pub artifact: String,
    /// Bind key: the artifact name scoped by the owning model instance
    /// and parameter, so same-shape linears (`wq`/`wk`/...) and layers
    /// of *different* [`SparseModel`]s stay distinct per backend.
    bind_key: String,
    nm: NmConfig,
    c_out: usize,
    c_in: usize,
    /// Compressed-format footprint (f32 values + u8 group offsets),
    /// recorded at build time — the transient `Compressed` itself is not
    /// retained, so resident memory is just the artifact tensors below.
    storage_bytes: usize,
    /// Cached artifact inputs.
    vals: TensorValue,
    idx: TensorValue,
    src: TensorValue,
    /// Channel permutation (`src_of`) kept on the host side for the
    /// dense verification path; the dense weight itself is materialized
    /// on demand so serving memory stays at the compressed footprint.
    src_of: Vec<usize>,
}

/// Process-unique id per [`SparseModel`] instance, folded into bind keys
/// so a backend shared across two models (e.g. after a re-prune) can
/// never serve the first model's resident weights for the second.
static MODEL_IDS: AtomicU64 = AtomicU64::new(0);

impl SparseLayer {
    fn build(
        instance: u64,
        lin: LinearRef,
        res: &crate::pruning::PruneResult,
    ) -> Result<SparseLayer> {
        let comp = Compressed::compress(&res.weight, &res.mask);
        SparseLayer::from_compressed(instance, lin, &comp, res.src_of.clone())
    }

    /// Build a serving layer from already-compressed storage — the shared
    /// tail of [`SparseLayer::build`] (fresh prune) and the snapshot
    /// loader ([`SparseModel::from_snapshot`]).  `comp` has been through
    /// [`Compressed`]'s structural validation, so a layer rebuilt from a
    /// snapshot caches byte-identical artifact tensors to one built from
    /// the original prune.
    fn from_compressed(
        instance: u64,
        lin: LinearRef,
        comp: &Compressed,
        src_of: Vec<usize>,
    ) -> Result<SparseLayer> {
        let (c_out, c_in) = comp.shape();
        let k = comp.k();
        let vals = TensorValue::f32(vec![c_out, k], comp.vals().to_vec())?;
        let idx =
            TensorValue::i32(vec![c_out, k], comp.idx().iter().map(|&v| v as i32).collect())?;
        anyhow::ensure!(
            src_of.len() == c_in,
            "layer {}: src_of has {} entries, expected {c_in}",
            lin.param_name(),
            src_of.len()
        );
        let src = TensorValue::i32(vec![c_in], src_of.iter().map(|&v| v as i32).collect())?;
        let artifact = format!("sparse_fwd_{c_out}x{c_in}");
        let bind_key = format!("{artifact}@m{instance}.{}", lin.param_name());
        Ok(SparseLayer {
            lin,
            artifact,
            bind_key,
            nm: comp.cfg(),
            c_out,
            c_in,
            storage_bytes: comp.storage_bytes(),
            vals,
            idx,
            src,
            src_of,
        })
    }

    /// `(C_out, C_in)` of the underlying weight.
    pub fn shape(&self) -> (usize, usize) {
        (self.c_out, self.c_in)
    }

    /// Compressed storage footprint of this layer.
    pub fn storage_bytes(&self) -> usize {
        self.storage_bytes
    }

    /// The key this layer's statics bind under on resident-weight
    /// backends (artifact name scoped by model instance + parameter
    /// name, e.g. `sparse_fwd_64x64@m0.layers.0.wq`).
    pub fn bind_key(&self) -> &str {
        &self.bind_key
    }

    /// `y = x W_sparse^T` through the backend's `sparse_fwd` artifact
    /// (the artifact permutes `x` by `src` internally). `x` is
    /// `[T, C_in]` in ORIGINAL channel order.
    ///
    /// Backends with [`ExecBackend::supports_bind`] get the static
    /// tensors bound on first use; afterwards only the activation crosses
    /// the call boundary.  Other backends receive the full input list
    /// every call.
    pub fn forward(&self, engine: &mut dyn ExecBackend, x: &Mat) -> Result<Mat> {
        let mut outs = if engine.supports_bind() {
            if !engine.is_bound(&self.bind_key) {
                engine.bind(
                    &self.bind_key,
                    &self.artifact,
                    &[("vals", &self.vals), ("idx", &self.idx), ("src_of", &self.src)],
                )?;
            }
            engine.run_bound(&self.bind_key, &[TensorValue::from_mat(x)])?
        } else {
            let inputs =
                [self.vals.clone(), self.idx.clone(), TensorValue::from_mat(x), self.src.clone()];
            engine.run(&self.artifact, &inputs)?
        };
        anyhow::ensure!(
            outs.len() == 1,
            "artifact {} returned {} outputs, expected 1",
            self.artifact,
            outs.len()
        );
        outs.pop().expect("len checked").into_mat()
    }

    /// [`SparseLayer::forward`] on arena storage: backends exposing the
    /// [`ExecBackend::run_bound_mat`] fast path compute straight into a
    /// recycled matrix with no `TensorValue` round-trip; everything else
    /// falls back to the allocating call.  Bit-identical either way —
    /// both routes run the same bound kernel.
    pub fn forward_scratch(
        &self,
        engine: &mut dyn ExecBackend,
        x: &Mat,
        arena: &mut StepArena,
    ) -> Result<Mat> {
        if engine.supports_bind() {
            if !engine.is_bound(&self.bind_key) {
                engine.bind(
                    &self.bind_key,
                    &self.artifact,
                    &[("vals", &self.vals), ("idx", &self.idx), ("src_of", &self.src)],
                )?;
            }
            if let Some(res) = engine.run_bound_mat(&self.bind_key, x, arena) {
                return res;
            }
        }
        self.forward(engine, x)
    }

    /// The masked weight in *storage* (permuted) channel order, rebuilt
    /// from the cached artifact tensors.
    fn stored_dense(&self) -> Mat {
        let vals = self.vals.as_f32().expect("vals dtype").to_vec();
        let idx: Vec<u32> =
            self.idx.as_i32().expect("idx dtype").iter().map(|&v| v as u32).collect();
        Compressed::from_parts(self.nm, self.c_out, self.c_in, vals, idx)
            .expect("layer was built from a valid compressed weight")
            .to_dense()
    }

    /// Host dense reference of [`SparseLayer::forward`]: permute the
    /// activations, dense matmul on the masked weight.  Materializes the
    /// dense weight per call from the cached artifact tensors — this is
    /// the *verification* path; keeping a permanent dense copy would make
    /// the compressed serving footprint a lie.
    pub fn forward_dense(&self, x: &Mat) -> Mat {
        x.permute_cols(&self.src_of).matmul_bt(&self.stored_dense())
    }

    /// The masked dense weight in ORIGINAL channel order (permutation
    /// folded back in), materialized on demand.  [`DenseModel`] caches
    /// these once for the benchmark baseline; serving itself never does.
    pub fn dense_weight(&self) -> Mat {
        let stored = self.stored_dense();
        let mut out = Mat::zeros(self.c_out, self.c_in);
        for r in 0..self.c_out {
            let srow = stored.row(r);
            let orow = out.row_mut(r);
            for (j, &oc) in self.src_of.iter().enumerate() {
                orow[oc] = srow[j];
            }
        }
        out
    }
}

/// Spans must tile `[0, rows)` contiguously: the attention glue treats
/// each span as one independent sequence, and a row outside every span
/// would silently skip attention.
fn check_seqs(seqs: &[(usize, usize)], rows: usize) -> Result<()> {
    let mut at = 0usize;
    for &(lo, hi) in seqs {
        anyhow::ensure!(
            lo == at && lo < hi,
            "sequence spans must tile the batch contiguously: got {seqs:?} for {rows} rows"
        );
        at = hi;
    }
    anyhow::ensure!(at == rows, "sequence spans cover {at} of {rows} rows: {seqs:?}");
    Ok(())
}

/// One [`KvStore`] per span, in span order — the prefill/decode stage
/// signature.  Prefill and decode are the *same* cached-attention call:
/// a span whose cache is empty is a prefill (RoPE starts at 0), a span
/// with cached positions is an incremental step (the new rows attend
/// over the cache at the right offsets).  A mixed batch simply mixes the
/// two kinds of span, and each store may be contiguous or paged — the
/// attention glue is layout-agnostic.
fn check_caches(seqs: &[(usize, usize)], caches: &[KvStore], n_layers: usize) -> Result<()> {
    anyhow::ensure!(
        caches.len() == seqs.len(),
        "got {} KV caches for {} sequence spans",
        caches.len(),
        seqs.len()
    );
    for (i, c) in caches.iter().enumerate() {
        anyhow::ensure!(
            c.n_layers() == n_layers,
            "span {i}: KV cache covers {} layers, model has {n_layers}",
            c.n_layers()
        );
    }
    Ok(())
}

/// KV-cached [`attend_spans`]: each span's rows are the *new* tokens of
/// its request; the span's queries/keys are rotated at the absolute
/// positions recorded in its cache, the rotated K and the V are appended
/// to the cache, and the new queries attend over the whole cached
/// sequence.  The per-span body is [`cached_attention`] — shared with
/// the host reference forward so the serving path cannot drift from it.
fn attend_spans_cached(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    (n_heads, theta): (usize, f32),
    seqs: &[(usize, usize)],
    caches: &mut [KvStore],
    layer: usize,
) -> Mat {
    let mut o = Mat::zeros(q.rows(), q.cols());
    for (cache, &(lo, hi)) in caches.iter_mut().zip(seqs) {
        let qs = q.row_block(lo, hi);
        let ks = k.row_block(lo, hi);
        let vs = v.row_block(lo, hi);
        let os = cached_attention(qs, ks, vs, n_heads, theta, cache, layer);
        for (r, dst) in (lo..hi).enumerate() {
            o.row_mut(dst).copy_from_slice(os.row(r));
        }
    }
    o
}

/// `m.row_block(lo, hi)` into arena storage — same copy, recycled buffer.
fn row_block_scratch(m: &Mat, lo: usize, hi: usize, arena: &mut StepArena) -> Mat {
    let mut out = arena.take(hi - lo, m.cols());
    for (r, src) in (lo..hi).enumerate() {
        out.row_mut(r).copy_from_slice(m.row(src));
    }
    out
}

/// [`attend_spans_cached`] on arena storage: the per-span q/k/v copies,
/// the per-span mix, and the assembled output all come from `arena` (the
/// span copies are given back inside [`cached_attention_scratch`], the
/// span mixes here).  Same copies, same arithmetic, same order — pinned
/// bit-identical by `forward_cached_scratch_is_bit_identical`.
#[allow(clippy::too_many_arguments)]
fn attend_spans_cached_scratch(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    (n_heads, theta): (usize, f32),
    seqs: &[(usize, usize)],
    caches: &mut [KvStore],
    layer: usize,
    arena: &mut StepArena,
) -> Mat {
    let mut o = arena.take(q.rows(), q.cols());
    for (cache, &(lo, hi)) in caches.iter_mut().zip(seqs) {
        let qs = row_block_scratch(q, lo, hi, arena);
        let ks = row_block_scratch(k, lo, hi, arena);
        let vs = row_block_scratch(v, lo, hi, arena);
        let os = cached_attention_scratch(qs, ks, vs, n_heads, theta, cache, layer, arena);
        for (r, dst) in (lo..hi).enumerate() {
            o.row_mut(dst).copy_from_slice(os.row(r));
        }
        arena.give(os);
    }
    o
}

/// Token-id -> `[T, d]` embedding rows with vocab validation — the one
/// copy behind [`SparseModel::embed`] and [`DenseModel::embed`].
fn embed_rows(tok_embed: &Mat, vocab: usize, tokens: &[u32]) -> Result<Mat> {
    anyhow::ensure!(!tokens.is_empty(), "cannot embed an empty token sequence");
    let mut x = Mat::zeros(tokens.len(), tok_embed.cols());
    for (r, &tok) in tokens.iter().enumerate() {
        anyhow::ensure!((tok as usize) < vocab, "token {tok} outside vocab {vocab}");
        x.row_mut(r).copy_from_slice(tok_embed.row(tok as usize));
    }
    Ok(x)
}

/// Final RMSNorm + dense LM-head matmul — the one copy behind
/// [`SparseModel::logits`] and [`DenseModel::logits`].
fn head_logits(h: &Mat, final_norm: &Mat, eps: f32, lm_head: &Mat) -> Mat {
    rmsnorm(h, final_norm, eps).matmul_bt(lm_head)
}

/// Greedy decoding: index of the largest logit (ties break to the lowest
/// index, deterministically).
///
/// NaN logits are skipped, so a degenerate model still decodes the best
/// finite candidate — the same rule [`Sampler::TopK`] ranks by, which
/// keeps `TopK { k: 1 }` bit-identical to greedy on any input.  All-NaN
/// logits return token 0.  (The old strict `v > logits[best]` scan got
/// stuck on a NaN at index 0: every comparison against NaN is false.)
pub fn greedy_token(logits: &[f32]) -> u32 {
    let mut best: Option<usize> = None;
    for (i, &v) in logits.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some(b) if logits[b] >= v => {}
            _ => best = Some(i),
        }
    }
    best.unwrap_or(0) as u32
}

/// Token-selection policy for the generation paths
/// ([`SparseModel::generate`] and the continuous-batching decode loop).
///
/// Sampling is deterministic under a fixed seed: each generation owns a
/// [`Pcg32`] derived from the sampler ([`Sampler::rng`]) and draws
/// exactly once per step, so a request's token trajectory is identical
/// whether it is served alone or coalesced into step batches.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Sampler {
    /// Argmax ([`greedy_token`]) — the default, bit-reproducible
    /// without any RNG state.
    #[default]
    Greedy,
    /// Sample from the `temperature`-scaled softmax over the `k`
    /// highest logits (ties broken toward lower token ids when ranking).
    TopK { k: usize, temperature: f32, seed: u64 },
    /// Nucleus sampling: the shortlist is the smallest set of
    /// highest-probability tokens whose `temperature`-scaled softmax
    /// mass reaches `p` (always at least one token), renormalized and
    /// sampled with one draw per step.  `p = 1.0` is the full softmax.
    TopP { p: f32, temperature: f32, seed: u64 },
}

/// The strict total order the stochastic samplers rank tokens by:
/// higher logit first, ties toward the lower token id, NaNs grouped
/// last.  NaNs must not be `Ordering::Equal`-ambiguous: Rust's sorts
/// reject non-total comparators, and a degenerate model (NaN logits)
/// must not panic the decode collector.
fn rank_tokens(logits: &[f32], a: usize, b: usize) -> std::cmp::Ordering {
    let (fa, fb) = (logits[a], logits[b]);
    fa.is_nan()
        .cmp(&fb.is_nan())
        .then(fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal))
        .then(a.cmp(&b))
}

impl Sampler {
    /// The per-generation RNG this sampler's draws come from.  Greedy
    /// never consumes it; top-k and top-p consume exactly one draw per
    /// step.
    pub fn rng(&self) -> Pcg32 {
        match self {
            Sampler::Greedy => Pcg32::new(0, 0x5a3),
            Sampler::TopK { seed, .. } | Sampler::TopP { seed, .. } => Pcg32::new(*seed, 0x5a3),
        }
    }

    /// Reject malformed configurations with a human-readable reason
    /// (checked once at submit time so the decode loop never panics on
    /// a bad request).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            Sampler::Greedy => Ok(()),
            Sampler::TopK { k, temperature, .. } => {
                if *k == 0 {
                    return Err("top-k sampler needs k >= 1".into());
                }
                Self::validate_temperature("top-k", *temperature)
            }
            Sampler::TopP { p, temperature, .. } => {
                if !p.is_finite() || *p <= 0.0 || *p > 1.0 {
                    return Err(format!("top-p sampler needs p in (0, 1], got {p}"));
                }
                Self::validate_temperature("top-p", *temperature)
            }
        }
    }

    fn validate_temperature(which: &str, temperature: f32) -> Result<(), String> {
        if !temperature.is_finite() || temperature <= 0.0 {
            return Err(format!(
                "{which} sampler needs a finite temperature > 0, got {temperature}"
            ));
        }
        Ok(())
    }

    /// Pick the next token from one row of LM-head logits.
    pub fn sample(&self, logits: &[f32], rng: &mut Pcg32) -> u32 {
        match self {
            Sampler::Greedy => greedy_token(logits),
            Sampler::TopK { k, temperature, .. } => {
                let k = (*k).clamp(1, logits.len());
                // Rank by logit, ties toward the lower token id — the
                // same deterministic order greedy_token uses.  NaNs
                // group last (a degenerate model must not panic the
                // decode collector: Rust's sorts reject non-total
                // comparators), which makes this a strict total order,
                // so partial selection of the k best then sorting just
                // those k is identical to a full sort + truncate —
                // O(V + k log k) per decode step instead of O(V log V).
                let by_rank = |&a: &usize, &b: &usize| rank_tokens(logits, a, b);
                let mut order: Vec<usize> = (0..logits.len()).collect();
                if k < order.len() {
                    let _ = order.select_nth_unstable_by(k - 1, by_rank);
                    order.truncate(k);
                }
                order.sort_by(by_rank);
                // NaNs ranked last: trim them so they cannot poison the
                // softmax normalizer (z = NaN would make every finite
                // candidate unreachable).  All-NaN logits keep one entry
                // and fall through to the deterministic tail return.
                while order.len() > 1 && logits[*order.last().expect("k >= 1")].is_nan() {
                    order.pop();
                }
                // Temperature-scaled softmax over the shortlist.
                let mx = logits[order[0]];
                let mut probs: Vec<f32> =
                    order.iter().map(|&i| ((logits[i] - mx) / temperature).exp()).collect();
                let z: f32 = probs.iter().sum();
                for p in probs.iter_mut() {
                    *p /= z;
                }
                // One inverse-CDF draw per step.
                let u = rng.uniform();
                let mut acc = 0.0f32;
                for (p, &i) in probs.iter().zip(&order) {
                    acc += p;
                    if u < acc {
                        return i as u32;
                    }
                }
                *order.last().expect("k >= 1") as u32
            }
            Sampler::TopP { p, temperature, .. } => {
                // Rank every token by the same total order top-k uses,
                // trim the NaN tail (it must not poison the softmax
                // normalizer), then keep the smallest prefix of the
                // distribution whose temperature-scaled mass reaches p
                // — the nucleus.  At least one token always survives.
                let mut order: Vec<usize> = (0..logits.len()).collect();
                order.sort_by(|&a, &b| rank_tokens(logits, a, b));
                while order.len() > 1 && logits[*order.last().expect("vocab nonempty")].is_nan() {
                    order.pop();
                }
                let mx = logits[order[0]];
                let mut probs: Vec<f32> =
                    order.iter().map(|&i| ((logits[i] - mx) / temperature).exp()).collect();
                let z: f32 = probs.iter().sum();
                // Cumulative walk in rank order; comparing against p*z
                // avoids dividing every term before the cut is known.
                let mut cut = order.len();
                let mut acc = 0.0f32;
                for (n, pr) in probs.iter().enumerate() {
                    acc += pr;
                    if acc >= *p * z {
                        cut = n + 1;
                        break;
                    }
                }
                order.truncate(cut);
                probs.truncate(cut);
                let zs: f32 = probs.iter().sum();
                for q in probs.iter_mut() {
                    *q /= zs;
                }
                // One inverse-CDF draw per step — the same discipline as
                // top-k, so trajectories are batching-independent.
                let u = rng.uniform();
                let mut acc = 0.0f32;
                for (pr, &i) in probs.iter().zip(&order) {
                    acc += pr;
                    if u < acc {
                        return i as u32;
                    }
                }
                *order.last().expect("nucleus keeps >= 1 token") as u32
            }
        }
    }
}

/// The dense decoder-stage math for one layer, parameterized by how a
/// linear is applied — the single copy shared by
/// [`SparseModel::dense_stage`] and [`DenseModel::stage`] so the two
/// dense references cannot drift from each other.
struct DenseStage<'a> {
    n_heads: usize,
    rope_theta: f32,
    attn_norm: &'a Mat,
    mlp_norm: &'a Mat,
    eps: f32,
}

impl DenseStage<'_> {
    fn run(
        &self,
        x: &Mat,
        seqs: &[(usize, usize)],
        path: ServePath,
        apply: &dyn Fn(LinearKind, &Mat) -> Mat,
    ) -> Mat {
        let x = match path {
            ServePath::MlpOnly => x.clone(),
            ServePath::FullDecoder => {
                check_seqs(seqs, x.rows()).expect("bad sequence spans");
                let xn = rmsnorm(x, self.attn_norm, self.eps);
                let q = apply(LinearKind::Wq, &xn);
                let k = apply(LinearKind::Wk, &xn);
                let v = apply(LinearKind::Wv, &xn);
                let o = attend_spans(&q, &k, &v, self.n_heads, self.rope_theta, seqs);
                let att = apply(LinearKind::Wo, &o);
                x.add(&att)
            }
        };
        let xn = rmsnorm(&x, self.mlp_norm, self.eps);
        let gate = apply(LinearKind::WGate, &xn);
        let up = apply(LinearKind::WUp, &xn);
        let h = swiglu(&gate, &up);
        let down = apply(LinearKind::WDown, &h);
        x.add(&down)
    }

    /// KV-cached counterpart of [`DenseStage::run`]: spans hold only the
    /// new tokens, attention goes through each span's cache at `layer`.
    /// On [`ServePath::MlpOnly`] the caches are untouched (the stage is
    /// position-independent).
    fn run_cached(
        &self,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
        path: ServePath,
        apply: &dyn Fn(LinearKind, &Mat) -> Mat,
    ) -> Mat {
        let x = match path {
            ServePath::MlpOnly => x.clone(),
            ServePath::FullDecoder => {
                check_seqs(seqs, x.rows()).expect("bad sequence spans");
                let xn = rmsnorm(x, self.attn_norm, self.eps);
                let q = apply(LinearKind::Wq, &xn);
                let k = apply(LinearKind::Wk, &xn);
                let v = apply(LinearKind::Wv, &xn);
                let o = attend_spans_cached(
                    &q,
                    &k,
                    &v,
                    (self.n_heads, self.rope_theta),
                    seqs,
                    caches,
                    layer,
                );
                let att = apply(LinearKind::Wo, &o);
                x.add(&att)
            }
        };
        let xn = rmsnorm(&x, self.mlp_norm, self.eps);
        let gate = apply(LinearKind::WGate, &xn);
        let up = apply(LinearKind::WUp, &xn);
        let h = swiglu(&gate, &up);
        let down = apply(LinearKind::WDown, &h);
        x.add(&down)
    }
}

/// RoPE + causal softmax applied independently to each request span of a
/// stacked micro-batch: positions restart at every span start and
/// attention never crosses a span boundary, so a request's attention
/// output is identical whether it is served alone or coalesced.
/// `q`/`k`/`v` are `[T, d]`; returns the `[T, d]` mix (the `W_o` input).
fn attend_spans(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    n_heads: usize,
    theta: f32,
    seqs: &[(usize, usize)],
) -> Mat {
    let mut o = Mat::zeros(q.rows(), q.cols());
    for &(lo, hi) in seqs {
        let mut qs = q.row_block(lo, hi);
        let mut ks = k.row_block(lo, hi);
        let vs = v.row_block(lo, hi);
        rope(&mut qs, n_heads, theta);
        rope(&mut ks, n_heads, theta);
        let os = causal_attention(&qs, &ks, &vs, n_heads);
        for (r, dst) in (lo..hi).enumerate() {
            o.row_mut(dst).copy_from_slice(os.row(r));
        }
    }
    o
}

/// All compressed linears of a pruned model plus the host glue (norms,
/// RoPE, causal softmax, SwiGLU) needed to run the decoder layers
/// end-to-end on the sparse path.
///
/// The serving pipeline treats each decoder layer as one pipeline stage,
/// `[T, d]` in and `[T, d]` out, so stages chain across decoder layers.
/// What a stage computes depends on the [`ServePath`]:
///
/// * [`ServePath::MlpOnly`] — the SwiGLU MLP sublayer only (three
///   `sparse_fwd` executions per stage);
/// * [`ServePath::FullDecoder`] — the attention sublayer (q/k/v/o through
///   `sparse_fwd`, RoPE + causal softmax applied per request span on the
///   host) followed by the MLP sublayer (seven `sparse_fwd` executions
///   per stage).
///
/// The attention host glue is shared with the reference forward
/// (`crate::model`) so the serving path and the host transformer cannot
/// drift.
pub struct SparseModel {
    cfg: ModelConfig,
    nm: NmConfig,
    layers: HashMap<LinearRef, SparseLayer>,
    /// Per-decoder-layer attention norm gain `[1, d]`.
    attn_norms: Vec<Mat>,
    /// Per-decoder-layer MLP norm gain `[1, d]`.
    mlp_norms: Vec<Mat>,
    norm_eps: f32,
    /// Token embedding `[vocab, d]` — dense (embeddings and the head are
    /// never pruned, paper §5.1); the decode path's token -> activation
    /// entry point.
    tok_embed: Mat,
    /// Final RMSNorm gain `[1, d]`.
    final_norm: Mat,
    /// LM head `[vocab, d]` — dense; the decode path's logits exit point.
    lm_head: Mat,
    /// Canonical label of the recipe that produced the weights.
    recipe_name: String,
    /// Full JSON descriptor of that recipe — stamped into bench
    /// artifacts (`sparse_inference --json`) so results always record
    /// which metric × permutation × update combination they measure.
    recipe_json: Json,
}

impl SparseModel {
    /// Compress every pruned linear of `pruned` once.  Fails on a Dense
    /// (unpruned) model or when any prunable linear lacks a prune result.
    pub fn from_pruned(pruned: &PrunedModel) -> Result<SparseModel> {
        let cfg = pruned.params.cfg().clone();
        let some = pruned
            .layers
            .values()
            .next()
            .ok_or_else(|| anyhow!("model has no pruned layers to serve (Dense method?)"))?;
        let nm = some.mask.cfg();
        let instance = MODEL_IDS.fetch_add(1, Ordering::Relaxed);
        let mut layers = HashMap::new();
        for lin in cfg.prunable_linears() {
            let res = pruned
                .layers
                .get(&lin)
                .ok_or_else(|| anyhow!("no prune result for {}", lin.param_name()))?;
            anyhow::ensure!(
                res.mask.cfg() == nm,
                "mixed N:M patterns: {} is {:?}, expected {nm:?}",
                lin.param_name(),
                res.mask.cfg()
            );
            layers.insert(lin, SparseLayer::build(instance, lin, res)?);
        }
        let attn_norms = (0..cfg.n_layers)
            .map(|l| pruned.params.get(&format!("layers.{l}.attn_norm")).clone())
            .collect();
        let mlp_norms = (0..cfg.n_layers)
            .map(|l| pruned.params.get(&format!("layers.{l}.mlp_norm")).clone())
            .collect();
        let norm_eps = cfg.norm_eps;
        let tok_embed = pruned.params.get("tok_embed").clone();
        let final_norm = pruned.params.get("final_norm").clone();
        let lm_head = pruned.params.get("lm_head").clone();
        Ok(SparseModel {
            cfg,
            nm,
            layers,
            attn_norms,
            mlp_norms,
            norm_eps,
            tok_embed,
            final_norm,
            lm_head,
            recipe_name: pruned.recipe.name(),
            recipe_json: pruned.recipe.to_json(),
        })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Canonical label of the recipe that produced these weights.
    pub fn recipe_name(&self) -> &str {
        &self.recipe_name
    }

    /// JSON descriptor of the producing recipe (for bench artifacts).
    pub fn recipe_json(&self) -> &Json {
        &self.recipe_json
    }

    pub fn nm(&self) -> NmConfig {
        self.nm
    }

    /// Serving pipeline depth (one stage per decoder layer).
    pub fn n_stages(&self) -> usize {
        self.cfg.n_layers
    }

    /// Activation width at every stage boundary.
    pub fn width(&self) -> usize {
        self.cfg.dim
    }

    /// A cached compressed linear.
    pub fn linear(&self, lin: LinearRef) -> &SparseLayer {
        &self.layers[&lin]
    }

    /// Total compressed storage across every cached linear.
    pub fn storage_bytes(&self) -> usize {
        self.layers.values().map(SparseLayer::storage_bytes).sum()
    }

    /// Dense f32 storage the same linears would occupy.
    pub fn dense_bytes(&self) -> usize {
        self.layers
            .values()
            .map(|l| {
                let (o, i) = l.shape();
                o * i * 4
            })
            .sum()
    }

    fn layer(&self, layer: usize, kind: LinearKind) -> &SparseLayer {
        &self.layers[&LinearRef { layer, kind }]
    }

    /// Decoder layer `layer`'s attention sublayer on the sparse path:
    /// `x + W_o(attend(rope(W_q xn), rope(W_k xn), W_v xn))`, with RoPE +
    /// causal softmax applied per request span (`seqs`).
    pub fn attn_stage(
        &self,
        engine: &mut dyn ExecBackend,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
    ) -> Result<Mat> {
        check_seqs(seqs, x.rows())?;
        let xn = rmsnorm(x, &self.attn_norms[layer], self.norm_eps);
        let q = self.layer(layer, LinearKind::Wq).forward(engine, &xn)?;
        let k = self.layer(layer, LinearKind::Wk).forward(engine, &xn)?;
        let v = self.layer(layer, LinearKind::Wv).forward(engine, &xn)?;
        let o = attend_spans(&q, &k, &v, self.cfg.n_heads, self.cfg.rope_theta, seqs);
        let att = self.layer(layer, LinearKind::Wo).forward(engine, &o)?;
        Ok(x.add(&att))
    }

    /// Decoder layer `layer`'s MLP sublayer on the sparse path:
    /// `x + W_down(silu(W_gate(xn)) ⊙ W_up(xn))`, `xn = rmsnorm(x)`.
    pub fn mlp_stage(&self, engine: &mut dyn ExecBackend, layer: usize, x: &Mat) -> Result<Mat> {
        let xn = rmsnorm(x, &self.mlp_norms[layer], self.norm_eps);
        let gate = self.layer(layer, LinearKind::WGate).forward(engine, &xn)?;
        let up = self.layer(layer, LinearKind::WUp).forward(engine, &xn)?;
        let h = swiglu(&gate, &up);
        let down = self.layer(layer, LinearKind::WDown).forward(engine, &h)?;
        Ok(x.add(&down))
    }

    /// [`SparseModel::mlp_stage`] on arena storage: every intermediate
    /// (normed input, gate/up projections, SwiGLU mix, down projection)
    /// is taken from and given back to `arena`; only the returned sum
    /// stays out, for the caller to give back once consumed.
    pub fn mlp_stage_scratch(
        &self,
        engine: &mut dyn ExecBackend,
        layer: usize,
        x: &Mat,
        arena: &mut StepArena,
    ) -> Result<Mat> {
        let xn = rmsnorm_scratch(x, &self.mlp_norms[layer], self.norm_eps, arena);
        let gate = self.layer(layer, LinearKind::WGate).forward_scratch(engine, &xn, arena)?;
        let up = self.layer(layer, LinearKind::WUp).forward_scratch(engine, &xn, arena)?;
        arena.give(xn);
        let h = swiglu_scratch(&gate, &up, arena);
        arena.give(gate);
        arena.give(up);
        let down = self.layer(layer, LinearKind::WDown).forward_scratch(engine, &h, arena)?;
        arena.give(h);
        let mut out = arena.take(x.rows(), x.cols());
        x.add_into(&down, &mut out);
        arena.give(down);
        Ok(out)
    }

    /// One pipeline stage (decoder layer `layer`) on the sparse path,
    /// `x: [T, d]` -> `[T, d]`.
    pub fn stage(
        &self,
        engine: &mut dyn ExecBackend,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
        path: ServePath,
    ) -> Result<Mat> {
        match path {
            ServePath::MlpOnly => self.mlp_stage(engine, layer, x),
            ServePath::FullDecoder => {
                let a = self.attn_stage(engine, layer, x, seqs)?;
                self.mlp_stage(engine, layer, &a)
            }
        }
    }

    /// Sparse forward through every decoder-layer stage in order.
    pub fn forward(
        &self,
        engine: &mut dyn ExecBackend,
        x: &Mat,
        seqs: &[(usize, usize)],
        path: ServePath,
    ) -> Result<Mat> {
        let mut cur = x.clone();
        for layer in 0..self.n_stages() {
            cur = self.stage(engine, layer, &cur, seqs, path)?;
        }
        Ok(cur)
    }

    /// An empty per-request KV store (contiguous layout) sized for this
    /// model — one per request, carried through every
    /// [`SparseModel::stage_cached`] call of that request's lifetime.
    /// Paged serving creates stores from a shared pool instead
    /// ([`SparseModel::new_kv_pool`] + [`KvPool::new_cache`]); the two
    /// layouts decode bit-identically.
    pub fn new_cache(&self) -> KvStore {
        KvStore::contiguous(self.cfg.n_layers, self.cfg.dim)
    }

    /// A shared paged-KV pool sized for this model: `n_pages` pages of
    /// `page_tokens` positions each, per decoder layer — the allocator
    /// behind `--kv-pages` paged serving.
    pub fn new_kv_pool(&self, n_pages: usize, page_tokens: usize) -> Arc<KvPool> {
        KvPool::new(n_pages, page_tokens, self.cfg.n_layers, self.cfg.dim)
    }

    /// Decoder layer `layer`'s attention sublayer on the **KV-cached**
    /// sparse path: each span's rows are the request's *new* tokens
    /// (whole prompt at prefill, one token per decode step), rotated at
    /// the absolute positions its cache records and attending over the
    /// whole cached sequence.  An empty cache makes this exactly the
    /// prefill of [`SparseModel::attn_stage`] — prefill and decode are
    /// one code path, not two.
    pub fn attn_stage_cached(
        &self,
        engine: &mut dyn ExecBackend,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
    ) -> Result<Mat> {
        check_seqs(seqs, x.rows())?;
        check_caches(seqs, caches, self.cfg.n_layers)?;
        let xn = rmsnorm(x, &self.attn_norms[layer], self.norm_eps);
        let q = self.layer(layer, LinearKind::Wq).forward(engine, &xn)?;
        let k = self.layer(layer, LinearKind::Wk).forward(engine, &xn)?;
        let v = self.layer(layer, LinearKind::Wv).forward(engine, &xn)?;
        let o = attend_spans_cached(
            &q,
            &k,
            &v,
            (self.cfg.n_heads, self.cfg.rope_theta),
            seqs,
            caches,
            layer,
        );
        let att = self.layer(layer, LinearKind::Wo).forward(engine, &o)?;
        Ok(x.add(&att))
    }

    /// [`SparseModel::attn_stage_cached`] on arena storage — the
    /// KV-cached attention sublayer with every intermediate recycled
    /// through `arena` (the caches themselves still grow by the step's
    /// new positions, which is state, not scratch).
    pub fn attn_stage_cached_scratch(
        &self,
        engine: &mut dyn ExecBackend,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
        arena: &mut StepArena,
    ) -> Result<Mat> {
        check_seqs(seqs, x.rows())?;
        check_caches(seqs, caches, self.cfg.n_layers)?;
        let xn = rmsnorm_scratch(x, &self.attn_norms[layer], self.norm_eps, arena);
        let q = self.layer(layer, LinearKind::Wq).forward_scratch(engine, &xn, arena)?;
        let k = self.layer(layer, LinearKind::Wk).forward_scratch(engine, &xn, arena)?;
        let v = self.layer(layer, LinearKind::Wv).forward_scratch(engine, &xn, arena)?;
        arena.give(xn);
        let o = attend_spans_cached_scratch(
            &q,
            &k,
            &v,
            (self.cfg.n_heads, self.cfg.rope_theta),
            seqs,
            caches,
            layer,
            arena,
        );
        arena.give(q);
        arena.give(k);
        arena.give(v);
        let att = self.layer(layer, LinearKind::Wo).forward_scratch(engine, &o, arena)?;
        arena.give(o);
        let mut out = arena.take(x.rows(), x.cols());
        x.add_into(&att, &mut out);
        arena.give(att);
        Ok(out)
    }

    /// One KV-cached pipeline stage: [`SparseModel::attn_stage_cached`]
    /// followed by the (position-independent) MLP sublayer.  On
    /// [`ServePath::MlpOnly`] the caches are validated but untouched —
    /// the stage has no attention state.
    pub fn stage_cached(
        &self,
        engine: &mut dyn ExecBackend,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
        path: ServePath,
    ) -> Result<Mat> {
        match path {
            ServePath::MlpOnly => {
                check_caches(seqs, caches, self.cfg.n_layers)?;
                self.mlp_stage(engine, layer, x)
            }
            ServePath::FullDecoder => {
                let a = self.attn_stage_cached(engine, layer, x, seqs, caches)?;
                self.mlp_stage(engine, layer, &a)
            }
        }
    }

    /// [`SparseModel::stage_cached`] on arena storage — the decode hot
    /// path's per-stage entry point.  Bit-identical to `stage_cached`
    /// (same kernels, same op order; only where the bytes live changes);
    /// the caller gives the returned matrix back to `arena` once
    /// consumed and calls [`StepArena::step`] at each batch-step
    /// boundary.
    pub fn stage_cached_scratch(
        &self,
        engine: &mut dyn ExecBackend,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
        path: ServePath,
        arena: &mut StepArena,
    ) -> Result<Mat> {
        match path {
            ServePath::MlpOnly => {
                check_caches(seqs, caches, self.cfg.n_layers)?;
                self.mlp_stage_scratch(engine, layer, x, arena)
            }
            ServePath::FullDecoder => {
                let a = self.attn_stage_cached_scratch(engine, layer, x, seqs, caches, arena)?;
                let out = self.mlp_stage_scratch(engine, layer, &a, arena)?;
                arena.give(a);
                Ok(out)
            }
        }
    }

    /// KV-cached sparse forward through every decoder-layer stage: the
    /// incremental counterpart of [`SparseModel::forward`].  Feeding a
    /// sequence in chunks (prefill, then token-by-token decode) produces
    /// the same outputs as re-forwarding the whole sequence — the
    /// decode-parity tests pin this at 2:4 and 4:8 on both serve paths.
    pub fn forward_cached(
        &self,
        engine: &mut dyn ExecBackend,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
        path: ServePath,
    ) -> Result<Mat> {
        let mut cur = x.clone();
        for layer in 0..self.n_stages() {
            cur = self.stage_cached(engine, layer, &cur, seqs, caches, path)?;
        }
        Ok(cur)
    }

    /// [`SparseModel::forward_cached`] on arena storage: the whole
    /// decoder stack runs on recycled buffers, so a steady-state decode
    /// step — after one warmup step has sized the pools — performs zero
    /// heap allocations inside this call (the `decode_allocs_per_step`
    /// bench gate measures exactly this region).  The caller gives the
    /// returned matrix back and calls [`StepArena::step`] per batch
    /// step.  Bit-identical to `forward_cached`, pinned by
    /// `forward_cached_scratch_is_bit_identical`.
    pub fn forward_cached_scratch(
        &self,
        engine: &mut dyn ExecBackend,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
        path: ServePath,
        arena: &mut StepArena,
    ) -> Result<Mat> {
        let mut cur = arena.take(x.rows(), x.cols());
        cur.data_mut().copy_from_slice(x.data());
        for layer in 0..self.n_stages() {
            let next = self.stage_cached_scratch(engine, layer, &cur, seqs, caches, path, arena)?;
            arena.give(cur);
            cur = next;
        }
        Ok(cur)
    }

    /// Embed token ids into `[T, d]` activation rows (the decode path's
    /// entry point; embeddings are dense — never pruned).
    pub fn embed(&self, tokens: &[u32]) -> Result<Mat> {
        embed_rows(&self.tok_embed, self.cfg.vocab, tokens)
    }

    /// [`SparseModel::embed`] into arena storage — same lookup copies,
    /// recycled buffer, so the decode loop's next-token embed stays off
    /// the allocator.
    pub fn embed_scratch(&self, tokens: &[u32], arena: &mut StepArena) -> Result<Mat> {
        anyhow::ensure!(!tokens.is_empty(), "cannot embed an empty token sequence");
        let mut x = arena.take(tokens.len(), self.tok_embed.cols());
        for (r, &tok) in tokens.iter().enumerate() {
            anyhow::ensure!(
                (tok as usize) < self.cfg.vocab,
                "token {tok} outside vocab {}",
                self.cfg.vocab
            );
            x.row_mut(r).copy_from_slice(self.tok_embed.row(tok as usize));
        }
        Ok(x)
    }

    /// LM-head logits `[T, vocab]` for decoder-stack outputs `h: [T, d]`
    /// (final RMSNorm + dense head matmul — the decode path's exit
    /// point).
    pub fn logits(&self, h: &Mat) -> Mat {
        head_logits(h, &self.final_norm, self.norm_eps, &self.lm_head)
    }

    /// KV-cached generation: prefill `prompt` once, then decode one
    /// token per step through [`SparseModel::forward_cached`], picking
    /// each token with `sampler` ([`Sampler::Greedy`] for argmax,
    /// [`Sampler::TopK`] for seeded stochastic decoding), stopping
    /// after `max_new_tokens` or at `eos` (which is included in the
    /// output when hit).  This is the single-request reference the
    /// continuous-batching decode loop (`Server::run_decode_streaming`)
    /// is bit-compared against: same kernels, same per-span attention,
    /// same one-draw-per-step RNG discipline, so batching must not
    /// change a request's tokens.
    pub fn generate(
        &self,
        engine: &mut dyn ExecBackend,
        prompt: &[u32],
        max_new_tokens: usize,
        eos: Option<u32>,
        path: ServePath,
        sampler: Sampler,
    ) -> Result<Vec<u32>> {
        anyhow::ensure!(max_new_tokens > 0, "max_new_tokens must be >= 1");
        if let Err(e) = sampler.validate() {
            anyhow::bail!("invalid sampler: {e}");
        }
        let mut rng = sampler.rng();
        let mut caches = vec![self.new_cache()];
        let mut x = self.embed(prompt)?;
        let mut out = Vec::with_capacity(max_new_tokens);
        loop {
            let rows = x.rows();
            let h = self.forward_cached(engine, &x, &[(0, rows)], &mut caches, path)?;
            let last = h.row_block(rows - 1, rows);
            let tok = sampler.sample(self.logits(&last).row(0), &mut rng);
            out.push(tok);
            if out.len() >= max_new_tokens || eos == Some(tok) {
                return Ok(out);
            }
            x = self.embed(&[tok])?;
        }
    }

    /// Host dense-masked reference of [`SparseModel::stage`] — same math
    /// and same host glue, per-call-materialized dense weights, no
    /// backend.
    pub fn dense_stage(
        &self,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
        path: ServePath,
    ) -> Mat {
        DenseStage {
            n_heads: self.cfg.n_heads,
            rope_theta: self.cfg.rope_theta,
            attn_norm: &self.attn_norms[layer],
            mlp_norm: &self.mlp_norms[layer],
            eps: self.norm_eps,
        }
        .run(x, seqs, path, &|kind, x| self.layer(layer, kind).forward_dense(x))
    }

    /// Host dense-masked reference of [`SparseModel::forward`].
    pub fn dense_forward(&self, x: &Mat, seqs: &[(usize, usize)], path: ServePath) -> Mat {
        let mut cur = x.clone();
        for layer in 0..self.n_stages() {
            cur = self.dense_stage(layer, &cur, seqs, path);
        }
        cur
    }

    /// Every artifact name this model serves through on `path` — for
    /// checking a backend's coverage up front.
    pub fn required_artifacts(&self, path: ServePath) -> Vec<String> {
        let mut names = Vec::new();
        for layer in self.layers.values() {
            if path.uses(layer.lin.kind) {
                names.push(layer.artifact.clone());
            }
        }
        names.sort();
        names.dedup();
        names
    }

    /// The parameters served through artifact `name` (for error messages
    /// that point at the offending layers, not just the artifact).
    pub fn artifact_users(&self, name: &str) -> String {
        let mut users = Vec::new();
        for layer in self.layers.values() {
            if layer.artifact == name {
                users.push(layer.lin.param_name());
            }
        }
        users.sort();
        users.join(", ")
    }
}

/// Fully materialized dense-masked model: every pruned linear
/// decompressed once to a dense `[C_out, C_in]` weight in ORIGINAL
/// channel order, driven by the same host glue as the sparse path.
///
/// This is the *benchmark baseline* (what serving would cost without the
/// compressed N:M path) and a fast parity reference; per-request serving
/// never materializes it.  [`SparseLayer::forward_dense`] remains the
/// memory-honest verification path.
pub struct DenseModel {
    cfg: ModelConfig,
    weights: HashMap<LinearRef, Mat>,
    attn_norms: Vec<Mat>,
    mlp_norms: Vec<Mat>,
    norm_eps: f32,
    tok_embed: Mat,
    final_norm: Mat,
    lm_head: Mat,
}

impl DenseModel {
    /// Decompress every cached linear of `sm` once.
    pub fn from_sparse(sm: &SparseModel) -> DenseModel {
        let weights = sm.layers.iter().map(|(&lin, l)| (lin, l.dense_weight())).collect();
        DenseModel {
            cfg: sm.cfg.clone(),
            weights,
            attn_norms: sm.attn_norms.clone(),
            mlp_norms: sm.mlp_norms.clone(),
            norm_eps: sm.norm_eps,
            tok_embed: sm.tok_embed.clone(),
            final_norm: sm.final_norm.clone(),
            lm_head: sm.lm_head.clone(),
        }
    }

    pub fn n_stages(&self) -> usize {
        self.cfg.n_layers
    }

    pub fn width(&self) -> usize {
        self.cfg.dim
    }

    fn weight(&self, layer: usize, kind: LinearKind) -> &Mat {
        &self.weights[&LinearRef { layer, kind }]
    }

    /// One decoder-layer stage on plain dense matmuls (same glue as the
    /// sparse path).
    pub fn stage(&self, layer: usize, x: &Mat, seqs: &[(usize, usize)], path: ServePath) -> Mat {
        DenseStage {
            n_heads: self.cfg.n_heads,
            rope_theta: self.cfg.rope_theta,
            attn_norm: &self.attn_norms[layer],
            mlp_norm: &self.mlp_norms[layer],
            eps: self.norm_eps,
        }
        .run(x, seqs, path, &|kind, x| x.matmul_bt(self.weight(layer, kind)))
    }

    /// Dense forward through every decoder-layer stage in order.
    pub fn forward(&self, x: &Mat, seqs: &[(usize, usize)], path: ServePath) -> Mat {
        let mut cur = x.clone();
        for layer in 0..self.n_stages() {
            cur = self.stage(layer, &cur, seqs, path);
        }
        cur
    }

    /// An empty per-request KV store (contiguous layout) sized for this
    /// model.
    pub fn new_cache(&self) -> KvStore {
        KvStore::contiguous(self.cfg.n_layers, self.cfg.dim)
    }

    /// KV-cached decoder-layer stage on plain dense matmuls — the decode
    /// baseline the bench gate compares the sparse decode path against
    /// (same cached-attention glue, dense weights).
    pub fn stage_cached(
        &self,
        layer: usize,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
        path: ServePath,
    ) -> Mat {
        check_caches(seqs, caches, self.cfg.n_layers).expect("bad KV caches");
        DenseStage {
            n_heads: self.cfg.n_heads,
            rope_theta: self.cfg.rope_theta,
            attn_norm: &self.attn_norms[layer],
            mlp_norm: &self.mlp_norms[layer],
            eps: self.norm_eps,
        }
        .run_cached(layer, x, seqs, caches, path, &|kind, x| {
            x.matmul_bt(self.weight(layer, kind))
        })
    }

    /// KV-cached dense forward through every decoder-layer stage.
    pub fn forward_cached(
        &self,
        x: &Mat,
        seqs: &[(usize, usize)],
        caches: &mut [KvStore],
        path: ServePath,
    ) -> Mat {
        let mut cur = x.clone();
        for layer in 0..self.n_stages() {
            cur = self.stage_cached(layer, &cur, seqs, caches, path);
        }
        cur
    }

    /// Embed token ids into `[T, d]` activation rows.
    pub fn embed(&self, tokens: &[u32]) -> Result<Mat> {
        embed_rows(&self.tok_embed, self.cfg.vocab, tokens)
    }

    /// LM-head logits `[T, vocab]` for decoder-stack outputs `h: [T, d]`.
    pub fn logits(&self, h: &Mat) -> Mat {
        head_logits(h, &self.final_norm, self.norm_eps, &self.lm_head)
    }

    /// Capture everything serving needs into a [`crate::snapshot::
    /// Snapshot`]: the per-linear compressed payloads exactly as the
    /// cached artifact tensors hold them, the dense statics, config,
    /// pattern, and recipe descriptor.
    ///
    /// Layers are emitted in [`ModelConfig::prunable_linears`] order (not
    /// map order), so the same model always snapshots to the same bytes.
    pub fn to_snapshot(&self) -> crate::snapshot::Snapshot {
        let mut layers = Vec::with_capacity(self.layers.len());
        for lin in self.cfg.prunable_linears() {
            let l = &self.layers[&lin];
            layers.push(crate::snapshot::SnapshotLayer {
                name: lin.param_name(),
                c_out: l.c_out,
                c_in: l.c_in,
                vals: l.vals.as_f32().expect("vals are f32").to_vec(),
                idx: l.idx.as_i32().expect("idx is i32").iter().map(|&v| v as u32).collect(),
                src_of: l.src_of.iter().map(|&v| v as u32).collect(),
            });
        }
        let mut statics = vec![
            ("tok_embed".to_string(), self.tok_embed.clone()),
            ("final_norm".to_string(), self.final_norm.clone()),
            ("lm_head".to_string(), self.lm_head.clone()),
        ];
        for l in 0..self.cfg.n_layers {
            statics.push((format!("layers.{l}.attn_norm"), self.attn_norms[l].clone()));
            statics.push((format!("layers.{l}.mlp_norm"), self.mlp_norms[l].clone()));
        }
        crate::snapshot::Snapshot {
            cfg: self.cfg.clone(),
            nm: self.nm,
            recipe_name: self.recipe_name.clone(),
            recipe_json: self.recipe_json.to_string(),
            statics,
            layers,
        }
    }

    /// Rebuild a servable model from a decoded snapshot, validating the
    /// payload semantically: every compressed linear replays through
    /// [`Compressed::from_parts`] (full N:M group-structure check),
    /// `src_of` must be a permutation, and every shape must agree with
    /// the snapshot's own [`ModelConfig`].  Container-level integrity
    /// (magic/version/checksum) has already been enforced by
    /// [`crate::snapshot::Snapshot::decode`].
    ///
    /// The rebuilt model caches byte-identical artifact tensors to the
    /// freshly pruned one it was dumped from, so serving output is
    /// bit-identical on both [`ServePath`]s.
    pub fn from_snapshot(snap: &crate::snapshot::Snapshot) -> Result<SparseModel> {
        let cfg = snap.cfg.clone();
        anyhow::ensure!(
            cfg.vocab > 0 && cfg.dim > 0 && cfg.n_layers > 0 && cfg.n_heads > 0 && cfg.ffn > 0,
            "snapshot config has a zero dimension: {cfg:?}"
        );
        anyhow::ensure!(
            cfg.dim % cfg.n_heads == 0,
            "snapshot config: dim {} not divisible by n_heads {}",
            cfg.dim,
            cfg.n_heads
        );
        let lins = cfg.prunable_linears();
        anyhow::ensure!(
            snap.layers.len() == lins.len(),
            "snapshot has {} compressed linears, config {} needs {}",
            snap.layers.len(),
            cfg.name,
            lins.len()
        );
        let by_name: HashMap<&str, &crate::snapshot::SnapshotLayer> =
            snap.layers.iter().map(|l| (l.name.as_str(), l)).collect();
        let instance = MODEL_IDS.fetch_add(1, Ordering::Relaxed);
        let mut layers = HashMap::new();
        for lin in &lins {
            let name = lin.param_name();
            let sl = by_name
                .get(name.as_str())
                .ok_or_else(|| anyhow!("snapshot is missing compressed linear {name}"))?;
            let want = cfg.param_shape(&name);
            anyhow::ensure!(
                vec![sl.c_out, sl.c_in] == want,
                "snapshot linear {name} is [{}, {}], config wants {want:?}",
                sl.c_out,
                sl.c_in
            );
            let comp =
                Compressed::from_parts(snap.nm, sl.c_out, sl.c_in, sl.vals.clone(), sl.idx.clone())
                    .map_err(|e| anyhow!("snapshot linear {name}: {e:#}"))?;
            let src_of = validate_permutation(&name, &sl.src_of, sl.c_in)?;
            layers.insert(*lin, SparseLayer::from_compressed(instance, *lin, &comp, src_of)?);
        }
        let by_name: HashMap<&str, &Mat> =
            snap.statics.iter().map(|(n, m)| (n.as_str(), m)).collect();
        let fetch = |name: String, rows: usize, cols: usize| -> Result<Mat> {
            let mat = *by_name
                .get(name.as_str())
                .ok_or_else(|| anyhow!("snapshot is missing static {name}"))?;
            anyhow::ensure!(
                mat.shape() == (rows, cols),
                "snapshot static {name} is {:?}, config wants ({rows}, {cols})",
                mat.shape()
            );
            Ok(mat.clone())
        };
        let tok_embed = fetch("tok_embed".to_string(), cfg.vocab, cfg.dim)?;
        let final_norm = fetch("final_norm".to_string(), 1, cfg.dim)?;
        let lm_head = fetch("lm_head".to_string(), cfg.vocab, cfg.dim)?;
        let attn_norms = (0..cfg.n_layers)
            .map(|l| fetch(format!("layers.{l}.attn_norm"), 1, cfg.dim))
            .collect::<Result<Vec<_>>>()?;
        let mlp_norms = (0..cfg.n_layers)
            .map(|l| fetch(format!("layers.{l}.mlp_norm"), 1, cfg.dim))
            .collect::<Result<Vec<_>>>()?;
        let recipe_json = Json::parse(&snap.recipe_json)
            .map_err(|e| anyhow!("snapshot recipe JSON does not parse: {e:?}"))?;
        let norm_eps = cfg.norm_eps;
        Ok(SparseModel {
            cfg,
            nm: snap.nm,
            layers,
            attn_norms,
            mlp_norms,
            norm_eps,
            tok_embed,
            final_norm,
            lm_head,
            recipe_name: snap.recipe_name.clone(),
            recipe_json,
        })
    }
}

/// Check that `src_of` is a permutation of `0..c_in` and widen to the
/// host-side `usize` form (snapshot payloads are untrusted input).
fn validate_permutation(name: &str, src_of: &[u32], c_in: usize) -> Result<Vec<usize>> {
    anyhow::ensure!(
        src_of.len() == c_in,
        "snapshot linear {name}: src_of has {} entries, expected {c_in}",
        src_of.len()
    );
    let mut seen = vec![false; c_in];
    for &v in src_of {
        let v = v as usize;
        anyhow::ensure!(v < c_in, "snapshot linear {name}: src_of entry {v} out of range");
        anyhow::ensure!(!seen[v], "snapshot linear {name}: src_of repeats channel {v}");
        seen[v] = true;
    }
    Ok(src_of.iter().map(|&v| v as usize).collect())
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::coordinator::{prune_with_recipe, PipelineCfg};
    use crate::data::{Corpus, CorpusKind};
    use crate::lcp::LcpCfg;
    use crate::model::synth_trained_params;
    use crate::pruning::Metric;
    use crate::recipe::PruneRecipe;
    use crate::runtime::{NativeCfg, NativeEngine};
    use crate::util::testkit::assert_close;

    pub(crate) fn sparse_model_named(name: &str, nm: NmConfig) -> SparseModel {
        let cfg = ModelConfig::by_name(name).unwrap();
        let ps = synth_trained_params(&cfg, 11);
        let corpus = Corpus::build(CorpusKind::C4Like, 5);
        let pc = PipelineCfg {
            nm,
            calib_seqs: 2,
            calib_len: 32,
            calib_rows: 32,
            lcp: LcpCfg { block: 16, steps: 6, lr: 0.1, nm, ..Default::default() },
            ..Default::default()
        };
        let pruned = prune_with_recipe(&ps, &corpus, &PruneRecipe::oneshot(Metric::Wanda, nm), &pc);
        SparseModel::from_pruned(&pruned).unwrap()
    }

    pub(crate) fn sparse_model_with(nm: NmConfig) -> SparseModel {
        sparse_model_named("tiny-s", nm)
    }

    pub(crate) fn tiny_sparse_model() -> SparseModel {
        sparse_model_with(NmConfig::PAT_2_4)
    }

    /// The whole batch as one sequence span.
    pub(crate) fn whole(x: &Mat) -> Vec<(usize, usize)> {
        vec![(0, x.rows())]
    }

    #[test]
    fn compresses_every_prunable_linear() {
        let sm = tiny_sparse_model();
        assert_eq!(sm.layers.len(), sm.cfg().prunable_linears().len());
        // 2:4 layers: values alone are half the dense bytes; metadata adds
        // 1/8 more => strictly between 0.5x and 0.65x dense.
        assert!(sm.storage_bytes() > sm.dense_bytes() / 2);
        assert!(sm.storage_bytes() <= sm.dense_bytes() * 65 / 100);
        assert_eq!(sm.n_stages(), sm.cfg().n_layers);
    }

    #[test]
    fn dense_model_is_rejected() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 11);
        let corpus = Corpus::build(CorpusKind::C4Like, 5);
        let pruned = prune_with_recipe(
            &ps,
            &corpus,
            &PruneRecipe::dense(NmConfig::PAT_2_4),
            &PipelineCfg::default(),
        );
        assert!(SparseModel::from_pruned(&pruned).is_err());
    }

    #[test]
    fn layer_forward_matches_dense_reference() {
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let mut rng = Pcg32::seeded(3);
        for lin in sm.cfg().prunable_linears() {
            let layer = sm.linear(lin);
            let (_, c_in) = layer.shape();
            let x = Mat::randn(5, c_in, 1.0, &mut rng);
            let got = layer.forward(&mut engine, &x).unwrap();
            let want = layer.forward_dense(&x);
            assert_close(got.data(), want.data(), 1e-4).unwrap();
            // The statics were bound on first use: the layer is resident
            // on the backend under its scoped key.
            assert!(engine.is_bound(layer.bind_key()), "{}", layer.bind_key());
        }
    }

    #[test]
    fn bind_keys_are_unique_per_model_instance() {
        // A backend shared across two models (e.g. after a re-prune) must
        // never serve the first model's resident weights for the second.
        let a = tiny_sparse_model();
        let b = tiny_sparse_model();
        let lin = a.cfg().prunable_linears()[0];
        assert_ne!(a.linear(lin).bind_key(), b.linear(lin).bind_key());
        let mut engine = NativeEngine::default();
        let mut rng = Pcg32::seeded(1);
        let x = Mat::randn(2, a.linear(lin).shape().1, 1.0, &mut rng);
        a.linear(lin).forward(&mut engine, &x).unwrap();
        b.linear(lin).forward(&mut engine, &x).unwrap();
        assert!(engine.is_bound(a.linear(lin).bind_key()));
        assert!(engine.is_bound(b.linear(lin).bind_key()));
    }

    #[test]
    fn dense_weight_folds_the_permutation_back() {
        let sm = tiny_sparse_model();
        let mut rng = Pcg32::seeded(12);
        for lin in sm.cfg().prunable_linears() {
            let layer = sm.linear(lin);
            let (_, c_in) = layer.shape();
            let x = Mat::randn(3, c_in, 1.0, &mut rng);
            // x @ W_orig^T must equal the permute-then-stored-matmul path.
            let via_orig = x.matmul_bt(&layer.dense_weight());
            let via_perm = layer.forward_dense(&x);
            assert_close(via_orig.data(), via_perm.data(), 1e-4).unwrap();
        }
    }

    #[test]
    fn prop_end_to_end_forward_matches_dense_masked_forward() {
        crate::util::testkit::check_n("serve-parity", 6, |rng| {
            let sm = tiny_sparse_model();
            let threads = 1 + rng.below_usize(3);
            let mut engine = NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() });
            let t = 1 + rng.below_usize(6);
            let x = Mat::randn(t, sm.width(), 1.0, rng);
            let got = sm
                .forward(&mut engine, &x, &whole(&x), ServePath::MlpOnly)
                .map_err(|e| format!("{e:#}"))?;
            let want = sm.dense_forward(&x, &whole(&x), ServePath::MlpOnly);
            assert_close(got.data(), want.data(), 1e-3)
                .map_err(|e| format!("threads={threads} t={t}: {e}"))
        });
    }

    #[test]
    fn full_decoder_parity_at_2_4_and_4_8() {
        // Tentpole acceptance: attention + MLP through sparse_fwd match
        // the dense-masked reference within 1e-3, at both N:M patterns,
        // including multi-span (coalesced-batch) attention.
        for nm in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
            let sm = sparse_model_with(nm);
            let mut engine = NativeEngine::new(NativeCfg { nm, ..NativeCfg::default() });
            let mut rng = Pcg32::seeded(9);
            let x = Mat::randn(9, sm.width(), 1.0, &mut rng);
            let seqs = [(0usize, 4usize), (4, 9)];
            let got = sm.forward(&mut engine, &x, &seqs, ServePath::FullDecoder).unwrap();
            let want = sm.dense_forward(&x, &seqs, ServePath::FullDecoder);
            assert_close(got.data(), want.data(), 1e-3)
                .unwrap_or_else(|e| panic!("{}: {e}", nm.name()));
            // The materialized DenseModel baseline agrees too.
            let dm = DenseModel::from_sparse(&sm);
            let base = dm.forward(&x, &seqs, ServePath::FullDecoder);
            assert_close(got.data(), base.data(), 1e-3)
                .unwrap_or_else(|e| panic!("{} dense baseline: {e}", nm.name()));
        }
    }

    #[test]
    fn snapshot_round_trip_is_bit_identical_on_both_paths() {
        // Tentpole acceptance: a model rebuilt from its snapshot serves
        // BIT-identical outputs to the freshly pruned original — logits
        // on both serve paths and greedy generation — and preserves the
        // recipe identity that gets stamped into bench artifacts.
        for nm in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
            let fresh = sparse_model_with(nm);
            let snap = fresh.to_snapshot();
            let bytes = snap.encode();
            let loaded = SparseModel::from_snapshot(
                &crate::snapshot::Snapshot::decode(&bytes).expect("own bytes decode"),
            )
            .expect("rebuild from snapshot");
            assert_eq!(loaded.recipe_name(), fresh.recipe_name());
            assert_eq!(
                loaded.recipe_json().to_string(),
                fresh.recipe_json().to_string()
            );
            assert_eq!(loaded.nm(), nm);
            assert_eq!(loaded.storage_bytes(), fresh.storage_bytes());
            let mut ea = NativeEngine::new(NativeCfg { nm, ..NativeCfg::default() });
            let mut eb = NativeEngine::new(NativeCfg { nm, ..NativeCfg::default() });
            let mut rng = Pcg32::seeded(77);
            let toks: Vec<u32> = (0..7).map(|_| rng.below(fresh.cfg().vocab as u32)).collect();
            for path in [ServePath::MlpOnly, ServePath::FullDecoder] {
                let x = fresh.embed(&toks).unwrap();
                let ha = fresh.forward(&mut ea, &x, &whole(&x), path).unwrap();
                let hb = loaded.forward(&mut eb, &x, &whole(&x), path).unwrap();
                assert_eq!(ha.data(), hb.data(), "{} {}: logits drifted", nm.name(), path.name());
                assert_eq!(
                    fresh.logits(&ha).data(),
                    loaded.logits(&hb).data(),
                    "{} {}: head logits drifted",
                    nm.name(),
                    path.name()
                );
                let ga = fresh
                    .generate(&mut ea, &toks[..4], 5, None, path, Sampler::Greedy)
                    .unwrap();
                let gb = loaded
                    .generate(&mut eb, &toks[..4], 5, None, path, Sampler::Greedy)
                    .unwrap();
                assert_eq!(ga, gb, "{} {}: generated tokens drifted", nm.name(), path.name());
            }
        }
    }

    #[test]
    fn snapshot_rejects_config_and_payload_drift() {
        let sm = tiny_sparse_model();
        // A layer claiming the wrong shape for its name.
        let mut snap = sm.to_snapshot();
        snap.layers[0].name = "layers.0.w_gate".to_string();
        snap.layers[4].name = "layers.0.wq".to_string(); // keep count/name-set valid
        assert!(SparseModel::from_snapshot(&snap).is_err());
        // A broken permutation (repeated channel).
        let mut snap = sm.to_snapshot();
        snap.layers[0].src_of[0] = snap.layers[0].src_of[1];
        let err = SparseModel::from_snapshot(&snap).expect_err("must reject");
        assert!(format!("{err:#}").contains("src_of"), "{err:#}");
        // A missing static.
        let mut snap = sm.to_snapshot();
        snap.statics.retain(|(n, _)| n != "final_norm");
        assert!(SparseModel::from_snapshot(&snap).is_err());
        // Recipe JSON that does not parse.
        let mut snap = sm.to_snapshot();
        snap.recipe_json = "{not json".to_string();
        assert!(SparseModel::from_snapshot(&snap).is_err());
    }

    #[test]
    fn decode_parity_tiny_l_at_2_4_and_4_8() {
        // Satellite acceptance: incremental (KV-cached) decode is
        // bit-close to re-forwarding the full sequence, on the tiny-l
        // config, at both N:M patterns, on both serve paths.  Prefill a
        // prompt, then decode token rows one at a time; after each step
        // the incremental output row must match the corresponding row of
        // a full-sequence forward over everything fed so far.
        for nm in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
            let sm = sparse_model_named("tiny-l", nm);
            let mut engine = NativeEngine::new(NativeCfg { nm, ..NativeCfg::default() });
            let mut rng = Pcg32::seeded(31);
            for path in [ServePath::MlpOnly, ServePath::FullDecoder] {
                let toks: Vec<u32> =
                    (0..9).map(|_| rng.below(sm.cfg().vocab as u32)).collect();
                let prompt = 5usize;
                let mut caches = vec![sm.new_cache()];
                let x = sm.embed(&toks[..prompt]).unwrap();
                let inc = sm
                    .forward_cached(&mut engine, &x, &[(0, prompt)], &mut caches, path)
                    .unwrap();
                let full =
                    sm.forward(&mut engine, &x, &[(0, prompt)], path).unwrap();
                assert_close(inc.data(), full.data(), 1e-4)
                    .unwrap_or_else(|e| panic!("{} {} prefill: {e}", nm.name(), path.name()));
                for t in prompt..toks.len() {
                    let xt = sm.embed(&toks[t..t + 1]).unwrap();
                    let step = sm
                        .forward_cached(&mut engine, &xt, &[(0, 1)], &mut caches, path)
                        .unwrap();
                    // Full re-forward over everything fed so far.
                    let xall = sm.embed(&toks[..t + 1]).unwrap();
                    let fall =
                        sm.forward(&mut engine, &xall, &[(0, t + 1)], path).unwrap();
                    assert_close(step.row(0), fall.row(t), 1e-4).unwrap_or_else(|e| {
                        panic!("{} {} decode step {t}: {e}", nm.name(), path.name())
                    });
                }
                if path == ServePath::FullDecoder {
                    assert_eq!(caches[0].len(), toks.len());
                    assert_eq!(
                        caches[0].bytes(),
                        2 * sm.cfg().n_layers * toks.len() * sm.width() * 4
                    );
                } else {
                    assert!(caches[0].is_empty(), "MLP-only must not touch the cache");
                }
            }
        }
    }

    #[test]
    fn forward_cached_scratch_is_bit_identical() {
        // The arena-backed decode hot path must reproduce the allocating
        // path byte for byte — both N:M patterns, both serve paths,
        // prefill and decode — and a second pass over the same workload
        // (pools sized by the first) must not grow the arena at all.
        for nm in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
            let sm = sparse_model_with(nm);
            let mut engine = NativeEngine::new(NativeCfg { nm, ..NativeCfg::default() });
            for path in [ServePath::MlpOnly, ServePath::FullDecoder] {
                let mut rng = Pcg32::seeded(37);
                let toks: Vec<u32> =
                    (0..8).map(|_| rng.below(sm.cfg().vocab as u32)).collect();
                let mut arena = StepArena::new();
                for pass in 0..2 {
                    let grows_at_start = arena.grow_events();
                    let mut c_ref = vec![sm.new_cache()];
                    let mut c_scr = vec![sm.new_cache()];
                    let x = sm.embed(&toks[..4]).unwrap();
                    let want = sm
                        .forward_cached(&mut engine, &x, &[(0, 4)], &mut c_ref, path)
                        .unwrap();
                    let got = sm
                        .forward_cached_scratch(
                            &mut engine,
                            &x,
                            &[(0, 4)],
                            &mut c_scr,
                            path,
                            &mut arena,
                        )
                        .unwrap();
                    assert_eq!(got.data(), want.data(), "{} {} prefill", nm.name(), path.name());
                    arena.give(got);
                    arena.step();
                    for t in 4..toks.len() {
                        let xt = sm.embed(&toks[t..t + 1]).unwrap();
                        let want = sm
                            .forward_cached(&mut engine, &xt, &[(0, 1)], &mut c_ref, path)
                            .unwrap();
                        let got = sm
                            .forward_cached_scratch(
                                &mut engine,
                                &xt,
                                &[(0, 1)],
                                &mut c_scr,
                                path,
                                &mut arena,
                            )
                            .unwrap();
                        assert_eq!(
                            got.data(),
                            want.data(),
                            "{} {} decode step {t}",
                            nm.name(),
                            path.name()
                        );
                        arena.give(got);
                        arena.step();
                    }
                    if pass == 1 {
                        assert_eq!(
                            arena.grow_events(),
                            grows_at_start,
                            "{} {}: warmed-up pass must not grow the arena",
                            nm.name(),
                            path.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mixed_prefill_decode_batch_is_request_local() {
        // One batch coalescing a prefill span (fresh cache) with a decode
        // span (warm cache) must give each request exactly what it would
        // get served alone — the continuous batcher's correctness core.
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let mut rng = Pcg32::seeded(33);
        let ta: Vec<u32> = (0..4).map(|_| rng.below(256)).collect();
        let tb: Vec<u32> = (0..3).map(|_| rng.below(256)).collect();

        // Request A alone: prefill 3, decode 1.
        let mut ca = vec![sm.new_cache()];
        let xa = sm.embed(&ta[..3]).unwrap();
        sm.forward_cached(&mut engine, &xa, &[(0, 3)], &mut ca, ServePath::FullDecoder)
            .unwrap();
        let xa1 = sm.embed(&ta[3..]).unwrap();
        let alone = sm
            .forward_cached(&mut engine, &xa1, &[(0, 1)], &mut ca, ServePath::FullDecoder)
            .unwrap();

        // Same decode step for A, coalesced with B's prefill: A's decode
        // row first (1 row, warm cache), then B's prefill span (3 rows,
        // fresh cache).
        let mut ca2 = vec![sm.new_cache()];
        sm.forward_cached(&mut engine, &xa, &[(0, 3)], &mut ca2, ServePath::FullDecoder)
            .unwrap();
        let xb = sm.embed(&tb).unwrap();
        let mut stacked = Mat::zeros(4, sm.width());
        stacked.row_mut(0).copy_from_slice(xa1.row(0));
        for r in 0..3 {
            stacked.row_mut(1 + r).copy_from_slice(xb.row(r));
        }
        let mut caches = vec![ca2.pop().unwrap(), sm.new_cache()];
        let mixed = sm
            .forward_cached(
                &mut engine,
                &stacked,
                &[(0, 1), (1, 4)],
                &mut caches,
                ServePath::FullDecoder,
            )
            .unwrap();
        // Same kernels on the same rows => bit-identical.
        assert_eq!(&mixed.data()[..sm.width()], alone.data());
        // B's span equals B served alone (prefill).
        let mut cb = vec![sm.new_cache()];
        let b_alone = sm
            .forward_cached(&mut engine, &xb, &[(0, 3)], &mut cb, ServePath::FullDecoder)
            .unwrap();
        assert_eq!(&mixed.data()[sm.width()..], b_alone.data());
    }

    #[test]
    fn generate_greedy_matches_full_recompute_and_stops() {
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let prompt: Vec<u32> = vec![5, 250, 17, 99];
        let got = sm
            .generate(&mut engine, &prompt, 6, None, ServePath::FullDecoder, Sampler::Greedy)
            .unwrap();
        assert_eq!(got.len(), 6);
        // Reference: greedy loop that re-forwards the whole sequence per
        // step (no KV cache) — same kernels, so argmax must agree.
        let mut toks = prompt.clone();
        let mut want = Vec::new();
        for _ in 0..6 {
            let x = sm.embed(&toks).unwrap();
            let h = sm
                .forward(&mut engine, &x, &[(0, x.rows())], ServePath::FullDecoder)
                .unwrap();
            let last = h.row_block(h.rows() - 1, h.rows());
            let tok = greedy_token(sm.logits(&last).row(0));
            want.push(tok);
            toks.push(tok);
        }
        assert_eq!(got, want);
        // EOS cuts generation short and is included in the output.
        let eos = got[1];
        let stopped = sm
            .generate(&mut engine, &prompt, 6, Some(eos), ServePath::FullDecoder, Sampler::Greedy)
            .unwrap();
        let cut = got.iter().position(|&t| t == eos).expect("eos came from got");
        assert_eq!(stopped, got[..=cut].to_vec());
        // Degenerate arguments are rejected.
        assert!(sm
            .generate(&mut engine, &prompt, 0, None, ServePath::FullDecoder, Sampler::Greedy)
            .is_err());
        assert!(sm
            .generate(
                &mut engine,
                &prompt,
                2,
                None,
                ServePath::FullDecoder,
                Sampler::TopK { k: 0, temperature: 1.0, seed: 1 },
            )
            .is_err());
        assert!(sm.embed(&[]).is_err());
        assert!(sm.embed(&[sm.cfg().vocab as u32]).is_err());
    }

    #[test]
    fn topk_sampling_is_seed_deterministic_and_k1_is_greedy() {
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let prompt: Vec<u32> = vec![12, 7, 200];
        let topk = Sampler::TopK { k: 4, temperature: 0.8, seed: 99 };
        let a = sm
            .generate(&mut engine, &prompt, 6, None, ServePath::FullDecoder, topk)
            .unwrap();
        let b = sm
            .generate(&mut engine, &prompt, 6, None, ServePath::FullDecoder, topk)
            .unwrap();
        // Same seed, same kernels => the stochastic trajectory is
        // reproducible bit for bit.
        assert_eq!(a, b);
        // A different seed is allowed to (and here does) diverge from
        // greedy at some step; k=1 must *always* equal greedy.
        let greedy = sm
            .generate(&mut engine, &prompt, 6, None, ServePath::FullDecoder, Sampler::Greedy)
            .unwrap();
        let k1 = sm
            .generate(
                &mut engine,
                &prompt,
                6,
                None,
                ServePath::FullDecoder,
                Sampler::TopK { k: 1, temperature: 0.5, seed: 3 },
            )
            .unwrap();
        assert_eq!(k1, greedy);
    }

    #[test]
    fn topk_sample_stays_inside_the_shortlist() {
        // Statistical unit check on the sampler itself: draws only come
        // from the k highest logits, and every shortlist member is
        // reachable at a hot temperature.
        let logits = vec![0.0f32, 5.0, 4.0, -1.0, 3.0, 2.0];
        let sampler = Sampler::TopK { k: 3, temperature: 2.0, seed: 11 };
        let mut rng = sampler.rng();
        let mut seen = [0usize; 6];
        for _ in 0..400 {
            let t = sampler.sample(&logits, &mut rng) as usize;
            seen[t] += 1;
        }
        // Top-3 by logit are tokens 1, 2, 4.
        assert_eq!(seen[0] + seen[3] + seen[5], 0, "{seen:?}");
        assert!(seen[1] > 0 && seen[2] > 0 && seen[4] > 0, "{seen:?}");
        // Greedy on the same logits is the argmax.
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn topk_sample_tolerates_nan_logits() {
        // A degenerate model (NaN in the LM head) must not panic the
        // decode collector: the ranking comparator stays a total order
        // with NaNs grouped last, so they are never sampled while any
        // real logit remains in the shortlist.
        let logits = vec![f32::NAN, 2.0, f32::NAN, 1.0, 3.0, f32::NAN];
        let sampler = Sampler::TopK { k: 3, temperature: 1.0, seed: 5 };
        let mut rng = sampler.rng();
        for _ in 0..100 {
            let t = sampler.sample(&logits, &mut rng) as usize;
            assert!(matches!(t, 1 | 3 | 4), "sampled NaN token {t}");
        }
        // k larger than the number of finite logits: the NaN tail is
        // trimmed from the shortlist, so the single real logit is the
        // only reachable token (a NaN softmax normalizer would
        // otherwise make it unreachable).
        let one_real = vec![1.0f32, f32::NAN, f32::NAN, f32::NAN];
        for _ in 0..20 {
            assert_eq!(sampler.sample(&one_real, &mut rng), 0);
        }
        // All-NaN logits still return deterministically instead of
        // panicking (greedy's behavior on the same input is token 0).
        let all_nan = vec![f32::NAN; 4];
        let _ = sampler.sample(&all_nan, &mut rng);
        assert_eq!(Sampler::Greedy.sample(&all_nan, &mut rng), 0);
    }

    #[test]
    fn topk1_is_bit_identical_to_greedy_on_adversarial_logits() {
        // Property test over adversarial logit vectors: `TopK { k: 1 }`
        // and `greedy_token` are the same function on *any* input —
        // ties, NaN holes (including a NaN at index 0, which the old
        // strict `>` greedy scan got stuck on), infinities, and all-NaN
        // rows.
        let mut rng = Pcg32::new(0xadf5, 17);
        for case in 0..500u32 {
            let n = 1 + rng.below(12) as usize;
            let mut logits: Vec<f32> = (0..n)
                .map(|_| match rng.below(8) {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    2 => f32::NEG_INFINITY,
                    3 => 0.0,
                    _ => (rng.below(5) as f32 - 2.0) * 0.5, // ties likely
                })
                .collect();
            if case % 3 == 0 {
                logits[0] = f32::NAN; // the old greedy bug's trigger
            }
            let want = greedy_token(&logits);
            // Greedy invariant: lowest-index maximum over the non-NaN
            // entries, token 0 when every entry is NaN.
            let non_nan: Vec<(usize, f32)> = logits
                .iter()
                .copied()
                .enumerate()
                .filter(|(_, v)| !v.is_nan())
                .collect();
            match non_nan.iter().map(|&(_, v)| v).reduce(f32::max) {
                Some(mx) => {
                    let first = non_nan.iter().find(|&&(_, v)| v == mx).unwrap().0;
                    assert_eq!(want as usize, first, "logits {logits:?}");
                }
                None => assert_eq!(want, 0, "all-NaN logits {logits:?}"),
            }
            for seed in [0u64, 7, 0xdead] {
                let sampler = Sampler::TopK { k: 1, temperature: 0.7, seed };
                let mut srng = sampler.rng();
                assert_eq!(
                    sampler.sample(&logits, &mut srng),
                    want,
                    "k=1 diverged from greedy on {logits:?}"
                );
            }
        }
    }

    /// Reserve and hand `store` the pages one step of `rows` new tokens
    /// needs — the funding contract the decode scheduler follows.
    fn fund(store: &mut KvStore, pool: &Arc<KvPool>, rows: usize) {
        let p = store.as_paged_mut().expect("paged store");
        let need = p.pages_for(rows);
        p.fund(pool.reserve(need).expect("pool sized amply"));
    }

    #[test]
    fn paged_decode_matches_contiguous_at_both_patterns_and_paths() {
        // Tentpole acceptance: a pool-backed paged KvStore decodes
        // bit-identically to the contiguous layout — only where K/V rows
        // live changes, never an arithmetic term — at 2:4 and 4:8, on
        // both serve paths, across prefill and token-by-token decode.
        for nm in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
            let sm = sparse_model_with(nm);
            let mut engine = NativeEngine::new(NativeCfg { nm, ..NativeCfg::default() });
            let mut rng = Pcg32::seeded(51);
            for path in [ServePath::MlpOnly, ServePath::FullDecoder] {
                let toks: Vec<u32> =
                    (0..10).map(|_| rng.below(sm.cfg().vocab as u32)).collect();
                let pool = sm.new_kv_pool(32, 3);
                let mut contig = vec![sm.new_cache()];
                let mut paged = vec![KvStore::paged(pool.new_cache())];
                let prompt = 6usize;
                let x = sm.embed(&toks[..prompt]).unwrap();
                if path == ServePath::FullDecoder {
                    fund(&mut paged[0], &pool, prompt);
                }
                let a = sm
                    .forward_cached(&mut engine, &x, &[(0, prompt)], &mut contig, path)
                    .unwrap();
                let b = sm
                    .forward_cached(&mut engine, &x, &[(0, prompt)], &mut paged, path)
                    .unwrap();
                assert_eq!(a.data(), b.data(), "{} {} prefill", nm.name(), path.name());
                for t in prompt..toks.len() {
                    let xt = sm.embed(&toks[t..t + 1]).unwrap();
                    if path == ServePath::FullDecoder {
                        fund(&mut paged[0], &pool, 1);
                    }
                    let sa = sm
                        .forward_cached(&mut engine, &xt, &[(0, 1)], &mut contig, path)
                        .unwrap();
                    let sb = sm
                        .forward_cached(&mut engine, &xt, &[(0, 1)], &mut paged, path)
                        .unwrap();
                    assert_eq!(
                        sa.data(),
                        sb.data(),
                        "{} {} decode step {t}",
                        nm.name(),
                        path.name()
                    );
                }
                if path == ServePath::FullDecoder {
                    assert_eq!(paged[0].len(), toks.len());
                    assert!(paged[0].bytes() > 0);
                }
                drop(paged);
                assert_eq!(pool.free_pages(), 32, "pages recycled after drop");
            }
        }
    }

    #[test]
    fn topp_sampling_is_seed_deterministic_and_tiny_p_is_greedy() {
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let prompt: Vec<u32> = vec![12, 7, 200];
        let topp = Sampler::TopP { p: 0.9, temperature: 0.8, seed: 99 };
        let a = sm
            .generate(&mut engine, &prompt, 6, None, ServePath::FullDecoder, topp)
            .unwrap();
        let b = sm
            .generate(&mut engine, &prompt, 6, None, ServePath::FullDecoder, topp)
            .unwrap();
        // Same seed, same kernels => reproducible bit for bit.
        assert_eq!(a, b);
        // A vanishing p keeps only the argmax in the nucleus — always
        // identical to greedy, the top-p analogue of k = 1.
        let greedy = sm
            .generate(&mut engine, &prompt, 6, None, ServePath::FullDecoder, Sampler::Greedy)
            .unwrap();
        let tight = sm
            .generate(
                &mut engine,
                &prompt,
                6,
                None,
                ServePath::FullDecoder,
                Sampler::TopP { p: 1e-6, temperature: 0.5, seed: 3 },
            )
            .unwrap();
        assert_eq!(tight, greedy);
        // Malformed configurations are rejected at validation.
        assert!(Sampler::TopP { p: 0.0, temperature: 1.0, seed: 1 }.validate().is_err());
        assert!(Sampler::TopP { p: 1.2, temperature: 1.0, seed: 1 }.validate().is_err());
        assert!(Sampler::TopP { p: f32::NAN, temperature: 1.0, seed: 1 }.validate().is_err());
        assert!(Sampler::TopP { p: 0.5, temperature: 0.0, seed: 1 }.validate().is_err());
        assert!(Sampler::TopP { p: 1.0, temperature: 0.7, seed: 1 }.validate().is_ok());
    }

    #[test]
    fn topp_sample_stays_inside_the_nucleus_and_tolerates_nan() {
        // exp(5) / z ~ 0.64, + exp(4) ~ 0.875, + exp(3) ~ 0.962: at
        // p = 0.95 the nucleus is exactly tokens {1, 2, 4}.
        let logits = vec![0.0f32, 5.0, 4.0, -1.0, 3.0, 2.0];
        let sampler = Sampler::TopP { p: 0.95, temperature: 1.0, seed: 11 };
        let mut rng = sampler.rng();
        let mut seen = [0usize; 6];
        for _ in 0..400 {
            seen[sampler.sample(&logits, &mut rng) as usize] += 1;
        }
        assert_eq!(seen[0] + seen[3] + seen[5], 0, "{seen:?}");
        assert!(seen[1] > 0 && seen[2] > 0 && seen[4] > 0, "{seen:?}");
        // NaN logits are trimmed before the softmax normalizer and never
        // sampled while a finite candidate exists, even at p = 1.
        let with_nan = vec![f32::NAN, 2.0, f32::NAN, 1.0, 3.0, f32::NAN];
        let wide = Sampler::TopP { p: 1.0, temperature: 1.0, seed: 5 };
        for _ in 0..100 {
            let t = wide.sample(&with_nan, &mut rng) as usize;
            assert!(matches!(t, 1 | 3 | 4), "sampled NaN token {t}");
        }
        // All-NaN logits return deterministically instead of panicking.
        let _ = wide.sample(&[f32::NAN; 4], &mut rng);
    }

    #[test]
    fn recipe_descriptor_is_stamped_into_the_model() {
        let sm = tiny_sparse_model();
        assert_eq!(sm.recipe_name(), "Wanda");
        let j = sm.recipe_json();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("Wanda"));
        assert_eq!(j.get("nm").and_then(Json::as_str), Some("2:4"));
        // The descriptor round-trips through the recipe deserializer.
        let back = PruneRecipe::from_json(j).unwrap();
        assert_eq!(back.name(), sm.recipe_name());
    }

    #[test]
    fn cache_mismatches_are_rejected() {
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let x = Mat::zeros(2, sm.width());
        // Wrong cache count.
        let mut none: Vec<KvStore> = vec![];
        assert!(sm
            .forward_cached(&mut engine, &x, &[(0, 2)], &mut none, ServePath::FullDecoder)
            .is_err());
        // Wrong layer count.
        let mut bad = vec![KvStore::contiguous(sm.cfg().n_layers + 1, sm.width())];
        assert!(sm
            .forward_cached(&mut engine, &x, &[(0, 2)], &mut bad, ServePath::FullDecoder)
            .is_err());
    }

    #[test]
    fn dense_model_cached_decode_matches_sparse_reference_shape() {
        // The dense baseline decodes through the same cached glue: its
        // incremental output equals its own full re-forward.
        let sm = tiny_sparse_model();
        let dm = DenseModel::from_sparse(&sm);
        let toks: Vec<u32> = vec![1, 2, 3, 4, 5, 6];
        let x = dm.embed(&toks[..4]).unwrap();
        let mut caches = vec![dm.new_cache()];
        let pre = dm.forward_cached(&x, &[(0, 4)], &mut caches, ServePath::FullDecoder);
        let full = dm.forward(&x, &[(0, 4)], ServePath::FullDecoder);
        assert_close(pre.data(), full.data(), 1e-5).unwrap();
        for t in 4..6 {
            let xt = dm.embed(&toks[t..t + 1]).unwrap();
            let step = dm.forward_cached(&xt, &[(0, 1)], &mut caches, ServePath::FullDecoder);
            let xall = dm.embed(&toks[..t + 1]).unwrap();
            let fall = dm.forward(&xall, &[(0, t + 1)], ServePath::FullDecoder);
            assert_close(step.row(0), fall.row(t), 1e-5)
                .unwrap_or_else(|e| panic!("dense decode step {t}: {e}"));
        }
        assert_eq!(dm.logits(&x).shape(), (4, 256));
    }

    #[test]
    fn attention_is_span_local() {
        // Two requests coalesced into one batch attend independently: the
        // second span's output must equal serving it alone.
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let mut rng = Pcg32::seeded(21);
        let a = Mat::randn(3, sm.width(), 1.0, &mut rng);
        let b = Mat::randn(4, sm.width(), 1.0, &mut rng);
        let mut stacked = Mat::zeros(7, sm.width());
        for r in 0..3 {
            stacked.row_mut(r).copy_from_slice(a.row(r));
        }
        for r in 0..4 {
            stacked.row_mut(3 + r).copy_from_slice(b.row(r));
        }
        let batched = sm
            .forward(&mut engine, &stacked, &[(0, 3), (3, 7)], ServePath::FullDecoder)
            .unwrap();
        let alone = sm.forward(&mut engine, &b, &whole(&b), ServePath::FullDecoder).unwrap();
        // Same kernels on the same rows => bit-identical.
        assert_eq!(&batched.data()[3 * sm.width()..], alone.data());
    }

    #[test]
    fn bad_sequence_spans_are_rejected() {
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let x = Mat::zeros(4, sm.width());
        for seqs in [vec![], vec![(0, 3)], vec![(1, 4)], vec![(0, 2), (3, 4)]] {
            assert!(
                sm.forward(&mut engine, &x, &seqs, ServePath::FullDecoder).is_err(),
                "{seqs:?} should be rejected"
            );
        }
    }

    #[test]
    fn required_artifacts_follow_the_serve_path() {
        let sm = tiny_sparse_model();
        let engine = NativeEngine::default();
        let full = sm.required_artifacts(ServePath::FullDecoder);
        let mlp = sm.required_artifacts(ServePath::MlpOnly);
        // tiny-s: q/k/v/o are dxd — an artifact shape the MLP sublayers
        // never use.
        assert!(mlp.len() < full.len());
        for name in &mlp {
            assert!(full.contains(name), "{name} on the MLP path but not the full path");
        }
        for name in full {
            assert!(
                crate::runtime::ExecBackend::supports(&engine, &name),
                "native backend lacks {name}"
            );
            assert!(!sm.artifact_users(&name).is_empty());
        }
    }
}
