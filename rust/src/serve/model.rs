//! Multi-layer sparse model: every prunable linear of a pruned model
//! compressed to the N:M serving layout once, cached, and served through
//! the [`ExecBackend`] artifact interface.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::coordinator::PrunedModel;
use crate::model::{rmsnorm, swiglu, LinearKind, LinearRef, ModelConfig};
use crate::runtime::{ExecBackend, TensorValue};
use crate::sparsity::{Compressed, NmConfig};
use crate::tensor::Mat;

/// One compressed linear, ready to serve: the `sparse_fwd` artifact name
/// plus its static inputs (vals / idx / src) converted exactly once at
/// build time, so per-request work is only the activation conversion.
#[derive(Debug, Clone)]
pub struct SparseLayer {
    pub lin: LinearRef,
    pub artifact: String,
    nm: NmConfig,
    c_out: usize,
    c_in: usize,
    /// Compressed-format footprint (f32 values + u8 group offsets),
    /// recorded at build time — the transient `Compressed` itself is not
    /// retained, so resident memory is just the artifact tensors below.
    storage_bytes: usize,
    /// Cached artifact inputs.
    vals: TensorValue,
    idx: TensorValue,
    src: TensorValue,
    /// Channel permutation (`src_of`) kept on the host side for the
    /// dense verification path; the dense weight itself is materialized
    /// on demand so serving memory stays at the compressed footprint.
    src_of: Vec<usize>,
}

impl SparseLayer {
    fn build(lin: LinearRef, res: &crate::pruning::PruneResult) -> Result<SparseLayer> {
        let comp = Compressed::compress(&res.weight, &res.mask);
        let (c_out, c_in) = comp.shape();
        let k = comp.k();
        let vals = TensorValue::f32(vec![c_out, k], comp.vals().to_vec())?;
        let idx =
            TensorValue::i32(vec![c_out, k], comp.idx().iter().map(|&v| v as i32).collect())?;
        anyhow::ensure!(
            res.src_of.len() == c_in,
            "layer {}: src_of has {} entries, expected {c_in}",
            lin.param_name(),
            res.src_of.len()
        );
        let src = TensorValue::i32(vec![c_in], res.src_of.iter().map(|&v| v as i32).collect())?;
        Ok(SparseLayer {
            lin,
            artifact: format!("sparse_fwd_{c_out}x{c_in}"),
            nm: comp.cfg(),
            c_out,
            c_in,
            storage_bytes: comp.storage_bytes(),
            vals,
            idx,
            src,
            src_of: res.src_of.clone(),
        })
    }

    /// `(C_out, C_in)` of the underlying weight.
    pub fn shape(&self) -> (usize, usize) {
        (self.c_out, self.c_in)
    }

    /// Compressed storage footprint of this layer.
    pub fn storage_bytes(&self) -> usize {
        self.storage_bytes
    }

    /// `y = x W_sparse^T` through the backend's `sparse_fwd` artifact
    /// (the artifact permutes `x` by `src` internally). `x` is
    /// `[T, C_in]` in ORIGINAL channel order.
    pub fn forward(&self, engine: &mut dyn ExecBackend, x: &Mat) -> Result<Mat> {
        let inputs =
            [self.vals.clone(), self.idx.clone(), TensorValue::from_mat(x), self.src.clone()];
        let mut outs = engine.run(&self.artifact, &inputs)?;
        anyhow::ensure!(
            outs.len() == 1,
            "artifact {} returned {} outputs, expected 1",
            self.artifact,
            outs.len()
        );
        outs.pop().expect("len checked").into_mat()
    }

    /// Host dense reference of [`SparseLayer::forward`]: permute the
    /// activations, dense matmul on the masked weight.  Materializes the
    /// dense weight per call from the cached artifact tensors — this is
    /// the *verification* path; keeping a permanent dense copy would make
    /// the compressed serving footprint a lie.
    pub fn forward_dense(&self, x: &Mat) -> Mat {
        let vals = self.vals.as_f32().expect("vals dtype").to_vec();
        let idx: Vec<u32> =
            self.idx.as_i32().expect("idx dtype").iter().map(|&v| v as u32).collect();
        let comp = Compressed::from_parts(self.nm, self.c_out, self.c_in, vals, idx)
            .expect("layer was built from a valid compressed weight");
        x.permute_cols(&self.src_of).matmul_bt(&comp.to_dense())
    }
}

/// All compressed linears of a pruned model plus the host glue (norms,
/// SwiGLU) needed to run the decoder layers' MLP sublayers end-to-end on
/// the sparse path.
///
/// The serving pipeline treats each decoder layer's MLP sublayer
/// (`x + W_down(silu(W_gate(xn)) ⊙ W_up(xn))`, `xn = rmsnorm(x)`) as one
/// pipeline stage: three `sparse_fwd` executions per stage, `[T, d]` in
/// and `[T, d]` out, so stages chain across decoder layers.  Attention
/// sublayers keep their compressed weights cached here too (served via
/// [`SparseModel::linear`]), but their softmax/RoPE glue stays on the
/// host path for now — see ROADMAP.
pub struct SparseModel {
    cfg: ModelConfig,
    nm: NmConfig,
    layers: HashMap<LinearRef, SparseLayer>,
    /// Per-decoder-layer MLP norm gain `[1, d]`.
    mlp_norms: Vec<Mat>,
    norm_eps: f32,
}

impl SparseModel {
    /// Compress every pruned linear of `pruned` once.  Fails on a Dense
    /// (unpruned) model or when any prunable linear lacks a prune result.
    pub fn from_pruned(pruned: &PrunedModel) -> Result<SparseModel> {
        let cfg = pruned.params.cfg().clone();
        let some = pruned
            .layers
            .values()
            .next()
            .ok_or_else(|| anyhow!("model has no pruned layers to serve (Dense method?)"))?;
        let nm = some.mask.cfg();
        let mut layers = HashMap::new();
        for lin in cfg.prunable_linears() {
            let res = pruned
                .layers
                .get(&lin)
                .ok_or_else(|| anyhow!("no prune result for {}", lin.param_name()))?;
            anyhow::ensure!(
                res.mask.cfg() == nm,
                "mixed N:M patterns: {} is {:?}, expected {nm:?}",
                lin.param_name(),
                res.mask.cfg()
            );
            layers.insert(lin, SparseLayer::build(lin, res)?);
        }
        let mlp_norms = (0..cfg.n_layers)
            .map(|l| pruned.params.get(&format!("layers.{l}.mlp_norm")).clone())
            .collect();
        let norm_eps = cfg.norm_eps;
        Ok(SparseModel { cfg, nm, layers, mlp_norms, norm_eps })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn nm(&self) -> NmConfig {
        self.nm
    }

    /// Serving pipeline depth (one stage per decoder layer).
    pub fn n_stages(&self) -> usize {
        self.cfg.n_layers
    }

    /// Activation width at every stage boundary.
    pub fn width(&self) -> usize {
        self.cfg.dim
    }

    /// A cached compressed linear.
    pub fn linear(&self, lin: LinearRef) -> &SparseLayer {
        &self.layers[&lin]
    }

    /// Total compressed storage across every cached linear.
    pub fn storage_bytes(&self) -> usize {
        self.layers.values().map(SparseLayer::storage_bytes).sum()
    }

    /// Dense f32 storage the same linears would occupy.
    pub fn dense_bytes(&self) -> usize {
        self.layers
            .values()
            .map(|l| {
                let (o, i) = l.shape();
                o * i * 4
            })
            .sum()
    }

    /// One pipeline stage on the sparse path: decoder layer `layer`'s MLP
    /// sublayer, `x: [T, d]` -> `[T, d]`.
    pub fn mlp_stage(&self, engine: &mut dyn ExecBackend, layer: usize, x: &Mat) -> Result<Mat> {
        let xn = rmsnorm(x, &self.mlp_norms[layer], self.norm_eps);
        let gate = self.layers[&LinearRef { layer, kind: LinearKind::WGate }].forward(engine, &xn)?;
        let up = self.layers[&LinearRef { layer, kind: LinearKind::WUp }].forward(engine, &xn)?;
        let h = swiglu(&gate, &up);
        let down = self.layers[&LinearRef { layer, kind: LinearKind::WDown }].forward(engine, &h)?;
        Ok(x.add(&down))
    }

    /// Sparse forward through every decoder layer's MLP stage in order.
    pub fn forward(&self, engine: &mut dyn ExecBackend, x: &Mat) -> Result<Mat> {
        let mut cur = x.clone();
        for layer in 0..self.n_stages() {
            cur = self.mlp_stage(engine, layer, &cur)?;
        }
        Ok(cur)
    }

    /// Host dense-masked reference of [`SparseModel::mlp_stage`] — same
    /// math, folded dense weights, no backend.
    pub fn dense_stage(&self, layer: usize, x: &Mat) -> Mat {
        let xn = rmsnorm(x, &self.mlp_norms[layer], self.norm_eps);
        let gate = self.layers[&LinearRef { layer, kind: LinearKind::WGate }].forward_dense(&xn);
        let up = self.layers[&LinearRef { layer, kind: LinearKind::WUp }].forward_dense(&xn);
        let h = swiglu(&gate, &up);
        let down = self.layers[&LinearRef { layer, kind: LinearKind::WDown }].forward_dense(&h);
        x.add(&down)
    }

    /// Host dense-masked reference of [`SparseModel::forward`].
    pub fn dense_forward(&self, x: &Mat) -> Mat {
        let mut cur = x.clone();
        for layer in 0..self.n_stages() {
            cur = self.dense_stage(layer, &cur);
        }
        cur
    }

    /// Every artifact name this model serves through — for checking a
    /// backend's coverage up front.
    pub fn required_artifacts(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.layers.values().map(|l| l.artifact.clone()).collect();
        names.sort();
        names.dedup();
        names
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::coordinator::{prune_model, PipelineCfg, PruneMethod};
    use crate::data::{Corpus, CorpusKind};
    use crate::lcp::LcpCfg;
    use crate::model::synth_trained_params;
    use crate::pruning::Metric;
    use crate::runtime::{NativeCfg, NativeEngine};
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    pub(crate) fn tiny_sparse_model() -> SparseModel {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 11);
        let corpus = Corpus::build(CorpusKind::C4Like, 5);
        let pc = PipelineCfg {
            calib_seqs: 2,
            calib_len: 32,
            calib_rows: 32,
            lcp: LcpCfg { block: 16, steps: 6, lr: 0.1, ..Default::default() },
            ..Default::default()
        };
        let pruned = prune_model(&ps, &corpus, PruneMethod::OneShot(Metric::Wanda), &pc);
        SparseModel::from_pruned(&pruned).unwrap()
    }

    #[test]
    fn compresses_every_prunable_linear() {
        let sm = tiny_sparse_model();
        assert_eq!(sm.layers.len(), sm.cfg().prunable_linears().len());
        // 2:4 layers: values alone are half the dense bytes; metadata adds
        // 1/8 more => strictly between 0.5x and 0.65x dense.
        assert!(sm.storage_bytes() > sm.dense_bytes() / 2);
        assert!(sm.storage_bytes() <= sm.dense_bytes() * 65 / 100);
        assert_eq!(sm.n_stages(), sm.cfg().n_layers);
    }

    #[test]
    fn dense_model_is_rejected() {
        let cfg = ModelConfig::by_name("tiny-s").unwrap();
        let ps = synth_trained_params(&cfg, 11);
        let corpus = Corpus::build(CorpusKind::C4Like, 5);
        let pruned =
            prune_model(&ps, &corpus, PruneMethod::Dense, &PipelineCfg::default());
        assert!(SparseModel::from_pruned(&pruned).is_err());
    }

    #[test]
    fn layer_forward_matches_dense_reference() {
        let sm = tiny_sparse_model();
        let mut engine = NativeEngine::default();
        let mut rng = Pcg32::seeded(3);
        for lin in sm.cfg().prunable_linears() {
            let layer = sm.linear(lin);
            let (_, c_in) = layer.shape();
            let x = Mat::randn(5, c_in, 1.0, &mut rng);
            let got = layer.forward(&mut engine, &x).unwrap();
            let want = layer.forward_dense(&x);
            assert_close(got.data(), want.data(), 1e-4).unwrap();
        }
    }

    #[test]
    fn prop_end_to_end_forward_matches_dense_masked_forward() {
        crate::util::testkit::check_n("serve-parity", 6, |rng| {
            let sm = tiny_sparse_model();
            let threads = 1 + rng.below_usize(3);
            let mut engine = NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() });
            let t = 1 + rng.below_usize(6);
            let x = Mat::randn(t, sm.width(), 1.0, rng);
            let got = sm.forward(&mut engine, &x).map_err(|e| format!("{e:#}"))?;
            let want = sm.dense_forward(&x);
            assert_close(got.data(), want.data(), 1e-3)
                .map_err(|e| format!("threads={threads} t={t}: {e}"))
        });
    }

    #[test]
    fn required_artifacts_are_supported_by_native() {
        let sm = tiny_sparse_model();
        let engine = NativeEngine::default();
        for name in sm.required_artifacts() {
            assert!(
                crate::runtime::ExecBackend::supports(&engine, &name),
                "native backend lacks {name}"
            );
        }
    }
}
