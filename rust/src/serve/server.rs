//! The batch serving front-end: micro-batch the request queue, run
//! batches through the decoder-layer stages ([`SparseModel::stage`] on
//! the configured [`ServePath`]), and hand per-request outputs back in
//! submission order.
//!
//! Two batch execution modes, same math:
//!
//! * [`Server::run_sequential`] — one [`ExecBackend`], stages executed in
//!   order per batch.  Works with any backend, including non-`Send` ones
//!   (the PJRT engine) — though backends with a *fixed* AOT activation
//!   shape are rejected up front; see `check_backend`.
//! * [`Server::run_pipelined`] — one backend *per stage*; batches flow
//!   through a channel-connected stage chain
//!   ([`crate::util::pool::pipeline_map`]) so stage `L` of batch `i`
//!   overlaps stage `L+1` of batch `i-1`, on top of the per-stage
//!   output-row-tile parallelism inside `Compressed::matmul_xt_threads`.
//!
//! The long-lived streaming mode ([`Server::run_streaming`]) lives in
//! `super::stream`.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::{BatcherCfg, MicroBatch, MicroBatcher, ReorderBuffer, Request};
use super::model::{ServePath, SparseModel};
use crate::runtime::ExecBackend;
use crate::tensor::Mat;
use crate::util::pool::pipeline_map;

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Micro-batcher limits.
    pub batcher: BatcherCfg,
    /// Which sublayers run on the sparse path (attention + MLP or MLP
    /// only).
    pub path: ServePath,
    /// Streaming only ([`Server::run_streaming`]): how long the
    /// micro-batcher waits for more requests before dispatching a
    /// partial batch.
    pub linger: Duration,
    /// Streaming/decode backpressure: maximum requests in flight
    /// (submitted but not yet replied to) before `submit` fails fast
    /// with [`super::ServeError::QueueFull`].  0 = unbounded (the
    /// pre-backpressure behavior).
    pub queue_depth: usize,
    /// Streaming/decode backpressure: how long a request may live
    /// before it expires with [`super::ServeError::TimedOut`] through
    /// its ticket.  For the forward loop this is time spent
    /// undispatched; for the decode loop it is a deadline on the
    /// *whole generation* — checked before prefill and every time the
    /// request rejoins the step pool, so a slow or stuck generation
    /// releases its in-flight slot and KV cache instead of holding
    /// them to its stop condition.  Zero disables the timeout.
    pub request_timeout: Duration,
    /// Decode only ([`Server::run_decode_streaming`]): hard cap on
    /// `max_new_tokens` a single generation request may ask for.  0 =
    /// uncapped.
    pub max_new_tokens_cap: usize,
    /// Streaming/decode observability: emit a [`super::StatsReport`]
    /// through [`ServeCfg::stats_sink`] on this cadence while the loop
    /// runs (plus one final post-drain aggregate).  Zero disables the
    /// sampler thread; the final aggregate is still computed and
    /// returned on the run's report.
    pub stats_every: Duration,
    /// Decode only ([`Server::run_decode_streaming`]): size of the
    /// shared paged-KV pool in pages.  0 (the default) keeps the
    /// contiguous per-request [`super::KvCache`]; nonzero allocates a
    /// [`super::KvPool`] and every generation's KV lives in pool pages,
    /// with admission gated on free pages and preemption-by-recompute
    /// when the pool runs dry mid-decode.
    pub kv_pages: usize,
    /// Decode only: token rows per KV page (per layer).  Ignored when
    /// `kv_pages` is 0.
    pub kv_page_tokens: usize,
    /// Decode only: share prefill pages between concurrent requests
    /// whose prompts have a common page-aligned prefix (copy-on-write;
    /// hash-matched at admission).  Ignored when `kv_pages` is 0.
    pub kv_share_prefix: bool,
    /// Where periodic reports go; `None` means the default sink (one
    /// JSON object per line on stderr).
    pub stats_sink: Option<super::StatsSink>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            batcher: BatcherCfg::default(),
            path: ServePath::default(),
            linger: Duration::from_millis(2),
            queue_depth: 0,
            request_timeout: Duration::ZERO,
            max_new_tokens_cap: 0,
            stats_every: Duration::ZERO,
            stats_sink: None,
            kv_pages: 0,
            kv_page_tokens: 16,
            kv_share_prefix: false,
        }
    }
}

/// Wall-clock + token accounting for one pipeline stage (decoder layer).
#[derive(Debug, Clone)]
pub struct StageStats {
    pub layer: usize,
    /// Summed busy seconds across every batch that passed this stage.
    pub seconds: f64,
    /// Tokens processed by this stage.
    pub tokens: usize,
}

impl StageStats {
    pub fn tokens_per_s(&self) -> f64 {
        if self.seconds > 0.0 {
            self.tokens as f64 / self.seconds
        } else {
            0.0
        }
    }
}

/// Result of serving a request set to completion.
#[derive(Debug)]
pub struct ServeReport {
    /// Per-request outputs in submission order.
    pub outputs: Vec<(u64, Mat)>,
    /// Per-decoder-layer busy time.
    pub stage_stats: Vec<StageStats>,
    /// End-to-end wall-clock of the whole run.
    pub total_seconds: f64,
    /// Total tokens served (summed over requests).
    pub total_tokens: usize,
    /// Micro-batches formed by the batcher.
    pub n_batches: usize,
}

impl ServeReport {
    /// End-to-end serving throughput.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.total_tokens as f64 / self.total_seconds
        } else {
            0.0
        }
    }
}

/// A batch mid-flight: activations plus per-stage timing breadcrumbs.
struct BatchWork {
    batch: MicroBatch,
    x: Mat,
    stage_s: Vec<f64>,
    err: Option<String>,
}

/// Multi-layer sparse serving front-end over a [`SparseModel`].
pub struct Server {
    model: SparseModel,
    cfg: ServeCfg,
}

impl Server {
    pub fn new(model: SparseModel, cfg: ServeCfg) -> Server {
        Server { model, cfg }
    }

    pub fn model(&self) -> &SparseModel {
        &self.model
    }

    pub fn cfg(&self) -> &ServeCfg {
        &self.cfg
    }

    /// Mutable serving configuration (e.g. to switch the [`ServePath`]
    /// between runs of the same compressed model).
    pub fn cfg_mut(&mut self) -> &mut ServeCfg {
        &mut self.cfg
    }

    /// Queue + coalesce `requests` into micro-batches (submission order).
    fn coalesce(&self, requests: Vec<Request>) -> Result<Vec<MicroBatch>> {
        anyhow::ensure!(!requests.is_empty(), "no requests to serve");
        let mut batcher = MicroBatcher::new(self.model.width(), self.cfg.batcher.clone());
        for req in requests {
            batcher.push(req)?;
        }
        Ok(batcher.drain())
    }

    /// Check `engine` serves every artifact the active [`ServePath`]
    /// needs, up front.
    ///
    /// The activation-shape check is skipped for backends that report
    /// *dynamic* shapes (`input_shape` returns `None` — the native
    /// engine, or any shape-polymorphic PJRT build).  A backend that
    /// bakes a fixed `[T, C_in]` into an artifact (the PJRT AOT manifest
    /// does) is rejected here rather than mid-run, with the offending
    /// artifact, its baked shape, and the layers routed through it all
    /// named: the micro-batcher produces variable-length token batches
    /// (e.g. a smaller tail batch), which a fixed shape cannot accept.
    /// Pad-to-shape micro-batching is the ROADMAP item that will lift
    /// this.
    pub(super) fn check_backend(&self, engine: &dyn ExecBackend) -> Result<()> {
        for name in self.model.required_artifacts(self.cfg.path) {
            anyhow::ensure!(
                engine.supports(&name),
                "backend '{}' does not serve artifact '{name}' (needed by {})",
                engine.backend_name(),
                self.model.artifact_users(&name)
            );
            if let Some(shape) = engine.input_shape(&name, "x") {
                anyhow::bail!(
                    "backend '{}' fixes the activation shape of artifact '{name}' \
                     (serving {}) to {shape:?}; serving needs shape-polymorphic \
                     artifacts — pad-to-shape micro-batching is on the ROADMAP",
                    engine.backend_name(),
                    self.model.artifact_users(&name)
                );
            }
        }
        Ok(())
    }

    /// Serve `requests` on a single backend, stages in order per batch.
    pub fn run_sequential(
        &self,
        requests: Vec<Request>,
        engine: &mut dyn ExecBackend,
    ) -> Result<ServeReport> {
        self.check_backend(engine)?;
        let batches = self.coalesce(requests)?;
        let n_stages = self.model.n_stages();
        let path = self.cfg.path;
        let t0 = Instant::now();
        let mut works: Vec<BatchWork> = Vec::with_capacity(batches.len());
        for mut batch in batches {
            // Move the stacked activations into the work item (no copy);
            // `finish` puts the final-stage output back into the batch.
            let x = std::mem::replace(&mut batch.x, Mat::zeros(0, 0));
            let stage_s = Vec::with_capacity(n_stages);
            let mut work = BatchWork { x, batch, stage_s, err: None };
            for layer in 0..n_stages {
                let s0 = Instant::now();
                match self.model.stage(engine, layer, &work.x, work.batch.spans(), path) {
                    Ok(y) => work.x = y,
                    Err(e) => {
                        work.err = Some(format!("{e:#}"));
                        break;
                    }
                }
                work.stage_s.push(s0.elapsed().as_secs_f64());
            }
            works.push(work);
        }
        self.finish(works, t0.elapsed().as_secs_f64())
    }

    /// Serve `requests` with cross-layer pipelining: one backend per
    /// stage (engines beyond `n_stages` are unused; fewer is an error —
    /// fall back to [`Server::run_sequential`] with a single backend).
    pub fn run_pipelined(
        &self,
        requests: Vec<Request>,
        engines: Vec<Box<dyn ExecBackend + Send>>,
    ) -> Result<ServeReport> {
        let n_stages = self.model.n_stages();
        anyhow::ensure!(
            engines.len() >= n_stages,
            "pipelined serving needs one backend per stage: got {}, need {n_stages}",
            engines.len()
        );
        for engine in &engines {
            self.check_backend(engine.as_ref())?;
        }
        let batches = self.coalesce(requests)?;
        let t0 = Instant::now();
        let model = &self.model;
        let path = self.cfg.path;
        let stages: Vec<_> = engines
            .into_iter()
            .take(n_stages)
            .enumerate()
            .map(|(layer, mut engine)| {
                move |mut work: BatchWork| {
                    if work.err.is_none() {
                        let s0 = Instant::now();
                        match model.stage(engine.as_mut(), layer, &work.x, work.batch.spans(), path)
                        {
                            Ok(y) => {
                                work.x = y;
                                work.stage_s.push(s0.elapsed().as_secs_f64());
                            }
                            Err(e) => work.err = Some(format!("{e:#}")),
                        }
                    }
                    work
                }
            })
            .collect();
        let works_in: Vec<BatchWork> = batches
            .into_iter()
            .map(|mut batch| {
                let x = std::mem::replace(&mut batch.x, Mat::zeros(0, 0));
                BatchWork { x, batch, stage_s: Vec::with_capacity(n_stages), err: None }
            })
            .collect();
        let works = pipeline_map(works_in, stages);
        self.finish(works, t0.elapsed().as_secs_f64())
    }

    /// Aggregate stats, reorder to submission order, split per request.
    fn finish(&self, works: Vec<BatchWork>, total_seconds: f64) -> Result<ServeReport> {
        let n_stages = self.model.n_stages();
        let n_batches = works.len();
        let mut stage_stats: Vec<StageStats> = (0..n_stages)
            .map(|layer| StageStats { layer, seconds: 0.0, tokens: 0 })
            .collect();
        // Completions can land out of submission order (out-of-order
        // engines); the reorder buffer restores queue order by `seq`.
        let mut reorder = ReorderBuffer::new();
        let mut ordered: Vec<MicroBatch> = Vec::with_capacity(n_batches);
        let mut total_tokens = 0usize;
        for work in works {
            if let Some(err) = work.err {
                return Err(anyhow!("batch {} failed: {err}", work.batch.seq));
            }
            // Restore the batch's activations (now the final-stage output)
            // before reading its token count — the run loop moved them out.
            let mut batch = work.batch;
            batch.x = work.x;
            let tokens = batch.tokens();
            total_tokens += tokens;
            for (layer, s) in work.stage_s.iter().enumerate() {
                stage_stats[layer].seconds += s;
                stage_stats[layer].tokens += tokens;
            }
            for (_, b) in reorder.push(batch.seq, batch) {
                ordered.push(b);
            }
        }
        anyhow::ensure!(reorder.is_empty(), "serving lost a batch (seq gap)");
        let mut outputs = Vec::new();
        for done in &ordered {
            // `x` now holds the final-stage output; spans still index it.
            outputs.extend(done.split(&done.x));
        }
        Ok(ServeReport { outputs, stage_stats, total_seconds, total_tokens, n_batches })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{NativeCfg, NativeEngine};
    use crate::serve::model::tests::{tiny_sparse_model, whole};
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    fn requests(n: usize, rows: usize, width: usize, seed: u64) -> Vec<Request> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|id| Request { id: id as u64, x: Mat::randn(rows, width, 1.0, &mut rng) })
            .collect()
    }

    fn native(threads: usize) -> NativeEngine {
        NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() })
    }

    #[test]
    fn sequential_serving_matches_dense_reference_per_request() {
        let sm = tiny_sparse_model();
        let width = sm.width();
        let server = Server::new(sm, ServeCfg::default());
        let reqs = requests(6, 7, width, 42);
        let mut engine = native(1);
        let report = server.run_sequential(reqs.clone(), &mut engine).unwrap();
        assert_eq!(report.outputs.len(), reqs.len());
        assert_eq!(report.total_tokens, 6 * 7);
        for ((id, got), req) in report.outputs.iter().zip(&reqs) {
            assert_eq!(*id, req.id, "outputs out of submission order");
            let want = server.model().dense_forward(&req.x, &whole(&req.x), ServePath::MlpOnly);
            assert_close(got.data(), want.data(), 1e-3).unwrap();
        }
    }

    #[test]
    fn full_decoder_serving_matches_dense_reference_per_request() {
        // Attention is span-local, so a coalesced request's output equals
        // its stand-alone dense reference even when batches mix requests
        // of different lengths.
        let sm = tiny_sparse_model();
        let width = sm.width();
        let server = Server::new(
            sm,
            ServeCfg {
                batcher: BatcherCfg { max_tokens: 12, max_requests: 3 },
                path: ServePath::FullDecoder,
                ..ServeCfg::default()
            },
        );
        let mut rng = Pcg32::seeded(17);
        let reqs: Vec<Request> = (0..5)
            .map(|id| Request {
                id,
                x: Mat::randn(2 + (id as usize % 4), width, 1.0, &mut rng),
            })
            .collect();
        let mut engine = native(2);
        let report = server.run_sequential(reqs.clone(), &mut engine).unwrap();
        assert_eq!(report.outputs.len(), reqs.len());
        for ((id, got), req) in report.outputs.iter().zip(&reqs) {
            assert_eq!(*id, req.id);
            let want =
                server.model().dense_forward(&req.x, &whole(&req.x), ServePath::FullDecoder);
            assert_close(got.data(), want.data(), 1e-3).unwrap();
        }
    }

    #[test]
    fn pipelined_serving_is_identical_to_sequential() {
        let sm = tiny_sparse_model();
        let width = sm.width();
        let n_stages = sm.n_stages();
        let server = Server::new(
            sm,
            ServeCfg {
                batcher: BatcherCfg { max_tokens: 16, max_requests: 4 },
                path: ServePath::FullDecoder,
                ..ServeCfg::default()
            },
        );
        let reqs = requests(9, 5, width, 7);
        let mut engine = native(2);
        let seq = server.run_sequential(reqs.clone(), &mut engine).unwrap();
        let engines: Vec<Box<dyn ExecBackend + Send>> =
            (0..n_stages).map(|_| Box::new(native(2)) as Box<dyn ExecBackend + Send>).collect();
        let par = server.run_pipelined(reqs, engines).unwrap();
        assert_eq!(seq.outputs.len(), par.outputs.len());
        assert_eq!(seq.n_batches, par.n_batches);
        for ((id_s, y_s), (id_p, y_p)) in seq.outputs.iter().zip(&par.outputs) {
            assert_eq!(id_s, id_p);
            // Same kernels, same tiling => bit-identical across modes.
            assert_eq!(y_s.data(), y_p.data(), "request {id_s} diverged");
        }
        for s in &par.stage_stats {
            assert_eq!(s.tokens, par.total_tokens, "stage {} token accounting", s.layer);
        }
    }

    #[test]
    fn pipelined_requires_enough_engines() {
        let sm = tiny_sparse_model();
        let width = sm.width();
        let server = Server::new(sm, ServeCfg::default());
        let engines: Vec<Box<dyn ExecBackend + Send>> =
            vec![Box::new(native(1)) as Box<dyn ExecBackend + Send>];
        assert!(server.run_pipelined(requests(2, 3, width, 1), engines).is_err());
    }

    #[test]
    fn empty_request_set_is_rejected() {
        let sm = tiny_sparse_model();
        let server = Server::new(sm, ServeCfg::default());
        let mut engine = native(1);
        assert!(server.run_sequential(vec![], &mut engine).is_err());
    }

    #[test]
    fn backend_coverage_is_checked_up_front() {
        let sm = tiny_sparse_model();
        let server = Server::new(sm, ServeCfg::default());
        // An engine whose N:M pattern disagrees with the model still
        // `supports` the names, but a backend lacking the artifacts is
        // rejected before any work runs.
        struct NoArtifacts;
        impl ExecBackend for NoArtifacts {
            fn backend_name(&self) -> &'static str {
                "none"
            }
            fn supports(&self, _artifact: &str) -> bool {
                false
            }
            fn run(
                &mut self,
                _artifact: &str,
                _inputs: &[crate::runtime::TensorValue],
            ) -> Result<Vec<crate::runtime::TensorValue>> {
                Err(anyhow!("unreachable"))
            }
        }
        let width = server.model().width();
        let mut engine = NoArtifacts;
        let err = server.run_sequential(requests(1, 2, width, 3), &mut engine).unwrap_err();
        assert!(format!("{err:#}").contains("does not serve"), "{err:#}");
    }

    #[test]
    fn fixed_shape_backends_are_rejected_up_front() {
        // An AOT backend that bakes the activation shape in (the PJRT
        // manifest does) cannot accept the batcher's variable-length
        // batches; the server must say so before any work runs.
        struct FixedShape;
        impl ExecBackend for FixedShape {
            fn backend_name(&self) -> &'static str {
                "fixed"
            }
            fn supports(&self, _artifact: &str) -> bool {
                true
            }
            fn run(
                &mut self,
                _artifact: &str,
                _inputs: &[crate::runtime::TensorValue],
            ) -> Result<Vec<crate::runtime::TensorValue>> {
                Err(anyhow!("unreachable"))
            }
            fn input_shape(&self, _artifact: &str, input: &str) -> Option<Vec<usize>> {
                (input == "x").then(|| vec![128, 64])
            }
        }
        let sm = tiny_sparse_model();
        let width = sm.width();
        let server = Server::new(sm, ServeCfg::default());
        let mut engine = FixedShape;
        let err = server.run_sequential(requests(1, 2, width, 3), &mut engine).unwrap_err();
        assert!(format!("{err:#}").contains("fixes the activation shape"), "{err:#}");
    }
}
