//! Lock-light metrics plane for the streaming and decode serving loops.
//!
//! Production serving is only as good as its observability: throughput
//! claims need measured tail latency, and the counters a metrics plane
//! maintains double as an invariant harness over the serve loops'
//! accounting (every submission ends in exactly one of
//! completed/abandoned/failed/expired/rejected/retracted).
//!
//! The design keeps the hot path cheap:
//!
//! * Each serve-loop thread (client submit path, scheduler, stage
//!   threads, collector) holds its own [`StatsRecorder`] and records
//!   typed [`StatsEvent`]s.  Counting events bump shared atomics —
//!   exact, wait-free.  Latency samples go into the recorder's **own**
//!   bounded ring buffer ([`CircularQueue`]), so recorders never
//!   contend with each other; a full ring drops the oldest sample
//!   (counted in [`StatsReport::events_dropped`]) instead of blocking
//!   the serving thread.
//! * A sampler thread (spawned by the loops when
//!   [`super::ServeCfg::stats_every`] is nonzero) periodically calls
//!   [`StatsHub::sample`], which drains every ring into sorted bounded
//!   windows and emits a [`StatsReport`]: cumulative counters,
//!   interval prefill/decode tokens-per-second, batch-occupancy
//!   histogram, KV-cache resident/high-water bytes (fed by
//!   [`crate::model::KvCache::bytes`] deltas), paged-KV pool gauges
//!   (pool/free/shared pages plus preemption and copy-on-write fork
//!   totals, published via [`StatsRecorder::set_kv_pool`]), and
//!   nearest-rank
//!   p50/p90/p99 request, per-token, and step latency.  Percentiles
//!   come from a sorted window, so `p50 <= p90 <= p99` holds by
//!   construction.
//! * Reports serialize to JSON through the in-repo
//!   [`crate::util::json`] substrate ([`StatsReport::to_json`]); the
//!   default [`StatsSink`] prints one JSON object per line to stderr,
//!   which is what `permllm serve --stats-every <ms>` and the CI
//!   stats-smoke step parse.
//!
//! ```
//! use permllm::serve::stats::{ReqOutcome, StatsEvent, StatsHub};
//!
//! let hub = StatsHub::new(64);
//! let rec = hub.recorder();
//! rec.record(StatsEvent::Submitted);
//! rec.record(StatsEvent::Admitted);
//! rec.record(StatsEvent::BatchDispatched { requests: 1, prefill_tokens: 3, decode_tokens: 0 });
//! rec.record(StatsEvent::StepDone { seconds: 0.002 });
//! rec.record(StatsEvent::TokenStreamed { latency_s: 0.002 });
//! rec.record(StatsEvent::RequestDone { latency_s: 0.004, outcome: ReqOutcome::Completed });
//!
//! let report = hub.sample(0, true);
//! assert_eq!((report.n_submitted, report.n_admitted, report.n_completed), (1, 1, 1));
//! assert_eq!(report.generated_tokens, 1);
//! assert!(report.request_latency_ms.p50 <= report.request_latency_ms.p99);
//! // One JSON object per line — what `--stats-every` prints to stderr.
//! let line = report.to_json().to_string();
//! assert!(line.starts_with('{'));
//! ```

use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::{self, num, Json};

/// Default latency-window capacity (samples kept per percentile window).
pub const DEFAULT_WINDOW: usize = 4096;

/// Batch-occupancy histogram buckets: requests per dispatched batch,
/// power-of-two edges `1, 2, <=4, <=8, ..., <=128, >128`.
pub const N_OCCUPANCY_BUCKETS: usize = 9;

fn occupancy_bucket(requests: usize) -> usize {
    let r = requests.clamp(1, 1 << 16);
    (r.next_power_of_two().trailing_zeros() as usize).min(N_OCCUPANCY_BUCKETS - 1)
}

/// Fixed-capacity ring buffer: `push` beyond capacity overwrites the
/// oldest element (and reports it), so writers never block and never
/// grow.  Retrieval order is unspecified — the consumers here sort
/// (percentile windows) or drain wholesale (recorder rings).
#[derive(Debug, Clone)]
pub struct CircularQueue<T> {
    buf: Vec<T>,
    cap: usize,
    /// Next overwrite position once `buf` is full.
    next: usize,
    /// Everything ever pushed (monotonic, survives overwrites/drains).
    total: u64,
}

impl<T> CircularQueue<T> {
    pub fn new(cap: usize) -> CircularQueue<T> {
        assert!(cap > 0, "CircularQueue needs capacity >= 1");
        CircularQueue { buf: Vec::new(), cap, next: 0, total: 0 }
    }

    /// Append `v`; returns `true` when an old element was overwritten.
    pub fn push(&mut self, v: T) -> bool {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(v);
            false
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % self.cap;
            true
        }
    }

    /// Resident elements (<= capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Elements ever pushed, including overwritten ones.
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn iter(&self) -> std::slice::Iter<'_, T> {
        self.buf.iter()
    }

    /// Take every resident element out (the cumulative `total` stays).
    pub fn drain(&mut self) -> Vec<T> {
        self.next = 0;
        std::mem::take(&mut self.buf)
    }
}

/// How a served request ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqOutcome {
    /// Ran to its stop condition (forward pass replied; generation hit
    /// max-new-tokens or EOS).
    Completed,
    /// Cut short because its ticket was dropped mid-flight.
    Abandoned,
    /// Its batch failed in a pipeline stage.
    Failed,
}

/// One typed observation from a serve-loop thread.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StatsEvent {
    /// A well-formed submission reached admission control.
    Submitted,
    /// Admission reserved an in-flight slot.
    Admitted,
    /// Admission refused (queue full).
    Rejected,
    /// An admitted submission was rolled back (lost the race with
    /// shutdown) — it never entered the loop.
    Retracted,
    /// An admitted request expired via `request_timeout` (before
    /// dispatch, or when a generation rejoined the step pool).
    Expired,
    /// The scheduler dispatched a batch into the stage chain.
    BatchDispatched { requests: usize, prefill_tokens: usize, decode_tokens: usize },
    /// A stage thread spent `seconds` of busy time on one batch.
    StageBusy { seconds: f64 },
    /// A dispatched batch cleared the full stage chain.
    StepDone { seconds: f64 },
    /// One token was streamed to a ticket; `latency_s` is the gap since
    /// that request's previous token (or its enqueue, for the first).
    TokenStreamed { latency_s: f64 },
    /// A request reached a terminal state; `latency_s` is enqueue to
    /// completion.
    RequestDone { latency_s: f64, outcome: ReqOutcome },
}

/// A latency sample routed to its percentile window at sample time.
#[derive(Debug, Clone, Copy)]
enum LatSample {
    Step(f64),
    Token(f64),
    Request(f64),
}

/// Shared exact counters (every recorder bumps the same atomics).
struct Counters {
    submitted: AtomicUsize,
    admitted: AtomicUsize,
    rejected: AtomicUsize,
    retracted: AtomicUsize,
    expired: AtomicUsize,
    completed: AtomicUsize,
    abandoned: AtomicUsize,
    failed: AtomicUsize,
    steps: AtomicUsize,
    prefill_tokens: AtomicUsize,
    decode_tokens: AtomicUsize,
    generated_tokens: AtomicUsize,
    occupancy: [AtomicUsize; N_OCCUPANCY_BUCKETS],
    stage_busy_us: AtomicU64,
    /// Resident KV-cache bytes across live requests (gauge).
    kv_bytes: AtomicUsize,
    /// High-water mark of `kv_bytes`.
    kv_high_water: AtomicUsize,
    /// Paged-KV pool capacity in pages (0 when serving contiguously).
    kv_pool_pages: AtomicUsize,
    /// Free pages in the paged-KV pool (gauge).
    kv_free_pages: AtomicUsize,
    /// Distinct pages currently referenced by the prefix registry.
    kv_shared_pages: AtomicUsize,
    /// High-water mark of `kv_shared_pages`.
    kv_shared_pages_peak: AtomicUsize,
    /// Generations evicted and re-queued for recompute (cumulative).
    kv_preemptions: AtomicUsize,
    /// Copy-on-write forks off a shared prefix (cumulative).
    kv_cow_forks: AtomicUsize,
    /// Last observed scheduler backlog (gauge).
    queue_depth: AtomicUsize,
}

impl Counters {
    fn new() -> Counters {
        Counters {
            submitted: AtomicUsize::new(0),
            admitted: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            retracted: AtomicUsize::new(0),
            expired: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            abandoned: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            steps: AtomicUsize::new(0),
            prefill_tokens: AtomicUsize::new(0),
            decode_tokens: AtomicUsize::new(0),
            generated_tokens: AtomicUsize::new(0),
            occupancy: std::array::from_fn(|_| AtomicUsize::new(0)),
            stage_busy_us: AtomicU64::new(0),
            kv_bytes: AtomicUsize::new(0),
            kv_high_water: AtomicUsize::new(0),
            kv_pool_pages: AtomicUsize::new(0),
            kv_free_pages: AtomicUsize::new(0),
            kv_shared_pages: AtomicUsize::new(0),
            kv_shared_pages_peak: AtomicUsize::new(0),
            kv_preemptions: AtomicUsize::new(0),
            kv_cow_forks: AtomicUsize::new(0),
            queue_depth: AtomicUsize::new(0),
        }
    }

    fn kv_alloc(&self, delta: usize) {
        if delta == 0 {
            return;
        }
        let now = self.kv_bytes.fetch_add(delta, Ordering::Relaxed) + delta;
        self.kv_high_water.fetch_max(now, Ordering::Relaxed);
    }

    fn kv_free(&self, bytes: usize) {
        // Saturating: an error path may release an estimate.
        let _ = self
            .kv_bytes
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| Some(n.saturating_sub(bytes)));
    }

    fn set_kv_pool(
        &self,
        pool_pages: usize,
        free_pages: usize,
        shared_pages: usize,
        preemptions: usize,
        cow_forks: usize,
    ) {
        self.kv_pool_pages.store(pool_pages, Ordering::Relaxed);
        self.kv_free_pages.store(free_pages, Ordering::Relaxed);
        self.kv_shared_pages.store(shared_pages, Ordering::Relaxed);
        self.kv_shared_pages_peak.fetch_max(shared_pages, Ordering::Relaxed);
        self.kv_preemptions.store(preemptions, Ordering::Relaxed);
        self.kv_cow_forks.store(cow_forks, Ordering::Relaxed);
    }
}

struct RecorderInner {
    counters: Arc<Counters>,
    ring: Mutex<CircularQueue<LatSample>>,
    /// Latency samples overwritten before a sampler drained them.
    dropped: AtomicUsize,
}

/// Per-thread event recorder.  `Clone` shares the same ring (cheap Arc
/// clone); for true per-thread buffers ask the hub for one recorder per
/// thread ([`StatsHub::recorder`]).
#[derive(Clone)]
pub struct StatsRecorder(Arc<RecorderInner>);

impl StatsRecorder {
    /// Record one event.  Counting events are exact (shared atomics);
    /// latency samples go into this recorder's own bounded ring.
    pub fn record(&self, ev: StatsEvent) {
        let c = &self.0.counters;
        match ev {
            StatsEvent::Submitted => {
                c.submitted.fetch_add(1, Ordering::Relaxed);
            }
            StatsEvent::Admitted => {
                c.admitted.fetch_add(1, Ordering::Relaxed);
            }
            StatsEvent::Rejected => {
                c.rejected.fetch_add(1, Ordering::Relaxed);
            }
            StatsEvent::Retracted => {
                c.retracted.fetch_add(1, Ordering::Relaxed);
            }
            StatsEvent::Expired => {
                c.expired.fetch_add(1, Ordering::Relaxed);
            }
            StatsEvent::BatchDispatched { requests, prefill_tokens, decode_tokens } => {
                c.steps.fetch_add(1, Ordering::Relaxed);
                c.prefill_tokens.fetch_add(prefill_tokens, Ordering::Relaxed);
                c.decode_tokens.fetch_add(decode_tokens, Ordering::Relaxed);
                c.occupancy[occupancy_bucket(requests)].fetch_add(1, Ordering::Relaxed);
            }
            StatsEvent::StageBusy { seconds } => {
                c.stage_busy_us.fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
            }
            StatsEvent::StepDone { seconds } => self.push(LatSample::Step(seconds)),
            StatsEvent::TokenStreamed { latency_s } => {
                c.generated_tokens.fetch_add(1, Ordering::Relaxed);
                self.push(LatSample::Token(latency_s));
            }
            StatsEvent::RequestDone { latency_s, outcome } => {
                let ctr = match outcome {
                    ReqOutcome::Completed => &c.completed,
                    ReqOutcome::Abandoned => &c.abandoned,
                    ReqOutcome::Failed => &c.failed,
                };
                ctr.fetch_add(1, Ordering::Relaxed);
                self.push(LatSample::Request(latency_s));
            }
        }
    }

    fn push(&self, s: LatSample) {
        if self.0.ring.lock().unwrap_or_else(|e| e.into_inner()).push(s) {
            self.0.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Grow the resident-KV gauge by `delta` bytes (tracks high water).
    pub fn kv_alloc(&self, delta: usize) {
        self.0.counters.kv_alloc(delta);
    }

    /// Shrink the resident-KV gauge by `bytes` (a cache was dropped).
    pub fn kv_free(&self, bytes: usize) {
        self.0.counters.kv_free(bytes);
    }

    /// Publish the scheduler backlog observed at its last wakeup.
    pub fn set_queue_depth(&self, depth: usize) {
        self.0.counters.queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Publish a snapshot of the paged-KV pool gauges (plain numbers, so
    /// the stats plane stays decoupled from the model layer).  The
    /// shared-pages peak is tracked here via `fetch_max`; preemption and
    /// CoW-fork totals are cumulative counters owned by the pool.
    pub fn set_kv_pool(
        &self,
        pool_pages: usize,
        free_pages: usize,
        shared_pages: usize,
        preemptions: usize,
        cow_forks: usize,
    ) {
        self.0.counters.set_kv_pool(pool_pages, free_pages, shared_pages, preemptions, cow_forks);
    }
}

impl fmt::Debug for StatsRecorder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("StatsRecorder").finish_non_exhaustive()
    }
}

/// Sorted-at-sample-time percentile windows (one per latency kind).
struct Windows {
    step: CircularQueue<f64>,
    token: CircularQueue<f64>,
    request: CircularQueue<f64>,
    /// Snapshot state for interval rates.
    last_t: f64,
    last_prefill: usize,
    last_decode: usize,
}

/// The aggregation point: hands out recorders, owns the percentile
/// windows, and turns the current state into [`StatsReport`]s.
pub struct StatsHub {
    t0: Instant,
    counters: Arc<Counters>,
    recorders: Mutex<Vec<Arc<RecorderInner>>>,
    windows: Mutex<Windows>,
    /// Ring capacity for new recorders (same as the window capacity).
    ring_cap: usize,
}

impl StatsHub {
    /// A hub whose latency windows (and per-recorder rings) keep up to
    /// `window` samples each ([`DEFAULT_WINDOW`] is the serving
    /// default).
    pub fn new(window: usize) -> StatsHub {
        StatsHub {
            t0: Instant::now(),
            counters: Arc::new(Counters::new()),
            recorders: Mutex::new(Vec::new()),
            windows: Mutex::new(Windows {
                step: CircularQueue::new(window),
                token: CircularQueue::new(window),
                request: CircularQueue::new(window),
                last_t: 0.0,
                last_prefill: 0,
                last_decode: 0,
            }),
            ring_cap: window,
        }
    }

    /// A new recorder with its own latency ring, registered with this
    /// hub so [`StatsHub::sample`] drains it.
    pub fn recorder(&self) -> StatsRecorder {
        let inner = Arc::new(RecorderInner {
            counters: Arc::clone(&self.counters),
            ring: Mutex::new(CircularQueue::new(self.ring_cap)),
            dropped: AtomicUsize::new(0),
        });
        self.recorders.lock().unwrap_or_else(|e| e.into_inner()).push(Arc::clone(&inner));
        StatsRecorder(inner)
    }

    /// Grow the resident-KV gauge (also available on every recorder).
    pub fn kv_alloc(&self, delta: usize) {
        self.counters.kv_alloc(delta);
    }

    /// Shrink the resident-KV gauge (also available on every recorder).
    pub fn kv_free(&self, bytes: usize) {
        self.counters.kv_free(bytes);
    }

    /// Publish the paged-KV pool gauges (also available on recorders).
    pub fn set_kv_pool(
        &self,
        pool_pages: usize,
        free_pages: usize,
        shared_pages: usize,
        preemptions: usize,
        cow_forks: usize,
    ) {
        self.counters.set_kv_pool(pool_pages, free_pages, shared_pages, preemptions, cow_forks);
    }

    /// Drain every recorder ring into the percentile windows and
    /// snapshot everything into a [`StatsReport`].  `in_flight` is the
    /// caller-observed in-flight request count (the hub does not own
    /// the admission atomics); `is_final` marks the post-drain
    /// aggregate emitted once per run.
    pub fn sample(&self, in_flight: usize, is_final: bool) -> StatsReport {
        let mut w = self.windows.lock().unwrap_or_else(|e| e.into_inner());
        let mut events_dropped = 0usize;
        {
            let recorders = self.recorders.lock().unwrap_or_else(|e| e.into_inner());
            for rec in recorders.iter() {
                let drained = rec.ring.lock().unwrap_or_else(|e| e.into_inner()).drain();
                for s in drained {
                    match s {
                        LatSample::Step(v) => w.step.push(v * 1e3),
                        LatSample::Token(v) => w.token.push(v * 1e3),
                        LatSample::Request(v) => w.request.push(v * 1e3),
                    };
                }
                events_dropped += rec.dropped.load(Ordering::Relaxed);
            }
        }
        let c = &self.counters;
        let t_s = self.t0.elapsed().as_secs_f64();
        let interval_s = (t_s - w.last_t).max(1e-9);
        let prefill_tokens = c.prefill_tokens.load(Ordering::Relaxed);
        let decode_tokens = c.decode_tokens.load(Ordering::Relaxed);
        let report = StatsReport {
            t_s,
            interval_s,
            is_final,
            n_submitted: c.submitted.load(Ordering::Relaxed) - c.retracted.load(Ordering::Relaxed),
            n_admitted: c.admitted.load(Ordering::Relaxed) - c.retracted.load(Ordering::Relaxed),
            n_rejected: c.rejected.load(Ordering::Relaxed),
            n_expired: c.expired.load(Ordering::Relaxed),
            n_completed: c.completed.load(Ordering::Relaxed),
            n_abandoned: c.abandoned.load(Ordering::Relaxed),
            n_failed: c.failed.load(Ordering::Relaxed),
            in_flight,
            queue_depth: c.queue_depth.load(Ordering::Relaxed),
            n_steps: c.steps.load(Ordering::Relaxed),
            prefill_tokens,
            decode_tokens,
            generated_tokens: c.generated_tokens.load(Ordering::Relaxed),
            prefill_tokens_per_s: (prefill_tokens - w.last_prefill) as f64 / interval_s,
            decode_tokens_per_s: (decode_tokens - w.last_decode) as f64 / interval_s,
            batch_occupancy_hist: std::array::from_fn(|i| {
                c.occupancy[i].load(Ordering::Relaxed)
            }),
            stage_busy_s: c.stage_busy_us.load(Ordering::Relaxed) as f64 / 1e6,
            kv_bytes: c.kv_bytes.load(Ordering::Relaxed),
            kv_high_water_bytes: c.kv_high_water.load(Ordering::Relaxed),
            kv_pool_pages: c.kv_pool_pages.load(Ordering::Relaxed),
            kv_free_pages: c.kv_free_pages.load(Ordering::Relaxed),
            kv_shared_pages: c.kv_shared_pages.load(Ordering::Relaxed),
            kv_shared_pages_peak: c.kv_shared_pages_peak.load(Ordering::Relaxed),
            kv_preemptions: c.kv_preemptions.load(Ordering::Relaxed),
            kv_cow_forks: c.kv_cow_forks.load(Ordering::Relaxed),
            request_latency_ms: Percentiles::of_window(&w.request),
            token_latency_ms: Percentiles::of_window(&w.token),
            step_latency_ms: Percentiles::of_window(&w.step),
            events_dropped,
        };
        w.last_t = t_s;
        w.last_prefill = prefill_tokens;
        w.last_decode = decode_tokens;
        report
    }
}

impl fmt::Debug for StatsHub {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("StatsHub").field("ring_cap", &self.ring_cap).finish_non_exhaustive()
    }
}

/// Nearest-rank percentiles over a sample set.  Computed from a sorted
/// window, so `p50 <= p90 <= p99` always holds; an empty set reports
/// zeros with `n = 0`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Percentiles {
    /// Samples ever observed (the window keeps the most recent ones).
    pub n: u64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Percentiles {
    /// Percentiles of `samples` (sorted in place; `n` = its length).
    pub fn of(samples: &mut [f64]) -> Percentiles {
        samples.sort_by(|a, b| a.total_cmp(b));
        Percentiles {
            n: samples.len() as u64,
            p50: nearest_rank(samples, 0.50),
            p90: nearest_rank(samples, 0.90),
            p99: nearest_rank(samples, 0.99),
        }
    }

    fn of_window(w: &CircularQueue<f64>) -> Percentiles {
        let mut resident: Vec<f64> = w.iter().copied().collect();
        let mut p = Percentiles::of(&mut resident);
        p.n = w.total();
        p
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("n", num(self.n as f64)),
            ("p50", num(self.p50)),
            ("p90", num(self.p90)),
            ("p99", num(self.p99)),
        ])
    }
}

fn nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

/// One aggregated snapshot of the serving loop — what the sampler
/// thread emits every `--stats-every` tick and what the final report
/// carries after drain.
#[derive(Debug, Clone)]
pub struct StatsReport {
    /// Seconds since the loop (hub) started.
    pub t_s: f64,
    /// Seconds since the previous sample (= `t_s` for the first).
    pub interval_s: f64,
    /// True for the once-per-run post-drain aggregate.
    pub is_final: bool,
    /// Well-formed submissions (validated; net of shutdown retractions).
    pub n_submitted: usize,
    /// Submissions admitted into the loop (net of retractions).
    pub n_admitted: usize,
    /// Submissions refused at admission (queue full).
    pub n_rejected: usize,
    /// Admitted requests expired via `request_timeout`.
    pub n_expired: usize,
    /// Requests that ran to their stop condition.
    pub n_completed: usize,
    /// Requests cut short by a dropped ticket.
    pub n_abandoned: usize,
    /// Requests whose batch failed in a stage.
    pub n_failed: usize,
    /// Admitted-but-unfinished requests at sample time.
    pub in_flight: usize,
    /// Scheduler backlog at its last wakeup.
    pub queue_depth: usize,
    /// Batches dispatched into the stage chain.
    pub n_steps: usize,
    /// Prompt rows processed (prefill spans).
    pub prefill_tokens: usize,
    /// One-token decode rows processed.
    pub decode_tokens: usize,
    /// Tokens streamed to tickets.
    pub generated_tokens: usize,
    /// Prefill rows per second over the last interval.
    pub prefill_tokens_per_s: f64,
    /// Decode rows per second over the last interval.
    pub decode_tokens_per_s: f64,
    /// Requests-per-batch histogram, bucket edges `1, 2, <=4, <=8,
    /// <=16, <=32, <=64, <=128, >128`.
    pub batch_occupancy_hist: [usize; N_OCCUPANCY_BUCKETS],
    /// Summed stage-thread busy seconds.
    pub stage_busy_s: f64,
    /// Resident KV-cache bytes at sample time.
    pub kv_bytes: usize,
    /// High-water mark of resident KV-cache bytes.
    pub kv_high_water_bytes: usize,
    /// Paged-KV pool capacity in pages (0 when serving contiguously).
    pub kv_pool_pages: usize,
    /// Free pages in the paged-KV pool at sample time.
    pub kv_free_pages: usize,
    /// Distinct pages held live by the shared-prefix registry.
    pub kv_shared_pages: usize,
    /// High-water mark of `kv_shared_pages`.
    pub kv_shared_pages_peak: usize,
    /// Generations evicted and re-queued for recompute (cumulative).
    pub kv_preemptions: usize,
    /// Copy-on-write forks off a shared prefix (cumulative).
    pub kv_cow_forks: usize,
    /// Enqueue-to-terminal request latency.
    pub request_latency_ms: Percentiles,
    /// Inter-token latency (gap between consecutive streamed tokens).
    pub token_latency_ms: Percentiles,
    /// Full-stage-chain latency per dispatched batch.
    pub step_latency_ms: Percentiles,
    /// Latency samples lost to ring-buffer overwrites (cumulative).
    pub events_dropped: usize,
}

impl StatsReport {
    /// Pool pages held by live requests or the prefix registry
    /// (`kv_pool_pages - kv_free_pages`; 0 when serving contiguously).
    pub fn kv_used_pages(&self) -> usize {
        self.kv_pool_pages.saturating_sub(self.kv_free_pages)
    }

    /// Serialize as one flat JSON object (stable keys; percentile
    /// fields nest `{n, p50, p90, p99}`).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("t_s", num(self.t_s)),
            ("interval_s", num(self.interval_s)),
            ("final", Json::Bool(self.is_final)),
            ("n_submitted", num(self.n_submitted as f64)),
            ("n_admitted", num(self.n_admitted as f64)),
            ("n_rejected", num(self.n_rejected as f64)),
            ("n_expired", num(self.n_expired as f64)),
            ("n_completed", num(self.n_completed as f64)),
            ("n_abandoned", num(self.n_abandoned as f64)),
            ("n_failed", num(self.n_failed as f64)),
            ("in_flight", num(self.in_flight as f64)),
            ("queue_depth", num(self.queue_depth as f64)),
            ("n_steps", num(self.n_steps as f64)),
            ("prefill_tokens", num(self.prefill_tokens as f64)),
            ("decode_tokens", num(self.decode_tokens as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("prefill_tokens_per_s", num(self.prefill_tokens_per_s)),
            ("decode_tokens_per_s", num(self.decode_tokens_per_s)),
            (
                "batch_occupancy_hist",
                json::arr(self.batch_occupancy_hist.iter().map(|&n| num(n as f64)).collect()),
            ),
            ("stage_busy_s", num(self.stage_busy_s)),
            ("kv_bytes", num(self.kv_bytes as f64)),
            ("kv_high_water_bytes", num(self.kv_high_water_bytes as f64)),
            ("kv_pool_pages", num(self.kv_pool_pages as f64)),
            ("kv_free_pages", num(self.kv_free_pages as f64)),
            ("kv_used_pages", num(self.kv_used_pages() as f64)),
            ("kv_shared_pages", num(self.kv_shared_pages as f64)),
            ("kv_shared_pages_peak", num(self.kv_shared_pages_peak as f64)),
            ("kv_preemptions", num(self.kv_preemptions as f64)),
            ("kv_cow_forks", num(self.kv_cow_forks as f64)),
            ("request_latency_ms", self.request_latency_ms.to_json()),
            ("token_latency_ms", self.token_latency_ms.to_json()),
            ("step_latency_ms", self.step_latency_ms.to_json()),
            ("events_dropped", num(self.events_dropped as f64)),
        ])
    }
}

type SinkFn = dyn Fn(&StatsReport) + Send + Sync;

/// Where periodic reports go.  The default prints one JSON object per
/// line to stderr (log lines never start with `{`, so consumers can
/// `grep '^{'`); tests install collecting sinks via [`StatsSink::new`].
pub struct StatsSink(Arc<SinkFn>);

impl StatsSink {
    pub fn new(f: impl Fn(&StatsReport) + Send + Sync + 'static) -> StatsSink {
        StatsSink(Arc::new(f))
    }

    /// One compact JSON object per report, to stderr.
    pub fn stderr_json() -> StatsSink {
        StatsSink::new(|r| eprintln!("{}", r.to_json().to_string()))
    }

    pub fn emit(&self, report: &StatsReport) {
        (self.0)(report)
    }
}

impl Default for StatsSink {
    fn default() -> Self {
        StatsSink::stderr_json()
    }
}

impl Clone for StatsSink {
    fn clone(&self) -> Self {
        StatsSink(Arc::clone(&self.0))
    }
}

impl fmt::Debug for StatsSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("StatsSink(..)")
    }
}

/// Stop flag for the sampler thread: `wait_for` parks for one cadence
/// tick (or until stopped), `stop` wakes and ends it.
pub(super) struct SamplerStop {
    stopped: Mutex<bool>,
    cv: Condvar,
}

impl SamplerStop {
    pub(super) fn new() -> SamplerStop {
        SamplerStop { stopped: Mutex::new(false), cv: Condvar::new() }
    }

    /// Park for `interval`; returns `true` once stopped.
    pub(super) fn wait_for(&self, interval: Duration) -> bool {
        let deadline = Instant::now() + interval;
        let mut stopped = self.stopped.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if *stopped {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(stopped, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            stopped = guard;
        }
    }

    pub(super) fn stop(&self) {
        *self.stopped.lock().unwrap_or_else(|e| e.into_inner()) = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn circular_queue_overwrites_oldest_and_counts_total() {
        let mut q = CircularQueue::new(3);
        assert!(q.is_empty());
        assert!(!q.push(1));
        assert!(!q.push(2));
        assert!(!q.push(3));
        assert_eq!((q.len(), q.total()), (3, 3));
        // Fourth push overwrites the oldest (1).
        assert!(q.push(4));
        assert_eq!((q.len(), q.total()), (3, 4));
        let mut resident: Vec<i32> = q.iter().copied().collect();
        resident.sort_unstable();
        assert_eq!(resident, vec![2, 3, 4]);
        assert_eq!(q.drain().len(), 3);
        assert!(q.is_empty());
        assert_eq!(q.total(), 4, "total survives drain");
        // Refills cleanly after a drain.
        assert!(!q.push(5));
        assert_eq!((q.len(), q.total()), (1, 5));
    }

    #[test]
    fn percentiles_are_nearest_rank_and_monotone() {
        let mut one = vec![7.0];
        let p = Percentiles::of(&mut one);
        assert_eq!((p.n, p.p50, p.p90, p.p99), (1, 7.0, 7.0, 7.0));

        let mut v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Percentiles::of(&mut v);
        assert_eq!((p.p50, p.p90, p.p99), (50.0, 90.0, 99.0));

        let empty = Percentiles::of(&mut []);
        assert_eq!((empty.n, empty.p50, empty.p99), (0, 0.0, 0.0));

        // Monotone regardless of input order.
        let mut shuffled = vec![9.0, 0.5, 3.0, 3.0, 12.0, 1.0, 8.0];
        let p = Percentiles::of(&mut shuffled);
        assert!(p.p50 <= p.p90 && p.p90 <= p.p99, "{p:?}");
    }

    #[test]
    fn occupancy_buckets_have_power_of_two_edges() {
        assert_eq!(occupancy_bucket(0), 0, "degenerate batches clamp to 1");
        assert_eq!(occupancy_bucket(1), 0);
        assert_eq!(occupancy_bucket(2), 1);
        assert_eq!(occupancy_bucket(3), 2);
        assert_eq!(occupancy_bucket(4), 2);
        assert_eq!(occupancy_bucket(5), 3);
        assert_eq!(occupancy_bucket(128), 7);
        assert_eq!(occupancy_bucket(129), 8);
        assert_eq!(occupancy_bucket(1 << 20), 8, "overflow clamps to the last bucket");
    }

    #[test]
    fn hub_aggregates_events_and_reports_json_roundtrip() {
        let hub = StatsHub::new(16);
        let rec = hub.recorder();
        for _ in 0..3 {
            rec.record(StatsEvent::Submitted);
            rec.record(StatsEvent::Admitted);
        }
        rec.record(StatsEvent::Submitted);
        rec.record(StatsEvent::Rejected);
        rec.record(StatsEvent::BatchDispatched {
            requests: 3,
            prefill_tokens: 9,
            decode_tokens: 0,
        });
        rec.record(StatsEvent::BatchDispatched {
            requests: 2,
            prefill_tokens: 0,
            decode_tokens: 2,
        });
        rec.record(StatsEvent::StageBusy { seconds: 0.5 });
        rec.record(StatsEvent::StepDone { seconds: 0.010 });
        rec.record(StatsEvent::StepDone { seconds: 0.030 });
        for latency_s in [0.001, 0.002, 0.003] {
            rec.record(StatsEvent::TokenStreamed { latency_s });
        }
        rec.record(StatsEvent::RequestDone { latency_s: 0.05, outcome: ReqOutcome::Completed });
        rec.record(StatsEvent::RequestDone { latency_s: 0.07, outcome: ReqOutcome::Completed });
        rec.record(StatsEvent::RequestDone { latency_s: 0.02, outcome: ReqOutcome::Abandoned });
        rec.record(StatsEvent::Expired);
        hub.kv_alloc(1000);
        hub.kv_alloc(500);
        hub.kv_free(1200);

        let report = hub.sample(1, false);
        assert_eq!(report.n_submitted, 4);
        assert_eq!(report.n_admitted, 3);
        assert_eq!(report.n_rejected, 1);
        assert_eq!(report.n_expired, 1);
        assert_eq!(report.n_completed, 2);
        assert_eq!(report.n_abandoned, 1);
        assert_eq!(report.n_steps, 2);
        assert_eq!((report.prefill_tokens, report.decode_tokens), (9, 2));
        assert_eq!(report.generated_tokens, 3);
        assert_eq!(report.batch_occupancy_hist[occupancy_bucket(3)], 1);
        assert_eq!(report.batch_occupancy_hist[occupancy_bucket(2)], 1);
        assert!((report.stage_busy_s - 0.5).abs() < 1e-6);
        assert_eq!(report.kv_bytes, 300);
        assert_eq!(report.kv_high_water_bytes, 1500);
        assert_eq!(report.request_latency_ms.n, 3);
        assert!((report.request_latency_ms.p50 - 50.0).abs() < 1e-9);
        assert!(report.step_latency_ms.p50 <= report.step_latency_ms.p99);
        assert_eq!(report.events_dropped, 0);

        // JSON round-trips through the in-repo parser with the same
        // numbers the CI smoke step asserts on.
        let parsed = crate::util::json::Json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("n_admitted").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("generated_tokens").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("final"), Some(&Json::Bool(false)));
        let p = parsed.get("request_latency_ms").unwrap();
        let (p50, p90, p99) = (
            p.get("p50").unwrap().as_f64().unwrap(),
            p.get("p90").unwrap().as_f64().unwrap(),
            p.get("p99").unwrap().as_f64().unwrap(),
        );
        assert!(p50 <= p90 && p90 <= p99);
        assert_eq!(
            parsed.get("batch_occupancy_hist").unwrap().as_arr().unwrap().len(),
            N_OCCUPANCY_BUCKETS
        );
    }

    #[test]
    fn kv_pool_gauges_snapshot_and_track_the_shared_peak() {
        let hub = StatsHub::new(8);
        let rec = hub.recorder();
        // No paged pool published: everything stays zero.
        let report = hub.sample(0, false);
        assert_eq!((report.kv_pool_pages, report.kv_used_pages()), (0, 0));

        // Mid-flight snapshot: 3 of 16 pages shared, 10 free.
        rec.record(StatsEvent::Submitted); // gauges coexist with counters
        hub.set_kv_pool(16, 10, 3, 0, 1);
        let report = hub.sample(0, false);
        assert_eq!(report.kv_pool_pages, 16);
        assert_eq!(report.kv_free_pages, 10);
        assert_eq!(report.kv_used_pages(), 6);
        assert_eq!(report.kv_shared_pages, 3);
        assert_eq!(report.kv_shared_pages_peak, 3);
        assert_eq!((report.kv_preemptions, report.kv_cow_forks), (0, 1));

        // Drain: shared pages flushed and a preemption happened; the
        // peak stays at its high-water mark while the gauge drops.
        hub.set_kv_pool(16, 16, 0, 2, 1);
        let report = hub.sample(0, true);
        assert_eq!(report.kv_free_pages, 16);
        assert_eq!(report.kv_used_pages(), 0);
        assert_eq!(report.kv_shared_pages, 0);
        assert_eq!(report.kv_shared_pages_peak, 3, "peak is monotone");
        assert_eq!(report.kv_preemptions, 2);

        let parsed = crate::util::json::Json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("kv_pool_pages").unwrap().as_usize(), Some(16));
        assert_eq!(parsed.get("kv_used_pages").unwrap().as_usize(), Some(0));
        assert_eq!(parsed.get("kv_shared_pages_peak").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("kv_preemptions").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("kv_cow_forks").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn retracted_admissions_are_netted_out() {
        let hub = StatsHub::new(8);
        let rec = hub.recorder();
        rec.record(StatsEvent::Submitted);
        rec.record(StatsEvent::Admitted);
        rec.record(StatsEvent::Retracted);
        let report = hub.sample(0, true);
        assert_eq!((report.n_submitted, report.n_admitted), (0, 0));
    }

    #[test]
    fn ring_overflow_is_counted_not_blocking() {
        let hub = StatsHub::new(2);
        let rec = hub.recorder();
        for i in 0..5 {
            rec.record(StatsEvent::StepDone { seconds: i as f64 });
        }
        let report = hub.sample(0, false);
        // 2 resident samples survive, 3 were overwritten.
        assert_eq!(report.events_dropped, 3);
        assert_eq!(report.step_latency_ms.n, 2, "window keeps the resident samples");
    }

    #[test]
    fn interval_rates_reset_between_samples() {
        let hub = StatsHub::new(8);
        let rec = hub.recorder();
        rec.record(StatsEvent::BatchDispatched {
            requests: 1,
            prefill_tokens: 100,
            decode_tokens: 0,
        });
        let first = hub.sample(0, false);
        assert!(first.prefill_tokens_per_s > 0.0);
        // No new tokens since the last sample: the interval rate is
        // zero even though the cumulative counter is not.
        let second = hub.sample(0, false);
        assert_eq!(second.prefill_tokens, 100);
        assert_eq!(second.prefill_tokens_per_s, 0.0);
    }

    #[test]
    fn sampler_stop_wakes_the_waiter() {
        let stop = SamplerStop::new();
        assert!(!stop.wait_for(Duration::from_millis(1)), "not stopped yet: tick elapses");
        stop.stop();
        assert!(stop.wait_for(Duration::from_secs(3600)), "stopped: returns immediately");
    }
}
