//! Long-lived streaming serving loop.
//!
//! [`Server::run_sequential`]/[`Server::run_pipelined`] drain a fixed
//! request set to completion; this module keeps the server *alive*:
//! clients enqueue requests while batches are already in flight, the
//! micro-batcher thread wakes on arrival (condvar) or after a linger
//! timeout and dispatches token-budgeted batches into the decoder-layer
//! stage chain, and a collector thread hands every request its own rows
//! back through a per-request reply channel.
//!
//! Shutdown is a drain, not a drop: when the client closure returns (or
//! unwinds), the queue closes, everything already enqueued still flows
//! through every pipeline stage, and the worker threads join before
//! [`Server::run_streaming`] returns its [`StreamReport`].
//!
//! Backpressure: [`super::ServeCfg::queue_depth`] caps the in-flight
//! request count (submit fails fast with [`ServeError::QueueFull`]
//! instead of letting a stalled client grow the queue without bound),
//! and [`super::ServeCfg::request_timeout`] expires requests that sit
//! undispatched too long ([`ServeError::TimedOut`] through
//! [`Ticket::wait`]).  Every failure mode a ticket can observe is a
//! typed [`ServeError`].

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{MicroBatcher, Request};
use super::server::{Server, StageStats};
use super::stats::{
    ReqOutcome, SamplerStop, StatsEvent, StatsHub, StatsRecorder, StatsReport, StatsSink,
    DEFAULT_WINDOW,
};
use crate::runtime::ExecBackend;
use crate::tensor::Mat;

/// Typed failure of a streamed request — what a [`Ticket`] (or a decode
/// ticket, `super::GenTicket`) can observe, and what `submit` returns
/// when admission is refused.  Implements `std::error::Error`, so `?`
/// into `anyhow::Result` keeps working at call sites that don't match on
/// the variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at submit: malformed request (wrong width, empty, token
    /// outside the vocabulary, ...).
    Invalid(String),
    /// Rejected at submit: `queue_depth` requests are already in flight.
    QueueFull { depth: usize },
    /// Admitted but expired before dispatch: sat in the queue longer
    /// than `request_timeout`.
    TimedOut { waited_ms: u64 },
    /// Rejected at submit: the serving loop is shutting down.
    ShuttingDown,
    /// A pipeline stage failed while this request's batch was in flight.
    Stage(String),
    /// The serving loop dropped the reply channel (a worker panicked).
    Dropped,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Invalid(msg) => write!(f, "invalid request: {msg}"),
            ServeError::QueueFull { depth } => {
                write!(f, "queue full: {depth} requests already in flight")
            }
            ServeError::TimedOut { waited_ms } => {
                write!(f, "timed out after {waited_ms}ms in the queue")
            }
            ServeError::ShuttingDown => write!(f, "serving loop is shutting down"),
            ServeError::Stage(msg) => write!(f, "pipeline stage failed: {msg}"),
            ServeError::Dropped => write!(f, "serving loop dropped the reply"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Outcome of one streamed request.
type Reply = std::result::Result<Mat, ServeError>;

/// A claim on one in-flight request's output.  Waiting tickets in the
/// order they were issued gives each client per-submission-order
/// completion, regardless of how requests were coalesced or interleaved
/// with other clients.
pub struct Ticket {
    id: u64,
    rx: mpsc::Receiver<Reply>,
}

impl Ticket {
    /// Block until the serving loop finishes this request.  Tickets stay
    /// valid across shutdown: anything enqueued before the loop closed is
    /// still served and its output buffered here.  Failures are typed —
    /// see [`ServeError`].
    pub fn wait(self) -> std::result::Result<Mat, ServeError> {
        match self.rx.recv() {
            Ok(reply) => reply,
            Err(_) => Err(ServeError::Dropped),
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }
}

struct PendingReq {
    req: Request,
    reply: mpsc::Sender<Reply>,
    enqueued: Instant,
}

#[derive(Default)]
struct QueueState {
    pending: Vec<PendingReq>,
    closed: bool,
}

impl HasClosed for QueueState {
    fn set_closed(&mut self) {
        self.closed = true;
    }
}

/// Loop-specific queue states that carry a shutdown flag (the forward
/// loop's [`QueueState`], the decode loop's pool state in
/// `super::decode`).
pub(super) trait HasClosed {
    fn set_closed(&mut self);
}

/// The shared request queue between clients and a batcher/scheduler
/// thread, generic over the loop-specific state `S` so the forward
/// streaming loop and the decode loop share one admission-control and
/// backpressure implementation.
pub(super) struct SharedQueue<S> {
    pub(super) state: Mutex<S>,
    pub(super) arrived: Condvar,
    /// Requests admitted but not yet replied to (pending + batched + in
    /// the stage chain) — the quantity `queue_depth` caps.
    pub(super) in_flight: AtomicUsize,
    /// Requests ever admitted (monotonic).
    pub(super) admitted: AtomicUsize,
    /// Requests expired before dispatch (`request_timeout`).
    pub(super) timed_out: AtomicUsize,
    /// Submissions refused at admission (queue full).
    pub(super) rejected: AtomicUsize,
}

impl<S: Default> SharedQueue<S> {
    pub(super) fn new() -> SharedQueue<S> {
        SharedQueue {
            state: Mutex::new(S::default()),
            arrived: Condvar::new(),
            in_flight: AtomicUsize::new(0),
            admitted: AtomicUsize::new(0),
            timed_out: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
        }
    }
}

impl<S: Default> Default for SharedQueue<S> {
    fn default() -> Self {
        SharedQueue::new()
    }
}

impl<S: HasClosed> SharedQueue<S> {
    pub(super) fn close(&self) {
        // Robust against a client thread having panicked mid-submit: a
        // poisoned queue still closes so the worker threads drain.
        self.state.lock().unwrap_or_else(|e| e.into_inner()).set_closed();
        self.arrived.notify_all();
    }
}

impl<S> SharedQueue<S> {
    /// Admission control shared by the forward and decode loops: reserve
    /// an in-flight slot or refuse with the typed reason.  The reserve is
    /// a single atomic update — concurrent submits cannot both slip under
    /// the cap.
    pub(super) fn admit(&self, queue_depth: usize) -> std::result::Result<(), ServeError> {
        let reserved = self.in_flight.fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
            if queue_depth > 0 && n >= queue_depth {
                None
            } else {
                Some(n + 1)
            }
        });
        if reserved.is_err() {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::QueueFull { depth: queue_depth });
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Release an in-flight slot (request replied to or expired).
    pub(super) fn release(&self) {
        if self.in_flight.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Dropped to zero: the decode scheduler's exit predicate
            // (`closed && in_flight == 0`) may now hold, and it reads
            // `in_flight` outside the state mutex — take the mutex
            // before notifying so a scheduler between checking its
            // predicate and parking on the condvar (it holds the lock
            // for that whole window) cannot miss this wakeup.  Releases
            // that don't reach zero never wake anyone, so the forward
            // loop's per-request completions stay lock-free here.
            let _st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            self.arrived.notify_all();
        }
    }

    /// Roll back an [`SharedQueue::admit`] that never enqueued (the
    /// submit lost the race with shutdown).
    pub(super) fn unadmit(&self) {
        self.admitted.fetch_sub(1, Ordering::Relaxed);
        self.release();
    }

    /// If a request enqueued at `enqueued` has outlived `timeout` (zero
    /// disables), release its slot, count it, and hand back the typed
    /// error for the caller to deliver on its reply channel.  Shared by
    /// the forward and decode batcher threads.
    pub(super) fn stale(&self, enqueued: Instant, timeout: Duration) -> Option<ServeError> {
        let waited = enqueued.elapsed();
        if timeout.is_zero() || waited <= timeout {
            return None;
        }
        self.timed_out.fetch_add(1, Ordering::Relaxed);
        self.release();
        Some(ServeError::TimedOut { waited_ms: waited.as_millis() as u64 })
    }
}

/// Closes the queue even if the client closure unwinds, so the worker
/// threads never deadlock waiting for requests that will not come.
pub(super) struct CloseGuard<'q, S: HasClosed>(pub(super) &'q SharedQueue<S>);

impl<S: HasClosed> Drop for CloseGuard<'_, S> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Handle clients use to enqueue requests while the loop is live.  It is
/// `Copy` — hand one to every submitting thread (e.g. via
/// `std::thread::scope` inside the client closure).
#[derive(Clone, Copy)]
pub struct StreamClient<'q> {
    queue: &'q SharedQueue<QueueState>,
    next_id: &'q AtomicU64,
    width: usize,
    queue_depth: usize,
    stats: &'q StatsRecorder,
}

impl StreamClient<'_> {
    /// Activation width every request must match.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Enqueue `[tokens, width]` activations; returns a [`Ticket`] for
    /// the output.  Wakes the micro-batcher immediately — requests
    /// coalesce with whatever else is pending when the batch forms.
    /// Fails fast with [`ServeError::QueueFull`] when
    /// [`super::ServeCfg::queue_depth`] requests are already in flight.
    pub fn submit(&self, x: Mat) -> std::result::Result<Ticket, ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if x.cols() != self.width {
            return Err(ServeError::Invalid(format!(
                "request {id}: width {} != serving width {}",
                x.cols(),
                self.width
            )));
        }
        if x.rows() == 0 {
            return Err(ServeError::Invalid(format!("request {id}: empty activation batch")));
        }
        self.stats.record(StatsEvent::Submitted);
        if let Err(e) = self.queue.admit(self.queue_depth) {
            self.stats.record(StatsEvent::Rejected);
            return Err(e);
        }
        self.stats.record(StatsEvent::Admitted);
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.queue.state.lock().unwrap_or_else(|e| e.into_inner());
            if st.closed {
                // Drop the state lock first: `unadmit` -> `release`
                // re-takes it to publish the wakeup.
                drop(st);
                self.queue.unadmit();
                self.stats.record(StatsEvent::Retracted);
                return Err(ServeError::ShuttingDown);
            }
            st.pending.push(PendingReq {
                req: Request { id, x },
                reply: tx,
                enqueued: Instant::now(),
            });
        }
        self.queue.arrived.notify_one();
        Ok(Ticket { id, rx })
    }
}

/// A micro-batch mid-flight through the streaming stage chain.
struct StreamWork {
    batch: super::batcher::MicroBatch,
    x: Mat,
    /// Reply senders (with enqueue times, for request latency)
    /// parallel to `batch.ids`.
    replies: Vec<(mpsc::Sender<Reply>, Instant)>,
    /// When the batcher sent this batch into the stage chain — the
    /// step-latency clock.
    dispatched: Instant,
    stage_s: Vec<f64>,
    err: Option<String>,
}

/// Wall-clock + token accounting for one streaming run.
#[derive(Debug)]
pub struct StreamReport {
    /// Per-decoder-layer busy time.
    pub stage_stats: Vec<StageStats>,
    /// From loop start to full drain.
    pub total_seconds: f64,
    /// Tokens served (summed over completed batches).
    pub total_tokens: usize,
    /// Micro-batches dispatched.
    pub n_batches: usize,
    /// Requests served (including failed ones).
    pub n_requests: usize,
    /// Requests whose batch failed mid-pipeline (the error was forwarded
    /// to their tickets).
    pub n_failed: usize,
    /// Requests that expired in the queue ([`ServeError::TimedOut`]).
    pub n_timed_out: usize,
    /// Submissions refused at admission ([`ServeError::QueueFull`]).
    pub n_rejected: usize,
    /// Final post-drain metrics aggregate (latency percentiles, batch
    /// occupancy, interval rates) from the stats plane.
    pub stats: StatsReport,
}

impl StreamReport {
    /// End-to-end streaming throughput.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.total_tokens as f64 / self.total_seconds
        } else {
            0.0
        }
    }
}

impl Server {
    /// Run the long-lived streaming loop for the duration of `client_fn`.
    ///
    /// `engines` selects the execution mode: exactly one backend runs
    /// every decoder-layer stage on a single execution thread; one
    /// backend *per stage* (`>= n_stages`) builds the channel-connected
    /// pipelined chain, so stage `L` of batch `i` overlaps stage `L+1`
    /// of batch `i-1` while new requests are still arriving.  Either
    /// way backends move to worker threads, so they must be `Send` —
    /// non-`Send` backends are batch-mode-only.
    ///
    /// `client_fn` receives a [`StreamClient`] (`Copy` — share it across
    /// submitting threads) and may submit requests at any point; batches
    /// form concurrently, woken by arrival or after the configured
    /// [`super::ServeCfg::linger`].  When `client_fn` returns, the queue
    /// closes, every enqueued request drains through the pipeline
    /// stages, the workers join, and the closure's result is returned
    /// next to the loop's [`StreamReport`].
    pub fn run_streaming<R>(
        &self,
        engines: Vec<Box<dyn ExecBackend + Send>>,
        client_fn: impl FnOnce(StreamClient<'_>) -> R,
    ) -> Result<(R, StreamReport)> {
        let n_stages = self.model().n_stages();
        anyhow::ensure!(!engines.is_empty(), "streaming needs at least one backend");
        anyhow::ensure!(
            engines.len() == 1 || engines.len() >= n_stages,
            "streaming runs with 1 backend (all stages on one thread) or one per stage: \
             got {}, need 1 or >= {n_stages}",
            engines.len()
        );
        for engine in &engines {
            self.check_backend(engine.as_ref())?;
        }
        let model = self.model();
        let path = self.cfg().path;
        let linger = self.cfg().linger;
        let timeout = self.cfg().request_timeout;
        let queue_depth = self.cfg().queue_depth;
        let batcher_cfg = self.cfg().batcher.clone();
        let queue: SharedQueue<QueueState> = SharedQueue::new();
        let next_id = AtomicU64::new(0);
        // Metrics plane: one recorder per serve-loop thread (declared
        // out here so non-`move` worker closures can borrow them), a
        // sampler stop flag, and the sink periodic reports go to.
        let stats_every = self.cfg().stats_every;
        let sink = self.cfg().stats_sink.clone().unwrap_or_default();
        let hub = StatsHub::new(DEFAULT_WINDOW);
        let submit_stats = hub.recorder();
        let sched_stats = hub.recorder();
        let coll_stats = hub.recorder();
        let stop = SamplerStop::new();
        let t0 = Instant::now();

        let (result, tally) = std::thread::scope(|scope| {
            // ---- stage chain: batcher -> [stage threads] -> collector ----
            let (batch_tx, mut prev_rx) = mpsc::channel::<StreamWork>();
            if engines.len() == 1 {
                let mut engine = engines.into_iter().next().expect("len checked");
                let (tx, rx) = mpsc::channel::<StreamWork>();
                let rx_in = std::mem::replace(&mut prev_rx, rx);
                let stage_rec = hub.recorder();
                scope.spawn(move || {
                    for mut work in rx_in {
                        for layer in 0..n_stages {
                            if work.err.is_some() {
                                break;
                            }
                            let s0 = Instant::now();
                            let spans = work.batch.spans();
                            match model.stage(engine.as_mut(), layer, &work.x, spans, path) {
                                Ok(y) => {
                                    work.x = y;
                                    let s = s0.elapsed().as_secs_f64();
                                    work.stage_s.push(s);
                                    stage_rec.record(StatsEvent::StageBusy { seconds: s });
                                }
                                Err(e) => work.err = Some(format!("{e:#}")),
                            }
                        }
                        if tx.send(work).is_err() {
                            break;
                        }
                    }
                });
            } else {
                for (layer, mut engine) in engines.into_iter().take(n_stages).enumerate() {
                    let (tx, rx) = mpsc::channel::<StreamWork>();
                    let rx_in = std::mem::replace(&mut prev_rx, rx);
                    let stage_rec = hub.recorder();
                    scope.spawn(move || {
                        for mut work in rx_in {
                            if work.err.is_none() {
                                let s0 = Instant::now();
                                match model.stage(
                                    engine.as_mut(),
                                    layer,
                                    &work.x,
                                    work.batch.spans(),
                                    path,
                                ) {
                                    Ok(y) => {
                                        work.x = y;
                                        let s = s0.elapsed().as_secs_f64();
                                        work.stage_s.push(s);
                                        stage_rec.record(StatsEvent::StageBusy { seconds: s });
                                    }
                                    Err(e) => work.err = Some(format!("{e:#}")),
                                }
                            }
                            if tx.send(work).is_err() {
                                break;
                            }
                        }
                    });
                }
            }

            // ---- collector: split batch outputs, reply per request ----
            let queue_ref = &queue;
            let collector = scope.spawn(move || {
                let done_rx = prev_rx;
                let mut stage_stats: Vec<StageStats> = (0..n_stages)
                    .map(|layer| StageStats { layer, seconds: 0.0, tokens: 0 })
                    .collect();
                let (mut total_tokens, mut n_batches) = (0usize, 0usize);
                let (mut n_requests, mut n_failed) = (0usize, 0usize);
                for work in done_rx {
                    let StreamWork { mut batch, x, replies, dispatched, stage_s, err } = work;
                    // The batcher moved the activations out; restore the
                    // final-stage output so `tokens`/`split` see it.
                    batch.x = x;
                    n_batches += 1;
                    n_requests += batch.n_requests();
                    coll_stats.record(StatsEvent::StepDone {
                        seconds: dispatched.elapsed().as_secs_f64(),
                    });
                    let tokens = batch.tokens();
                    for (layer, s) in stage_s.iter().enumerate() {
                        stage_stats[layer].seconds += s;
                        stage_stats[layer].tokens += tokens;
                    }
                    if let Some(e) = err {
                        n_failed += batch.n_requests();
                        for (reply, enqueued) in &replies {
                            // A dropped ticket is fine; ignore send errors.
                            let _ = reply.send(Err(ServeError::Stage(e.clone())));
                            coll_stats.record(StatsEvent::RequestDone {
                                latency_s: enqueued.elapsed().as_secs_f64(),
                                outcome: ReqOutcome::Failed,
                            });
                            queue_ref.release();
                        }
                        continue;
                    }
                    total_tokens += tokens;
                    for ((_, y), (reply, enqueued)) in
                        batch.split(&batch.x).into_iter().zip(&replies)
                    {
                        let _ = reply.send(Ok(y));
                        coll_stats.record(StatsEvent::RequestDone {
                            latency_s: enqueued.elapsed().as_secs_f64(),
                            outcome: ReqOutcome::Completed,
                        });
                        queue_ref.release();
                    }
                }
                (stage_stats, total_tokens, n_batches, n_requests, n_failed)
            });

            // ---- batcher thread: condvar-woken micro-batching ----
            scope.spawn(|| {
                let tx = batch_tx;
                let mut mb = MicroBatcher::new(model.width(), batcher_cfg.clone());
                let mut replies: HashMap<u64, (mpsc::Sender<Reply>, Instant)> = HashMap::new();
                loop {
                    let drained: Vec<PendingReq> = {
                        let mut st = queue.state.lock().unwrap_or_else(|e| e.into_inner());
                        while st.pending.is_empty() && !st.closed {
                            st = queue.arrived.wait(st).unwrap_or_else(|e| e.into_inner());
                        }
                        if st.pending.is_empty() && st.closed {
                            break;
                        }
                        sched_stats.set_queue_depth(st.pending.len());
                        // Linger: give the batch a chance to fill before
                        // dispatching a partial one — cut short by the
                        // token budget, the request cap, or shutdown.
                        let deadline = Instant::now() + linger;
                        loop {
                            let tokens: usize =
                                st.pending.iter().map(|p| p.req.x.rows()).sum();
                            if st.closed
                                || tokens >= batcher_cfg.max_tokens
                                || st.pending.len() >= batcher_cfg.max_requests
                            {
                                break;
                            }
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            let woken = queue.arrived.wait_timeout(st, deadline - now);
                            let (guard, _) = woken.unwrap_or_else(|e| e.into_inner());
                            st = guard;
                        }
                        st.pending.drain(..).collect()
                    };
                    for p in drained {
                        // Expire requests that sat past the timeout (the
                        // linger window is the usual way to get here) —
                        // their tickets get the typed error instead of a
                        // stale dispatch.
                        if let Some(e) = queue.stale(p.enqueued, timeout) {
                            sched_stats.record(StatsEvent::Expired);
                            let _ = p.reply.send(Err(e));
                            continue;
                        }
                        replies.insert(p.req.id, (p.reply, p.enqueued));
                        mb.push(p.req).expect("client validated width/rows at submit");
                    }
                    while let Some(mut batch) = mb.next_batch() {
                        let batch_replies: Vec<_> = batch
                            .ids
                            .iter()
                            .map(|id| replies.remove(id).expect("one reply per request"))
                            .collect();
                        sched_stats.record(StatsEvent::BatchDispatched {
                            requests: batch.n_requests(),
                            prefill_tokens: batch.tokens(),
                            decode_tokens: 0,
                        });
                        let x = std::mem::replace(&mut batch.x, Mat::zeros(0, 0));
                        let work = StreamWork {
                            batch,
                            x,
                            replies: batch_replies,
                            dispatched: Instant::now(),
                            stage_s: Vec::with_capacity(n_stages),
                            err: None,
                        };
                        if tx.send(work).is_err() {
                            return; // stage chain died; nothing to do
                        }
                    }
                }
                // Dropping `tx` here lets the stage chain and collector
                // run dry and exit.
            });

            // ---- sampler: periodic StatsReport JSON while the loop runs ----
            if !stats_every.is_zero() {
                scope.spawn(|| {
                    while !stop.wait_for(stats_every) {
                        sink.emit(&hub.sample(queue.in_flight.load(Ordering::Acquire), false));
                    }
                });
            }

            // ---- client closure on the caller's thread ----
            let close = CloseGuard(&queue);
            let result = client_fn(StreamClient {
                queue: &queue,
                next_id: &next_id,
                width: model.width(),
                queue_depth,
                stats: &submit_stats,
            });
            drop(close); // close + notify so the batcher drains and exits
            let tally = collector.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
            stop.stop(); // the sampler parks in ticks; end it so the scope joins fast
            (result, tally)
        });

        // Final post-drain aggregate: always computed into the report;
        // emitted through the sink only when periodic stats were on (so
        // short runs still produce at least one JSON line).
        let stats = hub.sample(queue.in_flight.load(Ordering::Acquire), true);
        if !stats_every.is_zero() {
            sink.emit(&stats);
        }
        let (stage_stats, total_tokens, n_batches, n_requests, n_failed) = tally;
        Ok((
            result,
            StreamReport {
                stage_stats,
                total_seconds: t0.elapsed().as_secs_f64(),
                total_tokens,
                n_batches,
                n_requests,
                n_failed,
                n_timed_out: queue.timed_out.load(Ordering::Relaxed),
                n_rejected: queue.rejected.load(Ordering::Relaxed),
                stats,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::runtime::{NativeCfg, NativeEngine};
    use crate::serve::batcher::BatcherCfg;
    use crate::serve::model::tests::{tiny_sparse_model, whole};
    use crate::serve::{ServeCfg, ServePath};
    use crate::util::rng::Pcg32;
    use crate::util::testkit::assert_close;

    fn engines(n: usize, threads: usize) -> Vec<Box<dyn ExecBackend + Send>> {
        (0..n)
            .map(|_| {
                Box::new(NativeEngine::new(NativeCfg { threads, ..NativeCfg::default() }))
                    as Box<dyn ExecBackend + Send>
            })
            .collect()
    }

    fn streaming_server(path: ServePath) -> Server {
        Server::new(
            tiny_sparse_model(),
            ServeCfg {
                batcher: BatcherCfg { max_tokens: 16, max_requests: 4 },
                path,
                linger: Duration::from_millis(1),
                ..ServeCfg::default()
            },
        )
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full serving stack: too slow under Miri
    fn concurrent_clients_complete_in_submission_order() {
        let server = streaming_server(ServePath::FullDecoder);
        let srv = &server;
        let n_stages = server.model().n_stages();
        let width = server.model().width();
        let ((), report) = server
            .run_streaming(engines(n_stages, 1), |client| {
                std::thread::scope(|s| {
                    for t in 0..3u64 {
                        s.spawn(move || {
                            let mut rng = Pcg32::seeded(100 + t);
                            let mut in_flight = Vec::new();
                            for i in 0..4usize {
                                let rows = 1 + (t as usize + i) % 5;
                                let x = Mat::randn(rows, width, 1.0, &mut rng);
                                let ticket = client.submit(x.clone()).unwrap();
                                in_flight.push((ticket, x));
                            }
                            // Tickets were issued in this client's
                            // submission order (ids strictly increase).
                            let ids: Vec<u64> =
                                in_flight.iter().map(|(t, _)| t.id()).collect();
                            assert!(
                                ids.windows(2).all(|w| w[0] < w[1]),
                                "per-client ids not monotonic: {ids:?}"
                            );
                            for (ticket, x) in in_flight {
                                let y = ticket.wait().unwrap();
                                assert_eq!(y.shape(), x.shape());
                                // Parity against the per-request dense
                                // reference proves no cross-request mixup.
                                // (A swapped reply would be wildly off.)
                                let want = srv.model().dense_forward(
                                    &x,
                                    &whole(&x),
                                    ServePath::FullDecoder,
                                );
                                assert_close(y.data(), want.data(), 1e-3).unwrap();
                            }
                        });
                    }
                });
            })
            .unwrap();
        assert_eq!(report.n_requests, 12);
        assert_eq!(report.n_failed, 0);
        assert!(report.n_batches >= 1 && report.n_batches <= 12);
        let rows_total: usize =
            (0..3usize).flat_map(|t| (0..4).map(move |i| 1 + (t + i) % 5)).sum();
        assert_eq!(report.total_tokens, rows_total);
        for s in &report.stage_stats {
            assert_eq!(s.tokens, report.total_tokens, "stage {} token accounting", s.layer);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full serving stack: too slow under Miri
    fn shutdown_drains_in_flight_batches() {
        // The client closure returns while requests are still queued /
        // in flight; every ticket must still be honoured after the loop
        // exits, through every pipeline stage.
        let server = streaming_server(ServePath::FullDecoder);
        let n_stages = server.model().n_stages();
        let width = server.model().width();
        let (submitted, report) = server
            .run_streaming(engines(n_stages, 1), |client| {
                let mut rng = Pcg32::seeded(7);
                (0..7)
                    .map(|_| {
                        let x = Mat::randn(3, width, 1.0, &mut rng);
                        (client.submit(x.clone()).unwrap(), x)
                    })
                    .collect::<Vec<_>>()
                // Return immediately: nothing waited on yet.
            })
            .unwrap();
        assert_eq!(report.n_requests, 7);
        assert_eq!(report.n_failed, 0);
        assert_eq!(report.total_tokens, 21);
        for (ticket, x) in submitted {
            let y = ticket.wait().unwrap();
            let want = server.model().dense_forward(&x, &whole(&x), ServePath::FullDecoder);
            assert_close(y.data(), want.data(), 1e-3).unwrap();
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full serving stack: too slow under Miri
    fn single_backend_streaming_works_and_matches_pipelined() {
        let server = streaming_server(ServePath::FullDecoder);
        let n_stages = server.model().n_stages();
        let width = server.model().width();
        let run = |engs: Vec<Box<dyn ExecBackend + Send>>| {
            server
                .run_streaming(engs, |client| {
                    let mut rng = Pcg32::seeded(11);
                    (0..5)
                        .map(|_| client.submit(Mat::randn(4, width, 1.0, &mut rng)).unwrap())
                        .collect::<Vec<_>>()
                })
                .unwrap()
        };
        let (tickets_seq, _) = run(engines(1, 1));
        let (tickets_pipe, _) = run(engines(n_stages, 1));
        for (a, b) in tickets_seq.into_iter().zip(tickets_pipe) {
            // Same kernels, same tiling => bit-identical across modes.
            assert_eq!(a.wait().unwrap().data(), b.wait().unwrap().data());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full serving stack: too slow under Miri
    fn streaming_rejects_bad_submissions_and_engine_counts() {
        let server = streaming_server(ServePath::MlpOnly);
        let width = server.model().width();
        // An empty engine set is rejected up front.
        assert!(server.run_streaming(engines(0, 1), |_| ()).is_err());
        let ((), report) = server
            .run_streaming(engines(1, 1), |client| {
                // Wrong width and empty requests are rejected at submit,
                // with the typed reason.
                assert!(matches!(
                    client.submit(Mat::zeros(2, width + 1)),
                    Err(ServeError::Invalid(_))
                ));
                assert!(matches!(
                    client.submit(Mat::zeros(0, width)),
                    Err(ServeError::Invalid(_))
                ));
                client.submit(Mat::zeros(1, width)).unwrap().wait().unwrap();
            })
            .unwrap();
        assert_eq!(report.n_requests, 1);
        assert_eq!((report.n_timed_out, report.n_rejected), (0, 0));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full serving stack: too slow under Miri
    fn queue_depth_cap_rejects_with_queue_full() {
        // queue_depth = 1 and a long linger: the first request is parked
        // in the batch-forming window (its reply cannot arrive yet), so a
        // second submit inside that window must be refused, typed.
        let mut server = streaming_server(ServePath::MlpOnly);
        server.cfg_mut().queue_depth = 1;
        server.cfg_mut().linger = Duration::from_millis(400);
        server.cfg_mut().batcher = BatcherCfg { max_tokens: 1 << 20, max_requests: 1 << 20 };
        let width = server.model().width();
        let (first, report) = server
            .run_streaming(engines(1, 1), |client| {
                let first = client.submit(Mat::zeros(1, width)).unwrap();
                let err = client.submit(Mat::zeros(1, width)).unwrap_err();
                assert_eq!(err, ServeError::QueueFull { depth: 1 });
                first
            })
            .unwrap();
        first.wait().unwrap();
        assert_eq!(report.n_requests, 1);
        assert_eq!(report.n_rejected, 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full serving stack: too slow under Miri
    fn request_timeout_expires_through_the_ticket() {
        // Timeout far below the linger: the request sits through the
        // batch-forming window, expires at drain, and the ticket observes
        // the typed TimedOut instead of a result.
        let mut server = streaming_server(ServePath::MlpOnly);
        server.cfg_mut().request_timeout = Duration::from_millis(1);
        server.cfg_mut().linger = Duration::from_millis(150);
        server.cfg_mut().batcher = BatcherCfg { max_tokens: 1 << 20, max_requests: 1 << 20 };
        let width = server.model().width();
        let (ticket, report) = server
            .run_streaming(engines(1, 1), |client| {
                let t = client.submit(Mat::zeros(1, width)).unwrap();
                // Stay alive past the linger window so the batcher ages
                // the request out instead of the shutdown drain racing it.
                std::thread::sleep(Duration::from_millis(200));
                t
            })
            .unwrap();
        match ticket.wait() {
            Err(ServeError::TimedOut { waited_ms }) => assert!(waited_ms >= 1),
            other => panic!("expected TimedOut, got {other:?}"),
        }
        assert_eq!(report.n_timed_out, 1);
        assert_eq!(report.n_requests, 0, "expired requests never reach the stages");
        // The stats plane saw the same story.
        assert_eq!(report.stats.n_admitted, 1);
        assert_eq!(report.stats.n_expired, 1);
        assert_eq!(report.stats.n_completed, 0);
        assert_eq!(report.stats.in_flight, 0, "the expired request released its slot");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full serving stack: too slow under Miri
    fn counter_invariants_hold_under_concurrent_stress() {
        // Satellite: with client threads hammering a depth-2 queue,
        // `n_requests + n_timed_out` must equal the client-observed
        // successful submissions and `n_rejected` the refused ones —
        // whatever the interleaving.
        let mut server = streaming_server(ServePath::MlpOnly);
        server.cfg_mut().queue_depth = 2;
        server.cfg_mut().request_timeout = Duration::from_millis(250);
        let width = server.model().width();
        let (counts, report) = server
            .run_streaming(engines(1, 1), |client| {
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..4u64)
                        .map(|t| {
                            s.spawn(move || {
                                let (mut ok, mut rejected) = (0usize, 0usize);
                                let mut tickets = Vec::new();
                                for i in 0..6usize {
                                    let rows = 1 + (t as usize + i) % 3;
                                    match client.submit(Mat::zeros(rows, width)) {
                                        Ok(ticket) => {
                                            ok += 1;
                                            tickets.push(ticket);
                                        }
                                        Err(ServeError::QueueFull { .. }) => rejected += 1,
                                        Err(e) => panic!("unexpected submit error: {e}"),
                                    }
                                }
                                let (mut served, mut timed_out) = (0usize, 0usize);
                                for ticket in tickets {
                                    match ticket.wait() {
                                        Ok(_) => served += 1,
                                        Err(ServeError::TimedOut { .. }) => timed_out += 1,
                                        Err(e) => panic!("unexpected ticket error: {e}"),
                                    }
                                }
                                (ok, rejected, served, timed_out)
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).fold(
                        (0usize, 0usize, 0usize, 0usize),
                        |a, b| (a.0 + b.0, a.1 + b.1, a.2 + b.2, a.3 + b.3),
                    )
                })
            })
            .unwrap();
        let (ok, rejected, served, timed_out) = counts;
        assert_eq!(ok + rejected, 4 * 6, "every submit resolved one way");
        assert_eq!(ok, served + timed_out, "every ticket resolved one way");
        assert_eq!(
            report.n_requests + report.n_timed_out,
            ok,
            "admitted = served through the stages + expired"
        );
        assert_eq!(report.n_requests, served);
        assert_eq!(report.n_timed_out, timed_out);
        assert_eq!(report.n_rejected, rejected);
        assert_eq!(report.n_failed, 0);
        // The stats plane agrees with the queue counters and clients.
        assert_eq!(report.stats.n_admitted, ok);
        assert_eq!(report.stats.n_rejected, rejected);
        assert_eq!(report.stats.n_expired, timed_out);
        assert_eq!(report.stats.n_completed, served);
        assert_eq!(report.stats.in_flight, 0, "drained: nothing left in flight");
    }

    #[test]
    fn serve_error_displays_and_converts_to_anyhow() {
        let e = ServeError::QueueFull { depth: 8 };
        assert!(e.to_string().contains("8 requests"));
        let as_anyhow: anyhow::Error = e.into();
        assert!(format!("{as_anyhow:#}").contains("queue full"));
        assert!(ServeError::TimedOut { waited_ms: 5 }.to_string().contains("5ms"));
    }
}
