//! Trace-driven workload harness: seeded mixed-workload generation,
//! replay through the continuous-batching decode loop, SLO reporting.
//!
//! The decode loop's unit tests pin *correctness* (batching is
//! bit-transparent); this module measures *behavior under load*.  A
//! [`Trace`] is a replayable JSON description of a workload — request
//! arrival times, prompts, generation lengths, latency deadlines —
//! produced by the seeded [`generate`] so a workload can be
//! regenerated, committed, or shipped to CI and replayed identically.
//!
//! Four request classes cover the serving scenarios the stack was built
//! for:
//!
//! * [`CLASS_CHAT`] — short prompts, short generations: the
//!   interactive-latency case.
//! * [`CLASS_LONGDOC`] — long prompts, few new tokens: prefill-heavy
//!   summarization/extraction traffic that stresses KV admission.
//! * [`CLASS_BURST`] — chat-shaped requests arriving in Poisson-ish
//!   clusters instead of uniformly: queueing and backpressure.
//! * [`CLASS_PREFIX`] — fleets of requests sharing a page-aligned
//!   prompt prefix (same system prompt, different suffixes): with
//!   [`super::ServeCfg::kv_share_prefix`] these exercise copy-on-write
//!   page adoption in the paged KV pool.
//!
//! [`replay`] submits the trace against [`super::Server::
//! run_decode_streaming`] at the recorded arrival offsets, timestamps
//! every streamed token, and distills a per-class [`SloReport`] —
//! p50/p90/p99 first-token, per-token, and whole-request latency,
//! completion/timeout/reject/deadline-miss counts, and the KV pool's
//! preemption and CoW-fork totals — emitted beside the decode loop's
//! own [`super::StatsReport`].  Entry points: `permllm serve
//! --trace-gen` / `--trace` and the `trace_bench` section of the
//! `sparse_inference --json` artifact (fields documented in
//! `docs/BENCH_SCHEMA.md`).

use std::collections::BTreeMap;
use std::path::Path;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::{DecodeReport, GenRequest, Percentiles, Sampler, ServeError, Server};
use crate::runtime::ExecBackend;
use crate::util::json::{self, Json};
use crate::util::rng::Pcg32;

/// Short interactive turns: small prompt, small generation.
pub const CLASS_CHAT: &str = "chat";
/// Long-document prefill: big prompt, few new tokens.
pub const CLASS_LONGDOC: &str = "longdoc";
/// Chat-shaped requests arriving in tight Poisson-ish clusters.
pub const CLASS_BURST: &str = "burst";
/// Shared-prefix fleets (common system prompt, distinct suffixes).
pub const CLASS_PREFIX: &str = "prefix-fleet";

/// One request of a workload trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Stable id, assigned in arrival order at generation time.
    pub id: u64,
    /// Workload class ([`CLASS_CHAT`] etc.; free-form in hand-written
    /// traces).
    pub class: String,
    /// Submission time, milliseconds from replay start.
    pub arrival_ms: u64,
    /// Prompt token ids.
    pub prompt: Vec<u32>,
    /// Generation length (no EOS in synthetic traces).
    pub max_new_tokens: usize,
    /// Completion deadline in milliseconds from submission; 0 = none.
    /// Accounted by the replayer (deadline misses in the [`SloReport`]),
    /// not enforced by the server.
    pub deadline_ms: u64,
}

/// A replayable workload: seeded provenance plus the request list.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Generator seed (0 for hand-written traces).
    pub seed: u64,
    /// Vocabulary the prompt tokens were drawn from; replay checks it
    /// against the serving model.
    pub vocab: u32,
    pub requests: Vec<TraceRequest>,
}

/// Knobs for the seeded generator — class mix, arrival window, prefix
/// geometry, deadlines.
#[derive(Debug, Clone)]
pub struct TraceCfg {
    pub seed: u64,
    /// Vocabulary to draw prompt tokens from.
    pub vocab: u32,
    /// Request count per class ([`CLASS_CHAT`] / [`CLASS_LONGDOC`] /
    /// [`CLASS_BURST`]).
    pub chat: usize,
    pub longdoc: usize,
    pub burst: usize,
    /// Shared-prefix fleets: `fleets` groups of `fleet_size` requests,
    /// each group sharing one `prefix_tokens`-token prompt prefix.
    pub fleets: usize,
    pub fleet_size: usize,
    /// Arrival window in milliseconds — class arrivals spread over it.
    pub horizon_ms: u64,
    /// Shared-prefix length; align to the serving page size
    /// (`--kv-page-tokens`) so whole prefix pages are adoptable.
    pub prefix_tokens: usize,
    /// Base completion deadline in ms (0 disables); scaled per class —
    /// 1x chat/burst, 2x prefix fleets, 3x longdoc.
    pub deadline_ms: u64,
}

impl Default for TraceCfg {
    fn default() -> TraceCfg {
        TraceCfg {
            seed: 7,
            vocab: 256,
            chat: 8,
            longdoc: 2,
            burst: 6,
            fleets: 2,
            fleet_size: 3,
            horizon_ms: 300,
            prefix_tokens: 16,
            deadline_ms: 10_000,
        }
    }
}

impl TraceCfg {
    /// Rescale the class mix to roughly `total` requests, keeping the
    /// default proportions (the `--trace-requests` CLI knob).
    pub fn with_requests(mut self, total: usize) -> TraceCfg {
        let total = total.max(4);
        self.fleets = (total / 8).max(1);
        let rest = total.saturating_sub(self.fleets * self.fleet_size).max(3);
        self.chat = (rest * 2 / 5).max(1);
        self.burst = (rest * 2 / 5).max(1);
        self.longdoc = rest.saturating_sub(self.chat + self.burst).max(1);
        self
    }
}

fn rand_tokens(rng: &mut Pcg32, n: usize, vocab: u32) -> Vec<u32> {
    (0..n).map(|_| rng.below(vocab.max(1))).collect()
}

/// Milliseconds drawn from an exponential distribution with the given
/// mean — Poisson-ish inter-arrival gaps inside a burst.
fn exp_ms(rng: &mut Pcg32, mean: f32) -> u64 {
    let u = (1.0 - rng.uniform()).max(1e-6);
    (-u.ln() * mean) as u64
}

/// Generate a mixed workload deterministically from `cfg.seed`: the
/// same config always yields the same [`Trace`], byte-for-byte through
/// [`Trace::to_json`].
pub fn generate(cfg: &TraceCfg) -> Trace {
    let mut rng = Pcg32::new(cfg.seed, 0x7ace);
    let horizon = cfg.horizon_ms.max(1) as u32;
    let mut reqs: Vec<TraceRequest> = Vec::new();
    let mut push = |reqs: &mut Vec<TraceRequest>,
                    rng: &mut Pcg32,
                    class: &str,
                    arrival_ms: u64,
                    plen: usize,
                    max_new: usize,
                    deadline_mult: u64| {
        let prompt = rand_tokens(rng, plen, cfg.vocab);
        reqs.push(TraceRequest {
            id: 0, // assigned after the arrival sort
            class: class.to_string(),
            arrival_ms,
            prompt,
            max_new_tokens: max_new.max(1),
            deadline_ms: cfg.deadline_ms.saturating_mul(deadline_mult),
        });
    };

    for _ in 0..cfg.chat {
        let arrival = rng.below(horizon) as u64;
        let plen = 4 + rng.below_usize(9); // 4..=12
        let max_new = 2 + rng.below_usize(7); // 2..=8
        push(&mut reqs, &mut rng, CLASS_CHAT, arrival, plen, max_new, 1);
    }
    for _ in 0..cfg.longdoc {
        let arrival = rng.below(horizon) as u64;
        let plen = 32 + rng.below_usize(33); // 32..=64
        let max_new = 2 + rng.below_usize(3); // 2..=4
        push(&mut reqs, &mut rng, CLASS_LONGDOC, arrival, plen, max_new, 3);
    }
    // Bursts: cluster centers spread over the horizon, members packed
    // behind each center by exponential gaps.
    let mut left = cfg.burst;
    while left > 0 {
        let members = left.min(3);
        left -= members;
        let mut at = rng.below(horizon) as u64;
        for _ in 0..members {
            at += exp_ms(&mut rng, 3.0);
            let plen = 4 + rng.below_usize(7); // 4..=10
            let max_new = 2 + rng.below_usize(5); // 2..=6
            push(&mut reqs, &mut rng, CLASS_BURST, at, plen, max_new, 1);
        }
    }
    // Shared-prefix fleets: one prefix per fleet, members staggered so
    // the first member's prefill can publish its pages before the rest
    // are admitted (CoW adoption is opportunistic, not required).
    for _ in 0..cfg.fleets {
        let prefix = rand_tokens(&mut rng, cfg.prefix_tokens, cfg.vocab);
        let base = rng.below(horizon) as u64;
        for i in 0..cfg.fleet_size {
            let suffix = rand_tokens(&mut rng, 2 + rng.below_usize(5), cfg.vocab);
            let mut prompt = prefix.clone();
            prompt.extend_from_slice(&suffix);
            let max_new = 2 + rng.below_usize(4); // 2..=5
            reqs.push(TraceRequest {
                id: 0,
                class: CLASS_PREFIX.to_string(),
                arrival_ms: base + (i as u64) * 10,
                prompt,
                max_new_tokens: max_new,
                deadline_ms: cfg.deadline_ms.saturating_mul(2),
            });
        }
    }
    reqs.sort_by(|a, b| (a.arrival_ms, &a.class, &a.prompt).cmp(&(b.arrival_ms, &b.class, &b.prompt)));
    for (i, r) in reqs.iter_mut().enumerate() {
        r.id = i as u64;
    }
    Trace { seed: cfg.seed, vocab: cfg.vocab, requests: reqs }
}

impl Trace {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("seed", json::num(self.seed as f64)),
            ("vocab", json::num(self.vocab as f64)),
            (
                "requests",
                json::arr(
                    self.requests
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("id", json::num(r.id as f64)),
                                ("class", json::s(&r.class)),
                                ("arrival_ms", json::num(r.arrival_ms as f64)),
                                (
                                    "prompt",
                                    json::arr(
                                        r.prompt.iter().map(|&t| json::num(t as f64)).collect(),
                                    ),
                                ),
                                ("max_new_tokens", json::num(r.max_new_tokens as f64)),
                                ("deadline_ms", json::num(r.deadline_ms as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Trace> {
        let field = |o: &Json, k: &str| -> Result<f64> {
            o.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace: missing or non-numeric field {k:?}"))
        };
        let vocab = field(v, "vocab")? as u32;
        anyhow::ensure!(vocab > 0, "trace: vocab must be > 0");
        let reqs = v
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace: missing \"requests\" array"))?;
        let mut requests = Vec::with_capacity(reqs.len());
        for (i, r) in reqs.iter().enumerate() {
            let class = r
                .get("class")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("trace request {i}: missing \"class\""))?
                .to_string();
            let prompt = r
                .get("prompt")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("trace request {i}: missing \"prompt\""))?
                .iter()
                .map(|t| {
                    t.as_f64().map(|v| v as u32).ok_or_else(|| {
                        anyhow!("trace request {i}: non-numeric prompt token")
                    })
                })
                .collect::<Result<Vec<u32>>>()?;
            anyhow::ensure!(!prompt.is_empty(), "trace request {i}: empty prompt");
            let max_new = field(r, "max_new_tokens")? as usize;
            anyhow::ensure!(max_new >= 1, "trace request {i}: max_new_tokens must be >= 1");
            requests.push(TraceRequest {
                id: field(r, "id")? as u64,
                class,
                arrival_ms: field(r, "arrival_ms")? as u64,
                prompt,
                max_new_tokens: max_new,
                deadline_ms: field(r, "deadline_ms")? as u64,
            });
        }
        Ok(Trace { seed: field(v, "seed")? as u64, vocab, requests })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow!("writing trace {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading trace {}: {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("trace {}: {e:?}", path.display()))?;
        Trace::from_json(&v)
    }
}

// ---------------------------------------------------------------------
// replay
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum Outcome {
    Completed,
    Rejected,
    TimedOut,
    Failed,
}

/// Replayer-side record of one request's fate.
struct ReqResult {
    class: String,
    outcome: Outcome,
    /// Cumulative ms from submission to each streamed token.
    token_ms: Vec<f64>,
    total_ms: f64,
    deadline_missed: bool,
}

/// Per-class slice of the SLO report.
#[derive(Debug, Clone)]
pub struct ClassSlo {
    pub class: String,
    pub n_requests: u64,
    pub n_completed: u64,
    pub n_rejected: u64,
    pub n_timed_out: u64,
    pub n_failed: u64,
    /// Requests that blew their trace deadline (including every
    /// non-completed request that had one).
    pub n_deadline_missed: u64,
    /// Tokens streamed to this class.
    pub tokens: u64,
    /// Submission -> first streamed token.
    pub first_token_ms: Percentiles,
    /// Per-token latency: first-token gap, then inter-token gaps.
    pub token_latency_ms: Percentiles,
    /// Submission -> stream end, completed requests only.
    pub request_ms: Percentiles,
}

impl ClassSlo {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("class", json::s(&self.class)),
            ("n_requests", json::num(self.n_requests as f64)),
            ("n_completed", json::num(self.n_completed as f64)),
            ("n_rejected", json::num(self.n_rejected as f64)),
            ("n_timed_out", json::num(self.n_timed_out as f64)),
            ("n_failed", json::num(self.n_failed as f64)),
            ("n_deadline_missed", json::num(self.n_deadline_missed as f64)),
            ("tokens", json::num(self.tokens as f64)),
            ("first_token_ms", self.first_token_ms.to_json()),
            ("token_latency_ms", self.token_latency_ms.to_json()),
            ("request_ms", self.request_ms.to_json()),
        ])
    }
}

/// What a trace replay measured: totals, per-class latency percentiles,
/// and the KV pool counters relevant to load behavior.  Emitted beside
/// (not instead of) the decode loop's [`super::StatsReport`].
#[derive(Debug, Clone)]
pub struct SloReport {
    pub replay_seconds: f64,
    pub n_requests: u64,
    pub n_completed: u64,
    pub n_rejected: u64,
    pub n_timed_out: u64,
    pub n_failed: u64,
    pub n_deadline_missed: u64,
    pub generated_tokens: u64,
    pub kv_preemptions: u64,
    pub kv_cow_forks: u64,
    /// Per-class breakdown, sorted by class name.
    pub classes: Vec<ClassSlo>,
}

impl SloReport {
    fn build(results: &[ReqResult], report: &DecodeReport, replay_seconds: f64) -> SloReport {
        let mut by_class: BTreeMap<&str, Vec<&ReqResult>> = BTreeMap::new();
        for r in results {
            by_class.entry(&r.class).or_default().push(r);
        }
        let classes: Vec<ClassSlo> = by_class
            .into_iter()
            .map(|(class, rs)| {
                let mut first = Vec::new();
                let mut gaps = Vec::new();
                let mut totals = Vec::new();
                let mut tokens = 0u64;
                for r in &rs {
                    tokens += r.token_ms.len() as u64;
                    if let Some(&t0) = r.token_ms.first() {
                        first.push(t0);
                        gaps.push(t0);
                        gaps.extend(r.token_ms.windows(2).map(|w| w[1] - w[0]));
                    }
                    if r.outcome == Outcome::Completed {
                        totals.push(r.total_ms);
                    }
                }
                let count = |o: Outcome| rs.iter().filter(|r| r.outcome == o).count() as u64;
                ClassSlo {
                    class: class.to_string(),
                    n_requests: rs.len() as u64,
                    n_completed: count(Outcome::Completed),
                    n_rejected: count(Outcome::Rejected),
                    n_timed_out: count(Outcome::TimedOut),
                    n_failed: count(Outcome::Failed),
                    n_deadline_missed: rs.iter().filter(|r| r.deadline_missed).count() as u64,
                    tokens,
                    first_token_ms: Percentiles::of(&mut first),
                    token_latency_ms: Percentiles::of(&mut gaps),
                    request_ms: Percentiles::of(&mut totals),
                }
            })
            .collect();
        let total = |f: fn(&ClassSlo) -> u64| classes.iter().map(f).sum();
        SloReport {
            replay_seconds,
            n_requests: total(|c| c.n_requests),
            n_completed: total(|c| c.n_completed),
            n_rejected: total(|c| c.n_rejected),
            n_timed_out: total(|c| c.n_timed_out),
            n_failed: total(|c| c.n_failed),
            n_deadline_missed: total(|c| c.n_deadline_missed),
            generated_tokens: total(|c| c.tokens),
            kv_preemptions: report.stats.kv_preemptions as u64,
            kv_cow_forks: report.stats.kv_cow_forks as u64,
            classes,
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("replay_seconds", json::num(self.replay_seconds)),
            ("n_requests", json::num(self.n_requests as f64)),
            ("n_completed", json::num(self.n_completed as f64)),
            ("n_rejected", json::num(self.n_rejected as f64)),
            ("n_timed_out", json::num(self.n_timed_out as f64)),
            ("n_failed", json::num(self.n_failed as f64)),
            ("n_deadline_missed", json::num(self.n_deadline_missed as f64)),
            ("generated_tokens", json::num(self.generated_tokens as f64)),
            ("kv_preemptions", json::num(self.kv_preemptions as f64)),
            ("kv_cow_forks", json::num(self.kv_cow_forks as f64)),
            ("classes", json::arr(self.classes.iter().map(ClassSlo::to_json).collect())),
        ])
    }
}

/// Replay `trace` against the decode loop: submit each request at its
/// `arrival_ms` offset (greedy sampling, no EOS), stream and timestamp
/// every token from a per-request collector thread, and distill the
/// [`SloReport`].  `engines` follows the
/// [`super::Server::run_decode_streaming`] contract (1 backend, or one
/// per decoder layer).
///
/// Outcome mapping: a submit-time refusal ([`ServeError::QueueFull`],
/// invalid request, shutdown race) counts as rejected; a mid-stream
/// [`ServeError::TimedOut`] as timed out; any other stream error as
/// failed.  Deadlines are accounted here, not enforced by the server: a
/// request misses its deadline when it does not complete within
/// `deadline_ms` of submission (non-completed requests with a deadline
/// always miss).
pub fn replay(
    server: &Server,
    engines: Vec<Box<dyn ExecBackend + Send>>,
    trace: &Trace,
) -> Result<(SloReport, DecodeReport)> {
    let vocab = server.model().cfg().vocab as u32;
    anyhow::ensure!(!trace.requests.is_empty(), "trace has no requests");
    for r in &trace.requests {
        anyhow::ensure!(!r.prompt.is_empty(), "trace request {}: empty prompt", r.id);
        if let Some(&t) = r.prompt.iter().find(|&&t| t >= vocab) {
            anyhow::bail!(
                "trace request {}: token {t} out of the serving model's vocab {vocab}",
                r.id
            );
        }
    }
    let mut order: Vec<&TraceRequest> = trace.requests.iter().collect();
    order.sort_by_key(|r| (r.arrival_ms, r.id));
    let t0 = Instant::now();
    let (results, report) = server.run_decode_streaming(engines, |client| {
        thread::scope(|s| {
            let start = Instant::now();
            let mut joins = Vec::with_capacity(order.len());
            for req in &order {
                let due = Duration::from_millis(req.arrival_ms);
                let elapsed = start.elapsed();
                if due > elapsed {
                    thread::sleep(due - elapsed);
                }
                let submitted = Instant::now();
                let gen = GenRequest {
                    prompt: req.prompt.clone(),
                    max_new_tokens: req.max_new_tokens,
                    eos: None,
                    sampler: Sampler::Greedy,
                };
                match client.submit(gen) {
                    Ok(mut ticket) => {
                        let handle = s.spawn(move || {
                            let mut token_ms = Vec::new();
                            let mut err = None;
                            while let Some(t) = ticket.next_token() {
                                match t {
                                    Ok(_) => {
                                        token_ms.push(submitted.elapsed().as_secs_f64() * 1e3)
                                    }
                                    Err(e) => {
                                        err = Some(e);
                                        break;
                                    }
                                }
                            }
                            (token_ms, err, submitted.elapsed().as_secs_f64() * 1e3)
                        });
                        joins.push((*req, Ok(handle)));
                    }
                    Err(e) => joins.push((*req, Err(e))),
                }
            }
            joins
                .into_iter()
                .map(|(req, sub)| {
                    let (outcome, token_ms, total_ms) = match sub {
                        Ok(handle) => {
                            let (token_ms, err, total_ms) =
                                handle.join().expect("collector thread never panics");
                            let outcome = match err {
                                None => Outcome::Completed,
                                Some(ServeError::TimedOut { .. }) => Outcome::TimedOut,
                                Some(_) => Outcome::Failed,
                            };
                            (outcome, token_ms, total_ms)
                        }
                        Err(_) => (Outcome::Rejected, Vec::new(), 0.0),
                    };
                    let deadline_missed = req.deadline_ms > 0
                        && (outcome != Outcome::Completed
                            || total_ms > req.deadline_ms as f64);
                    ReqResult {
                        class: req.class.clone(),
                        outcome,
                        token_ms,
                        total_ms,
                        deadline_missed,
                    }
                })
                .collect::<Vec<ReqResult>>()
        })
    })?;
    let slo = SloReport::build(&results, &report, t0.elapsed().as_secs_f64());
    Ok((slo, report))
}

#[cfg(test)]
mod tests {
    use super::super::model_tests::tiny_sparse_model;
    use super::super::{BatcherCfg, ServeCfg, ServePath};
    use super::*;
    use crate::runtime::{NativeCfg, NativeEngine};

    fn small_cfg() -> TraceCfg {
        TraceCfg {
            chat: 3,
            longdoc: 1,
            burst: 3,
            fleets: 1,
            fleet_size: 3,
            horizon_ms: 40,
            ..TraceCfg::default()
        }
    }

    #[test]
    fn generator_is_deterministic_and_mixed() {
        let a = generate(&small_cfg());
        let b = generate(&small_cfg());
        assert_eq!(a, b, "same seed must regenerate the identical trace");
        assert_ne!(
            a.requests,
            generate(&TraceCfg { seed: 8, ..small_cfg() }).requests,
            "different seed must change the workload itself"
        );
        let classes: std::collections::BTreeSet<&str> =
            a.requests.iter().map(|r| r.class.as_str()).collect();
        for want in [CLASS_CHAT, CLASS_LONGDOC, CLASS_BURST, CLASS_PREFIX] {
            assert!(classes.contains(want), "missing class {want}");
        }
        assert_eq!(a.requests.len(), 3 + 1 + 3 + 3);
        for (i, r) in a.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64, "ids are assigned in sorted order");
            assert!(r.prompt.iter().all(|&t| t < a.vocab));
            assert!(r.max_new_tokens >= 1);
            if i > 0 {
                assert!(r.arrival_ms >= a.requests[i - 1].arrival_ms, "arrivals sorted");
            }
        }
    }

    #[test]
    fn fleet_members_share_a_page_aligned_prefix() {
        let cfg = small_cfg();
        let trace = generate(&cfg);
        let fleet: Vec<&TraceRequest> =
            trace.requests.iter().filter(|r| r.class == CLASS_PREFIX).collect();
        assert_eq!(fleet.len(), cfg.fleet_size);
        let prefix = &fleet[0].prompt[..cfg.prefix_tokens];
        for m in &fleet {
            assert!(m.prompt.len() > cfg.prefix_tokens, "suffix must be non-empty");
            assert_eq!(&m.prompt[..cfg.prefix_tokens], prefix, "shared prefix diverged");
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let trace = generate(&small_cfg());
        let text = trace.to_json().to_string();
        let back = Trace::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, trace);
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn from_json_rejects_malformed_traces() {
        for bad in [
            r#"{"seed": 1, "vocab": 256}"#,
            r#"{"seed": 1, "vocab": 0, "requests": []}"#,
            r#"{"seed": 1, "vocab": 256, "requests": [{"id": 0, "class": "chat",
                "arrival_ms": 0, "prompt": [], "max_new_tokens": 2, "deadline_ms": 0}]}"#,
            r#"{"seed": 1, "vocab": 256, "requests": [{"id": 0, "class": "chat",
                "arrival_ms": 0, "prompt": [1], "max_new_tokens": 0, "deadline_ms": 0}]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(Trace::from_json(&v).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn replay_smoke_accounts_every_request() {
        // A small mixed trace replayed end-to-end through the decode
        // loop with a paged, prefix-sharing KV pool: every request must
        // complete (no deadline pressure at these sizes), per-class
        // percentiles must be populated and monotone, and the totals
        // must reconcile with the DecodeReport.
        let cfg = TraceCfg { deadline_ms: 0, horizon_ms: 30, ..small_cfg() };
        let trace = generate(&cfg);
        let server = super::super::Server::new(
            tiny_sparse_model(),
            ServeCfg {
                batcher: BatcherCfg { max_tokens: 96, max_requests: 4 },
                path: ServePath::FullDecoder,
                linger: Duration::from_millis(1),
                kv_pages: 128,
                kv_page_tokens: 16,
                kv_share_prefix: true,
                ..ServeCfg::default()
            },
        );
        let engines: Vec<Box<dyn ExecBackend + Send>> =
            vec![Box::new(NativeEngine::new(NativeCfg { threads: 1, ..NativeCfg::default() }))];
        let (slo, report) = replay(&server, engines, &trace).unwrap();
        assert_eq!(slo.n_requests, trace.requests.len() as u64);
        assert_eq!(slo.n_completed, slo.n_requests, "nothing should fail at this load");
        assert_eq!(slo.n_deadline_missed, 0, "deadline 0 disables accounting");
        assert_eq!(slo.generated_tokens, report.generated_tokens as u64);
        assert_eq!(slo.n_completed, report.n_completed as u64);
        assert!(slo.classes.len() >= 3, "mixed trace must span classes");
        let want: u64 = trace
            .requests
            .iter()
            .map(|r| r.max_new_tokens as u64)
            .sum();
        assert_eq!(slo.generated_tokens, want, "greedy, no EOS => full lengths");
        for c in &slo.classes {
            assert_eq!(c.n_requests, c.n_completed);
            assert!(c.tokens > 0);
            for p in [&c.first_token_ms, &c.token_latency_ms, &c.request_ms] {
                assert!(p.n > 0, "{}: empty percentiles", c.class);
                assert!(
                    p.p50 <= p.p90 && p.p90 <= p.p99,
                    "{}: non-monotone percentiles {p:?}",
                    c.class
                );
            }
        }
    }
}
