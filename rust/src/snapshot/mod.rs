//! Versioned on-disk snapshots of a pruned, compressed [`SparseModel`].
//!
//! Serving normally re-runs pruning from scratch on every cold start;
//! a snapshot makes the pruned artifact a reusable asset instead.
//! [`dump`] serializes everything [`SparseModel`] needs to serve — the
//! per-linear compressed N:M payloads (values + absolute column indices
//! + channel permutation), the dense statics (token embedding, norms,
//! LM head), the [`crate::model::ModelConfig`], the
//! [`crate::sparsity::NmConfig`] pattern, and the producing
//! [`crate::recipe::PruneRecipe`] JSON descriptor — into a single
//! versioned binary container; [`load`] rebuilds a bit-identical model
//! from it (`permllm serve --snapshot model.bin`).
//!
//! # Container layout (version 1)
//!
//! The byte-level specification lives in `docs/SNAPSHOT_FORMAT.md`; in
//! short:
//!
//! ```text
//! magic "PMSN" | version u32 | n_sections u32
//! section table: n_sections x (tag u32, byte_len u64)
//! section payloads, concatenated in table order
//! FNV-1a64 checksum over every preceding byte (u64)
//! ```
//!
//! All integers are little-endian.  Version 1 requires exactly the five
//! known sections in ascending tag order: CONFIG(1), NM(2), RECIPE(3),
//! STATICS(4), LAYERS(5).
//!
//! # Integrity
//!
//! [`Snapshot::decode`] rejects hostile or damaged input with a typed
//! [`SnapshotError`] — never a panic: wrong magic ([`SnapshotError::
//! BadMagic`]), unknown format version ([`SnapshotError::WrongVersion`]),
//! short reads ([`SnapshotError::Truncated`]), checksum mismatch
//! ([`SnapshotError::ChecksumMismatch`]), and structural damage inside a
//! checksum-valid container ([`SnapshotError::Corrupt`]).  Semantic
//! validation (group structure of the N:M indices, permutation
//! invariants, shape agreement with the config) happens in
//! [`SparseModel::from_snapshot`], which routes every compressed payload
//! back through [`crate::sparsity::Compressed::from_parts`].

use std::collections::BTreeSet;
use std::fmt;
use std::path::Path;

use crate::model::ModelConfig;
use crate::serve::SparseModel;
use crate::sparsity::NmConfig;
use crate::tensor::Mat;

/// First four bytes of every snapshot file.
pub const MAGIC: [u8; 4] = *b"PMSN";
/// Current container format version; see `docs/SNAPSHOT_FORMAT.md` for
/// the compatibility policy (what bumps it).
pub const VERSION: u32 = 1;

const TAG_CONFIG: u32 = 1;
const TAG_NM: u32 = 2;
const TAG_RECIPE: u32 = 3;
const TAG_STATICS: u32 = 4;
const TAG_LAYERS: u32 = 5;

/// Typed decode/IO failures; hostile input maps to exactly one variant.
#[derive(Debug)]
pub enum SnapshotError {
    /// Reading or writing the file failed at the OS level.
    Io(std::io::Error),
    /// The first four bytes are not [`MAGIC`] — not a snapshot file.
    BadMagic { found: [u8; 4] },
    /// A snapshot, but from an incompatible format version.
    WrongVersion { found: u32, expected: u32 },
    /// The buffer ends before the declared layout does.
    Truncated { needed: usize, have: usize },
    /// The trailing FNV-1a64 digest does not match the content.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// Structurally invalid content inside a checksum-valid container
    /// (bad section table, overrunning payload, non-UTF-8 string, ...).
    Corrupt(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "snapshot io error: {e}"),
            SnapshotError::BadMagic { found } => {
                write!(f, "snapshot has bad magic {found:02x?} (expected {MAGIC:02x?} \"PMSN\")")
            }
            SnapshotError::WrongVersion { found, expected } => {
                write!(f, "snapshot format version {found} is not supported (expected {expected})")
            }
            SnapshotError::Truncated { needed, have } => {
                write!(f, "snapshot truncated: need {needed} bytes, have {have}")
            }
            SnapshotError::ChecksumMismatch { stored, computed } => write!(
                f,
                "snapshot checksum mismatch: stored {stored:016x}, computed {computed:016x}"
            ),
            SnapshotError::Corrupt(msg) => write!(f, "snapshot corrupt: {msg}"),
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SnapshotError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> SnapshotError {
        SnapshotError::Io(e)
    }
}

/// FNV-1a 64-bit over `bytes` — the container's content digest, also
/// used by the serve CLI to fingerprint outputs for bit-identity diffs.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One compressed linear as stored on disk: the exact artifact-input
/// tensors a [`crate::serve::SparseLayer`] caches at build time.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotLayer {
    /// Parameter name (`layers.{l}.{wq|wk|wv|wo|w_gate|w_up|w_down}`).
    pub name: String,
    pub c_out: usize,
    pub c_in: usize,
    /// Retained values `[C_out, K]`, row-major.
    pub vals: Vec<f32>,
    /// Absolute column indices `[C_out, K]` (the `sparse_fwd` layout).
    pub idx: Vec<u32>,
    /// Channel permutation: `src_of[j]` = original column serving
    /// storage column `j`.
    pub src_of: Vec<u32>,
}

/// In-memory form of one snapshot file.
///
/// Produced by [`SparseModel::to_snapshot`], consumed by
/// [`SparseModel::from_snapshot`]; [`Snapshot::encode`] /
/// [`Snapshot::decode`] are the byte-exact container codec.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub cfg: ModelConfig,
    pub nm: NmConfig,
    /// Canonical recipe label (e.g. `PermLLM_Wanda+SparseGPT`).
    pub recipe_name: String,
    /// The producing recipe's JSON descriptor, stored as raw text so
    /// encode/decode round-trips are byte-exact.
    pub recipe_json: String,
    /// Dense statics by parameter name: `tok_embed`, `final_norm`,
    /// `lm_head`, then per-layer `attn_norm` / `mlp_norm` gains.
    pub statics: Vec<(String, Mat)>,
    /// Every compressed prunable linear, in
    /// [`ModelConfig::prunable_linears`] order (deterministic bytes).
    pub layers: Vec<SnapshotLayer>,
}

// ---------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

impl Snapshot {
    /// Serialize to the container byte layout (including the trailing
    /// checksum).  Deterministic: the same snapshot always encodes to
    /// the same bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut config = Vec::new();
        put_str(&mut config, &self.cfg.name);
        for v in [
            self.cfg.vocab,
            self.cfg.dim,
            self.cfg.n_layers,
            self.cfg.n_heads,
            self.cfg.ffn,
            self.cfg.seq_len,
        ] {
            put_u32(&mut config, v as u32);
        }
        put_f32(&mut config, self.cfg.rope_theta);
        put_f32(&mut config, self.cfg.norm_eps);

        let mut nm = Vec::new();
        put_u32(&mut nm, self.nm.m as u32);
        put_u32(&mut nm, self.nm.keep as u32);

        let mut recipe = Vec::new();
        put_str(&mut recipe, &self.recipe_name);
        put_str(&mut recipe, &self.recipe_json);

        let mut statics = Vec::new();
        put_u32(&mut statics, self.statics.len() as u32);
        for (name, mat) in &self.statics {
            put_str(&mut statics, name);
            put_u32(&mut statics, mat.rows() as u32);
            put_u32(&mut statics, mat.cols() as u32);
            for &v in mat.data() {
                put_f32(&mut statics, v);
            }
        }

        let mut layers = Vec::new();
        put_u32(&mut layers, self.layers.len() as u32);
        for l in &self.layers {
            put_str(&mut layers, &l.name);
            put_u32(&mut layers, l.c_out as u32);
            put_u32(&mut layers, l.c_in as u32);
            let k = if l.c_out == 0 { 0 } else { l.vals.len() / l.c_out };
            put_u32(&mut layers, k as u32);
            for &v in &l.vals {
                put_f32(&mut layers, v);
            }
            for &v in &l.idx {
                put_u32(&mut layers, v);
            }
            for &v in &l.src_of {
                put_u32(&mut layers, v);
            }
        }

        let sections: [(u32, Vec<u8>); 5] = [
            (TAG_CONFIG, config),
            (TAG_NM, nm),
            (TAG_RECIPE, recipe),
            (TAG_STATICS, statics),
            (TAG_LAYERS, layers),
        ];
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        put_u32(&mut out, VERSION);
        put_u32(&mut out, sections.len() as u32);
        for (tag, payload) in &sections {
            put_u32(&mut out, *tag);
            put_u64(&mut out, payload.len() as u64);
        }
        for (_, payload) in &sections {
            out.extend_from_slice(payload);
        }
        let digest = fnv1a64(&out);
        put_u64(&mut out, digest);
        out
    }

    /// Encode and write to `path`.
    pub fn write_to(&self, path: &Path) -> Result<(), SnapshotError> {
        std::fs::write(path, self.encode())?;
        Ok(())
    }

    /// Read `path` and decode.
    pub fn read_from(path: &Path) -> Result<Snapshot, SnapshotError> {
        let bytes = std::fs::read(path)?;
        Snapshot::decode(&bytes)
    }

    /// Decode a container, validating magic, version, section table,
    /// and checksum before touching any payload.
    pub fn decode(buf: &[u8]) -> Result<Snapshot, SnapshotError> {
        if buf.len() < 4 {
            return Err(SnapshotError::Truncated { needed: 4, have: buf.len() });
        }
        if buf[..4] != MAGIC {
            let mut found = [0u8; 4];
            found.copy_from_slice(&buf[..4]);
            return Err(SnapshotError::BadMagic { found });
        }
        if buf.len() < 12 {
            return Err(SnapshotError::Truncated { needed: 12, have: buf.len() });
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().expect("len checked"));
        if version != VERSION {
            return Err(SnapshotError::WrongVersion { found: version, expected: VERSION });
        }
        let n_sections = u32::from_le_bytes(buf[8..12].try_into().expect("len checked")) as usize;
        if n_sections != 5 {
            return Err(SnapshotError::Corrupt(format!(
                "version 1 has exactly 5 sections, table declares {n_sections}"
            )));
        }
        let table_end = 12 + n_sections * 12;
        if buf.len() < table_end {
            return Err(SnapshotError::Truncated { needed: table_end, have: buf.len() });
        }
        let mut lens = Vec::with_capacity(n_sections);
        let mut total = table_end as u64;
        for i in 0..n_sections {
            let off = 12 + i * 12;
            let tag = u32::from_le_bytes(buf[off..off + 4].try_into().expect("len checked"));
            if tag != (i as u32) + 1 {
                return Err(SnapshotError::Corrupt(format!(
                    "section {i} has tag {tag}, version 1 requires tag {}",
                    i + 1
                )));
            }
            let len =
                u64::from_le_bytes(buf[off + 4..off + 12].try_into().expect("len checked"));
            total = total.checked_add(len).ok_or_else(|| {
                SnapshotError::Corrupt(format!("section {i} length {len} overflows the layout"))
            })?;
            lens.push(len);
        }
        let total = total.checked_add(8).ok_or_else(|| {
            SnapshotError::Corrupt("declared layout overflows u64".to_string())
        })?;
        if total > usize::MAX as u64 || buf.len() < total as usize {
            return Err(SnapshotError::Truncated {
                needed: total.min(usize::MAX as u64) as usize,
                have: buf.len(),
            });
        }
        let total = total as usize;
        if buf.len() > total {
            return Err(SnapshotError::Corrupt(format!(
                "{} trailing bytes after the declared layout",
                buf.len() - total
            )));
        }
        let stored =
            u64::from_le_bytes(buf[total - 8..total].try_into().expect("len checked"));
        let computed = fnv1a64(&buf[..total - 8]);
        if stored != computed {
            return Err(SnapshotError::ChecksumMismatch { stored, computed });
        }

        let mut off = table_end;
        let mut sections = Vec::with_capacity(n_sections);
        for &len in &lens {
            let len = len as usize;
            sections.push(&buf[off..off + len]);
            off += len;
        }

        let cfg = decode_config(sections[0])?;
        let nm = decode_nm(sections[1])?;
        let (recipe_name, recipe_json) = decode_recipe(sections[2])?;
        let statics = decode_statics(sections[3])?;
        let layers = decode_layers(sections[4])?;
        Ok(Snapshot { cfg, nm, recipe_name, recipe_json, statics, layers })
    }
}

// ---------------------------------------------------------------------
// decode (per-section cursors)
// ---------------------------------------------------------------------

/// Bounds-checked cursor over one section's payload.  Container-level
/// lengths and the checksum are already validated, so any overrun here
/// is structural corruption, not truncation.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
    section: &'static str,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8], section: &'static str) -> Cursor<'a> {
        Cursor { buf, pos: 0, section }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            SnapshotError::Corrupt(format!("{} section: offset overflow", self.section))
        })?;
        if end > self.buf.len() {
            return Err(SnapshotError::Corrupt(format!(
                "{} section: payload overruns its declared {} bytes",
                self.section,
                self.buf.len()
            )));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("len checked")))
    }

    fn f32(&mut self) -> Result<f32, SnapshotError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("len checked")))
    }

    fn str_(&mut self) -> Result<String, SnapshotError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| {
            SnapshotError::Corrupt(format!("{} section: non-UTF-8 string", self.section))
        })
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, SnapshotError> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            SnapshotError::Corrupt(format!("{} section: element count overflow", self.section))
        })?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("chunk is 4")))
            .collect())
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>, SnapshotError> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            SnapshotError::Corrupt(format!("{} section: element count overflow", self.section))
        })?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().expect("chunk is 4")))
            .collect())
    }

    fn finish(&self) -> Result<(), SnapshotError> {
        if self.pos != self.buf.len() {
            return Err(SnapshotError::Corrupt(format!(
                "{} section: {} unread trailing bytes",
                self.section,
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn decode_config(buf: &[u8]) -> Result<ModelConfig, SnapshotError> {
    let mut c = Cursor::new(buf, "CONFIG");
    let name = c.str_()?;
    let vocab = c.u32()? as usize;
    let dim = c.u32()? as usize;
    let n_layers = c.u32()? as usize;
    let n_heads = c.u32()? as usize;
    let ffn = c.u32()? as usize;
    let seq_len = c.u32()? as usize;
    let rope_theta = c.f32()?;
    let norm_eps = c.f32()?;
    c.finish()?;
    Ok(ModelConfig { name, vocab, dim, n_layers, n_heads, ffn, seq_len, rope_theta, norm_eps })
}

fn decode_nm(buf: &[u8]) -> Result<NmConfig, SnapshotError> {
    let mut c = Cursor::new(buf, "NM");
    let m = c.u32()? as usize;
    let keep = c.u32()? as usize;
    c.finish()?;
    if m == 0 || keep == 0 || keep > m {
        return Err(SnapshotError::Corrupt(format!("NM section: bad pattern m={m} keep={keep}")));
    }
    Ok(NmConfig { m, keep })
}

fn decode_recipe(buf: &[u8]) -> Result<(String, String), SnapshotError> {
    let mut c = Cursor::new(buf, "RECIPE");
    let name = c.str_()?;
    let json = c.str_()?;
    c.finish()?;
    Ok((name, json))
}

fn decode_statics(buf: &[u8]) -> Result<Vec<(String, Mat)>, SnapshotError> {
    let mut c = Cursor::new(buf, "STATICS");
    let count = c.u32()? as usize;
    let mut out = Vec::new();
    let mut seen = BTreeSet::new();
    for _ in 0..count {
        let name = c.str_()?;
        if !seen.insert(name.clone()) {
            return Err(SnapshotError::Corrupt(format!("STATICS section: duplicate {name}")));
        }
        let rows = c.u32()? as usize;
        let cols = c.u32()? as usize;
        let n = rows.checked_mul(cols).ok_or_else(|| {
            SnapshotError::Corrupt(format!("STATICS section: {name} shape overflow"))
        })?;
        let data = c.f32s(n)?;
        let mat = Mat::from_vec(rows, cols, data);
        out.push((name, mat));
    }
    c.finish()?;
    Ok(out)
}

fn decode_layers(buf: &[u8]) -> Result<Vec<SnapshotLayer>, SnapshotError> {
    let mut c = Cursor::new(buf, "LAYERS");
    let count = c.u32()? as usize;
    let mut out = Vec::new();
    let mut seen = BTreeSet::new();
    for _ in 0..count {
        let name = c.str_()?;
        if !seen.insert(name.clone()) {
            return Err(SnapshotError::Corrupt(format!("LAYERS section: duplicate {name}")));
        }
        let c_out = c.u32()? as usize;
        let c_in = c.u32()? as usize;
        let k = c.u32()? as usize;
        let nvals = c_out.checked_mul(k).ok_or_else(|| {
            SnapshotError::Corrupt(format!("LAYERS section: {name} payload size overflow"))
        })?;
        let vals = c.f32s(nvals)?;
        let idx = c.u32s(nvals)?;
        let src_of = c.u32s(c_in)?;
        out.push(SnapshotLayer { name, c_out, c_in, vals, idx, src_of });
    }
    c.finish()?;
    Ok(out)
}

// ---------------------------------------------------------------------
// model-level conveniences
// ---------------------------------------------------------------------

/// Snapshot `model` to `path` (see `docs/SNAPSHOT_FORMAT.md`).
pub fn dump(model: &SparseModel, path: &Path) -> Result<(), SnapshotError> {
    model.to_snapshot().write_to(path)
}

/// Load a servable [`SparseModel`] from a snapshot file.
///
/// Container integrity failures surface as the typed [`SnapshotError`];
/// semantic validation failures (invalid N:M group structure, broken
/// permutation, shape drift vs the config) come from
/// [`SparseModel::from_snapshot`].
pub fn load(path: &Path) -> anyhow::Result<SparseModel> {
    let snap = Snapshot::read_from(path)?;
    SparseModel::from_snapshot(&snap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model_tests::{sparse_model_named, tiny_sparse_model};

    #[test]
    fn fnv1a64_known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn encode_decode_round_trip_is_bit_identical() {
        for nm in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
            let model = sparse_model_named("tiny-s", nm);
            let snap = model.to_snapshot();
            let bytes = snap.encode();
            let back = Snapshot::decode(&bytes).expect("decode own bytes");
            // Property: decode . encode is the identity on the byte level.
            assert_eq!(back.encode(), bytes, "re-encode must be bit-identical at {nm:?}");
            assert_eq!(back.recipe_name, snap.recipe_name);
            assert_eq!(back.nm, nm);
            assert_eq!(back.layers, snap.layers);
        }
    }

    #[test]
    fn wrong_magic_is_typed() {
        let mut bytes = tiny_sparse_model().to_snapshot().encode();
        bytes[0] = b'X';
        match Snapshot::decode(&bytes) {
            Err(SnapshotError::BadMagic { found }) => assert_eq!(&found[1..], &MAGIC[1..]),
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn wrong_version_is_typed() {
        let mut bytes = tiny_sparse_model().to_snapshot().encode();
        bytes[4] = 99; // version u32 LE low byte
        match Snapshot::decode(&bytes) {
            Err(SnapshotError::WrongVersion { found: 99, expected }) => {
                assert_eq!(expected, VERSION)
            }
            other => panic!("expected WrongVersion, got {other:?}"),
        }
    }

    #[test]
    fn truncation_is_typed_at_every_prefix() {
        let bytes = tiny_sparse_model().to_snapshot().encode();
        // Any strict prefix that keeps the magic intact must report
        // Truncated — exercised across header, table, and payload cuts.
        for cut in [4, 8, 11, 12, 40, bytes.len() / 2, bytes.len() - 9, bytes.len() - 1] {
            match Snapshot::decode(&bytes[..cut]) {
                Err(SnapshotError::Truncated { needed, have }) => {
                    assert_eq!(have, cut);
                    assert!(needed > cut, "cut {cut}: needed {needed}");
                }
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn flipped_byte_is_checksum_mismatch() {
        let bytes = tiny_sparse_model().to_snapshot().encode();
        // Flip every byte past the header (one at a time for a sample of
        // positions): the checksum must catch each, without panicking.
        let step = (bytes.len() / 17).max(1);
        for pos in (12..bytes.len() - 8).step_by(step) {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            match Snapshot::decode(&bad) {
                Err(SnapshotError::ChecksumMismatch { stored, computed }) => {
                    assert_ne!(stored, computed)
                }
                // A flip inside a section *length* changes the declared
                // layout itself, so Truncated/Corrupt is also sound.
                Err(SnapshotError::Truncated { .. }) | Err(SnapshotError::Corrupt(_))
                    if pos < 12 + 5 * 12 => {}
                other => panic!("flip at {pos}: expected typed error, got {other:?}"),
            }
        }
    }

    #[test]
    fn checksum_itself_flipped_is_mismatch() {
        let mut bytes = tiny_sparse_model().to_snapshot().encode();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        assert!(matches!(
            Snapshot::decode(&bytes),
            Err(SnapshotError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn trailing_garbage_is_corrupt() {
        let mut bytes = tiny_sparse_model().to_snapshot().encode();
        bytes.extend_from_slice(b"junk");
        assert!(matches!(Snapshot::decode(&bytes), Err(SnapshotError::Corrupt(_))));
    }

    #[test]
    fn hostile_semantic_payload_is_rejected_not_panicking() {
        // A checksum-valid container whose N:M indices are garbage must
        // be rejected by from_snapshot's Compressed::from_parts replay,
        // not panic.  Corrupt one index and re-seal the checksum.
        let model = tiny_sparse_model();
        let mut snap = model.to_snapshot();
        snap.layers[0].idx[0] = u32::MAX;
        let bytes = snap.encode(); // encode re-seals, so the container is valid
        let back = Snapshot::decode(&bytes).expect("container is checksum-valid");
        let err = crate::serve::SparseModel::from_snapshot(&back)
            .expect_err("hostile idx payload must be rejected");
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn file_round_trip_and_io_error() {
        let dir = std::env::temp_dir().join(format!("permllm_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        let model = tiny_sparse_model();
        dump(&model, &path).expect("dump");
        let loaded = load(&path).expect("load");
        assert_eq!(loaded.recipe_name(), model.recipe_name());
        assert!(matches!(
            Snapshot::read_from(&dir.join("missing.bin")),
            Err(SnapshotError::Io(_))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }
}
