//! Compressed N:M storage + sparse matmul (Sparse-Tensor-Core analogue).

use super::{NmConfig, NmMask};
use crate::tensor::Mat;

/// An N:M-sparse weight in compressed form: retained values plus column
/// metadata, `K = C_in / m * keep` entries per output row.
///
/// For 2:4 this halves both storage and the length of every inner product
/// — the mechanism behind the paper's Table 3 speedups. Layout matches
/// `ref.nm_compress_ref` / the `nm_spmm` Pallas kernel: within each group
/// retained entries appear in ascending column order.
#[derive(Debug, Clone)]
pub struct Compressed {
    cfg: NmConfig,
    c_out: usize,
    c_in: usize,
    /// `[C_out, K]` retained values, row-major.
    vals: Vec<f32>,
    /// `[C_out, K]` absolute column indices, row-major.
    idx: Vec<u32>,
}

impl Compressed {
    /// Compress `mask ⊙ w`.
    pub fn compress(w: &Mat, mask: &NmMask) -> Compressed {
        let (c_out, c_in) = w.shape();
        assert_eq!(mask.shape(), (c_out, c_in));
        let cfg = mask.cfg();
        let k = c_in / cfg.m * cfg.keep;
        let mut vals = Vec::with_capacity(c_out * k);
        let mut idx = Vec::with_capacity(c_out * k);
        for r in 0..c_out {
            let row = w.row(r);
            for c in 0..c_in {
                if mask.get(r, c) {
                    vals.push(row[c]);
                    idx.push(c as u32);
                }
            }
            debug_assert_eq!(vals.len(), (r + 1) * k, "mask not N:M at row {r}");
        }
        Compressed { cfg, c_out, c_in, vals, idx }
    }

    /// Rebuild compressed storage from raw buffers (the `sparse_fwd`
    /// artifact's input layout).  Validates entry counts and column-index
    /// bounds; the per-group structure is whatever the producer encoded.
    pub fn from_parts(
        cfg: NmConfig,
        c_out: usize,
        c_in: usize,
        vals: Vec<f32>,
        idx: Vec<u32>,
    ) -> anyhow::Result<Compressed> {
        anyhow::ensure!(cfg.m > 0 && cfg.keep <= cfg.m, "bad N:M config {cfg:?}");
        anyhow::ensure!(c_in % cfg.m == 0, "C_in {c_in} not divisible by M {}", cfg.m);
        let k = c_in / cfg.m * cfg.keep;
        anyhow::ensure!(
            vals.len() == c_out * k && idx.len() == c_out * k,
            "vals/idx have {}/{} entries, expected {}",
            vals.len(),
            idx.len(),
            c_out * k
        );
        anyhow::ensure!(
            idx.iter().all(|&c| (c as usize) < c_in),
            "column index out of range (C_in {c_in})"
        );
        Ok(Compressed { cfg, c_out, c_in, vals, idx })
    }

    pub fn cfg(&self) -> NmConfig {
        self.cfg
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.c_out, self.c_in)
    }

    /// Entries per output row.
    pub fn k(&self) -> usize {
        self.c_in / self.cfg.m * self.cfg.keep
    }

    /// Compressed values `[C_out, K]` (for feeding the sparse_fwd artifact).
    pub fn vals(&self) -> &[f32] {
        &self.vals
    }

    /// Column metadata `[C_out, K]`.
    pub fn idx(&self) -> &[u32] {
        &self.idx
    }

    /// Bytes of storage (values f32 + metadata; the paper's 2-bit NVIDIA
    /// metadata becomes u8 here because groups are small).
    pub fn storage_bytes(&self) -> usize {
        self.vals.len() * 4 + self.idx.len()
    }

    /// Decompress to a dense matrix (zeros at pruned positions).
    pub fn to_dense(&self) -> Mat {
        let k = self.k();
        let mut out = Mat::zeros(self.c_out, self.c_in);
        for r in 0..self.c_out {
            for e in 0..k {
                let c = self.idx[r * k + e] as usize;
                out[(r, c)] = self.vals[r * k + e];
            }
        }
        out
    }

    /// Sparse matmul: `y = x W_sparse^T` for activations `x: [T, C_in]`.
    ///
    /// Each output element is a K-length gather-dot instead of a C_in-length
    /// dense dot — exactly the 2x work reduction of 2:4 sparsity.
    ///
    /// Loop order is output-row-major (§Perf iteration 1): the compressed
    /// row (vals + idx, ~1.5 KB) is loaded once and streamed against every
    /// activation row, instead of re-streaming the whole compressed matrix
    /// (hundreds of KB) per activation row.  The T dimension is tiled so
    /// the touched activation rows stay L2-resident.
    pub fn matmul_xt(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols(), self.c_in);
        let t = x.rows();
        let k = self.k();
        let mut out = Mat::zeros(t, self.c_out);
        const T_TILE: usize = 64;
        let out_cols = self.c_out;
        for t0 in (0..t).step_by(T_TILE) {
            let t1 = (t0 + T_TILE).min(t);
            for o in 0..self.c_out {
                let vals = &self.vals[o * k..(o + 1) * k];
                let idx = &self.idx[o * k..(o + 1) * k];
                for ti in t0..t1 {
                    let xrow = x.row(ti);
                    // 2:4 / 4:8 rows have even K; unroll by 2.
                    let mut acc0 = 0.0f32;
                    let mut acc1 = 0.0f32;
                    let mut e = 0;
                    while e + 1 < k {
                        acc0 += vals[e] * xrow[idx[e] as usize];
                        acc1 += vals[e + 1] * xrow[idx[e + 1] as usize];
                        e += 2;
                    }
                    if e < k {
                        acc0 += vals[e] * xrow[idx[e] as usize];
                    }
                    out.data_mut()[ti * out_cols + o] = acc0 + acc1;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    fn sample(rng: &mut Pcg32, c_out: usize, c_in: usize, cfg: NmConfig) -> (Mat, NmMask) {
        let w = Mat::randn(c_out, c_in, 1.0, rng);
        let m = NmMask::from_scores(&w.map(f32::abs), cfg);
        (w, m)
    }

    #[test]
    fn prop_compress_roundtrips_to_masked_dense() {
        testkit::check("compress-roundtrip", |rng| {
            for cfg in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
                let c_out = 1 + rng.below_usize(6);
                let c_in = cfg.m * (1 + rng.below_usize(6));
                let (w, m) = sample(rng, c_out, c_in, cfg);
                let comp = Compressed::compress(&w, &m);
                let dense = comp.to_dense();
                let want = m.apply(&w);
                testkit::assert_close(dense.data(), want.data(), 1e-7)?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sparse_matmul_matches_masked_dense_matmul() {
        testkit::check("spmm-vs-dense", |rng| {
            let cfg = NmConfig::PAT_2_4;
            let c_out = 2 + rng.below_usize(6);
            let c_in = cfg.m * (2 + rng.below_usize(6));
            let t = 1 + rng.below_usize(5);
            let (w, m) = sample(rng, c_out, c_in, cfg);
            let x = Mat::randn(t, c_in, 1.0, rng);
            let comp = Compressed::compress(&w, &m);
            let got = comp.matmul_xt(&x);
            let want = x.matmul_bt(&m.apply(&w));
            testkit::assert_close(got.data(), want.data(), 1e-5)
        });
    }

    #[test]
    fn storage_is_half_plus_metadata_for_2_4() {
        let mut rng = Pcg32::seeded(1);
        let (w, m) = sample(&mut rng, 8, 64, NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &m);
        let dense_bytes = 8 * 64 * 4;
        assert_eq!(comp.vals().len(), 8 * 32);
        // values: exactly half the dense bytes; metadata adds 1 byte/entry
        // (u8 here vs NVIDIA's 2-bit) => 0.625x dense total.
        assert!(comp.storage_bytes() <= dense_bytes * 65 / 100);
    }

    #[test]
    fn from_parts_roundtrips_and_validates() {
        let mut rng = Pcg32::seeded(3);
        let (w, m) = sample(&mut rng, 4, 16, NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &m);
        let back = Compressed::from_parts(
            comp.cfg(),
            4,
            16,
            comp.vals().to_vec(),
            comp.idx().to_vec(),
        )
        .unwrap();
        assert_eq!(back.to_dense().data(), comp.to_dense().data());
        // Wrong entry count and out-of-range indices are rejected.
        assert!(Compressed::from_parts(comp.cfg(), 4, 16, vec![0.0; 3], vec![0; 3]).is_err());
        let mut bad_idx = comp.idx().to_vec();
        bad_idx[0] = 999;
        assert!(
            Compressed::from_parts(comp.cfg(), 4, 16, comp.vals().to_vec(), bad_idx).is_err()
        );
    }

    #[test]
    fn indices_ascending_within_groups() {
        let mut rng = Pcg32::seeded(2);
        let (w, m) = sample(&mut rng, 4, 16, NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &m);
        let k = comp.k();
        for r in 0..4 {
            let idx = &comp.idx()[r * k..(r + 1) * k];
            for pair in idx.chunks(2) {
                assert!(pair[0] < pair[1]);
            }
        }
    }
}
